#!/usr/bin/env python3
"""Front door for the repro.analysis static-analysis passes.

    PYTHONPATH=src python tools/analyze.py --all
    PYTHONPATH=src python tools/analyze.py --pass ast,jaxpr
    PYTHONPATH=src python tools/analyze.py --all --report artifacts/analysis_report.json

Runs the selected passes (default ``--all``: jaxpr lint + HLO audit over
the full program catalog, the retrace scenario, and the AST lint),
compares every finding against ``benchmarks/analysis_baseline.json``, and
exits non-zero iff any finding is NOT allowlisted there.  Stale baseline
entries (fixed violations) are warnings — delete them.

``--all`` forces ``xla_force_host_platform_device_count=8`` so the
mesh-sharded programs (sharded push, distributed bucket-sort summary,
sharded fused query) are analyzed on CPU exactly like the tier-1-sharded
CI job runs them.  ``--update-baseline`` rewrites the baseline to accept
the current findings — review the diff and fill in the reason strings
before committing.
"""

import argparse
import json
import os
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "src"))

_PASSES = ("jaxpr", "hlo", "retrace", "ast")


def _force_host_devices() -> None:
    # must happen before jax initializes its backends
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + " --xla_force_host_platform_device_count=8").strip()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="VeilGraph static-analysis passes")
    ap.add_argument("--all", action="store_true",
                    help="every pass, incl. mesh-sharded programs "
                         "(forces 8 host devices)")
    ap.add_argument("--pass", dest="passes", type=str, default=None,
                    help=f"comma-separated subset of {_PASSES}")
    ap.add_argument("--baseline", type=Path,
                    default=REPO / "benchmarks" / "analysis_baseline.json")
    ap.add_argument("--report", type=Path, default=None,
                    help="write the JSON findings report here "
                         "(CI uploads it as an artifact)")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite --baseline accepting current findings "
                         "(fill in reason strings before committing)")
    args = ap.parse_args(argv)

    passes = (list(_PASSES) if args.all or not args.passes
              else [p.strip() for p in args.passes.split(",") if p.strip()])
    for p in passes:
        if p not in _PASSES:
            ap.error(f"unknown pass {p!r}; expected subset of {_PASSES}")

    if args.all or "hlo" in passes:
        _force_host_devices()

    from repro.analysis import findings as F

    all_findings = []
    notes = []

    needs_programs = {"jaxpr", "hlo"} & set(passes)
    if needs_programs:
        from repro.analysis import programs as PR

        spec = PR.GraphSpec()
        mesh = PR.default_mesh()
        if mesh is None:
            notes.append("single device: mesh-sharded programs omitted "
                         "(run with XLA_FLAGS="
                         "--xla_force_host_platform_device_count=8)")
        cat = PR.catalog(spec, mesh=mesh)
        print(f"program catalog: {len(cat)} programs at "
              f"N={spec.node_capacity} E={spec.edge_capacity} "
              f"S={spec.num_shards} B={spec.batch}"
              + (f" mesh={mesh.devices.size}dev" if mesh else ""))

        if "jaxpr" in passes:
            from repro.analysis import jaxpr_lint
            for prog in cat:
                got = jaxpr_lint.lint_jaxpr(
                    prog.trace(), program=prog.name,
                    en_threshold=prog.spec.en_threshold,
                    edge_threshold=prog.spec.edge_threshold)
                all_findings.extend(got)
                print(f"  jaxpr  {prog.name}: {len(got)} finding(s)")
        if "hlo" in passes:
            from repro.analysis import hlo_audit
            for prog in cat:
                got = hlo_audit.audit_compiled(
                    prog.compile(), prog.budgets, program=prog.name)
                all_findings.extend(got)
                print(f"  hlo    {prog.name}: {len(got)} finding(s)")

    if "retrace" in passes:
        from repro.analysis import programs as PR
        got = PR.run_retrace_scenario()
        all_findings.extend(got)
        print(f"  retrace engine-loop[pagerank]: {len(got)} finding(s)")
        got = PR.run_async_retrace_scenario()
        all_findings.extend(got)
        print(f"  retrace engine-loop[pagerank,async]: {len(got)} finding(s)")

    if "ast" in passes:
        from repro.analysis import ast_lint
        files = ast_lint.iter_source_files()
        got = ast_lint.lint_files(files)
        all_findings.extend(got)
        print(f"  ast    {len(files)} files: {len(got)} finding(s)")

    baseline = F.load_baseline(args.baseline)
    report = F.render_report(all_findings, baseline, passes_run=passes)
    report["notes"] = notes

    if args.report:
        args.report.parent.mkdir(parents=True, exist_ok=True)
        args.report.write_text(json.dumps(report, indent=1),
                               encoding="utf-8")
        print(f"report -> {args.report}")

    if args.update_baseline:
        args.baseline.parent.mkdir(parents=True, exist_ok=True)
        existing = {e.key: e.reason for e in baseline}
        rows = []
        for f in sorted(all_findings, key=lambda f: f.key):
            rows.append({"rule": f.rule, "where": f.where,
                         "reason": existing.get(
                             f.key, "TODO: justify or fix")})
        args.baseline.write_text(
            json.dumps({"allow": rows}, indent=1) + "\n", encoding="utf-8")
        print(f"baseline rewritten with {len(rows)} entr(ies) -> "
              f"{args.baseline}")
        return 0

    new, matched, stale = F.check(all_findings, baseline, passes_run=passes)
    for f in matched:
        print(f"  allowlisted: {f.key}")
    for e in stale:
        print(f"  STALE baseline entry (violation fixed — delete it): "
              f"{e.key}")
    if new:
        print(f"\nanalyze: {len(new)} NEW finding(s) not in baseline:")
        for f in new:
            print(f"  {f}")
        return 1
    print(f"\nanalyze: OK — {len(all_findings)} finding(s), all "
          f"allowlisted; passes: {', '.join(passes)}")
    return 0


if __name__ == "__main__":
    sys.exit(main())

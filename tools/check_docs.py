#!/usr/bin/env python3
"""Docs gate: intra-repo markdown links resolve + public API is documented.

Two pure-stdlib checks (no jax import, so the CI job needs no deps):

1. **Markdown links** — every relative link target in the repo's tracked
   ``*.md`` files must exist on disk (anchors are stripped; absolute URLs
   and ``mailto:`` are ignored).  Catches docs pointing at renamed files.
2. **Docstrings** — every *public* top-level function and class in the
   graph-system API modules (``PUBLIC_API_MODULES``) must carry a
   docstring, and so must every public method defined directly on the
   classes named in ``STRICT_CLASSES`` (the plugin/engine surfaces users
   subclass or call).  Checked via ``ast``, so decorated/jitted functions
   count like plain ones.

Exit status is non-zero with one line per violation — wire into CI:

    python tools/check_docs.py
"""

from __future__ import annotations

import ast
import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

#: directories whose markdown is not ours to police
SKIP_DIRS = {".git", ".pytest_cache", "artifacts", "node_modules",
             ".claude", "__pycache__"}

#: the documented graph-system surface — every public top-level def/class
#: here must have a docstring (the LM substrate is quarantined and exempt;
#: see README "Repo layout")
PUBLIC_API_MODULES = [
    "src/repro/analysis/ast_lint.py",
    "src/repro/analysis/findings.py",
    "src/repro/analysis/hlo_audit.py",
    "src/repro/analysis/jaxpr_lint.py",
    "src/repro/analysis/programs.py",
    "src/repro/analysis/retrace.py",
    "src/repro/api.py",
    "src/repro/core/algorithm.py",
    "src/repro/core/backend.py",
    "src/repro/core/engine.py",
    "src/repro/core/epoch.py",
    "src/repro/core/fused.py",
    "src/repro/core/hits.py",
    "src/repro/core/hotset.py",
    "src/repro/core/katz.py",
    "src/repro/core/pagerank.py",
    "src/repro/core/policies.py",
    "src/repro/core/semiring.py",
    "src/repro/core/traversal.py",
    "src/repro/graph/csr.py",
    "src/repro/graph/generators.py",
    "src/repro/graph/graph.py",
    "src/repro/graph/partition.py",
    "src/repro/kernels/spmv/ops.py",
    "src/repro/metrics/ranking.py",
    "src/repro/metrics/rbo.py",
    "src/repro/serve/graph.py",
    "src/repro/stream/stream.py",
]

#: classes whose *methods* are part of the public contract (subclassed by
#: users or called directly); public methods defined on them need docs too
STRICT_CLASSES = {"StreamingAlgorithm", "Semiring", "VeilGraphEngine",
                  "VeilGraphSession", "GraphState", "EdgeLayout",
                  "ShardedEdgeLayout", "SummaryBuffers",
                  "GraphServingEngine", "QueryTicket", "ServeStats"}

_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_CODE_FENCE_RE = re.compile(r"```.*?```", re.DOTALL)


def iter_markdown() -> list[Path]:
    out = []
    for p in REPO.rglob("*.md"):
        if not any(part in SKIP_DIRS for part in p.relative_to(REPO).parts):
            out.append(p)
    return sorted(out)


def check_links() -> list[str]:
    errors = []
    for md in iter_markdown():
        text = _CODE_FENCE_RE.sub("", md.read_text(encoding="utf-8"))
        for target in _LINK_RE.findall(text):
            if re.match(r"^[a-z][a-z0-9+.-]*:", target):  # http:, mailto:, …
                continue
            path_part = target.split("#", 1)[0]
            if not path_part:  # pure in-page anchor
                continue
            resolved = (md.parent / path_part).resolve()
            if not resolved.exists():
                errors.append(
                    f"{md.relative_to(REPO)}: broken link -> {target}")
    return errors


def _has_doc(node: ast.AST) -> bool:
    return ast.get_docstring(node) is not None


def check_docstrings() -> list[str]:
    errors = []
    for rel in PUBLIC_API_MODULES:
        path = REPO / rel
        if not path.exists():
            errors.append(f"{rel}: listed in PUBLIC_API_MODULES but missing")
            continue
        tree = ast.parse(path.read_text(encoding="utf-8"))
        if not _has_doc(tree):
            errors.append(f"{rel}: missing module docstring")
        for node in tree.body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                if not node.name.startswith("_") and not _has_doc(node):
                    errors.append(
                        f"{rel}:{node.lineno}: public function "
                        f"{node.name!r} missing docstring")
            elif isinstance(node, ast.ClassDef):
                if not node.name.startswith("_") and not _has_doc(node):
                    errors.append(
                        f"{rel}:{node.lineno}: public class "
                        f"{node.name!r} missing docstring")
                if node.name not in STRICT_CLASSES:
                    continue
                for item in node.body:
                    if (isinstance(item,
                                   (ast.FunctionDef, ast.AsyncFunctionDef))
                            and not item.name.startswith("_")
                            and not _has_doc(item)):
                        errors.append(
                            f"{rel}:{item.lineno}: public method "
                            f"{node.name}.{item.name} missing docstring")
    return errors


def main() -> int:
    errors = check_links() + check_docstrings()
    for e in errors:
        print(e)
    checked = len(PUBLIC_API_MODULES)
    if errors:
        print(f"\ncheck_docs: {len(errors)} violation(s) across "
              f"{checked} API modules + markdown tree")
        return 1
    print(f"check_docs: OK ({checked} API modules, "
          f"{len(iter_markdown())} markdown files)")
    return 0


if __name__ == "__main__":
    sys.exit(main())

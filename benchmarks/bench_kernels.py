"""Kernel/substrate microbenchmarks (CPU wall time of the jnp paths;
Pallas kernels are TPU-target and validated in interpret mode, so CPU wall
times here measure the reference implementations the dry-run lowers).

Emits ``name,us_per_call,derived`` CSV rows.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def _bench(fn, *args, iters=10, warmup=2):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6  # us


def bench_pagerank_iteration():
    from repro.graph import from_edges
    from repro.graph.generators import gnm_edges
    from repro.core.pagerank import pagerank
    src, dst = gnm_edges(50_000, 500_000, seed=0)
    g = from_edges(src, dst, 50_000, 520_000)
    fn = jax.jit(lambda s: pagerank(s, num_iters=30)[0])
    us = _bench(fn, g, iters=3)
    return [("pagerank_exact_30it_500k_edges", us,
             f"{30*520_000/(us/1e6)/1e9:.2f}Gedge/s")]


def bench_summarized_query():
    from repro.graph import from_edges
    from repro.graph.generators import gnm_edges
    from repro.core.fused import approximate_query_step
    from repro.core.pagerank import pagerank
    src, dst = gnm_edges(50_000, 500_000, seed=0)
    g = from_edges(src, dst, 50_000, 520_000)
    ranks, _ = pagerank(g, num_iters=30)
    deg = jnp.copy(g.out_deg)
    act = jnp.copy(g.node_active)
    fn = jax.jit(lambda s, r, d, a: approximate_query_step(
        s, r, d, a, jnp.float32(0.2), jnp.float32(0.1),
        hot_node_capacity=8192, hot_edge_capacity=65536, num_iters=30,
        tol=1e-6)[0])
    us = _bench(fn, g, ranks, deg, act, iters=5)
    return [("veilgraph_query_500k_edges", us, "fused select+summary+iterate")]


def sweep_tune_specs(nodes=50_000, edges=500_000):
    """Autotune keys for the sweep-fixture layouts.  ``run.py --autotune
    full`` measures these into ``benchmarks/autotune_cache.json`` so the
    sweep rows replay tuned geometry from the committed cache."""
    cap = edges + 20_000
    return [
        dict(edge_capacity=cap, num_segments=nodes, reduce="sum"),
        dict(edge_capacity=cap, num_segments=nodes, reduce="min"),
    ]


def _tuned_geometry(g, reduce):
    """Cached-mode geometry for a sweep-fixture layout (the committed
    autotune cache answers when loaded; the analytic argmin otherwise —
    the same resolution the engine does at layout-build time)."""
    from repro.kernels.spmv import autotune as AT

    tile_n, chunk = AT.tune_for_push(
        edge_capacity=g.edge_capacity, num_segments=g.node_capacity,
        reduce=reduce, mode="cached")
    return dict(tile_n=tile_n, chunk=chunk)


def _sweep_fixture(nodes=50_000, edges=500_000):
    """The 500k-edge reference graph + everything a sweep bench needs."""
    from repro.graph import from_edges
    from repro.graph.generators import gnm_edges
    from repro.core import backend as B
    from repro.core.pagerank import build_summary, pagerank

    src, dst = gnm_edges(nodes, edges, seed=0)
    g = from_edges(src, dst, nodes, edges + 20_000)
    layout = B.build_layout(g, weight="inv_out", **_tuned_geometry(g, "sum"))
    ranks, _ = pagerank(g, num_iters=5)
    hot = jnp.asarray(
        np.random.default_rng(0).random(nodes) < 0.15)
    summary = build_summary(g, ranks, hot, hot_node_capacity=8192,
                            hot_edge_capacity=65536)
    return g, layout, ranks, summary


def _minplus_fixture(g):
    """min-plus (SSSP) operands over the same reference graph: a length
    layout, warm distances from a few relaxations, and a min_plus summary."""
    from repro.core import backend as B
    from repro.core.pagerank import build_summary
    from repro.core.traversal import sssp

    nodes = g.node_capacity
    layout = B.build_layout(g, weight="length", semiring="min_plus",
                            **_tuned_geometry(g, "min"))
    source = jnp.zeros((nodes,), bool).at[0].set(True)
    dist, _ = sssp(g, source, num_iters=3, layout=layout,
                   backend="segment_sum")
    hot = jnp.asarray(np.random.default_rng(1).random(nodes) < 0.15)
    summary = build_summary(g, dist, hot, hot_node_capacity=8192,
                            hot_edge_capacity=65536, weight="length",
                            semiring="min_plus")
    return layout, dist, source, summary


def _sharded_cases(g, ranks, live_edges, *, iters, shard_counts=(2, 4, 8)):
    """Sharded push rows (the shard_map partial-push + psum backend): one
    row per host-shard count.  When the process has >= S devices (the CI
    sharded job forces 8 host devices) the row measures the real
    shard_map-ed path over an S-device mesh; otherwise the on-device
    shard-loop reference path — the tag records which.
    """
    from jax.sharding import Mesh
    from repro.core import backend as B
    from repro.graph.partition import (build_sharded_layout,
                                       place_sharded_layout)

    cases = []
    for s_count in shard_counts:
        mesh = None
        if jax.device_count() >= s_count:
            mesh = Mesh(np.asarray(jax.devices()[:s_count]), ("shards",))
        # place once, like the engine cache — otherwise the timed calls
        # would measure per-call redistribution of the edge stream
        layout_s = place_sharded_layout(build_sharded_layout(
            g, mesh=mesh, num_shards=s_count, weight="inv_out"))
        fn = jax.jit(lambda r, lay: B.push(r, lay, backend="segment_sum"))
        us = _bench(fn, ranks, layout_s, iters=iters, warmup=1)
        tag = "mesh" if mesh is not None else "loop"
        cases.append((f"push_sharded_s{s_count}_{tag}", us,
                      f"{live_edges / (us / 1e6) / 1e9:.3f}Gedge/s"))
    return cases


def _sharded_summary_cases(g, ranks, *, iters, sweep_iters, num_shards=8):
    """Sharded-summary + rebalance rows: the distributed-bucket-sort
    ``build_summary`` (vs the replicated compaction, same hot mask), the
    summarized sweep over the resulting per-shard E_K layout, and the
    rebalance recut (counts + imbalance + balanced re-deal).  Mesh path
    when the process has the devices, the shard-loop reference otherwise —
    the tag records which, mirroring the sharded-push rows."""
    from jax.sharding import Mesh
    from repro.core.pagerank import build_summary, summarized_pagerank
    from repro.graph.partition import (balanced_shard_slots,
                                       build_sharded_layout,
                                       place_sharded_layout,
                                       rebalance_sharded_layout)

    nodes = g.node_capacity
    mesh = None
    if jax.device_count() >= num_shards:
        mesh = Mesh(np.asarray(jax.devices()[:num_shards]), ("shards",))
    tag = "mesh" if mesh is not None else "loop"
    layout_s = place_sharded_layout(build_sharded_layout(
        g, mesh=mesh, num_shards=num_shards, weight="inv_out"))
    hot = jnp.asarray(np.random.default_rng(0).random(nodes) < 0.15)
    caps = dict(hot_node_capacity=8192, hot_edge_capacity=65536)

    cases = []
    build_rep = jax.jit(lambda s, r, h: build_summary(s, r, h, **caps))
    us = _bench(build_rep, g, ranks, hot, iters=iters, warmup=1)
    cases.append(("build_summary_replicated", us, "E-space compaction"))
    build_sh = jax.jit(lambda s, r, h, lay: build_summary(
        s, r, h, **caps, layout=lay))
    us = _bench(build_sh, g, ranks, hot, layout_s, iters=iters, warmup=1)
    cases.append((f"build_summary_sharded_s{num_shards}_{tag}", us,
                  "distributed bucket sort"))

    summary_s = build_summary(g, ranks, hot, **caps, layout=layout_s)
    fn = jax.jit(lambda s, r: summarized_pagerank(
        s, r, num_iters=sweep_iters)[0])
    us = _bench(fn, summary_s, ranks, iters=iters, warmup=1)
    cases.append((f"summarized_sweep_sharded_s{num_shards}_{tag}_"
                  f"{sweep_iters}it", us,
                  f"|K|={int(summary_s.num_hot)},"
                  f"|E_K|={int(summary_s.num_ek)}"))

    recut = jax.jit(lambda s: balanced_shard_slots(s, num_shards=num_shards))
    us = _bench(recut, g, iters=iters, warmup=1)
    cases.append((f"rebalance_recut_s{num_shards}", us,
                  "balanced_shard_slots deal"))
    # the full detect-and-recut front door, host round-trip included (what
    # the engine pays once per applied update batch); warm up once so the
    # row measures steady state, not jit compilation, like every other row
    rebalance_sharded_layout(g, num_shards=num_shards, threshold=0.0)
    t0 = time.perf_counter()
    for _ in range(iters):
        _, rebalanced, imb = rebalance_sharded_layout(
            g, num_shards=num_shards, threshold=0.0)
    us = (time.perf_counter() - t0) / iters * 1e6
    cases.append((f"rebalance_detect_s{num_shards}", us,
                  f"imbalance={imb:.3f},recut={rebalanced}"))
    return cases


def _serving_cases(g, ranks, live_edges, *, iters, batch_sizes=(1, 8, 32)):
    """Multi-tenant serving rows: the batched ``[B, N]`` push vs the B-way
    loop of single pushes over the same layout, per batch size, plus one
    end-to-end serving-engine throughput row.  The derived column is
    queries per second (B pushes answered per call for the push rows;
    completed queries over wave wall time for the engine row) — the
    continuous-batching engine's case rests on the batched rows beating
    the looped ones at B >= 8.
    """
    from repro.core import backend as B

    layout = B.build_layout(g, weight="inv_out")
    nodes = g.node_capacity
    rng = np.random.default_rng(7)

    cases = []
    for bsz in batch_sizes:
        vals = jnp.asarray(rng.random((bsz, nodes), np.float32))
        batched = jax.jit(lambda v, lay: B.push(v, lay,
                                                backend="segment_sum"))
        us = _bench(batched, vals, layout, iters=iters, warmup=1)
        cases.append((f"serving_push_batched_b{bsz}", us,
                      f"{bsz / (us / 1e6):.0f}q/s"))
        looped = jax.jit(lambda v, lay, n=bsz: jnp.stack(
            [B.push(v[i], lay, backend="segment_sum") for i in range(n)]))
        us = _bench(looped, vals, layout, iters=iters, warmup=1)
        cases.append((f"serving_push_looped_b{bsz}", us,
                      f"{bsz / (us / 1e6):.0f}q/s"))

    # end-to-end: a slot-4 serving engine draining 8 PPR + 4 SSSP queries
    # over a smaller graph (full waves, refill, harvest — wall time is
    # dominated by trace/compile on the first wave, so report steady state
    # by timing a second drain on the warm engine)
    from repro.api import serve_session
    from repro.graph.generators import gnm_edges

    s_src, s_dst = gnm_edges(2_000, 16_000, seed=3)
    srv = serve_session((s_src, s_dst), slots=4,
                        hot_node_capacity=2_048, hot_edge_capacity=32_768)
    def _drain():
        for s in range(8):
            srv.submit("personalized-pagerank", seeds=(s,))
        for s in range(4):
            srv.submit("sssp", sources=(s,))
        srv.run()
    _drain()  # warm: traces the two lane programs
    waves0, wall0 = srv.stats.waves, srv.stats.wall_s
    t0 = time.perf_counter()
    _drain()
    us = (time.perf_counter() - t0) * 1e6
    waves = srv.stats.waves - waves0
    cases.append(("serving_engine_slots4_12q", us,
                  f"{12 / (us / 1e6):.1f}q/s,{waves}waves"))
    srv.close()
    return cases


def _controller_cases(*, smoke: bool = False):
    """``controller_*`` rows: the closed accuracy loop vs open-loop
    full accuracy on one drifting synthetic stream.

    Three sessions replay the identical stream: an exact oracle
    (ground-truth ranks per step), a ``quality_target=0.95`` closed-loop
    session, and the open-loop full-accuracy configuration (r=0, tiny Δ
    — every churned vertex hot).  Per step we score the approximate
    ranks against the oracle with RBO@100 and charge summarized work as
    E_K + E_B pushed edges (refresh/fallback steps charge the full live
    edge count — the controller pays for its exact recomputes).  The
    returned meta dict carries the acceptance numbers ISSUE 9 pins:
    closed-loop quality >= target with work strictly below open loop.
    """
    from repro.api import Action, session
    from repro.graph.generators import gnm_edges
    from repro.metrics.rbo import rbo_from_scores

    n, m = (600, 4_000) if smoke else (2_000, 16_000)
    steps = 4 if smoke else 10
    chunk = 60 if smoke else 200
    src, dst = gnm_edges(n, m, seed=7)
    rng = np.random.default_rng(11)
    stream = [(rng.integers(0, n, chunk).astype(np.int32),
               rng.integers(0, n, chunk).astype(np.int32))
              for _ in range(steps)]
    caps = dict(node_capacity=n, edge_capacity=m + steps * chunk + 1024)

    def _replay(label, **kw):
        scores, works, wall = [], [], 0.0
        with session((src, dst), algorithm="pagerank", **caps, **kw) as s:
            for a, b in stream:
                s.add_edges(a, b)
                t0 = time.perf_counter()
                res = s.query()
                wall += time.perf_counter() - t0
                st = res.stats
                full = (st.action == "exact" or st.overflow_fallback
                        or getattr(st, "refreshed", False))
                works.append(st.num_edges if full else st.num_ek + st.num_eb)
                scores.append(np.asarray(res.scores))
        return scores, works, wall / steps * 1e6

    exact_scores, _, _ = _replay(
        "exact", on_query=lambda qid, view: Action.EXACT)
    ctl_scores, ctl_work, ctl_us = _replay(
        "closedloop", quality_target=0.95)
    ol_scores, ol_work, ol_us = _replay(
        "openloop", r=0.0, delta=1e-6)

    active = exact_scores[-1] > -np.inf  # all rows; RBO masks via scores
    def _quality(series):
        vals = [float(rbo_from_scores(jnp.asarray(s), jnp.asarray(e),
                                      depth=100))
                for s, e in zip(series, exact_scores)]
        return float(np.mean(vals)), float(np.min(vals))

    q_ctl, q_ctl_min = _quality(ctl_scores)
    q_ol, _ = _quality(ol_scores)
    w_ctl = float(np.mean(ctl_work))
    w_ol = float(np.mean(ol_work))
    cases = [
        ("controller_closedloop_query", ctl_us,
         f"q={q_ctl:.4f},min={q_ctl_min:.4f},work={w_ctl:.0f}e/q"),
        ("controller_openloop_full_query", ol_us,
         f"q={q_ol:.4f},work={w_ol:.0f}e/q"),
    ]
    meta = {
        "quality_target": 0.95,
        "quality": q_ctl,
        "quality_min": q_ctl_min,
        "work_per_query": w_ctl,
        "openloop_quality": q_ol,
        "openloop_work_per_query": w_ol,
        "stream": {"nodes": n, "edges": m, "steps": steps, "chunk": chunk},
    }
    return cases, meta


def _async_overlap_cases(*, smoke: bool = False):
    """``async_overlap_*`` rows: query latency during a write burst,
    synchronous vs async-rebuild (``async_rebuild=True``) engine.

    Two sessions replay the identical stream — every query preceded by a
    ``chunk``-edge write burst, every query followed by a fixed host
    think-time (the inter-query gap a serving loop naturally has).  The
    sync engine pays layout sort + summary rebuild inside ``query()``;
    the async engine dispatches the same rebuild un-awaited, so it drains
    into the think-time gap and the measured ``query()`` wall collapses
    to the fused step + stats fetch.  Rows are query-wall p50/p95 per
    mode; the meta dict carries the ISSUE 10 acceptance number (async
    p95 < sync p95 under the burst).
    """
    from repro.api import session
    from repro.graph.generators import gnm_edges

    n, m = (4_000, 30_000) if smoke else (20_000, 120_000)
    steps = 8 if smoke else 30
    chunk = 256 if smoke else 1024
    # think-time sized to absorb the deferred rebuild (~35ms of layout
    # sort + preserving apply at the full config): shorter gaps push the
    # un-drained remainder onto the next query's fetch and the async
    # advantage shrinks toward zero
    think_s = 0.05
    src, dst = gnm_edges(n, m, seed=5)
    rng = np.random.default_rng(3)
    stream = [(rng.integers(0, n, chunk).astype(np.int32),
               rng.integers(0, n, chunk).astype(np.int32))
              for _ in range(steps)]
    caps = dict(node_capacity=n, edge_capacity=m + steps * chunk + 1024,
                update_pad=chunk)

    def _replay(async_rebuild):
        lats = []
        with session((src, dst), algorithm="pagerank",
                     async_rebuild=async_rebuild, **caps) as s:
            for a, b in stream:
                s.add_edges(a, b)
                t0 = time.perf_counter()
                s.query()
                lats.append(time.perf_counter() - t0)
                time.sleep(think_s)   # think-time: async dispatch drains here
        return np.asarray(lats[2:]) * 1e6  # drop compile warm-up queries

    sync_us = _replay(False)
    async_us = _replay(True)
    pct = lambda a, q: float(np.percentile(a, q))
    s50, s95 = pct(sync_us, 50), pct(sync_us, 95)
    a50, a95 = pct(async_us, 50), pct(async_us, 95)
    burst = f"burst={chunk}e,think={think_s * 1e3:.0f}ms"
    cases = [
        ("async_overlap_sync_query_p50", s50, burst),
        ("async_overlap_sync_query_p95", s95, burst),
        ("async_overlap_async_query_p50", a50,
         f"{burst},x{s50 / a50:.2f} vs sync"),
        ("async_overlap_async_query_p95", a95,
         f"{burst},x{s95 / a95:.2f} vs sync"),
    ]
    meta = {
        "stream": {"nodes": n, "edges": m, "steps": steps, "chunk": chunk},
        "think_time_us": think_s * 1e6,
        "sync_p50_us": s50, "sync_p95_us": s95,
        "async_p50_us": a50, "async_p95_us": a95,
        "p95_speedup": s95 / a95,
    }
    return cases, meta


def bench_sweep_backends(*, smoke: bool = False, nodes=50_000, edges=500_000):
    """Backend-vs-backend rows: a plus_times push + summarized PageRank
    sweep, and a min_plus push + summarized SSSP sweep, per backend on the
    500k-edge reference graph, plus sharded-push rows over 2/4/8 host
    shards, the sharded-summary / rebalance rows (distributed bucket
    sort vs replicated compaction, recut cost), and the serving rows
    (batched [B, N] push vs the B-way loop, engine throughput).  The
    pallas rows run in interpret mode off-TPU — they track kernel-logic
    cost trajectory, not TPU wall time (the dry-run covers that); the
    min_plus rows exercise the masked-reduce kernel variant instead of
    the one-hot matmul.
    Returns (rows, records); the records feed BENCH_sweeps.json.
    """
    from repro.core import backend as B
    from repro.core.pagerank import summarized_pagerank
    from repro.core.traversal import summarized_sssp

    g, layout, ranks, summary = _sweep_fixture(nodes, edges)
    mp_layout, dist, source, mp_summary = _minplus_fixture(g)
    iters = 1 if smoke else 3
    sweep_iters = 1 if smoke else 30
    interpret = B.default_interpret()
    live_edges = int(g.num_live_edges())

    cases = []
    for backend in ("segment_sum", "pallas"):
        tag = f"{backend}{'_interp' if backend == 'pallas' and interpret else ''}"
        push_fn = jax.jit(lambda r, lay, b=backend: B.push(
            r, lay, backend=b, interpret=interpret))
        us = _bench(push_fn, ranks, layout, iters=iters, warmup=1)
        cases.append((f"push_exact_{tag}_{edges // 1000}k", us,
                      f"{live_edges / (us / 1e6) / 1e9:.3f}Gedge/s"))
        summ_fn = jax.jit(lambda s, r, b=backend: summarized_pagerank(
            s, r, num_iters=sweep_iters, backend=b)[0])
        us = _bench(summ_fn, summary, ranks, iters=iters, warmup=1)
        cases.append((f"summarized_sweep_{sweep_iters}it_{tag}", us,
                      f"|K|={int(summary.num_hot)},|E_K|={int(summary.num_ek)}"))
        mp_push_fn = jax.jit(lambda d, lay, b=backend: B.push(
            d, lay, semiring="min_plus", backend=b, interpret=interpret))
        us = _bench(mp_push_fn, dist, mp_layout, iters=iters, warmup=1)
        cases.append((f"push_minplus_{tag}_{edges // 1000}k", us,
                      f"{live_edges / (us / 1e6) / 1e9:.3f}Gedge/s"))
        mp_sweep_fn = jax.jit(lambda s, d, m, b=backend: summarized_sssp(
            s, d, m, num_iters=sweep_iters, backend=b)[0])
        us = _bench(mp_sweep_fn, mp_summary, dist, source, iters=iters,
                    warmup=1)
        cases.append((f"summarized_sssp_{sweep_iters}it_{tag}", us,
                      f"|K|={int(mp_summary.num_hot)},"
                      f"|E_K|={int(mp_summary.num_ek)}"))
    cases.extend(_sharded_cases(g, ranks, live_edges, iters=iters))
    cases.extend(_sharded_summary_cases(g, ranks, iters=iters,
                                        sweep_iters=sweep_iters))
    cases.extend(_serving_cases(g, ranks, live_edges, iters=iters))
    controller_cases, controller_meta = _controller_cases(smoke=smoke)
    cases.extend(controller_cases)
    overlap_cases, overlap_meta = _async_overlap_cases(smoke=smoke)
    cases.extend(overlap_cases)
    records = [
        {"name": name, "us_per_call": round(us, 1), "derived": derived,
         # pallas rows carry _interp in the name when they ran in interpret
         # mode; everything else (and on-TPU pallas) is a compiled timing
         "mode": "interpret" if "_interp" in name else "compiled"}
        for name, us, derived in cases
    ]
    meta = {
        "graph": {"nodes": nodes, "edges": edges, "live_edges": live_edges},
        "interpret": interpret,
        "device": jax.default_backend(),
        "platform": jax.default_backend(),
        "device_kind": jax.devices()[0].device_kind,
        "device_count": jax.device_count(),
        "smoke": smoke,
        "sweep_iters": sweep_iters,
        # geometry the full-graph push layouts were built with (autotuned
        # when benchmarks/autotune_cache.json was loaded first)
        "push_geometry": {
            "plus_times": [layout.tile_n, layout.tile_chunk],
            "min_plus": [mp_layout.tile_n, mp_layout.tile_chunk],
        },
        # ISSUE 9 acceptance numbers: closed-loop quality/work vs the
        # open-loop full-accuracy replay of the same drifting stream
        "controller": controller_meta,
        # ISSUE 10 acceptance numbers: query p50/p95 during a write
        # burst, sync vs async-rebuild engine (async p95 must win)
        "async_overlap": overlap_meta,
    }
    return cases, {"meta": meta, "rows": records}


def bench_kernel_matrix(*, smoke: bool = False, autotune_mode: str = "cached"):
    """``--only kernels``: the per-geometry kernel matrix.

    Times both kernel variants — the one-hot-matmul sum push and the
    segmented-scan masked reduce — across the autotuner's ``(tile_n,
    chunk)`` candidate grid on a synthetic sorted edge stream, then pits
    the autotuned geometry against the hardcoded ``(TILE_N, CHUNK)``
    defaults on a summary-shaped stream (small destination space) where
    the defaults leave time on the table.  Off-TPU the kernels run in
    interpret mode; rows are tagged so the artifact records which.

    ``autotune_mode`` is the :func:`repro.kernels.spmv.autotune.tune` mode
    used for the tuned-vs-default rows: ``"full"`` times the whole pruned
    candidate grid (this is how ``benchmarks/autotune_cache.json`` is
    regenerated), ``"cached"`` replays a loaded cache (the CI smoke path).

    Returns (rows, record) shaped like :func:`bench_sweep_backends`.
    """
    from repro.core import backend as B
    from repro.kernels.spmv import autotune as AT
    from repro.kernels.spmv.kernel import CHUNK, TILE_N

    interpret = B.default_interpret()
    itag = "_interp" if interpret else ""
    iters = 1 if smoke else 3
    platform = jax.default_backend()

    # matrix shape: mid-sized stream, full destination space
    mx_n, mx_e = (2_048, 16_384) if smoke else (8_192, 131_072)
    tiles = (128, 512) if smoke else AT.TILE_N_CANDIDATES
    chunks = (256, 1024) if smoke else AT.CHUNK_CANDIDATES

    cases = []
    for reduce in ("sum", "min"):
        key = AT.TuneKey(e_pad=mx_e, n=mx_n, b=1, dtype="float32",
                         reduce=reduce, platform=platform)
        for tile_n in tiles:
            for chunk in chunks:
                cost = AT.modeled_push_cost(
                    e_pad=mx_e, n=mx_n, reduce=reduce,
                    tile_n=tile_n, chunk=chunk)
                if cost.vmem_bytes > AT.VMEM_LIMIT_BYTES:
                    continue
                us = AT._time_candidate(key, tile_n, chunk,
                                        interpret=interpret,
                                        iters=iters) * 1e6
                cases.append((
                    f"kernel_{reduce}_t{tile_n}_c{chunk}{itag}", us,
                    f"modeled={cost.bound_time_s * 1e6:.2f}us,"
                    f"hbm={cost.hbm_bytes / 1e6:.2f}MB"))

    # tuned vs hardcoded defaults on a non-default (summary-shaped) stream.
    # The shape is identical in smoke and full runs so the committed
    # autotune cache covers the CI smoke replay.
    cmp_n, cmp_e = 1_024, 65_536
    for reduce in ("sum", "min"):
        key = AT.TuneKey(e_pad=cmp_e, n=cmp_n, b=1, dtype="float32",
                         reduce=reduce, platform=platform)
        tile_t, chunk_t = AT.tune(key, autotune_mode, measure_top=99)
        us_t = AT._time_candidate(key, tile_t, chunk_t,
                                  interpret=interpret, iters=iters) * 1e6
        us_d = AT._time_candidate(key, TILE_N, CHUNK,
                                  interpret=interpret, iters=iters) * 1e6
        cases.append((f"kernel_{reduce}_tuned_summary1k{itag}", us_t,
                      f"t{tile_t}xc{chunk_t},{us_d / us_t:.2f}x vs default"))
        cases.append((f"kernel_{reduce}_default_summary1k{itag}", us_d,
                      f"t{TILE_N}xc{CHUNK}"))

    records = [
        {"name": name, "us_per_call": round(us, 1), "derived": derived,
         "mode": "interpret" if "_interp" in name else "compiled"}
        for name, us, derived in cases
    ]
    meta = {
        "matrix_shape": {"nodes": mx_n, "edges": mx_e},
        "compare_shape": {"nodes": cmp_n, "edges": cmp_e},
        "interpret": interpret,
        "platform": platform,
        "device_kind": jax.devices()[0].device_kind,
        "smoke": smoke,
        "autotune_mode": autotune_mode,
    }
    return cases, {"meta": meta, "rows": records}


def bench_attention():
    from repro.models.layers import blocked_attention
    rows = []
    for (s, name) in ((1024, "attn_fwd_s1024"), (4096, "attn_fwd_s4096")):
        q = jnp.ones((1, s, 8, 64), jnp.bfloat16)
        k = jnp.ones((1, s, 2, 64), jnp.bfloat16)
        v = jnp.ones((1, s, 2, 64), jnp.bfloat16)
        fn = jax.jit(lambda q, k, v: blocked_attention(q, k, v, causal=True))
        us = _bench(fn, q, k, v, iters=3)
        flops = 4 * s * s * 8 * 64 / 2  # causal
        rows.append((name, us, f"{flops/(us/1e6)/1e9:.1f}GFLOP/s"))
    return rows


def bench_decode_step():
    from repro.configs import get_smoke_config
    from repro.models.params import init_params
    from repro.models.transformer import lm_prefill, lm_decode_step
    cfg = get_smoke_config("yi_9b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    toks = jnp.ones((4, 64), jnp.int32)
    _, cache = lm_prefill(params, cfg, toks, cache_len=256)
    step = jax.jit(lambda p, c, t, pos: lm_decode_step(p, cfg, c, t, pos))
    tok = jnp.ones((4, 1), jnp.int32)
    us = _bench(step, params, cache, tok, jnp.int32(64), iters=5)
    return [("decode_step_smoke_yi", us, f"{4/(us/1e6):.0f}tok/s")]


def bench_moe_dispatch():
    from repro.configs import get_smoke_config
    from repro.models.moe import moe_mlp
    from repro.models.params import init_params
    cfg = get_smoke_config("mixtral_8x22b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    lp = jax.tree_util.tree_map(lambda a: a[0], params["blocks"]["mlp"])
    x = jnp.ones((4, 128, cfg.d_model), jnp.bfloat16)
    fn = jax.jit(lambda p, x: moe_mlp(p, x, cfg))
    us = _bench(fn, lp, x, iters=5)
    return [("moe_dispatch_4x128_e4top2", us, "scan-over-experts")]


# bench_sweep_backends is invoked by benchmarks.run (it also feeds the
# BENCH_sweeps.json artifact), not by the CSV-only main() below.
ALL = [bench_pagerank_iteration, bench_summarized_query, bench_attention,
       bench_decode_step, bench_moe_dispatch]


def main():
    rows = []
    for b in ALL:
        try:
            rows.extend(b())
        except Exception as e:  # keep the harness running
            rows.append((b.__name__, -1, f"ERROR {type(e).__name__}: {e}"))
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    return rows


if __name__ == "__main__":
    main()

"""The paper's evaluation protocol (§5): 18 (r, n, Δ) combos × datasets.

For each dataset: ground-truth replay (exact PageRank every query) plus one
summarized replay per parameter combo, Q=50 queries each, shuffled streams.
Emits one JSON per (dataset, combo) with the per-query series of the
paper's four metrics — summary vertex ratio (Figs 3/7/11/15/19/23/27),
summary edge ratio (Figs 4/8/12/16/20/24/28), RBO (Figs 5/9/13/17/21/25/29)
and speedup (Figs 6/10/14/18/22/26/30) — into artifacts/paper_sweep/.

  PYTHONPATH=src python -m benchmarks.paper_sweep --datasets synth-citation
  PYTHONPATH=src python -m benchmarks.paper_sweep --full
  PYTHONPATH=src python -m benchmarks.paper_sweep --algorithm hits

Runs through the session front door (`repro.api.session`), so `--algorithm`
sweeps any registered StreamingAlgorithm with the same protocol.
"""

from __future__ import annotations

import argparse
import itertools
import json
import time
from pathlib import Path

import numpy as np

import repro as veilgraph
from repro.core import Action
from repro.core.policies import always
from repro.graph.generators import DATASETS, generate
from repro.metrics import rbo_from_scores
from repro.stream import StreamConfig, build_stream

ART = Path(__file__).resolve().parent.parent / "artifacts" / "paper_sweep"

# the paper's §5.2 parameter grid: 18 combos
R_VALUES = (0.10, 0.20, 0.30)
N_VALUES = (0, 1)
DELTA_VALUES = (0.01, 0.10, 0.90)


def _pow2(x: int) -> int:
    n = 1
    while n < x:
        n *= 2
    return n


def _session_knobs(spec, stream, r, n, delta, hot_nodes=None,
                   hot_edges=None) -> dict:
    n_cap = spec.nodes
    e_cap = int(stream.total_edges * 1.1) + 1024
    return dict(
        node_capacity=n_cap, edge_capacity=e_cap,
        hot_node_capacity=min(hot_nodes or n_cap, n_cap),
        hot_edge_capacity=min(hot_edges or e_cap, e_cap),
        r=r, n=n, delta=delta, num_iters=30, tol=1e-6,
    )


def calibrate_capacities(spec, stream, algorithm, r, n, delta,
                         probe_queries=5):
    """Capacity planning: probe the first queries with generous buffers and
    size the hot buffers to ~1.5x the observed peak (pow2-bucketed so combos
    share compilations).  This is the deployment-realistic counterpart of the
    paper's dynamically-sized Flink summary; overflow at runtime falls back
    to exact recomputation and is recorded."""
    sess = veilgraph.session(stream, algorithm,
                             **_session_knobs(spec, stream, r, n, delta))
    max_hot, max_ek = 1, 1
    for q, res in enumerate(sess.play()):
        if q >= probe_queries:
            break
        max_hot = max(max_hot, res.stats.num_hot)
        max_ek = max(max_ek, res.stats.num_ek + 1)
    return (max(2048, _pow2(int(1.5 * max_hot))),
            max(8192, _pow2(int(1.5 * max_ek))))


def ground_truth(spec, stream, algorithm, queries):
    sess = veilgraph.session(stream, algorithm,
                             on_query=always(Action.EXACT),
                             **_session_knobs(spec, stream, 0.2, 1, 0.1))
    ranks, times = [], []
    for res in sess.play():
        ranks.append(res.scores)
        times.append(res.stats.wall_time_s)
    return ranks, times


def run_combo(spec, stream, algorithm, r, n, delta, gt_ranks, gt_times,
              depth):
    hot_nodes, hot_edges = calibrate_capacities(
        spec, stream, algorithm, r, n, delta)
    knobs = _session_knobs(spec, stream, r, n, delta, hot_nodes, hot_edges)
    sess = veilgraph.session(stream, algorithm, **knobs)
    rows = []
    for q, res in enumerate(sess.play()):
        st = res.stats
        rbo = rbo_from_scores(res.scores, gt_ranks[q], depth=depth,
                              active=np.asarray(sess.engine.state.node_active))
        rows.append({
            "q": q,
            "vertex_ratio": st.vertex_ratio,
            "edge_ratio": st.edge_ratio,
            "rbo": rbo,
            "speedup": gt_times[q] / max(st.wall_time_s, 1e-9),
            "num_hot": st.num_hot, "num_ek": st.num_ek, "num_eb": st.num_eb,
            "fallback": bool(st.overflow_fallback),
            "iterations": st.iterations,
        })
    # record the capacities the engine actually ran with (the calibrated
    # values are clamped to the graph capacities inside _session_knobs)
    return rows, (knobs["hot_node_capacity"], knobs["hot_edge_capacity"])


def sweep_dataset(name: str, queries: int = 50, shuffle: bool = True,
                  seed: int = 7, combos=None, verbose=True,
                  algorithm: str = "pagerank"):
    ART.mkdir(parents=True, exist_ok=True)
    spec = DATASETS[name]
    src, dst = generate(spec, seed=0)
    sc = StreamConfig(stream_size=spec.stream_size, num_queries=queries,
                      shuffle=shuffle, seed=seed)
    stream = build_stream(src, dst, sc)
    depth = 1000 if sc.edges_per_query <= 200 else 4000
    if verbose:
        print(f"[{name}] V~{stream.total_nodes} E={stream.total_edges} "
              f"chunk={sc.edges_per_query} rbo_depth={depth}")
    t0 = time.time()
    gt_ranks, gt_times = ground_truth(spec, stream, algorithm, queries)
    if verbose:
        print(f"  ground truth: {time.time()-t0:.1f}s "
              f"(mean query {1e3*np.mean(gt_times[1:]):.1f} ms)")

    combos = combos or list(itertools.product(R_VALUES, N_VALUES, DELTA_VALUES))
    results = {}
    for r, n, delta in combos:
        t0 = time.time()
        rows, cfg_used = run_combo(spec, stream, algorithm, r, n, delta,
                                   gt_ranks, gt_times, depth)
        key = f"r{r}_n{n}_d{delta}"
        results[key] = rows
        if not rows:
            raise SystemExit(
                f"[{name}] combo {key}: the replay produced no query rows "
                f"(empty stream?) — nothing to summarize")
        # the warm-up query is skipped when there is more than one row
        w = rows[1:] or rows
        summary = {
            "vertex_ratio": float(np.mean([x["vertex_ratio"] for x in w])),
            "edge_ratio": float(np.mean([x["edge_ratio"] for x in w])),
            "rbo": float(np.mean([x["rbo"] for x in w])),
            "rbo_final": w[-1]["rbo"],
            "speedup": float(np.mean([x["speedup"] for x in w])),
            "speedup_min": float(np.min([x["speedup"] for x in w])),
            "fallbacks": int(np.sum([x["fallback"] for x in w])),
        }
        out = {"dataset": name, "algorithm": algorithm,
               "r": r, "n": n, "delta": delta,
               "queries": queries, "shuffle": shuffle,
               "hot_node_capacity": cfg_used[0],
               "hot_edge_capacity": cfg_used[1],
               "summary": summary, "rows": rows}
        suffix = "" if algorithm == "pagerank" else f"__{algorithm}"
        (ART / f"{name}{suffix}__{key}.json").write_text(json.dumps(out))
        if verbose:
            print(f"  r={r} n={n} Δ={delta}: vr={summary['vertex_ratio']:.3f} "
                  f"er={summary['edge_ratio']:.3f} rbo={summary['rbo']:.4f} "
                  f"speedup={summary['speedup']:.2f} "
                  f"({time.time()-t0:.1f}s)")
    return results


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--datasets", nargs="*",
                    default=["synth-citation", "synth-social"])
    ap.add_argument("--full", action="store_true",
                    help="all datasets × all 18 combos")
    ap.add_argument("--queries", type=int, default=50)
    ap.add_argument("--no-shuffle", action="store_true")
    ap.add_argument("--algorithm", default="pagerank",
                    choices=sorted(veilgraph.available_algorithms()))
    args = ap.parse_args(argv)
    names = sorted(DATASETS) if args.full else args.datasets
    for name in names:
        sweep_dataset(name, queries=args.queries,
                      shuffle=not args.no_shuffle,
                      algorithm=args.algorithm)


if __name__ == "__main__":
    main()

"""Render the roofline table from the dry-run artifacts (§Roofline).

Reads artifacts/dryrun/<mesh>/*.json (produced by repro.launch.dryrun) —
re-running the dry-run requires 512 host devices, so this module only
formats; the dry-run itself is a separate process.
"""

from __future__ import annotations

import glob
import json
from pathlib import Path

ART = Path(__file__).resolve().parent.parent / "artifacts" / "dryrun"


def load(mesh: str):
    rows = []
    for f in sorted(glob.glob(str(ART / mesh / "*.json"))):
        r = json.load(open(f))
        rows.append(r)
    return rows


def _dominant(rf):
    if "dominant" in rf:
        return rf["dominant"]
    t = {"compute": rf["compute_s"], "memory": rf["memory_s"],
         "collective": rf["collective_s"]}
    return max(t, key=t.get)


def _frac(rf):
    if "roofline_fraction" in rf:
        return rf["roofline_fraction"]
    useful = (rf["model_flops"] / rf["chips"]) / 197e12
    b = max(rf["compute_s"], rf["memory_s"], rf["collective_s"])
    return useful / b if b else 0.0


def _ratio(rf):
    if "useful_flops_ratio" in rf:
        return rf["useful_flops_ratio"]
    tot = rf["flops_per_device"] * rf["chips"]
    return rf["model_flops"] / tot if tot else 0.0


def table(mesh: str = "single") -> str:
    rows = load(mesh)
    ok = [r for r in rows if r["status"] == "ok"]
    skipped = [r for r in rows if r["status"] == "skipped"]
    out = []
    hdr = (f"{'arch':24s} {'shape':14s} {'comp_s':>9s} {'mem_s':>9s} "
           f"{'coll_s':>9s} {'dom':>10s} {'6ND/HLO':>8s} {'frac':>7s} "
           f"{'args_GiB':>8s} {'temp_GiB':>8s}")
    out.append(hdr)
    out.append("-" * len(hdr))
    for r in sorted(ok, key=lambda x: (x["arch"], str(x["shape"]))):
        rf = r["roofline"]
        ms = rf.get("memory_stats") or {}
        out.append(
            f"{rf['arch']:24s} {str(rf['shape']):14s} {rf['compute_s']:9.3f} "
            f"{rf['memory_s']:9.3f} {rf['collective_s']:9.3f} "
            f"{_dominant(rf):>10s} {_ratio(rf):8.3f} "
            f"{_frac(rf):7.4f} "
            f"{ms.get('argument_bytes', 0)/2**30:8.2f} "
            f"{ms.get('temp_bytes', 0)/2**30:8.2f}")
    for r in skipped:
        out.append(f"{r['arch']:24s} {r['shape']:14s} "
                   f"   -- skipped: {r['reason'][:60]}")
    return "\n".join(out)


def main():
    for mesh in ("single", "multi"):
        if (ART / mesh).exists():
            print(f"\n=== roofline table: {mesh}-pod mesh ===")
            print(table(mesh))


if __name__ == "__main__":
    main()

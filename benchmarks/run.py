"""Benchmark harness entry point — one bench per paper table/figure family.

Prints ``name,us_per_call,derived`` CSV rows (kernel/microbenches), the
paper-protocol summary per (dataset × combo) from cached sweep artifacts
(benchmarks.paper_sweep produces them; a small live sweep runs if absent),
and the roofline tables from the dry-run artifacts.  Every run also emits a
machine-readable ``BENCH_sweeps.json`` (backend-vs-backend push/sweep
timings on the 500k-edge reference graph) so the propagation-backend perf
trajectory is tracked per PR.

  PYTHONPATH=src python -m benchmarks.run                 # everything
  PYTHONPATH=src python -m benchmarks.run --only sweeps   # backend rows only
  PYTHONPATH=src python -m benchmarks.run --only sweeps --smoke   # CI: 1 it
  PYTHONPATH=src python -m benchmarks.run --only kernels --autotune full
                                          # regen kernel matrix + tune cache
"""

from __future__ import annotations

import argparse
import glob
import json
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
ART = ROOT / "artifacts"

SWEEPS_JSON = ROOT / "BENCH_sweeps.json"
KERNELS_JSON = ROOT / "BENCH_kernels.json"
AUTOTUNE_CACHE = ROOT / "benchmarks" / "autotune_cache.json"


def sweeps_summary(*, smoke: bool = False, out_path: Path = None):
    """Backend-vs-backend sweep rows + the BENCH_sweeps.json artifact.

    Smoke runs (1 iteration — what CI executes) land in the gitignored
    ``artifacts/`` dir so they never clobber the tracked perf-trajectory
    file at the repo root.

    The committed autotune cache is loaded first so the push layouts are
    built at measured-tuned geometry (meta.push_geometry records it).
    """
    from benchmarks.bench_kernels import bench_sweep_backends
    from repro.kernels.spmv import autotune as AT

    added = AT.load_cache(AUTOTUNE_CACHE)
    print(f"# autotune cache: {added} entries loaded from "
          f"{AUTOTUNE_CACHE.relative_to(ROOT)}")

    if out_path is None:
        out_path = ART / "BENCH_sweeps_smoke.json" if smoke else SWEEPS_JSON
    print("\n# propagation backends (segment_sum vs sorted pallas push; "
          "pallas is interpret-mode off-TPU)")
    rows, record = bench_sweep_backends(smoke=smoke)
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    out_path.parent.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(record, indent=2) + "\n")
    print(f"# wrote {out_path}")
    return record


def kernels_summary(*, smoke: bool = False, autotune: str = "cached",
                    out_path: Path = None):
    """Per-geometry kernel matrix rows + the BENCH_kernels.json artifact.

    ``--autotune cached`` (the default, and what the CI autotune-smoke step
    runs) replays the committed ``benchmarks/autotune_cache.json``;
    ``--autotune full`` re-times the candidate grid and rewrites that cache
    alongside the bench artifact; ``--autotune off`` benches the hardcoded
    defaults as the "tuned" rows (a no-tuning control).
    """
    from benchmarks.bench_kernels import bench_kernel_matrix
    from repro.kernels.spmv import autotune as AT

    if autotune == "cached":
        added = AT.load_cache(AUTOTUNE_CACHE)
        print(f"# autotune cache: {added} entries loaded from "
              f"{AUTOTUNE_CACHE.relative_to(ROOT)}")
    if out_path is None:
        out_path = ART / "BENCH_kernels_smoke.json" if smoke else KERNELS_JSON
    print("\n# kernel geometry matrix (both push variants x (tile_n, chunk)"
          " grid; pallas is interpret-mode off-TPU)")
    rows, record = bench_kernel_matrix(smoke=smoke, autotune_mode=autotune)
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    out_path.parent.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(record, indent=2) + "\n")
    print(f"# wrote {out_path}")
    if autotune == "full":
        # also measure the sweep-fixture keys (the 500k reference-graph
        # layouts) so `--only sweeps` replays tuned geometry from the
        # committed cache; measure the whole pruned grid — the analytic
        # ranking targets the TPU roofline, which need not match the
        # platform actually being timed
        from benchmarks.bench_kernels import sweep_tune_specs
        for spec in sweep_tune_specs():
            AT.tune_for_push(**spec, mode="full", measure_top=99)
        AT.save_cache(AUTOTUNE_CACHE)
        print(f"# wrote {AUTOTUNE_CACHE.relative_to(ROOT)} "
              f"({len(AT.cache_entries())} measured entries)")
    return record


def paper_summary():
    pattern = str(ART / "paper_sweep" / "*.json")
    files = sorted(glob.glob(pattern))
    if not files:
        # an existing-but-empty artifacts/paper_sweep/ (e.g. a killed sweep)
        # takes the same path as a missing one: run the reduced live sweep,
        # then glob AGAIN — and fail with a clear message rather than
        # crashing downstream if the live sweep produced nothing either
        print("# no paper_sweep artifacts; running a reduced live sweep "
              "(synth-citation, 4 combos, Q=20)")
        from benchmarks.paper_sweep import sweep_dataset
        sweep_dataset("synth-citation", queries=20,
                      combos=[(0.10, 1, 0.01), (0.20, 1, 0.10),
                              (0.30, 0, 0.90), (0.30, 1, 0.90)])
        files = sorted(glob.glob(pattern))
        if not files:
            raise SystemExit(
                f"paper_summary: the reduced live sweep left no artifacts "
                f"matching {pattern} — run `python -m benchmarks.paper_sweep`"
                f" manually and check its output for errors")
    print("\n# paper protocol: dataset,combo,vertex_ratio,edge_ratio,"
          "rbo_mean,rbo_final,speedup_mean,speedup_min,fallbacks")
    best = {}
    for f in files:
        r = json.load(open(f))
        s = r["summary"]
        key = f"r{r['r']}_n{r['n']}_d{r['delta']}"
        print(f"paper,{r['dataset']},{key},{s['vertex_ratio']:.4f},"
              f"{s['edge_ratio']:.4f},{s['rbo']:.4f},{s['rbo_final']:.4f},"
              f"{s['speedup']:.2f},{s['speedup_min']:.2f},{s['fallbacks']}")
        d = best.setdefault(r["dataset"], {"speedup": 0.0, "rbo_at": 0.0})
        if s["rbo"] > 0.95 and s["speedup"] > d["speedup"]:
            d["speedup"] = s["speedup"]
            d["rbo_at"] = s["rbo"]
    print("\n# headline (best speedup with RBO > 0.95, the paper's claim "
          "regime):")
    for ds, d in sorted(best.items()):
        print(f"headline,{ds},speedup={d['speedup']:.2f}x,rbo={d['rbo_at']:.4f}")


def roofline_summary():
    try:
        from benchmarks.bench_roofline import main as roofline_main
        roofline_main()
    except Exception as e:
        print(f"# roofline artifacts unavailable: {e}")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", choices=("all", "sweeps", "kernels"),
                    default="all",
                    help="'sweeps' runs just the backend rows + JSON "
                    "artifact; 'kernels' runs the per-geometry kernel "
                    "matrix + BENCH_kernels.json")
    ap.add_argument("--smoke", action="store_true",
                    help="1 bench iter / 1 sweep iteration (CI regression "
                    "smoke; still exercises both backends end-to-end)")
    ap.add_argument("--autotune", choices=("off", "cached", "full"),
                    default="cached",
                    help="geometry source for the kernel-matrix tuned rows:"
                    " replay benchmarks/autotune_cache.json (cached), "
                    "re-time the grid and rewrite the cache (full), or "
                    "bench the hardcoded defaults (off)")
    args = ap.parse_args(argv)

    if args.only == "sweeps":
        sweeps_summary(smoke=args.smoke)
        return
    if args.only == "kernels":
        kernels_summary(smoke=args.smoke, autotune=args.autotune)
        return
    print("# microbenchmarks (CPU wall time of the jnp reference paths)")
    from benchmarks.bench_kernels import main as kernels_main
    kernels_main()
    sweeps_summary(smoke=args.smoke)
    kernels_summary(smoke=args.smoke, autotune=args.autotune)
    paper_summary()
    roofline_summary()


if __name__ == "__main__":
    main()

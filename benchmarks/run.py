"""Benchmark harness entry point — one bench per paper table/figure family.

Prints ``name,us_per_call,derived`` CSV rows (kernel/microbenches), the
paper-protocol summary per (dataset × combo) from cached sweep artifacts
(benchmarks.paper_sweep produces them; a small live sweep runs if absent),
and the roofline tables from the dry-run artifacts.

  PYTHONPATH=src python -m benchmarks.run
"""

from __future__ import annotations

import glob
import json
from pathlib import Path

ART = Path(__file__).resolve().parent.parent / "artifacts"


def paper_summary():
    files = sorted(glob.glob(str(ART / "paper_sweep" / "*.json")))
    if not files:
        print("# no paper_sweep artifacts; running a reduced live sweep "
              "(synth-citation, 4 combos, Q=20)")
        from benchmarks.paper_sweep import sweep_dataset
        sweep_dataset("synth-citation", queries=20,
                      combos=[(0.10, 1, 0.01), (0.20, 1, 0.10),
                              (0.30, 0, 0.90), (0.30, 1, 0.90)])
        files = sorted(glob.glob(str(ART / "paper_sweep" / "*.json")))
    print("\n# paper protocol: dataset,combo,vertex_ratio,edge_ratio,"
          "rbo_mean,rbo_final,speedup_mean,speedup_min,fallbacks")
    best = {}
    for f in files:
        r = json.load(open(f))
        s = r["summary"]
        key = f"r{r['r']}_n{r['n']}_d{r['delta']}"
        print(f"paper,{r['dataset']},{key},{s['vertex_ratio']:.4f},"
              f"{s['edge_ratio']:.4f},{s['rbo']:.4f},{s['rbo_final']:.4f},"
              f"{s['speedup']:.2f},{s['speedup_min']:.2f},{s['fallbacks']}")
        d = best.setdefault(r["dataset"], {"speedup": 0.0, "rbo_at": 0.0})
        if s["rbo"] > 0.95 and s["speedup"] > d["speedup"]:
            d["speedup"] = s["speedup"]
            d["rbo_at"] = s["rbo"]
    print("\n# headline (best speedup with RBO > 0.95, the paper's claim "
          "regime):")
    for ds, d in sorted(best.items()):
        print(f"headline,{ds},speedup={d['speedup']:.2f}x,rbo={d['rbo_at']:.4f}")


def roofline_summary():
    try:
        from benchmarks.bench_roofline import main as roofline_main
        roofline_main()
    except Exception as e:
        print(f"# roofline artifacts unavailable: {e}")


def main() -> None:
    print("# microbenchmarks (CPU wall time of the jnp reference paths)")
    from benchmarks.bench_kernels import main as kernels_main
    kernels_main()
    paper_summary()
    roofline_summary()


if __name__ == "__main__":
    main()

"""Serve a small model with batched requests (prefill + lockstep decode).

  PYTHONPATH=src python examples/serve_lm.py --arch yi_9b
"""

import argparse

from repro.launch.serve import main as serve_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default="yi_9b")
    ap.add_argument("--requests", type=int, default=8)
    args = ap.parse_args()
    stats = serve_main([
        "--arch", args.arch, "--smoke", "--requests", str(args.requests),
        "--prompt-len", "32", "--new-tokens", "16", "--slots", "4",
    ])
    assert stats.tokens_out == args.requests * 16


if __name__ == "__main__":
    main()

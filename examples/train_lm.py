"""Train an LM end-to-end on CPU (data pipeline -> jit'd train step ->
async checkpoints -> resume -> straggler timing).

Default is a fast ~2M config so the example finishes in ~2 minutes on one
CPU core; pass ``--arch train100m --steps 300`` for the full ~100M-parameter
run (about an hour on this container's single core — the per-step math is
identical, only width/vocab change).  The paper's own kind is a streaming
query/serving system, so the dictated end-to-end driver for this repo is
examples/streaming_pagerank.py; this example covers the training substrate.

  PYTHONPATH=src python examples/train_lm.py --steps 150
"""

import argparse

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--arch", type=str, default="train8m")
    args = ap.parse_args()

    from repro.models.config import ModelConfig
    import repro.configs.qwen2_0_5b as q
    if args.arch == "train100m":
        # ~100M params, registered on the fly via the qwen2 family
        q.SMOKE_CONFIG = ModelConfig(
            name="train100m", family="dense",
            num_layers=8, d_model=512, num_heads=8, num_kv_heads=4,
            d_ff=2048, vocab_size=32768, tie_embeddings=True,
            q_block=64, kv_block=128,
        )
        arch = "qwen2_0_5b"
    elif args.arch == "train8m":
        q.SMOKE_CONFIG = ModelConfig(
            name="train8m", family="dense",
            num_layers=4, d_model=192, num_heads=4, num_kv_heads=2,
            d_ff=768, vocab_size=2048, tie_embeddings=True,
            q_block=64, kv_block=128,
        )
        arch = "qwen2_0_5b"
    else:
        arch = args.arch

    losses = train_main([
        "--arch", arch, "--smoke", "--steps", str(args.steps),
        "--batch", "8", "--seq", "128", "--lr", "2e-4",
        "--ckpt-dir", f"/tmp/repro_train_{args.arch}", "--log-every", "20",
    ])
    # compare smoothed windows — per-step loss is noisy on synthetic data
    k = max(5, len(losses) // 10)
    first, last = sum(losses[:k]) / k, sum(losses[-k:]) / k
    assert last < first, f"loss did not decrease ({first:.3f} -> {last:.3f})"
    print(f"loss decreased {first:.3f} -> {last:.3f} "
          f"(smoothed over {k} steps, {len(losses)} total)")


if __name__ == "__main__":
    main()

"""Quickstart: VeilGraph in ~40 lines.

Build a streaming graph, serve queries approximately, compare against exact.

  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import Action, EngineConfig, VeilGraphEngine
from repro.core.policies import always
from repro.graph.generators import barabasi_albert_edges
from repro.metrics import rbo_from_scores
from repro.stream import StreamConfig, build_stream


def main():
    # a scale-free graph and a stream of 2000 edge additions in 10 chunks
    src, dst = barabasi_albert_edges(5000, 4, seed=0)
    stream = build_stream(src, dst, StreamConfig(stream_size=2000,
                                                 num_queries=10, seed=1))

    cfg = EngineConfig(
        node_capacity=5_000, edge_capacity=64_000,
        hot_node_capacity=2_048, hot_edge_capacity=16_384,
        r=0.2, n=1, delta=0.5,      # the paper's (r, n, Δ) knobs
        num_iters=30, tol=1e-6,
    )
    approx = VeilGraphEngine(cfg)                                # summarized
    exact = VeilGraphEngine(cfg, on_query=always(Action.EXACT))  # ground truth

    approx.start(stream.init_src, stream.init_dst)
    exact.start(stream.init_src, stream.init_dst)

    print(f"{'q':>3} {'hot%':>7} {'edges%':>7} {'RBO@100':>8} {'speedup':>8}")
    for q, (s, d) in enumerate(stream):
        approx.register_add_edges(s, d)
        exact.register_add_edges(s, d)
        ranks_a, st_a = approx.query()
        ranks_e, st_e = exact.query()
        rbo = rbo_from_scores(ranks_a, ranks_e, depth=100,
                              active=np.asarray(approx.state.node_active))
        sp = st_e.wall_time_s / max(st_a.wall_time_s, 1e-9)
        print(f"{q:>3} {100*st_a.vertex_ratio:>6.2f}% {100*st_a.edge_ratio:>6.2f}%"
              f" {rbo:>8.4f} {sp:>7.2f}x")
    approx.stop()
    exact.stop()


if __name__ == "__main__":
    main()

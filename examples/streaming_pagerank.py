"""End-to-end reproduction of the paper's experiment protocol on one dataset.

Initial exact computation on G, then Q=50 queries, each integrating a chunk
of edge additions and running the summarized update over the hot-vertex
summary graph.  Reports the paper's four metrics per query: summary vertex
ratio, summary edge ratio, RBO vs exact ground truth, and speedup.

Built on the session front door (``repro.api.session``), so any registered
algorithm runs through the same protocol — PageRank (the paper's case
study), personalized PageRank, HITS, or your own plugin:

  PYTHONPATH=src python examples/streaming_pagerank.py \\
      --dataset synth-citation --r 0.2 --n 1 --delta 0.1
  PYTHONPATH=src python examples/streaming_pagerank.py \\
      --dataset synth-citation --algorithm hits
"""

import argparse
import time

import numpy as np

import repro as veilgraph
from repro.core.policies import always
from repro.graph.generators import DATASETS, generate
from repro.metrics import rbo_from_scores
from repro.stream import StreamConfig, build_stream


def run(dataset="synth-citation", algorithm="pagerank", r=0.2, n=1, delta=0.1,
        queries=50, shuffle=True, seed=7, rbo_depth=None, verbose=True,
        **algo_params):
    spec = DATASETS[dataset]
    src, dst = generate(spec, seed=0)
    sc = StreamConfig(stream_size=spec.stream_size, num_queries=queries,
                      shuffle=shuffle, seed=seed)
    stream = build_stream(src, dst, sc)
    depth = rbo_depth or (1000 if sc.edges_per_query <= 200 else 4000)

    n_cap = spec.nodes
    e_cap = int(src.shape[0] * 1.15)
    knobs = dict(
        node_capacity=n_cap, edge_capacity=e_cap,
        hot_node_capacity=max(2048, n_cap // 2),
        hot_edge_capacity=max(16384, e_cap // 2),
        r=r, n=n, delta=delta,
        **algo_params,
    )
    # sweep knobs only where the algorithm takes them (the fixed-point
    # traversal workloads have no tol — they stop when nothing changes);
    # introspect the registry factory rather than instantiating it, so
    # algorithms with required constructor args don't crash here.  An
    # already-constructed instance carries its own knobs — session()
    # rejects forwarding to it, so inject nothing.
    if isinstance(algorithm, str):
        from repro.core.algorithm import algorithm_factory, factory_accepts
        factory = algorithm_factory(algorithm)
        for k, v in (("num_iters", 30), ("tol", 1e-6)):
            if factory_accepts(factory, k):
                knobs.setdefault(k, v)
    approx = veilgraph.session(stream, algorithm, **knobs)
    exact = veilgraph.session(stream, algorithm,
                              on_query=always(veilgraph.Action.EXACT), **knobs)
    st0 = approx.stats_log[0]
    if verbose:
        print(f"{dataset} (analogue of {spec.paper_analogue}): "
              f"V={stream.total_nodes} E={stream.total_edges} "
              f"|S|={spec.stream_size} chunk={sc.edges_per_query} "
              f"algorithm={approx.algorithm.name}")
        print(f"initial exact compute: {st0.wall_time_s:.3f}s")

    rows = []
    for q, (ra, re_) in enumerate(zip(approx.play(), exact.play())):
        # orient by the algorithm's ranking direction and drop sentinel
        # entries (+inf unreachable distances, int-max labels) — otherwise
        # distance/label workloads would be compared on an inverted,
        # tie-dominated ranking.  Only the *exact* run's validity filters:
        # a vertex the approximation left at a sentinel while the exact run
        # resolved it is a miss, and (sign-flipped to -inf) it ranks last
        # in the approx ordering, correctly dragging RBO down.
        mask = np.asarray(approx.engine.state.node_active)
        if re_.valid is not None:
            mask = mask & re_.valid
        sign = 1.0 if ra.descending else -1.0
        rbo = rbo_from_scores(
            sign * ra.scores.astype(np.float64),
            sign * re_.scores.astype(np.float64),
            depth=depth, active=mask)
        rows.append({
            "q": q, "vertex_ratio": ra.stats.vertex_ratio,
            "edge_ratio": ra.stats.edge_ratio, "rbo": rbo,
            "speedup": re_.stats.wall_time_s / max(ra.stats.wall_time_s, 1e-9),
            "fallback": ra.stats.overflow_fallback,
        })
        if verbose and (q % 10 == 0 or q == queries - 1):
            rr = rows[-1]
            print(f"q{q:>3}: hot {100*rr['vertex_ratio']:5.2f}%  "
                  f"edges {100*rr['edge_ratio']:5.2f}%  RBO {rbo:.4f}  "
                  f"speedup {rr['speedup']:.2f}x")
    approx.close()
    exact.close()
    if verbose:
        w = rows[1:]  # skip compile query
        print(f"mean: vertex {100*np.mean([x['vertex_ratio'] for x in w]):.2f}% "
              f"edge {100*np.mean([x['edge_ratio'] for x in w]):.2f}% "
              f"RBO {np.mean([x['rbo'] for x in w]):.4f} "
              f"speedup {np.mean([x['speedup'] for x in w]):.2f}x")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="synth-citation",
                    choices=sorted(DATASETS))
    ap.add_argument("--algorithm", default="pagerank",
                    choices=sorted(veilgraph.available_algorithms()))
    ap.add_argument("--r", type=float, default=0.2)
    ap.add_argument("--n", type=int, default=1)
    ap.add_argument("--delta", type=float, default=0.1)
    ap.add_argument("--queries", type=int, default=50)
    ap.add_argument("--no-shuffle", action="store_true")
    args = ap.parse_args()
    run(args.dataset, args.algorithm, args.r, args.n, args.delta, args.queries,
        shuffle=not args.no_shuffle)

"""End-to-end reproduction of the paper's experiment protocol on one dataset.

Initial exact PageRank on G, then Q=50 queries, each integrating a chunk of
edge additions and running the summarized PageRank over the hot-vertex
summary graph.  Reports the paper's four metrics per query: summary vertex
ratio, summary edge ratio, RBO vs exact ground truth, and speedup.

  PYTHONPATH=src python examples/streaming_pagerank.py \\
      --dataset synth-citation --r 0.2 --n 1 --delta 0.1
"""

import argparse
import time

import numpy as np

from repro.core import Action, EngineConfig, VeilGraphEngine
from repro.core.policies import always
from repro.graph.generators import DATASETS, generate
from repro.metrics import rbo_from_scores
from repro.stream import StreamConfig, build_stream


def run(dataset="synth-citation", r=0.2, n=1, delta=0.1, queries=50,
        shuffle=True, seed=7, rbo_depth=None, verbose=True):
    spec = DATASETS[dataset]
    src, dst = generate(spec, seed=0)
    sc = StreamConfig(stream_size=spec.stream_size, num_queries=queries,
                      shuffle=shuffle, seed=seed)
    stream = build_stream(src, dst, sc)
    depth = rbo_depth or (1000 if sc.edges_per_query <= 200 else 4000)

    n_cap = spec.nodes
    e_cap = int(src.shape[0] * 1.15)
    cfg = EngineConfig(
        node_capacity=n_cap, edge_capacity=e_cap,
        hot_node_capacity=max(2048, n_cap // 2),
        hot_edge_capacity=max(16384, e_cap // 2),
        r=r, n=n, delta=delta, num_iters=30, tol=1e-6,
    )
    approx = VeilGraphEngine(cfg)
    exact = VeilGraphEngine(cfg, on_query=always(Action.EXACT))
    st0 = approx.start(stream.init_src, stream.init_dst)
    exact.start(stream.init_src, stream.init_dst)
    if verbose:
        print(f"{dataset} (analogue of {spec.paper_analogue}): "
              f"V={stream.total_nodes} E={stream.total_edges} "
              f"|S|={spec.stream_size} chunk={sc.edges_per_query}")
        print(f"initial exact PageRank: {st0.wall_time_s:.3f}s")

    rows = []
    for q, (s, d) in enumerate(stream):
        approx.register_add_edges(s, d)
        exact.register_add_edges(s, d)
        ra, sa = approx.query()
        re_, se = exact.query()
        rbo = rbo_from_scores(ra, re_, depth=depth,
                              active=np.asarray(approx.state.node_active))
        rows.append({
            "q": q, "vertex_ratio": sa.vertex_ratio,
            "edge_ratio": sa.edge_ratio, "rbo": rbo,
            "speedup": se.wall_time_s / max(sa.wall_time_s, 1e-9),
            "fallback": sa.overflow_fallback,
        })
        if verbose and (q % 10 == 0 or q == queries - 1):
            rr = rows[-1]
            print(f"q{q:>3}: hot {100*rr['vertex_ratio']:5.2f}%  "
                  f"edges {100*rr['edge_ratio']:5.2f}%  RBO {rbo:.4f}  "
                  f"speedup {rr['speedup']:.2f}x")
    if verbose:
        w = rows[1:]  # skip compile query
        print(f"mean: vertex {100*np.mean([x['vertex_ratio'] for x in w]):.2f}% "
              f"edge {100*np.mean([x['edge_ratio'] for x in w]):.2f}% "
              f"RBO {np.mean([x['rbo'] for x in w]):.4f} "
              f"speedup {np.mean([x['speedup'] for x in w]):.2f}x")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="synth-citation",
                    choices=sorted(DATASETS))
    ap.add_argument("--r", type=float, default=0.2)
    ap.add_argument("--n", type=int, default=1)
    ap.add_argument("--delta", type=float, default=0.1)
    ap.add_argument("--queries", type=int, default=50)
    ap.add_argument("--no-shuffle", action="store_true")
    args = ap.parse_args()
    run(args.dataset, args.r, args.n, args.delta, args.queries,
        shuffle=not args.no_shuffle)

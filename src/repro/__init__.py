"""VeilGraph reproduction — streaming approximate graph processing on JAX.

The public front door lives in :mod:`repro.api`; ``repro.session`` et al.
are re-exported lazily here so ``import repro`` stays cheap for the
subpackages (models/kernels/launch) that never touch the graph engine.
"""

_API_NAMES = ("session", "serve_session", "VeilGraphSession", "QueryResult",
              "Action", "available_algorithms")


def __getattr__(name):
    if name in _API_NAMES:
        from repro import api
        return getattr(api, name)
    raise AttributeError(f"module 'repro' has no attribute {name!r}")


def __dir__():
    return sorted(list(globals()) + list(_API_NAMES))

"""Train / prefill / serve step factories — the functions the launcher jits.

``make_train_step(cfg)`` returns ``step(params, opt_state, batch)``;
``make_prefill_step`` / ``make_serve_step`` return the serving entry points.
These are what the multi-pod dry-run lowers for every (arch × shape) cell,
and what the examples run for real on CPU smoke configs.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.transformer import (init_cache, lm_decode_step, lm_forward,
                                      lm_prefill)
from repro.train.loss import cross_entropy
from repro.train.optimizer import (AdamWState, adamw_update,
                                   clip_by_global_norm)


def _model_inputs(cfg: ModelConfig, batch: Dict[str, jax.Array]) -> Dict[str, Any]:
    kw: Dict[str, Any] = {}
    if cfg.frontend == "vision":
        kw["prefix_embeds"] = batch["patch_embeds"]
    if cfg.encoder_layers > 0:
        kw["encoder_embeds"] = batch["frames"]
    return kw


def make_train_step(
    cfg: ModelConfig,
    *,
    learning_rate: Callable[[jax.Array], jax.Array] | float = 3e-4,
    grad_clip: float = 1.0,
    remat: bool = True,
    z_loss: float = 0.0,
    weight_decay: float = 0.1,
) -> Callable:
    """AdamW train step. batch: tokens (B,S), labels (B,S) [+ frontend inputs]."""

    def step(params, opt_state: AdamWState, batch):
        kw = _model_inputs(cfg, batch)

        def loss_fn(p):
            logits = lm_forward(p, cfg, batch["tokens"], remat=remat, **kw)
            if cfg.frontend == "vision":
                # loss only over text positions (prefix embeds are inputs)
                logits = logits[:, batch["patch_embeds"].shape[1]:]
            loss, acc = cross_entropy(logits, batch["labels"],
                                      batch.get("loss_mask"), z_loss=z_loss)
            return loss, acc

        (loss, acc), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        grads, gnorm = clip_by_global_norm(grads, grad_clip)
        lr = (learning_rate(opt_state.step) if callable(learning_rate)
              else jnp.asarray(learning_rate, jnp.float32))
        new_params, new_state = adamw_update(grads, opt_state, params, lr,
                                              weight_decay=weight_decay)
        metrics = {"loss": loss, "accuracy": acc, "grad_norm": gnorm, "lr": lr}
        return new_params, new_state, metrics

    return step


def make_eval_step(cfg: ModelConfig) -> Callable:
    def step(params, batch):
        kw = _model_inputs(cfg, batch)
        logits = lm_forward(params, cfg, batch["tokens"], **kw)
        if cfg.frontend == "vision":
            logits = logits[:, batch["patch_embeds"].shape[1]:]
        loss, acc = cross_entropy(logits, batch["labels"], batch.get("loss_mask"))
        return {"loss": loss, "accuracy": acc}
    return step


def make_prefill_step(cfg: ModelConfig, *, cache_len: int) -> Callable:
    """Prompt processing: returns (next-token logits, caches)."""

    def step(params, batch):
        kw = _model_inputs(cfg, batch)
        logits, cache = lm_prefill(params, cfg, batch["tokens"],
                                   cache_len=cache_len, **kw)
        return logits[:, -1], cache

    return step


def make_serve_step(cfg: ModelConfig) -> Callable:
    """One decode step: (params, cache, token (B,1), pos ()) -> (logits, cache)."""

    def step(params, cache, token, pos):
        logits, new_cache = lm_decode_step(params, cfg, cache, token, pos)
        return logits[:, 0], new_cache

    return step

"""Cross-entropy with sharded-vocab-safe log-softmax and optional z-loss."""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def cross_entropy(
    logits: jax.Array,            # (B, S, V) — V may be sharded over `model`
    labels: jax.Array,            # (B, S) int32
    mask: Optional[jax.Array] = None,   # (B, S) — 0 to ignore a position
    z_loss: float = 0.0,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (mean loss, accuracy).  All reductions in f32; GSPMD inserts
    the model-axis all-reduces for the max/sumexp over a sharded vocab."""
    logits = logits.astype(jnp.float32)
    m = jnp.max(logits, axis=-1, keepdims=True)
    shifted = logits - jax.lax.stop_gradient(m)
    sumexp = jnp.sum(jnp.exp(shifted), axis=-1)
    log_z = jnp.log(sumexp) + m[..., 0]
    label_logit = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = log_z - label_logit
    if z_loss > 0.0:
        nll = nll + z_loss * jnp.square(log_z)
    pred = jnp.argmax(logits, axis=-1)
    correct = (pred == labels).astype(jnp.float32)
    if mask is not None:
        w = mask.astype(jnp.float32)
        denom = jnp.maximum(w.sum(), 1.0)
        return (nll * w).sum() / denom, (correct * w).sum() / denom
    return nll.mean(), correct.mean()

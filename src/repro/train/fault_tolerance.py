"""Fault tolerance for pod-scale training.

Pieces (each independently testable on CPU):

- ``StepTimer``       — per-step EMA timing + straggler/outlier detection.
  At fleet scale the slowest participant sets the step time; surfacing
  p99/outlier steps early is the first mitigation (paired with bounded
  data prefetch, async checkpointing and — operationally — hot-spare
  replacement of the slow host).
- ``RestartableLoop`` — wraps a step function with checkpoint/restart:
  periodic async saves, save-on-signal (SIGTERM preemption), automatic
  resume-from-latest, bounded step retry on transient failure.
- ``elastic_reshard`` — loads a checkpoint saved on mesh A into shardings
  for mesh B (scale up/down between runs); relies on CheckpointManager
  storing global shapes + indices, not device layouts.
- gradient compression (see train/compression.py) — opt-in int8 DP
  all-reduce with error feedback.
"""

from __future__ import annotations

import signal
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.train.checkpoint import CheckpointManager


@dataclass
class StepTimer:
    ema_alpha: float = 0.1
    outlier_factor: float = 2.0
    ema_s: Optional[float] = None
    history: List[float] = field(default_factory=list)
    outliers: List[int] = field(default_factory=list)

    def record(self, step: int, dt: float) -> bool:
        """Returns True if this step is a straggler outlier."""
        self.history.append(dt)
        is_outlier = (self.ema_s is not None
                      and dt > self.outlier_factor * self.ema_s)
        if is_outlier:
            self.outliers.append(step)
        # outliers do not poison the EMA
        if not is_outlier:
            self.ema_s = (dt if self.ema_s is None
                          else (1 - self.ema_alpha) * self.ema_s
                          + self.ema_alpha * dt)
        return is_outlier

    def summary(self) -> Dict[str, float]:
        h = np.asarray(self.history) if self.history else np.zeros(1)
        return {
            "mean_s": float(h.mean()),
            "p50_s": float(np.percentile(h, 50)),
            "p99_s": float(np.percentile(h, 99)),
            "ema_s": float(self.ema_s or 0.0),
            "outliers": len(self.outliers),
        }


class PreemptionGuard:
    """Sets a flag on SIGTERM/SIGINT so the loop checkpoints and exits
    cleanly (cloud preemption notice)."""

    def __init__(self, signals=(signal.SIGTERM,)):
        self.requested = False
        self._prev = {}
        for sig in signals:
            try:
                self._prev[sig] = signal.signal(sig, self._handler)
            except (ValueError, OSError):  # non-main thread / unsupported
                pass

    def _handler(self, signum, frame):
        self.requested = True

    def restore(self):
        for sig, prev in self._prev.items():
            signal.signal(sig, prev)


@dataclass
class LoopConfig:
    total_steps: int
    checkpoint_every: int = 100
    max_step_retries: int = 2
    log_every: int = 10


class RestartableLoop:
    """Checkpoint/restart training driver.

    ``state`` is any pytree (params, opt state, data step, ...).  On start,
    resumes from the latest committed checkpoint if one exists.  Transient
    step failures are retried from the last good in-memory state; repeated
    failure restores from the last checkpoint before re-raising.
    """

    def __init__(self, ckpt: CheckpointManager, cfg: LoopConfig,
                 *, log: Callable[[str], None] = print):
        self.ckpt = ckpt
        self.cfg = cfg
        self.log = log
        self.timer = StepTimer()

    def resume_step(self) -> int:
        latest = self.ckpt.latest_step()
        return 0 if latest is None else latest + 1

    def restore(self, state_template: Any, shardings: Any = None) -> Any:
        latest = self.ckpt.latest_step()
        if latest is None:
            return None
        self.log(f"[restore] resuming from step {latest}")
        return self.ckpt.restore(latest, state_template, shardings)

    def run(self, state: Any, step_fn: Callable[[Any, int], Any],
            start_step: Optional[int] = None) -> Any:
        cfg = self.cfg
        guard = PreemptionGuard()
        step = self.resume_step() if start_step is None else start_step
        try:
            while step < cfg.total_steps:
                t0 = time.perf_counter()
                retries = 0
                while True:
                    try:
                        state = step_fn(state, step)
                        break
                    except Exception as e:  # noqa: BLE001 — retry transient
                        retries += 1
                        if retries > cfg.max_step_retries:
                            self.log(f"[fatal] step {step} failed "
                                     f"{retries - 1} retries: {e}")
                            raise
                        self.log(f"[retry] step {step} attempt {retries}: {e}")
                dt = time.perf_counter() - t0
                if self.timer.record(step, dt):
                    self.log(f"[straggler] step {step} took {dt:.3f}s "
                             f"(ema {self.timer.ema_s:.3f}s)")
                if cfg.log_every and step % cfg.log_every == 0:
                    self.log(f"[step {step}] {dt*1e3:.1f} ms")
                if cfg.checkpoint_every and step % cfg.checkpoint_every == 0 \
                        and step > 0:
                    self.ckpt.save(step, state)
                if guard.requested:
                    self.log(f"[preempt] checkpointing at step {step} and "
                             "exiting")
                    self.ckpt.save(step, state)
                    self.ckpt.wait()
                    break
                step += 1
            else:
                self.ckpt.save(cfg.total_steps - 1, state)
                self.ckpt.wait()
        finally:
            guard.restore()
        return state


def elastic_reshard(ckpt: CheckpointManager, step: int, state_template: Any,
                    new_shardings: Any) -> Any:
    """Load a checkpoint onto a different mesh (elastic rescale).

    The checkpoint stores global shapes + host shards; placement is entirely
    determined by ``new_shardings`` (built against the new mesh), so 256-chip
    state restores onto 512 chips (or 1 CPU device in tests) unchanged.
    """
    return ckpt.restore(step, state_template, new_shardings)

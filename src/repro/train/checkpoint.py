"""Sharded, async, restartable checkpointing (no orbax in this container).

Layout (multi-host ready):

    <dir>/step_<N>/
        manifest.json            # tree structure, shapes, dtypes, pspecs
        proc<P>_shard<i>.npz     # this process's addressable shards
    <dir>/step_<N>.COMMITTED     # atomic commit marker (written last)

Design points for 1000+-node fleets:
- every process writes only its addressable shards (no gather to host 0);
- the commit marker is written by process 0 only after a barrier, so a
  half-written checkpoint is never restored (atomicity under preemption);
- saves run on a background thread (async) — training continues while the
  previous step serializes; ``wait()`` joins before the next save;
- ``restore`` rebuilds jax.Arrays via make_array_from_single_device_arrays
  against ANY target mesh/sharding: restoring a 256-chip checkpoint onto a
  512-chip mesh (elastic rescale) just passes the new shardings;
- keep_last_k garbage collection.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

SEP = "/"


def _flatten_with_paths(tree: Any) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for kp, leaf in flat:
        key = SEP.join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in kp)
        out.append((key, leaf))
    return out


def _treedef_of(tree: Any):
    return jax.tree_util.tree_structure(tree)


class CheckpointManager:
    def __init__(self, directory: str | Path, *, keep_last_k: int = 3,
                 async_save: bool = True):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep_last_k = keep_last_k
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: Any) -> None:
        """Snapshot to host then serialize (async by default)."""
        self.wait()
        leaves = _flatten_with_paths(tree)
        # snapshot addressable shards to host memory NOW (so training can
        # donate/overwrite device buffers immediately)
        host_shards: Dict[str, List[Tuple[int, np.ndarray]]] = {}
        meta: Dict[str, Dict] = {}
        for key, leaf in leaves:
            arrs = []
            if isinstance(leaf, jax.Array):
                for s in leaf.addressable_shards:
                    arrs.append((s.index, np.asarray(s.data)))
                spec = getattr(leaf.sharding, "spec", None)
                meta[key] = {
                    "shape": list(leaf.shape),
                    "dtype": str(leaf.dtype),
                    "pspec": repr(spec) if spec is not None else None,
                }
            else:
                arrs.append((None, np.asarray(leaf)))
                meta[key] = {"shape": list(np.shape(leaf)),
                             "dtype": str(np.asarray(leaf).dtype),
                             "pspec": None}
            host_shards[key] = arrs

        def work():
            try:
                self._write(step, host_shards, meta)
            except BaseException as e:   # surfaced on next wait()
                self._error = e

        if self.async_save:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            work()
            self._raise_if_failed()

    def _write(self, step: int, host_shards, meta) -> None:
        proc = jax.process_index()
        step_dir = self.dir / f"step_{step:08d}"
        tmp_dir = self.dir / f".tmp_step_{step:08d}_p{proc}"
        tmp_dir.mkdir(parents=True, exist_ok=True)
        payload = {}
        shard_index: Dict[str, List] = {}
        for key, arrs in host_shards.items():
            for i, (idx, arr) in enumerate(arrs):
                name = f"{key.replace(SEP, '.')}__shard{i}"
                payload[name] = arr
                shard_index.setdefault(key, []).append(
                    {"file_key": name,
                     "index": None if idx is None else _index_to_json(idx)})
        np.savez(tmp_dir / f"proc{proc}.npz", **payload)
        (tmp_dir / f"proc{proc}_index.json").write_text(
            json.dumps({"shards": shard_index, "meta": meta}))
        # move into place; process 0 commits
        step_dir.mkdir(parents=True, exist_ok=True)
        for f in tmp_dir.iterdir():
            os.replace(f, step_dir / f.name)
        tmp_dir.rmdir()
        if proc == 0:
            (self.dir / f"step_{step:08d}.COMMITTED").write_text(
                json.dumps({"step": step, "time": time.time()}))
            self._gc()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._raise_if_failed()

    def _raise_if_failed(self):
        if self._error is not None:
            e, self._error = self._error, None
            raise RuntimeError(f"async checkpoint save failed: {e}") from e

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep_last_k] if self.keep_last_k else []:
            marker = self.dir / f"step_{s:08d}.COMMITTED"
            d = self.dir / f"step_{s:08d}"
            if marker.exists():
                marker.unlink()
            if d.exists():
                shutil.rmtree(d)

    # --------------------------------------------------------------- restore
    def all_steps(self) -> List[int]:
        out = []
        for f in self.dir.glob("step_*.COMMITTED"):
            out.append(int(f.stem.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, target: Any,
                shardings: Optional[Any] = None) -> Any:
        """Restore into the structure of ``target`` (pytree of arrays or
        ShapeDtypeStructs).  ``shardings`` (same structure, jax.sharding
        .Sharding leaves) places shards on the CURRENT mesh — pass the new
        mesh's shardings to rescale elastically."""
        step_dir = self.dir / f"step_{step:08d}"
        if not (self.dir / f"step_{step:08d}.COMMITTED").exists():
            raise FileNotFoundError(f"no committed checkpoint at step {step}")
        # load all processes' shards (single-host test path loads everything;
        # multi-host would filter to local indices)
        by_key: Dict[str, List[Tuple[Optional[tuple], np.ndarray]]] = {}
        for idx_file in sorted(step_dir.glob("proc*_index.json")):
            proc = idx_file.name.split("_")[0]
            index = json.loads(idx_file.read_text())
            data = np.load(step_dir / f"{proc}.npz")
            for key, shards in index["shards"].items():
                for sh in shards:
                    arr = data[sh["file_key"]]
                    by_key.setdefault(key, []).append(
                        (_index_from_json(sh["index"]), arr))

        leaves = _flatten_with_paths(target)
        flat_sh = (_flatten_with_paths(shardings) if shardings is not None
                   else [(k, None) for k, _ in leaves])
        sh_map = dict(flat_sh)
        out_leaves = []
        for key, leaf in leaves:
            shards = by_key[key]
            shape = tuple(leaf.shape)
            sharding = sh_map.get(key)
            if sharding is None:
                # assemble fully on host
                full = np.zeros(shape, dtype=shards[0][1].dtype)
                for idx, arr in shards:
                    if idx is None or len(shape) == 0:
                        full = arr
                    else:
                        full[idx] = arr
                out_leaves.append(jax.numpy.asarray(full))
            else:
                out_leaves.append(_place(shape, shards, sharding))
        treedef = _treedef_of(target)
        return jax.tree_util.tree_unflatten(treedef, out_leaves)


def _place(shape, shards, sharding) -> jax.Array:
    """Build a sharded jax.Array on the current mesh from saved shards."""
    full = np.zeros(shape, dtype=shards[0][1].dtype)
    for idx, arr in shards:
        if idx is None or len(shape) == 0:
            full = np.asarray(arr)
        else:
            full[idx] = arr
    return jax.make_array_from_callback(shape, sharding, lambda i: full[i])


def _index_to_json(idx) -> List:
    out = []
    for s in idx:
        out.append([s.start, s.stop, s.step])
    return out


def _index_from_json(j) -> Optional[tuple]:
    if j is None:
        return None
    return tuple(slice(a, b, c) for a, b, c in j)

"""Gradient compression for the data-parallel all-reduce (opt-in).

int8 block-quantized all-reduce with error feedback: each DP rank quantizes
its local gradient to int8 with per-block f32 scales, all-reduces the int8
payload (4× less ICI traffic than f32, 2× less than bf16), dequantizes, and
carries its quantization residual into the next step (error feedback keeps
the scheme unbiased over time — the 1-bit-Adam / PowerSGD family trick).

Implemented with shard_map + psum so the collective payload dtype is
explicit (GSPMD's implicit gradient all-reduce cannot change payload
dtype).  API: per-rank gradients live as arrays with a leading rank axis
sharded over the DP mesh axis; ``compressed_mean`` returns their mean as if
all-reduced, plus the per-rank error-feedback state.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

# jax promoted shard_map out of jax.experimental across 0.4.x/0.5.x
if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
else:  # pragma: no cover - version-dependent
    from jax.experimental.shard_map import shard_map as _shard_map

BLOCK = 256


def quantize(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """int8 block quantization: (q int8[nb, BLOCK], scale f32[nb])."""
    flat = g.reshape(-1)
    pad = (-flat.shape[0]) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1) / 127.0
    q = jnp.clip(jnp.round(blocks / jnp.maximum(scale[:, None], 1e-12)),
                 -127, 127)
    return q.astype(jnp.int8), scale


def dequantize(q: jax.Array, scale: jax.Array, shape, size) -> jax.Array:
    blocks = q.astype(jnp.float32) * scale[:, None]
    return blocks.reshape(-1)[:size].reshape(shape)


def compressed_mean(grads: Any, errors: Any, mesh: Mesh,
                    axis: str = "data") -> Tuple[Any, Any]:
    """Compressed mean-all-reduce over ``axis``.

    ``grads``/``errors`` leaves have a leading per-rank dim of size
    mesh.shape[axis], sharded over that axis (each rank holds its own
    gradient).  Returns (mean grads broadcast back to every rank — same
    leading dim —, updated per-rank errors).
    """

    def leaf(g, err):
        # inside shard_map: g is (1, ...) — this rank's gradient
        g1 = g[0].astype(jnp.float32) + err[0]
        q, scale = quantize(g1)
        # each rank's int8 payload is summed exactly in int32; per-rank
        # scales are exchanged alongside (tiny: 1/256 of payload)
        contrib = q.astype(jnp.float32) * scale[:, None]     # dequant local
        qsum = jax.lax.psum(q.astype(jnp.int32), axis)        # int payload
        ssum = jax.lax.psum(scale, axis)
        n = jax.lax.psum(jnp.ones((), jnp.float32), axis)
        # reconstruction with a shared (mean) scale; the difference between
        # per-rank-scale dequant and shared-scale dequant joins the error
        # feedback so nothing is lost over steps
        recon = (qsum.astype(jnp.float32) * (ssum / n)[:, None])
        mean = (recon.reshape(-1)[: g1.size].reshape(g1.shape)) / n
        sent = dequantize(q, scale, g1.shape, g1.size)
        new_err = (g1 - sent)[None]
        return mean[None].astype(g.dtype), new_err

    def mapped(gs, errs):
        flat_g, treedef = jax.tree_util.tree_flatten(gs)
        flat_e = treedef.flatten_up_to(errs)
        outs = [leaf(g, e) for g, e in zip(flat_g, flat_e)]
        return (treedef.unflatten([o[0] for o in outs]),
                treedef.unflatten([o[1] for o in outs]))

    in_spec = jax.tree_util.tree_map(lambda _: P(axis), grads)
    fn = _shard_map(mapped, mesh=mesh,
                       in_specs=(in_spec, in_spec),
                       out_specs=(in_spec, in_spec))
    return fn(grads, errors)


def init_error_state(grads_template: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_template)


def compression_ratio() -> float:
    """ICI payload ratio vs f32 all-reduce (int8 + scales overhead)."""
    return (1.0 + 4.0 / BLOCK) / 4.0

"""AdamW in pure JAX (no optax in this container) + schedules + clipping.

Optimizer state shards exactly like the parameters (moments inherit the
param PartitionSpecs), so memory_analysis in the dry-run reflects the real
sharded optimizer footprint.  ZeRO-1 (moments sharded over `data` as well)
is a §Perf hillclimb variant enabled through sharding rules, not code edits.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array       # int32
    mu: Any               # first moment, like params
    nu: Any               # second moment, like params


def adamw_init(params: Any) -> AdamWState:
    zeros = lambda p: jnp.zeros_like(p)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree_util.tree_map(zeros, params),
        nu=jax.tree_util.tree_map(zeros, params),
    )


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def clip_by_global_norm(tree: Any, max_norm: float) -> Tuple[Any, jax.Array]:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, tree), norm


def adamw_update(
    grads: Any,
    state: AdamWState,
    params: Any,
    lr: jax.Array,
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
) -> Tuple[Any, AdamWState]:
    step = state.step + 1
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / c1
        vhat = v / c2
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, mu=new_m, nu=new_v)


def cosine_schedule(base_lr: float, warmup: int, total: int) -> Callable[[jax.Array], jax.Array]:
    def lr(step: jax.Array) -> jax.Array:
        step = step.astype(jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, cos)
    return lr

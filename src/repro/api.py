"""The session-style front door to VeilGraph.

One call builds a started engine around any registered
:class:`~repro.core.algorithm.StreamingAlgorithm`:

    import repro as veilgraph   # or: from repro.api import session

    with veilgraph.session((src, dst), algorithm="pagerank") as s:
        s.add_edges(new_src, new_dst)
        result = s.query()
        print(result.top(10), result.stats.vertex_ratio)

``graph_source`` may be a ``(src, dst)`` edge-array pair, a named synthetic
dataset (``repro.graph.generators.DATASETS``), or a prebuilt
:class:`~repro.stream.EdgeStream` — in the stream case the session starts
from the stream's initial graph and ``s.play()`` replays the update chunks,
one query per chunk.

The registry spans the whole semiring family, not just ranking — the same
call drives non-float workloads end to end::

    veilgraph.session((src, dst), algorithm="sssp", sources=(0,))
    veilgraph.session((src, dst), algorithm="connected-components")
    veilgraph.session((src, dst), algorithm="katz", alpha=0.01)

``result.scores`` then carries the algorithm's own result dtype (f32
distances, int32 component labels, …); the engine's hot-set policy is
driven by each algorithm's float ``selection_view`` (label churn /
distance deltas for the traversal workloads).

Capacities are sized automatically from the source when no
:class:`EngineConfig` is given (hot buffers default to full capacity, so a
fresh session never overflow-falls-back; pass explicit ``hot_node_capacity``
/ ``hot_edge_capacity`` to get the paper's bounded-summary behaviour).

Migration from the pre-plugin API
---------------------------------
``VeilGraphEngine(cfg, on_query=...)`` keeps working — it runs PageRank
configured from the config's ``beta``/``num_iters``/``tol`` knobs.  New code
should prefer::

    s = veilgraph.session(src_dst, algorithm="hits", num_iters=50)
    s = veilgraph.session(src_dst, algorithm=PersonalizedPageRankAlgorithm(seeds=(3,)))

with the ``r``/``n``/``delta`` model knobs and buffer capacities passed as
keyword overrides.

The propagation backend for every sweep is likewise a config override:
``session(src_dst, backend="pallas")`` forces the destination-tiled Pallas
kernels (the one-hot-matmul MXU path for sum-of-products, the masked-reduce
variant for min/max semirings), ``"segment_sum"`` the sorted-XLA fallback,
and the default ``"auto"`` resolves per device (TPU → pallas) with
``$VEILGRAPH_BACKEND`` as the environment override.  Which semiring a sweep
runs over is the *algorithm's* declaration (``StreamingAlgorithm.semiring``
/ ``layout_specs``), not a session knob — see :mod:`repro.core.backend` and
:mod:`repro.core.semiring`.

Sharded execution is one more config override: pass a device mesh and the
engine partitions its cached edge layouts into one locally-sorted shard
per device and runs every O(E) sweep — exact, summarized boundary, fused —
as a shard_map partial push + semiring all-reduce::

    mesh = jax.make_mesh((jax.device_count(),), ("shards",))
    veilgraph.session((src, dst), algorithm="pagerank", mesh=mesh)

``mesh_axes`` optionally restricts which mesh axes the shard dimension
spans (default: all of them).  Results match the single-device engine —
bitwise for the min-semiring workloads, to f32 summation order for the
ranking family; see :mod:`repro.graph.partition`.
"""

from __future__ import annotations

from dataclasses import dataclass, fields
from typing import Callable, Dict, Iterator, Optional, Tuple, Union

import numpy as np

from repro.core.algorithm import (Action, StreamingAlgorithm,
                                  available_algorithms, make_algorithm)
from repro.core.engine import EngineConfig, QueryStats, VeilGraphEngine
from repro.graph.generators import DATASETS, generate
from repro.stream import EdgeStream

GraphSource = Union[str, Tuple[np.ndarray, np.ndarray], EdgeStream]

#: EngineConfig fields accepted as keyword overrides by :func:`session`.
_CONFIG_KEYS = frozenset(f.name for f in fields(EngineConfig))


def _result_valid(scores: np.ndarray, active: np.ndarray) -> np.ndarray:
    """Vertices whose result value is an answer, not padding: active, and
    not the ⊕-identity sentinel of a min/max workload (+∞ unreachable
    distances, int-extreme labels of never-seen capacity slots)."""
    valid = np.asarray(active, bool).copy()
    if np.issubdtype(scores.dtype, np.floating):
        valid &= np.isfinite(scores)
    elif np.issubdtype(scores.dtype, np.integer):
        info = np.iinfo(scores.dtype)
        valid &= (scores != info.max) & (scores != info.min)
    return valid


def _top_ids(scores: np.ndarray, k: int, *, descending: bool = True,
             valid: Optional[np.ndarray] = None) -> np.ndarray:
    """Ids of the k best-ranked vertices (stable ties).  ``descending``
    follows the algorithm's ``rank_descending`` (False for distances /
    min-labels); sentinel/inactive vertices are dropped, so fewer than
    ``k`` ids may come back."""
    order = np.argsort(-scores if descending else scores, kind="stable")
    if valid is not None:
        order = order[valid[order]]
    return order[:k]


@dataclass
class QueryResult:
    """One served query: the result vector plus the engine's stats row.

    ``scores`` is the algorithm's ``result_view`` in its own dtype (f32
    ranks/distances, int32 component labels); ``valid`` masks the entries
    that are real answers (capacity padding, never-seen vertices and
    unreachable-∞ slots are False) and ``descending`` records the
    algorithm's ranking direction — both feed :meth:`top`.
    """

    scores: np.ndarray
    stats: QueryStats
    valid: Optional[np.ndarray] = None
    descending: bool = True

    @property
    def action(self) -> str:
        return self.stats.action

    def top(self, k: int = 10) -> np.ndarray:
        return _top_ids(self.scores, k, descending=self.descending,
                        valid=self.valid)


class VeilGraphSession:
    """A started engine plus the streaming conveniences around it.

    Construct via :func:`session`.  Usable as a context manager (``with`` …)
    so OnStop fires on exit; the raw engine stays reachable at ``.engine``
    for anything not surfaced here.
    """

    def __init__(self, engine: VeilGraphEngine,
                 stream: Optional[EdgeStream] = None):
        self.engine = engine
        self.stream = stream

    # ---- convenience views ----------------------------------------------
    @property
    def algorithm(self) -> StreamingAlgorithm:
        """The resolved :class:`StreamingAlgorithm` instance the engine
        runs (frozen dataclass — its knobs are readable fields)."""
        return self.engine.algorithm

    @property
    def scores(self) -> np.ndarray:
        """Current score vector (whatever the last query/start computed)."""
        return np.asarray(self.engine.ranks)

    @property
    def stats_log(self):
        """Engine-accumulated :class:`~repro.core.engine.QueryStats`, one
        row per served query (index -1 = the initial exact compute)."""
        return self.engine.stats_log

    def top(self, k: int = 10) -> np.ndarray:
        """Ids of the k best-ranked vertices under the *current* scores
        (without serving a query): descending for score algorithms,
        ascending for distances/labels; sentinel and inactive vertices are
        dropped, so fewer than ``k`` ids may come back."""
        scores = self.scores
        return _top_ids(
            scores, k,
            descending=self.algorithm.rank_descending,
            valid=_result_valid(scores,
                                np.asarray(self.engine.state.node_active)))

    # ---- streaming -------------------------------------------------------
    def add_edges(self, src, dst) -> "VeilGraphSession":
        """Buffer edge additions (int 1-D ``src``/``dst`` of equal length,
        ids < ``node_capacity``); applied at the next :meth:`query`.
        Returns ``self`` for chaining."""
        self.engine.register_add_edges(np.asarray(src), np.asarray(dst))
        return self

    def remove_edges(self, src, dst) -> "VeilGraphSession":
        """Buffer edge removals (resolved to live buffer slots at apply
        time; a removal matching no live edge is counted as requested but
        never resolved).  Returns ``self`` for chaining."""
        self.engine.register_remove_edges(np.asarray(src), np.asarray(dst))
        return self

    def query(self, msg: Optional[Dict] = None) -> QueryResult:
        """Serve one query (Alg. 1 lines 6-21): apply buffered updates, let
        the OnQuery policy pick repeat/approximate/exact, run it, and wrap
        the answer.  Returns a :class:`QueryResult` whose ``scores`` is the
        algorithm's ``result_view`` (dtype[node_capacity]) with ``stats``
        the engine's row for this query."""
        scores, stats = self.engine.query(msg)
        return QueryResult(
            scores=scores, stats=stats,
            valid=_result_valid(scores,
                                np.asarray(self.engine.state.node_active)),
            descending=self.algorithm.rank_descending)

    def play(self) -> Iterator[QueryResult]:
        """Replay the attached stream: one update chunk + one query each."""
        if self.stream is None:
            raise ValueError(
                "session was not built from an EdgeStream; feed updates "
                "with add_edges()/query() instead")
        for s, d in self.stream:
            self.add_edges(s, d)
            yield self.query()

    # ---- lifecycle -------------------------------------------------------
    def close(self):
        """Fire the OnStop UDF (also called by ``with``-block exit)."""
        self.engine.stop()

    def __enter__(self) -> "VeilGraphSession":
        return self

    def __exit__(self, *exc):
        self.close()
        return False


def _resolve_source(graph_source: GraphSource):
    """-> (init_src, init_dst, stream_or_none, node_hint, edge_hint)."""
    if isinstance(graph_source, str):
        try:
            spec = DATASETS[graph_source]
        except KeyError:
            raise KeyError(
                f"unknown dataset {graph_source!r}; available: "
                f"{', '.join(sorted(DATASETS))}") from None
        src, dst = generate(spec)
        return src, dst, None, spec.nodes, src.shape[0]
    if isinstance(graph_source, EdgeStream):
        es = graph_source
        return (es.init_src, es.init_dst, es, es.total_nodes, es.total_edges)
    src, dst = graph_source
    src = np.asarray(src, np.int32)
    dst = np.asarray(dst, np.int32)
    nodes = 0
    if src.size:
        # raw edge lists carry no node-count bound, so leave headroom for
        # later add_edges with unseen ids (the engine rejects ids beyond
        # node_capacity rather than corrupting silently)
        nodes = int((int(max(src.max(), dst.max())) + 1) * 1.1) + 16
    return src, dst, None, nodes, src.shape[0]


def session(
    graph_source: GraphSource,
    algorithm: Union[StreamingAlgorithm, str] = "pagerank",
    config: Optional[EngineConfig] = None,
    *,
    on_start: Optional[Callable] = None,
    before_updates: Optional[Callable] = None,
    on_query: Optional[Callable] = None,
    on_query_result: Optional[Callable] = None,
    on_stop: Optional[Callable] = None,
    **overrides,
) -> VeilGraphSession:
    """Build and start a :class:`VeilGraphSession`.

    ``algorithm`` is a registry name (see
    :func:`repro.core.algorithm.available_algorithms`) or an instance.
    Keyword ``overrides`` split two ways: names matching
    :class:`EngineConfig` fields override the (auto-sized) config, the rest
    are forwarded to the algorithm factory::

        veilgraph.session("synth-citation", "personalized-pagerank",
                          r=0.3, delta=0.5, seeds=(0, 7), num_iters=50)
        veilgraph.session((src, dst), "hits", backend="pallas")

    ``quality_target=`` (e.g. ``0.95``) switches the engine to
    closed-loop quality control (:mod:`repro.core.control`): the fused
    query step measures drift on device and a controller steers the
    effective ``r``/``delta`` and exact-refresh cadence to keep
    estimated error inside ``1 - quality_target``.  Knob precedence: a
    knob you also pass explicitly (``quality_target=0.95, r=0.1``) is
    pinned at your value — the controller only adjusts the knobs you
    left to it.

    The five UDFs pass straight through to the engine.
    """
    init_src, init_dst, stream, node_hint, edge_hint = _resolve_source(
        graph_source)

    cfg_over = {k: v for k, v in overrides.items() if k in _CONFIG_KEYS}
    algo_params = {k: v for k, v in overrides.items() if k not in _CONFIG_KEYS}
    if cfg_over.get("quality_target") is not None:
        # knob precedence: an explicitly passed r/delta wins over the
        # controller — pin it unless the caller set control_* themselves
        cfg_over.setdefault("control_r", "r" not in cfg_over)
        cfg_over.setdefault("control_delta", "delta" not in cfg_over)
    # beta/num_iters/tol are EngineConfig fields only for the legacy
    # no-algorithm constructor; with an explicit algorithm they belong to
    # the algorithm itself, so forward them to the factory — and refuse to
    # drop them silently when they cannot reach it (instance passed, or the
    # factory doesn't take the knob).
    _legacy_knobs = [k for k in ("beta", "num_iters", "tol") if k in cfg_over]
    if isinstance(algorithm, StreamingAlgorithm):
        if _legacy_knobs:
            raise ValueError(
                f"{sorted(_legacy_knobs)} cannot be applied to an already-"
                f"constructed algorithm — pass them to "
                f"{type(algorithm).__name__}(...) instead")
    elif _legacy_knobs:
        from repro.core.algorithm import algorithm_factory, factory_accepts

        factory = algorithm_factory(algorithm)
        rejected = [k for k in _legacy_knobs
                    if not factory_accepts(factory, k)]
        if rejected:
            raise ValueError(
                f"algorithm {algorithm!r} does not accept {sorted(rejected)}")
        for k in _legacy_knobs:
            # the knob belongs to the algorithm once forwarded — leaving it
            # in cfg_over would double-apply it to EngineConfig and falsely
            # conflict with an explicitly passed config
            algo_params[k] = cfg_over.pop(k)
    algo = make_algorithm(algorithm, **algo_params)

    if config is None:
        node_cap = cfg_over.pop("node_capacity", max(node_hint, 2))
        edge_cap = cfg_over.pop(
            "edge_capacity", int(edge_hint * 1.15) + 1024)
        config = EngineConfig(
            node_capacity=node_cap,
            edge_capacity=edge_cap,
            hot_node_capacity=cfg_over.pop("hot_node_capacity", node_cap),
            hot_edge_capacity=cfg_over.pop("hot_edge_capacity", edge_cap),
            **cfg_over,
        )
    elif cfg_over:
        raise ValueError(
            f"pass either an explicit config or field overrides, not both: "
            f"{sorted(cfg_over)}")

    udfs = {}
    if on_start is not None:
        udfs["on_start"] = on_start
    if before_updates is not None:
        udfs["before_updates"] = before_updates
    if on_query is not None:
        udfs["on_query"] = on_query
    if on_query_result is not None:
        udfs["on_query_result"] = on_query_result
    if on_stop is not None:
        udfs["on_stop"] = on_stop

    engine = VeilGraphEngine(config, algo, **udfs)
    engine.start(init_src, init_dst)
    return VeilGraphSession(engine, stream)


def serve_session(
    graph_source: GraphSource,
    config: Optional[EngineConfig] = None,
    *,
    slots: int = 4,
    algorithm: Union[StreamingAlgorithm, str] = "pagerank",
    **overrides,
):
    """Build a started session and wrap it for multi-tenant serving.

    The sibling of :func:`session` for concurrent query workloads: one
    shared graph/engine, a
    :class:`~repro.serve.graph.GraphServingEngine` front door with
    ``slots`` static batch slots per algorithm lane::

        srv = veilgraph.serve_session((src, dst), slots=4)
        t1 = srv.submit("personalized-pagerank", seeds=(3,))
        t2 = srv.submit("sssp", sources=(17,))
        srv.run()
        t1.result, srv.stats.queries_per_s

    ``algorithm``/``config``/``overrides`` configure the underlying
    engine exactly as in :func:`session` (capacities, hot-set knobs,
    backend, mesh) — ``algorithm`` only sets the engine's base workload
    for the initial exact compute; served queries each carry their own.
    ``quality_target=`` enables the closed accuracy loop per serving
    lane: each wave's per-slot drift rides the existing row-delta
    transfer, a per-lane controller steers the effective knobs, and an
    SLO breach re-marks the lane's live slots cold so the next wave
    re-covers them (same knob precedence as :func:`session`).
    The underlying :class:`VeilGraphSession` stays reachable at
    ``.session`` and is closed by the serving engine's ``with``-exit.
    """
    from repro.serve.graph import GraphServingEngine

    base = session(graph_source, algorithm, config, **overrides)
    srv = GraphServingEngine(base.engine, slots=slots)
    srv.session = base
    return srv


__all__ = [
    "Action",
    "QueryResult",
    "VeilGraphSession",
    "available_algorithms",
    "serve_session",
    "session",
]

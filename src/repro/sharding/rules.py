"""Logical→physical sharding rules (MaxText-style logical axis names).

Every parameter and activation in the model stack is annotated with *logical*
axis names; a rule table maps those to mesh axes.  The same model code then
runs on the single-pod ``(data, model)`` mesh, the multi-pod
``(pod, data, model)`` mesh, or a single device (rules empty -> no
constraints).

Rules are intentionally data: hillclimbing §Perf iterations swap rule tables
instead of editing model code.
"""

from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisRules = Dict[str, Tuple[str, ...]]

# ---- rule tables -----------------------------------------------------------

# single-pod (16, 16) mesh: axes ("data", "model")
RULES_SINGLE_POD: AxisRules = {
    "batch": ("data",),
    "ctx": (),                # sequence dim of activations (replicated)
    "ctx_res": ("model",),    # residual-stream seq dim (Megatron-style SP):
                              # layer boundaries keep activations S-sharded so
                              # the per-layer scan carries saved for backward
                              # are 1/16th size; GSPMD all-gathers S around
                              # attention/MLP and reduce-scatters back
    "ctx_shard": ("data",),   # sequence dim when context-parallel (B=1 decode)
    "embed": (),              # d_model dim (activations)
    "embed_p": ("data",),     # d_model dim of PARAMETERS: ZeRO-3/FSDP-style
                              # 2D sharding (data × model) so 132B MoE params
                              # + AdamW state fit 256 chips
    "heads": ("model",),      # attention heads / head*hd fused dims
    "kv_heads": ("model",),   # kv heads (sharded only if divisible)
    "ff": ("model",),         # MLP hidden
    "vocab": ("model",),
    "experts": (),            # MoE expert dim (EP is a hillclimb variant)
    "ssm_heads": ("model",),  # mamba2 heads
    "conv_dim": ("model",),   # mamba2 conv channels
    "layers": (),             # stacked-layer leading dim
    "edges": ("data", "model"),  # veilgraph edge buffers: flattened mesh
    "nodes": (),              # veilgraph node vectors (replicated)
}

# multi-pod (2, 16, 16) mesh: axes ("pod", "data", "model"); pod acts as an
# outer data-parallel axis.
RULES_MULTI_POD: AxisRules = {
    **RULES_SINGLE_POD,
    "batch": ("pod", "data"),
    "ctx_shard": ("data",),
    "edges": ("pod", "data", "model"),
}

# ZeRO-1 style variant: optimizer/parameter ff dims also sharded over data.
RULES_SINGLE_POD_ZERO1: AxisRules = {
    **RULES_SINGLE_POD,
    "ff_zero": ("model", "data"),
}


_state = threading.local()


def set_rules(rules: Optional[AxisRules]) -> None:
    _state.rules = rules


def get_rules() -> Optional[AxisRules]:
    return getattr(_state, "rules", None)


@contextmanager
def axis_rules(rules: Optional[AxisRules]):
    prev = get_rules()
    set_rules(rules)
    try:
        yield
    finally:
        set_rules(prev)


def rules_for_mesh(mesh: Optional[Mesh]) -> AxisRules:
    if mesh is None:
        return {}
    if "pod" in mesh.axis_names:
        return RULES_MULTI_POD
    return RULES_SINGLE_POD


def logical_to_pspec(
    logical: Sequence[Optional[str]], rules: Optional[AxisRules] = None
) -> P:
    """Map logical axis names (None = replicated) to a PartitionSpec."""
    rules = rules if rules is not None else (get_rules() or {})
    out = []
    used: set = set()
    for name in logical:
        if name is None:
            out.append(None)
            continue
        axes = tuple(a for a in rules.get(name, ()) if a not in used)
        used.update(axes)
        if len(axes) == 0:
            out.append(None)
        elif len(axes) == 1:
            out.append(axes[0])
        else:
            out.append(axes)
    # trim trailing Nones (canonical form)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def ws(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """with_sharding_constraint by logical axis names; no-op without rules."""
    rules = get_rules()
    if not rules:
        return x
    return jax.lax.with_sharding_constraint(x, logical_to_pspec(logical, rules))


def named_sharding(mesh: Mesh, *logical: Optional[str]) -> NamedSharding:
    return NamedSharding(mesh, logical_to_pspec(logical, rules_for_mesh(mesh)))


def guarded_pspec(
    shape: Sequence[int],
    logical: Sequence[Optional[str]],
    rules: AxisRules,
    axis_sizes: Dict[str, int],
) -> P:
    """logical_to_pspec with divisibility guards.

    A mesh axis is applied to a dim only if the dim is divisible by the
    product of the axes selected so far times that axis (e.g. qwen2's
    2 kv-heads are NOT sharded over a 16-way model axis — replicated
    instead), and an axis is never used twice in one spec (so a decode
    cache with batch=1 automatically falls through to context-parallel
    sharding of the sequence dim when the rules list both).
    """
    out = []
    used: set = set()
    for dim, name in zip(shape, logical):
        if name is None:
            out.append(None)
            continue
        sel = []
        prod = 1
        for a in rules.get(name, ()):
            if a in used:
                continue
            nxt = prod * axis_sizes.get(a, 1)
            if nxt > 0 and dim % nxt == 0 and dim >= nxt:
                sel.append(a)
                prod = nxt
        used.update(sel)
        if not sel:
            out.append(None)
        elif len(sel) == 1:
            out.append(sel[0])
        else:
            out.append(tuple(sel))
    while out and out[-1] is None:
        out.pop()
    return P(*out)

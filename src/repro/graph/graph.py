"""Device-resident streaming graph state.

The TPU adaptation of VeilGraph's mutable Flink graph: a padded COO edge
buffer with *static* capacities.  Streaming edge additions/removals are
functional scatters into the preallocated buffers (the graph analogue of a
KV cache), so every update and every query step is jit-compatible.

Layout
------
- ``src``/``dst``: int32[edge_capacity] COO endpoints.  Slots at index >=
  ``num_edges`` are padding; padding slots hold ``0`` and are excluded by
  ``edge_mask()``.
- ``edge_alive``: bool[edge_capacity] — False for removed edges (removals are
  tombstones; the slot is not reused until ``compact`` is called host-side).
- ``out_deg``/``in_deg``: int32[node_capacity], maintained incrementally.
- ``node_active``: a node is active once it has appeared in any edge.

All graph-level reductions mask with ``edge_mask`` so padding and tombstones
never contribute.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class GraphState(NamedTuple):
    """Padded COO graph; a JAX pytree (NamedTuple of arrays)."""

    src: jax.Array          # int32[E_cap]
    dst: jax.Array          # int32[E_cap]
    edge_alive: jax.Array   # bool[E_cap]  (False => tombstoned removal)
    num_edges: jax.Array    # int32 scalar: high-water mark of used slots
    out_deg: jax.Array      # int32[N_cap]
    in_deg: jax.Array       # int32[N_cap]
    node_active: jax.Array  # bool[N_cap]
    #: optional f32[E_cap] per-edge length/weight in *slot* order (streamed
    #: in through add_edges); ``None`` until any edge carries a weight.
    #: Consumed by ``weight="length"`` layouts, which default to it when no
    #: explicit ``lengths=`` override is given.  Unset slots hold 1.0.
    edge_len: Optional[jax.Array] = None

    # ---- static-shape helpers -------------------------------------------
    @property
    def node_capacity(self) -> int:
        """Static node-space size (vertex ids are < node_capacity)."""
        return self.out_deg.shape[0]

    @property
    def edge_capacity(self) -> int:
        """Static COO buffer size (live + tombstoned + padding slots)."""
        return self.src.shape[0]

    def edge_mask(self) -> jax.Array:
        """bool[E_cap]: True for live (non-padding, non-tombstone) edges."""
        in_use = jnp.arange(self.edge_capacity, dtype=jnp.int32) < self.num_edges
        return in_use & self.edge_alive

    def num_live_edges(self) -> jax.Array:
        """int32 scalar: edges that are in use and not tombstoned."""
        return jnp.sum(self.edge_mask().astype(jnp.int32))

    def num_active_nodes(self) -> jax.Array:
        """int32 scalar: vertices that have appeared in any edge."""
        return jnp.sum(self.node_active.astype(jnp.int32))

    def total_deg(self) -> jax.Array:
        """int32[N_cap]: out-degree + in-degree per vertex."""
        return self.out_deg + self.in_deg


def empty(node_capacity: int, edge_capacity: int) -> GraphState:
    """An empty graph with the given static capacities."""
    return GraphState(
        src=jnp.zeros((edge_capacity,), jnp.int32),
        dst=jnp.zeros((edge_capacity,), jnp.int32),
        edge_alive=jnp.ones((edge_capacity,), bool),
        num_edges=jnp.zeros((), jnp.int32),
        out_deg=jnp.zeros((node_capacity,), jnp.int32),
        in_deg=jnp.zeros((node_capacity,), jnp.int32),
        node_active=jnp.zeros((node_capacity,), bool),
    )


def from_edges(
    src: np.ndarray,
    dst: np.ndarray,
    node_capacity: int,
    edge_capacity: int,
    weights: Optional[np.ndarray] = None,
) -> GraphState:
    """Build a GraphState from host edge arrays (initial graph G).

    ``weights`` optionally attaches a per-edge length column (f32, same
    length as ``src``) consumed by ``weight="length"`` layouts; absent
    edges/slots default to 1.0.
    """
    src = np.asarray(src, np.int32)
    dst = np.asarray(dst, np.int32)
    if src.shape != dst.shape or src.ndim != 1:
        raise ValueError("src/dst must be 1-D arrays of equal length")
    m = src.shape[0]
    if m > edge_capacity:
        raise ValueError(f"{m} edges exceed edge_capacity={edge_capacity}")
    if m and (src.max() >= node_capacity or dst.max() >= node_capacity):
        raise ValueError("node id exceeds node_capacity")
    edge_len = None
    if weights is not None:
        weights = np.asarray(weights, np.float32)
        if weights.shape != src.shape:
            raise ValueError("weights must align with src/dst")
        len_pad = np.ones((edge_capacity,), np.float32)
        len_pad[:m] = weights
        edge_len = jnp.asarray(len_pad)

    src_pad = np.zeros((edge_capacity,), np.int32)
    dst_pad = np.zeros((edge_capacity,), np.int32)
    src_pad[:m] = src
    dst_pad[:m] = dst
    out_deg = np.zeros((node_capacity,), np.int32)
    in_deg = np.zeros((node_capacity,), np.int32)
    np.add.at(out_deg, src, 1)
    np.add.at(in_deg, dst, 1)
    node_active = (out_deg + in_deg) > 0
    return GraphState(
        src=jnp.asarray(src_pad),
        dst=jnp.asarray(dst_pad),
        edge_alive=jnp.ones((edge_capacity,), bool),
        num_edges=jnp.asarray(m, jnp.int32),
        out_deg=jnp.asarray(out_deg),
        in_deg=jnp.asarray(in_deg),
        node_active=jnp.asarray(node_active),
        edge_len=edge_len,
    )


def _add_edges_impl(state: GraphState, new_src: jax.Array, new_dst: jax.Array,
                    new_len: Optional[jax.Array] = None) -> GraphState:
    k = new_src.shape[0]
    e_cap = state.edge_capacity
    base = state.num_edges
    slots = base + jnp.arange(k, dtype=jnp.int32)
    ok = slots < e_cap
    slots_c = jnp.minimum(slots, e_cap - 1)

    # Scatter endpoints; where !ok keep the previous value.
    src = state.src.at[slots_c].set(jnp.where(ok, new_src, state.src[slots_c]))
    dst = state.dst.at[slots_c].set(jnp.where(ok, new_dst, state.dst[slots_c]))
    alive = state.edge_alive.at[slots_c].set(
        jnp.where(ok, True, state.edge_alive[slots_c])
    )
    edge_len = state.edge_len
    if new_len is not None:
        if edge_len is None:
            edge_len = jnp.ones((e_cap,), jnp.float32)
        edge_len = edge_len.at[slots_c].set(
            jnp.where(ok, new_len.astype(jnp.float32), edge_len[slots_c]))
    elif edge_len is not None:
        # unweighted chunk into a weighted graph: slots default to 1.0
        edge_len = edge_len.at[slots_c].set(
            jnp.where(ok, 1.0, edge_len[slots_c]))

    one = jnp.where(ok, 1, 0).astype(jnp.int32)
    out_deg = state.out_deg.at[new_src].add(one)
    in_deg = state.in_deg.at[new_dst].add(one)
    node_active = state.node_active.at[new_src].set(
        state.node_active[new_src] | (one > 0)
    )
    node_active = node_active.at[new_dst].set(node_active[new_dst] | (one > 0))

    num_edges = jnp.minimum(base + k, e_cap).astype(jnp.int32)
    return GraphState(src, dst, alive, num_edges, out_deg, in_deg,
                      node_active, edge_len)


#: Append a fixed-size chunk of edges.
#:
#: ``new_src``/``new_dst`` have a *static* chunk length (the stream chunk
#: size), so this compiles once per chunk size.  Slots past
#: ``edge_capacity`` are silently dropped (callers check ``has_capacity``
#: first; the engine's BeforeUpdates stage enforces it).
#:
#: ``new_len`` optionally streams a per-edge length column alongside the
#: endpoints (f32[k]); the first weighted chunk materializes ``edge_len``
#: (previous slots default to 1.0), and later unweighted chunks leave
#: their slots at 1.0.
#:
#: Donates the input state: the previous epoch's buffers are reused in
#: place, so the caller must not hold references to them.
add_edges = functools.partial(jax.jit, donate_argnums=(0,))(_add_edges_impl)

#: Non-donating ``add_edges``: same program, but the input state's buffers
#: survive the call.  The async rebuild pipeline applies updates with this
#: variant so the served ``EpochSnapshot`` (which aliases the pre-update
#: buffers) stays immutable while the live state advances past it.
add_edges_preserving = jax.jit(_add_edges_impl)


def _remove_edges_by_slot_impl(state: GraphState, slots: jax.Array) -> GraphState:
    valid = (slots >= 0) & (slots < state.edge_capacity)
    slots_c = jnp.clip(slots, 0, state.edge_capacity - 1)
    was_alive = state.edge_alive[slots_c] & valid & (
        slots_c < state.num_edges
    )
    alive = state.edge_alive.at[slots_c].set(
        jnp.where(was_alive, False, state.edge_alive[slots_c])
    )
    dec = jnp.where(was_alive, 1, 0).astype(jnp.int32)
    out_deg = state.out_deg.at[state.src[slots_c]].add(-dec)
    in_deg = state.in_deg.at[state.dst[slots_c]].add(-dec)
    return state._replace(edge_alive=alive, out_deg=out_deg, in_deg=in_deg)


#: Tombstone the edges stored at ``slots`` (int32[k]); -1 entries are
#: no-ops.  Donates the input state (buffers reused in place).
#:
#: Beyond-paper: the paper restricts its evaluation to edge additions (e+)
#: and leaves removals to future work; the substrate supports them so the
#: engine's stream model is complete.
remove_edges_by_slot = functools.partial(
    jax.jit, donate_argnums=(0,))(_remove_edges_by_slot_impl)

#: Non-donating ``remove_edges_by_slot`` — see ``add_edges_preserving``;
#: used by the async pipeline so served snapshots keep their buffers.
remove_edges_by_slot_preserving = jax.jit(_remove_edges_by_slot_impl)


def find_edge_slots(state: GraphState, src: np.ndarray, dst: np.ndarray) -> np.ndarray:
    """Host-side lookup of buffer slots holding the given edges (-1 if absent)."""
    s = np.asarray(jax.device_get(state.src))
    d = np.asarray(jax.device_get(state.dst))
    alive = np.asarray(jax.device_get(state.edge_mask()))
    key = s.astype(np.int64) * (2**32) + d.astype(np.int64)
    lut = {}
    for i in np.nonzero(alive)[0]:
        lut.setdefault(key[i], i)
    q = np.asarray(src, np.int64) * (2**32) + np.asarray(dst, np.int64)
    return np.asarray([lut.get(k, -1) for k in q], np.int32)


@jax.jit
def recompute_degrees(state: GraphState) -> Tuple[jax.Array, jax.Array]:
    """O(E) degree recomputation — the oracle for the incremental counters."""
    m = state.edge_mask().astype(jnp.int32)
    n = state.node_capacity
    out_deg = jax.ops.segment_sum(m, state.src, num_segments=n)
    in_deg = jax.ops.segment_sum(m, state.dst, num_segments=n)
    return out_deg.astype(jnp.int32), in_deg.astype(jnp.int32)


@jax.jit
def inv_out_degree(state: GraphState) -> jax.Array:
    """f32[N_cap]: 1/d_out(u) with 0 for dangling/inactive nodes."""
    d = state.out_deg.astype(jnp.float32)
    return jnp.where(d > 0, 1.0 / jnp.maximum(d, 1.0), 0.0)


def compact(state: GraphState) -> GraphState:
    """Host-side rebuild dropping tombstones (reclaims removed-edge slots)."""
    mask = np.asarray(jax.device_get(state.edge_mask()))
    s = np.asarray(jax.device_get(state.src))[mask]
    d = np.asarray(jax.device_get(state.dst))[mask]
    w = None
    if state.edge_len is not None:
        w = np.asarray(jax.device_get(state.edge_len))[mask]
    return from_edges(s, d, state.node_capacity, state.edge_capacity,
                      weights=w)


def to_networkx(state: GraphState):
    """Debug/test helper: export live edges to a networkx DiGraph."""
    import networkx as nx

    mask = np.asarray(jax.device_get(state.edge_mask()))
    s = np.asarray(jax.device_get(state.src))[mask]
    d = np.asarray(jax.device_get(state.dst))[mask]
    g = nx.DiGraph()
    active = np.nonzero(np.asarray(jax.device_get(state.node_active)))[0]
    g.add_nodes_from(active.tolist())
    g.add_edges_from(zip(s.tolist(), d.tolist()))
    return g

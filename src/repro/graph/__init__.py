from repro.graph.csr import SortedEdges, gather_push, sort_by_dst
from repro.graph.graph import (GraphState, add_edges, compact, empty,
                               from_edges, inv_out_degree, recompute_degrees,
                               remove_edges_by_slot)

"""Edge partitioning across a device mesh.

The VeilGraph runtime shards the COO edge buffers over every mesh axis
(1-D edge parallelism: the TPU analogue of Pregel's edge-cut) while node
vectors stay replicated; the per-iteration push is a local segment-sum plus
one all-reduce of the dense rank vector.  These helpers build the shardings
the dry-run and a real deployment use, and a host-side round-robin
assignment for multi-host ingestion.
"""

from __future__ import annotations

from typing import Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.graph.graph import GraphState
from repro.sharding.rules import guarded_pspec, rules_for_mesh


def edge_sharding(mesh: Mesh, edge_capacity: int) -> NamedSharding:
    rules = rules_for_mesh(mesh)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return NamedSharding(mesh, guarded_pspec((edge_capacity,), ("edges",),
                                             rules, sizes))


def graph_shardings(mesh: Mesh, state: GraphState) -> GraphState:
    """Sharding pytree for a GraphState: edges sharded, nodes replicated."""
    e = edge_sharding(mesh, state.edge_capacity)
    n = NamedSharding(mesh, P())
    return GraphState(src=e, dst=e, edge_alive=e, num_edges=n,
                      out_deg=n, in_deg=n, node_active=n)


def host_edge_slice(num_edges: int, process: int,
                    num_processes: int) -> Tuple[int, int]:
    """Contiguous per-host ingestion range (multi-host streaming loaders)."""
    per = (num_edges + num_processes - 1) // num_processes
    lo = min(process * per, num_edges)
    return lo, min(lo + per, num_edges)

"""Edge partitioning across a device mesh.

The VeilGraph runtime shards the COO edge buffers over every mesh axis
(1-D edge parallelism: the TPU analogue of Pregel's edge-cut) while node
vectors stay replicated; the per-iteration push is a local partial reduce
plus one all-reduce of the dense rank vector.  Two layers live here:

- the GSPMD shardings the dry-run and a real deployment pin on the raw
  ``GraphState`` buffers (:func:`edge_sharding`, :func:`graph_shardings`),
  plus a host-side round-robin assignment for multi-host ingestion;
- the **sharded edge layouts** the mesh-aware propagation backend
  consumes (:func:`build_sharded_layout`): the edge buffer cut into
  contiguous shards, each destination-sorted *locally* — the cached-sort
  story of :mod:`repro.core.backend` carried to the distributed setting
  without ever running a sort across shards (a pod-scale global argsort
  would defeat GSPMD's edge sharding; S independent local sorts do not);
- **shard rebalancing** (:func:`rebalance_sharded_layout` and friends):
  streaming appends land at the high-water mark, so the contiguous cut
  fills tail-heavy and removals hollow out arbitrary shards; the engine
  tracks per-shard live-edge counts after each applied update batch and,
  past ``EngineConfig.rebalance_threshold``, recuts the partition with a
  live-balanced slot assignment (:func:`balanced_shard_slots`) that the
  next layout build migrates to with one static-shaped gather.  Any valid
  partition yields the same push result (bitwise for min semirings), so
  rebalancing is purely a load-balance decision.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import backend as B
from repro.graph.graph import GraphState, inv_out_degree
from repro.sharding.rules import guarded_pspec, rules_for_mesh


def edge_sharding(mesh: Mesh, edge_capacity: int) -> NamedSharding:
    """The 1-D GSPMD sharding for an edge-capacity buffer: the ``edges``
    logical axis laid over the mesh per its sharding rules."""
    rules = rules_for_mesh(mesh)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return NamedSharding(mesh, guarded_pspec((edge_capacity,), ("edges",),
                                             rules, sizes))


def graph_shardings(mesh: Mesh, state: GraphState) -> GraphState:
    """Sharding pytree for a GraphState: edges sharded, nodes replicated."""
    e = edge_sharding(mesh, state.edge_capacity)
    n = NamedSharding(mesh, P())
    return GraphState(src=e, dst=e, edge_alive=e, num_edges=n,
                      out_deg=n, in_deg=n, node_active=n,
                      edge_len=None if state.edge_len is None else e)


def host_edge_slice(num_edges: int, process: int,
                    num_processes: int) -> Tuple[int, int]:
    """Contiguous per-host ingestion range (multi-host streaming loaders)."""
    per = (num_edges + num_processes - 1) // num_processes
    lo = min(process * per, num_edges)
    return lo, min(lo + per, num_edges)


# ---------------------------------------------------------------------------
# Sharded edge layouts (the mesh-aware backend's input)
# ---------------------------------------------------------------------------


def shard_slots(edge_capacity: int, num_shards: int) -> np.ndarray:
    """int32[S, E_s] original edge slot per (shard, position) — the
    contiguous partition :func:`build_sharded_layout` applies *before* its
    per-shard sort.  Shard ``s`` owns slots ``[s·E_s, (s+1)·E_s)``
    (contiguous, so a 1-D edge-sharded buffer reshapes onto the shard axis
    with zero communication); slots ≥ ``edge_capacity`` are padding
    (sentinel ``edge_capacity``).  Every real slot lands in exactly one
    shard — the property the partition tests pin.
    """
    e_s = -(-edge_capacity // num_shards)
    slots = np.arange(num_shards * e_s, dtype=np.int32)
    return np.where(slots < edge_capacity, slots,
                    edge_capacity).reshape(num_shards, e_s)


@functools.partial(
    jax.jit,
    static_argnames=("num_shards", "weight", "reverse", "chunk", "semiring",
                     "tile_n", "weight_dtype"))
def _build_shards(
    state: GraphState,
    *,
    num_shards: int,
    weight: str,
    reverse: bool,
    chunk: int,
    semiring: str,
    lengths: Optional[jax.Array] = None,
    slots: Optional[jax.Array] = None,
    tile_n: Optional[int] = None,
    weight_dtype: Optional[str] = None,
) -> B.ShardedEdgeLayout:
    """The jitted core of :func:`build_sharded_layout` (no mesh metadata —
    the partition and the S local sorts are pure array work).

    ``slots`` (int32[S, ⌈E_cap/S⌉], sentinel ``E_cap`` for padding)
    overrides the default contiguous cut with an explicit slot→shard
    assignment — the rebalancing path: the stacked streams are then built
    by one static-shaped gather per buffer (the slot *migration*) instead
    of the communication-free pad+reshape.
    """
    if weight == "length" and lengths is None:
        lengths = state.edge_len  # streamed per-edge lengths, if any
    s = B.validate_weight_spec(weight, reverse=reverse, semiring=semiring,
                               lengths=lengths,
                               edge_capacity=state.edge_capacity)
    e_cap = state.edge_capacity
    n_cap = state.node_capacity
    mask = state.edge_mask()
    e_src, e_dst = (state.dst, state.src) if reverse else (state.src,
                                                           state.dst)
    # same ⊗-operand definition as build_layout, here in slot order
    w = B.bake_weights(s, weight, mask, e_src,
                       inv_deg=inv_out_degree(state), lengths=lengths,
                       weight_dtype=weight_dtype)

    e_s = -(-e_cap // num_shards)
    if slots is None:
        # contiguous slot partition: pad the slot space to S·E_s and
        # reshape — on a 1-D edge-sharded buffer this is communication-free
        # under GSPMD
        pad = num_shards * e_s - e_cap

        def cut(x, cval):
            return jnp.pad(x, (0, pad), constant_values=cval).reshape(
                num_shards, e_s)
    else:
        # rebalanced partition: migrate slots with one static-shaped gather
        # per buffer (a one-off resharding under GSPMD, amortized exactly
        # like the sort — once per applied update batch)
        ok = slots < e_cap
        sl = jnp.minimum(slots, e_cap - 1)

        def cut(x, cval):
            return jnp.where(ok, x[sl], jnp.asarray(cval, x.dtype))

    src2 = cut(e_src, 0)
    dst2 = cut(jnp.where(mask, e_dst, n_cap), n_cap)  # invalid sorts last
    w2 = cut(w, s.zero)
    valid2 = cut(mask, False)
    order2 = cut(jnp.arange(e_cap, dtype=jnp.int32), e_cap)

    # S independent destination sorts — axis-1 sorts stay shard-local under
    # GSPMD (no cross-device exchange), unlike one global E_cap argsort
    perm = jnp.argsort(dst2, axis=1, stable=True)
    take = lambda x: jnp.take_along_axis(x, perm, axis=1)
    src2, dst2, w2, valid2, order2 = map(take,
                                         (src2, dst2, w2, valid2, order2))
    row_offsets = jax.vmap(
        lambda d: jnp.searchsorted(
            d, jnp.arange(n_cap + 1, dtype=jnp.int32),
            side="left").astype(jnp.int32))(dst2)

    # chunk slack per shard, same convention as the single builder: the
    # kernel's fixed-size chunk loads never run past any shard's buffer
    extra = B.padded_length(e_s, chunk) - e_s
    pad2 = lambda x, cval: jnp.pad(x, ((0, 0), (0, extra)),
                                   constant_values=cval)
    dst_p = pad2(dst2, n_cap)
    valid_p = pad2(valid2, False)
    rank = (jax.vmap(B.stream_rank)(dst_p, valid_p, row_offsets)
            if s.add != "sum" else None)
    return B.ShardedEdgeLayout(
        pad2(src2, 0), dst_p, pad2(w2, s.zero),
        valid_p, row_offsets, pad2(order2, e_cap), rank,
        weight_mode=weight, reverse=reverse, pad_chunk=chunk,
        semiring=s.name, tile_n=tile_n, tile_chunk=chunk)


def build_sharded_layout(
    state: GraphState,
    *,
    mesh: Optional[Mesh] = None,
    axes: Optional[Tuple[str, ...]] = None,
    num_shards: Optional[int] = None,
    weight: str = "inv_out",
    reverse: bool = False,
    chunk: Optional[int] = None,
    semiring: str = "plus_times",
    lengths: Optional[jax.Array] = None,
    slots: Optional[jax.Array] = None,
    tile_n: Optional[int] = None,
    weight_dtype: Optional[str] = None,
) -> B.ShardedEdgeLayout:
    """Edge-partitioned, per-shard destination-sorted propagation layout.

    The sharded sibling of :func:`repro.core.backend.build_layout` — same
    ``weight``/``reverse``/``semiring``/``lengths`` spec space (validated
    by the same :func:`~repro.core.backend.validate_weight_spec`), but the
    edge stream is first cut into ``num_shards`` slot ranges and each
    shard sorted independently, so no sort ever crosses a shard boundary.
    :func:`repro.core.backend.push` consumes the result as a
    ``shard_map``-ed partial push + semiring all-reduce.

    Parameters
    ----------
    mesh / axes
        Device mapping: the shard axis is laid over ``axes`` (default:
        every mesh axis, flattened).  With ``mesh=None`` (``num_shards``
        required) the layout runs as an on-device loop — the reference
        semantics sharded parity tests compare against, and a way to
        exercise S-way partitioning without S devices.
    num_shards
        Defaults to the total device count of ``axes`` and must stay a
        multiple of it.
    weight / reverse / semiring / lengths
        The baked ⊗-operand spec — see
        :func:`repro.core.backend.build_layout`.
    slots
        Optional explicit slot→shard assignment
        (int32[num_shards, ⌈E_cap/num_shards⌉], sentinel ``E_cap`` in
        padding positions; every live slot must appear exactly once).
        Default is the contiguous cut of :func:`shard_slots`; pass
        :func:`balanced_shard_slots` output (or any custom partition) to
        *rebalance* — the streams are then gathered per the assignment
        instead of reshaped.  See :func:`rebalance_sharded_layout`.

    Returns a :class:`~repro.core.backend.ShardedEdgeLayout` of stacked
    ``[num_shards, E_pad]`` streams.  Traced inline-compatible: callable
    from inside jit (the fused query step builds sharded layouts on the
    fly when handed a mesh but no cache), with the engine caching built
    instances per applied update batch exactly like single layouts.
    """
    if mesh is not None:
        axes = tuple(axes) if axes is not None else tuple(mesh.axis_names)
        for a in axes:
            if a not in mesh.axis_names:
                raise ValueError(
                    f"mesh axis {a!r} not in mesh {tuple(mesh.axis_names)}")
        n_dev = mesh_shard_count(mesh, axes)
        if num_shards is None:
            num_shards = n_dev
        if num_shards % n_dev:
            raise ValueError(
                f"num_shards={num_shards} must be a multiple of the "
                f"{n_dev} devices on mesh axes {axes}")
    elif num_shards is None:
        raise ValueError("build_sharded_layout needs mesh= or num_shards=")
    else:
        axes = ()
    if slots is not None:
        want = (num_shards, -(-state.edge_capacity // num_shards))
        if tuple(slots.shape) != want:
            raise ValueError(
                f"slots assignment shape {tuple(slots.shape)} does not "
                f"match {want} for num_shards={num_shards}, "
                f"edge_capacity={state.edge_capacity}")
        slots = jnp.asarray(slots, jnp.int32)
    layout = _build_shards(
        state, num_shards=num_shards, weight=weight, reverse=reverse,
        chunk=B.CHUNK if chunk is None else chunk, semiring=semiring,
        lengths=lengths, slots=slots, tile_n=tile_n,
        weight_dtype=weight_dtype)
    if mesh is not None:
        layout = dataclasses.replace(layout, mesh=mesh, axes=axes)
    return layout


# ---------------------------------------------------------------------------
# Shard rebalancing (streaming keeps the contiguous cut tail-heavy)
# ---------------------------------------------------------------------------


def mesh_shard_count(mesh: Mesh, axes: Optional[Tuple[str, ...]]) -> int:
    """Total device count over ``axes`` (default: every mesh axis) — the
    shard count a mesh-configured engine partitions its layouts into.
    The single definition :func:`build_sharded_layout` and the engine's
    rebalance path both resolve through, so the rebalanced ``slots`` shape
    can never drift from the layout's shard count."""
    names = tuple(axes) if axes is not None else tuple(mesh.axis_names)
    n = 1
    for a in names:
        n *= mesh.shape[a]
    return n


@jax.jit
def shard_live_counts(state: GraphState, slots: jax.Array) -> jax.Array:
    """int32[S]: live (non-padding, non-tombstone) edges per shard under a
    slot assignment — the balance signal the engine tracks after
    ``add_edges``/``remove_edges`` batches apply."""
    e_cap = state.edge_capacity
    mask = state.edge_mask()
    ok = slots < e_cap
    live = ok & mask[jnp.minimum(slots, e_cap - 1)]
    return jnp.sum(live.astype(jnp.int32), axis=1)


def shard_imbalance(counts: jax.Array) -> jax.Array:
    """Scalar imbalance of per-shard live counts:
    ``(max − min) / max(mean, 1)``.  0 for a perfectly even partition;
    ``≈ S`` when one shard holds everything.  Dimensionless, so one
    threshold works across graph sizes."""
    c = counts.astype(jnp.float32)
    return (jnp.max(c) - jnp.min(c)) / jnp.maximum(jnp.mean(c), 1.0)


@functools.partial(jax.jit, static_argnames=("num_shards",))
def balanced_shard_slots(state: GraphState, *,
                         num_shards: int) -> jax.Array:
    """A live-balanced slot→shard assignment (int32[S, ⌈E_cap/S⌉]).

    Live slots are dealt round-robin across shards in slot order (shard
    counts differ by at most one), then dead/padding slots continue the
    same deal — so the slot ids a streaming ``add_edges`` will fill next
    (consecutive ids above the high-water mark) are also pre-spread across
    shards, keeping post-rebalance appends balanced instead of refilling
    one tail shard.  Pure prefix-sum work, jit-compatible; feed the result
    to :func:`build_sharded_layout` via ``slots=``.
    """
    e_cap = state.edge_capacity
    e_s = -(-e_cap // num_shards)
    mask = state.edge_mask()
    m = mask.astype(jnp.int32)
    live_rank = jnp.cumsum(m) - m           # exclusive prefix over lives
    dead_rank = jnp.cumsum(1 - m) - (1 - m)
    seq = jnp.where(mask, live_rank, jnp.sum(m) + dead_rank)
    flat = (seq % num_shards) * e_s + seq // num_shards
    out = jnp.full((num_shards * e_s,), e_cap, jnp.int32)
    out = out.at[flat].set(jnp.arange(e_cap, dtype=jnp.int32), mode="drop")
    return out.reshape(num_shards, e_s)


@jax.jit
def rebalance_decision(state: GraphState, slots: jax.Array,
                       threshold: jax.Array
                       ) -> Tuple[jax.Array, jax.Array]:
    """On-device rebalance verdict: ``(should_rebalance bool[],
    imbalance f32[])`` for the current slot assignment.

    One fused jitted program — live-count, imbalance and the threshold
    compare all stay on device, so the engine's per-applied-batch
    balance check transfers exactly one (bool, f32) pair to host instead
    of syncing on an eager ``float(...)`` mid-pipeline.  ``threshold``
    may be a python float (weak-typed scalar traces once).

    The async rebuild pipeline (``EngineConfig.async_rebuild``) goes one
    step further: the verdict is *dispatched but not awaited* alongside
    each :class:`~repro.core.epoch.EpochSnapshot` build and the (bool,
    f32) pair is fetched only when the snapshot is promoted at a wave
    boundary — a recut then applies to the *next* epoch's layout cuts,
    never to the already-sorted snapshot being promoted.
    """
    imbalance = shard_imbalance(shard_live_counts(state, slots))
    return imbalance > threshold, imbalance


def rebalance_sharded_layout(
    state: GraphState,
    *,
    num_shards: int,
    slots: Optional[jax.Array] = None,
    threshold: float = 1.0,
) -> Tuple[jax.Array, bool, float]:
    """Recut the edge partition when live-edge imbalance exceeds
    ``threshold``.

    ``slots`` is the current assignment (default: the contiguous
    :func:`shard_slots` cut — what a mesh engine starts from).  Returns
    ``(slots', rebalanced, imbalance)``: the assignment to build the next
    layouts with, whether it changed, and the imbalance that was measured
    (one :func:`rebalance_decision` verdict pair read back to host — this
    runs between jitted steps, once per applied update batch, never in
    the query hot loop).

    The recut itself is :func:`balanced_shard_slots`; the *migration*
    happens at the next :func:`build_sharded_layout` call, which gathers
    the streams per the new assignment (static shapes — one O(E) gather,
    amortized exactly like the per-shard sorts).  The engine drives this
    loop: it invalidates its cached layouts and counts the event in
    ``engine.rebalances``.
    """
    if slots is None:
        slots = jnp.asarray(shard_slots(state.edge_capacity, num_shards))
    should, imbalance = jax.device_get(  # analysis: allow(AST-HOST-SYNC): the one verdict read per applied batch — the documented host boundary of the rebalance loop
        rebalance_decision(state, slots, jnp.float32(threshold)))
    if not bool(should):
        return slots, False, float(imbalance)
    return (balanced_shard_slots(state, num_shards=num_shards), True,
            float(imbalance))


def place_sharded_layout(layout: B.ShardedEdgeLayout) -> B.ShardedEdgeLayout:
    """``device_put`` the stacked arrays onto the layout's mesh (leading
    shard axis over ``layout.axes``, trailing dims replicated).

    A freshly built layout lives wherever jit put it (one device, by
    default); left there, every consuming ``shard_map`` would re-distribute
    the full O(E) stream per call — paying the data-movement half of the
    "sorted at most once per update batch" amortization every query.  The
    engine runs this once per cache fill instead.  No-op without a mesh.
    """
    if layout.mesh is None:
        return layout
    sharded = NamedSharding(layout.mesh, P(layout.axes))
    put = lambda x: None if x is None else jax.device_put(x, sharded)
    return dataclasses.replace(
        layout, src=put(layout.src), dst=put(layout.dst),
        weight=put(layout.weight), valid=put(layout.valid),
        row_offsets=put(layout.row_offsets), order=put(layout.order),
        rank=put(layout.rank))


__all__ = [
    "balanced_shard_slots",
    "build_sharded_layout",
    "edge_sharding",
    "graph_shardings",
    "host_edge_slice",
    "mesh_shard_count",
    "place_sharded_layout",
    "rebalance_decision",
    "rebalance_sharded_layout",
    "shard_imbalance",
    "shard_live_counts",
    "shard_slots",
]

"""CSR/CSC derivation from the padded COO buffer.

The power-iteration push consumes a *receiver-sorted* (CSC-like) edge layout
so each output tile accumulates from a contiguous edge range — both the
Pallas SpMV kernel and the ``indices_are_sorted`` segment-sum fallback in
:mod:`repro.core.backend` are built on it.  Sorting happens at most once per
applied update batch: the engine caches the sorted layout and reuses it
across queries, and each query's ~30 power iterations reuse it per
iteration.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .graph import GraphState


class SortedEdges(NamedTuple):
    """Edges permuted so the receiving endpoint is non-decreasing.

    ``src`` is the *emitting* endpoint and ``dst`` the *receiving* one in
    the chosen orientation — with ``reverse=True`` they are the transposed
    graph's, i.e. ``src`` holds original destinations.  Padding/tombstone
    slots sort to the end with ``dst = node_capacity``.  ``order`` is the
    applied permutation (sorted position → original edge slot) so per-edge
    payloads such as lengths can be carried into the sorted stream.
    """

    src: jax.Array        # int32[E_cap] emitting endpoint
    dst: jax.Array        # int32[E_cap] receiving endpoint (n_cap = padding)
    valid: jax.Array      # bool[E_cap]
    row_offsets: jax.Array  # int32[N_cap + 1] — edge range per receiver
    order: jax.Array      # int32[E_cap] — original edge slot per position


@functools.partial(jax.jit, static_argnames=("reverse",))
def sort_by_dst(state: GraphState, *, reverse: bool = False) -> SortedEdges:
    """Sort live edges by receiving endpoint (``state.src`` when ``reverse``).

    ``reverse=True`` sorts the transposed edge set — the layout for sweeps
    that accumulate along *out*-edges (the hub update in HITS).
    """
    mask = state.edge_mask()
    n = state.node_capacity
    e_src, e_dst = (state.dst, state.src) if reverse else (state.src, state.dst)
    # invalid edges get dst = n so they sort last
    key = jnp.where(mask, e_dst, n)
    order = jnp.argsort(key, stable=True)
    dst_s = key[order]
    src_s = e_src[order]
    valid = mask[order]
    # offsets via searchsorted over the sorted keys
    row_offsets = jnp.searchsorted(
        dst_s, jnp.arange(n + 1, dtype=jnp.int32), side="left"
    ).astype(jnp.int32)
    return SortedEdges(src_s, dst_s, valid, row_offsets,
                       order.astype(jnp.int32))


def gather_push(
    edges,
    values: jax.Array,
    num_segments: int,
    *,
    weight: Optional[jax.Array] = None,
    mask: Optional[jax.Array] = None,
    semiring=None,
) -> jax.Array:
    """out[v] = ⊕ over sorted in-edges (u,v) of values[u] ⊗ weight(u,v).

    The ``indices_are_sorted`` segment-reduce fallback of the propagation
    backend (:func:`repro.core.backend.push`): on sorted layouts XLA skips
    the scatter's sort/unique analysis, so even the non-Pallas path profits
    from the amortized edge sort.  ``edges`` is anything with
    ``src``/``dst``/``valid`` fields over the same (sorted) edge order — a
    :class:`SortedEdges` or a :class:`repro.core.backend.EdgeLayout`;
    ``weight``/``mask`` are optional per-edge multipliers/filters in that
    order.  ``semiring`` is a resolved
    :class:`~repro.core.semiring.Semiring` (``None`` = the classic
    sum-of-products): ⊗ combines value and weight, masked/invalid edges
    contribute the ⊕-identity, and the reduce lowers to XLA's
    ``segment_sum``/``segment_min``/``segment_max``.  Traced inline (call
    from inside jit).
    """
    contrib = values[edges.src]
    if weight is not None:
        contrib = contrib * weight if semiring is None else \
            semiring.combine(contrib, weight)
    keep = edges.valid if mask is None else (edges.valid & mask)
    zero = 0.0 if semiring is None else \
        jnp.asarray(semiring.zero, contrib.dtype)
    contrib = jnp.where(keep, contrib, zero)
    # padding sentinel (= node capacity) clamps into range; its contribution
    # is already the reduce identity
    dst = jnp.minimum(edges.dst, num_segments - 1)
    if semiring is None:
        return jax.ops.segment_sum(
            contrib, dst, num_segments=num_segments, indices_are_sorted=True
        )
    return semiring.segment_reduce(
        contrib, dst, num_segments=num_segments, indices_are_sorted=True
    )

"""CSR/CSC derivation from the padded COO buffer.

The power-iteration push is expressed as a segment-sum over COO in the pure
JAX path; the Pallas SpMV kernel instead consumes a *destination-sorted*
(CSC-like) layout so each output tile accumulates from a contiguous edge
range.  Sorting happens once per query (after updates are applied), which the
paper's own summary construction also amortizes over ~30 power iterations.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .graph import GraphState


class SortedEdges(NamedTuple):
    """Edges permuted so dst is non-decreasing; padding sorts to the end."""

    src: jax.Array        # int32[E_cap]
    dst: jax.Array        # int32[E_cap]  (node_capacity for padding slots)
    valid: jax.Array      # bool[E_cap]
    row_offsets: jax.Array  # int32[N_cap + 1] — edge range per destination


@jax.jit
def sort_by_dst(state: GraphState) -> SortedEdges:
    mask = state.edge_mask()
    n = state.node_capacity
    # invalid edges get dst = n so they sort last
    key = jnp.where(mask, state.dst, n)
    order = jnp.argsort(key, stable=True)
    dst_s = key[order]
    src_s = state.src[order]
    valid = mask[order]
    # offsets via searchsorted over the sorted keys
    row_offsets = jnp.searchsorted(
        dst_s, jnp.arange(n + 1, dtype=jnp.int32), side="left"
    ).astype(jnp.int32)
    return SortedEdges(src_s, dst_s, valid, row_offsets)


@jax.jit
def gather_push(
    edges: SortedEdges, values: jax.Array, num_segments: int
) -> jax.Array:
    """out[v] = sum over sorted in-edges (u,v) of values[u] — sorted segments."""
    contrib = jnp.where(edges.valid, values[edges.src], 0.0)
    dst = jnp.minimum(edges.dst, num_segments - 1)
    return jax.ops.segment_sum(
        contrib, dst, num_segments=num_segments, indices_are_sorted=True
    )

"""Synthetic graph generators (offline stand-ins for the paper's datasets).

The container has no network access, so the LAW/SNAP datasets in the paper's
Table 1 (cnr-2000, eu-2005, Cit-HepPh, enron, dblp-2010, amazon-2008,
Facebook-ego) are unavailable.  We generate synthetic graphs from the same
structural families — scale-free preferential attachment for web/social
graphs, a time-ordered preferential-attachment DAG for the citation network,
G(n,m) as an unstructured control — and mirror the paper's protocol on them.
All generators are numpy-based (networkx is too slow at these sizes) and
deterministic given a seed.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple

import numpy as np


def barabasi_albert_edges(
    n: int, m: int, seed: int = 0, directed_both: float = 0.25
) -> Tuple[np.ndarray, np.ndarray]:
    """Directed scale-free graph via the repeated-nodes BA construction.

    Each new node u attaches m out-edges to targets sampled proportionally to
    degree (classic Barabási–Albert).  With probability ``directed_both`` a
    reciprocal edge is added, approximating the partial symmetry of web
    graphs.  O(n·m) time.
    """
    rng = np.random.default_rng(seed)
    if n <= m:
        raise ValueError("n must exceed m")
    # `repeated` holds one entry per edge endpoint => sampling uniformly from
    # it is sampling proportional to degree.
    repeated = np.empty(2 * n * m + 2 * m, np.int64)
    rsize = 0
    src_l = np.empty(n * m, np.int64)
    dst_l = np.empty(n * m, np.int64)
    e = 0
    # seed clique-ish core: node m attaches to 0..m-1
    for t in range(m):
        src_l[e], dst_l[e] = m, t
        repeated[rsize] = m
        repeated[rsize + 1] = t
        rsize += 2
        e += 1
    for u in range(m + 1, n):
        # sample m distinct targets from the repeated-node pool
        targets = repeated[rng.integers(0, rsize, size=4 * m)]
        targets = np.unique(targets)[:m]
        while targets.shape[0] < m:
            extra = repeated[rng.integers(0, rsize, size=4 * m)]
            targets = np.unique(np.concatenate([targets, extra]))[:m]
        k = targets.shape[0]
        src_l[e : e + k] = u
        dst_l[e : e + k] = targets
        repeated[rsize : rsize + k] = u
        repeated[rsize + k : rsize + 2 * k] = targets
        rsize += 2 * k
        e += k
    src = src_l[:e]
    dst = dst_l[:e]
    # reciprocal edges
    flip = np.random.default_rng(seed + 1).random(e) < directed_both
    src = np.concatenate([src, dst[flip]])
    dst = np.concatenate([dst, src[:e][flip]])
    return src.astype(np.int32), dst.astype(np.int32)


def citation_dag_edges(
    n: int, m: int, seed: int = 0, recency_bias: float = 0.3
) -> Tuple[np.ndarray, np.ndarray]:
    """Time-ordered preferential-attachment DAG (Cit-HepPh stand-in).

    Node u (published at time u) cites ~m earlier papers, chosen by a mix of
    preferential attachment and recency — edges always point backwards in
    time, giving the acyclic structure of citation networks.
    """
    rng = np.random.default_rng(seed)
    deg = np.ones(n, np.float64)  # +1 smoothing
    src_l, dst_l = [], []
    for u in range(1, n):
        k = min(u, 1 + rng.poisson(m - 1))
        if rng.random() < recency_bias and u > 10:
            # recency: cite among the latest 10% of papers
            lo = max(0, int(u * 0.9))
            cand = rng.integers(lo, u, size=k)
        else:
            p = deg[:u] / deg[:u].sum()
            cand = rng.choice(u, size=k, p=p, replace=True)
        cand = np.unique(cand)
        src_l.append(np.full(cand.shape[0], u, np.int64))
        dst_l.append(cand)
        deg[cand] += 1.0
        deg[u] += cand.shape[0]
    src = np.concatenate(src_l).astype(np.int32)
    dst = np.concatenate(dst_l).astype(np.int32)
    return src, dst


def gnm_edges(n: int, m: int, seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """Erdős–Rényi G(n,m) directed, no self loops (duplicates possible but rare)."""
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, size=int(m * 1.05)).astype(np.int32)
    dst = rng.integers(0, n, size=int(m * 1.05)).astype(np.int32)
    ok = src != dst
    return src[ok][:m], dst[ok][:m]


def community_ego_edges(
    n: int, n_comm: int, p_in_deg: float, seed: int = 0
) -> Tuple[np.ndarray, np.ndarray]:
    """Dense community graph (Facebook-ego stand-in): planted partitions with
    degree-skewed intra-community edges plus a sparse global hub overlay."""
    rng = np.random.default_rng(seed)
    comm = rng.integers(0, n_comm, size=n)
    order = np.argsort(comm, kind="stable")
    src_l, dst_l = [], []
    for c in range(n_comm):
        members = order[np.searchsorted(comm[order], c, "left"):
                        np.searchsorted(comm[order], c, "right")]
        k = members.shape[0]
        if k < 2:
            continue
        m_edges = int(p_in_deg * k)
        # power-law-ish endpoint choice inside the community
        a = members[np.minimum((rng.pareto(2.0, m_edges)).astype(np.int64), k - 1)]
        b = members[rng.integers(0, k, size=m_edges)]
        ok = a != b
        src_l.append(a[ok])
        dst_l.append(b[ok])
    # hub overlay: 1% hubs receive global edges
    hubs = rng.choice(n, size=max(1, n // 100), replace=False)
    g_src = rng.integers(0, n, size=n)
    g_dst = hubs[rng.integers(0, hubs.shape[0], size=n)]
    ok = g_src != g_dst
    src_l.append(g_src[ok])
    dst_l.append(g_dst[ok])
    src = np.concatenate(src_l).astype(np.int32)
    dst = np.concatenate(dst_l).astype(np.int32)
    return src, dst


@dataclass(frozen=True)
class DatasetSpec:
    """A named synthetic dataset: generator id + kwargs, scaled to the
    paper's Table 1 families (hashable, so specs can key caches)."""

    name: str
    family: str        # web | social | citation | ego | random
    nodes: int
    gen: str           # generator id
    gen_kwargs: tuple  # sorted kv pairs, hashable
    stream_size: int   # |S| per the paper's Table 1 scaling
    paper_analogue: str


# CPU-scaled stand-ins for Table 1.  Node counts are ~the paper's smaller
# datasets; stream sizes follow the paper's |S| choices.
DATASETS: Dict[str, DatasetSpec] = {
    "synth-web": DatasetSpec(
        "synth-web", "web", 100_000, "ba", (("m", 8), ("directed_both", 0.3)),
        40_000, "cnr-2000 (325k/3.2M)"),
    "synth-web-lg": DatasetSpec(
        "synth-web-lg", "web", 300_000, "ba", (("m", 10), ("directed_both", 0.3)),
        20_000, "eu-2005 (862k/19.2M)"),
    "synth-citation": DatasetSpec(
        "synth-citation", "citation", 34_000, "citation", (("m", 12),),
        40_000, "Cit-HepPh (34.5k/421k)"),
    "synth-social": DatasetSpec(
        "synth-social", "social", 70_000, "ba", (("m", 4), ("directed_both", 0.6)),
        40_000, "enron (69k/276k)"),
    "synth-dblp": DatasetSpec(
        "synth-dblp", "social", 100_000, "ba", (("m", 5), ("directed_both", 0.9)),
        40_000, "dblp-2010 (326k/1.6M)"),
    "synth-amazon": DatasetSpec(
        "synth-amazon", "social", 150_000, "gnm", (("m_edges", 1_000_000),),
        20_000, "amazon-2008 (735k/5.2M)"),
    "synth-ego": DatasetSpec(
        "synth-ego", "ego", 60_000, "ego", (("n_comm", 120), ("p_in_deg", 18.0)),
        40_000, "Facebook-ego (63.7k/1.5M)"),
}


def generate(spec_or_name, seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """Materialize a dataset's edge list (deduplicated)."""
    spec = DATASETS[spec_or_name] if isinstance(spec_or_name, str) else spec_or_name
    kw = dict(spec.gen_kwargs)
    if spec.gen == "ba":
        src, dst = barabasi_albert_edges(
            spec.nodes, int(kw["m"]), seed, kw.get("directed_both", 0.25))
    elif spec.gen == "citation":
        src, dst = citation_dag_edges(spec.nodes, int(kw["m"]), seed)
    elif spec.gen == "gnm":
        src, dst = gnm_edges(spec.nodes, int(kw["m_edges"]), seed)
    elif spec.gen == "ego":
        src, dst = community_ego_edges(
            spec.nodes, int(kw["n_comm"]), float(kw["p_in_deg"]), seed)
    else:
        raise ValueError(f"unknown generator {spec.gen}")
    # dedupe (streams sample without replacement from unique edges)
    key = src.astype(np.int64) * np.int64(2**32) + dst.astype(np.int64)
    _, idx = np.unique(key, return_index=True)
    idx.sort()
    return src[idx], dst[idx]

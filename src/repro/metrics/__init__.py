from repro.metrics.rbo import rbo_extrapolated, rbo_from_scores

"""Ranking utilities: top-k extraction and rank-value deltas."""

from __future__ import annotations

import numpy as np


def top_k_ids(scores: np.ndarray, k: int,
              active: np.ndarray | None = None) -> np.ndarray:
    """ids of the k highest scores, ties broken by id (deterministic)."""
    s = np.asarray(scores, np.float64)
    idx = np.nonzero(np.asarray(active))[0] if active is not None \
        else np.arange(s.shape[0])
    k = min(k, idx.shape[0])
    return idx[np.lexsort((idx, -s[idx]))][:k]


def l1_delta(a: np.ndarray, b: np.ndarray,
             active: np.ndarray | None = None) -> float:
    """L1 distance between two score vectors over the active mask."""
    m = np.asarray(active, bool) if active is not None \
        else np.ones(len(a), bool)
    return float(np.abs(np.asarray(a)[m] - np.asarray(b)[m]).sum())


def linf_delta(a: np.ndarray, b: np.ndarray,
               active: np.ndarray | None = None) -> float:
    """L∞ (max per-vertex) distance between two score vectors."""
    m = np.asarray(active, bool) if active is not None \
        else np.ones(len(a), bool)
    return float(np.abs(np.asarray(a)[m] - np.asarray(b)[m]).max())

"""Rank-Biased Overlap (Webber, Moffat, Zobel — TOIS 2010).

The paper's accuracy metric: compares the summarized PageRank's ranking
against the exact ranking, weighting higher ranks more heavily.  We implement
extrapolated RBO (RBO_ext, Webber Eq. 32) over prefix depth k, the standard
choice when both lists are available to a fixed evaluation depth — the paper
uses depth 1000 (≤200 edges/query) or 4000 (above).

Host-side numpy: this is an evaluation metric, not device compute.
"""

from __future__ import annotations

from typing import Sequence

import numpy as np


def rbo_extrapolated(s: Sequence[int], t: Sequence[int], p: float = 0.99,
                     depth: int | None = None) -> float:
    """RBO_ext between two rankings (sequences of distinct ids, best first).

    ``p`` is the persistence parameter (expected evaluation depth 1/(1-p)).
    ``depth`` truncates both lists.  Returns a scalar in [0, 1]; equals 1
    iff the two (truncated) lists contain the same elements at every prefix
    depth.  RBO_ext(S,T) = (1-p)/1 · Σ_{d=1..k} (X_d/d)·p^{d-1}·(1-p)… — we
    use the prefix form  (1-p)·Σ_{d<k} A_d·p^{d-1} + A_k·p^{k-1}  with
    A_d = X_d/d, which reduces to Webber Eq. 32 when |S|=|T|=k.
    """
    if depth is not None:
        s = list(s[:depth])
        t = list(t[:depth])
    else:
        s = list(s)
        t = list(t)
    k = max(len(s), len(t))
    if k == 0:
        return 1.0
    if min(len(s), len(t)) == 0:
        return 0.0

    seen_s: set = set()
    seen_t: set = set()
    overlap = 0            # |S_{:d} ∩ T_{:d}|
    weighted_sum = 0.0     # Σ_{d=1..k-1} A_d · p^{d-1}
    weight = 1.0           # p^{d-1}
    a_d = 0.0
    for d in range(1, k + 1):
        e_s = s[d - 1] if d <= len(s) else None
        e_t = t[d - 1] if d <= len(t) else None
        if e_s is not None and e_s == e_t:
            overlap += 1
        else:
            if e_s is not None and e_s in seen_t:
                overlap += 1
            if e_t is not None and e_t in seen_s:
                overlap += 1
        if e_s is not None:
            seen_s.add(e_s)
        if e_t is not None:
            seen_t.add(e_t)
        a_d = overlap / d
        if d < k:
            weighted_sum += a_d * weight
        weight *= p
    # contribution of depths 1..k-1, plus extrapolation of A_k beyond depth k
    return float((1.0 - p) * weighted_sum + a_d * (p ** (k - 1)))


def rbo_from_scores(scores_a: np.ndarray, scores_b: np.ndarray, *,
                    depth: int, p: float = 0.99,
                    active: np.ndarray | None = None) -> float:
    """RBO_ext between the rankings induced by two score vectors.

    Ties broken by vertex id (stable), matching a deterministic sort of the
    engine's output.  ``active`` restricts to active vertices.
    """
    a = np.asarray(scores_a, np.float64)
    b = np.asarray(scores_b, np.float64)
    if active is not None:
        idx = np.nonzero(np.asarray(active))[0]
    else:
        idx = np.arange(a.shape[0])
    d = min(depth, idx.shape[0])
    # top-d by (-score, id): lexsort uses the last key as primary
    top_a = idx[np.lexsort((idx, -a[idx]))][:d]
    top_b = idx[np.lexsort((idx, -b[idx]))][:d]
    return rbo_extrapolated(top_a.tolist(), top_b.tolist(), p=p)

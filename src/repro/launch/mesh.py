"""Production meshes (assignment-fixed shapes).

A FUNCTION, not a module-level constant: importing this module must never
touch jax device state (device count is locked at first jax init, and the
dry-run needs 512 host-platform devices while tests/benches see 1).
"""

from __future__ import annotations

from typing import Dict, Optional

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh() -> Mesh:
    """1-device mesh with the single-pod axis names (for smoke pjit paths)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def axis_sizes(mesh: Mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


# TPU v5e hardware constants used by the roofline analysis (assignment-fixed)
PEAK_FLOPS_BF16 = 197e12        # FLOP/s per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW_PER_LINK = 50e9          # bytes/s per link

"""Input specs + shardings per (architecture × shape × mesh) cell.

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every
model input (weak-type-correct, shardable, no device allocation);
``input_pspecs`` the matching PartitionSpec tree.  ``cell_spec`` bundles
everything the dry-run needs to lower one cell: the step function, its
abstract inputs and its in/out shardings.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig, ShapeConfig, SHAPES
from repro.models.params import (ParamDef, _tree_map_defs, abstract_params,
                                 build_defs, init_params)
from repro.models.transformer import init_cache
from repro.sharding.rules import AxisRules, guarded_pspec
from repro.train.optimizer import AdamWState, adamw_init
from repro.train.step import make_prefill_step, make_serve_step, make_train_step


def text_and_prefix_lens(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[int, int]:
    """Split a cell's seq_len into (text tokens, frontend prefix/frames)."""
    if cfg.frontend == "vision":
        pref = min(cfg.frontend_len, shape.seq_len // 2)
        return shape.seq_len - pref, pref
    if cfg.encoder_layers > 0:
        # half the budget to encoder frames, half to decoder tokens
        return shape.seq_len // 2, shape.seq_len // 2
    return shape.seq_len, 0


def skip_reason(cfg: ModelConfig, shape: ShapeConfig) -> Optional[str]:
    """None if the cell runs; otherwise why it is skipped (DESIGN.md table)."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return ("full quadratic attention at 524288 would need a "
                "sub-quadratic mechanism this arch does not have")
    return None


def param_pspecs_guarded(cfg: ModelConfig, rules: AxisRules,
                         sizes: Dict[str, int]):
    return _tree_map_defs(
        lambda path, pd: guarded_pspec(pd.shape, pd.logical, rules, sizes),
        build_defs(cfg))


def _cache_pspec(path: Tuple[str, ...], leaf, rules: AxisRules,
                 sizes: Dict[str, int]) -> P:
    """Sharding for one cache leaf, chosen by its owner key + rank.

    KV caches (L,B,S,KV,hd): batch over data when divisible, else the
    sequence dim context-parallel (guarded_pspec's used-set handles the
    fall-through).  Mamba conv (L,B,K,C): channels over model; SSM state
    (L,B,H,P,N): heads over model.  MLA latent (L,B,S,r): replicated rank.
    """
    name = path[0]
    nd = len(leaf.shape)
    if name in ("kv", "attn", "self", "cross"):
        logical = ("layers", "batch", "ctx_shard", "kv_heads", None)[:nd]
    elif name == "mla":
        logical = ("layers", "batch", "ctx_shard", None)[:nd]
    elif name == "ssm":
        if nd == 4:      # conv (L,B,K,C)
            logical = ("layers", "batch", None, "conv_dim")
        else:            # state (L,B,H,P,N)
            logical = ("layers", "batch", "ssm_heads", None, None)
    else:
        logical = (None,) * nd
    return guarded_pspec(leaf.shape, logical, rules, sizes)


def cache_pspecs(cache_sds, rules: AxisRules, sizes: Dict[str, int]):
    def walk(tree, path=()):
        if isinstance(tree, dict):
            return {k: walk(v, path + (k,)) for k, v in tree.items()}
        return _cache_pspec(path, tree, rules, sizes)
    return walk(cache_sds)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Abstract model inputs for one cell (ShapeDtypeStruct stand-ins)."""
    b = shape.global_batch
    text_len, prefix_len = text_and_prefix_lens(cfg, shape)
    i32 = jnp.int32
    f32 = jnp.float32

    if shape.kind == "train":
        batch = {
            "tokens": jax.ShapeDtypeStruct((b, text_len), i32),
            "labels": jax.ShapeDtypeStruct((b, text_len), i32),
        }
        if cfg.frontend == "vision":
            batch["patch_embeds"] = jax.ShapeDtypeStruct(
                (b, prefix_len, cfg.d_model), f32)
        if cfg.encoder_layers > 0:
            batch["frames"] = jax.ShapeDtypeStruct((b, prefix_len, cfg.d_model), f32)
        return {"batch": batch}

    if shape.kind == "prefill":
        batch = {"tokens": jax.ShapeDtypeStruct((b, text_len), i32)}
        if cfg.frontend == "vision":
            batch["patch_embeds"] = jax.ShapeDtypeStruct(
                (b, prefix_len, cfg.d_model), f32)
        if cfg.encoder_layers > 0:
            batch["frames"] = jax.ShapeDtypeStruct((b, prefix_len, cfg.d_model), f32)
        return {"batch": batch}

    # decode: one new token against a seq_len-deep cache
    enc_len = prefix_len if cfg.encoder_layers > 0 else 0
    cache = jax.eval_shape(
        lambda: init_cache(cfg, b, shape.seq_len, enc_len=enc_len))
    return {
        "cache": cache,
        "token": jax.ShapeDtypeStruct((b, 1), i32),
        "pos": jax.ShapeDtypeStruct((), i32),
    }


def input_pspecs(cfg: ModelConfig, shape: ShapeConfig, specs: Dict[str, Any],
                 rules: AxisRules, sizes: Dict[str, int]) -> Dict[str, Any]:
    def batch_spec(sds):
        nd = len(sds.shape)
        logical = ("batch",) + (None,) * (nd - 1)
        return guarded_pspec(sds.shape, logical, rules, sizes)

    out: Dict[str, Any] = {}
    if "batch" in specs:
        out["batch"] = {k: batch_spec(v) for k, v in specs["batch"].items()}
    if "cache" in specs:
        out["cache"] = cache_pspecs(specs["cache"], rules, sizes)
        out["token"] = guarded_pspec(specs["token"].shape, ("batch", None),
                                     rules, sizes)
        out["pos"] = P()
    return out


@dataclasses.dataclass
class CellSpec:
    """Everything needed to lower one (arch × shape) cell on a mesh."""
    arch: str
    shape: ShapeConfig
    step_fn: Callable
    args_sds: Tuple          # abstract positional args
    in_pspecs: Tuple         # matching PartitionSpec tree
    out_pspecs: Any          # or None to let XLA choose
    donate: Tuple[int, ...]  # donated positional args


def cell_spec(cfg: ModelConfig, arch: str, shape: ShapeConfig,
              rules: AxisRules, sizes: Dict[str, int]) -> CellSpec:
    p_sds = abstract_params(cfg)
    p_ps = param_pspecs_guarded(cfg, rules, sizes)
    specs = input_specs(cfg, shape)
    in_ps = input_pspecs(cfg, shape, specs, rules, sizes)
    text_len, prefix_len = text_and_prefix_lens(cfg, shape)

    if shape.kind == "train":
        o_sds = jax.eval_shape(adamw_init, p_sds)
        o_ps = AdamWState(step=P(), mu=p_ps, nu=p_ps)
        step = make_train_step(cfg, remat=True)
        metrics_ps = {"loss": P(), "accuracy": P(), "grad_norm": P(), "lr": P()}
        return CellSpec(arch, shape, step,
                        (p_sds, o_sds, specs["batch"]),
                        (p_ps, o_ps, in_ps["batch"]),
                        (p_ps, o_ps, metrics_ps),
                        donate=(0, 1))

    if shape.kind == "prefill":
        step = make_prefill_step(cfg, cache_len=shape.seq_len)
        enc_len = prefix_len if cfg.encoder_layers > 0 else 0
        cache_sds = jax.eval_shape(
            lambda: init_cache(cfg, shape.global_batch, shape.seq_len,
                               enc_len=enc_len))
        cache_ps = cache_pspecs(cache_sds, rules, sizes)
        logits_ps = guarded_pspec((shape.global_batch, cfg.vocab_size),
                                  ("batch", "vocab"), rules, sizes)
        return CellSpec(arch, shape, step,
                        (p_sds, specs["batch"]),
                        (p_ps, in_ps["batch"]),
                        (logits_ps, cache_ps),
                        donate=())

    # decode
    step = make_serve_step(cfg)
    logits_ps = guarded_pspec((shape.global_batch, cfg.vocab_size),
                              ("batch", "vocab"), rules, sizes)
    return CellSpec(arch, shape, step,
                    (p_sds, specs["cache"], specs["token"], specs["pos"]),
                    (p_ps, in_ps["cache"], in_ps["token"], in_ps["pos"]),
                    (logits_ps, in_ps["cache"]),
                    donate=(1,))

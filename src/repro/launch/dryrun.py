import os
os.environ["XLA_FLAGS"] = (
    os.environ.get("REPRO_EXTRA_XLA_FLAGS", "") +
    " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (architecture × shape × mesh).

For each cell this lowers the real step function (train_step / prefill /
serve_step) with ShapeDtypeStruct inputs against the production mesh,
compiles it (SPMD partitioning — sharding mismatches, OOM-at-compile and
unsupported collectives all surface here), prints memory_analysis() and
cost_analysis(), and writes the roofline terms to
``artifacts/dryrun/<mesh>/<arch>__<shape>.json``.

Usage:
  python -m repro.launch.dryrun --arch yi_9b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh single
  python -m repro.launch.dryrun --all --mesh multi
  python -m repro.launch.dryrun --workload veilgraph --mesh single
"""

import argparse
import json
import sys
import time
import traceback
from pathlib import Path

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ALIASES, ARCH_IDS, get_config
from repro.launch import roofline as RL
from repro.launch.mesh import axis_sizes, make_production_mesh
from repro.launch.specs import cell_spec, input_specs, skip_reason
from repro.models.config import SHAPES
from repro.sharding.rules import axis_rules, rules_for_mesh

ART = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def _ns(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def run_cell(arch: str, shape_name: str, mesh, mesh_name: str,
             verbose: bool = True) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    reason = skip_reason(cfg, shape)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name}
    if reason is not None:
        rec.update(status="skipped", reason=reason)
        return rec

    rules = rules_for_mesh(mesh)
    sizes = axis_sizes(mesh)
    t0 = time.time()
    try:
        with mesh:
            with axis_rules(rules):
                cell = cell_spec(cfg, arch, shape, rules, sizes)
                jitted = jax.jit(
                    cell.step_fn,
                    in_shardings=_ns(mesh, cell.in_pspecs),
                    out_shardings=_ns(mesh, cell.out_pspecs),
                    donate_argnums=cell.donate,
                )
                lowered = jitted.lower(*cell.args_sds)
                t_lower = time.time() - t0
                compiled = lowered.compile()
                t_compile = time.time() - t0 - t_lower
        chips = 1
        for v in sizes.values():
            chips *= v
        rf = RL.analyze(compiled, arch=arch, shape=shape, mesh_name=mesh_name,
                        chips=chips, cfg=cfg)
        mem = compiled.memory_analysis()
        if verbose:
            print(f"  memory_analysis: args={mem.argument_size_in_bytes/2**30:.2f}GiB "
                  f"out={mem.output_size_in_bytes/2**30:.2f}GiB "
                  f"temp={mem.temp_size_in_bytes/2**30:.2f}GiB "
                  f"alias={mem.alias_size_in_bytes/2**30:.2f}GiB (per device)")
            ca = compiled.cost_analysis()
            print(f"  cost_analysis: flops={ca.get('flops', 0):.3e} "
                  f"bytes={ca.get('bytes accessed', 0):.3e} (per device)")
            print(f"  roofline: compute={rf.compute_s*1e3:.2f}ms "
                  f"memory={rf.memory_s*1e3:.2f}ms "
                  f"collective={rf.collective_s*1e3:.2f}ms "
                  f"dominant={rf.dominant} "
                  f"useful_ratio={rf.useful_flops_ratio:.3f} "
                  f"roofline_frac={rf.roofline_fraction:.3f}")
        rec.update(status="ok", lower_s=round(t_lower, 1),
                   compile_s=round(t_compile, 1), roofline=rf.to_dict())
    except Exception as e:
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
    return rec


def run_veilgraph_cell(mesh, mesh_name: str, *, nodes=2**25, edges=2**30,
                       backend: str = "auto") -> dict:
    """The paper-representative workload: one fused summarized-PageRank query
    over a pod-scale streaming graph, through the *sharded plugin path* —
    ``fused_query_step`` with ``mesh=`` builds one locally-sorted edge shard
    per device inline (a contiguous reshape of the 1-D edge sharding, then S
    independent axis-1 sorts) and runs every O(E) pass as a shard_map
    partial push + all-reduce.  Summary construction is the mesh-native
    distributed bucket sort (per-shard E_K selection + dst-sorted
    compaction, one capacity-padded all-to-all, shard-local row offsets).

    Three gates are asserted on the lowered/compiled program:

    - it traces **zero** unsorted ``push_coo`` calls (the pre-sharded cost
      model this replaced);
    - it contains **zero** all-gathers of a full edge-space buffer (the
      pre-sharded E_K compaction replicated ``e_src``/``e_dst`` that way —
      the wall-clock ceiling the sharded summary removes);
    - every pinned push shape in ``benchmarks/roofline_baseline.json``
      re-models within 10% of its committed HBM byte volume
      (:func:`repro.launch.roofline.check_push_baselines` — the
      "modeled HBM traffic must not regress" CI check; run
      ``check_push_baselines(..., update=True)`` and commit the diff after
      an intentional kernel-geometry or cost-model change).

    ``backend`` picks the per-shard propagation kernels ("auto" resolves
    per device: TPU → the Pallas MXU/VPU kernels inside each shard,
    otherwise the sorted segment-sum path)."""
    import jax.numpy as jnp
    from repro.core import backend as B
    from repro.core.algorithm import PageRankAlgorithm
    from repro.core.fused import fused_query_step
    from repro.graph.graph import GraphState
    from repro.sharding.rules import guarded_pspec

    rules = rules_for_mesh(mesh)
    sizes = axis_sizes(mesh)
    rec = {"arch": "veilgraph-pagerank", "shape": f"N=2^25,E=2^30",
           "mesh": mesh_name}
    e_spec = guarded_pspec((edges,), ("edges",), rules, sizes)
    n_spec = P()
    state_sds = GraphState(
        src=jax.ShapeDtypeStruct((edges,), jnp.int32),
        dst=jax.ShapeDtypeStruct((edges,), jnp.int32),
        edge_alive=jax.ShapeDtypeStruct((edges,), jnp.bool_),
        num_edges=jax.ShapeDtypeStruct((), jnp.int32),
        out_deg=jax.ShapeDtypeStruct((nodes,), jnp.int32),
        in_deg=jax.ShapeDtypeStruct((nodes,), jnp.int32),
        node_active=jax.ShapeDtypeStruct((nodes,), jnp.bool_),
    )
    state_ps = GraphState(
        src=e_spec, dst=e_spec, edge_alive=e_spec, num_edges=P(),
        out_deg=n_spec, in_deg=n_spec, node_active=n_spec)
    algo_sds = {"ranks": jax.ShapeDtypeStruct((nodes,), jnp.float32)}
    deg_sds = jax.ShapeDtypeStruct((nodes,), jnp.int32)
    act_sds = jax.ShapeDtypeStruct((nodes,), jnp.bool_)
    scal = jax.ShapeDtypeStruct((), jnp.float32)
    algo = PageRankAlgorithm(num_iters=30, tol=1e-6)
    backend_r = B.resolve_backend(backend)

    t0 = time.time()
    try:
        with mesh:
            with axis_rules(rules):
                fn = lambda st, a, dp, ap, rr, dd: fused_query_step(
                    st, a, dp, ap, rr, dd, algo=algo,
                    hot_node_capacity=2**21, hot_edge_capacity=2**26, n=1,
                    backend=backend_r, mesh=mesh)
                jitted = jax.jit(
                    fn,
                    in_shardings=(_ns(mesh, state_ps), None, None, None, None, None),
                )
                B.reset_trace_counts()
                lowered = jitted.lower(state_sds, algo_sds, deg_sds, act_sds,
                                       scal, scal)
                push_coo_traces = B.trace_count("push_coo")
                if push_coo_traces:
                    raise AssertionError(
                        f"sharded plugin path traced {push_coo_traces} "
                        f"unsorted push_coo call(s); the lowered hot loop "
                        f"must be cached-layout pushes only")
                t_lower = time.time() - t0
                compiled = lowered.compile()
                t_compile = time.time() - t0 - t_lower
        chips = 1
        for v in sizes.values():
            chips *= v
        from repro.launch.hlo_cost import analyze_hlo
        hc = analyze_hlo(compiled.as_text())
        cost = {"flops": hc.flops, "bytes accessed": hc.bytes}
        coll = dict(hc.coll)
        counts = dict(hc.coll_counts)
        # the sharded summary construction must never materialize a
        # replicated full-edge-space buffer: with 4-byte endpoints, an
        # all-gather at least edge-buffer-sized means some stage (the
        # pre-sharded E_K compaction gathered e_src/e_dst this way, ~9 GiB
        # per device at this shape) replicated the stream.  The bucket
        # exchange is an all-to-all of capacity-padded hot blocks — orders
        # of magnitude smaller.  The gate is the shared analysis pass
        # (repro.analysis.hlo_audit) so the dry-run and tools/analyze.py
        # can never disagree about the budget.
        from repro.analysis.hlo_audit import audit_cost, budgets_for_graph
        audit = audit_cost(hc, budgets_for_graph(edges),
                           program="veilgraph-cell[sharded]")
        if audit:
            raise AssertionError(
                "HLO collective audit failed for the sharded cell:\n"
                + "\n".join(f"  {f}" for f in audit))
        ag_max = hc.coll_max.get("all-gather", 0.0)
        # per-kernel roofline gate: every pinned push shape must re-model
        # within 10% of its committed HBM byte volume (AssertionError here
        # fails the dryrun cell, and CI with it)
        baseline_path = (Path(__file__).resolve().parents[3] /
                         "benchmarks" / "roofline_baseline.json")
        push_checks = RL.check_push_baselines(baseline_path)
        print(f"  push roofline: {len(push_checks)} pinned shapes within "
              f"10% of baseline HBM bytes")
        mem = compiled.memory_analysis()
        # "model flops" for the graph query: the paper's useful work = selection
        # + summary + 30 iterations over the hot subgraph; approximate with
        # 2 flops/edge-visit × (O(E) selection passes + 30·hot_edge_capacity)
        useful = 2.0 * (6 * edges + 30 * 2**26)
        rec.update(status="ok", lower_s=round(t_lower, 1),
                   compile_s=round(t_compile, 1),
                   push_roofline=push_checks,
                   backend=backend_r, push_coo_traces=push_coo_traces,
                   replicated_edge_buffer_gathers=0,
                   max_all_gather_bytes=ag_max,
                   roofline={
                       "arch": "veilgraph-pagerank", "shape": rec["shape"],
                       "mesh": mesh_name, "chips": chips,
                       "flops_per_device": float(cost.get("flops", 0.0)),
                       "bytes_per_device": float(cost.get("bytes accessed", 0.0)),
                       "collective_bytes_per_device": float(sum(coll.values())),
                       "collective_breakdown": {**coll, "counts": counts},
                       "model_flops": useful,
                       "compute_s": float(cost.get("flops", 0.0)) / 197e12,
                       "memory_s": float(cost.get("bytes accessed", 0.0)) / 819e9,
                       "collective_s": float(sum(coll.values())) / 50e9,
                       "memory_stats": {
                           "argument_bytes": mem.argument_size_in_bytes,
                           "output_bytes": mem.output_size_in_bytes,
                           "temp_bytes": mem.temp_size_in_bytes,
                       },
                   })
        print(f"  veilgraph memory: args={mem.argument_size_in_bytes/2**30:.2f}GiB "
              f"temp={mem.temp_size_in_bytes/2**30:.2f}GiB; "
              f"flops={cost.get('flops', 0):.3e} bytes={cost.get('bytes accessed', 0):.3e}")
    except Exception as e:
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None,
                    choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", type=str, default="single",
                    choices=["single", "multi"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--workload", type=str, default="lm",
                    choices=["lm", "veilgraph"])
    ap.add_argument("--backend", type=str, default="auto",
                    choices=["auto", "pallas", "segment_sum"],
                    help="per-shard propagation kernels for the veilgraph "
                    "workload (auto: TPU → pallas)")
    args = ap.parse_args(argv)

    mesh = make_production_mesh(multi_pod=args.mesh == "multi")
    out_dir = ART / args.mesh
    out_dir.mkdir(parents=True, exist_ok=True)
    print(f"mesh {args.mesh}: {dict(zip(mesh.axis_names, mesh.devices.shape))} "
          f"({mesh.devices.size} devices)")

    if args.workload == "veilgraph":
        rec = run_veilgraph_cell(mesh, args.mesh, backend=args.backend)
        (out_dir / "veilgraph__pagerank.json").write_text(json.dumps(rec, indent=1))
        print(json.dumps({k: rec[k] for k in ("arch", "status")}, indent=1))
        return 0 if rec["status"] == "ok" else 1

    if args.all:
        cells = [(a, s) for a in ARCH_IDS for s in SHAPES]
    else:
        arch = ALIASES.get(args.arch, args.arch)
        shapes = [args.shape] if args.shape else list(SHAPES)
        cells = [(arch, s) for s in shapes]

    failures = 0
    for arch, shape_name in cells:
        path = out_dir / f"{arch}__{shape_name}.json"
        print(f"[{arch} × {shape_name} × {args.mesh}]", flush=True)
        rec = run_cell(arch, shape_name, mesh, args.mesh)
        path.write_text(json.dumps(rec, indent=1))
        if rec["status"] == "error":
            failures += 1
            print(f"  ERROR: {rec['error']}", flush=True)
        elif rec["status"] == "skipped":
            print(f"  skipped: {rec['reason']}", flush=True)
        else:
            print(f"  ok (lower {rec['lower_s']}s compile {rec['compile_s']}s)",
                  flush=True)
    print(f"done: {len(cells)} cells, {failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())

"""Serving driver: batched requests against a smoke (or full, on TPU) model.

  PYTHONPATH=src python -m repro.launch.serve --arch yi_9b --smoke \\
      --requests 8 --prompt-len 32 --new-tokens 16
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import ALIASES, get_config, get_smoke_config
from repro.models.params import init_params
from repro.serve.engine import Request, ServingEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi_9b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    arch = ALIASES.get(args.arch, args.arch)
    cfg = get_smoke_config(arch) if args.smoke else get_config(arch)
    print(f"serving {cfg.name} with {args.requests} requests × "
          f"{args.new_tokens} new tokens, {args.slots} slots")

    rng = np.random.default_rng(args.seed)
    params = init_params(jax.random.PRNGKey(args.seed), cfg)
    engine = ServingEngine(cfg, params, batch_slots=args.slots,
                           max_len=args.max_len)
    reqs = [
        Request(prompt=rng.integers(0, cfg.vocab_size, args.prompt_len,
                                    dtype=np.int32),
                max_new_tokens=args.new_tokens, id=i)
        for i in range(args.requests)
    ]
    stats = engine.run(reqs)
    done = sum(r.done for r in reqs)
    print(f"done: {done}/{len(reqs)} requests, {stats.tokens_out} tokens, "
          f"prefill {stats.prefill_s:.2f}s decode {stats.decode_s:.2f}s "
          f"({stats.tokens_per_s:.1f} tok/s)")
    return stats


if __name__ == "__main__":
    main()

"""Roofline-term extraction from a compiled (SPMD-partitioned) executable.

Terms per the assignment (TPU v5e constants in launch/mesh.py):

  compute term    = per-device HLO FLOPs / 197e12
  memory term     = per-device HLO bytes accessed / 819e9
  collective term = per-device collective bytes / 50e9 per link

``cost_analysis()`` reports the per-device SPMD program, so no /chips
normalization is applied.  Collective bytes are not in cost_analysis: we
parse the partitioned HLO and sum result-shape bytes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute, with ring
factors (all-reduce counts 2×(n-1)/n, gather/scatter (n-1)/n of the full
buffer; n approximated by the largest mesh axis participating — recorded as
an approximation in EXPERIMENTS.md).
"""

from __future__ import annotations

import json
import re
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Optional

from repro.launch.hlo_cost import analyze_hlo
from repro.launch.mesh import HBM_BW, ICI_BW_PER_LINK, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

# e.g. "bf16[16,4096,256]{2,1,0}" — first shape in the op result
_SHAPE_RE = re.compile(r"([a-z]+[0-9]*(?:e[0-9]+m[0-9]+)?)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"^\s*(?:%[\w.-]+ = )?(\(?[a-z0-9\[\],{}() ]+?\)?) (all-reduce|all-gather|"
    r"reduce-scatter|all-to-all|collective-permute)(?:-start)?\(",
    re.MULTILINE)
_GROUPS_RE = re.compile(r"replica_groups=\{?\[?([0-9]+),?([0-9]+)?\]?")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-collective-kind effective bytes moved per device (ring model)."""
    out: Dict[str, float] = {}
    counts: Dict[str, int] = {}
    for m in _COLL_RE.finditer(hlo_text):
        type_str, kind = m.group(1), m.group(2)
        nbytes = _shape_bytes(type_str)
        # ring factors; n unknown per-op here -> use (n-1)/n ≈ 1 upper bound,
        # all-reduce moves ~2× its buffer
        factor = 2.0 if kind == "all-reduce" else 1.0
        out[kind] = out.get(kind, 0.0) + factor * nbytes
        counts[kind] = counts.get(kind, 0) + 1
    out["_counts"] = counts  # type: ignore
    return out


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    collective_breakdown: Dict[str, float]
    model_flops: float              # 6·N_active·D analytic, GLOBAL per step
    memory_stats: Optional[Dict[str, float]] = None

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / PEAK_FLOPS_BF16

    @property
    def memory_s(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes_per_device / ICI_BW_PER_LINK

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / compiled FLOPs (global) — remat/redundancy waste."""
        total = self.flops_per_device * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def bound_time_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """Useful compute time / bound time — the §Perf score per cell."""
        useful_s = (self.model_flops / self.chips) / PEAK_FLOPS_BF16
        return useful_s / self.bound_time_s if self.bound_time_s else 0.0

    def to_dict(self) -> Dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "collective_bytes_per_device": self.collective_bytes_per_device,
            "collective_breakdown": self.collective_breakdown,
            "model_flops": self.model_flops,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "memory_stats": self.memory_stats,
        }


def model_flops_for(cfg, shape) -> float:
    """Analytic MODEL_FLOPS per step: 6·N_active·D train, 2·N_active·D
    inference (forward only); decode counts the single new token."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


# ---------------------------------------------------------------------------
# Push-kernel roofline: the per-kernel bytes/FLOPs model + regression gate
# ---------------------------------------------------------------------------


def push_roofline_check(*, edge_capacity: int, num_segments: int,
                        batch: int = 1, reduce: str = "sum",
                        dtype: str = "float32",
                        tile_n: Optional[int] = None,
                        chunk: Optional[int] = None,
                        measured_s: Optional[float] = None,
                        baseline: Optional[Dict] = None,
                        tolerance: float = 0.10) -> Dict:
    """Roofline record for ONE SpMV push sweep, with optional gates.

    The bytes/FLOPs come from the same analytic model the autotuner ranks
    candidates with (:func:`repro.kernels.spmv.autotune.modeled_push_cost`),
    so the CI gate and the tuner can never disagree about what a shape
    "should" cost.  Two gates, both optional:

    - ``measured_s``: a wall-clock measurement for the sweep (compiled,
      real device).  The record gains ``fraction_of_peak`` =
      bound_time / measured — the asserted-on number on TPU.  In interpret
      mode there is no meaningful wall clock; gate on the modeled byte
      volume instead (the ``baseline`` gate below).
    - ``baseline``: a dict holding a committed ``hbm_bytes`` figure for
      this shape.  Raises ``AssertionError`` when the current model
      exceeds it by more than ``tolerance`` (default 10%) — the "modeled
      HBM traffic must not regress" CI check.

    Geometry defaults to the kernel's hardcoded tiles; pass the autotuned
    ``(tile_n, chunk)`` to score the tuned sweep.
    """
    from repro.kernels.spmv import autotune as AT
    from repro.kernels.spmv.kernel import CHUNK, TILE_N

    import numpy as _np

    e_pad = (edge_capacity // CHUNK + 2) * CHUNK
    itemsize = _np.dtype(dtype).itemsize
    cost = AT.modeled_push_cost(
        e_pad=e_pad, n=num_segments, b=batch, itemsize=itemsize,
        reduce=reduce,
        tile_n=TILE_N if tile_n is None else tile_n,
        chunk=CHUNK if chunk is None else chunk)
    rec = {
        "edge_capacity": edge_capacity,
        "num_segments": num_segments,
        "batch": batch,
        "reduce": reduce,
        "dtype": dtype,
        "tile_n": TILE_N if tile_n is None else tile_n,
        "chunk": CHUNK if chunk is None else chunk,
        "hbm_bytes": cost.hbm_bytes,
        "flops": cost.flops,
        "vmem_bytes": cost.vmem_bytes,
        "memory_s": cost.memory_s,
        "compute_s": cost.compute_s,
        "bound_time_s": cost.bound_time_s,
        "dominant": "memory" if cost.memory_s >= cost.compute_s
        else "compute",
    }
    if measured_s is not None:
        rec["measured_s"] = measured_s
        rec["fraction_of_peak"] = (cost.bound_time_s / measured_s
                                   if measured_s > 0 else 0.0)
    if baseline is not None:
        base_bytes = float(baseline["hbm_bytes"])
        ratio = cost.hbm_bytes / base_bytes if base_bytes else float("inf")
        rec["baseline_hbm_bytes"] = base_bytes
        rec["hbm_ratio_vs_baseline"] = ratio
        if ratio > 1.0 + tolerance:
            raise AssertionError(
                f"modeled HBM traffic regressed {100 * (ratio - 1):.1f}% "
                f"(> {100 * tolerance:.0f}%) for push shape "
                f"E={edge_capacity} N={num_segments} B={batch} "
                f"reduce={reduce}: {cost.hbm_bytes:.3e} B vs baseline "
                f"{base_bytes:.3e} B")
    return rec


def check_push_baselines(baseline_path, *, update: bool = False,
                         tolerance: float = 0.10) -> Dict:
    """Gate every pinned push shape in a committed baseline JSON.

    The file holds named shapes with their parameters and the blessed
    modeled ``hbm_bytes``; each is re-modeled and checked within
    ``tolerance`` via :func:`push_roofline_check`.  ``update=True``
    rewrites the file with current numbers instead of asserting (run it
    after an *intentional* cost-model or kernel-geometry change and commit
    the diff).  Returns ``{name: record}``.
    """
    path = Path(baseline_path)
    payload = json.loads(path.read_text())
    out = {}
    for name, entry in sorted(payload.get("shapes", {}).items()):
        params = {k: entry[k] for k in
                  ("edge_capacity", "num_segments", "batch", "reduce",
                   "dtype") if k in entry}
        geom = {k: entry[k] for k in ("tile_n", "chunk") if k in entry}
        rec = push_roofline_check(
            **params, **geom,
            baseline=None if update else {"hbm_bytes": entry["hbm_bytes"]},
            tolerance=tolerance)
        out[name] = rec
        if update:
            entry["hbm_bytes"] = rec["hbm_bytes"]
            entry["flops"] = rec["flops"]
    if update:
        path.write_text(json.dumps(payload, indent=1, sort_keys=True) + "\n")
    return out


def analyze(compiled, *, arch: str, shape, mesh_name: str, chips: int,
            cfg) -> Roofline:
    """Roofline terms from the trip-count-aware HLO analyzer (hlo_cost).

    XLA's cost_analysis() counts while bodies once (scan-over-layers would
    be ~L× undercounted); the analyzer multiplies by known_trip_count.  The
    raw XLA numbers are retained in the record for reference.
    """
    xla_cost = compiled.cost_analysis()
    text = compiled.as_text()
    hc = analyze_hlo(text)
    flops = float(hc.flops)
    nbytes = float(hc.bytes)
    coll = dict(hc.coll)
    counts = dict(hc.coll_counts)
    total_coll = float(hc.collective_bytes)
    mem = None
    try:
        ms = compiled.memory_analysis()
        mem = {
            "argument_bytes": ms.argument_size_in_bytes,
            "output_bytes": ms.output_size_in_bytes,
            "temp_bytes": ms.temp_size_in_bytes,
            "alias_bytes": ms.alias_size_in_bytes,
        }
    except Exception:
        pass
    if mem is not None:
        mem["xla_flops_raw"] = float(xla_cost.get("flops", 0.0))
        mem["xla_bytes_raw"] = float(xla_cost.get("bytes accessed", 0.0))
    return Roofline(
        arch=arch, shape=shape.name, mesh=mesh_name, chips=chips,
        flops_per_device=flops, bytes_per_device=nbytes,
        collective_bytes_per_device=total_coll,
        collective_breakdown={**coll, "counts": counts},
        model_flops=model_flops_for(cfg, shape),
        memory_stats=mem,
    )

"""Trip-count-aware HLO cost model (FLOPs / bytes / collective bytes).

XLA's built-in ``compiled.cost_analysis()`` counts a while-loop body ONCE
regardless of trip count (verified empirically: a scan of 32 matmuls
reports the flops of one), which silently undercounts scan-over-layers
models by ~L×.  This analyzer parses the post-SPMD-partitioning HLO text
and multiplies loop bodies by the ``known_trip_count`` the CPU/TPU
backends record in ``backend_config``.

Cost model (per-device, roofline-oriented):
- flops: dot = 2·numel(out)·K (K = product of lhs contracting dims);
  elementwise arithmetic/transcendental = numel(out); reduce = numel(in).
- bytes: every top-level op reads its operands and writes its output;
  fusions count their operands+output only (that IS the fusion's memory
  traffic); gather/dynamic-slice count touched bytes, not whole operands.
- collectives: all-reduce counts 2× buffer (ring all-reduce moves
  2·(n-1)/n ≈ 2×), others 1× their result buffer; multiplied by enclosing
  trip counts like everything else.

This module only *measures*.  Budget enforcement (all-gather < one edge
buffer, the capacity-padded all-to-all bound, peak-temp ceiling) lives in
:mod:`repro.analysis.hlo_audit`, which both the pod-scale dry-run gate
(``launch/dryrun.py``) and ``tools/analyze.py`` consume — one set of
spec-derived budgets, two entry points.
"""

from __future__ import annotations

import json
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_TYPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\](?:\{[^}]*\})?")

# instruction: [ROOT] %name = TYPE opcode(...), attrs
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+?)\s+([a-z][\w\-]*)\((.*)$")

_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\((.*?)\)\s*->")

_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def _dims(dim_str: str) -> List[int]:
    return [int(d) for d in dim_str.split(",") if d] if dim_str else []


def type_numel_bytes(type_str: str) -> Tuple[int, int]:
    """Total (elements, bytes) over all array components of a type string."""
    numel = 0
    nbytes = 0
    for dt, dims in _TYPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in _dims(dims):
            n *= d
        numel += n
        nbytes += n * _DTYPE_BYTES[dt]
    return numel, nbytes


@dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    tail: str          # text after the opening paren (operands + attrs)

    def operands(self) -> List[str]:
        # operand list terminates at the matching close paren
        depth = 1
        out = []
        cur = []
        for ch in self.tail:
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
            if depth >= 1:
                cur.append(ch)
        args = "".join(cur)
        return re.findall(r"%([\w.\-]+)", args)

    def attr(self, pattern: str) -> Optional[str]:
        m = re.search(pattern, self.tail)
        return m.group(1) if m else None


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    types: Dict[str, str] = field(default_factory=dict)  # symbol -> type str


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = field(default_factory=dict)
    coll_counts: Dict[str, float] = field(default_factory=dict)
    #: largest single-instruction buffer per collective kind (bytes, NOT
    #: trip-multiplied) — how callers detect "something replicated a whole
    #: sharded buffer" (e.g. an all-gather the size of the edge stream)
    #: independently of how often the loop runs it
    coll_max: Dict[str, float] = field(default_factory=dict)

    def add(self, other: "Cost", mult: float = 1.0) -> None:
        self.flops += mult * other.flops
        self.bytes += mult * other.bytes
        for k, v in other.coll.items():
            self.coll[k] = self.coll.get(k, 0.0) + mult * v
        for k, v in other.coll_counts.items():
            self.coll_counts[k] = self.coll_counts.get(k, 0.0) + mult * v
        for k, v in other.coll_max.items():
            self.coll_max[k] = max(self.coll_max.get(k, 0.0), v)

    @property
    def collective_bytes(self) -> float:
        return sum(self.coll.values())


_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "power", "maximum", "minimum",
    "exponential", "log", "tanh", "rsqrt", "sqrt", "negate", "abs", "sign",
    "floor", "ceil", "cosine", "sine", "logistic", "expm1", "log1p",
    "atan2", "remainder", "select", "clamp", "compare", "and", "or", "xor",
    "not", "convert", "exponential-minus-one",
}
_FREE = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "reshape", "copy-done", "all-reduce-done", "all-gather-done",
    "collective-permute-done", "partition-id", "replica-id", "domain",
    "opt-barrier",
}


def parse_module(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        if not line.startswith(" ") and "{" in line and "->" in line:
            m = _COMP_HDR_RE.match(line.strip())
            if m:
                cur = Computation(m.group(1))
                comps[cur.name] = cur
                if line.lstrip().startswith("ENTRY"):
                    entry = cur.name
                # parameter types from the header signature
                for pname, ptype in re.findall(
                        r"([\w.\-]+):\s*((?:\([^)]*\)|[a-z][a-z0-9]*\[[0-9,]*\](?:\{[^}]*\})?))",
                        m.group(2)):
                    cur.types[pname] = ptype
                continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if m:
            name, type_str, opcode, tail = m.groups()
            ins = Instr(name, type_str, opcode, tail)
            cur.instrs.append(ins)
            cur.types[name] = type_str
    return comps, entry


def _dot_flops(ins: Instr, comp: Computation) -> float:
    _, out_bytes = type_numel_bytes(ins.type_str)
    out_numel, _ = type_numel_bytes(ins.type_str)
    ops = ins.operands()
    lhs_t = comp.types.get(ops[0], "") if ops else ""
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.tail)
    cdims = _dims(m.group(1)) if m else []
    lhs_dims = []
    tm = _TYPE_RE.search(lhs_t)
    if tm:
        lhs_dims = _dims(tm.group(2))
    k = 1
    for c in cdims:
        if c < len(lhs_dims):
            k *= lhs_dims[c]
    return 2.0 * out_numel * max(k, 1)


def analyze_computation(name: str, comps: Dict[str, Computation],
                        memo: Dict[str, Cost]) -> Cost:
    if name in memo:
        return memo[name]
    memo[name] = Cost()  # cycle guard
    comp = comps.get(name)
    if comp is None:
        return memo[name]
    total = Cost()
    for ins in comp.instrs:
        op = ins.opcode
        if op in _FREE:
            continue
        out_numel, out_bytes = type_numel_bytes(ins.type_str)
        opnd_bytes = 0
        for o in ins.operands():
            _, b = type_numel_bytes(comp.types.get(o, ""))
            opnd_bytes += b

        if op == "while":
            body = ins.attr(r"body=%?([\w.\-]+)")
            cond = ins.attr(r"condition=%?([\w.\-]+)")
            trip = 1
            tm = _TRIP_RE.search(ins.tail)
            if tm:
                trip = int(tm.group(1))
            sub = Cost()
            if body:
                sub.add(analyze_computation(body, comps, memo))
            if cond:
                sub.add(analyze_computation(cond, comps, memo))
            total.add(sub, mult=trip)
            continue
        if op == "fusion":
            callee = ins.attr(r"calls=%?([\w.\-]+)")
            if callee:
                sub = analyze_computation(callee, comps, memo)
                # fusion flops count; bytes = fusion operands + output only
                total.flops += sub.flops
                for k, v in sub.coll.items():
                    total.coll[k] = total.coll.get(k, 0.0) + v
                for k, v in sub.coll_max.items():
                    total.coll_max[k] = max(total.coll_max.get(k, 0.0), v)
            total.bytes += opnd_bytes + out_bytes
            continue
        if op in ("call", "async-start", "custom-call", "conditional"):
            for callee in re.findall(r"(?:calls|to_apply)=%?([\w.\-]+)", ins.tail):
                total.add(analyze_computation(callee, comps, memo))
            for callee in re.findall(
                    r"branch_computations=\{([^}]*)\}", ins.tail):
                for c in re.findall(r"%([\w.\-]+)", callee):
                    total.add(analyze_computation(c, comps, memo))
            total.bytes += opnd_bytes + out_bytes
            continue

        is_coll = None
        for c in COLLECTIVES:
            if op == c or op == c + "-start":
                is_coll = c
                break
        if is_coll:
            factor = 2.0 if is_coll == "all-reduce" else 1.0
            raw = max(out_bytes, opnd_bytes)
            eff = factor * raw
            total.coll[is_coll] = total.coll.get(is_coll, 0.0) + eff
            total.coll_counts[is_coll] = total.coll_counts.get(is_coll, 0.0) + 1
            total.coll_max[is_coll] = max(
                total.coll_max.get(is_coll, 0.0), raw)
            total.bytes += opnd_bytes + out_bytes
            continue

        if op == "dot":
            total.flops += _dot_flops(ins, comp)
            total.bytes += opnd_bytes + out_bytes
        elif op == "convolution":
            # rare in this stack; approximate via output·K from window string
            total.flops += 2.0 * out_numel
            total.bytes += opnd_bytes + out_bytes
        elif op in ("gather", "dynamic-slice"):
            # touched bytes: output read+write + indices, not whole operand
            idx_bytes = 0
            ops = ins.operands()
            for o in ops[1:]:
                _, b = type_numel_bytes(comp.types.get(o, ""))
                idx_bytes += b
            total.bytes += 2 * out_bytes + idx_bytes
        elif op in ("scatter", "dynamic-update-slice"):
            ops = ins.operands()
            upd_bytes = 0
            for o in ops[1:]:
                _, b = type_numel_bytes(comp.types.get(o, ""))
                upd_bytes += b
            total.bytes += 2 * upd_bytes
            if op == "scatter":
                total.flops += out_numel  # combiner adds
        elif op in ("reduce", "reduce-window"):
            # one combiner application per input element read
            in_n = 0
            if ins.operands():
                in_n, _ = type_numel_bytes(comp.types.get(ins.operands()[0], ""))
            total.flops += in_n
            total.bytes += opnd_bytes + out_bytes
        elif op in _ELEMENTWISE:
            total.flops += out_numel
            total.bytes += opnd_bytes + out_bytes
        elif op in ("transpose", "broadcast", "iota", "concatenate", "slice",
                    "pad", "reverse", "copy", "copy-start", "all-gather-start",
                    "rng", "rng-bit-generator", "sort"):
            total.bytes += opnd_bytes + out_bytes
        else:
            total.bytes += opnd_bytes + out_bytes
    memo[name] = total
    return total


def analyze_hlo(text: str) -> Cost:
    comps, entry = parse_module(text)
    memo: Dict[str, Cost] = {}
    if entry is None:
        # fall back: sum all non-called computations (best effort)
        total = Cost()
        for name in comps:
            total.add(analyze_computation(name, comps, memo))
        return total
    return analyze_computation(entry, comps, memo)

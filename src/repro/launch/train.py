"""End-to-end training driver.

CPU-runnable with smoke configs (``--smoke``); the same driver pjits over a
real mesh on TPU.  Fault tolerance on by default: async checkpointing,
resume-from-latest, straggler timing, preemption-save.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2_0_5b --smoke \\
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ALIASES, get_config, get_smoke_config
from repro.data.pipeline import DataConfig, Prefetcher, SyntheticLMData
from repro.models.params import init_params
from repro.train.checkpoint import CheckpointManager
from repro.train.fault_tolerance import LoopConfig, RestartableLoop
from repro.train.optimizer import adamw_init, cosine_schedule
from repro.train.step import make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2_0_5b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=5e-4)
    ap.add_argument("--weight-decay", type=float, default=0.0)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    arch = ALIASES.get(args.arch, args.arch)
    cfg = get_smoke_config(arch) if args.smoke else get_config(arch)
    print(f"training {cfg.name}: L={cfg.num_layers} d={cfg.d_model} "
          f"V={cfg.vocab_size}")

    key = jax.random.PRNGKey(args.seed)
    params = init_params(key, cfg)
    opt = adamw_init(params)
    sched = cosine_schedule(args.lr, args.warmup, args.steps)
    step_fn = jax.jit(make_train_step(cfg, learning_rate=sched, remat=True,
                                      weight_decay=args.weight_decay),
                      donate_argnums=(0, 1))

    # lag=1: the target mostly repeats the current input token — a strong
    # learnable signal that shows loss decreasing within ~100 CPU steps
    data = SyntheticLMData(DataConfig(cfg.vocab_size, args.seq, args.batch,
                                      seed=args.seed, lag=1),
                           host_batch=args.batch)
    ckpt = CheckpointManager(args.ckpt_dir, keep_last_k=2)
    loop = RestartableLoop(
        ckpt, LoopConfig(total_steps=args.steps,
                         checkpoint_every=args.ckpt_every,
                         log_every=0))

    restored = loop.restore({"params": params, "opt": opt})
    if restored is not None:
        params, opt = restored["params"], restored["opt"]

    losses = []

    def one_step(state, step):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(step).items()}
        p, o, metrics = step_fn(state["params"], state["opt"], batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        if args.log_every and step % args.log_every == 0:
            print(f"step {step:4d} loss {loss:.4f} "
                  f"acc {float(metrics['accuracy']):.3f} "
                  f"gnorm {float(metrics['grad_norm']):.2f}")
        return {"params": p, "opt": o}

    state = loop.run({"params": params, "opt": opt}, one_step,
                     start_step=loop.resume_step())
    ckpt.wait()
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f}); "
          f"timing {loop.timer.summary()}")
    return losses


if __name__ == "__main__":
    main()

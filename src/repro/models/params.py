"""Parameter definitions: one source of truth for shape / dtype / sharding / init.

``build_defs(cfg)`` returns a pytree (nested dicts) of ``ParamDef`` leaves.
From it derive:
- ``init_params(key, cfg)``      — materialized params (smoke tests, examples)
- ``abstract_params(cfg)``       — ShapeDtypeStruct tree (dry-run: no allocation)
- ``param_pspecs(cfg, rules)``   — PartitionSpec tree (pjit in/out shardings)

Per-layer weights are stacked with a leading ``num_layers`` dim and consumed
via lax.scan, keeping HLO size O(1) in depth (critical for 88-layer granite
on a CPU-compile dry-run, and good practice on TPU).
"""

from __future__ import annotations

import math
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.sharding.rules import AxisRules, logical_to_pspec


class ParamDef(NamedTuple):
    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]   # logical axis per dim
    init: str = "normal"                  # normal | zeros | ones | small_normal
    dtype: Optional[str] = None           # override cfg.param_dtype


def _attn_defs(cfg: ModelConfig, layers: Optional[int], cross: bool = False) -> Dict[str, ParamDef]:
    """GQA attention projections; ``layers=None`` => unstacked (shared block)."""
    d, h, kv = cfg.d_model, cfg.num_heads, cfg.num_kv_heads
    hd = cfg.resolved_head_dim
    lead = () if layers is None else (layers,)
    ll = () if layers is None else ("layers",)
    defs = {
        "wq": ParamDef(lead + (d, h * hd), ll + ("embed_p", "heads")),
        "wk": ParamDef(lead + (d, kv * hd), ll + ("embed_p", "kv_heads")),
        "wv": ParamDef(lead + (d, kv * hd), ll + ("embed_p", "kv_heads")),
        "wo": ParamDef(lead + (h * hd, d), ll + ("heads", "embed_p")),
    }
    if cfg.qkv_bias and not cross:
        defs["bq"] = ParamDef(lead + (h * hd,), ll + ("heads",), "zeros")
        defs["bk"] = ParamDef(lead + (kv * hd,), ll + ("kv_heads",), "zeros")
        defs["bv"] = ParamDef(lead + (kv * hd,), ll + ("kv_heads",), "zeros")
    return defs


def _mla_defs(cfg: ModelConfig, layers: int) -> Dict[str, ParamDef]:
    m = cfg.mla
    d = cfg.d_model
    h = cfg.sharded_heads          # logical head padding (e.g. 40 -> 48)
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "q_a": ParamDef((layers, d, m.q_lora_rank), ("layers", "embed_p", None)),
        "q_norm": ParamDef((layers, m.q_lora_rank), ("layers", None), "ones"),
        "q_b": ParamDef((layers, m.q_lora_rank, h * qk_dim),
                        ("layers", None, "heads")),
        "kv_a": ParamDef((layers, d, m.kv_lora_rank + m.qk_rope_head_dim),
                         ("layers", "embed_p", None)),
        "kv_norm": ParamDef((layers, m.kv_lora_rank), ("layers", None), "ones"),
        "kv_b": ParamDef(
            (layers, m.kv_lora_rank, h * (m.qk_nope_head_dim + m.v_head_dim)),
            ("layers", None, "heads")),
        "wo": ParamDef((layers, h * m.v_head_dim, d), ("layers", "heads", "embed_p")),
    }


def _mlp_defs(cfg: ModelConfig, layers: Optional[int]) -> Dict[str, ParamDef]:
    d, ff = cfg.d_model, cfg.d_ff
    lead = () if layers is None else (layers,)
    ll = () if layers is None else ("layers",)
    defs = {
        "w_up": ParamDef(lead + (d, ff), ll + ("embed_p", "ff")),
        "w_down": ParamDef(lead + (ff, d), ll + ("ff", "embed_p")),
    }
    if cfg.mlp_gated:
        defs["w_gate"] = ParamDef(lead + (d, ff), ll + ("embed_p", "ff"))
    return defs


def _moe_defs(cfg: ModelConfig, layers: int) -> Dict[str, ParamDef]:
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.moe.num_experts
    return {
        "router": ParamDef((layers, d, e), ("layers", "embed_p", None)),
        "w_gate": ParamDef((layers, e, d, ff), ("layers", "experts", "embed_p", "ff")),
        "w_up": ParamDef((layers, e, d, ff), ("layers", "experts", "embed_p", "ff")),
        "w_down": ParamDef((layers, e, ff, d), ("layers", "experts", "ff", "embed_p")),
    }


def _ssm_defs(cfg: ModelConfig, layers: int) -> Dict[str, ParamDef]:
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    nh = s.num_heads(d)
    gn = s.n_groups * s.d_state
    cdim = s.conv_dim(d)
    in_out = 2 * di + 2 * gn + nh   # z, x, B, C, dt
    return {
        "in_proj": ParamDef((layers, d, in_out), ("layers", "embed_p", "conv_dim")),
        "conv_w": ParamDef((layers, s.conv_kernel, cdim), ("layers", None, "conv_dim"),
                           "small_normal"),
        "conv_b": ParamDef((layers, cdim), ("layers", "conv_dim"), "zeros"),
        "a_log": ParamDef((layers, nh), ("layers", "ssm_heads"), "ones"),
        "d_skip": ParamDef((layers, nh), ("layers", "ssm_heads"), "ones"),
        "dt_bias": ParamDef((layers, nh), ("layers", "ssm_heads"), "zeros"),
        "norm": ParamDef((layers, di), ("layers", "conv_dim"), "ones"),
        "out_proj": ParamDef((layers, di, d), ("layers", "conv_dim", "embed_p")),
    }


def _block_norms(layers: int, d: int, n: int = 2) -> Dict[str, ParamDef]:
    return {f"norm{i}": ParamDef((layers, d), ("layers", None), "ones")
            for i in range(n)}


def build_defs(cfg: ModelConfig) -> Dict[str, Any]:
    """Full parameter-definition tree for any pool architecture."""
    d, v, L = cfg.d_model, cfg.vocab_size, cfg.num_layers
    defs: Dict[str, Any] = {
        "embed": {"tok": ParamDef((v, d), ("vocab", "embed_p"), "small_normal")},
        "final_norm": ParamDef((d,), (None,), "ones"),
    }
    if not cfg.tie_embeddings:
        defs["lm_head"] = ParamDef((d, v), ("embed_p", "vocab"), "small_normal")

    if cfg.family == "ssm":
        defs["blocks"] = {"ssm": _ssm_defs(cfg, L), **_block_norms(L, d, 1)}
    elif cfg.family == "hybrid":
        defs["blocks"] = {"ssm": _ssm_defs(cfg, L), **_block_norms(L, d, 1)}
        # one shared attention+mlp block applied every cfg.hybrid_period layers
        defs["shared"] = {
            "attn": _attn_defs(cfg, None),
            "mlp": _mlp_defs(cfg, None),
            "norm0": ParamDef((d,), (None,), "ones"),
            "norm1": ParamDef((d,), (None,), "ones"),
        }
    elif cfg.encoder_layers > 0:
        eL = cfg.encoder_layers
        defs["encoder"] = {
            "attn": _attn_defs(cfg, eL),
            "mlp": _mlp_defs(cfg, eL),
            **_block_norms(eL, d, 2),
        }
        defs["enc_final_norm"] = ParamDef((d,), (None,), "ones")
        defs["blocks"] = {
            "attn": _attn_defs(cfg, L),
            "cross": _attn_defs(cfg, L, cross=True),
            "mlp": _mlp_defs(cfg, L),
            **_block_norms(L, d, 3),
        }
    else:  # dense / moe / mla / vlm text backbone
        blocks: Dict[str, Any] = {}
        blocks["attn"] = _mla_defs(cfg, L) if cfg.mla else _attn_defs(cfg, L)
        blocks["mlp"] = _moe_defs(cfg, L) if cfg.moe else _mlp_defs(cfg, L)
        blocks.update(_block_norms(L, d, 2))
        defs["blocks"] = blocks
    return defs


# ---------------------------------------------------------------------------
# materializers
# ---------------------------------------------------------------------------


def _init_leaf(key: jax.Array, pd: ParamDef, cfg: ModelConfig) -> jax.Array:
    dtype = jnp.dtype(pd.dtype or cfg.param_dtype)
    if pd.init == "zeros":
        return jnp.zeros(pd.shape, dtype)
    if pd.init == "ones":
        return jnp.ones(pd.shape, dtype)
    scale = 0.02 if pd.init == "small_normal" else (
        1.0 / math.sqrt(max(pd.shape[-2] if len(pd.shape) >= 2 else pd.shape[-1], 1)))
    return (jax.random.normal(key, pd.shape, jnp.float32) * scale).astype(dtype)


def _tree_map_defs(f: Callable[[Tuple[str, ...], ParamDef], Any],
                   defs: Dict[str, Any], prefix: Tuple[str, ...] = ()) -> Dict[str, Any]:
    out = {}
    for k, v in defs.items():
        if isinstance(v, ParamDef):
            out[k] = f(prefix + (k,), v)
        else:
            out[k] = _tree_map_defs(f, v, prefix + (k,))
    return out


def init_params(key: jax.Array, cfg: ModelConfig) -> Dict[str, Any]:
    defs = build_defs(cfg)
    leaves = jax.tree_util.tree_leaves(defs, is_leaf=lambda x: isinstance(x, ParamDef))
    keys = list(jax.random.split(key, len(leaves)))
    it = iter(keys)
    return _tree_map_defs(lambda path, pd: _init_leaf(next(it), pd, cfg), defs)


def abstract_params(cfg: ModelConfig) -> Dict[str, Any]:
    return _tree_map_defs(
        lambda path, pd: jax.ShapeDtypeStruct(
            pd.shape, jnp.dtype(pd.dtype or cfg.param_dtype)),
        build_defs(cfg))


def param_pspecs(cfg: ModelConfig, rules: AxisRules) -> Dict[str, Any]:
    return _tree_map_defs(
        lambda path, pd: logical_to_pspec(pd.logical, rules), build_defs(cfg))


def param_count_actual(cfg: ModelConfig) -> int:
    defs = build_defs(cfg)
    leaves = jax.tree_util.tree_leaves(defs, is_leaf=lambda x: isinstance(x, ParamDef))
    return sum(int(np.prod(pd.shape)) for pd in leaves)

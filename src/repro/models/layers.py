"""Layer primitives: norms, RoPE, blocked attention (pure JAX).

The blocked attention here is the *reference* path: an exact online-softmax
computed over (q_block × kv_block) tiles with lax.scan, so a 32k-token
prefill never materializes an S×S score matrix.  On TPU the Pallas
flash_attention kernel (kernels/flash_attention) replaces it; the math is
identical and the kernel tests assert allclose against this implementation.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.sharding.rules import ws

NEG_INF = -1e30


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(dt) * scale.astype(dt)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(dt) * scale.astype(dt) + bias.astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_cos_sin(positions: jax.Array, head_dim: int,
                 theta: float = 10_000.0) -> Tuple[jax.Array, jax.Array]:
    """positions int32[...]; returns cos/sin of shape positions.shape + (hd/2,)."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (..., S, H, hd); cos/sin: (..., S, hd/2) broadcast over heads."""
    half = x.shape[-1] // 2
    # rotate in the cos/sin dtype (f32): bf16 activations widen explicitly
    # — same numerics standard promotion gave implicitly, legal under
    # jax_numpy_dtype_promotion=strict
    x1 = x[..., :half].astype(cos.dtype)
    x2 = x[..., half:].astype(cos.dtype)
    c = cos[..., None, :]  # add head axis
    s = sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# Blocked exact attention (online softmax) — the jnp reference path
# ---------------------------------------------------------------------------


def _block_mask(q_pos: jax.Array, k_pos: jax.Array, causal: bool,
                window: Optional[int], kv_valid_len: Optional[jax.Array]) -> jax.Array:
    """(q_blk, k_blk) boolean mask of allowed attention pairs."""
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        m &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        m &= k_pos[None, :] > (q_pos[:, None] - window)
    if kv_valid_len is not None:
        m &= k_pos[None, :] < kv_valid_len
    return m


def _tile(q, k, v, q_block, kv_block):
    """Group heads and tile sequences: returns grouped/tiled views + meta."""
    b, sq, h, hd = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    vd = v.shape[-1]
    groups = h // kvh
    nq, nk = sq // q_block, skv // kv_block
    qb = q.reshape(b, nq, q_block, kvh, groups, hd).transpose(1, 0, 3, 4, 2, 5)
    kb = k.reshape(b, nk, kv_block, kvh, hd).transpose(1, 0, 3, 2, 4)
    vb = v.reshape(b, nk, kv_block, kvh, vd).transpose(1, 0, 3, 2, 4)
    return qb, kb, vb  # (nq,B,KV,G,qb,hd), (nk,B,KV,kvb,hd), (nk,B,KV,kvb,vd)


def _untile(out, b, sq, h, vd, q_block):
    # (nq,B,KV,G,qb,vd) -> (B,Sq,H,vd)
    nq = out.shape[0]
    return out.transpose(1, 0, 4, 2, 3, 5).reshape(b, sq, h, vd)


def _static_mask(qi, ki, q_block, kv_block, causal, window, skv_valid):
    q_pos = qi * q_block + jnp.arange(q_block, dtype=jnp.int32)
    k_pos = ki * kv_block + jnp.arange(kv_block, dtype=jnp.int32)
    m = jnp.ones((q_block, kv_block), bool)
    if causal:
        m &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        m &= k_pos[None, :] > (q_pos[:, None] - window)
    if skv_valid is not None:
        m &= k_pos[None, :] < skv_valid
    return m


def _make_flash(*, causal, window, q_block, kv_block, scale, skv_valid):
    """custom_vjp flash attention over grouped/tiled tensors.

    The backward recomputes tile probabilities from the saved log-sum-exp
    (flash-attention backward), so reverse mode never materializes the
    (nq × nk) stack of (qb × kvb) probability tiles that a plain
    reverse-of-scan would save — measured ~9.6 GB/layer on train_4k cells.
    """

    def fwd_impl(qb, kb, vb):
        nq, b, kvh, groups, qblk, hd = qb.shape
        nk = kb.shape[0]
        vd = vb.shape[-1]

        def q_step(_, qi_qtile):
            qi, qtile = qi_qtile
            qs = qtile.astype(jnp.float32) * scale

            def kv_step(carry, ki_tiles):
                acc, m_run, l_run = carry
                ki, ktile, vtile = ki_tiles
                mask = _static_mask(qi, ki, q_block, kv_block, causal,
                                    window, skv_valid)
                s = jnp.einsum("bkgqd,bkcd->bkgqc", qs,
                               ktile.astype(jnp.float32))
                s = jnp.where(mask[None, None, None], s, NEG_INF)
                m_new = jnp.maximum(m_run, s.max(axis=-1))
                p = jnp.exp(s - m_new[..., None])
                corr = jnp.exp(m_run - m_new)
                l_new = l_run * corr + p.sum(axis=-1)
                pv = jnp.einsum("bkgqc,bkcd->bkgqd", p,
                                vtile.astype(jnp.float32))
                return (acc * corr[..., None] + pv, m_new, l_new), None

            acc0 = jnp.zeros((b, kvh, groups, qblk, vd), jnp.float32)
            m0 = jnp.full((b, kvh, groups, qblk), NEG_INF, jnp.float32)
            l0 = jnp.zeros((b, kvh, groups, qblk), jnp.float32)
            (acc, m_run, l_run), _ = jax.lax.scan(
                kv_step, (acc0, m0, l0),
                (jnp.arange(nk, dtype=jnp.int32), kb, vb))
            out = jnp.where((l_run > 0)[..., None],
                            acc / jnp.maximum(l_run[..., None], 1e-30), 0.0)
            lse = jnp.where(l_run > 0, m_run + jnp.log(
                jnp.maximum(l_run, 1e-30)), jnp.inf)
            return None, (out, lse)

        _, (outs, lses) = jax.lax.scan(
            q_step, None, (jnp.arange(nq, dtype=jnp.int32), qb))
        return outs, lses          # (nq,B,KV,G,qb,vd), (nq,B,KV,G,qb)

    @jax.custom_vjp
    def flash(qb, kb, vb):
        return fwd_impl(qb, kb, vb)[0]

    def flash_fwd(qb, kb, vb):
        outs, lses = fwd_impl(qb, kb, vb)
        return outs, (qb, kb, vb, outs, lses)

    def flash_bwd(res, dout):
        qb, kb, vb, outs, lses = res
        nq, b, kvh, groups, qblk, hd = qb.shape
        nk = kb.shape[0]
        do32 = dout.astype(jnp.float32)
        delta = jnp.sum(do32 * outs.astype(jnp.float32), axis=-1)  # (nq,B,KV,G,qb)

        def recompute(qi, ki, qtile, ktile):
            mask = _static_mask(qi, ki, q_block, kv_block, causal,
                                window, skv_valid)
            s = jnp.einsum("bkgqd,bkcd->bkgqc",
                           qtile.astype(jnp.float32) * scale,
                           ktile.astype(jnp.float32))
            return jnp.where(mask[None, None, None], s, NEG_INF)

        # pass 1: dq (scan q tiles, inner scan kv tiles)
        def dq_qstep(_, inp):
            qi, qtile, do_i, lse_i, delta_i = inp

            def kv_step(dq_acc, ki_tiles):
                ki, ktile, vtile = ki_tiles
                s = recompute(qi, ki, qtile, ktile)
                p = jnp.exp(s - lse_i[..., None])
                dp = jnp.einsum("bkgqd,bkcd->bkgqc", do_i,
                                vtile.astype(jnp.float32))
                ds = p * (dp - delta_i[..., None])
                dq_acc = dq_acc + scale * jnp.einsum(
                    "bkgqc,bkcd->bkgqd", ds, ktile.astype(jnp.float32))
                return dq_acc, None

            dq0 = jnp.zeros((b, kvh, groups, qblk, hd), jnp.float32)
            dq_i, _ = jax.lax.scan(
                kv_step, dq0, (jnp.arange(nk, dtype=jnp.int32), kb, vb))
            return None, dq_i

        _, dq = jax.lax.scan(
            dq_qstep, None,
            (jnp.arange(nq, dtype=jnp.int32), qb, do32, lses, delta))

        # pass 2: dk, dv (scan kv tiles, inner scan q tiles)
        def dkv_kstep(_, inp):
            ki, ktile, vtile = inp

            def q_step(carry, qi_tiles):
                dk_acc, dv_acc = carry
                qi, qtile, do_i, lse_i, delta_i = qi_tiles
                s = recompute(qi, ki, qtile, ktile)
                p = jnp.exp(s - lse_i[..., None])
                dv_acc = dv_acc + jnp.einsum("bkgqc,bkgqd->bkcd", p, do_i)
                dp = jnp.einsum("bkgqd,bkcd->bkgqc", do_i,
                                vtile.astype(jnp.float32))
                ds = p * (dp - delta_i[..., None])
                dk_acc = dk_acc + scale * jnp.einsum(
                    "bkgqc,bkgqd->bkcd", ds, qtile.astype(jnp.float32))
                return (dk_acc, dv_acc), None

            dk0 = jnp.zeros((b, kvh, kv_block, hd), jnp.float32)
            dv0 = jnp.zeros((b, kvh, kv_block, vb.shape[-1]), jnp.float32)
            (dk_i, dv_i), _ = jax.lax.scan(
                q_step, (dk0, dv0),
                (jnp.arange(nq, dtype=jnp.int32), qb, do32, lses, delta))
            return None, (dk_i, dv_i)

        _, (dk, dv) = jax.lax.scan(
            dkv_kstep, None,
            (jnp.arange(nk, dtype=jnp.int32), kb, vb))
        return (dq.astype(qb.dtype), dk.astype(kb.dtype), dv.astype(vb.dtype))

    flash.defvjp(flash_fwd, flash_bwd)
    return flash


def blocked_attention(
    q: jax.Array,                    # (B, Sq, H, hd)
    k: jax.Array,                    # (B, Skv, KV, hd)
    v: jax.Array,                    # (B, Skv, KV, hd)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: jax.Array | int = 0,   # absolute position of q[0] (decode)
    kv_offset: jax.Array | int = 0,  # absolute position of k[0] (ring caches)
    kv_valid_len: Optional[jax.Array] = None,  # mask cache slots >= this
    q_block: int = 512,
    kv_block: int = 1024,
    softmax_scale: Optional[float] = None,
) -> jax.Array:
    """Exact attention in (q_block × kv_block) tiles; GQA via head groups.

    Training/prefill calls (static zero offsets, no dynamic valid length)
    take the custom_vjp flash path; everything else the generic tiled path.
    Returns (B, Sq, H, vd).  All accumulation in f32.
    """
    b, sq, h, hd = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    vd = v.shape[-1]
    assert h % kvh == 0, (h, kvh)
    scale = softmax_scale if softmax_scale is not None else hd ** -0.5

    q_block = min(q_block, sq)
    kv_block = min(kv_block, skv)
    sq_p = ((sq + q_block - 1) // q_block) * q_block
    skv_p = ((skv + kv_block - 1) // kv_block) * kv_block

    static_offsets = (isinstance(q_offset, int) and q_offset == 0 and
                      isinstance(kv_offset, int) and kv_offset == 0 and
                      kv_valid_len is None)
    if static_offsets:
        if sq_p != sq:
            q = jnp.pad(q, ((0, 0), (0, sq_p - sq), (0, 0), (0, 0)))
        if skv_p != skv:
            k = jnp.pad(k, ((0, 0), (0, skv_p - skv), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, skv_p - skv), (0, 0), (0, 0)))
        skv_valid = skv if skv_p != skv else None
        qb, kb, vb = _tile(q, k, v, q_block, kv_block)
        # NOTE (§Perf M2, refuted): pinning the tiled layouts to kv_heads
        # sharding (padded, KV=8 on a 16-way axis) cut the memory term 20%
        # but grew the collective term 33% on mixtral train_4k — the padded
        # shards ping-pong at tile boundaries.  GSPMD cannot express the
        # factorized (KV x G) head sharding a single mesh axis needs here;
        # on TPU the Pallas flash kernel owns its tiling and avoids the
        # issue entirely.  Baseline (unconstrained) layouts retained.
        flash = _make_flash(causal=causal, window=window, q_block=q_block,
                            kv_block=kv_block, scale=scale,
                            skv_valid=skv_valid)
        outs = flash(qb, kb, vb)
        out = _untile(outs, b, sq_p, h, vd, q_block)[:, :sq]
        return out.astype(q.dtype)

    return _blocked_attention_ref(
        q, k, v, causal=causal, window=window, q_offset=q_offset,
        kv_offset=kv_offset, kv_valid_len=kv_valid_len, q_block=q_block,
        kv_block=kv_block, softmax_scale=scale)


def _blocked_attention_ref(
    q, k, v, *, causal, window, q_offset, kv_offset, kv_valid_len,
    q_block, kv_block, softmax_scale,
) -> jax.Array:
    """Generic tiled online-softmax attention (dynamic offsets supported)."""
    b, sq, h, hd = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    vd = v.shape[-1]
    groups = h // kvh
    scale = softmax_scale

    sq_p = ((sq + q_block - 1) // q_block) * q_block
    skv_p = ((skv + kv_block - 1) // kv_block) * kv_block
    if sq_p != sq:
        q = jnp.pad(q, ((0, 0), (0, sq_p - sq), (0, 0), (0, 0)))
    if skv_p != skv:
        k = jnp.pad(k, ((0, 0), (0, skv_p - skv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, skv_p - skv), (0, 0), (0, 0)))
        pad_valid = jnp.asarray(skv, jnp.int32)
        kv_valid_len = pad_valid if kv_valid_len is None else jnp.minimum(
            jnp.asarray(kv_valid_len, jnp.int32), pad_valid)

    nq, nk = sq_p // q_block, skv_p // kv_block
    qb = q.reshape(b, nq, q_block, h, hd).transpose(1, 0, 3, 2, 4) * scale
    kb = k.reshape(b, nk, kv_block, kvh, hd).transpose(1, 0, 3, 2, 4)
    vb = v.reshape(b, nk, kv_block, kvh, vd).transpose(1, 0, 3, 2, 4)

    q_offset = jnp.asarray(q_offset, jnp.int32)
    kv_offset = jnp.asarray(kv_offset, jnp.int32)

    def q_step(_, qi_and_block):
        qi, qtile = qi_and_block            # qtile: (B, H, q_block, hd)
        q_pos = q_offset + qi * q_block + jnp.arange(q_block, dtype=jnp.int32)

        def kv_step(carry, ki_and_tiles):
            acc, m_run, l_run = carry
            ki, ktile, vtile = ki_and_tiles  # (B, KV, kv_block, hd)
            k_pos = kv_offset + ki * kv_block + jnp.arange(kv_block, dtype=jnp.int32)
            mask = _block_mask(q_pos, k_pos, causal, window, kv_valid_len)
            qg = qtile.reshape(b, kvh, groups, q_block, hd)
            s = jnp.einsum("bkgqd,bkcd->bkgqc", qg.astype(jnp.float32),
                           ktile.astype(jnp.float32))
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m_run, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m_run - m_new)
            l_new = l_run * corr + p.sum(axis=-1)
            pv = jnp.einsum("bkgqc,bkcd->bkgqd", p, vtile.astype(jnp.float32))
            acc = acc * corr[..., None] + pv
            return (acc, m_new, l_new), None

        acc0 = jnp.zeros((b, kvh, groups, q_block, vd), jnp.float32)
        m0 = jnp.full((b, kvh, groups, q_block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kvh, groups, q_block), jnp.float32)
        (acc, m_run, l_run), _ = jax.lax.scan(
            kv_step, (acc0, m0, l0),
            (jnp.arange(nk, dtype=jnp.int32), kb, vb))
        out = acc / jnp.maximum(l_run[..., None], 1e-30)
        out = jnp.where((l_run > 0)[..., None], out, 0.0)
        return None, out.reshape(b, h, q_block, vd)

    _, outs = jax.lax.scan(q_step, None,
                           (jnp.arange(nq, dtype=jnp.int32), qb))
    out = outs.transpose(1, 0, 3, 2, 4).reshape(b, sq_p, h, vd)[:, :sq]
    return out.astype(q.dtype)


def decode_attention(
    q: jax.Array,                    # (B, 1, H, hd) — single new token
    k_cache: jax.Array,              # (B, S_cache, KV, hd)
    v_cache: jax.Array,
    *,
    cache_len: jax.Array,            # int32 — valid slots (prefix or ring fill)
    window: Optional[int] = None,    # unused: ring caches are window-sized
    positions_are_ring: bool = False,
) -> jax.Array:
    """One-token attention over a (possibly ring-buffered) KV cache.

    Unlike prefill, the score row is only O(S_cache) so it is computed
    directly (no tiling scan — better for both XLA scheduling and the
    sharded-softmax context-parallel path where S_cache shards over `data`).
    Causality is implicit: the cache holds only past tokens.  For ring
    caches (sliding window) every filled slot is attendable.
    """
    del window
    b, _, h, hd = q.shape
    s, kvh = k_cache.shape[1], k_cache.shape[2]
    vd = v_cache.shape[-1]
    groups = h // kvh
    valid = jnp.minimum(jnp.asarray(cache_len, jnp.int32), s)
    qg = (q[:, 0].reshape(b, kvh, groups, hd) * hd ** -0.5).astype(jnp.float32)
    scores = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache.astype(jnp.float32))
    slot_ok = jnp.arange(s, dtype=jnp.int32)[None, None, None, :] < valid
    scores = jnp.where(slot_ok, scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, h, vd).astype(q.dtype)


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
           w_down: jax.Array) -> jax.Array:
    g = jnp.einsum("bsd,df->bsf", x, w_gate.astype(x.dtype))
    u = jnp.einsum("bsd,df->bsf", x, w_up.astype(x.dtype))
    h = jax.nn.silu(g) * u
    h = ws(h, "batch", "ctx", "ff")
    return jnp.einsum("bsf,fd->bsd", h, w_down.astype(x.dtype))


def gelu_mlp(x: jax.Array, w_up: jax.Array, w_down: jax.Array) -> jax.Array:
    h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, w_up.astype(x.dtype)))
    h = ws(h, "batch", "ctx", "ff")
    return jnp.einsum("bsf,fd->bsd", h, w_down.astype(x.dtype))


def mlp_apply_dense(p, x, gated: bool) -> jax.Array:
    if gated:
        return swiglu(x, p["w_gate"], p["w_up"], p["w_down"])
    return gelu_mlp(x, p["w_up"], p["w_down"])

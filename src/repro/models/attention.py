"""Attention blocks: GQA/MQA (with optional sliding window + QKV bias) and
MLA (multi-head latent attention, MiniCPM3-style).

Each block exposes three entry points used by the model assembly:
- ``*_full``   : full-sequence attention (training / prefill)
- ``*_decode`` : one-token step against a KV cache (linear or ring)

Caches are per-layer pytrees; the model stacks them with a leading layer dim
and feeds them through lax.scan.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import (apply_rope, blocked_attention,
                                 decode_attention, rope_cos_sin)
from repro.sharding.rules import ws


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------


def _project_qkv(p: Dict[str, jax.Array], x: jax.Array, cfg: ModelConfig):
    b, s, _ = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = jnp.einsum("bsd,dk->bsk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dk->bsk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dk->bsk", x, p["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    # NOTE: no sharding constraints here.  Head counts are often not
    # divisible by the model axis (qwen2 h=14, kv=2); GSPMD then picks a
    # factorized sharding (e.g. 2-way over kv × 8-way over head_dim) for the
    # attention interior, and forcing a 16-way heads constraint makes the
    # partitioner fall back to full rematerialization (replicate+reslice)
    # inside the KV scan — catastrophic HBM traffic.  Constraints live at
    # block boundaries (see transformer._dense_block_full).
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s, kv, hd)
    v = v.reshape(b, s, kv, hd)
    return q, k, v


def gqa_full(
    p: Dict[str, jax.Array],
    x: jax.Array,                       # (B, S, d)
    cfg: ModelConfig,
    *,
    positions: Optional[jax.Array] = None,
    causal: bool = True,
) -> jax.Array:
    b, s, _ = x.shape
    q, k, v = _project_qkv(p, x, cfg)
    if positions is None:
        positions = jnp.arange(s, dtype=jnp.int32)[None, :]
    cos, sin = rope_cos_sin(positions, cfg.resolved_head_dim, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    out = blocked_attention(
        q, k, v, causal=causal, window=cfg.sliding_window,
        q_block=cfg.q_block, kv_block=cfg.kv_block,
    )
    out = out.reshape(b, s, -1)
    return jnp.einsum("bsk,kd->bsd", out, p["wo"].astype(x.dtype))


def gqa_init_cache(cfg: ModelConfig, batch: int, max_len: int,
                   dtype=jnp.bfloat16) -> Dict[str, Any]:
    """Linear cache, or ring cache of window size under sliding-window."""
    size = max_len if cfg.sliding_window is None else min(max_len, cfg.sliding_window)
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, size, kv, hd), dtype),
        "v": jnp.zeros((batch, size, kv, hd), dtype),
    }


def gqa_prefill(
    p: Dict[str, jax.Array], x: jax.Array, cfg: ModelConfig,
    cache_len: int,
) -> Tuple[jax.Array, Dict[str, Any]]:
    """Full attention over the prompt + cache population."""
    b, s, _ = x.shape
    q, k, v = _project_qkv(p, x, cfg)
    positions = jnp.arange(s, dtype=jnp.int32)[None, :]
    cos, sin = rope_cos_sin(positions, cfg.resolved_head_dim, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    out = blocked_attention(
        q, k, v, causal=True, window=cfg.sliding_window,
        q_block=cfg.q_block, kv_block=cfg.kv_block,
    )
    cache = gqa_init_cache(cfg, b, cache_len, dtype=k.dtype)
    size = cache["k"].shape[1]
    if cfg.sliding_window is None or s <= size:
        cache["k"] = jax.lax.dynamic_update_slice(
            cache["k"], k[:, :size], (0, 0, 0, 0))
        cache["v"] = jax.lax.dynamic_update_slice(
            cache["v"], v[:, :size], (0, 0, 0, 0))
    else:
        # ring cache: keep the last `size` positions, slot = pos % size
        tail_k = k[:, s - size:]
        tail_v = v[:, s - size:]
        idx = (jnp.arange(s - size, s, dtype=jnp.int32)) % size
        cache["k"] = cache["k"].at[:, idx].set(tail_k)
        cache["v"] = cache["v"].at[:, idx].set(tail_v)
    out = out.reshape(b, s, -1)
    return jnp.einsum("bsk,kd->bsd", out, p["wo"].astype(x.dtype)), cache


def gqa_decode(
    p: Dict[str, jax.Array],
    x: jax.Array,                       # (B, 1, d)
    cache: Dict[str, Any],
    pos: jax.Array,                     # int32 — absolute position of this token
    cfg: ModelConfig,
) -> Tuple[jax.Array, Dict[str, Any]]:
    b = x.shape[0]
    q, k, v = _project_qkv(p, x, cfg)
    cos, sin = rope_cos_sin(pos[None, None], cfg.resolved_head_dim, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    size = cache["k"].shape[1]
    slot = pos % size if cfg.sliding_window is not None else pos
    k_cache = jax.lax.dynamic_update_slice(
        cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(
        cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))
    out = decode_attention(
        q, k_cache, v_cache, cache_len=pos + 1,
        positions_are_ring=cfg.sliding_window is not None,
    )
    out = out.reshape(b, 1, -1)
    y = jnp.einsum("bsk,kd->bsd", out, p["wo"].astype(x.dtype))
    return y, {"k": k_cache, "v": v_cache}


# ---------------------------------------------------------------------------
# MLA (MiniCPM3 / DeepSeek-style latent attention)
# ---------------------------------------------------------------------------
#
# q = W_qb · rmsnorm(W_qa · x)            split into (nope, rope) per head
# kv_latent = rmsnorm(W_kva · x [: r])    cached (rank r)  + k_rope (shared)
# k,v = W_kvb · kv_latent                 expanded per step (naive decoding)


def _mla_project_q(p, x, cfg: ModelConfig):
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.sharded_heads
    from repro.models.layers import rms_norm
    qa = rms_norm(jnp.einsum("bsd,dr->bsr", x, p["q_a"].astype(x.dtype)),
                  p["q_norm"], cfg.norm_eps)
    q = jnp.einsum("bsr,rk->bsk", qa, p["q_b"].astype(x.dtype))
    q = q.reshape(b, s, h, m.qk_nope_head_dim + m.qk_rope_head_dim)
    return q[..., : m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]


def _mla_latent(p, x, cfg: ModelConfig):
    m = cfg.mla
    from repro.models.layers import rms_norm
    kv = jnp.einsum("bsd,dr->bsr", x, p["kv_a"].astype(x.dtype))
    latent = rms_norm(kv[..., : m.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    k_rope = kv[..., m.kv_lora_rank:]       # (B, S, rope_dim) shared across heads
    return latent, k_rope


def _mla_expand_kv(p, latent, cfg: ModelConfig):
    m = cfg.mla
    b, s, _ = latent.shape
    h = cfg.sharded_heads
    kvb = jnp.einsum("bsr,rk->bsk", latent, p["kv_b"].astype(latent.dtype))
    kvb = kvb.reshape(b, s, h, m.qk_nope_head_dim + m.v_head_dim)
    return kvb[..., : m.qk_nope_head_dim], kvb[..., m.qk_nope_head_dim:]


def mla_full(p, x, cfg: ModelConfig, *, positions=None, causal=True) -> jax.Array:
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.sharded_heads
    q_nope, q_rope = _mla_project_q(p, x, cfg)
    latent, k_rope = _mla_latent(p, x, cfg)
    k_nope, v = _mla_expand_kv(p, latent, cfg)
    if positions is None:
        positions = jnp.arange(s, dtype=jnp.int32)[None, :]
    cos, sin = rope_cos_sin(positions, m.qk_rope_head_dim, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    k_rope = apply_rope(k_rope[..., None, :], cos, sin)  # single shared head
    k_rope = jnp.broadcast_to(k_rope, (b, s, h, m.qk_rope_head_dim))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, k_rope], axis=-1)
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    out = blocked_attention(q, k, v, causal=causal, softmax_scale=scale,
                            q_block=cfg.q_block, kv_block=cfg.kv_block)
    out = out.reshape(b, s, h * m.v_head_dim)
    return jnp.einsum("bsk,kd->bsd", out, p["wo"].astype(x.dtype))


def mla_init_cache(cfg: ModelConfig, batch: int, max_len: int,
                   dtype=jnp.bfloat16) -> Dict[str, Any]:
    """MLA caches the rank-r latent + shared rope key — the MLA memory win:
    bytes/token = r + rope_dim instead of 2·H·hd."""
    m = cfg.mla
    return {
        "latent": jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_len, m.qk_rope_head_dim), dtype),
    }


def mla_prefill(p, x, cfg: ModelConfig, cache_len: int):
    b, s, _ = x.shape
    out = mla_full(p, x, cfg)
    latent, k_rope = _mla_latent(p, x, cfg)
    positions = jnp.arange(s, dtype=jnp.int32)[None, :]
    cos, sin = rope_cos_sin(positions, cfg.mla.qk_rope_head_dim, cfg.rope_theta)
    k_rope = apply_rope(k_rope[..., None, :], cos, sin)[..., 0, :]
    cache = mla_init_cache(cfg, b, cache_len, dtype=jnp.bfloat16)
    cache["latent"] = jax.lax.dynamic_update_slice(
        cache["latent"], latent[:, :cache_len].astype(jnp.bfloat16), (0, 0, 0))
    cache["k_rope"] = jax.lax.dynamic_update_slice(
        cache["k_rope"], k_rope[:, :cache_len].astype(jnp.bfloat16), (0, 0, 0))
    return out, cache


def mla_decode(p, x, cache, pos, cfg: ModelConfig):
    m = cfg.mla
    b = x.shape[0]
    h = cfg.sharded_heads
    q_nope, q_rope = _mla_project_q(p, x, cfg)           # (B,1,H,·)
    latent_new, k_rope_new = _mla_latent(p, x, cfg)      # (B,1,r), (B,1,rope)
    cos, sin = rope_cos_sin(pos[None, None], m.qk_rope_head_dim, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    k_rope_new = apply_rope(k_rope_new[..., None, :], cos, sin)[..., 0, :]
    latent_c = jax.lax.dynamic_update_slice(
        cache["latent"], latent_new.astype(cache["latent"].dtype), (0, pos, 0))
    krope_c = jax.lax.dynamic_update_slice(
        cache["k_rope"], k_rope_new.astype(cache["k_rope"].dtype), (0, pos, 0))
    # naive decode: expand k/v from the latent cache (absorbed variant is a
    # §Perf hillclimb option)
    k_nope, v = _mla_expand_kv(p, latent_c.astype(x.dtype), cfg)  # (B,S,H,·)
    s = k_nope.shape[1]
    k_rope_b = jnp.broadcast_to(krope_c.astype(x.dtype)[..., None, :],
                                (b, s, h, m.qk_rope_head_dim))
    k = jnp.concatenate([k_nope, k_rope_b], axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    # decode_attention's default hd^-0.5 scale is exactly (nope+rope)^-0.5 here
    out = decode_attention(q, k, v, cache_len=pos + 1)
    out = out.reshape(b, 1, h * m.v_head_dim)
    y = jnp.einsum("bsk,kd->bsd", out, p["wo"].astype(x.dtype))
    return y, {"latent": latent_c, "k_rope": krope_c}

"""Mixture-of-Experts MLP: top-k routing with capacity-bounded dispatch.

TPU-native design notes
-----------------------
The dispatch uses the *same pattern as VeilGraph's hot-edge compaction*
(core/pagerank.compact_indices): assignments are compacted into bounded
per-expert buffers via a prefix-sum over a one-hot expert matrix, and
assignments beyond an expert's capacity are dropped (token passes through
the residual — the standard "token dropping" MoE trade, and the direct MoE
analogue of the paper's accuracy-for-compute knob).

All dispatch indices are computed *per batch row*, so under pjit the whole
block is local to each data shard: no collectives besides the usual TP
reductions inside the expert matmuls.  Experts are evaluated with a
lax.scan over the (stacked) expert weights: peak activation memory is one
expert's (B, C, ·) tile instead of an (B, E·C, ·) dispatch tensor, which is
what makes dbrx-132b (16 experts) fit at 32k prefill.
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.sharding.rules import ws


def moe_mlp(p: Dict[str, jax.Array], x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """x: (B, S, d) -> (B, S, d).  p holds router + stacked expert weights."""
    moe = cfg.moe
    b, s, d = x.shape
    e, k = moe.num_experts, moe.top_k
    cap = int(s * k * moe.capacity_factor / e) + 1  # per-row per-expert slots

    logits = jnp.einsum("bsd,de->bse", x, p["router"].astype(x.dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_w, top_i = jax.lax.top_k(probs, k)                 # (B, S, k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # ---- assignment -> per-expert slot (compact-into-capacity) ----------
    flat_e = top_i.reshape(b, s * k)                        # expert per assignment
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)     # (B, S*k, E)
    pos = jnp.cumsum(onehot, axis=1) - onehot               # rank within expert
    pos = jnp.take_along_axis(pos, flat_e[..., None], axis=-1)[..., 0]  # (B,S*k)
    ok = pos < cap
    slot = jnp.where(ok, flat_e * cap + pos, e * cap)       # OOB => dropped
    tok = jnp.arange(s * k, dtype=jnp.int32) // k           # source token

    # ---- dispatch: (B, E*cap, d) buffers, scatter per batch row ---------
    def scatter_row(xb, slotb):
        buf = jnp.zeros((e * cap, d), xb.dtype)
        return buf.at[slotb].set(xb[tok], mode="drop")

    buf = jax.vmap(scatter_row)(x, slot)                    # (B, E*cap, d)
    buf = buf.reshape(b, e, cap, d)
    buf = ws(buf, "batch", "experts", None, None)

    # ---- experts: scan over E, one (B, cap, ·) tile live at a time ------
    def expert_step(_, wz):
        wg, wu, wd, xe = wz                                 # xe: (B, cap, d)
        h = jax.nn.silu(jnp.einsum("bcd,df->bcf", xe, wg.astype(xe.dtype)))
        h = h * jnp.einsum("bcd,df->bcf", xe, wu.astype(xe.dtype))
        h = ws(h, "batch", None, "ff")
        return None, jnp.einsum("bcf,fd->bcd", h, wd.astype(xe.dtype))

    _, y = jax.lax.scan(
        expert_step, None,
        (p["w_gate"], p["w_up"], p["w_down"], buf.transpose(1, 0, 2, 3)),
    )                                                       # (E, B, cap, d)
    y = y.transpose(1, 0, 2, 3).reshape(b, e * cap, d)

    # ---- combine: gather per assignment, weight, sum over k -------------
    def gather_row(yb, slotb):
        padded = jnp.concatenate([yb, jnp.zeros((1, d), yb.dtype)], axis=0)
        return padded[jnp.minimum(slotb, e * cap)]          # dropped -> zeros

    gathered = jax.vmap(gather_row)(y, slot)                # (B, S*k, d)
    w = (top_w.reshape(b, s * k) * ok.astype(jnp.float32)).astype(x.dtype)
    out = (gathered * w[..., None]).reshape(b, s, k, d).sum(axis=2)
    return ws(out, "batch", "ctx", "embed")


def moe_load_balance_loss(p: Dict[str, jax.Array], x: jax.Array,
                          cfg: ModelConfig) -> jax.Array:
    """Aux loss (Switch-style): E · Σ_e f_e · P_e over the batch."""
    moe = cfg.moe
    logits = jnp.einsum("bsd,de->bse", x, p["router"].astype(x.dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top1 = jnp.argmax(probs, axis=-1)
    frac = jnp.mean(jax.nn.one_hot(top1, moe.num_experts, dtype=jnp.float32),
                    axis=(0, 1))
    prob = jnp.mean(probs, axis=(0, 1))
    return moe.num_experts * jnp.sum(frac * prob)

"""Model assembly for the architecture pool.

Entry points (all pure functions of (params, cfg, inputs)):

- ``lm_forward``      : full-sequence logits (training / eval)
- ``lm_prefill``      : prompt -> (last-position logits, KV/state caches)
- ``lm_decode_step``  : one token against the caches (serving)

Families: dense/moe/mla decoder-only (+ VLM/audio prefix embeddings),
ssm (mamba2), hybrid (zamba2: mamba backbone + one shared attention block
applied every ``hybrid_period`` layers), encdec (seamless backbone: frame
embeddings -> encoder; tokens -> causal decoder with cross attention).

Per-layer weights are stacked on a leading L axis and consumed by lax.scan;
caches are stacked the same way.  ``remat=True`` wraps each block in
jax.checkpoint (used by train_step).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import mamba2 as m2
from repro.models.config import ModelConfig
from repro.models.layers import mlp_apply_dense, rms_norm
from repro.models.moe import moe_mlp
from repro.sharding.rules import ws


# ---------------------------------------------------------------------------
# block bodies (one layer each)
# ---------------------------------------------------------------------------


def _mlp_apply(p: Dict[str, Any], x: jax.Array, cfg: ModelConfig) -> jax.Array:
    if cfg.moe is not None and "router" in p:
        return moe_mlp(p, x, cfg)
    return mlp_apply_dense(p, x, cfg.mlp_gated)


def _attn_apply_full(p, x, cfg, *, causal=True):
    if cfg.mla is not None:
        return attn.mla_full(p, x, cfg, causal=causal)
    return attn.gqa_full(p, x, cfg, causal=causal)


def _dense_block_full(lp: Dict[str, Any], x: jax.Array, cfg: ModelConfig) -> jax.Array:
    h = _attn_apply_full(lp["attn"], rms_norm(x, lp["norm0"], cfg.norm_eps), cfg)
    x = x + h
    h = _mlp_apply(lp["mlp"], rms_norm(x, lp["norm1"], cfg.norm_eps), cfg)
    x = x + h
    return ws(x, "batch", "ctx_res", "embed")


def _ssm_block_full(lp: Dict[str, Any], x: jax.Array, cfg: ModelConfig) -> jax.Array:
    h = m2.mamba2_full(lp["ssm"], rms_norm(x, lp["norm0"], cfg.norm_eps), cfg)
    return ws(x + h, "batch", "ctx_res", "embed")


def _shared_block_full(sp: Dict[str, Any], x: jax.Array, cfg: ModelConfig) -> jax.Array:
    h = attn.gqa_full(sp["attn"], rms_norm(x, sp["norm0"], cfg.norm_eps), cfg)
    x = x + h
    h = mlp_apply_dense(sp["mlp"], rms_norm(x, sp["norm1"], cfg.norm_eps), cfg.mlp_gated)
    return x + h


def _maybe_remat(fn, remat: bool):
    if not remat:
        return fn
    return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)


# ---------------------------------------------------------------------------
# embedding / head
# ---------------------------------------------------------------------------


def _embed(params, cfg: ModelConfig, tokens: jax.Array,
           prefix_embeds: Optional[jax.Array]) -> jax.Array:
    x = params["embed"]["tok"].astype(jnp.dtype(cfg.activation_dtype))[tokens]
    if prefix_embeds is not None:
        # modality frontend stub: precomputed frame/patch embeddings are
        # prepended to the token embeddings (audio/vision backbones)
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    return ws(x, "batch", "ctx_res", "embed")


def _head(params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        w = params["embed"]["tok"].astype(x.dtype).T
    else:
        w = params["lm_head"].astype(x.dtype)
    logits = jnp.einsum("bsd,dv->bsv", x, w)
    return ws(logits, "batch", "ctx", "vocab")


# ---------------------------------------------------------------------------
# full-sequence forward (train / eval)
# ---------------------------------------------------------------------------


def lm_forward(
    params: Dict[str, Any],
    cfg: ModelConfig,
    tokens: jax.Array,                       # (B, S_text)
    *,
    prefix_embeds: Optional[jax.Array] = None,  # (B, S_prefix, d) frontend stub
    encoder_embeds: Optional[jax.Array] = None,  # (B, S_enc, d) for enc-dec
    remat: bool = False,
) -> jax.Array:
    """Returns logits (B, S_total, V)."""
    x = _embed(params, cfg, tokens, prefix_embeds)

    if cfg.encoder_layers > 0:
        memory = encode(params, cfg, encoder_embeds, remat=remat)
        return _decode_stack_full(params, cfg, x, memory, remat=remat)

    if cfg.family == "ssm":
        body = _maybe_remat(
            lambda xx, lp: (_ssm_block_full(lp, xx, cfg), None), remat)
        x, _ = jax.lax.scan(lambda xx, lp: body(xx, lp), x, params["blocks"])
    elif cfg.family == "hybrid":
        x = _hybrid_full(params, cfg, x, remat=remat)
    else:
        body = _maybe_remat(
            lambda xx, lp: (_dense_block_full(lp, xx, cfg), None), remat)
        x, _ = jax.lax.scan(lambda xx, lp: body(xx, lp), x, params["blocks"])
    return _head(params, cfg, x)


def _hybrid_full(params, cfg: ModelConfig, x, *, remat: bool):
    period = cfg.hybrid_period
    L = cfg.num_layers
    n_groups, rem = divmod(L, period)
    blocks = params["blocks"]
    grouped = jax.tree_util.tree_map(
        lambda a: a[: n_groups * period].reshape(
            (n_groups, period) + a.shape[1:]), blocks)
    tail = jax.tree_util.tree_map(lambda a: a[n_groups * period:], blocks)
    ssm_body = _maybe_remat(
        lambda xx, lp: (_ssm_block_full(lp, xx, cfg), None), remat)
    shared_body = _maybe_remat(
        lambda xx, sp: (_shared_block_full(sp, xx, cfg), None), remat)

    for g in range(n_groups):
        lp_g = jax.tree_util.tree_map(lambda a: a[g], grouped)
        x, _ = jax.lax.scan(lambda xx, lp: ssm_body(xx, lp), x, lp_g)
        x, _ = shared_body(x, params["shared"])
    if rem:
        x, _ = jax.lax.scan(lambda xx, lp: ssm_body(xx, lp), x, tail)
    return x


def encode(params, cfg: ModelConfig, frames: jax.Array, *, remat=False) -> jax.Array:
    """Bidirectional encoder over precomputed frame embeddings (stub frontend)."""
    x = ws(frames.astype(jnp.dtype(cfg.activation_dtype)), "batch", "ctx", "embed")

    def block(xx, lp):
        h = attn.gqa_full(lp["attn"], rms_norm(xx, lp["norm0"], cfg.norm_eps),
                          cfg, causal=False)
        xx = xx + h
        h = mlp_apply_dense(lp["mlp"], rms_norm(xx, lp["norm1"], cfg.norm_eps), cfg.mlp_gated)
        return xx + h, None

    body = _maybe_remat(block, remat)
    x, _ = jax.lax.scan(lambda xx, lp: body(xx, lp), x, params["encoder"])
    return rms_norm(x, params["enc_final_norm"], cfg.norm_eps)


def _cross_attend(lp, x, memory, cfg: ModelConfig):
    """Cross attention: queries from decoder, keys/values from encoder memory."""
    b, s, _ = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = jnp.einsum("bsd,dk->bsk", x, lp["wq"].astype(x.dtype)).reshape(b, s, h, hd)
    k = jnp.einsum("bsd,dk->bsk", memory.astype(x.dtype),
                   lp["wk"].astype(x.dtype)).reshape(b, -1, kv, hd)
    v = jnp.einsum("bsd,dk->bsk", memory.astype(x.dtype),
                   lp["wv"].astype(x.dtype)).reshape(b, -1, kv, hd)
    from repro.models.layers import blocked_attention
    out = blocked_attention(q, k, v, causal=False,
                            q_block=cfg.q_block, kv_block=cfg.kv_block)
    return jnp.einsum("bsk,kd->bsd", out.reshape(b, s, -1),
                      lp["wo"].astype(x.dtype))


def _decode_stack_full(params, cfg: ModelConfig, x, memory, *, remat: bool):
    def block(xx, lp):
        h = attn.gqa_full(lp["attn"], rms_norm(xx, lp["norm0"], cfg.norm_eps),
                          cfg, causal=True)
        xx = xx + h
        h = _cross_attend(lp["cross"], rms_norm(xx, lp["norm1"], cfg.norm_eps),
                          memory, cfg)
        xx = xx + h
        h = mlp_apply_dense(lp["mlp"], rms_norm(xx, lp["norm2"], cfg.norm_eps), cfg.mlp_gated)
        return xx + h, None

    body = _maybe_remat(block, remat)
    x, _ = jax.lax.scan(lambda xx, lp: body(xx, lp), x, params["blocks"])
    return _head(params, cfg, x)


# ---------------------------------------------------------------------------
# caches: init / prefill / decode-step
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               *, enc_len: int = 0, dtype=jnp.bfloat16) -> Dict[str, Any]:
    """Abstract-shape-stable cache pytree for serving."""
    L = cfg.num_layers

    def stack(make_one):
        one = make_one()
        return jax.tree_util.tree_map(
            lambda a: jnp.zeros((L,) + a.shape, a.dtype), one)

    if cfg.family == "ssm":
        return {"ssm": stack(lambda: m2.mamba2_init_cache(cfg, batch))}
    if cfg.family == "hybrid":
        n_apps = cfg.num_layers // cfg.hybrid_period
        one_attn = attn.gqa_init_cache(cfg, batch, max_len, dtype)
        attn_stack = jax.tree_util.tree_map(
            lambda a: jnp.zeros((n_apps,) + a.shape, a.dtype), one_attn)
        return {"ssm": stack(lambda: m2.mamba2_init_cache(cfg, batch)),
                "attn": attn_stack}
    if cfg.encoder_layers > 0:
        self_c = stack(lambda: attn.gqa_init_cache(cfg, batch, max_len, dtype))
        # cross K/V computed once from encoder memory at prefill
        kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        cross = {"k": jnp.zeros((L, batch, enc_len, kv, hd), dtype),
                 "v": jnp.zeros((L, batch, enc_len, kv, hd), dtype)}
        return {"self": self_c, "cross": cross}
    if cfg.mla is not None:
        return {"mla": stack(lambda: attn.mla_init_cache(cfg, batch, max_len, dtype))}
    return {"kv": stack(lambda: attn.gqa_init_cache(cfg, batch, max_len, dtype))}


def lm_prefill(
    params: Dict[str, Any],
    cfg: ModelConfig,
    tokens: jax.Array,
    *,
    cache_len: int,
    prefix_embeds: Optional[jax.Array] = None,
    encoder_embeds: Optional[jax.Array] = None,
    remat: bool = False,
) -> Tuple[jax.Array, Dict[str, Any]]:
    """Run the prompt, return (full logits, populated caches)."""
    x = _embed(params, cfg, tokens, prefix_embeds)
    b = x.shape[0]

    if cfg.encoder_layers > 0:
        memory = encode(params, cfg, encoder_embeds, remat=remat)

        # decoder prefill with self-KV + cross-KV cache capture
        def blockc(xx, lp):
            h, kvc = attn.gqa_prefill(
                lp["attn"], rms_norm(xx, lp["norm0"], cfg.norm_eps), cfg, cache_len)
            xx = xx + h
            h = _cross_attend(lp["cross"], rms_norm(xx, lp["norm1"], cfg.norm_eps),
                              memory, cfg)
            xx = xx + h
            h = mlp_apply_dense(lp["mlp"], rms_norm(xx, lp["norm2"], cfg.norm_eps), cfg.mlp_gated)
            xx = xx + h
            # cross K/V cache (constant during decode)
            kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
            ck = jnp.einsum("bsd,dk->bsk", memory.astype(xx.dtype),
                            lp["cross"]["wk"].astype(xx.dtype)).reshape(
                                b, -1, kv, hd).astype(jnp.bfloat16)
            cv = jnp.einsum("bsd,dk->bsk", memory.astype(xx.dtype),
                            lp["cross"]["wv"].astype(xx.dtype)).reshape(
                                b, -1, kv, hd).astype(jnp.bfloat16)
            return xx, (kvc, {"k": ck, "v": cv})

        body = _maybe_remat(blockc, remat)
        x, caches = jax.lax.scan(lambda xx, lp: body(xx, lp), x, params["blocks"])
        self_c, cross_c = caches
        return _head(params, cfg, x), {"self": self_c, "cross": cross_c}

    if cfg.family == "ssm":
        # run full SSD then recompute final state via a cheap decode replay of
        # the last conv window is incorrect; instead we capture states by
        # running the chunked scan with state capture (mamba2_prefill).
        def block(xx, lp):
            h, st = _mamba_prefill_block(lp, xx, cfg)
            return xx + h, st
        body = _maybe_remat(block, remat)
        x, states = jax.lax.scan(lambda xx, lp: body(xx, lp), x, params["blocks"])
        return _head(params, cfg, x), {"ssm": states}

    if cfg.family == "hybrid":
        return _hybrid_prefill(params, cfg, x, cache_len, remat=remat)

    # dense / mla / moe decoder-only
    def block(xx, lp):
        if cfg.mla is not None:
            h, c = attn.mla_prefill(
                lp["attn"], rms_norm(xx, lp["norm0"], cfg.norm_eps), cfg, cache_len)
        else:
            h, c = attn.gqa_prefill(
                lp["attn"], rms_norm(xx, lp["norm0"], cfg.norm_eps), cfg, cache_len)
        xx = xx + h
        h = _mlp_apply(lp["mlp"], rms_norm(xx, lp["norm1"], cfg.norm_eps), cfg)
        return xx + h, c

    body = _maybe_remat(block, remat)
    x, caches = jax.lax.scan(lambda xx, lp: body(xx, lp), x, params["blocks"])
    key = "mla" if cfg.mla is not None else "kv"
    return _head(params, cfg, x), {key: caches}


def _mamba_prefill_block(lp, x, cfg: ModelConfig):
    """Full SSD + capture (conv tail, final ssm state) for decode continuation."""
    h = m2.mamba2_full(lp["ssm"], rms_norm(x, lp["norm0"], cfg.norm_eps), cfg)
    # final states: replay the projection on the last conv_kernel-1 positions
    # for the conv cache; final SSD state via a short recurrent pass over the
    # last chunk is equivalent but costly — we recompute it from the full
    # sequence with a dedicated scan inside mamba2_full would complicate the
    # fast path, so the state capture here runs the recurrence on the last
    # chunk only (exact: chunk boundaries carry the running state).
    st = _mamba_final_state(lp["ssm"], rms_norm(x, lp["norm0"], cfg.norm_eps), cfg)
    return h, st


def _mamba_final_state(p, x, cfg: ModelConfig):
    """Exact final (conv, ssm) state after processing sequence x."""
    s_cfg = cfg.ssm
    b, s, d = x.shape
    z, xh, bc, dt, di, gn, nh = m2._split_proj(p, x, cfg)
    xbc = jnp.concatenate([xh, bc], -1)
    k = s_cfg.conv_kernel
    conv_state = xbc[:, s - (k - 1):, :].astype(jnp.float32)
    conv_out = m2._causal_conv_full(xbc, p["conv_w"], p["conv_b"])
    xh_c, bc_c = conv_out[..., :di], conv_out[..., di:]
    bmat, _ = jnp.split(bc_c, 2, axis=-1)
    n, hp = s_cfg.d_state, s_cfg.head_dim
    dt_f = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    da = dt_f * a                                           # (B,S,H)
    da_cum = jnp.cumsum(da, axis=1)
    decay_to_end = jnp.exp(da_cum[:, -1:, :] - da_cum)      # (B,S,H)
    heads_per_group = nh // s_cfg.n_groups
    bmat = jnp.repeat(bmat.reshape(b, s, s_cfg.n_groups, n), heads_per_group, 2)
    xh_h = xh_c.reshape(b, s, nh, hp).astype(jnp.float32)
    state = jnp.einsum("bshn,bsh,bsh,bshp->bhpn",
                       bmat.astype(jnp.float32), decay_to_end, dt_f, xh_h)
    return {"conv": conv_state, "ssm": state}


def _hybrid_prefill(params, cfg: ModelConfig, x, cache_len, *, remat):
    period = cfg.hybrid_period
    L = cfg.num_layers
    n_groups, rem = divmod(L, period)
    blocks = params["blocks"]
    grouped = jax.tree_util.tree_map(
        lambda a: a[: n_groups * period].reshape((n_groups, period) + a.shape[1:]),
        blocks)
    tail = jax.tree_util.tree_map(lambda a: a[n_groups * period:], blocks)

    def ssm_block(xx, lp):
        h, st = _mamba_prefill_block(lp, xx, cfg)
        return xx + h, st
    body = _maybe_remat(ssm_block, remat)

    ssm_states = []
    attn_caches = []
    for g in range(n_groups):
        lp_g = jax.tree_util.tree_map(lambda a: a[g], grouped)
        x, st = jax.lax.scan(lambda xx, lp: body(xx, lp), x, lp_g)
        ssm_states.append(st)
        sp = params["shared"]
        h, kvc = attn.gqa_prefill(
            sp["attn"], rms_norm(x, sp["norm0"], cfg.norm_eps), cfg, cache_len)
        x = x + h
        h = mlp_apply_dense(sp["mlp"], rms_norm(x, sp["norm1"], cfg.norm_eps), cfg.mlp_gated)
        x = x + h
        attn_caches.append(kvc)
    if rem:
        x, st = jax.lax.scan(lambda xx, lp: body(xx, lp), x, tail)
        ssm_states.append(st)

    ssm_stacked = jax.tree_util.tree_map(
        lambda *xs: jnp.concatenate(xs, axis=0), *ssm_states)
    attn_stacked = jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs, axis=0), *attn_caches)
    return _head(params, cfg, x), {"ssm": ssm_stacked, "attn": attn_stacked}


# ---------------------------------------------------------------------------
# decode step
# ---------------------------------------------------------------------------


def lm_decode_step(
    params: Dict[str, Any],
    cfg: ModelConfig,
    cache: Dict[str, Any],
    token: jax.Array,                     # (B, 1) int32
    pos: jax.Array,                       # () int32 — absolute position
) -> Tuple[jax.Array, Dict[str, Any]]:
    """One serving step: next-token logits + updated caches."""
    x = _embed(params, cfg, token, None)

    if cfg.family == "ssm":
        def body(xx, inp):
            lp, lc = inp
            h, nc = m2.mamba2_decode(
                lp["ssm"], rms_norm(xx, lp["norm0"], cfg.norm_eps), lc, cfg)
            return xx + h, nc
        x, new_ssm = jax.lax.scan(body, x, (params["blocks"], cache["ssm"]))
        return _head(params, cfg, x), {"ssm": new_ssm}

    if cfg.family == "hybrid":
        return _hybrid_decode(params, cfg, cache, x, pos)

    if cfg.encoder_layers > 0:
        def body(xx, inp):
            lp, (sc, cc) = inp
            h, nsc = attn.gqa_decode(
                lp["attn"], rms_norm(xx, lp["norm0"], cfg.norm_eps), sc, pos, cfg)
            xx = xx + h
            # cross attention against the precomputed cross cache
            from repro.models.layers import decode_attention
            b = xx.shape[0]
            h_, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
            xq = jnp.einsum("bsd,dk->bsk", rms_norm(xx, lp["norm1"], cfg.norm_eps),
                            lp["cross"]["wq"].astype(xx.dtype)).reshape(b, 1, h_, hd)
            out = decode_attention(xq, cc["k"].astype(xx.dtype),
                                   cc["v"].astype(xx.dtype),
                                   cache_len=cc["k"].shape[1])
            h2 = jnp.einsum("bsk,kd->bsd", out.reshape(b, 1, -1),
                            lp["cross"]["wo"].astype(xx.dtype))
            xx = xx + h2
            h3 = mlp_apply_dense(lp["mlp"], rms_norm(xx, lp["norm2"], cfg.norm_eps), cfg.mlp_gated)
            return xx + h3, nsc
        x, new_self = jax.lax.scan(
            body, x, (params["blocks"], (cache["self"], cache["cross"])))
        return _head(params, cfg, x), {"self": new_self, "cross": cache["cross"]}

    # dense / mla / moe
    key = "mla" if cfg.mla is not None else "kv"

    def body(xx, inp):
        lp, lc = inp
        if cfg.mla is not None:
            h, nc = attn.mla_decode(
                lp["attn"], rms_norm(xx, lp["norm0"], cfg.norm_eps), lc, pos, cfg)
        else:
            h, nc = attn.gqa_decode(
                lp["attn"], rms_norm(xx, lp["norm0"], cfg.norm_eps), lc, pos, cfg)
        xx = xx + h
        h = _mlp_apply(lp["mlp"], rms_norm(xx, lp["norm1"], cfg.norm_eps), cfg)
        return xx + h, nc

    x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache[key]))
    return _head(params, cfg, x), {key: new_cache}


def _hybrid_decode(params, cfg: ModelConfig, cache, x, pos):
    period = cfg.hybrid_period
    L = cfg.num_layers
    n_groups, rem = divmod(L, period)
    blocks = params["blocks"]
    grouped = jax.tree_util.tree_map(
        lambda a: a[: n_groups * period].reshape((n_groups, period) + a.shape[1:]),
        blocks)
    tail_p = jax.tree_util.tree_map(lambda a: a[n_groups * period:], blocks)
    ssm_c = cache["ssm"]
    g_ssm = jax.tree_util.tree_map(
        lambda a: a[: n_groups * period].reshape((n_groups, period) + a.shape[1:]),
        ssm_c)
    tail_c = jax.tree_util.tree_map(lambda a: a[n_groups * period:], ssm_c)

    def body(xx, inp):
        lp, lc = inp
        h, nc = m2.mamba2_decode(
            lp["ssm"], rms_norm(xx, lp["norm0"], cfg.norm_eps), lc, cfg)
        return xx + h, nc

    new_ssm_groups = []
    new_attn = []
    for g in range(n_groups):
        lp_g = jax.tree_util.tree_map(lambda a: a[g], grouped)
        lc_g = jax.tree_util.tree_map(lambda a: a[g], g_ssm)
        x, nc = jax.lax.scan(body, x, (lp_g, lc_g))
        new_ssm_groups.append(nc)
        sp = params["shared"]
        ac = jax.tree_util.tree_map(lambda a: a[g], cache["attn"])
        h, nac = attn.gqa_decode(
            sp["attn"], rms_norm(x, sp["norm0"], cfg.norm_eps), ac, pos, cfg)
        x = x + h
        h = mlp_apply_dense(sp["mlp"], rms_norm(x, sp["norm1"], cfg.norm_eps), cfg.mlp_gated)
        x = x + h
        new_attn.append(nac)
    if rem:
        x, nc = jax.lax.scan(body, x, (tail_p, tail_c))
        new_ssm_groups.append(nc)

    # each group's states are already (period, B, ...); concat along layers
    new_ssm = jax.tree_util.tree_map(
        lambda *xs: jnp.concatenate(xs, axis=0), *new_ssm_groups)
    attn_stacked = jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs, axis=0), *new_attn)
    return _head(params, cfg, x), {"ssm": new_ssm, "attn": attn_stacked}

"""Model configuration for the assigned architecture pool.

One ``ModelConfig`` describes any member of the pool: dense GQA/MQA
transformers, MLA (MiniCPM3), MoE (Mixtral/DBRX), SSM (Mamba2), hybrid
(Zamba2), encoder-decoder (Seamless backbone) and VLM/audio variants whose
modality frontends are stubs providing precomputed embeddings.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_head_dim: int = 64
    qk_rope_head_dim: int = 32
    v_head_dim: int = 64


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    n_groups: int = 1
    conv_kernel: int = 4
    chunk_size: int = 256

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def num_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim

    def conv_dim(self, d_model: int) -> int:
        return self.d_inner(d_model) + 2 * self.n_groups * self.d_state


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None   # default d_model // num_heads
    # logical head padding for TP divisibility (e.g. MiniCPM3 40->48 on a
    # 16-way model axis).  Pad heads are zero-initialized in the q/kv
    # expansions and wo rows, so they are mathematically inert at init;
    # standard TPU sharding practice, documented in DESIGN.md.
    padded_heads: Optional[int] = None
    qkv_bias: bool = False
    mlp_gated: bool = True            # False => 2-matrix GELU MLP (gpt_bigcode)
    sliding_window: Optional[int] = None
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (zamba2): a shared attention block is applied every
    # ``hybrid_period`` SSM layers, reusing one set of weights.
    hybrid_period: int = 6
    # encoder-decoder
    encoder_layers: int = 0          # >0 => enc-dec; num_layers = decoder layers
    # modality frontend stub: prepended precomputed embeddings
    frontend: Optional[str] = None   # None | "audio" | "vision"
    frontend_len: int = 0            # patches/frames in train/prefill inputs
    # numerics
    param_dtype: str = "float32"
    activation_dtype: str = "bfloat16"
    # attention reference-path blocking (pure-jnp online softmax)
    q_block: int = 512
    kv_block: int = 1024

    @property
    def sharded_heads(self) -> int:
        return self.padded_heads or self.num_heads

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.num_heads

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic decode: SSM state, hybrid, or sliding-window."""
        return self.family in ("ssm", "hybrid") or self.sliding_window is not None

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks), for roofline 6ND."""
        d, ff, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.resolved_head_dim
        h, kv = self.num_heads, self.num_kv_heads
        emb = v * d * (1 if self.tie_embeddings else 2)
        per_attn = d * h * hd + 2 * d * kv * hd + h * hd * d
        if self.mla is not None:
            m = self.mla
            per_attn = (
                d * m.q_lora_rank
                + m.q_lora_rank * h * (m.qk_nope_head_dim + m.qk_rope_head_dim)
                + d * (m.kv_lora_rank + m.qk_rope_head_dim)
                + m.kv_lora_rank * h * (m.qk_nope_head_dim + m.v_head_dim)
                + h * m.v_head_dim * d
            )
        n_mats = 3 if self.mlp_gated else 2
        per_mlp = n_mats * d * ff
        if self.moe is not None:
            per_mlp = self.moe.num_experts * n_mats * d * ff + d * self.moe.num_experts
        per_ssm = 0
        if self.ssm is not None:
            s = self.ssm
            di = s.d_inner(d)
            nh = s.num_heads(d)
            per_ssm = (
                d * (2 * di + 2 * s.n_groups * s.d_state + nh)   # in_proj
                + s.conv_dim(d) * s.conv_kernel                   # conv
                + 3 * nh                                          # A_log, D, dt_bias
                + di                                              # gated norm
                + di * d                                          # out_proj
            )
        if self.family == "ssm":
            blocks = self.num_layers * (per_ssm + 2 * d)
        elif self.family == "hybrid":
            n_attn_apps = self.num_layers // self.hybrid_period
            blocks = self.num_layers * (per_ssm + 2 * d) + (per_attn + per_mlp + 2 * d)
        elif self.encoder_layers > 0:
            enc = self.encoder_layers * (per_attn + per_mlp + 2 * d)
            dec = self.num_layers * (2 * per_attn + per_mlp + 3 * d)  # self+cross
            blocks = enc + dec
        else:
            blocks = self.num_layers * (per_attn + per_mlp + 2 * d)
        return emb + blocks + d  # + final norm

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k of num_experts)."""
        if self.moe is None:
            return self.param_count()
        d, ff = self.d_model, self.d_ff
        n_mats = 3 if self.mlp_gated else 2
        full_moe = self.moe.num_experts * n_mats * d * ff
        active_moe = self.moe.top_k * n_mats * d * ff
        return self.param_count() - self.num_layers * (full_moe - active_moe)


@dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell from the assignment."""

    name: str            # train_4k | prefill_32k | decode_32k | long_500k
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}

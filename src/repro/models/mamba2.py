"""Mamba2 mixer — SSD (state-space duality) chunked scan + recurrent decode.

Follows the Mamba2 paper's minimal SSD formulation (Dao & Gu 2024, Listing 1),
with the depthwise causal conv on (x, B, C), softplus-dt, scalar-per-head A,
D skip and gated RMSNorm.  The chunked algorithm:

  1. within-chunk (quadratic in chunk length Q): Y_diag via the masked decay
     matrix L = exp(segsum(dt·A)),
  2. chunk states: right-decayed outer products Bᵀ·(decay·x),
  3. inter-chunk recurrence: lax.scan over chunks carrying (H, P, N) state,
  4. state -> output correction Y_off.

Decode is the O(1)/token recurrence:  h ← exp(dt·A)·h + dt·(B ⊗ x);
y = C·h + D·x — this is what makes `long_500k` a constant-memory shape for
SSM/hybrid architectures.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import rms_norm
from repro.sharding.rules import ws


def _segsum(x: jax.Array) -> jax.Array:
    """x: (..., Q) -> (..., Q, Q) with out[l, s] = sum_{s < j <= l} x_j,
    -inf above the diagonal (decay mask exponent)."""
    q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    d = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool), k=0)
    return jnp.where(mask, d, -jnp.inf)


def _split_proj(p, x, cfg: ModelConfig):
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    gn = s.n_groups * s.d_state
    nh = s.num_heads(d)
    zxbcdt = jnp.einsum("bsd,dk->bsk", x, p["in_proj"].astype(x.dtype))
    z, xh, bc, dt = jnp.split(zxbcdt, [di, 2 * di, 2 * di + 2 * gn], axis=-1)
    return z, xh, bc, dt, di, gn, nh


def _causal_conv_full(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv along S. xbc: (B,S,C); w: (k,C); b: (C,)."""
    k = w.shape[0]
    w = w.astype(xbc.dtype)  # params follow activations (as in _split_proj)
    b = b.astype(xbc.dtype)
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(xbc)
    for i in range(k):  # k = 4: unrolled shifts beat a conv op for clarity
        out = out + pad[:, i: i + xbc.shape[1]] * w[i]
    return jax.nn.silu(out + b)


def mamba2_full(p: Dict[str, jax.Array], x: jax.Array,
                cfg: ModelConfig) -> jax.Array:
    """Full-sequence SSD. x: (B, S, d) -> (B, S, d)."""
    s_cfg = cfg.ssm
    b, s, d = x.shape
    z, xh, bc, dt, di, gn, nh = _split_proj(p, x, cfg)
    xbc = _causal_conv_full(jnp.concatenate([xh, bc], -1), p["conv_w"], p["conv_b"])
    xh, bc = xbc[..., :di], xbc[..., di:]
    bmat, cmat = jnp.split(bc, 2, axis=-1)

    n = s_cfg.d_state
    hp = s_cfg.head_dim
    q = min(s_cfg.chunk_size, s)
    s_orig = s
    if s % q:  # pad tail to a chunk multiple; padded outputs are sliced off
        pad = q - s % q
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        z = jnp.pad(z, ((0, 0), (0, pad), (0, 0)))
        s = s + pad
    nc = s // q

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["a_log"].astype(jnp.float32))            # (H,)
    da = dt * a                                             # (B,S,H)

    xh = ws(xh.reshape(b, nc, q, nh, hp), "batch", None, None, "ssm_heads", None)
    # groups broadcast to heads (n_groups=1 in the pool configs)
    bmat = bmat.reshape(b, nc, q, s_cfg.n_groups, n)
    cmat = cmat.reshape(b, nc, q, s_cfg.n_groups, n)
    heads_per_group = nh // s_cfg.n_groups
    bmat = jnp.repeat(bmat, heads_per_group, axis=3)        # (B,nc,Q,H,N)
    cmat = jnp.repeat(cmat, heads_per_group, axis=3)
    da = da.reshape(b, nc, q, nh).transpose(0, 3, 1, 2)     # (B,H,nc,Q)
    dt_c = dt.reshape(b, nc, q, nh)

    x_dt = (xh.astype(jnp.float32) * dt_c[..., None])       # (B,nc,Q,H,P)

    # 1. intra-chunk
    ell = jnp.exp(_segsum(da))                              # (B,H,nc,Q,Q)
    y_diag = jnp.einsum("bclhn,bcshn,bhcls,bcshp->bclhp",
                        cmat.astype(jnp.float32), bmat.astype(jnp.float32),
                        ell, x_dt)

    # 2. per-chunk end states
    da_cum = jnp.cumsum(da, axis=-1)                        # (B,H,nc,Q)
    decay_to_end = jnp.exp(da_cum[..., -1:] - da_cum)       # (B,H,nc,Q)
    states = jnp.einsum("bcshn,bhcs,bcshp->bchpn",
                        bmat.astype(jnp.float32), decay_to_end, x_dt)

    # 3. inter-chunk recurrence
    chunk_decay = jnp.exp(da_cum[..., -1])                  # (B,H,nc)

    def chunk_step(h_prev, inp):
        st, dec = inp                                       # (B,H,P,N), (B,H)
        h_new = h_prev * dec[..., None, None] + st
        return h_new, h_prev                                # emit state BEFORE chunk

    h0 = jnp.zeros((b, nh, hp, n), jnp.float32)
    h_last, h_prevs = jax.lax.scan(
        chunk_step, h0,
        (states.transpose(1, 0, 2, 3, 4),                   # (nc,B,H,P,N)
         chunk_decay.transpose(2, 0, 1)))                   # (nc,B,H)

    # 4. state -> output
    in_decay = jnp.exp(da_cum)                              # (B,H,nc,Q)
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)              # (B,nc,H,P,N)
    y_off = jnp.einsum("bclhn,bchpn,bhcl->bclhp",
                       cmat.astype(jnp.float32), h_prevs, in_decay)

    y = (y_diag + y_off).reshape(b, s, nh, hp)
    y = y + xh.reshape(b, s, nh, hp).astype(jnp.float32) * p["d_skip"].astype(
        jnp.float32)[None, None, :, None]
    y = y.reshape(b, s, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)  # gated norm
    y = y[:, :s_orig]
    return jnp.einsum("bsk,kd->bsd", y, p["out_proj"].astype(x.dtype))


def mamba2_init_cache(cfg: ModelConfig, batch: int,
                      dtype=jnp.float32) -> Dict[str, Any]:
    s = cfg.ssm
    d = cfg.d_model
    return {
        "conv": jnp.zeros((batch, s.conv_kernel - 1, s.conv_dim(d)), dtype),
        "ssm": jnp.zeros((batch, s.num_heads(d), s.head_dim, s.d_state), dtype),
    }


def mamba2_decode(p: Dict[str, jax.Array], x: jax.Array,
                  cache: Dict[str, Any], cfg: ModelConfig
                  ) -> Tuple[jax.Array, Dict[str, Any]]:
    """One-token recurrent step. x: (B, 1, d)."""
    s_cfg = cfg.ssm
    b = x.shape[0]
    z, xh, bc, dt, di, gn, nh = _split_proj(p, x, cfg)
    n, hp = s_cfg.d_state, s_cfg.head_dim

    # conv ring: append new column, apply kernel over the last k positions
    xbc_new = jnp.concatenate([xh, bc], -1)[:, 0]           # (B, conv_dim)
    hist = jnp.concatenate([cache["conv"],
                            xbc_new[:, None].astype(cache["conv"].dtype)], axis=1)
    w = p["conv_w"].astype(jnp.float32)                     # (k, C)
    conv_out = jnp.einsum("bkc,kc->bc", hist.astype(jnp.float32), w)
    conv_out = jax.nn.silu(conv_out + p["conv_b"].astype(jnp.float32))
    new_conv = hist[:, 1:]

    xh_c, bc_c = conv_out[..., :di], conv_out[..., di:]
    bvec, cvec = jnp.split(bc_c, 2, axis=-1)                # (B, G*N)
    heads_per_group = nh // s_cfg.n_groups
    bvec = jnp.repeat(bvec.reshape(b, s_cfg.n_groups, n), heads_per_group, 1)
    cvec = jnp.repeat(cvec.reshape(b, s_cfg.n_groups, n), heads_per_group, 1)

    dt1 = jax.nn.softplus(dt[:, 0].astype(jnp.float32)
                          + p["dt_bias"].astype(jnp.float32))   # (B,H)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    decay = jnp.exp(dt1 * a)                                # (B,H)
    xh_h = xh_c.reshape(b, nh, hp).astype(jnp.float32)
    dbx = jnp.einsum("bh,bhn,bhp->bhpn", dt1, bvec.astype(jnp.float32), xh_h)
    h_new = cache["ssm"] * decay[..., None, None] + dbx
    y = jnp.einsum("bhn,bhpn->bhp", cvec.astype(jnp.float32), h_new)
    y = y + xh_h * p["d_skip"].astype(jnp.float32)[None, :, None]
    y = y.reshape(b, 1, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = jnp.einsum("bsk,kd->bsd", y, p["out_proj"].astype(x.dtype))
    return out, {"conv": new_conv, "ssm": h_new}

from repro.stream.stream import EdgeStream, StreamConfig, build_stream

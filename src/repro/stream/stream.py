"""Edge-update streams — the paper's evaluation protocol (§5).

A stream S is built by uniformly sampling |S| edges (without replacement)
from a dataset's edge list; the *initial graph* is the remaining edges.  S is
split into Q chunks (the paper fixes Q = 50), one chunk applied before each
query.  The paper additionally evaluates a *shuffled* variant to break the
incidence-model ordering of web-graph files; we reproduce both.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, List, Tuple

import numpy as np


@dataclass(frozen=True)
class StreamConfig:
    """The paper's stream protocol parameters: |S| total streamed edges,
    delivered in Q equal chunks (one query per chunk), with optional
    deterministic shuffling of the update order."""

    stream_size: int      # |S| ∈ {5000, 10000, 20000, 40000} in the paper
    num_queries: int = 50  # Q
    shuffle: bool = True
    seed: int = 7

    @property
    def edges_per_query(self) -> int:
        return self.stream_size // self.num_queries


@dataclass
class EdgeStream:
    """The initial graph plus the chunked update stream."""

    init_src: np.ndarray
    init_dst: np.ndarray
    chunks: List[Tuple[np.ndarray, np.ndarray]]
    config: StreamConfig

    def __iter__(self) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        return iter(self.chunks)

    @property
    def total_nodes(self) -> int:
        hi = 0
        if self.init_src.size:
            hi = max(hi, int(self.init_src.max()), int(self.init_dst.max()))
        for s, d in self.chunks:
            if s.size:
                hi = max(hi, int(s.max()), int(d.max()))
        return hi + 1

    @property
    def total_edges(self) -> int:
        return int(self.init_src.size) + sum(int(s.size) for s, _ in self.chunks)


def build_stream(src: np.ndarray, dst: np.ndarray, config: StreamConfig) -> EdgeStream:
    """Split a dataset edge list into (initial graph, Q update chunks).

    Sampling matches the paper: stream edges are a uniform sample of the
    dataset's edges; without ``shuffle`` the stream preserves the dataset
    file order (incidence model — out-edges of a vertex arrive together),
    with ``shuffle`` a single offline permutation is applied.
    """
    src = np.asarray(src, np.int32)
    dst = np.asarray(dst, np.int32)
    m = src.shape[0]
    s_size = min(config.stream_size, m // 2)  # keep a non-trivial initial graph
    rng = np.random.default_rng(config.seed)
    stream_idx = np.sort(rng.choice(m, size=s_size, replace=False))
    mask = np.zeros(m, bool)
    mask[stream_idx] = True

    init_src, init_dst = src[~mask], dst[~mask]
    s_src, s_dst = src[mask], dst[mask]  # dataset order (incidence model)
    if config.shuffle:
        perm = rng.permutation(s_size)
        s_src, s_dst = s_src[perm], s_dst[perm]

    q = config.num_queries
    per = s_size // q
    chunks = [
        (s_src[i * per:(i + 1) * per], s_dst[i * per:(i + 1) * per])
        for i in range(q)
    ]
    return EdgeStream(init_src, init_dst, chunks, config)

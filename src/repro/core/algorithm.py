"""The pluggable algorithm layer: ``StreamingAlgorithm`` + registry.

The paper presents VeilGraph as a *general* model for approximate graph
processing — the five-UDF structure (Alg. 1) and the hot-vertex/big-vertex
summarization (§3) are algorithm-agnostic, with PageRank only the case
study.  This module makes that separation concrete: the engine owns stream
ingestion, update buffering, hot-set selection and the action policy, while
everything rank-computation-specific lives behind :class:`StreamingAlgorithm`:

    init_state(graph)            -> state pytree (dict of arrays)
    exact(state, graph)          -> (state', iterations)        # ground truth
    build_summaries(state, graph, hot, caps) -> (SummaryBuffers, ...)
    summarized(state, graph, summaries)      -> (state', iterations)
    score_view(state)            -> f32[N_cap]  # drives hot-set Δ + ranking
    layout_specs                 -> ((weight, reverse), ...)  # cached edge
                                    layouts the sweeps consume

Every sweep runs through the unified propagation primitive in
:mod:`repro.core.backend`; ``layout_specs`` declares which full-graph
:class:`~repro.core.backend.EdgeLayout` orientations an algorithm needs so
the engine can build them once per applied update batch and pass them into
``exact`` / ``build_summaries`` (the ``layouts`` tuple, same order).  The
``backend`` keyword selects the implementation (``"pallas"`` MXU kernel vs
``"segment_sum"`` XLA fallback); ``None`` resolves per device/env.

Algorithms are **frozen dataclasses** so instances are hashable and can ride
through ``jax.jit`` as static arguments — the generic fused query step in
:mod:`repro.core.fused` traces ``build_summaries`` + ``summarized`` inline
into one XLA program per (algorithm, capacities) pair.

Three algorithms ship in the registry:

- ``pagerank``  — the paper's case study (Gelly-style normalization);
- ``personalized-pagerank`` — seeded teleport vector, same summarized path;
- ``hits``      — hubs & authorities via a forward + reverse summary pair.

Register your own with :func:`register_algorithm` and run it through
``veilgraph``'s session front door (:func:`repro.api.session`).
"""

from __future__ import annotations

import abc
import enum
from dataclasses import dataclass
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.hits import hits as _hits
from repro.core.hits import summarized_hits as _summarized_hits
from repro.core.pagerank import SummaryBuffers
from repro.core.pagerank import build_summary as _build_summary
from repro.core.pagerank import pagerank as _pagerank
from repro.core.pagerank import summarized_pagerank as _summarized_pagerank
from repro.graph.graph import GraphState

#: Algorithm state is a flat dict of device arrays — a JAX pytree, so the
#: whole engine step stays jit-compatible and donation-friendly.
AlgoState = Dict[str, jax.Array]


class Action(enum.Enum):
    """The paper's three OnQuery action indicators (Alg. 1 lines 9-19)."""

    REPEAT_LAST = "repeat-last-answer"
    APPROXIMATE = "compute-approximate"
    EXACT = "compute-exact"


class StreamingAlgorithm(abc.ABC):
    """Interface every engine-pluggable algorithm implements.

    Subclasses must be immutable/hashable (use ``@dataclass(frozen=True)``)
    — instances are jit static arguments.  Numeric knobs (β, iteration
    budget, seeds) are dataclass fields; per-vertex state (score vectors,
    personalization vectors) lives in the state dict returned by
    :meth:`init_state`.
    """

    #: registry key; subclasses override.
    name: str = "abstract"
    #: False opts an algorithm out of the single-XLA-program fused query
    #: path (the engine then runs select/summarize/iterate as separate jits).
    supports_fused: bool = True
    #: True rescales score_view to mean 1 over active vertices inside the
    #: hot-set Δ-dilution bound (Eqs. 4-5 are calibrated against
    #: PageRank-scale scores; L1-normalized algorithms opt in).
    normalize_selection_scores: bool = False
    #: full-graph edge layouts the sweeps consume, as (weight, reverse)
    #: pairs — the engine builds and caches one EdgeLayout per entry (once
    #: per applied update batch) and passes them as the ``layouts`` tuple.
    layout_specs: Tuple[Tuple[str, bool], ...] = (("inv_out", False),)

    @abc.abstractmethod
    def init_state(self, graph: GraphState) -> AlgoState:
        """Fresh per-vertex state sized to ``graph.node_capacity``."""

    @abc.abstractmethod
    def exact(
        self, state: AlgoState, graph: GraphState, *,
        layouts=None, backend: Optional[str] = None,
    ) -> Tuple[AlgoState, jax.Array]:
        """Full recomputation over the live graph (the exact reference).

        Implementations may warm-start from ``state`` — every algorithm
        here converges to a unique fixed point, so warm starts only save
        iterations.  ``layouts`` is the cached tuple matching
        :attr:`layout_specs` (or None to let the sweep build/fall back).
        """

    def build_summaries(
        self,
        state: AlgoState,
        graph: GraphState,
        hot_mask: jax.Array,
        *,
        hot_node_capacity: int,
        hot_edge_capacity: int,
        layouts=None,
        backend: Optional[str] = None,
    ) -> Tuple[SummaryBuffers, ...]:
        """Compacted summary graph(s) the summarized step consumes.

        The default is the paper's single forward big-vertex summary with
        PageRank edge weights, frozen from :meth:`score_view`.  Algorithms
        needing different weights or both orientations (HITS) override.
        ``layouts`` matches :attr:`layout_specs` and accelerates the frozen
        big-vertex pass.
        """
        return (
            _build_summary(
                graph,
                self.score_view(state),
                hot_mask,
                hot_node_capacity=hot_node_capacity,
                hot_edge_capacity=hot_edge_capacity,
                layout=layouts[0] if layouts else None,
                backend=backend,
            ),
        )

    @abc.abstractmethod
    def summarized(
        self,
        state: AlgoState,
        graph: GraphState,
        summaries: Tuple[SummaryBuffers, ...],
        *,
        backend: Optional[str] = None,
    ) -> Tuple[AlgoState, jax.Array]:
        """Approximate update restricted to the hot set (§3.1)."""

    @abc.abstractmethod
    def score_view(self, state: AlgoState) -> jax.Array:
        """f32[N_cap] score vector: the query answer, and the v_s term in
        the hot-set Δ-expansion (Eqs. 4-5)."""


def summaries_overflow(summaries: Tuple[SummaryBuffers, ...]) -> jax.Array:
    """True if any summary exceeded its capacities (caller must fall back)."""
    flag = summaries[0].overflow
    for s in summaries[1:]:
        flag = flag | s.overflow
    return flag


# ---------------------------------------------------------------------------
# PageRank — the paper's case study
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class PageRankAlgorithm(StreamingAlgorithm):
    """Gelly-style PageRank (§2) on the five-UDF engine.

    ``warm_start=False`` (default) keeps the paper protocol: every EXACT
    action recomputes from the uniform start, so ground-truth wall times are
    comparable across queries and to prior sweep artifacts.  Set True to
    seed the power iteration from the previous ranks (fewer iterations, same
    fixed point — PageRank is a contraction).
    """

    beta: float = 0.85
    num_iters: int = 30
    tol: float = 0.0
    teleport_by_n: bool = False
    dangling: bool = False
    warm_start: bool = False

    name = "pagerank"

    def init_state(self, graph: GraphState) -> AlgoState:
        init = 1.0 / jnp.maximum(
            graph.num_active_nodes().astype(jnp.float32), 1.0
        ) if self.teleport_by_n else 1.0
        return {"ranks": jnp.where(graph.node_active, init, 0.0).astype(jnp.float32)}

    def exact(self, state, graph, *, layouts=None, backend=None):
        ranks, iters = _pagerank(
            graph,
            state["ranks"] if self.warm_start else None,
            beta=self.beta,
            num_iters=self.num_iters,
            tol=self.tol,
            teleport_by_n=self.teleport_by_n,
            dangling=self.dangling,
            layout=layouts[0] if layouts else None,
            backend=backend,
        )
        return {"ranks": ranks}, iters

    def summarized(self, state, graph, summaries, *, backend=None):
        (summary,) = summaries
        ranks, iters = _summarized_pagerank(
            summary,
            state["ranks"],
            beta=self.beta,
            num_iters=self.num_iters,
            tol=self.tol,
            backend=backend,
        )
        return {"ranks": ranks}, iters

    def score_view(self, state):
        return state["ranks"]


# ---------------------------------------------------------------------------
# Personalized PageRank — seeded teleport vector
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class PersonalizedPageRankAlgorithm(StreamingAlgorithm):
    """PageRank with teleport mass restricted to a seed set.

    ``seeds`` is a (hashable) tuple of vertex ids; the teleport vector is
    uniform over the seeds and lives in the state dict (it is data, not a
    static knob).  Rankings are localized around the seeds — the streaming
    scenario is e.g. per-user recommendation feeds over a shared engine.
    """

    seeds: Tuple[int, ...] = (0,)
    beta: float = 0.85
    num_iters: int = 30
    tol: float = 0.0
    # False = EXACT recomputes from the teleport vector (protocol-faithful
    # baseline); True = seed from previous ranks (same fixed point, faster)
    warm_start: bool = False

    name = "personalized-pagerank"
    normalize_selection_scores = True

    def __post_init__(self):
        if not self.seeds:
            raise ValueError("personalized-pagerank needs >= 1 seed vertex")

    def _teleport(self, n_cap: int) -> jax.Array:
        seeds = jnp.asarray(self.seeds, jnp.int32)
        if int(seeds.min()) < 0:  # negative ids would wrap via jax indexing
            raise ValueError(f"seed {int(seeds.min())} is negative")
        if int(seeds.max()) >= n_cap:
            raise ValueError(
                f"seed {int(seeds.max())} >= node_capacity {n_cap}")
        t = jnp.zeros((n_cap,), jnp.float32)
        return t.at[seeds].add(1.0 / len(self.seeds))

    def init_state(self, graph: GraphState) -> AlgoState:
        t = self._teleport(graph.node_capacity)
        return {"ranks": t, "teleport": t}

    def exact(self, state, graph, *, layouts=None, backend=None):
        ranks, iters = _pagerank(
            graph,
            state["ranks"] if self.warm_start else None,
            beta=self.beta,
            num_iters=self.num_iters,
            tol=self.tol,
            teleport_v=state["teleport"],
            layout=layouts[0] if layouts else None,
            backend=backend,
        )
        return {"ranks": ranks, "teleport": state["teleport"]}, iters

    def summarized(self, state, graph, summaries, *, backend=None):
        (summary,) = summaries
        ranks, iters = _summarized_pagerank(
            summary,
            state["ranks"],
            beta=self.beta,
            num_iters=self.num_iters,
            tol=self.tol,
            teleport_v=state["teleport"],
            backend=backend,
        )
        return {"ranks": ranks, "teleport": state["teleport"]}, iters

    def score_view(self, state):
        return state["ranks"]


# ---------------------------------------------------------------------------
# HITS — hubs & authorities through a forward + reverse summary pair
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class HITSAlgorithm(StreamingAlgorithm):
    """Kleinberg's HITS with per-iteration L1 normalization.

    State carries both vectors; :meth:`score_view` exposes authorities (the
    usual query answer — swap for hubs with ``rank_by="hub"``).  The
    summarized path freezes cold contributions in *both* directions, which
    needs the forward and the reverse (transposed) big-vertex summary.

    EXACT actions warm-start from the previous vectors: HITS converges to
    the principal singular pair from any positive start, so unlike
    PageRank's protocol there is no canonical cold baseline to preserve and
    the warm start only saves iterations.
    """

    num_iters: int = 30
    tol: float = 0.0
    rank_by: str = "auth"

    name = "hits"
    normalize_selection_scores = True
    layout_specs = (("unit", False), ("unit", True))

    def __post_init__(self):
        if self.rank_by not in ("auth", "hub"):
            raise ValueError(
                f"rank_by must be 'auth' or 'hub', got {self.rank_by!r}")

    def init_state(self, graph: GraphState) -> AlgoState:
        n = jnp.maximum(graph.num_active_nodes().astype(jnp.float32), 1.0)
        uniform = jnp.where(graph.node_active, 1.0 / n, 0.0).astype(jnp.float32)
        return {"auth": uniform, "hub": uniform}

    def exact(self, state, graph, *, layouts=None, backend=None):
        auth, hub, iters = _hits(
            graph,
            state["auth"],
            state["hub"],
            num_iters=self.num_iters,
            tol=self.tol,
            fwd_layout=layouts[0] if layouts else None,
            rev_layout=layouts[1] if layouts else None,
            backend=backend,
        )
        return {"auth": auth, "hub": hub}, iters

    def build_summaries(
        self, state, graph, hot_mask, *, hot_node_capacity, hot_edge_capacity,
        layouts=None, backend=None,
    ):
        fwd = _build_summary(
            graph, state["hub"], hot_mask,
            hot_node_capacity=hot_node_capacity,
            hot_edge_capacity=hot_edge_capacity,
            weight="unit",
            layout=layouts[0] if layouts else None,
            backend=backend,
        )
        rev = _build_summary(
            graph, state["auth"], hot_mask,
            hot_node_capacity=hot_node_capacity,
            hot_edge_capacity=hot_edge_capacity,
            weight="unit", reverse=True,
            layout=layouts[1] if layouts else None,
            backend=backend,
        )
        return (fwd, rev)

    def summarized(self, state, graph, summaries, *, backend=None):
        fwd, rev = summaries
        auth, hub, iters = _summarized_hits(
            fwd, rev, state["auth"], state["hub"],
            num_iters=self.num_iters, tol=self.tol,
            backend=backend,
        )
        return {"auth": auth, "hub": hub}, iters

    def score_view(self, state):
        return state["auth"] if self.rank_by == "auth" else state["hub"]


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Callable[..., StreamingAlgorithm]] = {}
#: alias -> canonical name.  Aliases resolve in :func:`make_algorithm` but
#: never show up in :func:`available_algorithms` (and thus in CLI choices
#: or benchmark artifact names), so one algorithm has one canonical spelling.
_ALIASES: Dict[str, str] = {}


def register_algorithm(
    name: str,
    factory: Callable[..., StreamingAlgorithm],
    *,
    aliases: Tuple[str, ...] = (),
) -> None:
    """Register an algorithm factory under ``name`` (overwrites allowed —
    latest registration wins, so users can shadow the built-ins)."""
    _REGISTRY[name] = factory
    for alias in aliases:
        _ALIASES[alias] = name


def available_algorithms() -> Tuple[str, ...]:
    """Canonical registered names (aliases resolve but are not listed)."""
    return tuple(sorted(_REGISTRY))


def make_algorithm(spec, **params) -> StreamingAlgorithm:
    """Resolve ``spec`` into a :class:`StreamingAlgorithm` instance.

    ``spec`` may be an instance (returned as-is; ``params`` must be empty),
    or a registry name/alias with factory kwargs, e.g.
    ``make_algorithm("personalized-pagerank", seeds=(3, 14))``.
    """
    if isinstance(spec, StreamingAlgorithm):
        if params:
            raise ValueError(
                "algorithm instance given — pass parameters to its "
                "constructor instead")
        return spec
    name = _ALIASES.get(spec, spec)
    try:
        factory = _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown algorithm {spec!r}; registered: "
            f"{', '.join(available_algorithms())}") from None
    return factory(**params)


register_algorithm("pagerank", PageRankAlgorithm)
register_algorithm("personalized-pagerank", PersonalizedPageRankAlgorithm,
                   aliases=("ppr",))
register_algorithm("hits", HITSAlgorithm)

"""The pluggable algorithm layer: ``StreamingAlgorithm`` + registry.

The paper presents VeilGraph as a *general* model for approximate graph
processing — the five-UDF structure (Alg. 1) and the hot-vertex/big-vertex
summarization (§3) are algorithm-agnostic, with PageRank only the case
study.  This module makes that separation concrete: the engine owns stream
ingestion, update buffering, hot-set selection and the action policy, while
everything rank-computation-specific lives behind :class:`StreamingAlgorithm`:

    init_state(graph)            -> state pytree (dict of arrays, any dtypes
                                    — declared in ``state_dtypes``)
    exact(state, graph)          -> (state', iterations)        # ground truth
    build_summaries(state, graph, hot, caps) -> (SummaryBuffers, ...)
    summarized(state, graph, summaries)      -> (state', iterations)
    summarized_batched(batch_state, graph, summaries, row_mask)
                                 -> (batch', iters, row_delta)  # serving
    result_view(state)           -> dtype[N_cap]  # the query answer
    selection_view(state)        -> f32[N_cap]    # drives the hot-set Δ
                                    policy (defaults to result_view as f32)
    semiring                     -> the (⊕, ⊗) algebra the sweeps run over
    layout_specs                 -> ((weight, reverse, semiring), ...) —
                                    cached edge layouts the sweeps consume

Every sweep runs through the unified propagation primitive in
:mod:`repro.core.backend`, parameterized by an explicit
:class:`~repro.core.semiring.Semiring` — ``plus_times`` sum-of-products for
the ranking family, ``min_plus`` for SSSP relaxations, ``min_min`` label
propagation over int32 state for connected components.  ``layout_specs``
declares which full-graph :class:`~repro.core.backend.EdgeLayout`
orientations/algebras an algorithm needs so the engine can build them once
per applied update batch and pass them into ``exact`` / ``build_summaries``
(the ``layouts`` tuple, same order).  The ``backend`` keyword selects the
implementation (``"pallas"`` MXU/VPU kernels vs ``"segment_sum"`` XLA
fallback); ``None`` resolves per device/env.

The old single ``score_view`` is split in two: :meth:`result_view` is the
query answer in the algorithm's own dtype (ranks, distances, int labels)
while :meth:`selection_view` is the *float* volatility signal the paper's
Δ-dilution bound consumes (Eqs. 4-5) — ranking algorithms use their scores
for both, whereas CC/SSSP expose label-churn / distance-delta indicators.
``score_view`` remains as a deprecated alias of ``result_view``.

Algorithms are **frozen dataclasses** so instances are hashable and can ride
through ``jax.jit`` as static arguments — the generic fused query step in
:mod:`repro.core.fused` traces ``build_summaries`` + ``summarized`` inline
into one XLA program per (algorithm, capacities) pair.

Seven algorithms ship in the registry:

- ``pagerank``  — the paper's case study (Gelly-style normalization);
- ``personalized-pagerank`` — seeded teleport vector, same summarized path;
- ``hits``      — hubs & authorities via a forward + reverse summary pair;
- ``katz``      — attenuated-walk centrality (unit weights, β attraction);
- ``connected-components`` — label-min propagation on ``min_min``/int32;
- ``sssp``      — single-source shortest paths on ``min_plus``;
- ``widest-path`` — most-reliable paths on ``max_times`` (the max-reduce
  kernel path).

Register your own with :func:`register_algorithm` and run it through
``veilgraph``'s session front door (:func:`repro.api.session`).
"""

from __future__ import annotations

import abc
import enum
from dataclasses import dataclass
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import backend as B
from repro.core.hits import hits as _hits
from repro.core.hits import summarized_hits as _summarized_hits
from repro.core.hits import summarized_hits_batched as _summarized_hits_batched
from repro.core.katz import katz as _katz
from repro.core.katz import summarized_katz as _summarized_katz
from repro.core.katz import summarized_katz_batched as _summarized_katz_batched
from repro.core.pagerank import SummaryBuffers
from repro.core.pagerank import build_summary as _build_summary
from repro.core.pagerank import pagerank as _pagerank
from repro.core.pagerank import summarized_pagerank as _summarized_pagerank
from repro.core.pagerank import \
    summarized_pagerank_batched as _summarized_pagerank_batched
from repro.core.traversal import LABEL_SENTINEL
from repro.core.traversal import connected_components as _cc
from repro.core.traversal import sssp as _sssp
from repro.core.traversal import \
    summarized_connected_components as _summarized_cc
from repro.core.traversal import \
    summarized_connected_components_batched as _summarized_cc_batched
from repro.core.traversal import summarized_sssp as _summarized_sssp
from repro.core.traversal import \
    summarized_sssp_batched as _summarized_sssp_batched
from repro.core.traversal import summarized_widest_path as \
    _summarized_widest_path
from repro.core.traversal import summarized_widest_path_batched as \
    _summarized_widest_path_batched
from repro.core.traversal import widest_path as _widest_path
from repro.graph.graph import GraphState

#: Algorithm state is a flat dict of device arrays — a JAX pytree, so the
#: whole engine step stays jit-compatible and donation-friendly.
AlgoState = Dict[str, jax.Array]


class Action(enum.Enum):
    """The paper's three OnQuery action indicators (Alg. 1 lines 9-19)."""

    REPEAT_LAST = "repeat-last-answer"
    APPROXIMATE = "compute-approximate"
    EXACT = "compute-exact"


class StreamingAlgorithm(abc.ABC):
    """Interface every engine-pluggable algorithm implements.

    Subclasses must be immutable/hashable (use ``@dataclass(frozen=True)``)
    — instances are jit static arguments.  Numeric knobs (β, iteration
    budget, seeds) are dataclass fields; per-vertex state (score vectors,
    personalization vectors) lives in the state dict returned by
    :meth:`init_state`.
    """

    #: registry key; subclasses override.
    name: str = "abstract"
    #: False opts an algorithm out of the single-XLA-program fused query
    #: path (the engine then runs select/summarize/iterate as separate jits).
    supports_fused: bool = True
    #: True rescales selection_view to mean 1 over active vertices inside
    #: the hot-set Δ-dilution bound (Eqs. 4-5 are calibrated against
    #: PageRank-scale scores; L1-normalized algorithms opt in).
    normalize_selection_scores: bool = False
    #: the (⊕, ⊗) algebra the sweeps run over (registry name in
    #: :mod:`repro.core.semiring`); the default :meth:`build_summaries`
    #: bakes ``ek_w``/``b_in`` for it.
    semiring: str = "plus_times"
    #: True: bigger result values rank first (scores).  False: smaller
    #: values rank first (distances, min-labels) — ``QueryResult.top``
    #: orders accordingly.
    rank_descending: bool = True
    #: weight mode of the default single-summary :meth:`build_summaries`
    #: (``"inv_out"``, ``"unit"`` or ``"length"``).
    summary_weight: str = "inv_out"
    #: declared per-key dtypes of the :meth:`init_state` pytree — the
    #: engine validates them once at state initialization so non-float
    #: state (e.g. CC's int32 labels) can't silently decay to float.
    #: Empty (the default) declares nothing: legacy plugins with arbitrary
    #: state keys construct unchecked.
    state_dtypes: Dict[str, str] = {}
    #: normalization mode for the drift estimator's signals
    #: (:func:`repro.core.control.drift_signals`): ``"mass"`` divides the
    #: residual by total |result| mass (scores, distances); ``"count"``
    #: by the active-vertex count — for 0/1 changed-indicator residuals
    #: (connected components' label flips), where result magnitudes are
    #: ids and carry no error meaning.
    drift_normalize: str = "mass"
    #: declared contraction factor of the algorithm's update operator,
    #: consumed by the :class:`~repro.core.control.QualityController` to
    #: calibrate its drift→error gain: an observed residual amplifies to
    #: at most ``residual / (1 - contraction)`` steady-state error, so the
    #: effective gain is ``1 / (1 - contraction)``.  ``None`` (the
    #: default) keeps the conservative legacy ``gain=3`` bound — right
    #: for damped ranking algebras whose contraction (β ≈ 0.85) is weak.
    #: Min-semiring relaxations (CC, SSSP, widest path) converge to their
    #: fixed point in finitely many sweeps with no geometric tail — they
    #: declare ``0.0`` (gain 1) so a quiet stream stops over-refreshing.
    drift_contraction: Optional[float] = None
    #: constructor knobs whose whole effect is captured by
    #: :meth:`init_state` (seed sets, source sets) — the per-query
    #: *identity* as opposed to numeric sweep knobs.  The serving engine
    #: batches requests that differ only in these into one slot lane (the
    #: identity rides in the ``[B, ...]`` batch state; the batched sweep
    #: never reads it from ``self``).
    per_query_params: Tuple[str, ...] = ()
    #: full-graph edge layouts the sweeps consume, as
    #: (weight, reverse, semiring) triples — the engine builds and caches
    #: one EdgeLayout per entry (once per applied update batch) and passes
    #: them as the ``layouts`` tuple.  Two-element (weight, reverse)
    #: entries from the pre-semiring API mean ``plus_times``.
    layout_specs: Tuple[Tuple, ...] = (("inv_out", False, "plus_times"),)

    @abc.abstractmethod
    def init_state(self, graph: GraphState) -> AlgoState:
        """Fresh per-vertex state sized to ``graph.node_capacity``."""

    @abc.abstractmethod
    def exact(
        self, state: AlgoState, graph: GraphState, *,
        layouts=None, backend: Optional[str] = None,
    ) -> Tuple[AlgoState, jax.Array]:
        """Full recomputation over the live graph (the exact reference).

        Implementations may warm-start from ``state`` — every algorithm
        here converges to a unique fixed point, so warm starts only save
        iterations.  ``layouts`` is the cached tuple matching
        :attr:`layout_specs` (or None to let the sweep build/fall back).
        """

    def build_summaries(
        self,
        state: AlgoState,
        graph: GraphState,
        hot_mask: jax.Array,
        *,
        hot_node_capacity: int,
        hot_edge_capacity: int,
        layouts=None,
        backend: Optional[str] = None,
        shard_bucket_capacity: Optional[int] = None,
    ) -> Tuple[SummaryBuffers, ...]:
        """Compacted summary graph(s) the summarized step consumes.

        The default is the paper's single forward big-vertex summary over
        the algorithm's declared :attr:`semiring` and
        :attr:`summary_weight`, frozen from :meth:`result_view`.
        Algorithms needing different frozen vectors or both orientations
        (HITS, connected components) override.  ``layouts`` matches
        :attr:`layout_specs` and accelerates the frozen big-vertex pass.
        ``shard_bucket_capacity`` tightens the mesh-sharded construction's
        per-(shard, bucket) slot count (see
        :func:`repro.core.pagerank.build_summary`); the engine only
        forwards it when set, so legacy overrides without the keyword
        keep working.

        Handed a *batched* ``[B, ...]`` state (serving lanes), the frozen
        vector :meth:`result_view` returns is ``[B, N]`` and the summary
        comes back with a per-query ``b_in [B, K_cap]`` over one shared
        E_K structure — hot ids, compacted edges and weights depend only
        on the graph and hot mask, never on per-query scores.
        """
        return (
            _build_summary(
                graph,
                self.result_view(state),
                hot_mask,
                hot_node_capacity=hot_node_capacity,
                hot_edge_capacity=hot_edge_capacity,
                weight=self.summary_weight,
                semiring=self.semiring,
                layout=layouts[0] if layouts else None,
                backend=backend,
                shard_bucket_capacity=shard_bucket_capacity,
            ),
        )

    @abc.abstractmethod
    def summarized(
        self,
        state: AlgoState,
        graph: GraphState,
        summaries: Tuple[SummaryBuffers, ...],
        *,
        backend: Optional[str] = None,
    ) -> Tuple[AlgoState, jax.Array]:
        """Approximate update restricted to the hot set (§3.1)."""

    def summarized_batched(
        self,
        batch_state: AlgoState,
        graph: GraphState,
        summaries: Tuple[SummaryBuffers, ...],
        *,
        row_mask: Optional[jax.Array] = None,
        backend: Optional[str] = None,
    ) -> Tuple[AlgoState, jax.Array, jax.Array]:
        """Batched summarized sweep for B concurrent queries (serving).

        ``batch_state`` is the :meth:`init_state` pytree with a leading
        batch axis on every leaf (``[B, ...]``, see
        :meth:`validate_batch_state`); ``summaries`` shares one E_K
        structure across all rows, with ``b_in`` either ``[K_cap]``
        (identical frozen boundary) or ``[B, K_cap]`` (per-query
        boundary from a batched :meth:`build_summaries`).  ``row_mask``
        (bool[B], True = live) freezes converged/vacant serving slots:
        masked rows carry through unchanged and report zero delta.

        Returns ``(batch_state', iterations, row_delta f32[B])`` where
        ``row_delta`` is the per-row convergence signal of the *last*
        inner iteration (L1 change for the ranking family, changed-entry
        count for the min-semiring relaxations).  The shipped algorithms
        all implement this; plugins that don't are rejected by the
        serving engine at submit time.
        """
        raise NotImplementedError(
            f"{type(self).__name__} does not implement summarized_batched; "
            "multi-tenant serving needs the batched [B, N] sweep")

    def validate_batch_state(self, batch_state: AlgoState,
                             batch: int) -> None:
        """Validate a serving slot bank against :attr:`state_dtypes`.

        Every declared key must be present with its declared dtype and a
        leading axis of exactly ``batch`` rows.  Algorithms with an empty
        ``state_dtypes`` declaration (legacy plugins) validate nothing.
        """
        if not self.state_dtypes:
            return
        missing = sorted(set(self.state_dtypes) - set(batch_state))
        if missing:
            raise ValueError(
                f"{self.name}: batch state is missing declared keys "
                f"{missing}")
        for key, want in self.state_dtypes.items():
            arr = batch_state[key]
            if jnp.dtype(arr.dtype) != jnp.dtype(want):
                raise ValueError(
                    f"{self.name}: batch state[{key!r}] has dtype "
                    f"{arr.dtype}, declared {want}")
            if arr.ndim < 2 or arr.shape[0] != batch:
                raise ValueError(
                    f"{self.name}: batch state[{key!r}] must have a "
                    f"leading batch axis of {batch} rows; got shape "
                    f"{tuple(arr.shape)}")

    def drift_residual(
        self,
        state: AlgoState,
        graph: GraphState,
        *,
        layouts=None,
        backend: Optional[str] = None,
    ) -> Optional[jax.Array]:
        """f32[N_cap] fixed-point residual of ``state`` on the *live*
        graph — the quality controller's drift signal (see
        :mod:`repro.core.control`).

        The residual is ``|F(x) − x|`` for one application of the
        algorithm's exact update F over the full graph: zero everywhere
        at the true fixed point, and concentrated on the vertices a
        summarized sweep froze (or whose inputs the stream changed)
        otherwise.  One O(E) push per query, computed inside the fused
        step only when the controller is armed.

        ``layouts`` is the cached tuple matching :attr:`layout_specs`.
        Implementations must be pure gathers/pushes/elementwise ops (no
        host syncs) and must accept batched ``[B, N]`` state leaves
        unchanged (``push`` is batch-polymorphic).  The default returns
        ``None`` — the fused step then falls back to the per-query churn
        of :meth:`result_view` as a (weaker) drift proxy.
        """
        return None

    def batched_cold_seeds(
        self, batch_state: AlgoState,
    ) -> Optional[jax.Array]:
        """bool[B, N] seed masks for cold-start coverage, or ``None``.

        A freshly seated serving slot has no churn history, so its first
        waves need coverage beyond the churn-driven hot set.  Algorithms
        whose results are nonzero/finite only on the set reachable from a
        per-query seed (personalized PageRank's teleport support, the
        traversal sources) return those seed masks here: the batched
        fused step expands them to the reachability fixpoint and runs the
        cold wave on that — *seed-local* instead of the whole active set.
        The default ``None`` keeps full-active cold coverage (global
        algorithms: PageRank, HITS, Katz, connected components).
        """
        return None

    def batched_selection_scores(
        self,
        batch_state: AlgoState,
        row_mask: Optional[jax.Array] = None,
    ) -> jax.Array:
        """Aggregate f32[N_cap] hot-set signal for a ``[B, ...]`` bank.

        The serving engine picks *one* shared hot set per wave, so the B
        per-query :meth:`selection_view` signals collapse to their
        element-wise maximum — a vertex volatile for any live query stays
        hot for the whole wave.  ``row_mask`` rows that are False (vacant
        or finished slots) are excluded; if every row is masked the
        signal is all-zero.
        """
        scores = jax.vmap(self.selection_view)(batch_state)
        if row_mask is not None:
            scores = jnp.where(row_mask[:, None], scores, -jnp.inf)
        agg = jnp.max(scores, axis=0)
        return jnp.where(jnp.isfinite(agg), agg, 0.0)

    def __init_subclass__(cls, **kwargs):
        """Legacy-plugin dispatch, resolved once at class creation.

        A pre-semiring plugin overrides ``score_view``; the engine now
        reads ``result_view``.  Whenever a class (re-)defines
        ``score_view`` *below* the most-derived ``result_view`` in its MRO
        — a fresh old-style plugin, or a subclass of a shipped algorithm
        that customizes only ``score_view`` — the override is what the
        author meant the engine to see, so ``result_view`` is rerouted
        through it.  Classes defining both at the same level (the new API)
        are left alone.  Rerouted methods are tagged so the base
        ``score_view`` alias can skip them when a legacy override chains
        up via ``super().score_view(...)`` (no mutual recursion).
        """
        super().__init_subclass__(**kwargs)

        def defining(name):
            for klass in cls.__mro__:
                if name in vars(klass):
                    return klass
            return None

        sv, rv = defining("score_view"), defining("result_view")
        if (sv not in (None, StreamingAlgorithm) and rv is not None
                and sv is not rv
                # MRO position, not issubclass: a score_view supplied by a
                # mixin precedes the algorithm base without subclassing it
                and cls.__mro__.index(sv) < cls.__mro__.index(rv)):
            orig = vars(sv)["score_view"]

            def _rerouted(self, state, _orig=orig):
                return _orig(self, state)

            _rerouted._legacy_reroute = True
            _rerouted.__doc__ = (
                f"result_view rerouted through the legacy "
                f"{sv.__name__}.score_view override.")
            cls.result_view = _rerouted

    @abc.abstractmethod
    def result_view(self, state: AlgoState) -> jax.Array:
        """dtype[N_cap] query answer — PageRank/Katz scores, HITS
        authorities, int32 component labels, f32 distances, …

        Subclasses must override this (or, legacy pre-semiring plugins,
        ``score_view`` — :meth:`__init_subclass__` reroutes *before*
        ``__abstractmethods__`` is computed, so old plugins stay
        instantiable while a class implementing neither view still fails
        at construction).
        """

    def selection_view(self, state: AlgoState) -> jax.Array:
        """f32[N_cap] volatility signal: the v_s term in the hot-set
        Δ-expansion (Eqs. 4-5).  Ranking algorithms default to their
        scores; algorithms with non-score state (CC, SSSP) override with
        churn indicators (recent label flips / distance deltas)."""
        return self.result_view(state).astype(jnp.float32)

    def score_view(self, state: AlgoState) -> jax.Array:
        """Deprecated pre-semiring alias of :meth:`result_view` (the
        engine's selection now reads :meth:`selection_view` instead).

        Resolves to the first *non-rerouted* ``result_view`` in the MRO so
        a legacy override calling ``super().score_view(...)`` gets its
        parent's answer (the pre-split behaviour), not itself back.
        """
        for klass in type(self).__mro__:
            rv = vars(klass).get("result_view")
            if rv is not None and not getattr(rv, "_legacy_reroute", False):
                return rv(self, state)
        raise NotImplementedError(
            f"{type(self).__name__} implements neither result_view nor the "
            "legacy score_view")


def summaries_overflow(summaries: Tuple[SummaryBuffers, ...]) -> jax.Array:
    """True if any summary exceeded its capacities (caller must fall back)."""
    flag = summaries[0].overflow
    for s in summaries[1:]:
        flag = flag | s.overflow
    return flag


# ---------------------------------------------------------------------------
# PageRank — the paper's case study
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class PageRankAlgorithm(StreamingAlgorithm):
    """Gelly-style PageRank (§2) on the five-UDF engine.

    ``warm_start=False`` (default) keeps the paper protocol: every EXACT
    action recomputes from the uniform start, so ground-truth wall times are
    comparable across queries and to prior sweep artifacts.  Set True to
    seed the power iteration from the previous ranks (fewer iterations, same
    fixed point — PageRank is a contraction).
    """

    beta: float = 0.85
    num_iters: int = 30
    tol: float = 0.0
    teleport_by_n: bool = False
    dangling: bool = False
    warm_start: bool = False

    name = "pagerank"
    state_dtypes = {"ranks": "float32"}

    def init_state(self, graph: GraphState) -> AlgoState:
        init = 1.0 / jnp.maximum(
            graph.num_active_nodes().astype(jnp.float32), 1.0
        ) if self.teleport_by_n else 1.0
        return {"ranks": jnp.where(graph.node_active, init, 0.0).astype(jnp.float32)}

    def exact(self, state, graph, *, layouts=None, backend=None):
        ranks, iters = _pagerank(
            graph,
            state["ranks"] if self.warm_start else None,
            beta=self.beta,
            num_iters=self.num_iters,
            tol=self.tol,
            teleport_by_n=self.teleport_by_n,
            dangling=self.dangling,
            layout=layouts[0] if layouts else None,
            backend=backend,
        )
        return {"ranks": ranks}, iters

    def summarized(self, state, graph, summaries, *, backend=None):
        (summary,) = summaries
        ranks, iters = _summarized_pagerank(
            summary,
            state["ranks"],
            beta=self.beta,
            num_iters=self.num_iters,
            tol=self.tol,
            backend=backend,
        )
        return {"ranks": ranks}, iters

    def summarized_batched(self, batch_state, graph, summaries, *,
                           row_mask=None, backend=None):
        (summary,) = summaries
        ranks, iters, row_delta = _summarized_pagerank_batched(
            summary,
            batch_state["ranks"],
            beta=self.beta,
            num_iters=self.num_iters,
            tol=self.tol,
            row_mask=row_mask,
            backend=backend,
        )
        return {"ranks": ranks}, iters, row_delta

    def drift_residual(self, state, graph, *, layouts=None, backend=None):
        # |(1-β)·t + β·push(r) − r| — zero at pagerank()'s fixed point.
        # Matches the exact update including the teleport normalization;
        # the rarely-used dangling redistribution is omitted (it only
        # shifts the residual by the dangling mass, same order as the
        # drift being measured).
        if layouts is None:
            return None
        r = state["ranks"]
        incoming = B.push(r, layouts[0], backend=backend)
        n_active = jnp.maximum(
            graph.num_active_nodes().astype(jnp.float32), 1.0)
        tele = jnp.where(self.teleport_by_n,
                         (1.0 - self.beta) / n_active, 1.0 - self.beta)
        new_r = jnp.where(graph.node_active,
                          tele + self.beta * incoming, 0.0)
        return jnp.abs(new_r - r)

    def result_view(self, state):
        return state["ranks"]


# ---------------------------------------------------------------------------
# Personalized PageRank — seeded teleport vector
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class PersonalizedPageRankAlgorithm(StreamingAlgorithm):
    """PageRank with teleport mass restricted to a seed set.

    ``seeds`` is a (hashable) tuple of vertex ids; the teleport vector is
    uniform over the seeds and lives in the state dict (it is data, not a
    static knob).  Rankings are localized around the seeds — the streaming
    scenario is e.g. per-user recommendation feeds over a shared engine.
    """

    seeds: Tuple[int, ...] = (0,)
    beta: float = 0.85
    num_iters: int = 30
    tol: float = 0.0
    # False = EXACT recomputes from the teleport vector (protocol-faithful
    # baseline); True = seed from previous ranks (same fixed point, faster)
    warm_start: bool = False

    name = "personalized-pagerank"
    normalize_selection_scores = True
    state_dtypes = {"ranks": "float32", "teleport": "float32"}
    per_query_params = ("seeds",)  # identity lives in state["teleport"]

    def __post_init__(self):
        if not self.seeds:
            raise ValueError("personalized-pagerank needs >= 1 seed vertex")

    def _teleport(self, n_cap: int) -> jax.Array:
        seeds = jnp.asarray(self.seeds, jnp.int32)
        if int(seeds.min()) < 0:  # negative ids would wrap via jax indexing
            raise ValueError(f"seed {int(seeds.min())} is negative")
        if int(seeds.max()) >= n_cap:
            raise ValueError(
                f"seed {int(seeds.max())} >= node_capacity {n_cap}")
        t = jnp.zeros((n_cap,), jnp.float32)
        return t.at[seeds].add(1.0 / len(self.seeds))

    def init_state(self, graph: GraphState) -> AlgoState:
        t = self._teleport(graph.node_capacity)
        return {"ranks": t, "teleport": t}

    def exact(self, state, graph, *, layouts=None, backend=None):
        ranks, iters = _pagerank(
            graph,
            state["ranks"] if self.warm_start else None,
            beta=self.beta,
            num_iters=self.num_iters,
            tol=self.tol,
            teleport_v=state["teleport"],
            layout=layouts[0] if layouts else None,
            backend=backend,
        )
        return {"ranks": ranks, "teleport": state["teleport"]}, iters

    def summarized(self, state, graph, summaries, *, backend=None):
        (summary,) = summaries
        ranks, iters = _summarized_pagerank(
            summary,
            state["ranks"],
            beta=self.beta,
            num_iters=self.num_iters,
            tol=self.tol,
            teleport_v=state["teleport"],
            backend=backend,
        )
        return {"ranks": ranks, "teleport": state["teleport"]}, iters

    def summarized_batched(self, batch_state, graph, summaries, *,
                           row_mask=None, backend=None):
        # one engine lane serves B different seed sets: the teleport
        # vectors ride in the batch state ([B, N]), not in `self`
        (summary,) = summaries
        ranks, iters, row_delta = _summarized_pagerank_batched(
            summary,
            batch_state["ranks"],
            beta=self.beta,
            num_iters=self.num_iters,
            tol=self.tol,
            teleport_v=batch_state["teleport"],
            row_mask=row_mask,
            backend=backend,
        )
        return {"ranks": ranks, "teleport": batch_state["teleport"]}, \
            iters, row_delta

    def drift_residual(self, state, graph, *, layouts=None, backend=None):
        # |(1-β)·t(v) + β·push(r) − r|: the personalized-teleport fixed
        # point.  Batched states carry [B, N] ranks/teleports — push and
        # the elementwise ops are batch-polymorphic.
        if layouts is None:
            return None
        r = state["ranks"]
        incoming = B.push(r, layouts[0], backend=backend)
        new_r = jnp.where(graph.node_active,
                          (1.0 - self.beta) * state["teleport"]
                          + self.beta * incoming, 0.0)
        return jnp.abs(new_r - r)

    def batched_cold_seeds(self, batch_state):
        # ranks are nonzero only on the set reachable from the teleport
        # support — seed-local cold coverage suffices
        return batch_state["teleport"] > 0.0

    def result_view(self, state):
        return state["ranks"]


# ---------------------------------------------------------------------------
# HITS — hubs & authorities through a forward + reverse summary pair
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class HITSAlgorithm(StreamingAlgorithm):
    """Kleinberg's HITS with per-iteration L1 normalization.

    State carries both vectors plus the tracked global-σ estimate
    (``sigma`` — ``f32[2]``, one per direction); :meth:`result_view`
    exposes authorities (the usual query answer — swap for hubs with
    ``rank_by="hub"``).  The summarized path freezes cold contributions in
    *both* directions, which needs the forward and the reverse (transposed)
    big-vertex summary; its normalization is anchored to ``sigma``, which
    exact computations measure and summarized sweeps refresh (see
    :func:`repro.core.hits.summarized_hits`).

    EXACT actions warm-start from the previous vectors: HITS converges to
    the principal singular pair from any positive start, so unlike
    PageRank's protocol there is no canonical cold baseline to preserve and
    the warm start only saves iterations.
    """

    num_iters: int = 30
    tol: float = 0.0
    rank_by: str = "auth"

    name = "hits"
    normalize_selection_scores = True
    summary_weight = "unit"
    state_dtypes = {"auth": "float32", "hub": "float32", "sigma": "float32"}
    layout_specs = (("unit", False, "plus_times"), ("unit", True, "plus_times"))

    def __post_init__(self):
        if self.rank_by not in ("auth", "hub"):
            raise ValueError(
                f"rank_by must be 'auth' or 'hub', got {self.rank_by!r}")

    def init_state(self, graph: GraphState) -> AlgoState:
        n = jnp.maximum(graph.num_active_nodes().astype(jnp.float32), 1.0)
        uniform = jnp.where(graph.node_active, 1.0 / n, 0.0).astype(jnp.float32)
        return {"auth": uniform, "hub": uniform,
                "sigma": jnp.ones((2,), jnp.float32)}

    def exact(self, state, graph, *, layouts=None, backend=None):
        auth, hub, iters, sigma = _hits(
            graph,
            state["auth"],
            state["hub"],
            num_iters=self.num_iters,
            tol=self.tol,
            fwd_layout=layouts[0] if layouts else None,
            rev_layout=layouts[1] if layouts else None,
            backend=backend,
        )
        return {"auth": auth, "hub": hub, "sigma": sigma}, iters

    def build_summaries(
        self, state, graph, hot_mask, *, hot_node_capacity, hot_edge_capacity,
        layouts=None, backend=None, shard_bucket_capacity=None,
    ):
        fwd = _build_summary(
            graph, state["hub"], hot_mask,
            hot_node_capacity=hot_node_capacity,
            hot_edge_capacity=hot_edge_capacity,
            weight="unit",
            layout=layouts[0] if layouts else None,
            backend=backend,
            shard_bucket_capacity=shard_bucket_capacity,
        )
        rev = _build_summary(
            graph, state["auth"], hot_mask,
            hot_node_capacity=hot_node_capacity,
            hot_edge_capacity=hot_edge_capacity,
            weight="unit", reverse=True,
            layout=layouts[1] if layouts else None,
            backend=backend,
            shard_bucket_capacity=shard_bucket_capacity,
        )
        return (fwd, rev)

    def summarized(self, state, graph, summaries, *, backend=None):
        fwd, rev = summaries
        auth, hub, iters, sigma = _summarized_hits(
            fwd, rev, state["auth"], state["hub"], state["sigma"],
            num_iters=self.num_iters, tol=self.tol,
            backend=backend,
        )
        return {"auth": auth, "hub": hub, "sigma": sigma}, iters

    def summarized_batched(self, batch_state, graph, summaries, *,
                           row_mask=None, backend=None):
        fwd, rev = summaries
        auth, hub, iters, row_delta, sigma = _summarized_hits_batched(
            fwd, rev, batch_state["auth"], batch_state["hub"],
            batch_state["sigma"],
            num_iters=self.num_iters, tol=self.tol,
            row_mask=row_mask, backend=backend,
        )
        return {"auth": auth, "hub": hub, "sigma": sigma}, iters, row_delta

    def result_view(self, state):
        return state["auth"] if self.rank_by == "auth" else state["hub"]


# ---------------------------------------------------------------------------
# Katz centrality — attenuated walk counts (plus_times, unit weights)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class KatzAlgorithm(StreamingAlgorithm):
    """Katz centrality ``c = Σ_k α^k (Aᵀ)^k β·1`` on the five-UDF engine.

    The sweep contracts (and the fixed point exists) only while
    ``α < 1/σ_max(A)`` — keep ``alpha`` small on hub-heavy graphs.  EXACT
    actions warm-start from the previous scores by default (same fixed
    point, fewer iterations); ``warm_start=False`` restores the
    cold-baseline protocol.
    """

    alpha: float = 0.05
    beta: float = 1.0
    num_iters: int = 30
    tol: float = 0.0
    warm_start: bool = True

    name = "katz"
    normalize_selection_scores = True
    summary_weight = "unit"
    state_dtypes = {"katz": "float32"}
    layout_specs = (("unit", False, "plus_times"),)

    def __post_init__(self):
        if not 0.0 < self.alpha < 1.0:
            raise ValueError(f"alpha must be in (0, 1), got {self.alpha}")

    def init_state(self, graph: GraphState) -> AlgoState:
        return {"katz": jnp.where(graph.node_active, self.beta, 0.0).astype(
            jnp.float32)}

    def exact(self, state, graph, *, layouts=None, backend=None):
        c, iters = _katz(
            graph,
            state["katz"] if self.warm_start else None,
            alpha=self.alpha,
            beta=self.beta,
            num_iters=self.num_iters,
            tol=self.tol,
            layout=layouts[0] if layouts else None,
            backend=backend,
        )
        return {"katz": c}, iters

    def summarized(self, state, graph, summaries, *, backend=None):
        (summary,) = summaries
        c, iters = _summarized_katz(
            summary,
            state["katz"],
            alpha=self.alpha,
            beta=self.beta,
            num_iters=self.num_iters,
            tol=self.tol,
            backend=backend,
        )
        return {"katz": c}, iters

    def summarized_batched(self, batch_state, graph, summaries, *,
                           row_mask=None, backend=None):
        (summary,) = summaries
        c, iters, row_delta = _summarized_katz_batched(
            summary,
            batch_state["katz"],
            alpha=self.alpha,
            beta=self.beta,
            num_iters=self.num_iters,
            tol=self.tol,
            row_mask=row_mask,
            backend=backend,
        )
        return {"katz": c}, iters, row_delta

    def drift_residual(self, state, graph, *, layouts=None, backend=None):
        # |β + α·push(c) − c| — zero at katz()'s fixed point
        if layouts is None:
            return None
        c = state["katz"]
        incoming = B.push(c, layouts[0], backend=backend)
        new_c = jnp.where(graph.node_active,
                          self.beta + self.alpha * incoming, 0.0)
        return jnp.abs(new_c - c)

    def result_view(self, state):
        return state["katz"]


# ---------------------------------------------------------------------------
# Connected components — label-min propagation (min_min, int32 state)
# ---------------------------------------------------------------------------


def _finite_churn(new: jax.Array, old: jax.Array) -> jax.Array:
    """f32 per-vertex change indicator robust to ±∞/sentinel state:
    |new − old| where both are finite, 1.0 where exactly one is, 0 else."""
    new_f = new.astype(jnp.float32)
    old_f = old.astype(jnp.float32)
    both = jnp.isfinite(new_f) & jnp.isfinite(old_f)
    return jnp.where(both, jnp.abs(new_f - old_f),
                     jnp.where(new_f != old_f, 1.0, 0.0))


@dataclass(frozen=True)
class ConnectedComponentsAlgorithm(StreamingAlgorithm):
    """Weakly-connected components via min-label propagation.

    The first non-float workload on the engine: state is *int32* labels
    (every vertex converges to the minimum vertex id in its weakly
    connected component; inactive vertices hold the int32-max sentinel),
    propagated over the ``min_min`` semiring in both edge orientations.
    :meth:`selection_view` is the label-*churn* indicator — 1.0 where the
    last sweep changed a vertex's label — so the Δ-expansion grows the hot
    set around recently-merged regions rather than around big labels.

    EXACT actions recompute labels from scratch by default (correct under
    removals); ``warm_start=True`` reuses previous labels, which is exact
    for the paper's addition-only streams and converges faster.
    """

    num_iters: int = 30
    warm_start: bool = False

    name = "connected-components"
    normalize_selection_scores = True
    rank_descending = False  # smaller labels first (component min ids)
    drift_normalize = "count"  # residual = label flips, not id magnitudes
    drift_contraction = 0.0  # label relaxation has no geometric tail
    semiring = "min_min"
    summary_weight = "unit"
    state_dtypes = {"labels": "int32", "churn": "float32"}
    layout_specs = (("unit", False, "min_min"), ("unit", True, "min_min"))

    def init_state(self, graph: GraphState) -> AlgoState:
        ids = jnp.arange(graph.node_capacity, dtype=jnp.int32)
        return {
            "labels": jnp.where(graph.node_active, ids, LABEL_SENTINEL),
            "churn": jnp.zeros((graph.node_capacity,), jnp.float32),
        }

    def exact(self, state, graph, *, layouts=None, backend=None):
        labels, iters = _cc(
            graph,
            state["labels"] if self.warm_start else None,
            num_iters=self.num_iters,
            fwd_layout=layouts[0] if layouts else None,
            rev_layout=layouts[1] if layouts else None,
            backend=backend,
        )
        return {"labels": labels,
                "churn": (labels != state["labels"]).astype(jnp.float32)}, \
            iters

    def build_summaries(
        self, state, graph, hot_mask, *, hot_node_capacity, hot_edge_capacity,
        layouts=None, backend=None, shard_bucket_capacity=None,
    ):
        common = dict(hot_node_capacity=hot_node_capacity,
                      hot_edge_capacity=hot_edge_capacity,
                      weight="unit", semiring="min_min", backend=backend,
                      shard_bucket_capacity=shard_bucket_capacity)
        fwd = _build_summary(
            graph, state["labels"], hot_mask,
            layout=layouts[0] if layouts else None, **common)
        rev = _build_summary(
            graph, state["labels"], hot_mask, reverse=True,
            layout=layouts[1] if layouts else None, **common)
        return (fwd, rev)

    def summarized(self, state, graph, summaries, *, backend=None):
        fwd, rev = summaries
        labels, iters = _summarized_cc(
            fwd, rev, state["labels"],
            num_iters=self.num_iters, backend=backend,
        )
        return {"labels": labels,
                "churn": (labels != state["labels"]).astype(jnp.float32)}, \
            iters

    def summarized_batched(self, batch_state, graph, summaries, *,
                           row_mask=None, backend=None):
        fwd, rev = summaries
        labels, iters, changed = _summarized_cc_batched(
            fwd, rev, batch_state["labels"],
            num_iters=self.num_iters, row_mask=row_mask, backend=backend,
        )
        churn = (labels != batch_state["labels"]).astype(jnp.float32)
        return {"labels": labels, "churn": churn}, iters, \
            changed.astype(jnp.float32)

    def drift_residual(self, state, graph, *, layouts=None, backend=None):
        # 1.0 where one more min-label relaxation would still change a
        # vertex (the fixpoint test of connected_components's relax step)
        if layouts is None or len(layouts) < 2:
            return None
        lab = state["labels"]
        relaxed = jnp.minimum(
            lab,
            jnp.minimum(
                B.push(lab, layouts[0], semiring="min_min",
                       backend=backend),
                B.push(lab, layouts[1], semiring="min_min",
                       backend=backend)))
        changed = graph.node_active & (relaxed != lab)
        return changed.astype(jnp.float32)

    def result_view(self, state):
        return state["labels"]

    def selection_view(self, state):
        return state["churn"]


# ---------------------------------------------------------------------------
# SSSP — single-source shortest paths (min_plus)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class SSSPAlgorithm(StreamingAlgorithm):
    """Streaming single-source shortest paths (Bellman-Ford on min-plus).

    ``sources`` is a (hashable) tuple of vertex ids whose distances are
    pinned to 0; unreachable vertices hold +∞.  Edge lengths default to
    the unit hop count; streams that register edges with a per-edge
    ``weights`` column (``GraphState.edge_len``) feed real lengths into
    every ``weight="length"`` layout automatically.  :meth:`selection_view`
    is the
    distance-*delta* indicator of the last sweep, so the Δ-expansion
    follows shortest-path churn instead of raw distance magnitude.

    EXACT actions recompute from the sources by default (correct under
    removals); ``warm_start=True`` relaxes from the previous distances,
    exact for addition-only streams (distances are monotone
    non-increasing) and typically far fewer iterations.
    """

    sources: Tuple[int, ...] = (0,)
    num_iters: int = 30
    warm_start: bool = False

    name = "sssp"
    normalize_selection_scores = True
    rank_descending = False  # nearest vertices first
    drift_contraction = 0.0  # Bellman-Ford settles, no geometric tail
    semiring = "min_plus"
    summary_weight = "length"
    state_dtypes = {"dist": "float32", "source": "bool",
                    "delta": "float32"}
    per_query_params = ("sources",)  # identity lives in state["source"]
    layout_specs = (("length", False, "min_plus"),)

    def __post_init__(self):
        if not self.sources:
            raise ValueError("sssp needs >= 1 source vertex")

    def _source_mask(self, n_cap: int) -> jax.Array:
        src = jnp.asarray(self.sources, jnp.int32)
        if int(src.min()) < 0:
            raise ValueError(f"source {int(src.min())} is negative")
        if int(src.max()) >= n_cap:
            raise ValueError(
                f"source {int(src.max())} >= node_capacity {n_cap}")
        return jnp.zeros((n_cap,), bool).at[src].set(True)

    def init_state(self, graph: GraphState) -> AlgoState:
        source = self._source_mask(graph.node_capacity)
        return {
            "dist": jnp.where(source, 0.0, jnp.inf).astype(jnp.float32),
            "source": source,
            "delta": jnp.zeros((graph.node_capacity,), jnp.float32),
        }

    def exact(self, state, graph, *, layouts=None, backend=None):
        dist, iters = _sssp(
            graph,
            state["source"],
            state["dist"] if self.warm_start else None,
            num_iters=self.num_iters,
            layout=layouts[0] if layouts else None,
            backend=backend,
        )
        return {"dist": dist, "source": state["source"],
                "delta": _finite_churn(dist, state["dist"])}, iters

    # build_summaries: the inherited default — one forward summary frozen
    # from result_view (= dist) over summary_weight/semiring declared above

    def summarized(self, state, graph, summaries, *, backend=None):
        (summary,) = summaries
        dist, iters = _summarized_sssp(
            summary, state["dist"], state["source"],
            num_iters=self.num_iters, backend=backend,
        )
        return {"dist": dist, "source": state["source"],
                "delta": _finite_churn(dist, state["dist"])}, iters

    def summarized_batched(self, batch_state, graph, summaries, *,
                           row_mask=None, backend=None):
        # one engine lane serves B different source sets: the pinned-0
        # masks ride in the batch state ([B, N]), not in `self`
        (summary,) = summaries
        dist, iters, changed = _summarized_sssp_batched(
            summary, batch_state["dist"], batch_state["source"],
            num_iters=self.num_iters, row_mask=row_mask, backend=backend,
        )
        return {"dist": dist, "source": batch_state["source"],
                "delta": _finite_churn(dist, batch_state["dist"])}, \
            iters, changed.astype(jnp.float32)

    def drift_residual(self, state, graph, *, layouts=None, backend=None):
        # how much one more full-graph relaxation would still lower the
        # distances (finite-churn encoded: a reachability flip counts 1.0)
        if layouts is None:
            return None
        dist = state["dist"]
        incoming = B.push(dist, layouts[0], semiring="min_plus",
                          backend=backend)
        relaxed = jnp.where(state["source"], 0.0,
                            jnp.minimum(dist, incoming))
        return _finite_churn(relaxed, dist)

    def batched_cold_seeds(self, batch_state):
        # distances are finite only on the set reachable from the sources
        return batch_state["source"]

    def result_view(self, state):
        return state["dist"]

    def selection_view(self, state):
        return state["delta"]


# ---------------------------------------------------------------------------
# Widest path — most-reliable paths (max_times)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class WidestPathAlgorithm(StreamingAlgorithm):
    """Streaming widest (most-reliable) paths on the ``max_times`` semiring.

    ``sources`` is a (hashable) tuple of vertex ids whose widths are pinned
    to 1; unreachable vertices hold 0.  Edge lengths act as multiplicative
    reliabilities/capacities and must be **non-negative** — streams that
    register edges with a ``weights`` column feed them into the
    ``weight="length"`` layout automatically; unit lengths make every
    reachable vertex width 1.  This is the seventh registry algorithm and
    the one exercising the masked-reduce *max* kernel path end to end
    (exact, summarized, and batched serving sweeps).

    EXACT actions recompute from the sources by default (correct under
    removals); ``warm_start=True`` relaxes from the previous widths, exact
    for addition-only streams (widths are monotone non-decreasing).
    """

    sources: Tuple[int, ...] = (0,)
    num_iters: int = 30
    warm_start: bool = False

    name = "widest-path"
    normalize_selection_scores = True
    drift_contraction = 0.0  # bottleneck relaxation settles in finite sweeps
    semiring = "max_times"
    summary_weight = "length"
    state_dtypes = {"width": "float32", "source": "bool",
                    "delta": "float32"}
    per_query_params = ("sources",)  # identity lives in state["source"]
    layout_specs = (("length", False, "max_times"),)

    def __post_init__(self):
        if not self.sources:
            raise ValueError("widest-path needs >= 1 source vertex")

    def _source_mask(self, n_cap: int) -> jax.Array:
        src = jnp.asarray(self.sources, jnp.int32)
        if int(src.min()) < 0:
            raise ValueError(f"source {int(src.min())} is negative")
        if int(src.max()) >= n_cap:
            raise ValueError(
                f"source {int(src.max())} >= node_capacity {n_cap}")
        return jnp.zeros((n_cap,), bool).at[src].set(True)

    def init_state(self, graph: GraphState) -> AlgoState:
        source = self._source_mask(graph.node_capacity)
        return {
            "width": jnp.where(source, 1.0, 0.0).astype(jnp.float32),
            "source": source,
            "delta": jnp.zeros((graph.node_capacity,), jnp.float32),
        }

    def exact(self, state, graph, *, layouts=None, backend=None):
        width, iters = _widest_path(
            graph,
            state["source"],
            state["width"] if self.warm_start else None,
            num_iters=self.num_iters,
            layout=layouts[0] if layouts else None,
            backend=backend,
        )
        return {"width": width, "source": state["source"],
                "delta": _finite_churn(width, state["width"])}, iters

    # build_summaries: the inherited default — one forward summary frozen
    # from result_view (= width) over summary_weight/semiring declared above

    def summarized(self, state, graph, summaries, *, backend=None):
        (summary,) = summaries
        width, iters = _summarized_widest_path(
            summary, state["width"], state["source"],
            num_iters=self.num_iters, backend=backend,
        )
        return {"width": width, "source": state["source"],
                "delta": _finite_churn(width, state["width"])}, iters

    def summarized_batched(self, batch_state, graph, summaries, *,
                           row_mask=None, backend=None):
        # one engine lane serves B different source sets: the pinned-1
        # masks ride in the batch state ([B, N]), not in `self`
        (summary,) = summaries
        width, iters, changed = _summarized_widest_path_batched(
            summary, batch_state["width"], batch_state["source"],
            num_iters=self.num_iters, row_mask=row_mask, backend=backend,
        )
        return {"width": width, "source": batch_state["source"],
                "delta": _finite_churn(width, batch_state["width"])}, \
            iters, changed.astype(jnp.float32)

    def drift_residual(self, state, graph, *, layouts=None, backend=None):
        # how much one more max_times relaxation would still widen paths
        if layouts is None:
            return None
        width = state["width"]
        incoming = B.push(width, layouts[0], semiring="max_times",
                          backend=backend)
        relaxed = jnp.where(state["source"], 1.0,
                            jnp.maximum(width, incoming))
        return jnp.abs(relaxed - width)

    def batched_cold_seeds(self, batch_state):
        # widths are nonzero only on the set reachable from the sources
        return batch_state["source"]

    def result_view(self, state):
        return state["width"]

    def selection_view(self, state):
        return state["delta"]


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Callable[..., StreamingAlgorithm]] = {}
#: alias -> canonical name.  Aliases resolve in :func:`make_algorithm` but
#: never show up in :func:`available_algorithms` (and thus in CLI choices
#: or benchmark artifact names), so one algorithm has one canonical spelling.
_ALIASES: Dict[str, str] = {}


def register_algorithm(
    name: str,
    factory: Callable[..., StreamingAlgorithm],
    *,
    aliases: Tuple[str, ...] = (),
) -> None:
    """Register an algorithm factory under ``name`` (overwrites allowed —
    latest registration wins, so users can shadow the built-ins)."""
    _REGISTRY[name] = factory
    for alias in aliases:
        _ALIASES[alias] = name


def available_algorithms() -> Tuple[str, ...]:
    """Canonical registered names (aliases resolve but are not listed)."""
    return tuple(sorted(_REGISTRY))


def algorithm_factory(name: str) -> Callable[..., StreamingAlgorithm]:
    """The registered factory for a name or alias, without instantiating —
    for callers that want to introspect an algorithm's knobs (e.g. its
    dataclass fields / signature) before constructing it."""
    try:
        return _REGISTRY[_ALIASES.get(name, name)]
    except KeyError:
        raise KeyError(
            f"unknown algorithm {name!r}; registered: "
            f"{', '.join(available_algorithms())}") from None


def factory_accepts(factory: Callable, knob: str) -> bool:
    """True if ``factory``'s signature takes ``knob`` — directly or via
    ``**kwargs`` (the documented registration pattern).  The single answer
    to "can this algorithm receive this keyword?", shared by the session
    builder's legacy-knob forwarding and example drivers."""
    import inspect

    try:
        params = inspect.signature(factory).parameters
    except (TypeError, ValueError):
        return False
    return knob in params or any(
        p.kind is inspect.Parameter.VAR_KEYWORD for p in params.values())


def make_algorithm(spec, **params) -> StreamingAlgorithm:
    """Resolve ``spec`` into a :class:`StreamingAlgorithm` instance.

    ``spec`` may be an instance (returned as-is; ``params`` must be empty),
    or a registry name/alias with factory kwargs, e.g.
    ``make_algorithm("personalized-pagerank", seeds=(3, 14))``.
    """
    if isinstance(spec, StreamingAlgorithm):
        if params:
            raise ValueError(
                "algorithm instance given — pass parameters to its "
                "constructor instead")
        return spec
    return algorithm_factory(spec)(**params)


register_algorithm("pagerank", PageRankAlgorithm)
register_algorithm("personalized-pagerank", PersonalizedPageRankAlgorithm,
                   aliases=("ppr",))
register_algorithm("hits", HITSAlgorithm)
register_algorithm("katz", KatzAlgorithm)
register_algorithm("connected-components", ConnectedComponentsAlgorithm,
                   aliases=("cc", "wcc"))
register_algorithm("sssp", SSSPAlgorithm,
                   aliases=("shortest-paths",))
register_algorithm("widest-path", WidestPathAlgorithm,
                   aliases=("most-reliable-path",))

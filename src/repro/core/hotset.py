"""Hot-vertex selection: K = K_r ∪ K_n ∪ K_Δ  (paper §3.2, Eqs. 2–5).

All three stages are expressed as dense masked edge sweeps (the TPU-native
form of the paper's vertex-centric BFS): a frontier expansion is one
scatter-or along the edge list, so K_n costs n sweeps and K_Δ costs at most
``delta_hop_cap`` sweeps.  Selection runs once per query and is O(E) with
tiny constants; the savings come from the power iterations afterwards
running only on the compacted hot subgraph.

Faithfulness notes
------------------
- Eq. 2 uses the vertex degree d_t(u) = |N_t(u)| (out-neighbors); new
  vertices (no previous degree) are always included (paper footnote 2).
- Eq. 3 expands along directed edges u→v from K_r, n hops.
- Eqs. 4–5: candidates v beyond K_r ∪ K_n are included while their hop
  distance from K_n stays below f_Δ(v) = log(n + d̄·v_s/(Δ·d_t(v))) / log d̄.
  f_Δ is clamped to [0, delta_hop_cap]; d̄ is the average degree over the
  currently active vertices.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.graph.graph import GraphState


class HotSetParams(NamedTuple):
    """The paper's hot-set model knobs (r, n, Δ) bundled as a pytree —
    r and Δ are runtime scalars, n is a static hop count."""

    r: jax.Array       # update-ratio threshold (f32 scalar)
    n: int             # neighborhood diameter (static: 0, 1, 2, …)
    delta: jax.Array   # Δ score-dilution bound (f32 scalar)


class HotSetStats(NamedTuple):
    """Device-side sizes of the three selection stages (K_r, K_n, K_Δ)
    and their union |K| — one host transfer per query."""

    num_kr: jax.Array
    num_kn: jax.Array
    num_kdelta: jax.Array
    num_hot: jax.Array


def _frontier_sweep(state: GraphState, mark: jax.Array, *, both: bool) -> jax.Array:
    """One BFS sweep: returns mask of vertices reachable in <=1 hop from mark."""
    mask = state.edge_mask()
    hit_src = mask & mark[state.src]
    reach = jnp.zeros_like(mark).at[state.dst].max(hit_src)
    if both:
        hit_dst = mask & mark[state.dst]
        reach = reach.at[state.src].max(hit_dst)
    return mark | reach


@functools.partial(
    jax.jit,
    static_argnames=("n", "delta_hop_cap", "degree_mode", "expand_both",
                     "normalize_scores"),
)
def select_hot_set(
    state: GraphState,
    deg_prev: jax.Array,
    ranks_prev: jax.Array,
    r: jax.Array,
    delta: jax.Array,
    *,
    active_prev: Optional[jax.Array] = None,
    n: int = 1,
    delta_hop_cap: int = 4,
    degree_mode: str = "out",
    expand_both: bool = False,
    normalize_scores: bool = False,
) -> Tuple[jax.Array, HotSetStats]:
    """Compute the hot-vertex mask K over the current graph.

    ``deg_prev`` is the degree snapshot taken at the previous measurement
    point t-1 (same ``degree_mode``); ``active_prev`` the activity snapshot
    (a vertex first seen after t-1 has no previous rank and is always in K_r
    — paper footnote 2).  Without ``active_prev``, deg_prev>0 is the proxy
    (wrong for pre-existing sinks under degree_mode="out").

    ``normalize_scores`` rescales v_s to mean 1 over the active set before
    the Δ-dilution bound.  Eqs. 4-5 calibrate Δ against Gelly-style
    PageRank, whose scores average ≈ 1 per vertex; algorithms with
    L1-normalized score vectors (personalized PageRank, HITS) opt in so the
    same Δ values keep the paper's semantics.  Off by default — the raw
    paper formula.

    Returns (bool[N_cap] mask, stats).
    """
    if degree_mode == "out":
        deg_now = state.out_deg
    elif degree_mode == "in":
        deg_now = state.in_deg
    elif degree_mode == "total":
        deg_now = state.out_deg + state.in_deg
    else:
        raise ValueError(f"degree_mode={degree_mode}")

    active = state.node_active
    deg_now_f = deg_now.astype(jnp.float32)
    deg_prev_f = deg_prev.astype(jnp.float32)

    # ---- Eq. 2: K_r ------------------------------------------------------
    if active_prev is None:
        was_seen = deg_prev > 0
    else:
        was_seen = active_prev
    is_new = active & ~was_seen
    # Zero-prior-degree audit: the paper's relative-degree-change test
    # divides by deg_prev, which is 0 for brand-new vertices and for
    # pre-existing zero-degree ones (sinks under out-degree mode).  Both
    # paths are deterministic and division-free here:
    #  - brand-new vertices (active now, unseen before) are unconditionally
    #    hot via `is_new`, regardless of r — a vertex with no prior result
    #    has nothing valid to freeze;
    #  - the ratio clamps its denominator to >= 1, so it is always finite
    #    (never NaN/inf) and only *consulted* where deg_prev > 0 — the
    #    deg_prev == 0 branch of `changed` triggers purely on gaining
    #    degree, at any r including r = inf.
    ratio = jnp.abs(deg_now_f / jnp.maximum(deg_prev_f, 1.0) - 1.0)
    changed = jnp.where(deg_prev > 0, ratio > r, deg_now > 0)
    k_r = active & (is_new | (was_seen & changed))

    # ---- Eq. 3: K_n — n-hop directed expansion around K_r -----------------
    k_rn = k_r
    for _ in range(n):
        k_rn = _frontier_sweep(state, k_rn, both=expand_both)
    k_n_only = k_rn & ~k_r

    # ---- Eqs. 4-5: K_Δ — score-dilution-bounded expansion -----------------
    # f_Δ(v) = log(n + d̄·v_s / (Δ·d_t(v))) / log(d̄), clamped to >= 0.
    n_active = jnp.maximum(state.num_active_nodes().astype(jnp.float32), 1.0)
    total_deg = jnp.sum(jnp.where(active, deg_now_f, 0.0))
    d_bar = jnp.maximum(total_deg / n_active, 1.0 + 1e-6)
    v_s = jnp.maximum(ranks_prev, 0.0)
    if normalize_scores:
        total_score = jnp.sum(jnp.where(active, v_s, 0.0))
        v_s = v_s * (n_active / jnp.maximum(total_score, 1e-30))
    arg = n + d_bar * v_s / (jnp.maximum(delta, 1e-9) * jnp.maximum(deg_now_f, 1.0))
    f_delta = jnp.log(jnp.maximum(arg, 1e-9)) / jnp.log(d_bar)
    f_delta = jnp.clip(f_delta, 0.0, float(delta_hop_cap))

    # hop-distance relaxation from K_r ∪ K_n, capped at delta_hop_cap sweeps;
    # a candidate v joins when its distance h satisfies h <= f_Δ(v).  The
    # loop exits early once a sweep adds nothing (typical after 1-2 hops),
    # saving O(E) passes per query.
    def delta_body(carry):
        h, k_delta, frontier, _ = carry
        nxt = _frontier_sweep(state, frontier, both=expand_both) & ~frontier
        joined = nxt & (f_delta >= h.astype(jnp.float32)) & ~k_rn & ~k_delta
        grew = jnp.any(joined)
        # expansion continues only through vertices that actually joined
        return h + 1, k_delta | joined, frontier | joined, grew

    def delta_cond(carry):
        h, _, _, grew = carry
        return (h <= delta_hop_cap) & grew

    _, k_delta, _, _ = jax.lax.while_loop(
        delta_cond,
        delta_body,
        (jnp.int32(1), jnp.zeros_like(k_rn), k_rn, jnp.bool_(True)),
    )

    hot = (k_r | k_rn | k_delta) & active
    stats = HotSetStats(
        num_kr=jnp.sum(k_r.astype(jnp.int32)),
        num_kn=jnp.sum(k_n_only.astype(jnp.int32)),
        num_kdelta=jnp.sum(k_delta.astype(jnp.int32)),
        num_hot=jnp.sum(hot.astype(jnp.int32)),
    )
    return hot, stats

"""VeilGraph execution engine — the paper's Alg. 1 as a Python/JAX hybrid.

The engine is the orchestration layer: it ingests stream messages
(RegisterAddEdge / RegisterRemoveEdge / Query), buffers updates until a query
arrives, and serves each query through the five-UDF structure:

    OnStart -> [BeforeUpdates -> ApplyUpdates -> OnQuery ->
                {repeat-last | approximate | exact} -> OnQueryResult]* -> OnStop

Heavy computation (update application, hot-set selection, summary
construction, power iterations) is jitted with static capacities; the UDFs
are host callbacks so users can express arbitrary policies, exactly as the
paper's API intends.
"""

from __future__ import annotations

import enum
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pagerank import pagerank as _pagerank
from repro.core.pagerank import build_summary as _build_summary
from repro.core.pagerank import summarized_pagerank as _summarized_pagerank
from repro.graph import graph as G
from repro.core.hotset import select_hot_set


class Action(enum.Enum):
    REPEAT_LAST = "repeat-last-answer"
    APPROXIMATE = "compute-approximate"
    EXACT = "compute-exact"


@dataclass
class EngineConfig:
    node_capacity: int
    edge_capacity: int
    hot_node_capacity: int
    hot_edge_capacity: int
    # PageRank
    beta: float = 0.85
    num_iters: int = 30
    tol: float = 0.0
    # hot-set parameters (r, n, Δ) — the paper's model knobs
    r: float = 0.2
    n: int = 1
    delta: float = 0.1
    delta_hop_cap: int = 4
    degree_mode: str = "out"
    expand_both: bool = False
    # update chunks are padded to a multiple of this to bound recompiles
    update_pad: int = 1024
    # fused=True runs selection+summary+iteration as a single XLA program
    # (overflow fallback handled on-device via lax.cond)
    fused: bool = True


@dataclass
class QueryStats:
    query_id: int
    action: str
    wall_time_s: float
    num_nodes: int
    num_edges: int
    num_hot: int = 0
    num_kr: int = 0
    num_kn: int = 0
    num_kdelta: int = 0
    num_ek: int = 0
    num_eb: int = 0
    iterations: int = 0
    overflow_fallback: bool = False
    pending_applied: int = 0

    @property
    def vertex_ratio(self) -> float:
        return self.num_hot / max(self.num_nodes, 1)

    @property
    def edge_ratio(self) -> float:
        # summary graph edges = E_K ∪ E_B, as a fraction of |E| (paper Figs 4/8/…)
        return (self.num_ek + self.num_eb) / max(self.num_edges, 1)


# Default UDFs ---------------------------------------------------------------


def default_before_updates(pending: int, stats: Dict) -> bool:
    return True


def default_on_query(query_id: int, view: Dict) -> Action:
    return Action.APPROXIMATE


class VeilGraphEngine:
    """Streaming approximate graph-processing engine (PageRank case study)."""

    def __init__(
        self,
        config: EngineConfig,
        *,
        on_start: Optional[Callable] = None,
        before_updates: Callable[[int, Dict], bool] = default_before_updates,
        on_query: Callable[[int, Dict], Action] = default_on_query,
        on_query_result: Optional[Callable] = None,
        on_stop: Optional[Callable] = None,
    ):
        self.config = config
        self._on_start = on_start
        self._before_updates = before_updates
        self._on_query = on_query
        self._on_query_result = on_query_result
        self._on_stop = on_stop

        self.state = G.empty(config.node_capacity, config.edge_capacity)
        self.ranks = jnp.zeros((config.node_capacity,), jnp.float32)
        self.deg_prev = jnp.zeros((config.node_capacity,), jnp.int32)
        self.active_prev = jnp.zeros((config.node_capacity,), bool)
        self._pending_src: List[np.ndarray] = []
        self._pending_dst: List[np.ndarray] = []
        self._pending_removals: List = []
        self._pending_count = 0
        self.stats_log: List[QueryStats] = []
        self._query_id = 0
        self._started = False

    # ---- lifecycle -------------------------------------------------------
    def start(self, init_src: np.ndarray, init_dst: np.ndarray) -> QueryStats:
        """OnStart + load the initial graph G and compute the initial exact
        PageRank (the paper's protocol: results already exist for G)."""
        if self._on_start:
            self._on_start(self)
        self.state = G.from_edges(
            init_src, init_dst, self.config.node_capacity, self.config.edge_capacity
        )
        t0 = time.perf_counter()
        self.ranks, iters = _pagerank(
            self.state,
            beta=self.config.beta,
            num_iters=self.config.num_iters,
            tol=self.config.tol,
        )
        self.ranks.block_until_ready()
        wall = time.perf_counter() - t0
        self.deg_prev = self._degree_snapshot()
        self.active_prev = jnp.copy(self.state.node_active)
        self._started = True
        st = QueryStats(
            query_id=-1,
            action="initial-exact",
            wall_time_s=wall,
            num_nodes=int(self.state.num_active_nodes()),
            num_edges=int(self.state.num_live_edges()),
            iterations=int(iters),
        )
        self.stats_log.append(st)
        return st

    def stop(self):
        if self._on_stop:
            self._on_stop(self)

    # ---- stream ingestion --------------------------------------------------
    def register_add_edges(self, src: np.ndarray, dst: np.ndarray):
        src = np.asarray(src, np.int32)
        dst = np.asarray(dst, np.int32)
        self._pending_src.append(src)
        self._pending_dst.append(dst)
        self._pending_count += src.shape[0]

    def register_remove_edges(self, src: np.ndarray, dst: np.ndarray):
        """Alg. 1 RegisterRemoveEdge (the paper evaluates e+ only and leaves
        removals to future work; the engine supports them end-to-end).
        Removals are buffered and resolved to buffer slots at apply time."""
        self._pending_removals.append(
            (np.asarray(src, np.int32), np.asarray(dst, np.int32)))
        self._pending_count += len(src)

    @property
    def pending_updates(self) -> int:
        return self._pending_count

    # ---- internals -----------------------------------------------------------
    def _degree_snapshot(self) -> jax.Array:
        # NOTE: must copy — add_edges donates the state buffers, so an alias
        # into the old state would be deleted by the next update.
        if self.config.degree_mode == "out":
            return jnp.copy(self.state.out_deg)
        if self.config.degree_mode == "in":
            return jnp.copy(self.state.in_deg)
        return self.state.out_deg + self.state.in_deg

    def _apply_pending(self) -> int:
        if not self._pending_count:
            return 0
        applied_removals = 0
        if self._pending_removals:
            r_src = np.concatenate([a for a, _ in self._pending_removals])
            r_dst = np.concatenate([b for _, b in self._pending_removals])
            slots = G.find_edge_slots(self.state, r_src, r_dst)
            self.state = G.remove_edges_by_slot(self.state, jnp.asarray(slots))
            applied_removals = int((slots >= 0).sum())
            self._pending_removals.clear()
        if not self._pending_src:
            self._pending_count = 0
            return applied_removals
        src = np.concatenate(self._pending_src)
        dst = np.concatenate(self._pending_dst)
        pad = self.config.update_pad
        k = src.shape[0]
        padded = ((k + pad - 1) // pad) * pad
        # pad with a self-referencing no-op edge on node 0? No — pad slots
        # must not change degrees; we pad by *repeating* the last edge and
        # masking via a length argument is not possible in add_edges, so we
        # simply split into pad-sized exact chunks plus one remainder chunk
        # whose shape recompiles at most `update_pad` distinct sizes.
        applied = applied_removals
        for lo in range(0, k, pad):
            hi = min(lo + pad, k)
            self.state = G.add_edges(
                self.state, jnp.asarray(src[lo:hi]), jnp.asarray(dst[lo:hi])
            )
            applied += hi - lo
        self._pending_src.clear()
        self._pending_dst.clear()
        self._pending_count = 0
        return applied

    # ---- query serving ---------------------------------------------------
    def query(self, msg: Optional[Dict] = None) -> Tuple[np.ndarray, QueryStats]:
        """Serve one query (Alg. 1 lines 6-21). Returns (ranks, stats)."""
        assert self._started, "call start() first"
        qid = self._query_id
        self._query_id += 1
        cfg = self.config

        stats_view = {
            "pending": self._pending_count,
            "num_nodes": int(self.state.num_active_nodes()),
            "num_edges": int(self.state.num_live_edges()),
        }
        applied = 0
        if self._before_updates(self._pending_count, stats_view):
            applied = self._apply_pending()

        action = self._on_query(qid, stats_view)
        t0 = time.perf_counter()
        st = QueryStats(
            query_id=qid,
            action=action.value,
            wall_time_s=0.0,
            num_nodes=int(self.state.num_active_nodes()),
            num_edges=int(self.state.num_live_edges()),
            pending_applied=applied,
        )

        if action == Action.REPEAT_LAST:
            pass  # previous ranks returned as-is
        elif action == Action.EXACT:
            self.ranks, iters = _pagerank(
                self.state, beta=cfg.beta, num_iters=cfg.num_iters, tol=cfg.tol
            )
            self.ranks.block_until_ready()
            st.iterations = int(iters)
            self.deg_prev = self._degree_snapshot()
        elif cfg.fused:  # APPROXIMATE, single fused XLA program
            from repro.core.fused import approximate_query_step

            self.ranks, qs = approximate_query_step(
                self.state,
                self.ranks,
                self.deg_prev,
                self.active_prev,
                jnp.float32(cfg.r),
                jnp.float32(cfg.delta),
                hot_node_capacity=cfg.hot_node_capacity,
                hot_edge_capacity=cfg.hot_edge_capacity,
                beta=cfg.beta,
                num_iters=cfg.num_iters,
                tol=cfg.tol,
                n=cfg.n,
                delta_hop_cap=cfg.delta_hop_cap,
                degree_mode=cfg.degree_mode,
                expand_both=cfg.expand_both,
            )
            if bool(qs.used_fallback):
                # capacities exceeded: the summarized result is invalid;
                # recompute exactly (graceful degradation, recorded below)
                self.ranks, iters_fb = _pagerank(
                    self.state, beta=cfg.beta, num_iters=cfg.num_iters,
                    tol=cfg.tol,
                )
                qs = qs._replace(iterations=iters_fb)
            self.ranks.block_until_ready()
            qs = jax.device_get(qs)  # one host transfer for all stats
            st.num_hot = int(qs.num_hot)
            st.num_kr = int(qs.num_kr)
            st.num_kn = int(qs.num_kn)
            st.num_kdelta = int(qs.num_kdelta)
            st.num_ek = int(qs.num_ek)
            st.num_eb = int(qs.num_eb)
            st.iterations = int(qs.iterations)
            st.overflow_fallback = bool(qs.used_fallback)
            self.deg_prev = self._degree_snapshot()
            self.active_prev = jnp.copy(self.state.node_active)
        else:  # APPROXIMATE — unfused reference path
            hot, hstats = select_hot_set(
                self.state,
                self.deg_prev,
                self.ranks,
                jnp.float32(cfg.r),
                jnp.float32(cfg.delta),
                active_prev=self.active_prev,
                n=cfg.n,
                delta_hop_cap=cfg.delta_hop_cap,
                degree_mode=cfg.degree_mode,
                expand_both=cfg.expand_both,
            )
            summary = _build_summary(
                self.state,
                self.ranks,
                hot,
                hot_node_capacity=cfg.hot_node_capacity,
                hot_edge_capacity=cfg.hot_edge_capacity,
            )
            st.num_hot = int(hstats.num_hot)
            st.num_kr = int(hstats.num_kr)
            st.num_kn = int(hstats.num_kn)
            st.num_kdelta = int(hstats.num_kdelta)
            st.num_ek = int(summary.num_ek)
            st.num_eb = int(summary.num_eb)
            if bool(summary.overflow):
                # graceful degradation: capacities exceeded -> exact recompute
                st.overflow_fallback = True
                self.ranks, iters = _pagerank(
                    self.state, beta=cfg.beta, num_iters=cfg.num_iters, tol=cfg.tol
                )
                st.iterations = int(iters)
            else:
                self.ranks, iters = _summarized_pagerank(
                    summary,
                    self.ranks,
                    beta=cfg.beta,
                    num_iters=cfg.num_iters,
                    tol=cfg.tol,
                )
                st.iterations = int(iters)
            self.ranks.block_until_ready()
            self.deg_prev = self._degree_snapshot()

        st.wall_time_s = time.perf_counter() - t0
        self.stats_log.append(st)
        if self._on_query_result:
            self._on_query_result(qid, msg, action, self.ranks, st)
        return np.asarray(jax.device_get(self.ranks)), st

"""VeilGraph execution engine — the paper's Alg. 1 as a Python/JAX hybrid.

The engine is the orchestration layer: it ingests stream messages
(RegisterAddEdge / RegisterRemoveEdge / Query), buffers updates until a query
arrives, and serves each query through the five-UDF structure:

    OnStart -> [BeforeUpdates -> ApplyUpdates -> OnQuery ->
                {repeat-last | approximate | exact} -> OnQueryResult]* -> OnStop

The engine is **algorithm-generic**: everything rank-computation-specific
lives behind the :class:`~repro.core.algorithm.StreamingAlgorithm` plugin
(PageRank is just the default).  The engine owns the graph state, the update
buffers, the hot-set selection snapshots (previous degrees/activity) and the
UDF policy loop; the algorithm owns its per-vertex state pytree (ranks,
hub/authority vectors, teleport vectors, …) and its exact / summarized
kernels.

Heavy computation (update application, hot-set selection, summary
construction, power iterations) is jitted with static capacities; the UDFs
are host callbacks so users can express arbitrary policies, exactly as the
paper's API intends.

Prefer the session front door :func:`repro.api.session` for new code; the
``VeilGraphEngine(cfg, on_query=...)`` constructor (algorithm omitted)
remains supported as the legacy PageRank-only signature — the PageRank knobs
on :class:`EngineConfig` (``beta``/``num_iters``/``tol``) configure the
default algorithm in that case.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import backend as B
from repro.core.algorithm import (Action, AlgoState, PageRankAlgorithm,
                                  StreamingAlgorithm, make_algorithm,
                                  summaries_overflow)
from repro.core.hotset import select_hot_set
from repro.graph import graph as G


@dataclass
class EngineConfig:
    """Static engine configuration: buffer capacities, the paper's hot-set
    model knobs (r, n, Δ), and execution selection (backend, mesh,
    sharding, rebalancing).  Capacities are jit-static — changing them
    recompiles; everything the engine can vary per query is runtime state
    instead.  Field groups are commented inline below."""

    node_capacity: int
    edge_capacity: int
    hot_node_capacity: int
    hot_edge_capacity: int
    # legacy PageRank knobs — configure the default algorithm when none is
    # passed to the engine (kept for the old constructor signature; plugin
    # algorithms carry their own numeric knobs)
    beta: float = 0.85
    num_iters: int = 30
    tol: float = 0.0
    # hot-set parameters (r, n, Δ) — the paper's model knobs
    r: float = 0.2
    n: int = 1
    delta: float = 0.1
    delta_hop_cap: int = 4
    degree_mode: str = "out"
    expand_both: bool = False
    # update chunks are padded to a multiple of this to bound recompiles
    update_pad: int = 1024
    # fused=True runs selection+summary+iteration as a single XLA program
    # (overflow fallback handled on host after a one-flag device read)
    fused: bool = True
    # propagation backend for every sweep: "pallas" (destination-tiled MXU
    # kernel; interpret mode off-TPU), "segment_sum" (sorted XLA fallback),
    # or "auto" (per device, overridable via $VEILGRAPH_BACKEND) — see
    # repro.core.backend
    backend: str = "auto"
    # per-shape kernel-geometry autotuning for the pallas push: "off" keeps
    # the TILE_N/CHUNK defaults; "cached" picks the analytic cost-model
    # argmin (or a cached/JSON-loaded tuning — deterministic, CI-safe);
    # "full" additionally times the top model-ranked candidates on synthetic
    # streams and caches the winner.  Tunings are keyed per shape and reused
    # across layout rebuilds; engine.autotune_runs counts timed searches.
    # See repro.kernels.spmv.autotune.
    autotune: str = "off"
    # storage dtype for baked edge weights ("bfloat16"/"float16"): halves
    # the weight column of the edge-stream HBM traffic.  Accumulation stays
    # f32 (jnp type promotion inside the semiring combine).  Only applied to
    # float32 semirings — integer-algebra layouts (e.g. min_min labels)
    # keep their native dtype.  None = no compression.
    weight_dtype: Optional[str] = None
    # device mesh for sharded execution: edge layouts are cut into one
    # locally-sorted shard per device over `mesh_axes` (default: every mesh
    # axis) and every O(E) sweep runs as a shard_map partial push + semiring
    # all-reduce; None = single-layout execution.  See
    # repro.graph.partition.build_sharded_layout
    mesh: Optional["jax.sharding.Mesh"] = None
    mesh_axes: Optional[Tuple[str, ...]] = None
    # shard count for the mesh layouts: None = one shard per device on
    # `mesh_axes`; a multiple of the device count runs surplus shards as a
    # per-device loop (how a 1-device dev box exercises S-way partitioning
    # and rebalancing)
    num_shards: Optional[int] = None
    # per-(shard, bucket) hot-edge slot cap for the mesh-sharded summary
    # construction: None keeps the conservative default C = ceil(H_cap/S)
    # (per-device E_K footprint S*C grows with H_cap even when hot edges
    # are well spread); a tighter cap shrinks the footprint to
    # S * shard_hot_edge_capacity and relies on the overflow flag (-> exact
    # fallback) for the rare skewed batch.  See
    # repro.core.pagerank._build_summary_sharded.
    shard_hot_edge_capacity: Optional[int] = None
    # shard-rebalancing trigger (mesh engines only): after each applied
    # update batch the engine measures per-shard live-edge imbalance
    # ((max - min) / mean, see repro.graph.partition.shard_imbalance) and
    # recuts the slot partition when it exceeds this threshold — streaming
    # appends land at the high-water mark, so the contiguous cut fills
    # tail-heavy without it.  None disables rebalancing (the pre-rebalance
    # contiguous-cut behaviour); rebalances are counted in
    # `engine.rebalances`.
    rebalance_threshold: Optional[float] = 1.0
    # closed-loop quality control (repro.core.control): an accuracy SLO in
    # (0, 1) — e.g. 0.95 — that replaces open-loop r/Δ tuning.  The fused
    # query step additionally computes an on-device drift estimate (the
    # algorithm's fixed-point residual sampled on `drift_probes` fixed
    # vertices + the residual mass frozen outside K), and a host-side
    # QualityController steers the *effective* r/Δ and requests exact
    # refreshes to keep the estimated error inside 1 - quality_target.
    # control_r/control_delta=False pin a knob at its configured value
    # (knob precedence: an explicitly passed r/delta wins — repro.api
    # clears the matching control_* flag).  None = open loop (no drift
    # computation, no controller).  Requires fused=True and an algorithm
    # with supports_fused.
    quality_target: Optional[float] = None
    control_r: bool = True
    control_delta: bool = True
    drift_probes: int = 64
    # epoch-versioned async rebuild (repro.core.epoch): queries serve an
    # immutable EpochSnapshot N while snapshot N+1's update application,
    # layout sorts and rebalance probe are dispatched-but-not-awaited —
    # JAX's async dispatch overlaps the rebuild with the host loop because
    # the engine stops forcing results between apply and query.  Epochs
    # promote at query/wave boundaries only (snapshot_lag ∈ {0, 1});
    # QueryStats/ServeStats grow epoch/snapshot_lag columns.  Requires
    # fused=True and a supports_fused algorithm (like quality_target).
    async_rebuild: bool = False


@dataclass
class QueryStats:
    """One row of engine observability per served query: the action taken,
    wall time, graph/hot-set/summary sizes (the paper's model statistics —
    ``vertex_ratio``/``edge_ratio`` are Figs. 4/8's axes), update
    accounting, and the overflow/rebalance flags."""

    query_id: int
    action: str
    wall_time_s: float
    num_nodes: int
    num_edges: int
    num_hot: int = 0
    num_kr: int = 0
    num_kn: int = 0
    num_kdelta: int = 0
    num_ek: int = 0
    num_eb: int = 0
    iterations: int = 0
    overflow_fallback: bool = False
    # updates integrated by this query: pending_applied = edge additions +
    # *resolved* removals (a buffered removal that matches no live edge slot
    # is counted in removals_requested but not here)
    pending_applied: int = 0
    removals_requested: int = 0
    removals_resolved: int = 0
    # True when this query's applied updates pushed per-shard live-edge
    # imbalance past the threshold and the edge partition was recut
    rebalanced: bool = False
    algorithm: str = "pagerank"
    # closed-loop quality columns (quality_target engines only): the drift
    # estimate this query observed, the controller's error-based quality
    # estimate, the effective knobs it chose, and whether it forced an
    # exact refresh to stay inside the SLO
    drift: float = 0.0
    quality_est: float = 1.0
    r_eff: float = 0.0
    delta_eff: float = 0.0
    refreshed: bool = False
    # async-pipeline staleness columns (async_rebuild engines; sync engines
    # keep the zeros): the epoch this query was served from, and how many
    # epochs the served snapshot trailed the newest dispatched build when
    # the answer was computed (0 = caught up; never exceeds 1)
    epoch: int = 0
    snapshot_lag: int = 0

    @property
    def vertex_ratio(self) -> float:
        return self.num_hot / max(self.num_nodes, 1)

    @property
    def edge_ratio(self) -> float:
        # summary graph edges = E_K ∪ E_B, as a fraction of |E| (paper Figs 4/8/…)
        return (self.num_ek + self.num_eb) / max(self.num_edges, 1)


# Default UDFs ---------------------------------------------------------------


def default_before_updates(pending: int, stats: Dict) -> bool:
    """Default BeforeUpdates UDF: always integrate pending updates."""
    return True


def default_on_query(query_id: int, view: Dict) -> Action:
    """Default OnQuery UDF: always take the summarized fast path."""
    return Action.APPROXIMATE


class VeilGraphEngine:
    """Streaming approximate graph-processing engine.

    ``algorithm`` is a :class:`StreamingAlgorithm` instance or registry name
    (``"pagerank"``, ``"personalized-pagerank"``, ``"hits"``, …).  Omitted,
    the engine runs PageRank configured from the legacy ``EngineConfig``
    knobs — the paper's case study and the pre-plugin constructor signature.
    """

    def __init__(
        self,
        config: EngineConfig,
        algorithm: Union[StreamingAlgorithm, str, None] = None,
        *,
        on_start: Optional[Callable] = None,
        before_updates: Callable[[int, Dict], bool] = default_before_updates,
        on_query: Callable[[int, Dict], Action] = default_on_query,
        on_query_result: Optional[Callable] = None,
        on_stop: Optional[Callable] = None,
    ):
        self.config = config
        if config.mesh is None and config.num_shards is not None:
            # the field is only consumed by the mesh layout/rebalance path;
            # accepting it meshless would silently run unsharded
            raise ValueError(
                "EngineConfig.num_shards requires mesh= (sharding and "
                "rebalancing are mesh-engine features; a 1-device box can "
                "pass a 1-device mesh with num_shards=S)")
        self.backend = B.resolve_backend(config.backend)
        if algorithm is None:
            # legacy shim: PageRank from the config's scalar knobs
            algorithm = PageRankAlgorithm(
                beta=config.beta, num_iters=config.num_iters, tol=config.tol
            )
        self.algorithm = make_algorithm(algorithm)
        self._on_start = on_start
        self._before_updates = before_updates
        self._on_query = on_query
        self._on_query_result = on_query_result
        self._on_stop = on_stop

        self.state = G.empty(config.node_capacity, config.edge_capacity)
        self.algo_state: AlgoState = self._init_algo_state()
        # amortized edge-layout cache: sorted once per applied update batch,
        # reused across queries and by every sweep in between
        self._edge_layouts: Optional[Tuple[B.EdgeLayout, ...]] = None
        self.layout_builds = 0  # observability: how many sorts actually ran
        # batch width hint for autotune keys: 1 for single-query engines;
        # the serving engine sets this to its slot count so batched sweeps
        # tune for the [B, chunk] @ [chunk, tile_n] shape they actually run
        self.autotune_batch_hint = 1
        # shard-rebalancing state (mesh engines): the current slot→shard
        # assignment (None = the contiguous cut), how many recuts have
        # happened, and the last measured imbalance
        self._shard_slots = None
        self.rebalances = 0
        self.last_imbalance = 0.0
        self.deg_prev = jnp.zeros((config.node_capacity,), jnp.int32)
        self.active_prev = jnp.zeros((config.node_capacity,), bool)
        self._pending_src: List[np.ndarray] = []
        self._pending_dst: List[np.ndarray] = []
        self._pending_len: List[Optional[np.ndarray]] = []
        self._pending_removals: List = []
        self._pending_count = 0
        self._pending_removal_count = 0
        # closed-loop quality control: host-side SLO controller + fixed
        # on-device probe set (built once; rides the fused step under
        # with_drift=True at zero extra host syncs)
        self.controller = None
        self._probe_ids = None
        if config.quality_target is not None:
            from repro.core.control import (QualityController,
                                            default_probe_ids)

            if not (config.fused and self.algorithm.supports_fused):
                raise ValueError(
                    "quality_target requires the fused query path "
                    f"(fused=True and a supports_fused algorithm; got "
                    f"fused={config.fused}, "
                    f"algorithm={self.algorithm.name!r})")
            self.controller = QualityController(
                config.quality_target,
                r0=config.r, delta0=config.delta,
                adjust_r=config.control_r,
                adjust_delta=config.control_delta,
                contraction=self.algorithm.drift_contraction,
            )
            self._probe_ids = default_probe_ids(
                config.node_capacity, config.drift_probes)
        # epoch-versioned async rebuild (repro.core.epoch): the pipeline
        # holds the served snapshot + the in-flight build; _async_specs is
        # the ordered set of normalized layout specs every new snapshot
        # eagerly dispatches (seeded from the algorithm, extended by the
        # serving engine's per-lane algorithms)
        self._pipeline = None
        self._async_specs: Dict = {}
        if config.async_rebuild:
            if not (config.fused and self.algorithm.supports_fused):
                raise ValueError(
                    "async_rebuild requires the fused query path "
                    f"(fused=True and a supports_fused algorithm; got "
                    f"fused={config.fused}, "
                    f"algorithm={self.algorithm.name!r})")
            for spec in map(B.normalize_layout_spec,
                            self.algorithm.layout_specs):
                self._async_specs[spec] = True
        # updates integrated while serving repeat-last answers — lets
        # policies threshold on staleness, not just the current batch
        self._stale_updates = 0
        self.stats_log: List[QueryStats] = []
        self._query_id = 0
        self._started = False

    @property
    def ranks(self) -> jax.Array:
        """The algorithm's result vector (legacy alias: PageRank's ranks).
        Any dtype — f32 scores, f32 distances, int32 component labels."""
        return self.algorithm.result_view(self.algo_state)

    def _init_algo_state(self) -> AlgoState:
        """init_state + one-time validation against the algorithm's
        declared ``state_dtypes`` (so e.g. an int32 label vector can't
        silently decay to float inside a custom plugin)."""
        state = self.algorithm.init_state(self.state)
        for key, want in self.algorithm.state_dtypes.items():
            if key not in state:
                raise ValueError(
                    f"{self.algorithm.name}.init_state missing declared "
                    f"state key {key!r}")
            got = jnp.asarray(state[key]).dtype
            if got != jnp.dtype(want):
                raise ValueError(
                    f"{self.algorithm.name} state {key!r} declared "
                    f"{want} but init_state produced {got}")
        return state

    # ---- lifecycle -------------------------------------------------------
    def start(self, init_src: np.ndarray, init_dst: np.ndarray) -> QueryStats:
        """OnStart + load the initial graph G and compute the initial exact
        result (the paper's protocol: results already exist for G)."""
        if self._on_start:
            self._on_start(self)
        self.state = G.from_edges(
            init_src, init_dst, self.config.node_capacity, self.config.edge_capacity
        )
        self._invalidate_layouts()
        self.algo_state = self._init_algo_state()
        t0 = time.perf_counter()
        self.algo_state, iters = self.algorithm.exact(
            self.algo_state, self.state,
            layouts=self.edge_layouts(), backend=self.backend)
        self.ranks.block_until_ready()
        wall = time.perf_counter() - t0
        self.deg_prev = self._degree_snapshot()
        self.active_prev = jnp.copy(self.state.node_active)
        if self.config.async_rebuild:
            from repro.core.epoch import AsyncRebuildPipeline

            # epoch 0 = the initial graph; its layouts were just built for
            # the exact pass, so _make_snapshot seeds them without
            # re-sorting.  Epoch 0 is never promoted, so fetch its count
            # vector here (start() is a host boundary anyway).
            snap0 = self._make_snapshot(0)
            self._finalize_promotion(snap0)
            self._pipeline = AsyncRebuildPipeline(snap0)
        self._started = True
        st = QueryStats(
            query_id=-1,
            action="initial-exact",
            wall_time_s=wall,
            num_nodes=int(self.state.num_active_nodes()),
            num_edges=int(self.state.num_live_edges()),
            iterations=int(iters),
            algorithm=self.algorithm.name,
        )
        self.stats_log.append(st)
        return st

    def stop(self):
        """OnStop: fire the shutdown UDF (no device state is torn down)."""
        if self._on_stop:
            self._on_stop(self)

    # ---- stream ingestion --------------------------------------------------
    @staticmethod
    def _check_shapes(src: np.ndarray, dst: np.ndarray):
        # mismatched shapes would broadcast or truncate inside the jitted
        # scatters — fail loudly at ingestion
        if src.ndim != 1 or dst.ndim != 1 or src.shape != dst.shape:
            raise ValueError(
                f"src/dst must be 1-D arrays of equal length; got shapes "
                f"{src.shape} and {dst.shape}")

    def _check_ids(self, src: np.ndarray, dst: np.ndarray):
        # out-of-range ids would silently clamp/drop inside the jitted
        # scatters and corrupt neighbouring vertices' results
        self._check_shapes(src, dst)
        if src.size == 0:
            return
        lo = min(int(src.min()), int(dst.min()))
        hi = max(int(src.max()), int(dst.max()))
        if lo < 0 or hi >= self.config.node_capacity:
            raise ValueError(
                f"edge endpoint id {lo if lo < 0 else hi} outside "
                f"[0, node_capacity={self.config.node_capacity})")

    def register_add_edges(self, src: np.ndarray, dst: np.ndarray,
                           weights: Optional[np.ndarray] = None):
        """Alg. 1 RegisterAddEdge: buffer an edge-addition chunk (validated
        host-side) until the next query's ApplyUpdates stage.

        ``weights`` optionally streams a per-edge length column alongside
        the endpoints (same 1-D shape); it lands in
        ``GraphState.edge_len`` and feeds every ``weight="length"`` layout
        (SSSP).  Omitted, new edges carry unit length — chunks with and
        without weights can be mixed freely on one stream.
        """
        src = np.asarray(src, np.int32)
        dst = np.asarray(dst, np.int32)
        self._check_ids(src, dst)
        if weights is not None:
            weights = np.asarray(weights, np.float32)
            if weights.shape != src.shape:
                raise ValueError(
                    f"weights must match src/dst shape {src.shape}; got "
                    f"{weights.shape}")
        self._pending_src.append(src)
        self._pending_dst.append(dst)
        self._pending_len.append(weights)
        self._pending_count += src.shape[0]

    def register_remove_edges(self, src: np.ndarray, dst: np.ndarray):
        """Alg. 1 RegisterRemoveEdge (the paper evaluates e+ only and leaves
        removals to future work; the engine supports them end-to-end).
        Removals are buffered and resolved to buffer slots at apply time; a
        removal that matches no live slot counts as *requested* but never as
        *resolved* in the query stats."""
        src = np.asarray(src, np.int32)
        dst = np.asarray(dst, np.int32)
        self._check_shapes(src, dst)
        self._pending_removals.append((src, dst))
        self._pending_count += len(src)
        self._pending_removal_count += len(src)

    @property
    def pending_updates(self) -> int:
        """Buffered updates (additions + removals) not yet applied."""
        return self._pending_count

    # ---- internals -----------------------------------------------------------
    def edge_layouts(self) -> Tuple[B.AnyEdgeLayout, ...]:
        """Sorted edge layouts per ``algorithm.layout_specs`` — built at most
        once per applied update batch (graph mutations invalidate them).

        With ``config.mesh`` set, each cached entry is a
        :class:`~repro.core.backend.ShardedEdgeLayout` — one locally-sorted
        stream *per shard*, so the amortized sort cost is paid (and cached)
        per shard, never across shards — and every consuming sweep runs
        through the shard_map-ed push automatically.
        """
        if self._edge_layouts is None:
            self._edge_layouts = tuple(
                self._build_spec_layout(self.state, spec)
                for spec in map(B.normalize_layout_spec,
                                self.algorithm.layout_specs)
            )
            self.layout_builds += 1
        return self._edge_layouts

    def _build_spec_layout(self, state: G.GraphState,
                           spec: Tuple) -> B.AnyEdgeLayout:
        """Build (dispatch) the sorted layout for one *normalized* spec
        against an explicit graph state — the single layout constructor
        shared by the sync cache (:meth:`edge_layouts`), the serving
        engine's per-lane cache, and :class:`~repro.core.epoch.
        EpochSnapshot` builds (which pass a frozen snapshot state rather
        than ``self.state``).  Mesh engines get a placed
        ``ShardedEdgeLayout`` cut at the current slot assignment."""
        w, rev, s = spec
        tile_n, chunk = self._tuned_geometry(s)
        if self.config.mesh is not None:
            from repro.graph.partition import (build_sharded_layout,
                                               place_sharded_layout)

            return place_sharded_layout(
                build_sharded_layout(
                    state, mesh=self.config.mesh,
                    axes=self.config.mesh_axes,
                    num_shards=self.config.num_shards,
                    weight=w, reverse=rev,
                    semiring=s, slots=self._shard_slots,
                    chunk=chunk, tile_n=tile_n,
                    weight_dtype=self._weight_dtype_for(s)))
        return B.build_layout(
            state, weight=w, reverse=rev, semiring=s,
            chunk=B.CHUNK if chunk is None else chunk,
            tile_n=tile_n,
            weight_dtype=self._weight_dtype_for(s))

    def _tuned_geometry(self, semiring) -> Tuple[Optional[int], Optional[int]]:
        """Autotuned ``(tile_n, chunk)`` for one layout spec, resolved at
        layout-build time so every consuming sweep (exact, summarized,
        batched) inherits it through the layout meta; ``(None, None)`` when
        autotuning is off (push then uses the hardcoded defaults)."""
        cfg = self.config
        if cfg.autotune == "off":
            return None, None
        from repro.core.semiring import resolve_semiring
        from repro.kernels.spmv import autotune as AT

        s = resolve_semiring(semiring)
        e_cap = cfg.edge_capacity
        if cfg.mesh is not None:
            from repro.graph.partition import mesh_shard_count

            num_shards = (cfg.num_shards if cfg.num_shards is not None
                          else mesh_shard_count(cfg.mesh, cfg.mesh_axes))
            e_cap = -(-e_cap // num_shards)  # per-shard stream length
        return AT.tune_for_push(
            edge_capacity=e_cap,
            num_segments=cfg.node_capacity,
            batch=self.autotune_batch_hint,
            dtype=s.dtype,
            reduce=s.add,
            mode=cfg.autotune)

    def _weight_dtype_for(self, semiring) -> Optional[str]:
        """Engine-level weight compression applies only to f32 semirings;
        integer algebras (min_min labels) keep their native dtype rather
        than erroring out of a mixed-algebra algorithm."""
        wd = self.config.weight_dtype
        if wd is None:
            return None
        from repro.core.semiring import resolve_semiring

        if jnp.dtype(resolve_semiring(semiring).dtype) != jnp.float32:
            return None
        return wd

    @property
    def autotune_runs(self) -> int:
        """Measured (timed) autotune searches so far — cache hits and
        analytic-only resolutions excluded."""
        from repro.kernels.spmv import autotune as AT

        return AT.run_count()

    def _invalidate_layouts(self):
        self._edge_layouts = None

    def _maybe_rebalance(self) -> bool:
        """Recut the edge partition when streaming has skewed per-shard
        live-edge counts past ``config.rebalance_threshold``.

        Runs once per applied update batch (never in the query hot loop),
        only on mesh-configured engines.  On a recut the cached layouts are
        invalidated so the next :meth:`edge_layouts` build migrates every
        stream to the balanced assignment with one static-shaped gather;
        ``engine.rebalances`` counts the recuts and
        ``engine.last_imbalance`` records the most recent measurement.
        """
        cfg = self.config
        if cfg.mesh is None or cfg.rebalance_threshold is None:
            return False
        from repro.graph.partition import (balanced_shard_slots,
                                           mesh_shard_count,
                                           rebalance_decision,
                                           shard_slots)

        num_shards = (cfg.num_shards if cfg.num_shards is not None
                      else mesh_shard_count(cfg.mesh, cfg.mesh_axes))
        slots = self._shard_slots
        if slots is None:
            slots = jnp.asarray(
                shard_slots(self.state.edge_capacity, num_shards))
        # the measurement, the threshold compare and the recut signal all
        # stay on device; exactly one (bool, f32) pair crosses to host per
        # applied batch
        should, imbalance = jax.device_get(rebalance_decision(
            self.state, slots, jnp.float32(cfg.rebalance_threshold)))
        self.last_imbalance = float(imbalance)
        rebalanced = bool(should)
        if rebalanced:
            self._shard_slots = balanced_shard_slots(
                self.state, num_shards=num_shards)
            self.rebalances += 1
            self._invalidate_layouts()
        return rebalanced

    def _degree_snapshot(self) -> jax.Array:
        # NOTE: must copy — add_edges donates the state buffers, so an alias
        # into the old state would be deleted by the next update.
        if self.config.degree_mode == "out":
            return jnp.copy(self.state.out_deg)
        if self.config.degree_mode == "in":
            return jnp.copy(self.state.in_deg)
        return self.state.out_deg + self.state.in_deg

    def _apply_pending(self, preserve: bool = False) -> Tuple[int, int, int]:
        """Apply buffered updates.  Returns
        ``(applied, removals_requested, removals_resolved)`` where
        ``applied`` counts additions + resolved removals.

        ``preserve=True`` (the async pipeline) applies through the
        non-donating mutation variants so the served snapshot's buffers —
        which alias the pre-update state — stay valid."""
        if not self._pending_count:
            return 0, 0, 0
        remove_fn = (G.remove_edges_by_slot_preserving if preserve
                     else G.remove_edges_by_slot)
        add_fn = G.add_edges_preserving if preserve else G.add_edges
        removals_requested = self._pending_removal_count
        removals_resolved = 0
        if self._pending_removals:
            r_src = np.concatenate([a for a, _ in self._pending_removals])
            r_dst = np.concatenate([b for _, b in self._pending_removals])
            slots = G.find_edge_slots(self.state, r_src, r_dst)
            self.state = remove_fn(self.state, jnp.asarray(slots))
            removals_resolved = int((slots >= 0).sum())
            if removals_resolved:
                self._invalidate_layouts()
            self._pending_removals.clear()
            self._pending_removal_count = 0
        applied = removals_resolved
        if not self._pending_src:
            self._pending_count = 0
            return applied, removals_requested, removals_resolved
        src = np.concatenate(self._pending_src)
        dst = np.concatenate(self._pending_dst)
        if any(w is not None for w in self._pending_len):
            # mixed weighted/unweighted chunks: unweighted ones take the
            # unit length explicitly so the concatenation lines up
            lens = np.concatenate([
                w if w is not None else np.ones(s.shape[0], np.float32)
                for s, w in zip(self._pending_src, self._pending_len)])
        else:
            lens = None
        self._invalidate_layouts()
        pad = self.config.update_pad
        k = src.shape[0]
        # pad slots must not change degrees, so updates are split into
        # pad-sized exact chunks plus one remainder chunk whose shape
        # recompiles at most `update_pad` distinct sizes.
        for lo in range(0, k, pad):
            hi = min(lo + pad, k)
            self.state = add_fn(
                self.state, jnp.asarray(src[lo:hi]), jnp.asarray(dst[lo:hi]),
                None if lens is None else jnp.asarray(lens[lo:hi]),
            )
            applied += hi - lo
        self._pending_src.clear()
        self._pending_dst.clear()
        self._pending_len.clear()
        self._pending_count = 0
        return applied, removals_requested, removals_resolved

    def _stats_view(self, pending: int, applied: int) -> Dict:
        return {
            "pending": pending,
            "applied": applied,
            # everything not reflected in the current scores: updates
            # integrated under earlier repeat-last answers + this query's
            "since_compute": self._stale_updates + applied + pending,
            "num_nodes": int(self.state.num_active_nodes()),
            "num_edges": int(self.state.num_live_edges()),
            "algorithm": self.algorithm.name,
        }

    def _run_exact(self, st: QueryStats):
        self.algo_state, iters = self.algorithm.exact(
            self.algo_state, self.state,
            layouts=self.edge_layouts(), backend=self.backend)
        st.iterations = int(iters)

    # ---- epoch-versioned async rebuild -----------------------------------
    def _make_snapshot(self, epoch: int, *, applied: int = 0,
                       removals_requested: int = 0,
                       removals_resolved: int = 0):
        """Freeze the current state as :class:`EpochSnapshot` ``epoch`` and
        *dispatch* everything the snapshot serves from: layout sorts for
        every spec the engine has ever served, the count vector, the
        hot-set baselines, and (mesh engines, post-update epochs) the
        rebalance verdict.  Nothing here is awaited — the snapshot's
        device work overlaps with whatever the host does next."""
        from repro.core.epoch import EpochSnapshot, snapshot_counts

        snap = EpochSnapshot(
            epoch=epoch,
            state=self.state,
            deg=self._degree_snapshot(),
            active=jnp.copy(self.state.node_active),
            counts=snapshot_counts(self.state),
            applied=applied,
            removals_requested=removals_requested,
            removals_resolved=removals_resolved,
            rebalance_probe=(self._dispatch_rebalance_probe()
                             if applied else None),
        )
        if self._edge_layouts is not None:
            # the sync cache is valid for this exact state (start() path):
            # seed it into the snapshot instead of re-sorting
            for spec, layout in zip(
                    map(B.normalize_layout_spec, self.algorithm.layout_specs),
                    self._edge_layouts):
                snap.layouts[spec] = layout
        built = False
        for spec in self._async_specs:
            if spec not in snap.layouts:
                snap.layout_for(spec, self._build_spec_layout)
                built = True
        if built:
            self.layout_builds += 1
        return snap

    def _snapshot_layouts(self, snap) -> Tuple[B.AnyEdgeLayout, ...]:
        """The snapshot-bound equivalent of :meth:`edge_layouts`: this
        epoch's sorted layouts per ``algorithm.layout_specs``."""
        return tuple(
            snap.layout_for(spec, self._build_spec_layout)
            for spec in map(B.normalize_layout_spec,
                            self.algorithm.layout_specs))

    def _dispatch_rebalance_probe(self):
        """Dispatch (never await) the on-device rebalance verdict for the
        state being snapshotted; the (bool, f32) pair is fetched once at
        promotion by :meth:`_finalize_promotion` — the async replacement
        for the sync path's per-batch :meth:`_maybe_rebalance` sync."""
        cfg = self.config
        if cfg.mesh is None or cfg.rebalance_threshold is None:
            return None
        from repro.graph.partition import (mesh_shard_count,
                                           rebalance_decision, shard_slots)

        num_shards = (cfg.num_shards if cfg.num_shards is not None
                      else mesh_shard_count(cfg.mesh, cfg.mesh_axes))
        slots = self._shard_slots
        if slots is None:
            slots = jnp.asarray(
                shard_slots(self.state.edge_capacity, num_shards))
        return rebalance_decision(
            self.state, slots, jnp.float32(cfg.rebalance_threshold))

    def _finalize_promotion(self, snap) -> bool:
        """Host-side bookkeeping for a freshly promoted snapshot: fetch its
        dispatched count vector (the per-epoch replacement for the sync
        path's per-query ``int(num_active_nodes())``) and, on mesh
        engines, its rebalance verdict — recutting the slot partition for
        the *next* epoch's builds when streaming has skewed the shards.
        Returns True when a recut happened."""
        counts = np.asarray(jax.device_get(snap.counts))
        snap.num_nodes = int(counts[0])
        snap.num_edges = int(counts[1])
        if snap.rebalance_probe is None:
            return False
        should, imbalance = jax.device_get(snap.rebalance_probe)
        snap.rebalance_probe = None
        self.last_imbalance = float(imbalance)
        if not bool(should):
            return False
        from repro.graph.partition import (balanced_shard_slots,
                                           mesh_shard_count)

        cfg = self.config
        num_shards = (cfg.num_shards if cfg.num_shards is not None
                      else mesh_shard_count(cfg.mesh, cfg.mesh_axes))
        self._shard_slots = balanced_shard_slots(
            self.state, num_shards=num_shards)
        self.rebalances += 1
        self._invalidate_layouts()
        return True

    def _async_integrate(self) -> Tuple[int, int, int]:
        """ApplyUpdates, async flavour: apply buffered updates through the
        non-donating variants and dispatch the next epoch's snapshot build
        (the served snapshot keeps its buffers).  Called *after* the
        query's compute has been dispatched against the served snapshot,
        so the result fetch never waits on this work.  Returns the applied
        counts; an all-unresolved removal batch mutates nothing and
        dispatches no epoch."""
        pipe = self._pipeline
        applied, requested, resolved = self._apply_pending(preserve=True)
        if applied:
            pipe.dispatch(self._make_snapshot(
                pipe.latest_epoch + 1, applied=applied,
                removals_requested=requested, removals_resolved=resolved))
        return applied, requested, resolved

    def _run_exact_on(self, snap, st: QueryStats):
        """Exact recompute pinned to the served snapshot (refresh/fallback
        in the async path must not leak the in-flight epoch's graph)."""
        self.algo_state, iters = self.algorithm.exact(
            self.algo_state, snap.state,
            layouts=self._snapshot_layouts(snap), backend=self.backend)
        st.iterations = int(iters)

    def _query_async(self, msg: Optional[Dict]) -> Tuple[np.ndarray, QueryStats]:
        """Serve one query from the epoch pipeline.

        The wave order is what buys the overlap: (1) promote the finished
        build at the boundary, (2) dispatch this query's compute against
        the served snapshot, (3) integrate pending updates + dispatch the
        next epoch, and only then (4) fetch the result — which was
        enqueued before the rebuild work, so the fetch waits on the query
        compute alone.  Updates integrated at query q become visible at
        q+1's promotion and are charged to that promoted epoch's stats
        row."""
        from repro.core.fused import fused_query_step

        qid = self._query_id
        self._query_id += 1
        cfg = self.config
        pipe = self._pipeline

        # (1) wave boundary: flip in the finished build, if any
        promoted = pipe.promote()
        rebalanced = False
        if promoted is not None:
            rebalanced = self._finalize_promotion(promoted)
        snap = pipe.current
        applied = promoted.applied if promoted is not None else 0
        removals_requested = (promoted.removals_requested
                              if promoted is not None else 0)
        removals_resolved = (promoted.removals_resolved
                             if promoted is not None else 0)

        view = {
            "pending": self._pending_count,
            "applied": applied,
            "since_compute": (self._stale_updates + applied
                              + self._pending_count),
            "num_nodes": snap.num_nodes,
            "num_edges": snap.num_edges,
            "algorithm": self.algorithm.name,
            "epoch": snap.epoch,
        }
        integrate = self._before_updates(self._pending_count, view)
        action = self._on_query(qid, view)
        t0 = time.perf_counter()
        st = QueryStats(
            query_id=qid,
            action=action.value,
            wall_time_s=0.0,
            num_nodes=snap.num_nodes,
            num_edges=snap.num_edges,
            pending_applied=applied,
            removals_requested=removals_requested,
            removals_resolved=removals_resolved,
            rebalanced=rebalanced,
            algorithm=self.algorithm.name,
            epoch=snap.epoch,
        )

        # (2) dispatch this query's compute on the served snapshot — no
        # block_until_ready, no host transfer until step (4)
        ctl = self.controller
        new_state = qs = None
        if action == Action.APPROXIMATE:
            r_now = ctl.r_eff if ctl is not None else cfg.r
            delta_now = ctl.delta_eff if ctl is not None else cfg.delta
            new_state, qs = fused_query_step(
                snap.state,
                self.algo_state,
                self.deg_prev,
                self.active_prev,
                jnp.float32(r_now),
                jnp.float32(delta_now),
                self._probe_ids,
                algo=self.algorithm,
                hot_node_capacity=cfg.hot_node_capacity,
                hot_edge_capacity=cfg.hot_edge_capacity,
                n=cfg.n,
                delta_hop_cap=cfg.delta_hop_cap,
                degree_mode=cfg.degree_mode,
                expand_both=cfg.expand_both,
                layouts=self._snapshot_layouts(snap),
                backend=self.backend,
                shard_bucket_capacity=cfg.shard_hot_edge_capacity,
                with_drift=ctl is not None,
            )
        elif action == Action.EXACT:
            self._run_exact_on(snap, st)

        # (3) integrate buffered updates and dispatch epoch N+1; its sorts
        # and probe overlap with the compute already in the device queue
        if integrate and self._pending_count:
            _, extra_req, extra_res = self._async_integrate()
            if pipe.building is None and extra_req:
                # nothing mutated (all removals unresolved): no new epoch,
                # so the request is only observable on this row
                st.removals_requested += extra_req - extra_res
        st.snapshot_lag = pipe.snapshot_lag

        # (4) fetch — waits on the query compute dispatched in step (2)
        if action == Action.REPEAT_LAST:
            self._stale_updates += applied
        elif action == Action.EXACT:
            self.deg_prev = snap.deg
            self.active_prev = snap.active
            if ctl is not None:
                ctl.refreshed()
                st.refreshed = True
        elif qs is not None:
            qs = jax.device_get(qs)  # one host transfer for all stats
            if bool(qs.used_fallback):
                # capacities exceeded: the summarized state is invalid;
                # recompute exactly on the *served* snapshot
                self._run_exact_on(snap, st)
                qs = qs._replace(iterations=st.iterations)
                if ctl is not None:
                    ctl.refreshed()
                    st.refreshed = True
            else:
                self.algo_state = new_state
            st.num_hot = int(qs.num_hot)
            st.num_kr = int(qs.num_kr)
            st.num_kn = int(qs.num_kn)
            st.num_kdelta = int(qs.num_kdelta)
            st.num_ek = int(qs.num_ek)
            st.num_eb = int(qs.num_eb)
            st.iterations = int(qs.iterations)
            st.overflow_fallback = bool(qs.used_fallback)
            if ctl is not None and not st.overflow_fallback:
                dec = ctl.observe(float(qs.drift_probe),
                                  float(qs.drift_cold))
                st.drift = max(float(qs.drift_probe), float(qs.drift_cold))
                st.r_eff = float(r_now)
                st.delta_eff = float(delta_now)
                st.quality_est = dec.quality_est
                if dec.refresh:
                    self._run_exact_on(snap, st)
                    ctl.refreshed()
                    st.refreshed = True
                    st.quality_est = 1.0
            elif ctl is not None:
                st.r_eff = float(r_now)
                st.delta_eff = float(delta_now)
                st.quality_est = 1.0
            # the epoch's own baselines become the next query's deg_prev/
            # active_prev, so drift is always measured across whole epochs
            self.deg_prev = snap.deg
            self.active_prev = snap.active

        if action != Action.REPEAT_LAST:
            self._stale_updates = 0
        st.wall_time_s = time.perf_counter() - t0
        self.stats_log.append(st)
        scores = self.ranks
        if self._on_query_result:
            self._on_query_result(qid, msg, action, scores, st)
        return np.asarray(jax.device_get(scores)), st

    # ---- query serving ---------------------------------------------------
    def query(self, msg: Optional[Dict] = None) -> Tuple[np.ndarray, QueryStats]:
        """Serve one query (Alg. 1 lines 6-21). Returns (scores, stats)."""
        assert self._started, "call start() first"
        if self._pipeline is not None:
            return self._query_async(msg)
        qid = self._query_id
        self._query_id += 1
        cfg = self.config

        applied = removals_requested = removals_resolved = 0
        rebalanced = False
        view = self._stats_view(self._pending_count, 0)
        if self._before_updates(self._pending_count, view):
            applied, removals_requested, removals_resolved = self._apply_pending()
            if applied:
                rebalanced = self._maybe_rebalance()
            # the OnQuery policy must see the post-update graph: refresh the
            # node/edge counts snapshotted before _apply_pending
            view = self._stats_view(self._pending_count, applied)

        action = self._on_query(qid, view)
        t0 = time.perf_counter()
        st = QueryStats(
            query_id=qid,
            action=action.value,
            wall_time_s=0.0,
            num_nodes=view["num_nodes"],
            num_edges=view["num_edges"],
            pending_applied=applied,
            removals_requested=removals_requested,
            removals_resolved=removals_resolved,
            rebalanced=rebalanced,
            algorithm=self.algorithm.name,
        )

        if action == Action.REPEAT_LAST:
            self._stale_updates += applied  # previous scores returned as-is
        elif action == Action.EXACT:
            self._run_exact(st)
            self.ranks.block_until_ready()
            self.deg_prev = self._degree_snapshot()
            self.active_prev = jnp.copy(self.state.node_active)
            if self.controller is not None:
                # an exact recompute is a refresh: accumulated drift resets
                self.controller.refreshed()
                st.refreshed = True
        elif cfg.fused and self.algorithm.supports_fused:
            # APPROXIMATE, single fused XLA program for any algorithm
            from repro.core.fused import fused_query_step

            ctl = self.controller
            r_now = ctl.r_eff if ctl is not None else cfg.r
            delta_now = ctl.delta_eff if ctl is not None else cfg.delta
            new_state, qs = fused_query_step(
                self.state,
                self.algo_state,
                self.deg_prev,
                self.active_prev,
                jnp.float32(r_now),
                jnp.float32(delta_now),
                self._probe_ids,
                algo=self.algorithm,
                hot_node_capacity=cfg.hot_node_capacity,
                hot_edge_capacity=cfg.hot_edge_capacity,
                n=cfg.n,
                delta_hop_cap=cfg.delta_hop_cap,
                degree_mode=cfg.degree_mode,
                expand_both=cfg.expand_both,
                layouts=self.edge_layouts(),
                backend=self.backend,
                shard_bucket_capacity=cfg.shard_hot_edge_capacity,
                with_drift=ctl is not None,
            )
            if bool(qs.used_fallback):
                # capacities exceeded: the summarized state is invalid;
                # discard it and recompute exactly (graceful degradation)
                self._run_exact(st)
                qs = qs._replace(iterations=st.iterations)
                if ctl is not None:
                    ctl.refreshed()  # exact fallback = accurate baseline
                    st.refreshed = True
            else:
                self.algo_state = new_state
            self.ranks.block_until_ready()
            qs = jax.device_get(qs)  # one host transfer for all stats
            st.num_hot = int(qs.num_hot)
            st.num_kr = int(qs.num_kr)
            st.num_kn = int(qs.num_kn)
            st.num_kdelta = int(qs.num_kdelta)
            st.num_ek = int(qs.num_ek)
            st.num_eb = int(qs.num_eb)
            st.iterations = int(qs.iterations)
            st.overflow_fallback = bool(qs.used_fallback)
            if ctl is not None and not st.overflow_fallback:
                # fold the drift reading (rode the stats transfer above)
                # into the loop: knobs for the *next* query, and possibly
                # an exact refresh to pull the state back inside the SLO
                dec = ctl.observe(float(qs.drift_probe),
                                  float(qs.drift_cold))
                st.drift = max(float(qs.drift_probe), float(qs.drift_cold))
                st.r_eff = float(r_now)
                st.delta_eff = float(delta_now)
                st.quality_est = dec.quality_est
                if dec.refresh:
                    self._run_exact(st)
                    self.ranks.block_until_ready()
                    ctl.refreshed()
                    st.refreshed = True
                    st.quality_est = 1.0
            elif ctl is not None:
                st.r_eff = float(r_now)
                st.delta_eff = float(delta_now)
                st.quality_est = 1.0
            self.deg_prev = self._degree_snapshot()
            self.active_prev = jnp.copy(self.state.node_active)
        else:  # APPROXIMATE — unfused reference path
            hot, hstats = select_hot_set(
                self.state,
                self.deg_prev,
                self.algorithm.selection_view(self.algo_state),
                jnp.float32(cfg.r),
                jnp.float32(cfg.delta),
                active_prev=self.active_prev,
                n=cfg.n,
                delta_hop_cap=cfg.delta_hop_cap,
                degree_mode=cfg.degree_mode,
                expand_both=cfg.expand_both,
                normalize_scores=self.algorithm.normalize_selection_scores,
            )
            # forwarded only when set: legacy plugin build_summaries
            # overrides may predate the shard_bucket_capacity keyword
            extra = ({} if cfg.shard_hot_edge_capacity is None else
                     {"shard_bucket_capacity": cfg.shard_hot_edge_capacity})
            summaries = self.algorithm.build_summaries(
                self.algo_state,
                self.state,
                hot,
                hot_node_capacity=cfg.hot_node_capacity,
                hot_edge_capacity=cfg.hot_edge_capacity,
                layouts=self.edge_layouts(),
                backend=self.backend,
                **extra,
            )
            st.num_hot = int(hstats.num_hot)
            st.num_kr = int(hstats.num_kr)
            st.num_kn = int(hstats.num_kn)
            st.num_kdelta = int(hstats.num_kdelta)
            st.num_ek = int(summaries[0].num_ek)
            st.num_eb = int(sum(int(s.num_eb) for s in summaries))
            if bool(summaries_overflow(summaries)):
                # graceful degradation: capacities exceeded -> exact recompute
                st.overflow_fallback = True
                self._run_exact(st)
            else:
                self.algo_state, iters = self.algorithm.summarized(
                    self.algo_state, self.state, summaries,
                    backend=self.backend,
                )
                st.iterations = int(iters)
            self.ranks.block_until_ready()
            self.deg_prev = self._degree_snapshot()
            self.active_prev = jnp.copy(self.state.node_active)

        if action != Action.REPEAT_LAST:
            self._stale_updates = 0
        st.wall_time_s = time.perf_counter() - t0
        self.stats_log.append(st)
        scores = self.ranks
        if self._on_query_result:
            self._on_query_result(qid, msg, action, scores, st)
        return np.asarray(jax.device_get(scores)), st

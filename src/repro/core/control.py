"""Closed-loop quality control: drift estimation + an SLO-targeting knob
controller (the GraphGuess-style adaptive correction of ROADMAP's
"close the accuracy loop" item).

The open-loop engine exposes the paper's model knobs (r, n, Δ) and
whatever accuracy falls out of them is unmeasured at runtime.  This
module closes the loop with two pieces:

**On-device drift estimation** (:func:`drift_signals`) — computed inside
the fused query step (no extra host sync; the two f32 scalars ride the
existing :class:`~repro.core.fused.QueryStepStats` transfer):

- ``drift_probe`` — the algorithm's own fixed-point residual
  (:meth:`~repro.core.algorithm.StreamingAlgorithm.drift_residual`, e.g.
  ``|(1-β)t + β·push(r) − r|`` for PageRank) sampled on a small fixed
  vertex probe set and scaled to an estimate of the *relative* L1 error
  of the whole vector.  This is the "sampled exact-vs-summarized delta":
  at the true fixed point the residual is zero everywhere, so probe
  residual mass measures how far the summarized state has drifted from
  the exact answer.
- ``drift_cold`` — the residual mass on vertices *outside* the hot set K,
  as a fraction of total result mass.  A summarized sweep freezes cold
  vertices by construction, so this is exactly the error the current
  hot-set selection chose to ignore this query; the controller
  accumulates it across queries (frozen error compounds until a refresh).

**A host-side controller** (:class:`QualityController`) — pure python
floats, no device work — that turns ``quality_target`` (e.g. 0.95) into
an error budget and steers two things per query/wave:

- *hot-set sizing*: multiplicative tighten/relax of the effective ``r``
  and ``Δ`` knobs (both runtime scalars — adjusting them never
  recompiles) with a deadband, so the hot set grows under drift and
  shrinks back when the stream quiets down;
- *refresh cadence*: when the accumulated error estimate exceeds the
  budget the controller requests a refresh — the engine recomputes
  exactly (serving: the next wave re-runs every live slot with full
  coverage), resetting the accumulated drift to zero.

Knob precedence: an explicitly passed ``r``/``delta`` wins over the
controller (``adjust_r=False`` / ``adjust_delta=False`` — see
:func:`repro.api.session`).  The estimator is deliberately conservative
(``gain`` inflates the one-sweep residual toward the true error bound
``resid/(1−contraction)``), so the measured rank quality typically sits
well above the target while summarized work stays far below the
open-loop full-accuracy configuration — the numbers recorded in
``BENCH_sweeps.json`` (``controller_*`` rows).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def default_probe_ids(node_capacity: int, num_probes: int = 64) -> jax.Array:
    """A fixed, deterministic probe set: ``num_probes`` vertex ids strided
    evenly across the id space.  Static shape (jit-friendly), independent
    of the stream — the same vertices are probed every query, so
    successive probe readings are comparable."""
    num = max(1, min(int(num_probes), int(node_capacity)))
    stride = max(node_capacity // num, 1)
    ids = (np.arange(num, dtype=np.int64) * stride) % node_capacity
    return jnp.asarray(ids, jnp.int32)


def drift_signals(
    resid: jax.Array,
    result: jax.Array,
    hot: jax.Array,
    active: jax.Array,
    probe_ids: jax.Array,
    *,
    normalize: str = "mass",
) -> Tuple[jax.Array, jax.Array]:
    """The two on-device drift scalars from one residual vector.

    ``resid`` is the per-vertex fixed-point residual (f32[N], >= 0 where
    meaningful), ``result`` the algorithm's result view (any dtype),
    ``hot``/``active`` the wave's hot and active masks, ``probe_ids`` the
    fixed probe set.  Everything is gathers + reductions — no scatters,
    no host syncs; returns ``(drift_probe, drift_cold)`` f32 scalars,
    both normalized so they read as *relative* L1 error estimates:
    ``normalize="mass"`` divides by total |result| mass (the ranking /
    distance workloads), ``"count"`` by the active-vertex count (for
    0/1 changed-indicator residuals, e.g. connected components' label
    flips — see ``StreamingAlgorithm.drift_normalize``).

    Non-finite entries (±∞ sentinels of the min/max-semiring workloads)
    are excluded from both the residual and the mass — a vertex that is
    unreachable in both states contributes nothing, while reachability
    flips show up through the residual's own churn encoding.
    """
    res_f = result.astype(jnp.float32)
    resid = resid.astype(jnp.float32)
    finite = active & jnp.isfinite(res_f) & jnp.isfinite(resid)
    resid = jnp.where(finite, jnp.maximum(resid, 0.0), 0.0)
    n_active = jnp.maximum(jnp.sum(active.astype(jnp.float32)), 1.0)
    if normalize == "count":
        mass = n_active
    else:
        mass = jnp.maximum(
            jnp.sum(jnp.where(finite, jnp.abs(res_f), 0.0)), 1e-30)

    # residual mass the hot-set selection chose to freeze this query
    drift_cold = jnp.sum(jnp.where(hot, 0.0, resid)) / mass

    # sampled residual on the fixed probe set, extrapolated to the full
    # active set: mean probe residual × n_active ≈ total residual mass
    p_resid = resid[probe_ids]
    p_live = finite[probe_ids].astype(jnp.float32)
    p_mean = (jnp.sum(p_resid * p_live)
              / jnp.maximum(jnp.sum(p_live), 1.0))
    drift_probe = p_mean * n_active / mass
    return drift_probe, drift_cold


@dataclass
class ControlDecision:
    """One controller step's output: the knobs to use next, the current
    error estimate, and whether a refresh (exact recompute / full-coverage
    wave) is required to stay inside the SLO."""

    refresh: bool
    r_eff: float
    delta_eff: float
    err_est: float
    quality_est: float


class QualityController:
    """Host-side SLO controller: drift in, effective knobs + refresh out.

    ``quality_target`` in (0, 1) sets the error budget
    ``1 − quality_target``.  Per observation (one query / one serving
    wave) the controller

    1. accumulates ``drift_cold`` (frozen-error compounds until a
       refresh) and takes ``err = gain · max(drift_probe, accum)`` —
       ``gain`` inflates the one-sweep residual toward the true error
       bound ``resid / (1 − contraction)``, erring conservative;
    2. requests a **refresh** when ``err`` exceeds the budget (the
       caller recomputes exactly and then calls :meth:`refreshed`);
    3. steers the knobs multiplicatively with a deadband: *tighten*
       (×``tighten`` < 1 → bigger hot set) above ``tighten_at`` of the
       budget, *relax* (×``relax`` > 1 → smaller hot set, less work)
       below ``relax_at`` of it, clamped to ``r_bounds``/
       ``delta_bounds``.  ``adjust_r=False`` / ``adjust_delta=False``
       pin a knob (explicit user knobs win — see
       :func:`repro.api.session`).

    All state is python floats — observing never touches the device; the
    caller feeds it the two scalars that already ride the per-query
    stats transfer.
    """

    def __init__(
        self,
        quality_target: float,
        *,
        r0: float,
        delta0: float,
        adjust_r: bool = True,
        adjust_delta: bool = True,
        gain: Optional[float] = None,
        contraction: Optional[float] = None,
        tighten: float = 0.5,
        relax: float = 1.35,
        tighten_at: float = 0.5,
        relax_at: float = 0.125,
        r_bounds: Tuple[float, float] = (1e-3, 4.0),
        delta_bounds: Tuple[float, float] = (1e-4, 16.0),
    ):
        if not 0.0 < quality_target < 1.0:
            raise ValueError(
                f"quality_target must be in (0, 1); got {quality_target}")
        self.quality_target = float(quality_target)
        self.budget = 1.0 - self.quality_target
        self.adjust_r = bool(adjust_r)
        self.adjust_delta = bool(adjust_delta)
        # drift→error gain calibration: an explicit ``gain`` wins; else an
        # algorithm-declared contraction c (StreamingAlgorithm.
        # drift_contraction) gives the tight amplification bound
        # 1/(1−c) — e.g. the min-semiring relaxations declare c=0 (gain 1)
        # and stop over-refreshing on quiet streams; else the conservative
        # legacy 3.0 (right for weakly-contracting damped ranking algebras
        # that declare nothing).
        if gain is not None:
            self.gain = float(gain)
        elif contraction is not None:
            c = float(contraction)
            if not 0.0 <= c < 1.0:
                raise ValueError(
                    f"contraction must be in [0, 1); got {contraction}")
            self.gain = 1.0 / max(1.0 - c, 1e-6)
        else:
            self.gain = 3.0
        self.tighten = float(tighten)
        self.relax = float(relax)
        self.tighten_at = float(tighten_at)
        self.relax_at = float(relax_at)
        self.r_bounds = (float(r_bounds[0]), float(r_bounds[1]))
        self.delta_bounds = (float(delta_bounds[0]), float(delta_bounds[1]))
        self.r_eff = float(np.clip(r0, *self.r_bounds))
        self.delta_eff = float(np.clip(delta0, *self.delta_bounds))
        # accumulated frozen (cold) drift since the last refresh, and the
        # last total error estimate — observability for stats rows
        self.accum = 0.0
        self.last_err = 0.0
        self.refreshes = 0
        self.observations = 0

    def observe(self, drift_probe: float,
                drift_cold: float) -> ControlDecision:
        """Fold one query/wave's drift reading into the loop.

        Two error readings drive two different levers: the *instantaneous*
        estimate (this query's probe residual / freshly frozen mass)
        steers the knobs — so a quiet stream relaxes them even while old
        frozen error persists — while the *accumulated* estimate (probe +
        compounded cold drift since the last refresh) gates the refresh
        decision, because only an exact recompute can pay that debt."""
        self.observations += 1
        probe = max(float(drift_probe), 0.0)
        cold = max(float(drift_cold), 0.0)
        self.accum += cold
        inst = self.gain * max(probe, cold)
        err = self.gain * max(probe, self.accum)
        self.last_err = err
        refresh = err > self.budget

        if inst > self.tighten_at * self.budget:
            if self.adjust_r:
                self.r_eff = max(self.r_eff * self.tighten,
                                 self.r_bounds[0])
            if self.adjust_delta:
                self.delta_eff = max(self.delta_eff * self.tighten,
                                     self.delta_bounds[0])
        elif inst < self.relax_at * self.budget:
            if self.adjust_r:
                self.r_eff = min(self.r_eff * self.relax, self.r_bounds[1])
            if self.adjust_delta:
                self.delta_eff = min(self.delta_eff * self.relax,
                                     self.delta_bounds[1])

        return ControlDecision(
            refresh=refresh,
            r_eff=self.r_eff,
            delta_eff=self.delta_eff,
            err_est=err,
            quality_est=max(0.0, 1.0 - err),
        )

    def refreshed(self) -> None:
        """The caller ran an exact recompute (or a full-coverage wave):
        the summarized baseline is accurate again, so accumulated frozen
        drift resets to zero."""
        self.accum = 0.0
        self.refreshes += 1

"""VeilGraph core: the paper's contribution — approximate streaming graph
processing via hot-vertex selection + big-vertex summarization — behind a
pluggable :class:`StreamingAlgorithm` interface (PageRank is the paper's
case study; personalized PageRank and HITS ship alongside it)."""
from repro.core.algorithm import (Action, AlgoState, HITSAlgorithm,
                                  PageRankAlgorithm,
                                  PersonalizedPageRankAlgorithm,
                                  StreamingAlgorithm, available_algorithms,
                                  make_algorithm, register_algorithm)
from repro.core.backend import (EdgeLayout, build_layout, push, push_coo,
                                resolve_backend, summary_layout)
from repro.core.engine import (EngineConfig, QueryStats, VeilGraphEngine)
from repro.core.hits import hits, summarized_hits
from repro.core.hotset import HotSetStats, select_hot_set
from repro.core.pagerank import (SummaryBuffers, build_summary, pagerank,
                                 summarized_pagerank)

"""VeilGraph core: the paper's contribution — approximate streaming graph
processing via hot-vertex selection + big-vertex summarization."""
from repro.core.engine import Action, EngineConfig, QueryStats, VeilGraphEngine
from repro.core.hotset import HotSetStats, select_hot_set
from repro.core.pagerank import (SummaryBuffers, build_summary, pagerank,
                                 summarized_pagerank)

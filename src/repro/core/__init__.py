"""VeilGraph core: the paper's contribution — approximate streaming graph
processing via hot-vertex selection + big-vertex summarization — behind a
pluggable :class:`StreamingAlgorithm` interface over semiring-generic
propagation (PageRank is the paper's case study; personalized PageRank,
HITS, Katz, connected components and SSSP ship alongside it)."""
from repro.core.algorithm import (Action, AlgoState,
                                  ConnectedComponentsAlgorithm,
                                  HITSAlgorithm, KatzAlgorithm,
                                  PageRankAlgorithm,
                                  PersonalizedPageRankAlgorithm,
                                  SSSPAlgorithm, StreamingAlgorithm,
                                  algorithm_factory, available_algorithms,
                                  make_algorithm, register_algorithm)
from repro.core.backend import (EdgeLayout, ShardedEdgeLayout, build_layout,
                                push, push_coo, resolve_backend,
                                summary_layout)
from repro.core.engine import (EngineConfig, QueryStats, VeilGraphEngine)
from repro.core.hits import hits, summarized_hits
from repro.core.hotset import HotSetStats, select_hot_set
from repro.core.katz import katz, summarized_katz
from repro.core.pagerank import (SummaryBuffers, build_summary, pagerank,
                                 summarized_pagerank)
from repro.core.semiring import (Semiring, available_semirings,
                                 register_semiring, resolve_semiring)
from repro.core.traversal import (connected_components, sssp,
                                  summarized_connected_components,
                                  summarized_sssp)

"""The algebra behind every propagation sweep: a frozen ``Semiring`` spec.

Every sweep in the repo is one primitive applied per iteration,

    out[v] = ⊕ over in-edges (u, v) of ( values[u] ⊗ weight(u, v) )

and until this module existed the primitive was hard-wired to the
``(+, ·)`` semiring over float32 — which is exactly why PageRank/HITS/Katz
ran through the engine while connected components (needs integer label
state) and SSSP-style relaxations (need a min-reduce) could not.  A
:class:`Semiring` names the pair of operations, their identities, and the
element dtype; :func:`repro.core.backend.push` dispatches on it:

=============  =====  =====  ========  =================================
name           ⊕      ⊗      dtype     workload
=============  =====  =====  ========  =================================
``plus_times`` sum    ×      float32   PageRank, HITS, Katz (the paper's
                                       sum-of-products; MXU fast path)
``min_plus``   min    \\+     float32   SSSP / shortest-path relaxation
``min_min``    min    min    int32     connected components (label-min:
                                       ⊗'s identity is +∞, so unit
                                       weights pass labels through)
``max_times``  max    ×      float32   widest/most-reliable-path sweeps
                                       over multiplicative reliabilities
=============  =====  =====  ========  =================================

Identities are derived, not stored: ``zero`` is ⊕'s identity (0 for sum,
+∞ for min, −∞ for max — the value padding/masked edges contribute) and
``one`` is ⊗'s identity (1 for ×, 0 for +, +∞ for min — the value a
``weight="unit"`` edge layout bakes).  For integer dtypes ±∞ means the
dtype's extrema.  Instances are frozen/hashable so they ride through
``jax.jit`` as static arguments, and every ``semiring=`` knob accepts the
registry name or an instance (:func:`resolve_semiring`).

Register custom semirings with :func:`register_semiring` — e.g. a
``max_min`` bottleneck-capacity semiring — and they become usable by every
backend, sweep, and :class:`~repro.core.algorithm.StreamingAlgorithm`.
One backend caveat: ``sum`` reductions on the pallas backend run the f32
one-hot-matmul MXU path, so a sum semiring over any other dtype must use
``backend="segment_sum"`` (the pallas path rejects it loudly rather than
silently casting); ``min``/``max`` reductions support f32 and i32 on both
backends.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Union

import jax
import jax.numpy as jnp
import numpy as np

#: ⊕ reduce kinds the backends implement.
ADD_OPS = ("sum", "min", "max")
#: ⊗ combine kinds.
MUL_OPS = ("times", "plus", "min")
#: ⊕ reduce → cross-device collective.  The sharded push backend computes a
#: per-shard partial reduce and merges partials with this collective — the
#: all-reduce is the distributed half of the same ⊕, so semirings whose
#: reduce is reassociation-exact (min/max) stay *bitwise* identical under
#: sharding while sum semirings differ only by f32 summation order.
COLLECTIVES = {"sum": "psum", "min": "pmin", "max": "pmax"}


def _identity(op: str, dtype: np.dtype, *, lower: bool):
    """The neutral element of ``op`` over ``dtype``.

    ``sum``/``plus`` → 0, ``times`` → 1; ``min`` → +∞ (int max),
    ``max`` → −∞ (int min) — ``lower`` selects which extremum.
    """
    if op in ("sum", "plus"):
        return dtype.type(0)
    if op == "times":
        return dtype.type(1)
    if np.issubdtype(dtype, np.floating):
        return dtype.type(-np.inf if lower else np.inf)
    info = np.iinfo(dtype)
    return dtype.type(info.min if lower else info.max)


@dataclasses.dataclass(frozen=True)
class Semiring:
    """A (⊕, ⊗) pair with identities and element dtype.

    ``add`` is the per-vertex reduce over incoming contributions, ``mul``
    combines a value with the edge weight.  ``dtype`` is a string
    (``"float32"``, ``"int32"``, …) so instances stay hashable and valid
    ``jax.jit`` static arguments.
    """

    name: str
    add: str = "sum"
    mul: str = "times"
    dtype: str = "float32"

    def __post_init__(self):
        if self.add not in ADD_OPS:
            raise ValueError(f"unknown ⊕ op {self.add!r}; expected {ADD_OPS}")
        if self.mul not in MUL_OPS:
            raise ValueError(f"unknown ⊗ op {self.mul!r}; expected {MUL_OPS}")
        np.dtype(self.dtype)  # fail fast on bogus dtype strings

    # ---- dtype / identities ---------------------------------------------
    @property
    def np_dtype(self) -> np.dtype:
        """The element dtype as a ``np.dtype`` (``dtype`` is stored as a
        string so instances stay hashable/jit-static)."""
        return np.dtype(self.dtype)

    @property
    def zero(self):
        """⊕'s identity — what padding, masked edges and empty in-neighbor
        sets contribute (0 for sum, +∞ for min, −∞ for max)."""
        return _identity(self.add, self.np_dtype, lower=(self.add == "max"))

    @property
    def one(self):
        """⊗'s identity — the weight a ``"unit"`` edge layout bakes so the
        push propagates values unchanged (1 for ×, 0 for +, +∞ for min)."""
        return _identity(self.mul, self.np_dtype, lower=False)

    # ---- traced ops ------------------------------------------------------
    def combine(self, values: jax.Array, weight: jax.Array) -> jax.Array:
        """``values ⊗ weight`` (elementwise, traced inline).

        bf16-compressed edge weights widen to the values' dtype here —
        storage is half the bytes, accumulation stays in the values'
        precision (strict-promotion safe).
        """
        if weight.dtype != values.dtype:
            weight = weight.astype(values.dtype)
        if self.mul == "times":
            return values * weight
        if self.mul == "plus":
            return values + weight
        return jnp.minimum(values, weight)

    def segment_reduce(self, contrib: jax.Array, segments: jax.Array, *,
                       num_segments: int,
                       indices_are_sorted: bool = False) -> jax.Array:
        """⊕-reduce contributions per segment; empty segments get ``zero``
        (XLA's segment ops already initialize with the matching identity)."""
        op = {"sum": jax.ops.segment_sum, "min": jax.ops.segment_min,
              "max": jax.ops.segment_max}[self.add]
        return op(contrib, segments, num_segments=num_segments,
                  indices_are_sorted=indices_are_sorted)

    # ---- distributed ⊕ ---------------------------------------------------
    @property
    def collective(self) -> str:
        """Name of the all-reduce that completes a sharded ⊕
        (``psum``/``pmin``/``pmax`` — see :data:`COLLECTIVES`)."""
        return COLLECTIVES[self.add]

    def merge(self, x: jax.Array, y: jax.Array) -> jax.Array:
        """⊕ of two partial reduces (elementwise, traced inline) — how two
        shards' partial push results combine on one device."""
        if self.add == "sum":
            return x + y
        if self.add == "min":
            return jnp.minimum(x, y)
        return jnp.maximum(x, y)

    def all_reduce(self, x: jax.Array, axis_name) -> jax.Array:
        """⊕ all-reduce across mapped mesh axes (inside ``shard_map``):
        the cross-device merge of per-shard partial pushes.  ``axis_name``
        is a mesh axis name or tuple of names.  Resolves through
        :attr:`collective`, so :data:`COLLECTIVES` is the single ⊕ →
        collective mapping."""
        return getattr(jax.lax, self.collective)(x, axis_name)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Semiring] = {}


def register_semiring(s: Semiring) -> Semiring:
    """Register ``s`` under its name (latest registration wins)."""
    _REGISTRY[s.name] = s
    return s


def available_semirings() -> tuple:
    """Sorted names of every registered semiring."""
    return tuple(sorted(_REGISTRY))


def resolve_semiring(spec: Union[str, Semiring, None]) -> Semiring:
    """Name / instance / ``None`` (→ ``plus_times``) to a :class:`Semiring`."""
    if spec is None:
        return PLUS_TIMES
    if isinstance(spec, Semiring):
        return spec
    try:
        return _REGISTRY[spec]
    except KeyError:
        raise KeyError(
            f"unknown semiring {spec!r}; registered: "
            f"{', '.join(available_semirings())}") from None


PLUS_TIMES = register_semiring(Semiring("plus_times", "sum", "times",
                                        "float32"))
MIN_PLUS = register_semiring(Semiring("min_plus", "min", "plus", "float32"))
MIN_MIN = register_semiring(Semiring("min_min", "min", "min", "int32"))
MAX_TIMES = register_semiring(Semiring("max_times", "max", "times",
                                       "float32"))


__all__ = [
    "ADD_OPS",
    "COLLECTIVES",
    "MUL_OPS",
    "MAX_TIMES",
    "MIN_MIN",
    "MIN_PLUS",
    "PLUS_TIMES",
    "Semiring",
    "available_semirings",
    "register_semiring",
    "resolve_semiring",
]

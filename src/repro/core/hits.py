"""HITS (hubs & authorities) — exact and VeilGraph-summarized versions.

HITS is the second propagation workload ported onto the engine's
:class:`StreamingAlgorithm` interface (beyond-paper: the paper's five-UDF
structure and hot-vertex summarization are algorithm-agnostic; PageRank is
only its case study).  The update rules are the classic mutual recursion

    auth(v) = Σ_{(u,v) ∈ E} hub(u)          (gather along in-edges)
    hub(u)  = Σ_{(u,v) ∈ E} auth(v)         (gather along out-edges)

with L1 normalization over the active vertex set each half-iteration, which
keeps 30-iteration power sweeps inside f32 range.

Both directions run through the unified :func:`repro.core.backend.push`
primitive on the ``plus_times`` semiring (unit weights are its ⊗-identity,
1): the authority update over a forward (dst-sorted) layout, the hub update
over a reverse (src-sorted) one — on the pallas backend each half-iteration
is one destination-tiled one-hot-matmul MXU kernel call.

The summarized version runs both updates only for vertices in the hot set K,
against *two* compacted summaries built by the generalized
:func:`repro.core.pagerank.build_summary`:

- a forward summary (``weight="unit"``) whose ``b_in`` freezes the hub mass
  flowing from non-hot vertices into hot authorities, and
- a reverse summary (``weight="unit", reverse=True``) whose ``b_in`` freezes
  the authority mass that hot hubs collect from their non-hot out-neighbors.

Cold scores are carried over unchanged; per-iteration normalization counts
the frozen cold mass so that with K = V (r = 1.0) the summarized sweep is
the exact sweep up to f32 reassociation.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import backend as B
from repro.core.pagerank import SummaryBuffers
from repro.graph.graph import GraphState

_EPS = 1e-12


def _l1_normalize(x: jax.Array) -> jax.Array:
    return x / jnp.maximum(jnp.sum(jnp.abs(x)), _EPS)


@functools.partial(jax.jit, static_argnames=("num_iters", "tol", "backend"))
def hits(
    state: GraphState,
    auth0: jax.Array | None = None,
    hub0: jax.Array | None = None,
    *,
    num_iters: int = 30,
    tol: float = 0.0,
    fwd_layout: Optional[B.EdgeLayout] = None,
    rev_layout: Optional[B.EdgeLayout] = None,
    backend: Optional[str] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Full HITS power iteration.  Returns ``(auth, hub, iterations_run)``.

    With ``tol > 0`` the loop exits early once the L1 change of the
    authority vector drops below ``tol``.  ``auth0``/``hub0`` warm-start the
    iteration (both converge to the principal singular vectors from any
    positive start, so warm starts only save iterations).

    ``fwd_layout``/``rev_layout`` are optional cached unit-weight layouts
    (forward/reverse orientation — see
    :func:`repro.core.backend.build_layout`); the pallas backend sorts on
    entry when they are absent.
    """
    backend_r = B.resolve_backend(backend)
    B.require_layout(fwd_layout, weight="unit", reverse=False,
                     who="hits fwd_layout")
    B.require_layout(rev_layout, weight="unit", reverse=True,
                     who="hits rev_layout")
    n_cap = state.node_capacity
    active = state.node_active
    mask = state.edge_mask()
    n_active = jnp.maximum(state.num_active_nodes().astype(jnp.float32), 1.0)

    uniform = jnp.where(active, 1.0 / n_active, 0.0)
    a0 = uniform if auth0 is None else _l1_normalize(jnp.where(active, auth0, 0.0))
    h0 = uniform if hub0 is None else _l1_normalize(jnp.where(active, hub0, 0.0))

    if backend_r == "pallas":
        if fwd_layout is None:
            fwd_layout = B.build_layout(state, weight="unit")
        if rev_layout is None:
            rev_layout = B.build_layout(state, weight="unit", reverse=True)
    edge_w = mask.astype(jnp.float32)

    def _push_fwd(x):
        if fwd_layout is None:
            return B.push_coo(x, state.src, state.dst, n_cap, weight=edge_w)
        return B.push(x, fwd_layout, backend=backend_r)

    def _push_rev(x):
        if rev_layout is None:
            return B.push_coo(x, state.dst, state.src, n_cap, weight=edge_w)
        return B.push(x, rev_layout, backend=backend_r)

    def body(carry):
        i, a, h, _ = carry
        a_new = _l1_normalize(jnp.where(active, _push_fwd(h), 0.0))
        h_new = _l1_normalize(jnp.where(active, _push_rev(a_new), 0.0))
        delta = jnp.sum(jnp.abs(a_new - a))
        return i + 1, a_new, h_new, delta

    def cond(carry):
        i, _, _, delta = carry
        return (i < num_iters) & (delta > tol)

    i, a, h, _ = jax.lax.while_loop(
        cond, body, (jnp.int32(0), a0, h0, jnp.float32(jnp.inf))
    )
    return a, h, i


@functools.partial(jax.jit, static_argnames=("num_iters", "tol", "backend"))
def summarized_hits(
    fwd: SummaryBuffers,
    rev: SummaryBuffers,
    auth_prev: jax.Array,
    hub_prev: jax.Array,
    *,
    num_iters: int = 30,
    tol: float = 0.0,
    backend: Optional[str] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """HITS power iteration restricted to the hot set K.

    ``fwd``/``rev`` are summaries over the same hot mask (so they share
    ``hot_ids``); ``fwd.b_in`` holds the frozen cold→hot hub contribution to
    authorities and ``rev.b_in`` the frozen hot→cold authority contribution
    to hubs.

    Unlike PageRank, HITS is an eigenvector problem: the exact sweep's
    normalization divides by the global raw-update mass, which converges to
    the principal singular value σ.  The restricted sweep treats cold scores
    as a Dirichlet boundary (frozen, injected through ``b_in``) and
    normalizes each half-update by a *local* σ estimate — the growth rate of
    the hot block itself, ``σ̂ = Σ|raw| / Σ|prev|``.  With K = V the two
    normalizations are identical (both make the update sum equal the
    previous sum, and the previous sum is 1), so the r = 1.0 sweep is the
    exact sweep up to f32 reassociation.  Returns the updated *global*
    ``(auth, hub, iterations_run)``.

    Each half-iteration is one :func:`repro.core.backend.push` over its
    summary's pre-sorted E_K layout.
    """
    backend_r = B.resolve_backend(backend)
    k_cap = fwd.hot_ids.shape[0]
    local_valid = jnp.arange(k_cap, dtype=jnp.int32) < fwd.num_hot

    a0 = jnp.where(local_valid, auth_prev[fwd.hot_ids], 0.0)
    h0 = jnp.where(local_valid, hub_prev[fwd.hot_ids], 0.0)
    fwd_layout = B.summary_layout(fwd)
    rev_layout = B.summary_layout(rev)

    def half_step(prev, raw):
        """Normalize a raw half-update by the hot block's growth rate."""
        growth = jnp.sum(jnp.abs(raw)) / jnp.maximum(jnp.sum(jnp.abs(prev)), _EPS)
        # degenerate hot blocks (no internal edges, no boundary inflow)
        # keep their previous scores instead of collapsing to zero
        return jnp.where(growth > _EPS, raw / jnp.maximum(growth, _EPS), prev)

    def body(carry):
        i, a, h, _ = carry
        a_in = B.push(h, fwd_layout, backend=backend_r)
        a_new = half_step(a, jnp.where(local_valid, a_in + fwd.b_in, 0.0))
        h_in = B.push(a_new, rev_layout, backend=backend_r)
        h_new = half_step(h, jnp.where(local_valid, h_in + rev.b_in, 0.0))
        delta = jnp.sum(jnp.abs(a_new - a))
        return i + 1, a_new, h_new, delta

    def cond(carry):
        i, _, _, delta = carry
        return (i < num_iters) & (delta > tol)

    i, a_loc, h_loc, _ = jax.lax.while_loop(
        cond, body, (jnp.int32(0), a0, h0, jnp.float32(jnp.inf))
    )

    auth = auth_prev.at[fwd.hot_ids].set(a_loc, mode="drop")
    hub = hub_prev.at[fwd.hot_ids].set(h_loc, mode="drop")
    return auth, hub, i

"""HITS (hubs & authorities) — exact and VeilGraph-summarized versions.

HITS is the second propagation workload ported onto the engine's
:class:`StreamingAlgorithm` interface (beyond-paper: the paper's five-UDF
structure and hot-vertex summarization are algorithm-agnostic; PageRank is
only its case study).  The update rules are the classic mutual recursion

    auth(v) = Σ_{(u,v) ∈ E} hub(u)          (gather along in-edges)
    hub(u)  = Σ_{(u,v) ∈ E} auth(v)         (gather along out-edges)

with L1 normalization over the active vertex set each half-iteration, which
keeps 30-iteration power sweeps inside f32 range.

Both directions run through the unified :func:`repro.core.backend.push`
primitive on the ``plus_times`` semiring (unit weights are its ⊗-identity,
1): the authority update over a forward (dst-sorted) layout, the hub update
over a reverse (src-sorted) one — on the pallas backend each half-iteration
is one destination-tiled one-hot-matmul MXU kernel call.

The summarized version runs both updates only for vertices in the hot set K,
against *two* compacted summaries built by the generalized
:func:`repro.core.pagerank.build_summary`:

- a forward summary (``weight="unit"``) whose ``b_in`` freezes the hub mass
  flowing from non-hot vertices into hot authorities, and
- a reverse summary (``weight="unit", reverse=True``) whose ``b_in`` freezes
  the authority mass that hot hubs collect from their non-hot out-neighbors.

Cold scores are carried over unchanged; per-iteration normalization uses a
global σ estimate *tracked across sweeps* (measured by exact computations,
carried in the algorithm state, anchored by the frozen cold mass) so that
with K = V (r = 1.0) the summarized sweep is the exact sweep up to f32
reassociation and at partial coverage the hot block's mass stays stationary
against the frozen boundary.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import backend as B
from repro.core.pagerank import SummaryBuffers
from repro.graph.graph import GraphState

_EPS = 1e-12


def _l1_normalize(x: jax.Array) -> jax.Array:
    return x / jnp.maximum(jnp.sum(jnp.abs(x)), _EPS)


@functools.partial(jax.jit, static_argnames=("num_iters", "tol", "backend"))
def hits(
    state: GraphState,
    auth0: jax.Array | None = None,
    hub0: jax.Array | None = None,
    *,
    num_iters: int = 30,
    tol: float = 0.0,
    fwd_layout: Optional[B.EdgeLayout] = None,
    rev_layout: Optional[B.EdgeLayout] = None,
    backend: Optional[str] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Full HITS power iteration.  Returns ``(auth, hub, iterations_run,
    sigma)``.

    ``sigma`` is ``f32[2]`` — the last half-update's L1 normalizer per
    direction ``[σ_auth, σ_hub]``.  Because the iterate entering each half
    step is L1-normalized, that normalizer is the growth rate of the raw
    update, which converges to the principal singular value σ of the
    (unit-weight) adjacency operator.  Exact computations are where the
    engine *measures* σ; the summarized sweeps track it across queries and
    use it to extrapolate the frozen cold boundary's raw mass (see
    :func:`summarized_hits`).

    With ``tol > 0`` the loop exits early once the L1 change of the
    authority vector drops below ``tol``.  ``auth0``/``hub0`` warm-start the
    iteration (both converge to the principal singular vectors from any
    positive start, so warm starts only save iterations).

    ``fwd_layout``/``rev_layout`` are optional cached unit-weight layouts
    (forward/reverse orientation — see
    :func:`repro.core.backend.build_layout`); the pallas backend sorts on
    entry when they are absent.
    """
    backend_r = B.resolve_backend(backend)
    B.require_layout(fwd_layout, weight="unit", reverse=False,
                     who="hits fwd_layout")
    B.require_layout(rev_layout, weight="unit", reverse=True,
                     who="hits rev_layout")
    n_cap = state.node_capacity
    active = state.node_active
    mask = state.edge_mask()
    n_active = jnp.maximum(state.num_active_nodes().astype(jnp.float32), 1.0)

    uniform = jnp.where(active, 1.0 / n_active, 0.0)
    a0 = uniform if auth0 is None else _l1_normalize(jnp.where(active, auth0, 0.0))
    h0 = uniform if hub0 is None else _l1_normalize(jnp.where(active, hub0, 0.0))

    if backend_r == "pallas":
        if fwd_layout is None:
            fwd_layout = B.build_layout(state, weight="unit")
        if rev_layout is None:
            rev_layout = B.build_layout(state, weight="unit", reverse=True)
    edge_w = mask.astype(jnp.float32)

    def _push_fwd(x):
        if fwd_layout is None:
            return B.push_coo(x, state.src, state.dst, n_cap, weight=edge_w)
        return B.push(x, fwd_layout, backend=backend_r)

    def _push_rev(x):
        if rev_layout is None:
            return B.push_coo(x, state.dst, state.src, n_cap, weight=edge_w)
        return B.push(x, rev_layout, backend=backend_r)

    def body(carry):
        i, a, h, _, _, _ = carry
        a_raw = jnp.where(active, _push_fwd(h), 0.0)
        sig_a = jnp.sum(jnp.abs(a_raw))
        a_new = a_raw / jnp.maximum(sig_a, _EPS)
        h_raw = jnp.where(active, _push_rev(a_new), 0.0)
        sig_h = jnp.sum(jnp.abs(h_raw))
        h_new = h_raw / jnp.maximum(sig_h, _EPS)
        delta = jnp.sum(jnp.abs(a_new - a))
        return i + 1, a_new, h_new, delta, sig_a, sig_h

    def cond(carry):
        i, _, _, delta = carry[:4]
        return (i < num_iters) & (delta > tol)

    i, a, h, _, sig_a, sig_h = jax.lax.while_loop(
        cond, body,
        (jnp.int32(0), a0, h0, jnp.float32(jnp.inf), jnp.float32(1.0),
         jnp.float32(1.0)))
    return a, h, i, jnp.stack([sig_a, sig_h])


@functools.partial(jax.jit, static_argnames=("num_iters", "tol", "backend"))
def summarized_hits(
    fwd: SummaryBuffers,
    rev: SummaryBuffers,
    auth_prev: jax.Array,
    hub_prev: jax.Array,
    sigma_prev: Optional[jax.Array] = None,
    *,
    num_iters: int = 30,
    tol: float = 0.0,
    backend: Optional[str] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """HITS power iteration restricted to the hot set K.

    ``fwd``/``rev`` are summaries over the same hot mask (so they share
    ``hot_ids``); ``fwd.b_in`` holds the frozen cold→hot hub contribution to
    authorities and ``rev.b_in`` the frozen hot→cold authority contribution
    to hubs.

    Unlike PageRank, HITS is an eigenvector problem: the exact sweep's
    normalization divides by the global raw-update mass, whose growth rate
    converges to the principal singular value σ.  The restricted sweep
    treats cold scores as a Dirichlet boundary (frozen, injected through
    ``b_in``) and normalizes each half-update by a global σ estimate
    anchored to the *tracked* value ``sigma_prev`` (``f32[2] = [σ_a, σ_h]``,
    measured by the last exact computation or returned by the last
    summarized sweep — see :func:`hits`)::

        σ̂ = (Σ|raw_hot| + σ_tracked·cold) / (Σ|prev_hot| + cold)

    with ``cold = Σ|prev_global| − Σ|prev_hot|``.  The cold block never
    recomputes its raw update, but at the global fixed point that raw mass
    is exactly ``σ·cold`` — extrapolating it with the tracked σ makes the
    restricted iteration's equilibrium normalizer *pin to* ``σ_tracked``
    whenever cold mass is present, so the hot block's L1 mass is stationary
    against the boundary instead of drifting (the pre-fix estimator used
    the hot block's own growth rate alone, which pinned the hot/cold mass
    ratio even when updates genuinely shifted mass into or out of K; a
    naive ``(Σ|raw|+cold)/(Σ|prev|+cold)`` blend systematically
    underestimates σ and drifts linearly).  With K = V the cold mass is
    zero and σ̂ reduces to the exact sweep's normalization, so the r = 1.0
    sweep is still the exact sweep up to f32 reassociation — and a cold
    start with an untrusted ``sigma_prev`` under a full-coverage hot set is
    still properly normalized.  A degenerate half-update (no internal
    edges, no boundary inflow) keeps the previous scores and estimate.

    Returns the updated *global* ``(auth, hub, iterations_run, sigma)``
    where ``sigma`` is the sweep's final per-direction σ̂ — the value to
    track into the next sweep.  ``sigma_prev=None`` starts the track at 1.

    Each half-iteration is one :func:`repro.core.backend.push` over its
    summary's pre-sorted E_K layout.
    """
    backend_r = B.resolve_backend(backend)
    k_cap = fwd.hot_ids.shape[0]
    local_valid = jnp.arange(k_cap, dtype=jnp.int32) < fwd.num_hot

    sig0 = (jnp.ones((2,), jnp.float32) if sigma_prev is None
            else jnp.asarray(sigma_prev, jnp.float32))
    a0 = jnp.where(local_valid, auth_prev[fwd.hot_ids], 0.0)
    h0 = jnp.where(local_valid, hub_prev[fwd.hot_ids], 0.0)
    # frozen cold L1 mass per direction — constant across the sweep (cold
    # scores are the Dirichlet boundary), computed once outside the loop
    cold_a = jnp.maximum(
        jnp.sum(jnp.abs(auth_prev)) - jnp.sum(jnp.abs(a0)), 0.0)
    cold_h = jnp.maximum(
        jnp.sum(jnp.abs(hub_prev)) - jnp.sum(jnp.abs(h0)), 0.0)
    fwd_layout = B.summary_layout(fwd)
    rev_layout = B.summary_layout(rev)

    def half_step(prev, raw, cold, anchor, sigma_last):
        """Normalize a raw half-update by the anchored global-σ estimate."""
        mass = jnp.sum(jnp.abs(raw)) + cold
        growth = ((jnp.sum(jnp.abs(raw)) + anchor * cold)
                  / jnp.maximum(jnp.sum(jnp.abs(prev)) + cold, _EPS))
        # degenerate hot blocks (no internal edges, no boundary inflow)
        # keep their previous scores and carry the last well-defined σ̂
        ok = mass > _EPS
        sigma = jnp.where(ok, growth, sigma_last)
        return (jnp.where(ok, raw / jnp.maximum(sigma, _EPS), prev), sigma)

    def body(carry):
        i, a, h, _, sig_a, sig_h = carry
        a_in = B.push(h, fwd_layout, backend=backend_r)
        a_new, sig_a = half_step(
            a, jnp.where(local_valid, a_in + fwd.b_in, 0.0), cold_a,
            sig0[0], sig_a)
        h_in = B.push(a_new, rev_layout, backend=backend_r)
        h_new, sig_h = half_step(
            h, jnp.where(local_valid, h_in + rev.b_in, 0.0), cold_h,
            sig0[1], sig_h)
        delta = jnp.sum(jnp.abs(a_new - a))
        return i + 1, a_new, h_new, delta, sig_a, sig_h

    def cond(carry):
        i, _, _, delta = carry[:4]
        return (i < num_iters) & (delta > tol)

    i, a_loc, h_loc, _, sig_a, sig_h = jax.lax.while_loop(
        cond, body,
        (jnp.int32(0), a0, h0, jnp.float32(jnp.inf), sig0[0], sig0[1]))

    auth = auth_prev.at[fwd.hot_ids].set(a_loc, mode="drop")
    hub = hub_prev.at[fwd.hot_ids].set(h_loc, mode="drop")
    return auth, hub, i, jnp.stack([sig_a, sig_h])


@functools.partial(jax.jit, static_argnames=("num_iters", "tol", "backend"))
def summarized_hits_batched(
    fwd: SummaryBuffers,
    rev: SummaryBuffers,
    auth_prev: jax.Array,
    hub_prev: jax.Array,
    sigma_prev: Optional[jax.Array] = None,
    *,
    num_iters: int = 30,
    tol: float = 0.0,
    row_mask: Optional[jax.Array] = None,
    backend: Optional[str] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array, jax.Array]:
    """Batched :func:`summarized_hits`: ``[B, N]`` auth/hub matrices
    sharing one fwd/rev summary pair, with the per-row anchored-σ
    normalization of the single-query sweep (cold mass and σ̂ are ``[B]``
    vectors; ``sigma_prev`` is the ``[B, 2]`` tracked anchor, None → 1s).
    ``row_mask`` (bool[B]) freezes finished/vacant slots — their scores
    *and* their tracked σ.  Returns ``(auth [B, N], hub [B, N],
    iterations, row_delta [B], sigma [B, 2])``.
    """
    backend_r = B.resolve_backend(backend)
    batch = auth_prev.shape[0]
    k_cap = fwd.hot_ids.shape[0]
    local_valid = jnp.arange(k_cap, dtype=jnp.int32) < fwd.num_hot

    sig0 = (jnp.ones((batch, 2), jnp.float32) if sigma_prev is None
            else jnp.asarray(sigma_prev, jnp.float32))
    a0 = jnp.where(local_valid, auth_prev[:, fwd.hot_ids], 0.0)
    h0 = jnp.where(local_valid, hub_prev[:, fwd.hot_ids], 0.0)
    cold_a = jnp.maximum(
        jnp.sum(jnp.abs(auth_prev), axis=1) - jnp.sum(jnp.abs(a0), axis=1),
        0.0)
    cold_h = jnp.maximum(
        jnp.sum(jnp.abs(hub_prev), axis=1) - jnp.sum(jnp.abs(h0), axis=1),
        0.0)
    live = (jnp.ones((batch,), bool) if row_mask is None else row_mask)
    keep = live[:, None]
    fwd_layout = B.summary_layout(fwd)
    rev_layout = B.summary_layout(rev)

    def half_step(prev, raw, cold, anchor, sigma_last):
        mass = jnp.sum(jnp.abs(raw), axis=1) + cold
        growth = ((jnp.sum(jnp.abs(raw), axis=1) + anchor * cold)
                  / jnp.maximum(jnp.sum(jnp.abs(prev), axis=1) + cold, _EPS))
        ok = (mass > _EPS) & live
        sigma = jnp.where(ok, growth, sigma_last)
        scaled = jnp.where(ok[:, None],
                           raw / jnp.maximum(sigma, _EPS)[:, None], prev)
        return jnp.where(keep, scaled, prev), sigma

    def body(carry):
        i, a, h, _, sig_a, sig_h = carry
        a_in = B.push(h, fwd_layout, backend=backend_r)
        a_new, sig_a = half_step(
            a, jnp.where(local_valid, a_in + fwd.b_in, 0.0), cold_a,
            sig0[:, 0], sig_a)
        h_in = B.push(a_new, rev_layout, backend=backend_r)
        h_new, sig_h = half_step(
            h, jnp.where(local_valid, h_in + rev.b_in, 0.0), cold_h,
            sig0[:, 1], sig_h)
        delta = jnp.sum(jnp.abs(a_new - a), axis=1)
        return i + 1, a_new, h_new, delta, sig_a, sig_h

    def cond(carry):
        i, _, _, delta = carry[:4]
        return (i < num_iters) & (jnp.max(delta) > tol)

    i, a_loc, h_loc, delta, sig_a, sig_h = jax.lax.while_loop(
        cond, body,
        (jnp.int32(0), a0, h0, jnp.full((batch,), jnp.inf, jnp.float32),
         sig0[:, 0], sig0[:, 1]))

    auth = auth_prev.at[:, fwd.hot_ids].set(a_loc, mode="drop")
    hub = hub_prev.at[:, fwd.hot_ids].set(h_loc, mode="drop")
    auth = jnp.where(keep, auth, auth_prev)
    hub = jnp.where(keep, hub, hub_prev)
    return auth, hub, i, delta, jnp.stack([sig_a, sig_h], axis=1)

"""HITS (hubs & authorities) — exact and VeilGraph-summarized versions.

HITS is the second propagation workload ported onto the engine's
:class:`StreamingAlgorithm` interface (beyond-paper: the paper's five-UDF
structure and hot-vertex summarization are algorithm-agnostic; PageRank is
only its case study).  The update rules are the classic mutual recursion

    auth(v) = Σ_{(u,v) ∈ E} hub(u)          (gather along in-edges)
    hub(u)  = Σ_{(u,v) ∈ E} auth(v)         (gather along out-edges)

with L1 normalization over the active vertex set each half-iteration, which
keeps 30-iteration power sweeps inside f32 range.

The summarized version runs both updates only for vertices in the hot set K,
against *two* compacted summaries built by the generalized
:func:`repro.core.pagerank.build_summary`:

- a forward summary (``weight="unit"``) whose ``b_in`` freezes the hub mass
  flowing from non-hot vertices into hot authorities, and
- a reverse summary (``weight="unit", reverse=True``) whose ``b_in`` freezes
  the authority mass that hot hubs collect from their non-hot out-neighbors.

Cold scores are carried over unchanged; per-iteration normalization counts
the frozen cold mass so that with K = V (r = 1.0) the summarized sweep is
the exact sweep up to f32 reassociation.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.pagerank import SummaryBuffers
from repro.graph.graph import GraphState

_EPS = 1e-12


def _l1_normalize(x: jax.Array) -> jax.Array:
    return x / jnp.maximum(jnp.sum(jnp.abs(x)), _EPS)


@functools.partial(jax.jit, static_argnames=("num_iters", "tol"))
def hits(
    state: GraphState,
    auth0: jax.Array | None = None,
    hub0: jax.Array | None = None,
    *,
    num_iters: int = 30,
    tol: float = 0.0,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Full HITS power iteration.  Returns ``(auth, hub, iterations_run)``.

    With ``tol > 0`` the loop exits early once the L1 change of the
    authority vector drops below ``tol``.  ``auth0``/``hub0`` warm-start the
    iteration (both converge to the principal singular vectors from any
    positive start, so warm starts only save iterations).
    """
    n_cap = state.node_capacity
    active = state.node_active
    mask = state.edge_mask()
    n_active = jnp.maximum(state.num_active_nodes().astype(jnp.float32), 1.0)

    uniform = jnp.where(active, 1.0 / n_active, 0.0)
    a0 = uniform if auth0 is None else _l1_normalize(jnp.where(active, auth0, 0.0))
    h0 = uniform if hub0 is None else _l1_normalize(jnp.where(active, hub0, 0.0))

    def body(carry):
        i, a, h, _ = carry
        a_in = jax.ops.segment_sum(
            jnp.where(mask, h[state.src], 0.0), state.dst, num_segments=n_cap
        )
        a_new = _l1_normalize(jnp.where(active, a_in, 0.0))
        h_in = jax.ops.segment_sum(
            jnp.where(mask, a_new[state.dst], 0.0), state.src, num_segments=n_cap
        )
        h_new = _l1_normalize(jnp.where(active, h_in, 0.0))
        delta = jnp.sum(jnp.abs(a_new - a))
        return i + 1, a_new, h_new, delta

    def cond(carry):
        i, _, _, delta = carry
        return (i < num_iters) & (delta > tol)

    i, a, h, _ = jax.lax.while_loop(
        cond, body, (jnp.int32(0), a0, h0, jnp.float32(jnp.inf))
    )
    return a, h, i


@functools.partial(jax.jit, static_argnames=("num_iters", "tol"))
def summarized_hits(
    fwd: SummaryBuffers,
    rev: SummaryBuffers,
    auth_prev: jax.Array,
    hub_prev: jax.Array,
    *,
    num_iters: int = 30,
    tol: float = 0.0,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """HITS power iteration restricted to the hot set K.

    ``fwd``/``rev`` are summaries over the same hot mask (so they share
    ``hot_ids``); ``fwd.b_in`` holds the frozen cold→hot hub contribution to
    authorities and ``rev.b_in`` the frozen hot→cold authority contribution
    to hubs.

    Unlike PageRank, HITS is an eigenvector problem: the exact sweep's
    normalization divides by the global raw-update mass, which converges to
    the principal singular value σ.  The restricted sweep treats cold scores
    as a Dirichlet boundary (frozen, injected through ``b_in``) and
    normalizes each half-update by a *local* σ estimate — the growth rate of
    the hot block itself, ``σ̂ = Σ|raw| / Σ|prev|``.  With K = V the two
    normalizations are identical (both make the update sum equal the
    previous sum, and the previous sum is 1), so the r = 1.0 sweep is the
    exact sweep up to f32 reassociation.  Returns the updated *global*
    ``(auth, hub, iterations_run)``.
    """
    k_cap = fwd.hot_ids.shape[0]
    local_valid = jnp.arange(k_cap, dtype=jnp.int32) < fwd.num_hot

    a0 = jnp.where(local_valid, auth_prev[fwd.hot_ids], 0.0)
    h0 = jnp.where(local_valid, hub_prev[fwd.hot_ids], 0.0)

    def half_step(prev, raw):
        """Normalize a raw half-update by the hot block's growth rate."""
        growth = jnp.sum(jnp.abs(raw)) / jnp.maximum(jnp.sum(jnp.abs(prev)), _EPS)
        # degenerate hot blocks (no internal edges, no boundary inflow)
        # keep their previous scores instead of collapsing to zero
        return jnp.where(growth > _EPS, raw / jnp.maximum(growth, _EPS), prev)

    def body(carry):
        i, a, h, _ = carry
        a_in = jax.ops.segment_sum(
            h[fwd.ek_src] * fwd.ek_w, fwd.ek_dst, num_segments=k_cap
        )
        a_new = half_step(a, jnp.where(local_valid, a_in + fwd.b_in, 0.0))
        h_in = jax.ops.segment_sum(
            a_new[rev.ek_src] * rev.ek_w, rev.ek_dst, num_segments=k_cap
        )
        h_new = half_step(h, jnp.where(local_valid, h_in + rev.b_in, 0.0))
        delta = jnp.sum(jnp.abs(a_new - a))
        return i + 1, a_new, h_new, delta

    def cond(carry):
        i, _, _, delta = carry
        return (i < num_iters) & (delta > tol)

    i, a_loc, h_loc, _ = jax.lax.while_loop(
        cond, body, (jnp.int32(0), a0, h0, jnp.float32(jnp.inf))
    )

    auth = auth_prev.at[fwd.hot_ids].set(a_loc, mode="drop")
    hub = hub_prev.at[fwd.hot_ids].set(h_loc, mode="drop")
    return auth, hub, i

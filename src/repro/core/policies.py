"""Built-in OnQuery / BeforeUpdates policies (paper §4: "for simple rules,
these functions don't need to be programmed").

Each factory returns a callable with the engine's UDF signature.  These map
directly to the paper's three action indicators: repeat-last-answer,
compute-approximate, compute-exact.
"""

from __future__ import annotations

from typing import Callable, Dict

from repro.core.algorithm import Action


def _updates_since_compute(view: Dict) -> int:
    """Updates not yet reflected in the current scores: those integrated
    under earlier repeat-last answers plus this query's batch (applied or
    still buffered — BeforeUpdates may have deferred application).  The view
    is refreshed after ApplyUpdates, so ``pending`` alone would read 0 once
    the engine has integrated the batch."""
    if "since_compute" in view:
        return int(view["since_compute"])
    return int(view.get("applied", 0)) + int(view.get("pending", 0))


def always(action: Action) -> Callable[[int, Dict], Action]:
    """Fixed action every query (the paper's evaluation uses always-approx)."""
    def policy(query_id: int, view: Dict) -> Action:
        return action
    return policy


def repeat_below_threshold(min_pending: int) -> Callable[[int, Dict], Action]:
    """Repeat the last answer when fewer than ``min_pending`` updates have
    arrived since the last computed answer; otherwise approximate (paper §7:
    "repeating the last results if the updates were not deemed
    significant")."""
    def policy(query_id: int, view: Dict) -> Action:
        if _updates_since_compute(view) < min_pending:
            return Action.REPEAT_LAST
        return Action.APPROXIMATE
    return policy


def exact_above_entropy(max_update_ratio: float) -> Callable[[int, Dict], Action]:
    """Exact recompute when accumulated updates exceed a fraction of |E|
    (paper §7: "performing an exact computation if too much entropy has
    accumulated"); otherwise approximate."""
    def policy(query_id: int, view: Dict) -> Action:
        if view["num_edges"] > 0 and \
                _updates_since_compute(view) / view["num_edges"] > max_update_ratio:
            return Action.EXACT
        return Action.APPROXIMATE
    return policy


def periodic_exact(every: int) -> Callable[[int, Dict], Action]:
    """Exact refresh every ``every`` queries to bound error accumulation
    (beyond-paper: counteracts the RBO drift the paper observes in Figs 5/9/…)."""
    def policy(query_id: int, view: Dict) -> Action:
        if every > 0 and query_id > 0 and query_id % every == 0:
            return Action.EXACT
        return Action.APPROXIMATE
    return policy

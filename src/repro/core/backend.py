"""Unified propagation backend: one ``push`` primitive for every sweep.

Every power sweep in the repo — exact PageRank, summarized PageRank, both
HITS directions, Katz, SSSP relaxations, connected-components label
propagation, ``build_summary``'s frozen big-vertex pass and the
algorithm-generic fused query step — is the same primitive applied to a
different edge layout under a different algebra:

    out[v] = ⊕ over in-edges (u, v) of ( values[u] ⊗ weight(u, v) )

The (⊕, ⊗) pair is an explicit :class:`~repro.core.semiring.Semiring`
(``plus_times`` sum-of-products, ``min_plus`` shortest paths, ``min_min``
label-min over int32, ``max_times`` widest paths — see
:mod:`repro.core.semiring`).  This module owns the primitive and its two
implementations:

- ``"pallas"``  — the destination-tiled MXU/VPU kernels in
  :mod:`repro.kernels.spmv.kernel` (Mosaic on TPU, ``interpret`` mode
  elsewhere), consuming a receiver-sorted edge stream with per-tile
  ranges: the one-hot matmul for ``sum`` reductions, the tiled
  masked-reduce variant for ``min``/``max``;
- ``"segment_sum"`` — :func:`repro.graph.csr.gather_push`, an
  ``indices_are_sorted`` XLA segment-sum/min/max over the same sorted
  stream.

Both consume an :class:`EdgeLayout`: the receiver-sorted edge stream with
the per-edge weight baked in, in the semiring's dtype (``1/d_out(u)`` for
PageRank-style sweeps, the ⊗-identity for ``"unit"`` layouts, per-edge
lengths for ``"length"`` ones).  Sorting is the amortizable cost — layouts
are built once per applied update batch (the engine caches them; see
``VeilGraphEngine.edge_layouts``), reused across queries, and within one
query across all ~30 power iterations.

Backend selection
-----------------
``resolve_backend(None)`` picks per device: ``"pallas"`` when JAX's default
backend is TPU, ``"segment_sum"`` otherwise.  The ``VEILGRAPH_BACKEND``
environment variable overrides (values: ``pallas``, ``segment_sum``,
``auto``), and every sweep/engine entry point takes an explicit ``backend=``
knob that overrides both.  Resolution happens at trace time; a changed
environment variable does not invalidate already-compiled sweeps.

Sharded execution
-----------------
``push`` is mesh-aware: hand it a :class:`ShardedEdgeLayout` (built by
:func:`repro.graph.partition.build_sharded_layout` — the edge stream cut
into contiguous shards, each destination-sorted *locally* so no sort ever
crosses a shard boundary) and the same primitive runs as a
``shard_map``-ed partial push per shard followed by one semiring
all-reduce of the dense node vector (``psum``/``pmin``/``pmax`` per the
(⊕, ⊗) pair — min/max reductions stay bitwise identical to the
single-device result, sums differ only by f32 summation order).  Either
backend runs *inside* each shard, so the Pallas MXU kernels lower under
GSPMD too.  Without a mesh attached the same layout runs as a sequential
per-shard loop on one device — the reference semantics the parity tests
pin the distributed path against.
"""

from __future__ import annotations

import collections
import dataclasses
import functools
import os
from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.semiring import Semiring, resolve_semiring
from repro.graph.csr import SortedEdges, gather_push, sort_by_dst
from repro.graph.graph import GraphState, inv_out_degree
from repro.kernels.spmv.kernel import (CHUNK, TILE_N, spmv_push,
                                       spmv_push_batched, spmv_reduce_push,
                                       spmv_reduce_push_batched)

# jax promoted shard_map out of jax.experimental across 0.4.x/0.5.x
if hasattr(jax, "shard_map"):  # pragma: no cover - version-dependent
    _shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map as _shard_map

BACKENDS = ("segment_sum", "pallas")

#: weight modes an EdgeLayout can bake: ``inv_out`` = 1/d_out(u) (PageRank
#: emission; plus_times only), ``unit`` = the semiring's ⊗-identity,
#: ``length`` = per-edge lengths (default 1) for min_plus-style relaxations.
WEIGHT_MODES = ("inv_out", "unit", "length")

#: env override for backend selection (read at trace time)
BACKEND_ENV_VAR = "VEILGRAPH_BACKEND"


def resolve_backend(backend: Optional[str] = None) -> str:
    """Resolve ``None``/``"auto"`` to a concrete backend name.

    Priority: explicit argument > ``$VEILGRAPH_BACKEND`` > device default
    (TPU → ``"pallas"``, anything else → ``"segment_sum"``).
    """
    if backend in (None, "auto"):
        backend = os.environ.get(BACKEND_ENV_VAR, "auto")
    if backend in (None, "auto", ""):
        backend = "pallas" if jax.default_backend() == "tpu" else "segment_sum"
    if backend not in BACKENDS:
        raise ValueError(
            f"unknown backend {backend!r}; expected one of "
            f"{BACKENDS + ('auto',)}")
    return backend


def default_interpret() -> bool:
    """Pallas runs as a compiled Mosaic kernel only on TPU; everywhere else
    the kernel body executes in interpret mode (how CI validates it)."""
    return jax.default_backend() != "tpu"


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=("src", "dst", "weight", "valid", "row_offsets", "order",
                 "rank"),
    meta_fields=("weight_mode", "reverse", "pad_chunk", "semiring", "tile_n",
                 "tile_chunk"),
)
@dataclasses.dataclass(frozen=True)
class EdgeLayout:
    """Receiver-sorted edge stream with baked per-edge weights.

    The propagation-ready form of :class:`~repro.graph.csr.SortedEdges`:
    same sorted order plus the per-edge multiplier, padded by at least one
    kernel chunk so the Pallas kernel's fixed-size chunk loads never run
    past the buffer.  ``dst`` holds ``num_segments`` in padding slots and
    ``weight`` the semiring's ⊕-identity there (0 for sum-of-products,
    ±∞/int extrema for min/max reductions), so both backends ignore
    padding without branching.

    ``row_offsets`` (int32[num_segments + 1]) gives the edge range per
    receiver; per-tile kernel ranges for any tile size derive from it with
    one gather, so one cached layout serves every ``tile_n``.

    ``weight_mode``/``reverse``/``semiring`` record how the layout was
    built and ``pad_chunk`` how much chunk slack the stream was padded
    with; they ride through jit as static metadata so consumers can reject
    a mismatched cached layout at trace time (:func:`require_layout`, the
    semiring check and ``chunk`` bound in :func:`push`) instead of
    silently mis-weighting, mis-padding, or reading out of bounds.
    """

    src: jax.Array          # int32[E_pad] emitting endpoint (sorted order)
    dst: jax.Array          # int32[E_pad] receiving endpoint (sentinel = N)
    weight: jax.Array       # dtype[E_pad] per-edge operand (⊕-id if invalid)
    valid: jax.Array        # bool[E_pad]
    row_offsets: jax.Array  # int32[num_segments + 1]
    #: original edge slot per sorted position (sentinel = edge_capacity in
    #: padding) — lets consumers map baked weights back to slot order
    #: (build_summary recovers per-edge lengths this way).  None for
    #: summary layouts, whose edge space is already compacted.
    order: Optional[jax.Array] = None
    #: per-edge rank within its destination run (``i - row_offsets[dst_i]``
    #: in sorted order; 0 in padding) — the segmented-scan reduce kernel's
    #: same-run test.  Only baked for min/max-semiring layouts (``push``
    #: derives it inline otherwise).
    rank: Optional[jax.Array] = None
    weight_mode: str = "inv_out"
    reverse: bool = False
    pad_chunk: int = CHUNK
    semiring: str = "plus_times"
    #: autotuned kernel geometry (static, ``None`` = kernel defaults):
    #: stamped at build time by the engine's autotune pass so every
    #: consuming sweep picks the tuned ``(tile_n, chunk)`` with no user
    #: knobs — ``push`` resolves explicit argument > layout meta > default.
    tile_n: Optional[int] = None
    tile_chunk: Optional[int] = None

    @property
    def num_segments(self) -> int:
        """Size of the receiver/node space this layout pushes into."""
        return self.row_offsets.shape[0] - 1


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=("src", "dst", "weight", "valid", "row_offsets", "order",
                 "rank"),
    meta_fields=("weight_mode", "reverse", "pad_chunk", "semiring", "tile_n",
                 "tile_chunk", "mesh", "axes"),
)
@dataclasses.dataclass(frozen=True)
class ShardedEdgeLayout:
    """Edge-partitioned sibling of :class:`EdgeLayout`: one locally
    destination-sorted stream per shard, stacked along a leading shard axis.

    Built by :func:`repro.graph.partition.build_sharded_layout`: the COO
    buffer is cut into ``num_shards`` contiguous slot ranges (so a
    1-D-edge-sharded buffer reshapes onto the shard axis with zero
    communication) and each shard is sorted by receiving endpoint
    *independently* — the amortized sort never crosses a shard boundary,
    which is what makes the cached-layout backend viable under GSPMD where
    a global pod-scale argsort would defeat the edge sharding.

    Every per-shard row carries the same invariants as a single
    :class:`EdgeLayout` (baked ⊗-operand, ⊕-identity padding, per-receiver
    ``row_offsets`` over the full ``num_segments`` node space, ≥ one chunk
    of slack), so :func:`push` runs the ordinary single-shard kernel inside
    each shard and completes the ⊕ with one collective.

    ``mesh``/``axes`` are static metadata naming where the shard axis
    lives: ``mesh=None`` means no device mapping — :func:`push` then loops
    shards sequentially and merges partials on one device (the reference
    semantics).  With a mesh, the leading axis is ``shard_map``-ed over
    ``axes`` (``num_shards`` must be a multiple of the axes' total device
    count; the per-device surplus shards loop locally).
    """

    src: jax.Array          # int32[S, E_pad] emitting endpoint (sorted)
    dst: jax.Array          # int32[S, E_pad] receiving endpoint (sentinel=N)
    weight: jax.Array       # dtype[S, E_pad] per-edge operand (⊕-id invalid)
    valid: jax.Array        # bool[S, E_pad]
    row_offsets: jax.Array  # int32[S, num_segments + 1]
    #: original edge slot per (shard, sorted position); sentinel =
    #: edge_capacity in padding — the partition certificate (each live slot
    #: appears in exactly one shard) and the lengths back-map.
    order: Optional[jax.Array] = None
    #: per-(shard, position) rank within its destination run — the
    #: segmented-scan reduce kernel's same-run test, baked only for
    #: min/max-semiring layouts (see :class:`EdgeLayout`).
    rank: Optional[jax.Array] = None
    weight_mode: str = "inv_out"
    reverse: bool = False
    pad_chunk: int = CHUNK
    semiring: str = "plus_times"
    #: autotuned kernel geometry (static; see :class:`EdgeLayout`)
    tile_n: Optional[int] = None
    tile_chunk: Optional[int] = None
    mesh: Optional[Mesh] = None
    axes: Tuple[str, ...] = ()

    @property
    def num_shards(self) -> int:
        """Number of edge shards stacked along the leading axis."""
        return self.row_offsets.shape[0]

    @property
    def num_segments(self) -> int:
        """Size of the receiver/node space (shared by every shard)."""
        return self.row_offsets.shape[1] - 1


#: layout kinds push() accepts
AnyEdgeLayout = Union[EdgeLayout, ShardedEdgeLayout]


def padded_length(e: int, chunk: int) -> int:
    """Stream length after chunk-slack padding — the next chunk multiple
    plus one spare chunk, so the kernel's fixed-size dynamic loads never
    run past the buffer.  The one definition every layout builder (single
    and sharded) pads with."""
    return (e // chunk + 2) * chunk


def validate_weight_dtype(weight_dtype: Optional[str],
                          s: Semiring) -> Optional[str]:
    """Trace-time check for compressed edge-weight storage: only the f32
    semirings may store weights in a narrower float dtype (accumulation
    stays f32 via jnp promotion — ``bf16 ⊗ f32 → f32``); the int32
    ``min_min`` family has no narrow storage form."""
    if weight_dtype is None:
        return None
    dt = jnp.dtype(weight_dtype)
    if dt == jnp.dtype(s.dtype):
        return None  # storage dtype == semiring dtype: nothing to compress
    if jnp.dtype(s.dtype) != jnp.float32 or dt not in (
            jnp.dtype(jnp.bfloat16), jnp.dtype(jnp.float16)):
        raise ValueError(
            f"weight_dtype={weight_dtype!r} is not a storage form of "
            f"semiring {s.name!r} ({s.dtype}); compressed weights need an "
            f"f32 semiring and a bfloat16/float16 storage dtype")
    return str(dt)


def bake_weights(s: Semiring, weight: str, valid: jax.Array,
                 src: jax.Array, *, inv_deg=None,
                 lengths=None, weight_dtype: Optional[str] = None
                 ) -> jax.Array:
    """The per-edge ⊗-operand for a stream, per weight mode — the single
    definition of what ``inv_out``/``unit``/``length`` bake, shared by the
    single and sharded layout builders so the two cannot drift.

    ``valid``/``src``/``lengths`` are aligned to the caller's stream order
    (sorted or slot order — the caller gathers); ``inv_deg`` is the
    node-space ``1/d_out`` vector for ``inv_out``.  ``lengths=None`` under
    ``weight="length"`` means unit hop counts.  Invalid slots bake the
    semiring's ⊕-identity so they never contribute.

    ``weight_dtype`` optionally narrows the *storage* dtype (bf16 halves
    the weight stream's HBM traffic); the ⊗ with f32 node values promotes
    back to f32, so accumulation precision is unchanged.
    """
    dtype = jnp.dtype(s.dtype)
    zero = jnp.asarray(s.zero, dtype)
    if weight == "inv_out":
        w = jnp.where(valid, inv_deg[src], 0.0)
    elif weight == "unit":
        w = jnp.where(valid, jnp.asarray(s.one, dtype), zero)
    else:
        per_edge = (jnp.asarray(1, dtype) if lengths is None
                    else lengths.astype(dtype))
        w = jnp.where(valid, per_edge, zero)
    if validate_weight_dtype(weight_dtype, s) is not None:
        w = w.astype(weight_dtype)
    return w


def stream_rank(dst: jax.Array, valid: jax.Array,
                row_offsets: jax.Array) -> jax.Array:
    """Per-edge rank within its destination run (``i - row_offsets[dst_i]``
    over the sorted stream; 0 in invalid/padding slots so the segmented
    scan's ``rank >= offset`` test never crosses into them).  Baked at
    layout-build time for min/max-semiring layouts; :func:`push` computes
    it inline for layouts that lack it."""
    num_segments = row_offsets.shape[0] - 1
    idx = jnp.arange(dst.shape[0], dtype=jnp.int32)
    start = row_offsets[jnp.minimum(dst, num_segments)]
    return jnp.where(valid, idx - start, 0)


def _pad_stream(src, dst, weight, valid, *, sentinel: int, chunk: int,
                zero=0.0):
    """Pad the sorted stream to a chunk multiple plus one spare chunk;
    padded weight slots hold ``zero`` (the consuming semiring's
    ⊕-identity) so they never contribute."""
    e = src.shape[0]
    pad = padded_length(e, chunk) - e
    return (
        jnp.pad(src, (0, pad)),
        jnp.pad(dst, (0, pad), constant_values=sentinel),
        jnp.pad(weight, (0, pad), constant_values=zero),
        jnp.pad(valid, (0, pad)),
    )


def validate_weight_spec(weight: str, *, reverse: bool = False,
                         semiring="plus_times", lengths=None,
                         edge_capacity: Optional[int] = None) -> "Semiring":
    """Shared trace-time checks for every (weight, reverse, semiring)
    consumer — :func:`build_layout` and ``build_summary`` must accept
    exactly the same spec space or layouts and summaries drift apart.
    Returns the resolved semiring."""
    s = resolve_semiring(semiring)
    if weight not in WEIGHT_MODES:
        raise ValueError(f"unknown weight mode {weight!r}; expected one of "
                         f"{WEIGHT_MODES}")
    if reverse and weight == "inv_out":
        raise ValueError(
            "reverse=True requires weight='unit' or 'length': inv_out "
            "would normalize by the out-degree of the receiving endpoint")
    if weight == "inv_out" and (s.add, s.mul) != ("sum", "times"):
        raise ValueError(
            "weight='inv_out' (1/d_out emission) is a sum-of-products "
            f"notion; semiring {s.name!r} needs 'unit' or 'length' weights")
    if lengths is not None and weight != "length":
        raise ValueError("lengths= is only meaningful with weight='length'")
    if (lengths is not None and edge_capacity is not None
            and lengths.shape[0] != edge_capacity):
        # a shorter array would silently clamp-gather its last element into
        # every higher edge slot (streamed edges land beyond the initial
        # edge list) — fail loudly at trace time instead
        raise ValueError(
            f"lengths must cover every edge slot: got shape "
            f"{lengths.shape}, edge_capacity={edge_capacity}")
    return s


@functools.partial(
    jax.jit, static_argnames=("weight", "reverse", "chunk", "semiring",
                              "tile_n", "weight_dtype"))
def build_layout(
    state: GraphState,
    *,
    weight: str = "inv_out",
    reverse: bool = False,
    chunk: int = CHUNK,
    semiring: str = "plus_times",
    lengths: Optional[jax.Array] = None,
    tile_n: Optional[int] = None,
    weight_dtype: Optional[str] = None,
) -> EdgeLayout:
    """Full-graph propagation layout, sorted once per call.

    ``weight`` picks the baked per-edge ⊗-operand:

    - ``"inv_out"`` — ``1/d_out(u)`` (PageRank-style emission; only
      meaningful under ``plus_times`` and the forward orientation);
    - ``"unit"``    — the semiring's ⊗-identity (1 for sum-of-products —
      HITS/Katz — but e.g. +∞ for ``min_min`` so labels pass through
      unchanged);
    - ``"length"``  — per-edge lengths for ``min_plus``-style relaxations:
      ``lengths`` (dtype[E_cap], indexed by edge slot) if given, else the
      graph's streamed ``state.edge_len`` column if present, else 1 per
      edge (hop counts).

    ``reverse=True`` builds the transposed layout (receivers are original
    sources — the HITS hub direction / CC's symmetric pass).  Invalid and
    padding slots bake the semiring's ⊕-identity so they never contribute.

    Degrees are baked into ``weight``, so a layout is valid exactly until
    the next applied update batch — the engine invalidates its cache then.

    ``tile_n`` stamps an autotuned output-tile width onto the layout (and
    ``chunk`` doubles as the tuned stream chunk, since the pad slack must
    cover it); :func:`push` then picks the tuned geometry with no per-call
    knobs.  ``weight_dtype`` selects compressed weight storage (bf16
    stream, f32 accumulation — see :func:`bake_weights`).
    """
    record_trace("build_layout")
    if weight == "length" and lengths is None:
        lengths = state.edge_len  # streamed per-edge lengths, if any
    s = validate_weight_spec(weight, reverse=reverse, semiring=semiring,
                             lengths=lengths,
                             edge_capacity=state.edge_capacity)
    se = sort_by_dst(state, reverse=reverse)
    w = bake_weights(
        s, weight, se.valid, se.src, inv_deg=inv_out_degree(state),
        # slot-order lengths follow the sort through se.order
        lengths=None if lengths is None else lengths[se.order],
        weight_dtype=weight_dtype)
    src, dst, w, valid = _pad_stream(
        se.src, se.dst, w, se.valid,
        sentinel=state.node_capacity, chunk=chunk, zero=s.zero)
    order = jnp.pad(se.order, (0, src.shape[0] - se.order.shape[0]),
                    constant_values=state.edge_capacity)
    rank = (stream_rank(dst, valid, se.row_offsets)
            if s.add != "sum" else None)
    return EdgeLayout(src, dst, w, valid, se.row_offsets, order, rank,
                      weight_mode=weight, reverse=reverse, pad_chunk=chunk,
                      semiring=s.name, tile_n=tile_n, tile_chunk=chunk)


def summary_layout(summary, *, chunk: int = CHUNK,
                   semiring: str = "plus_times") -> AnyEdgeLayout:
    """Propagation layout over a summary's compacted, pre-sorted E_K buffer.

    :func:`repro.core.pagerank.build_summary` already emits E_K sorted by
    local destination with ``ek_row_offsets``; this only derives validity
    and pads for the kernel — flat summaries keep valid edges first, and
    the stacked per-shard form (a summary built through a
    :class:`ShardedEdgeLayout`) marks padding with the ``K_cap`` sentinel
    destination.  A sharded summary yields a :class:`ShardedEdgeLayout`
    carrying the summary's ``mesh``/``axes``, so the consuming sweep's
    :func:`push` runs shard_map-ed per-shard partial pushes + the
    semiring's all-reduce with no further changes.

    ``semiring`` must match the one the summary's ``ek_w``/``b_in`` were
    baked for (checked at trace time against the summary's recorded
    metadata — a ``plus_times`` reduce over +∞-baked min-semiring buffers
    would silently produce NaNs).  Traced inline — call it outside the
    power loop so padding happens once per query, not once per iteration.
    """
    record_trace("summary_layout")
    s = resolve_semiring(semiring)
    baked = getattr(summary, "semiring", None)
    if baked is not None and baked != s.name:
        raise ValueError(
            f"summary_layout(semiring={s.name!r}) over a summary baked for "
            f"{baked!r}; rebuild the summary for this semiring")
    k_cap = summary.hot_ids.shape[0]
    # summaries built through a tuned layout inherit its kernel geometry
    # (stamped as SummaryBuffers meta); older/bare summaries fall back to
    # the kernel defaults
    tile_n = getattr(summary, "tile_n", None)
    tile_chunk = getattr(summary, "tile_chunk", None)
    if tile_chunk is not None:
        chunk = tile_chunk
    if summary.ek_src.ndim == 2:  # stacked per-shard E_K form
        h_s = summary.ek_src.shape[1]
        extra = padded_length(h_s, chunk) - h_s
        pad2 = lambda x, cval: jnp.pad(x, ((0, 0), (0, extra)),
                                       constant_values=cval)
        valid = summary.ek_dst < k_cap
        dst = pad2(summary.ek_dst, k_cap)
        valid = pad2(valid, False)
        rank = (jax.vmap(stream_rank)(dst, valid, summary.ek_row_offsets)
                if s.add != "sum" else None)
        return ShardedEdgeLayout(
            pad2(summary.ek_src, 0), dst,
            pad2(summary.ek_w, s.zero), valid,
            summary.ek_row_offsets, None, rank,
            weight_mode="summary", pad_chunk=chunk, semiring=s.name,
            tile_n=tile_n, tile_chunk=chunk,
            mesh=summary.mesh, axes=summary.axes)
    h_cap = summary.ek_src.shape[0]
    valid = jnp.arange(h_cap, dtype=jnp.int32) < jnp.minimum(
        summary.num_ek, h_cap)
    src, dst, w, valid = _pad_stream(
        summary.ek_src, summary.ek_dst, summary.ek_w, valid,
        sentinel=k_cap, chunk=chunk, zero=s.zero)
    rank = (stream_rank(dst, valid, summary.ek_row_offsets)
            if s.add != "sum" else None)
    return EdgeLayout(src, dst, w, valid, summary.ek_row_offsets, None, rank,
                      weight_mode="summary", pad_chunk=chunk,
                      semiring=s.name, tile_n=tile_n, tile_chunk=chunk)


def require_layout(layout: Optional[AnyEdgeLayout], *, weight: str,
                   reverse: bool, who: str,
                   semiring: str = "plus_times") -> None:
    """Trace-time guard: a cached layout (single or sharded — both carry
    the same static metadata) must match the weighting, orientation and
    semiring the sweep was built for, else its baked weights silently
    mis-weight the propagation (e.g. an algorithm overriding
    ``layout_specs`` without overriding the consuming method).
    ``None`` passes — sweeps fall back to building/unsorted paths."""
    want_s = resolve_semiring(semiring).name
    if layout is not None and (layout.weight_mode != weight
                               or layout.reverse != reverse
                               or layout.semiring != want_s):
        raise ValueError(
            f"{who} needs a layout built with (weight={weight!r}, "
            f"reverse={reverse}, semiring={want_s!r}); got "
            f"(weight={layout.weight_mode!r}, reverse={layout.reverse}, "
            f"semiring={layout.semiring!r})")


def normalize_layout_spec(spec) -> tuple:
    """``(weight, reverse[, semiring])`` → ``(weight, reverse, semiring)``.

    ``StreamingAlgorithm.layout_specs`` entries written before the semiring
    API carry no third element; they mean ``plus_times``.
    """
    if len(spec) == 2:
        return (spec[0], spec[1], "plus_times")
    if len(spec) != 3:
        raise ValueError(
            f"layout spec must be (weight, reverse[, semiring]); got {spec!r}")
    return tuple(spec)


def push(
    values: jax.Array,
    layout: AnyEdgeLayout,
    *,
    semiring: Union[str, Semiring] = "plus_times",
    backend: Optional[str] = None,
    mask: Optional[jax.Array] = None,
    tile_n: Optional[int] = None,
    chunk: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """The shared propagation primitive:
    ``out[v] = ⊕_{(u,v)} values[u] ⊗ layout.weight[(u,v)]``.

    ``semiring`` names the (⊕, ⊗) pair (registry name or
    :class:`~repro.core.semiring.Semiring`); it must match the semiring the
    layout was built for — the baked weights and padding values are
    algebra-specific, so a mismatch fails at trace time rather than
    silently corrupting the reduce.  ``plus_times`` keeps the one-hot
    matmul MXU fast path; ``min``/``max`` reductions run the tiled
    masked-reduce kernel variant (or XLA segment-min/max on the
    ``segment_sum`` backend).

    ``layout`` may be a single :class:`EdgeLayout` or a
    :class:`ShardedEdgeLayout` — the sharded form runs one partial push
    per shard (the same per-shard kernel, either backend) completed by the
    semiring's all-reduce, ``shard_map``-ed over the layout's mesh when it
    carries one and looped on-device otherwise.

    ``values`` lives in the layout's *node* space (global ids for full-graph
    layouts, local hot ids for summary layouts); the result has
    ``layout.num_segments`` entries.  Receivers with no (unmasked) in-edge
    get the semiring's ⊕-identity (0 / +∞ / −∞).  ``mask`` optionally
    filters edges in the layout's sorted order (shape ``[E_pad]``, or
    ``[S, E_pad]`` for sharded layouts — e.g. the E_B selection in the
    big-vertex pass).  Traced inline — call from inside jitted sweeps;
    ``backend``/``semiring`` must be Python values at trace time.

    **Batched form**: ``values`` may be a ``[B, N]`` matrix — B independent
    query vectors pushed through the one shared layout, returning
    ``[B, num_segments]``.  The pallas sum path runs the batched kernel
    (a true ``[B, chunk] @ [chunk, tile_n]`` MXU matmul per chunk); min/max
    reductions are reassociation-exact, so every batch row is bitwise
    equal to its single-query push.  ``mask`` stays per-edge (shared
    across the batch).

    **Kernel geometry**: ``tile_n``/``chunk`` default to the layout's
    stamped (autotuned) geometry, falling back to the kernel defaults —
    explicit argument > layout meta > ``TILE_N``/``CHUNK``.
    """
    s = resolve_semiring(semiring)
    if isinstance(layout, ShardedEdgeLayout):
        record_trace("push[sharded]")
        return _push_sharded(values, layout, s=s, backend=backend, mask=mask,
                             tile_n=tile_n, chunk=chunk, interpret=interpret)
    if layout.semiring != s.name:
        raise ValueError(
            f"push(semiring={s.name!r}) over a layout built for "
            f"{layout.semiring!r}; rebuild the layout for this semiring")
    backend = resolve_backend(backend)
    record_trace(f"push[{backend}]")
    tile_n = tile_n if tile_n is not None else (
        layout.tile_n if layout.tile_n is not None else TILE_N)
    chunk = chunk if chunk is not None else (
        layout.tile_chunk if layout.tile_chunk is not None else CHUNK)
    num_segments = layout.num_segments
    batched = values.ndim == 2
    if values.ndim > 2:
        raise ValueError(
            f"push expects values of shape [N] or [B, N]; got {values.shape}")
    if backend == "segment_sum":
        if batched:
            # vmap keeps each row's segment-reduce order identical to the
            # single-query call, so min/max rows stay bitwise equal
            return jax.vmap(lambda v: gather_push(
                layout, v, num_segments, weight=layout.weight, mask=mask,
                semiring=s))(values)
        return gather_push(
            layout, values, num_segments, weight=layout.weight, mask=mask,
            semiring=s)

    if chunk > layout.pad_chunk:
        # kernel chunk loads past [start, end) stay inside the buffer only
        # up to the chunk the stream was padded with at build time
        raise ValueError(
            f"push(chunk={chunk}) exceeds the layout's pad_chunk="
            f"{layout.pad_chunk}; rebuild the layout with chunk>={chunk}")

    # pallas: gather contributions outside the kernel (XLA gathers are
    # efficient on TPU), then accumulate per output tile — one-hot matmul
    # for sum reductions, masked min/max reduce otherwise
    num_tiles = -(-num_segments // tile_n)
    bounds = jnp.minimum(
        jnp.arange(num_tiles + 1, dtype=jnp.int32) * tile_n, num_segments)
    tile_start = layout.row_offsets[bounds]
    if interpret is None:
        interpret = default_interpret()
    if s.add == "sum":
        if jnp.dtype(s.dtype) != jnp.float32:
            # the one-hot matmul accumulates on the f32 MXU — a silent cast
            # would break dtype/exactness parity with the segment backend
            # (e.g. int32 path counts losing exactness above 2^24)
            raise NotImplementedError(
                f"the pallas sum-reduce is the f32 one-hot-matmul MXU path; "
                f"semiring {s.name!r} ({s.dtype}) needs "
                f"backend='segment_sum'")
        contrib = s.combine(values[..., layout.src], layout.weight)
        if mask is not None:
            contrib = jnp.where(mask, contrib, 0.0)
        push_fn = spmv_push_batched if batched else spmv_push
        out = push_fn(
            contrib.astype(jnp.float32), layout.dst, tile_start,
            num_tiles=num_tiles, tile_n=tile_n, chunk=chunk,
            interpret=interpret)
    else:
        dtype = jnp.dtype(s.dtype)
        zero = jnp.asarray(s.zero, dtype)
        contrib = s.combine(values.astype(dtype)[..., layout.src],
                            layout.weight)
        if contrib.dtype != dtype:
            # compressed (bf16) weights promote the ⊗ up to f32 already;
            # this cast only normalizes layouts whose weights were stored
            # *below* the semiring dtype but whose ⊗ did not promote
            contrib = contrib.astype(dtype)
        keep = layout.valid if mask is None else (layout.valid & mask)
        contrib = jnp.where(keep, contrib, zero)
        rank = layout.rank
        if rank is None:
            rank = stream_rank(layout.dst, layout.valid, layout.row_offsets)
        reduce_fn = spmv_reduce_push_batched if batched else spmv_reduce_push
        out = reduce_fn(
            contrib, layout.dst, rank, tile_start, num_tiles=num_tiles,
            op=s.add, tile_n=tile_n, chunk=chunk, interpret=interpret)
    return out[..., :num_segments]


def _shard_view(layout: ShardedEdgeLayout, i, src, dst, w, valid,
                ro, rank) -> EdgeLayout:
    """Shard ``i`` of the stacked arrays as a plain :class:`EdgeLayout`
    (same static metadata), ready for the single-shard :func:`push`."""
    return EdgeLayout(
        src[i], dst[i], w[i], valid[i], ro[i], None,
        None if rank is None else rank[i],
        weight_mode=layout.weight_mode, reverse=layout.reverse,
        pad_chunk=layout.pad_chunk, semiring=layout.semiring,
        tile_n=layout.tile_n, tile_chunk=layout.tile_chunk)


def _push_sharded(
    values: jax.Array,
    layout: ShardedEdgeLayout,
    *,
    s: Semiring,
    backend: Optional[str],
    mask: Optional[jax.Array],
    tile_n: Optional[int],
    chunk: Optional[int],
    interpret: Optional[bool],
) -> jax.Array:
    """Sharded form of :func:`push`: per-shard partial push + ⊕ all-reduce.

    Each shard's stream is locally destination-sorted, so the shard-local
    reduce is the ordinary single-shard push (either backend, including
    the Pallas kernels); shard partials are dense ``[num_segments]``
    vectors merged by the semiring's ⊕ — ``lax.psum``/``pmin``/``pmax``
    across the mesh axes when the layout carries a mesh, an on-device
    merge loop otherwise.  min/max semirings are reassociation-exact, so
    the sharded result is *bitwise* equal to the single-layout push; sum
    semirings differ only by f32 summation order.
    """
    if layout.semiring != s.name:
        raise ValueError(
            f"push(semiring={s.name!r}) over a sharded layout built for "
            f"{layout.semiring!r}; rebuild the layout for this semiring")
    backend = resolve_backend(backend)
    num_shards = layout.num_shards
    if mask is not None and mask.shape != layout.dst.shape:
        raise ValueError(
            f"sharded push mask must cover the sharded sorted stream "
            f"{layout.dst.shape}; got {mask.shape}")

    def local_push(values, src, dst, w, valid, ro, rank, m, lo, hi):
        """⊕-merge of shards [lo, hi) resident on this device."""
        part = None
        for i in range(lo, hi):
            one = push(values,
                       _shard_view(layout, i, src, dst, w, valid, ro, rank),
                       semiring=s, backend=backend,
                       mask=None if m is None else m[i],
                       tile_n=tile_n, chunk=chunk, interpret=interpret)
            part = one if part is None else s.merge(part, one)
        return part

    if layout.mesh is None:
        return local_push(values, layout.src, layout.dst, layout.weight,
                          layout.valid, layout.row_offsets, layout.rank,
                          mask, 0, num_shards)

    mesh, axes = layout.mesh, layout.axes
    n_dev = 1
    for a in axes:
        n_dev *= mesh.shape[a]
    if num_shards % n_dev:
        raise ValueError(
            f"sharded layout has {num_shards} shards over {n_dev} devices "
            f"(mesh axes {axes}); shards must divide evenly")
    per_dev = num_shards // n_dev

    has_rank = layout.rank is not None

    def mapped(values, src, dst, w, valid, ro, *rest):
        rest = list(rest)
        rank = rest.pop(0) if has_rank else None
        m = rest.pop(0) if rest else None
        part = local_push(values, src, dst, w, valid, ro, rank, m,
                          0, per_dev)
        return s.all_reduce(part, axes)

    args = [values, layout.src, layout.dst, layout.weight, layout.valid,
            layout.row_offsets]
    in_specs = [P()] + [P(axes)] * 5
    if has_rank:
        args.append(layout.rank)
        in_specs.append(P(axes))
    if mask is not None:
        args.append(mask)
        in_specs.append(P(axes))
    # check_rep=False: the pallas kernels inside each shard have no
    # replication rule, but the all-reduce makes the output replicated by
    # construction
    fn = _shard_map(mapped, mesh=mesh, in_specs=tuple(in_specs),
                    out_specs=P(), check_rep=False)
    return fn(*args)


#: trace-time invocation counters — observability for "the compiled
#: program contains zero unsorted pushes" and friends: counters tick when
#: a Python call traces the primitive, so lowering a program fresh and
#: reading the counter delta tells what the program is built from.  Every
#: hot entry point ticks its own name (``push[<backend>]``,
#: ``push[sharded]``, ``push_coo``, ``build_layout``, ``summary_layout``);
#: the jaxpr lint's JXP-UNSORTED-SCATTER rule is the structural
#: generalization of the ``push_coo`` counter pin.
_TRACE_COUNTS: collections.Counter = collections.Counter()


def record_trace(name: str) -> None:
    """Tick the trace counter for ``name``.

    Call at trace time from any primitive whose presence in a compiled
    program is a contract (the built-ins above tick themselves; plugins
    and kernels may register their own names).  No-op at run time: jitted
    bodies only execute this while tracing, so counter deltas measure
    *program structure*, not call volume.
    """
    _TRACE_COUNTS[name] += 1


def trace_count(name: str) -> int:
    """Times primitive ``name`` (e.g. ``"push_coo"``) traced since the last
    :func:`reset_trace_counts` — see the counter note above."""
    return _TRACE_COUNTS[name]


def reset_trace_counts() -> None:
    """Zero every trace counter (call before lowering a program fresh)."""
    _TRACE_COUNTS.clear()


def push_coo(
    values: jax.Array,
    src: jax.Array,
    dst: jax.Array,
    num_segments: int,
    *,
    weight: Optional[jax.Array] = None,
    mask: Optional[jax.Array] = None,
    semiring: Union[str, Semiring] = "plus_times",
) -> jax.Array:
    """Unsorted-COO fallback for callers with no layout at hand.

    A plain XLA segment-sum/min/max over the caller's (unsorted) edge
    order.  ``weight`` is the raw ⊗-operand per edge; masked edges
    contribute the semiring's ⊕-identity.  ``values`` may be ``[N]`` or a
    batched ``[B, N]`` matrix (→ ``[B, num_segments]``, vmapped so each
    row matches its single-query call).  Prefer :func:`push` with a
    cached (possibly sharded) layout everywhere else — since the sharded
    layouts landed, no engine/dry-run hot loop goes through here
    (:func:`trace_count` ``("push_coo")`` is how tests and the dry-run
    assert that).
    """
    _TRACE_COUNTS["push_coo"] += 1
    s = resolve_semiring(semiring)

    def one(v):
        contrib = v[src]
        if weight is not None:
            contrib = s.combine(contrib, weight)
        if mask is not None:
            contrib = jnp.where(mask, contrib,
                                jnp.asarray(s.zero, contrib.dtype))
        return s.segment_reduce(contrib, dst, num_segments=num_segments)

    if values.ndim == 2:
        return jax.vmap(one)(values)
    return one(values)


__all__ = [
    "BACKENDS",
    "BACKEND_ENV_VAR",
    "WEIGHT_MODES",
    "AnyEdgeLayout",
    "EdgeLayout",
    "Semiring",
    "bake_weights",
    "padded_length",
    "ShardedEdgeLayout",
    "SortedEdges",
    "build_layout",
    "default_interpret",
    "normalize_layout_spec",
    "record_trace",
    "reset_trace_counts",
    "stream_rank",
    "trace_count",
    "validate_weight_dtype",
    "validate_weight_spec",
    "push",
    "push_coo",
    "require_layout",
    "resolve_backend",
    "resolve_semiring",
    "summary_layout",
]

"""Unified propagation backend: one ``push`` primitive for every sweep.

Every power sweep in the repo — exact PageRank, summarized PageRank, both
HITS directions, Katz, SSSP relaxations, connected-components label
propagation, ``build_summary``'s frozen big-vertex pass and the
algorithm-generic fused query step — is the same primitive applied to a
different edge layout under a different algebra:

    out[v] = ⊕ over in-edges (u, v) of ( values[u] ⊗ weight(u, v) )

The (⊕, ⊗) pair is an explicit :class:`~repro.core.semiring.Semiring`
(``plus_times`` sum-of-products, ``min_plus`` shortest paths, ``min_min``
label-min over int32, ``max_times`` widest paths — see
:mod:`repro.core.semiring`).  This module owns the primitive and its two
implementations:

- ``"pallas"``  — the destination-tiled MXU/VPU kernels in
  :mod:`repro.kernels.spmv.kernel` (Mosaic on TPU, ``interpret`` mode
  elsewhere), consuming a receiver-sorted edge stream with per-tile
  ranges: the one-hot matmul for ``sum`` reductions, the tiled
  masked-reduce variant for ``min``/``max``;
- ``"segment_sum"`` — :func:`repro.graph.csr.gather_push`, an
  ``indices_are_sorted`` XLA segment-sum/min/max over the same sorted
  stream.

Both consume an :class:`EdgeLayout`: the receiver-sorted edge stream with
the per-edge weight baked in, in the semiring's dtype (``1/d_out(u)`` for
PageRank-style sweeps, the ⊗-identity for ``"unit"`` layouts, per-edge
lengths for ``"length"`` ones).  Sorting is the amortizable cost — layouts
are built once per applied update batch (the engine caches them; see
``VeilGraphEngine.edge_layouts``), reused across queries, and within one
query across all ~30 power iterations.

Backend selection
-----------------
``resolve_backend(None)`` picks per device: ``"pallas"`` when JAX's default
backend is TPU, ``"segment_sum"`` otherwise.  The ``VEILGRAPH_BACKEND``
environment variable overrides (values: ``pallas``, ``segment_sum``,
``auto``), and every sweep/engine entry point takes an explicit ``backend=``
knob that overrides both.  Resolution happens at trace time; a changed
environment variable does not invalidate already-compiled sweeps.
"""

from __future__ import annotations

import dataclasses
import functools
import os
from typing import Optional, Union

import jax
import jax.numpy as jnp

from repro.core.semiring import Semiring, resolve_semiring
from repro.graph.csr import SortedEdges, gather_push, sort_by_dst
from repro.graph.graph import GraphState, inv_out_degree
from repro.kernels.spmv.kernel import (CHUNK, TILE_N, spmv_push,
                                       spmv_reduce_push)

BACKENDS = ("segment_sum", "pallas")

#: weight modes an EdgeLayout can bake: ``inv_out`` = 1/d_out(u) (PageRank
#: emission; plus_times only), ``unit`` = the semiring's ⊗-identity,
#: ``length`` = per-edge lengths (default 1) for min_plus-style relaxations.
WEIGHT_MODES = ("inv_out", "unit", "length")

#: env override for backend selection (read at trace time)
BACKEND_ENV_VAR = "VEILGRAPH_BACKEND"


def resolve_backend(backend: Optional[str] = None) -> str:
    """Resolve ``None``/``"auto"`` to a concrete backend name.

    Priority: explicit argument > ``$VEILGRAPH_BACKEND`` > device default
    (TPU → ``"pallas"``, anything else → ``"segment_sum"``).
    """
    if backend in (None, "auto"):
        backend = os.environ.get(BACKEND_ENV_VAR, "auto")
    if backend in (None, "auto", ""):
        backend = "pallas" if jax.default_backend() == "tpu" else "segment_sum"
    if backend not in BACKENDS:
        raise ValueError(
            f"unknown backend {backend!r}; expected one of "
            f"{BACKENDS + ('auto',)}")
    return backend


def default_interpret() -> bool:
    """Pallas runs as a compiled Mosaic kernel only on TPU; everywhere else
    the kernel body executes in interpret mode (how CI validates it)."""
    return jax.default_backend() != "tpu"


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=("src", "dst", "weight", "valid", "row_offsets", "order"),
    meta_fields=("weight_mode", "reverse", "pad_chunk", "semiring"),
)
@dataclasses.dataclass(frozen=True)
class EdgeLayout:
    """Receiver-sorted edge stream with baked per-edge weights.

    The propagation-ready form of :class:`~repro.graph.csr.SortedEdges`:
    same sorted order plus the per-edge multiplier, padded by at least one
    kernel chunk so the Pallas kernel's fixed-size chunk loads never run
    past the buffer.  ``dst`` holds ``num_segments`` in padding slots and
    ``weight`` the semiring's ⊕-identity there (0 for sum-of-products,
    ±∞/int extrema for min/max reductions), so both backends ignore
    padding without branching.

    ``row_offsets`` (int32[num_segments + 1]) gives the edge range per
    receiver; per-tile kernel ranges for any tile size derive from it with
    one gather, so one cached layout serves every ``tile_n``.

    ``weight_mode``/``reverse``/``semiring`` record how the layout was
    built and ``pad_chunk`` how much chunk slack the stream was padded
    with; they ride through jit as static metadata so consumers can reject
    a mismatched cached layout at trace time (:func:`require_layout`, the
    semiring check and ``chunk`` bound in :func:`push`) instead of
    silently mis-weighting, mis-padding, or reading out of bounds.
    """

    src: jax.Array          # int32[E_pad] emitting endpoint (sorted order)
    dst: jax.Array          # int32[E_pad] receiving endpoint (sentinel = N)
    weight: jax.Array       # dtype[E_pad] per-edge operand (⊕-id if invalid)
    valid: jax.Array        # bool[E_pad]
    row_offsets: jax.Array  # int32[num_segments + 1]
    #: original edge slot per sorted position (sentinel = edge_capacity in
    #: padding) — lets consumers map baked weights back to slot order
    #: (build_summary recovers per-edge lengths this way).  None for
    #: summary layouts, whose edge space is already compacted.
    order: Optional[jax.Array] = None
    weight_mode: str = "inv_out"
    reverse: bool = False
    pad_chunk: int = CHUNK
    semiring: str = "plus_times"

    @property
    def num_segments(self) -> int:
        return self.row_offsets.shape[0] - 1


def _pad_stream(src, dst, weight, valid, *, sentinel: int, chunk: int,
                zero=0.0):
    """Pad the sorted stream to a chunk multiple plus one spare chunk;
    padded weight slots hold ``zero`` (the consuming semiring's
    ⊕-identity) so they never contribute."""
    e = src.shape[0]
    e_pad = (e // chunk + 2) * chunk
    pad = e_pad - e
    return (
        jnp.pad(src, (0, pad)),
        jnp.pad(dst, (0, pad), constant_values=sentinel),
        jnp.pad(weight, (0, pad), constant_values=zero),
        jnp.pad(valid, (0, pad)),
    )


def validate_weight_spec(weight: str, *, reverse: bool = False,
                         semiring="plus_times", lengths=None,
                         edge_capacity: Optional[int] = None) -> "Semiring":
    """Shared trace-time checks for every (weight, reverse, semiring)
    consumer — :func:`build_layout` and ``build_summary`` must accept
    exactly the same spec space or layouts and summaries drift apart.
    Returns the resolved semiring."""
    s = resolve_semiring(semiring)
    if weight not in WEIGHT_MODES:
        raise ValueError(f"unknown weight mode {weight!r}; expected one of "
                         f"{WEIGHT_MODES}")
    if reverse and weight == "inv_out":
        raise ValueError(
            "reverse=True requires weight='unit' or 'length': inv_out "
            "would normalize by the out-degree of the receiving endpoint")
    if weight == "inv_out" and (s.add, s.mul) != ("sum", "times"):
        raise ValueError(
            "weight='inv_out' (1/d_out emission) is a sum-of-products "
            f"notion; semiring {s.name!r} needs 'unit' or 'length' weights")
    if lengths is not None and weight != "length":
        raise ValueError("lengths= is only meaningful with weight='length'")
    if (lengths is not None and edge_capacity is not None
            and lengths.shape[0] != edge_capacity):
        # a shorter array would silently clamp-gather its last element into
        # every higher edge slot (streamed edges land beyond the initial
        # edge list) — fail loudly at trace time instead
        raise ValueError(
            f"lengths must cover every edge slot: got shape "
            f"{lengths.shape}, edge_capacity={edge_capacity}")
    return s


@functools.partial(
    jax.jit, static_argnames=("weight", "reverse", "chunk", "semiring"))
def build_layout(
    state: GraphState,
    *,
    weight: str = "inv_out",
    reverse: bool = False,
    chunk: int = CHUNK,
    semiring: str = "plus_times",
    lengths: Optional[jax.Array] = None,
) -> EdgeLayout:
    """Full-graph propagation layout, sorted once per call.

    ``weight`` picks the baked per-edge ⊗-operand:

    - ``"inv_out"`` — ``1/d_out(u)`` (PageRank-style emission; only
      meaningful under ``plus_times`` and the forward orientation);
    - ``"unit"``    — the semiring's ⊗-identity (1 for sum-of-products —
      HITS/Katz — but e.g. +∞ for ``min_min`` so labels pass through
      unchanged);
    - ``"length"``  — per-edge lengths for ``min_plus``-style relaxations:
      ``lengths`` (dtype[E_cap], indexed by edge slot) if given, else 1
      per edge (hop counts).

    ``reverse=True`` builds the transposed layout (receivers are original
    sources — the HITS hub direction / CC's symmetric pass).  Invalid and
    padding slots bake the semiring's ⊕-identity so they never contribute.

    Degrees are baked into ``weight``, so a layout is valid exactly until
    the next applied update batch — the engine invalidates its cache then.
    """
    s = validate_weight_spec(weight, reverse=reverse, semiring=semiring,
                             lengths=lengths,
                             edge_capacity=state.edge_capacity)
    se = sort_by_dst(state, reverse=reverse)
    dtype = jnp.dtype(s.dtype)
    zero = jnp.asarray(s.zero, dtype)
    if weight == "inv_out":
        w = jnp.where(se.valid, inv_out_degree(state)[se.src], 0.0)
    elif weight == "unit":
        w = jnp.where(se.valid, jnp.asarray(s.one, dtype), zero)
    else:  # "length"
        per_edge = (jnp.ones((state.edge_capacity,), dtype)
                    if lengths is None else lengths.astype(dtype))
        w = jnp.where(se.valid, per_edge[se.order], zero)
    src, dst, w, valid = _pad_stream(
        se.src, se.dst, w, se.valid,
        sentinel=state.node_capacity, chunk=chunk, zero=s.zero)
    order = jnp.pad(se.order, (0, src.shape[0] - se.order.shape[0]),
                    constant_values=state.edge_capacity)
    return EdgeLayout(src, dst, w, valid, se.row_offsets, order,
                      weight_mode=weight, reverse=reverse, pad_chunk=chunk,
                      semiring=s.name)


def summary_layout(summary, *, chunk: int = CHUNK,
                   semiring: str = "plus_times") -> EdgeLayout:
    """Propagation layout over a summary's compacted, pre-sorted E_K buffer.

    :func:`repro.core.pagerank.build_summary` already emits E_K sorted by
    local destination with ``ek_row_offsets``; this only derives validity
    (sorted buffers keep valid edges first) and pads for the kernel.
    ``semiring`` must match the one the summary's ``ek_w``/``b_in`` were
    baked for (checked at trace time against the summary's recorded
    metadata — a ``plus_times`` reduce over +∞-baked min-semiring buffers
    would silently produce NaNs).  Traced inline — call it outside the
    power loop so padding happens once per query, not once per iteration.
    """
    s = resolve_semiring(semiring)
    baked = getattr(summary, "semiring", None)
    if baked is not None and baked != s.name:
        raise ValueError(
            f"summary_layout(semiring={s.name!r}) over a summary baked for "
            f"{baked!r}; rebuild the summary for this semiring")
    k_cap = summary.hot_ids.shape[0]
    h_cap = summary.ek_src.shape[0]
    valid = jnp.arange(h_cap, dtype=jnp.int32) < jnp.minimum(
        summary.num_ek, h_cap)
    src, dst, w, valid = _pad_stream(
        summary.ek_src, summary.ek_dst, summary.ek_w, valid,
        sentinel=k_cap, chunk=chunk, zero=s.zero)
    return EdgeLayout(src, dst, w, valid, summary.ek_row_offsets, None,
                      weight_mode="summary", pad_chunk=chunk,
                      semiring=s.name)


def require_layout(layout: Optional[EdgeLayout], *, weight: str,
                   reverse: bool, who: str,
                   semiring: str = "plus_times") -> None:
    """Trace-time guard: a cached layout must match the weighting,
    orientation and semiring the sweep was built for, else its baked
    weights silently mis-weight the propagation (e.g. an algorithm
    overriding ``layout_specs`` without overriding the consuming method).
    ``None`` passes — sweeps fall back to building/unsorted paths."""
    want_s = resolve_semiring(semiring).name
    if layout is not None and (layout.weight_mode != weight
                               or layout.reverse != reverse
                               or layout.semiring != want_s):
        raise ValueError(
            f"{who} needs a layout built with (weight={weight!r}, "
            f"reverse={reverse}, semiring={want_s!r}); got "
            f"(weight={layout.weight_mode!r}, reverse={layout.reverse}, "
            f"semiring={layout.semiring!r})")


def normalize_layout_spec(spec) -> tuple:
    """``(weight, reverse[, semiring])`` → ``(weight, reverse, semiring)``.

    ``StreamingAlgorithm.layout_specs`` entries written before the semiring
    API carry no third element; they mean ``plus_times``.
    """
    if len(spec) == 2:
        return (spec[0], spec[1], "plus_times")
    if len(spec) != 3:
        raise ValueError(
            f"layout spec must be (weight, reverse[, semiring]); got {spec!r}")
    return tuple(spec)


def push(
    values: jax.Array,
    layout: EdgeLayout,
    *,
    semiring: Union[str, Semiring] = "plus_times",
    backend: Optional[str] = None,
    mask: Optional[jax.Array] = None,
    tile_n: int = TILE_N,
    chunk: int = CHUNK,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """The shared propagation primitive:
    ``out[v] = ⊕_{(u,v)} values[u] ⊗ layout.weight[(u,v)]``.

    ``semiring`` names the (⊕, ⊗) pair (registry name or
    :class:`~repro.core.semiring.Semiring`); it must match the semiring the
    layout was built for — the baked weights and padding values are
    algebra-specific, so a mismatch fails at trace time rather than
    silently corrupting the reduce.  ``plus_times`` keeps the one-hot
    matmul MXU fast path; ``min``/``max`` reductions run the tiled
    masked-reduce kernel variant (or XLA segment-min/max on the
    ``segment_sum`` backend).

    ``values`` lives in the layout's *node* space (global ids for full-graph
    layouts, local hot ids for summary layouts); the result has
    ``layout.num_segments`` entries.  Receivers with no (unmasked) in-edge
    get the semiring's ⊕-identity (0 / +∞ / −∞).  ``mask`` optionally
    filters edges in the layout's sorted order (e.g. the E_B selection in
    the big-vertex pass).  Traced inline — call from inside jitted sweeps;
    ``backend``/``semiring`` must be Python values at trace time.
    """
    s = resolve_semiring(semiring)
    if layout.semiring != s.name:
        raise ValueError(
            f"push(semiring={s.name!r}) over a layout built for "
            f"{layout.semiring!r}; rebuild the layout for this semiring")
    backend = resolve_backend(backend)
    num_segments = layout.num_segments
    if backend == "segment_sum":
        return gather_push(
            layout, values, num_segments, weight=layout.weight, mask=mask,
            semiring=s)

    if chunk > layout.pad_chunk:
        # kernel chunk loads past [start, end) stay inside the buffer only
        # up to the chunk the stream was padded with at build time
        raise ValueError(
            f"push(chunk={chunk}) exceeds the layout's pad_chunk="
            f"{layout.pad_chunk}; rebuild the layout with chunk>={chunk}")

    # pallas: gather contributions outside the kernel (XLA gathers are
    # efficient on TPU), then accumulate per output tile — one-hot matmul
    # for sum reductions, masked min/max reduce otherwise
    num_tiles = -(-num_segments // tile_n)
    bounds = jnp.minimum(
        jnp.arange(num_tiles + 1, dtype=jnp.int32) * tile_n, num_segments)
    tile_start = layout.row_offsets[bounds]
    if interpret is None:
        interpret = default_interpret()
    if s.add == "sum":
        if jnp.dtype(s.dtype) != jnp.float32:
            # the one-hot matmul accumulates on the f32 MXU — a silent cast
            # would break dtype/exactness parity with the segment backend
            # (e.g. int32 path counts losing exactness above 2^24)
            raise NotImplementedError(
                f"the pallas sum-reduce is the f32 one-hot-matmul MXU path; "
                f"semiring {s.name!r} ({s.dtype}) needs "
                f"backend='segment_sum'")
        contrib = s.combine(values[layout.src], layout.weight)
        if mask is not None:
            contrib = jnp.where(mask, contrib, 0.0)
        out = spmv_push(
            contrib.astype(jnp.float32), layout.dst, tile_start,
            num_tiles=num_tiles, tile_n=tile_n, chunk=chunk,
            interpret=interpret)
    else:
        dtype = jnp.dtype(s.dtype)
        zero = jnp.asarray(s.zero, dtype)
        contrib = s.combine(values.astype(dtype)[layout.src], layout.weight)
        keep = layout.valid if mask is None else (layout.valid & mask)
        contrib = jnp.where(keep, contrib, zero)
        out = spmv_reduce_push(
            contrib, layout.dst, tile_start, num_tiles=num_tiles,
            op=s.add, tile_n=tile_n, chunk=chunk, interpret=interpret)
    return out[:num_segments]


def push_coo(
    values: jax.Array,
    src: jax.Array,
    dst: jax.Array,
    num_segments: int,
    *,
    weight: Optional[jax.Array] = None,
    mask: Optional[jax.Array] = None,
    semiring: Union[str, Semiring] = "plus_times",
) -> jax.Array:
    """Unsorted-COO fallback for callers with no layout at hand.

    A plain XLA segment-sum/min/max — today's cost model when no cached
    layout exists (e.g. the sharded dry-run lowering, where a pod-scale
    argsort would defeat GSPMD's edge sharding).  ``weight`` is the raw
    ⊗-operand per edge in the caller's (unsorted) edge order; masked edges
    contribute the semiring's ⊕-identity.  Prefer :func:`push` with a
    cached layout everywhere else.
    """
    s = resolve_semiring(semiring)
    contrib = values[src]
    if weight is not None:
        contrib = s.combine(contrib, weight)
    if mask is not None:
        contrib = jnp.where(mask, contrib, jnp.asarray(s.zero, contrib.dtype))
    return s.segment_reduce(contrib, dst, num_segments=num_segments)


__all__ = [
    "BACKENDS",
    "BACKEND_ENV_VAR",
    "WEIGHT_MODES",
    "EdgeLayout",
    "Semiring",
    "SortedEdges",
    "build_layout",
    "default_interpret",
    "normalize_layout_spec",
    "validate_weight_spec",
    "push",
    "push_coo",
    "require_layout",
    "resolve_backend",
    "resolve_semiring",
    "summary_layout",
]

"""Unified propagation backend: one ``push`` primitive for every sweep.

Every power sweep in the repo — exact PageRank, summarized PageRank, both
HITS directions, ``build_summary``'s frozen big-vertex pass and the
algorithm-generic fused query step — is the same primitive applied to a
different edge layout:

    out[v] = Σ over in-edges (u, v) of values[u] · weight(u, v)

This module owns that primitive and its two implementations:

- ``"pallas"``  — the destination-tiled one-hot-matmul MXU kernel in
  :mod:`repro.kernels.spmv.kernel` (Mosaic on TPU, ``interpret`` mode
  elsewhere), consuming a receiver-sorted edge stream with per-tile ranges;
- ``"segment_sum"`` — :func:`repro.graph.csr.gather_push`, an
  ``indices_are_sorted`` XLA segment-sum over the same sorted stream.

Both consume an :class:`EdgeLayout`: the receiver-sorted edge stream with
the per-edge weight baked in (``1/d_out(u)`` for PageRank-style sweeps,
``1`` for HITS/Katz-style ones).  Sorting is the amortizable cost — layouts
are built once per applied update batch (the engine caches them; see
``VeilGraphEngine.edge_layouts``), reused across queries, and within one
query across all ~30 power iterations.

Backend selection
-----------------
``resolve_backend(None)`` picks per device: ``"pallas"`` when JAX's default
backend is TPU, ``"segment_sum"`` otherwise.  The ``VEILGRAPH_BACKEND``
environment variable overrides (values: ``pallas``, ``segment_sum``,
``auto``), and every sweep/engine entry point takes an explicit ``backend=``
knob that overrides both.  Resolution happens at trace time; a changed
environment variable does not invalidate already-compiled sweeps.
"""

from __future__ import annotations

import dataclasses
import functools
import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.graph.csr import SortedEdges, gather_push, sort_by_dst
from repro.graph.graph import GraphState, inv_out_degree
from repro.kernels.spmv.kernel import CHUNK, TILE_N, spmv_push

BACKENDS = ("segment_sum", "pallas")

#: env override for backend selection (read at trace time)
BACKEND_ENV_VAR = "VEILGRAPH_BACKEND"


def resolve_backend(backend: Optional[str] = None) -> str:
    """Resolve ``None``/``"auto"`` to a concrete backend name.

    Priority: explicit argument > ``$VEILGRAPH_BACKEND`` > device default
    (TPU → ``"pallas"``, anything else → ``"segment_sum"``).
    """
    if backend in (None, "auto"):
        backend = os.environ.get(BACKEND_ENV_VAR, "auto")
    if backend in (None, "auto", ""):
        backend = "pallas" if jax.default_backend() == "tpu" else "segment_sum"
    if backend not in BACKENDS:
        raise ValueError(
            f"unknown backend {backend!r}; expected one of "
            f"{BACKENDS + ('auto',)}")
    return backend


def default_interpret() -> bool:
    """Pallas runs as a compiled Mosaic kernel only on TPU; everywhere else
    the kernel body executes in interpret mode (how CI validates it)."""
    return jax.default_backend() != "tpu"


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=("src", "dst", "weight", "valid", "row_offsets"),
    meta_fields=("weight_mode", "reverse", "pad_chunk"),
)
@dataclasses.dataclass(frozen=True)
class EdgeLayout:
    """Receiver-sorted edge stream with baked per-edge weights.

    The propagation-ready form of :class:`~repro.graph.csr.SortedEdges`:
    same sorted order plus the per-edge multiplier, padded by at least one
    kernel chunk so the Pallas kernel's fixed-size chunk loads never run
    past the buffer.  ``dst`` holds ``num_segments`` in padding slots and
    ``weight`` is 0 there, so both backends ignore padding without
    branching.

    ``row_offsets`` (int32[num_segments + 1]) gives the edge range per
    receiver; per-tile kernel ranges for any tile size derive from it with
    one gather, so one cached layout serves every ``tile_n``.

    ``weight_mode``/``reverse`` record how the layout was built and
    ``pad_chunk`` how much chunk slack the stream was padded with; they
    ride through jit as static metadata so consumers can reject a
    mismatched cached layout at trace time (:func:`require_layout`, the
    ``chunk`` bound in :func:`push`) instead of silently mis-weighting or
    reading out of bounds.
    """

    src: jax.Array          # int32[E_pad] emitting endpoint (sorted order)
    dst: jax.Array          # int32[E_pad] receiving endpoint (sentinel = N)
    weight: jax.Array       # f32[E_pad]   per-edge multiplier (0 if invalid)
    valid: jax.Array        # bool[E_pad]
    row_offsets: jax.Array  # int32[num_segments + 1]
    weight_mode: str = "inv_out"
    reverse: bool = False
    pad_chunk: int = CHUNK

    @property
    def num_segments(self) -> int:
        return self.row_offsets.shape[0] - 1


def _pad_stream(src, dst, weight, valid, *, sentinel: int, chunk: int):
    """Pad the sorted stream to a chunk multiple plus one spare chunk."""
    e = src.shape[0]
    e_pad = (e // chunk + 2) * chunk
    pad = e_pad - e
    return (
        jnp.pad(src, (0, pad)),
        jnp.pad(dst, (0, pad), constant_values=sentinel),
        jnp.pad(weight, (0, pad)),
        jnp.pad(valid, (0, pad)),
    )


@functools.partial(jax.jit, static_argnames=("weight", "reverse", "chunk"))
def build_layout(
    state: GraphState,
    *,
    weight: str = "inv_out",
    reverse: bool = False,
    chunk: int = CHUNK,
) -> EdgeLayout:
    """Full-graph propagation layout, sorted once per call.

    ``weight="inv_out"`` bakes ``1/d_out(u)`` (PageRank-style emission),
    ``"unit"`` bakes 1 (HITS/Katz).  ``reverse=True`` builds the transposed
    layout (receivers are original sources — the HITS hub direction);
    ``"inv_out"`` is only meaningful in the forward orientation.

    Degrees are baked into ``weight``, so a layout is valid exactly until
    the next applied update batch — the engine invalidates its cache then.
    """
    if reverse and weight == "inv_out":
        raise ValueError(
            "build_layout(reverse=True) requires weight='unit': inv_out "
            "would normalize by the out-degree of the receiving endpoint")
    if weight not in ("inv_out", "unit"):
        raise ValueError(f"unknown weight mode {weight!r}")
    se = sort_by_dst(state, reverse=reverse)
    if weight == "inv_out":
        w = jnp.where(se.valid, inv_out_degree(state)[se.src], 0.0)
    else:
        w = jnp.where(se.valid, 1.0, 0.0)
    src, dst, w, valid = _pad_stream(
        se.src, se.dst, w, se.valid,
        sentinel=state.node_capacity, chunk=chunk)
    return EdgeLayout(src, dst, w, valid, se.row_offsets,
                      weight_mode=weight, reverse=reverse, pad_chunk=chunk)


def summary_layout(summary, *, chunk: int = CHUNK) -> EdgeLayout:
    """Propagation layout over a summary's compacted, pre-sorted E_K buffer.

    :func:`repro.core.pagerank.build_summary` already emits E_K sorted by
    local destination with ``ek_row_offsets``; this only derives validity
    (sorted buffers keep valid edges first) and pads for the kernel.
    Traced inline — call it outside the power loop so padding happens once
    per query, not once per iteration.
    """
    k_cap = summary.hot_ids.shape[0]
    h_cap = summary.ek_src.shape[0]
    valid = jnp.arange(h_cap, dtype=jnp.int32) < jnp.minimum(
        summary.num_ek, h_cap)
    src, dst, w, valid = _pad_stream(
        summary.ek_src, summary.ek_dst, summary.ek_w, valid,
        sentinel=k_cap, chunk=chunk)
    return EdgeLayout(src, dst, w, valid, summary.ek_row_offsets,
                      weight_mode="summary", pad_chunk=chunk)


def require_layout(layout: Optional[EdgeLayout], *, weight: str,
                   reverse: bool, who: str) -> None:
    """Trace-time guard: a cached layout must match the weighting and
    orientation the sweep was built for, else its baked weights silently
    mis-weight the propagation (e.g. an algorithm overriding
    ``layout_specs`` without overriding the consuming method).  ``None``
    passes — sweeps fall back to building/unsorted paths."""
    if layout is not None and (layout.weight_mode != weight
                               or layout.reverse != reverse):
        raise ValueError(
            f"{who} needs a layout built with (weight={weight!r}, "
            f"reverse={reverse}); got (weight={layout.weight_mode!r}, "
            f"reverse={layout.reverse})")


def push(
    values: jax.Array,
    layout: EdgeLayout,
    *,
    backend: Optional[str] = None,
    mask: Optional[jax.Array] = None,
    tile_n: int = TILE_N,
    chunk: int = CHUNK,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """The shared propagation primitive:
    ``out[v] = Σ_{(u,v)} values[u] · layout.weight[(u,v)]``.

    ``values`` lives in the layout's *node* space (global ids for full-graph
    layouts, local hot ids for summary layouts); the result has
    ``layout.num_segments`` entries.  ``mask`` optionally filters edges in
    the layout's sorted order (e.g. the E_B selection in the big-vertex
    pass).  Traced inline — call from inside jitted sweeps; ``backend`` must
    be a Python string (or None) at trace time.
    """
    backend = resolve_backend(backend)
    num_segments = layout.num_segments
    if backend == "segment_sum":
        return gather_push(
            layout, values, num_segments, weight=layout.weight, mask=mask)

    if chunk > layout.pad_chunk:
        # kernel chunk loads past [start, end) stay inside the buffer only
        # up to the chunk the stream was padded with at build time
        raise ValueError(
            f"push(chunk={chunk}) exceeds the layout's pad_chunk="
            f"{layout.pad_chunk}; rebuild the layout with chunk>={chunk}")

    # pallas: gather contributions outside the kernel (XLA gathers are
    # efficient on TPU), then one-hot-matmul accumulate per output tile
    contrib = values[layout.src] * layout.weight
    if mask is not None:
        contrib = jnp.where(mask, contrib, 0.0)
    num_tiles = -(-num_segments // tile_n)
    bounds = jnp.minimum(
        jnp.arange(num_tiles + 1, dtype=jnp.int32) * tile_n, num_segments)
    tile_start = layout.row_offsets[bounds]
    if interpret is None:
        interpret = default_interpret()
    out = spmv_push(
        contrib.astype(jnp.float32), layout.dst, tile_start,
        num_tiles=num_tiles, tile_n=tile_n, chunk=chunk, interpret=interpret)
    return out[:num_segments]


def push_coo(
    values: jax.Array,
    src: jax.Array,
    dst: jax.Array,
    num_segments: int,
    *,
    weight: Optional[jax.Array] = None,
    mask: Optional[jax.Array] = None,
) -> jax.Array:
    """Unsorted-COO fallback for callers with no layout at hand.

    A plain XLA segment-sum — today's cost model when no cached layout
    exists (e.g. the sharded dry-run lowering, where a pod-scale argsort
    would defeat GSPMD's edge sharding).  Prefer :func:`push` with a cached
    layout everywhere else.
    """
    contrib = values[src]
    if weight is not None:
        contrib = contrib * weight
    if mask is not None:
        contrib = jnp.where(mask, contrib, 0.0)
    return jax.ops.segment_sum(contrib, dst, num_segments=num_segments)


__all__ = [
    "BACKENDS",
    "BACKEND_ENV_VAR",
    "EdgeLayout",
    "SortedEdges",
    "build_layout",
    "default_interpret",
    "push",
    "push_coo",
    "require_layout",
    "resolve_backend",
    "summary_layout",
]

"""Fused approximate-query step: selection + summary + iteration in one jit.

This is the performance-critical form of the paper's query path (Alg. 1
lines 6–19 for the compute-approximate action) and the function the
multi-pod dry-run lowers for the `veilgraph-pagerank` workload:

    (GraphState, ranks, deg_prev, r, Δ)  ->  (ranks', stats)

Differences vs the unfused engine path:
- one XLA program per query (no host round-trips between selection, summary
  construction and power iterations);
- the overflow fallback (|K| or |E_K| over capacity -> exact recompute)
  stays a device-side flag: the summarized result is computed
  unconditionally and the caller discards it and recomputes exactly when
  ``used_fallback`` reads back True (the engine does this on host);
- the algorithm-generic :func:`fused_query_step` is mesh-aware: pass
  cached :class:`~repro.core.backend.ShardedEdgeLayout` s (the engine
  does when configured with a mesh) or ``mesh=``/``mesh_axes=`` to build
  them inline, and every O(E) pass — the frozen big-vertex boundary and
  the exact sweeps — runs as a shard_map partial push + semiring
  all-reduce over per-shard locally-sorted edge streams, with node
  vectors replicated (the TPU analogue of Pregel's vertex-cut message
  exchange).  Summary construction itself is mesh-native too: with
  sharded layouts, ``build_summary`` runs the distributed bucket sort
  (per-shard E_K selection, capacity-padded all-to-all, shard-local row
  offsets — see :func:`repro.core.pagerank._build_summary_sharded`), so
  the lowered program contains no replicated edge-space gathers and no
  unsorted ``push_coo``.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.graph.graph import GraphState
from repro.core.hotset import select_hot_set
from repro.core.pagerank import (build_summary, pagerank,
                                 summarized_pagerank)


class QueryStepStats(NamedTuple):
    """Device-side stats for one fused query step (one host transfer)."""
    num_hot: jax.Array
    num_kr: jax.Array
    num_kn: jax.Array
    num_kdelta: jax.Array
    num_ek: jax.Array
    num_eb: jax.Array
    iterations: jax.Array
    used_fallback: jax.Array  # bool


@functools.partial(
    jax.jit,
    static_argnames=(
        "hot_node_capacity", "hot_edge_capacity", "beta", "num_iters", "tol",
        "n", "delta_hop_cap", "degree_mode", "expand_both", "backend",
    ),
)
def approximate_query_step(
    state: GraphState,
    ranks_prev: jax.Array,
    deg_prev: jax.Array,
    active_prev: jax.Array,
    r: jax.Array,
    delta: jax.Array,
    *,
    hot_node_capacity: int,
    hot_edge_capacity: int,
    beta: float = 0.85,
    num_iters: int = 30,
    tol: float = 0.0,
    n: int = 1,
    delta_hop_cap: int = 4,
    degree_mode: str = "out",
    expand_both: bool = False,
    layout=None,
    backend: str | None = None,
) -> Tuple[jax.Array, QueryStepStats]:
    """One summarized-PageRank query over the current graph state.

    ``layout`` is an optional cached forward ``inv_out`` edge layout for the
    frozen big-vertex pass; ``backend`` selects the propagation
    implementation (see :mod:`repro.core.backend`).
    """
    hot, hstats = select_hot_set(
        state, deg_prev, ranks_prev, r, delta,
        active_prev=active_prev, n=n, delta_hop_cap=delta_hop_cap,
        degree_mode=degree_mode, expand_both=expand_both,
    )
    summary = build_summary(
        state, ranks_prev, hot,
        hot_node_capacity=hot_node_capacity,
        hot_edge_capacity=hot_edge_capacity,
        layout=layout, backend=backend,
    )

    # No lax.cond here: the overflow fallback is almost never taken, and a
    # cond bars XLA from fusing across the branch boundary (and forces extra
    # buffer copies for the captured state).  The summarized result is
    # computed unconditionally; when ``used_fallback`` is set the caller
    # discards it and runs the exact recompute (engine does this on host).
    ranks, iters = summarized_pagerank(
        summary, ranks_prev, beta=beta, num_iters=num_iters, tol=tol,
        backend=backend,
    )
    stats = QueryStepStats(
        num_hot=hstats.num_hot,
        num_kr=hstats.num_kr,
        num_kn=hstats.num_kn,
        num_kdelta=hstats.num_kdelta,
        num_ek=summary.num_ek,
        num_eb=summary.num_eb,
        iterations=iters,
        used_fallback=summary.overflow,
    )
    return ranks, stats


# ---------------------------------------------------------------------------
# Algorithm-generic fused step
# ---------------------------------------------------------------------------


@functools.partial(
    jax.jit,
    static_argnames=(
        "algo", "hot_node_capacity", "hot_edge_capacity",
        "n", "delta_hop_cap", "degree_mode", "expand_both", "backend",
        "mesh", "mesh_axes",
    ),
)
def fused_query_step(
    state: GraphState,
    algo_state,
    deg_prev: jax.Array,
    active_prev: jax.Array,
    r: jax.Array,
    delta: jax.Array,
    *,
    algo,
    hot_node_capacity: int,
    hot_edge_capacity: int,
    n: int = 1,
    delta_hop_cap: int = 4,
    degree_mode: str = "out",
    expand_both: bool = False,
    layouts=None,
    backend: str | None = None,
    mesh=None,
    mesh_axes=None,
):
    """One summarized query for *any* :class:`StreamingAlgorithm`.

    ``algo`` is a frozen (hashable) algorithm instance riding through jit as
    a static argument, so its ``selection_view`` / ``build_summaries`` /
    ``summarized`` trace inline: selection, summary construction and the
    restricted power sweep compile to a single XLA program per
    (algorithm, capacities) pair — the PageRank-specific
    :func:`approximate_query_step` above is the ``algo=PageRankAlgorithm``
    specialization of this (kept for the bench harnesses that lower it
    directly).

    ``layouts`` is the cached edge-layout tuple matching
    ``algo.layout_specs`` (the engine builds it once per applied update
    batch) — single :class:`~repro.core.backend.EdgeLayout` s or, under a
    mesh-configured engine, :class:`~repro.core.backend.ShardedEdgeLayout`
    s, which route the frozen big-vertex pass through the shard_map-ed
    partial push.  ``mesh``/``mesh_axes`` (static) cover the cache-less
    caller — the multi-pod dry-run: with ``layouts=None`` and a mesh, the
    per-shard locally-sorted layouts are built inline (S independent
    axis-1 sorts, communication-free under GSPMD edge sharding), so the
    whole query step compiles sharded with zero unsorted ``push_coo``
    calls.  ``backend`` picks the propagation implementation inside each
    shard for the summarized sweep and the frozen big-vertex pass.

    Returns ``(new_algo_state, QueryStepStats)``.  Like the specialized
    path, overflow does not branch on device — the caller discards
    ``new_algo_state`` and recomputes exactly when ``used_fallback`` is set.
    """
    from repro.core.algorithm import summaries_overflow
    from repro.core.backend import normalize_layout_spec

    if layouts is None and mesh is not None:
        from repro.graph.partition import build_sharded_layout

        layouts = tuple(
            build_sharded_layout(state, mesh=mesh, axes=mesh_axes,
                                 weight=w, reverse=rev, semiring=s)
            for (w, rev, s) in map(normalize_layout_spec,
                                   algo.layout_specs))

    scores = algo.selection_view(algo_state)
    hot, hstats = select_hot_set(
        state, deg_prev, scores, r, delta,
        active_prev=active_prev, n=n, delta_hop_cap=delta_hop_cap,
        degree_mode=degree_mode, expand_both=expand_both,
        normalize_scores=algo.normalize_selection_scores,
    )
    summaries = algo.build_summaries(
        algo_state, state, hot,
        hot_node_capacity=hot_node_capacity,
        hot_edge_capacity=hot_edge_capacity,
        layouts=layouts, backend=backend,
    )
    new_state, iters = algo.summarized(
        algo_state, state, summaries, backend=backend)

    num_eb = summaries[0].num_eb
    for s in summaries[1:]:
        num_eb = num_eb + s.num_eb
    stats = QueryStepStats(
        num_hot=hstats.num_hot,
        num_kr=hstats.num_kr,
        num_kn=hstats.num_kn,
        num_kdelta=hstats.num_kdelta,
        num_ek=summaries[0].num_ek,
        num_eb=num_eb,
        iterations=iters,
        used_fallback=summaries_overflow(summaries),
    )
    return new_state, stats

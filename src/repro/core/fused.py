"""Fused approximate-query step: selection + summary + iteration in one jit.

This is the performance-critical form of the paper's query path (Alg. 1
lines 6–19 for the compute-approximate action) and the function the
multi-pod dry-run lowers for the `veilgraph-pagerank` workload:

    (GraphState, ranks, deg_prev, r, Δ)  ->  (ranks', stats)

Differences vs the unfused engine path:
- one XLA program per query (no host round-trips between selection, summary
  construction and power iterations);
- the overflow fallback (|K| or |E_K| over capacity -> exact recompute)
  stays a device-side flag: the summarized result is computed
  unconditionally and the caller discards it and recomputes exactly when
  ``used_fallback`` reads back True (the engine does this on host);
- the algorithm-generic :func:`fused_query_step` is mesh-aware: pass
  cached :class:`~repro.core.backend.ShardedEdgeLayout` s (the engine
  does when configured with a mesh) or ``mesh=``/``mesh_axes=`` to build
  them inline, and every O(E) pass — the frozen big-vertex boundary and
  the exact sweeps — runs as a shard_map partial push + semiring
  all-reduce over per-shard locally-sorted edge streams, with node
  vectors replicated (the TPU analogue of Pregel's vertex-cut message
  exchange).  Summary construction itself is mesh-native too: with
  sharded layouts, ``build_summary`` runs the distributed bucket sort
  (per-shard E_K selection, capacity-padded all-to-all, shard-local row
  offsets — see :func:`repro.core.pagerank._build_summary_sharded`), so
  the lowered program contains no replicated edge-space gathers and no
  unsorted ``push_coo``.
- under ``EngineConfig.async_rebuild`` every input here is *epoch-bound*:
  the graph state, the layouts and the ``deg_prev``/``active_prev``
  baselines all come from one frozen
  :class:`~repro.core.epoch.EpochSnapshot`, never from the engine's live
  (possibly mid-apply) state — and the caller fetches the stats/result
  only after dispatching the next epoch's rebuild, so this program's
  execution overlaps the apply+sort work queued behind it.  The program
  itself is identical in both modes (same trace, zero retraces across an
  epoch flip — pinned by ``analysis/programs.py``).
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.graph.graph import GraphState
from repro.core.hotset import _frontier_sweep, select_hot_set
from repro.core.pagerank import (build_summary, pagerank,
                                 summarized_pagerank)


class QueryStepStats(NamedTuple):
    """Device-side stats for one fused query step (one host transfer)."""
    num_hot: jax.Array
    num_kr: jax.Array
    num_kn: jax.Array
    num_kdelta: jax.Array
    num_ek: jax.Array
    num_eb: jax.Array
    iterations: jax.Array
    used_fallback: jax.Array  # bool
    # drift estimator outputs (repro.core.control) — populated only under
    # with_drift=True; they ride the same single stats transfer, so the
    # quality controller costs no extra host sync
    drift_probe: jax.Array = 0.0  # sampled fixed-point residual (relative)
    drift_cold: jax.Array = 0.0   # residual mass frozen outside K (relative)


def _drift_from_state(algo, new_state, old_state, graph, hot, probe_ids,
                      *, layouts, backend):
    """(drift_probe, drift_cold) for one fused step — the algorithm's
    fixed-point residual when it defines one, else the per-query churn of
    its result view as a proxy.  Works unchanged for batched ``[B, N]``
    states (push is batch-polymorphic); batched callers vmap the signal
    reduction instead."""
    from repro.core.algorithm import _finite_churn
    from repro.core.control import drift_signals

    resid = algo.drift_residual(
        new_state, graph, layouts=layouts, backend=backend)
    if resid is None:
        resid = _finite_churn(algo.result_view(new_state),
                              algo.result_view(old_state))
    result = algo.result_view(new_state)
    if result.ndim == 1:
        return drift_signals(resid, result, hot, graph.node_active,
                             probe_ids, normalize=algo.drift_normalize)
    sig = functools.partial(drift_signals, normalize=algo.drift_normalize)
    return jax.vmap(sig, in_axes=(0, 0, None, None, None))(
        resid, result, hot, graph.node_active, probe_ids)


@functools.partial(
    jax.jit,
    static_argnames=(
        "hot_node_capacity", "hot_edge_capacity", "beta", "num_iters", "tol",
        "n", "delta_hop_cap", "degree_mode", "expand_both", "backend",
    ),
)
def approximate_query_step(
    state: GraphState,
    ranks_prev: jax.Array,
    deg_prev: jax.Array,
    active_prev: jax.Array,
    r: jax.Array,
    delta: jax.Array,
    *,
    hot_node_capacity: int,
    hot_edge_capacity: int,
    beta: float = 0.85,
    num_iters: int = 30,
    tol: float = 0.0,
    n: int = 1,
    delta_hop_cap: int = 4,
    degree_mode: str = "out",
    expand_both: bool = False,
    layout=None,
    backend: str | None = None,
) -> Tuple[jax.Array, QueryStepStats]:
    """One summarized-PageRank query over the current graph state.

    ``layout`` is an optional cached forward ``inv_out`` edge layout for the
    frozen big-vertex pass; ``backend`` selects the propagation
    implementation (see :mod:`repro.core.backend`).
    """
    hot, hstats = select_hot_set(
        state, deg_prev, ranks_prev, r, delta,
        active_prev=active_prev, n=n, delta_hop_cap=delta_hop_cap,
        degree_mode=degree_mode, expand_both=expand_both,
    )
    summary = build_summary(
        state, ranks_prev, hot,
        hot_node_capacity=hot_node_capacity,
        hot_edge_capacity=hot_edge_capacity,
        layout=layout, backend=backend,
    )

    # No lax.cond here: the overflow fallback is almost never taken, and a
    # cond bars XLA from fusing across the branch boundary (and forces extra
    # buffer copies for the captured state).  The summarized result is
    # computed unconditionally; when ``used_fallback`` is set the caller
    # discards it and runs the exact recompute (engine does this on host).
    ranks, iters = summarized_pagerank(
        summary, ranks_prev, beta=beta, num_iters=num_iters, tol=tol,
        backend=backend,
    )
    stats = QueryStepStats(
        num_hot=hstats.num_hot,
        num_kr=hstats.num_kr,
        num_kn=hstats.num_kn,
        num_kdelta=hstats.num_kdelta,
        num_ek=summary.num_ek,
        num_eb=summary.num_eb,
        iterations=iters,
        used_fallback=summary.overflow,
    )
    return ranks, stats


# ---------------------------------------------------------------------------
# Algorithm-generic fused step
# ---------------------------------------------------------------------------


@functools.partial(
    jax.jit,
    static_argnames=(
        "algo", "hot_node_capacity", "hot_edge_capacity",
        "n", "delta_hop_cap", "degree_mode", "expand_both", "backend",
        "mesh", "mesh_axes", "shard_bucket_capacity", "with_drift",
    ),
)
def fused_query_step(
    state: GraphState,
    algo_state,
    deg_prev: jax.Array,
    active_prev: jax.Array,
    r: jax.Array,
    delta: jax.Array,
    probe_ids: jax.Array | None = None,
    *,
    algo,
    hot_node_capacity: int,
    hot_edge_capacity: int,
    n: int = 1,
    delta_hop_cap: int = 4,
    degree_mode: str = "out",
    expand_both: bool = False,
    layouts=None,
    backend: str | None = None,
    mesh=None,
    mesh_axes=None,
    shard_bucket_capacity: int | None = None,
    with_drift: bool = False,
):
    """One summarized query for *any* :class:`StreamingAlgorithm`.

    ``algo`` is a frozen (hashable) algorithm instance riding through jit as
    a static argument, so its ``selection_view`` / ``build_summaries`` /
    ``summarized`` trace inline: selection, summary construction and the
    restricted power sweep compile to a single XLA program per
    (algorithm, capacities) pair — the PageRank-specific
    :func:`approximate_query_step` above is the ``algo=PageRankAlgorithm``
    specialization of this (kept for the bench harnesses that lower it
    directly).

    ``layouts`` is the cached edge-layout tuple matching
    ``algo.layout_specs`` (the engine builds it once per applied update
    batch) — single :class:`~repro.core.backend.EdgeLayout` s or, under a
    mesh-configured engine, :class:`~repro.core.backend.ShardedEdgeLayout`
    s, which route the frozen big-vertex pass through the shard_map-ed
    partial push.  ``mesh``/``mesh_axes`` (static) cover the cache-less
    caller — the multi-pod dry-run: with ``layouts=None`` and a mesh, the
    per-shard locally-sorted layouts are built inline (S independent
    axis-1 sorts, communication-free under GSPMD edge sharding), so the
    whole query step compiles sharded with zero unsorted ``push_coo``
    calls.  ``backend`` picks the propagation implementation inside each
    shard for the summarized sweep and the frozen big-vertex pass.

    ``probe_ids`` (i32[P]) + static ``with_drift=True`` additionally
    compute the on-device drift estimator (:mod:`repro.core.control`):
    the algorithm's fixed-point residual sampled on the probe set and its
    mass outside the hot set, folded into the returned stats'
    ``drift_probe``/``drift_cold`` fields — same single host transfer,
    no extra sync.

    Returns ``(new_algo_state, QueryStepStats)``.  Like the specialized
    path, overflow does not branch on device — the caller discards
    ``new_algo_state`` and recomputes exactly when ``used_fallback`` is set.
    """
    from repro.core.algorithm import summaries_overflow
    from repro.core.backend import normalize_layout_spec

    if layouts is None and mesh is not None:
        from repro.graph.partition import build_sharded_layout

        layouts = tuple(
            build_sharded_layout(state, mesh=mesh, axes=mesh_axes,
                                 weight=w, reverse=rev, semiring=s)
            for (w, rev, s) in map(normalize_layout_spec,
                                   algo.layout_specs))

    scores = algo.selection_view(algo_state)
    hot, hstats = select_hot_set(
        state, deg_prev, scores, r, delta,
        active_prev=active_prev, n=n, delta_hop_cap=delta_hop_cap,
        degree_mode=degree_mode, expand_both=expand_both,
        normalize_scores=algo.normalize_selection_scores,
    )
    # only forward the knob when set, so legacy plugin overrides of
    # build_summaries without the keyword keep working
    extra = ({} if shard_bucket_capacity is None
             else {"shard_bucket_capacity": shard_bucket_capacity})
    summaries = algo.build_summaries(
        algo_state, state, hot,
        hot_node_capacity=hot_node_capacity,
        hot_edge_capacity=hot_edge_capacity,
        layouts=layouts, backend=backend, **extra,
    )
    new_state, iters = algo.summarized(
        algo_state, state, summaries, backend=backend)

    num_eb = summaries[0].num_eb
    for s in summaries[1:]:
        num_eb = num_eb + s.num_eb
    stats = QueryStepStats(
        num_hot=hstats.num_hot,
        num_kr=hstats.num_kr,
        num_kn=hstats.num_kn,
        num_kdelta=hstats.num_kdelta,
        num_ek=summaries[0].num_ek,
        num_eb=num_eb,
        iterations=iters,
        used_fallback=summaries_overflow(summaries),
    )
    if with_drift:
        drift_probe, drift_cold = _drift_from_state(
            algo, new_state, algo_state, state, hot, probe_ids,
            layouts=layouts, backend=backend)
        stats = stats._replace(drift_probe=drift_probe,
                               drift_cold=drift_cold)
    return new_state, stats


# ---------------------------------------------------------------------------
# Batched (multi-query) fused step — the serving engine's wave kernel
# ---------------------------------------------------------------------------


@functools.partial(
    jax.jit,
    static_argnames=(
        "algo", "hot_node_capacity", "hot_edge_capacity",
        "n", "delta_hop_cap", "degree_mode", "expand_both", "backend",
        "mesh", "mesh_axes", "shard_bucket_capacity", "with_drift",
    ),
)
def fused_query_step_batched(
    state: GraphState,
    batch_state,
    deg_prev: jax.Array,
    active_prev: jax.Array,
    r: jax.Array,
    delta: jax.Array,
    row_mask: jax.Array,
    cold_rows: jax.Array | None = None,
    probe_ids: jax.Array | None = None,
    *,
    algo,
    hot_node_capacity: int,
    hot_edge_capacity: int,
    n: int = 1,
    delta_hop_cap: int = 4,
    degree_mode: str = "out",
    expand_both: bool = False,
    layouts=None,
    backend: str | None = None,
    mesh=None,
    mesh_axes=None,
    shard_bucket_capacity: int | None = None,
    with_drift: bool = False,
):
    """One summarized wave for B concurrent queries of one algorithm.

    The multi-tenant analogue of :func:`fused_query_step`:
    ``batch_state`` carries every slot's per-query state with a leading
    batch axis (``[B, ...]`` leaves — e.g. B teleport vectors, B source
    masks), and the whole wave shares ONE hot set, ONE summary structure
    and ONE edge layout across all B queries:

    - selection reads ``algo.batched_selection_scores`` — the element-wise
      max of the live rows' volatility signals, so a vertex hot for any
      query is hot for the wave;
    - ``algo.build_summaries`` sees the ``[B, N]`` frozen vectors and
      produces summaries whose compacted E_K structure is row-independent
      with a per-query ``b_in [B, K_cap]`` (one batched push);
    - ``algo.summarized_batched`` then runs the restricted sweep as
      batched ``[B, K_cap]`` pushes, with ``row_mask`` (bool[B], True =
      live) freezing finished/vacant serving slots so they stop
      contributing work and report zero delta.

    ``cold_rows`` (traced bool[B], optional) marks freshly seated slots
    that have not yet converged once.  The paper's selection is driven
    by degree churn and score volatility *since the last measurement
    point* — a cold query has neither (its state is brand new), so its
    first waves need coverage beyond the churn-selected K, exactly as
    the single-query protocol computes initial results over all of G
    before streaming.  Instead of widening to the whole active set, the
    wave expands the cold rows' **seed-local reachability**: the
    algorithm's :meth:`~repro.core.algorithm.StreamingAlgorithm.\
batched_cold_seeds` masks (PPR teleport support, SSSP/widest sources)
    are OR-reduced over the live cold rows and grown to their forward
    reachability fixpoint in a growth-conditioned ``while_loop`` — zero
    sweeps when no row is cold.  The fixpoint is closed under out-edges,
    so no hot→cold edge exists: E_K contains every edge among reachable
    vertices, unreachable cold vertices hold their ⊕-identity values,
    and the seed-local wave is result-identical to the old full-width
    one (bitwise for the min/max semirings).  Algorithms without seed
    structure (``batched_cold_seeds() is None`` — global workloads like
    PageRank/CC) fall back to full-active coverage when any live row is
    cold.  Capacities permitting — bounded caps overflow into the exact
    fallback as usual.

    ``probe_ids`` + static ``with_drift=True`` additionally compute the
    per-slot drift estimator (:mod:`repro.core.control`) and return a
    fourth value ``row_drift f32[B, 2]`` (columns: drift_probe,
    drift_cold per slot, zeroed for vacant rows), riding the same
    transfer as ``row_delta`` — no extra host sync.  The wave-level
    stats carry the max over live slots.

    Returns ``(new_batch_state, QueryStepStats, row_delta f32[B])`` —
    plus ``row_drift`` under ``with_drift`` — where stats describe the
    shared wave (hot-set sizes, E_K/E_B, overflow);
    ``row_delta`` is the per-slot convergence signal the serving engine's
    harvest step compares against each request's tolerance.  Overflow
    semantics are unchanged: no device-side branch, the caller discards
    the batch result and falls back to per-row exact recomputes when
    ``used_fallback`` reads True.
    """
    from repro.core.algorithm import summaries_overflow
    from repro.core.backend import normalize_layout_spec

    if layouts is None and mesh is not None:
        from repro.graph.partition import build_sharded_layout

        layouts = tuple(
            build_sharded_layout(state, mesh=mesh, axes=mesh_axes,
                                 weight=w, reverse=rev, semiring=s)
            for (w, rev, s) in map(normalize_layout_spec,
                                   algo.layout_specs))

    scores = algo.batched_selection_scores(batch_state, row_mask)
    hot, hstats = select_hot_set(
        state, deg_prev, scores, r, delta,
        active_prev=active_prev, n=n, delta_hop_cap=delta_hop_cap,
        degree_mode=degree_mode, expand_both=expand_both,
        normalize_scores=algo.normalize_selection_scores,
    )
    if cold_rows is not None:
        live_cold = cold_rows & row_mask
        any_cold = jnp.any(live_cold)
        seeds = algo.batched_cold_seeds(batch_state)
        if seeds is None:
            # no per-query seed structure (global workloads): cold-start
            # coverage is the whole active set, as before
            hot = hot | (state.node_active & any_cold)
        else:
            # seed-local delta expansion: grow the live cold rows' seed
            # union to its forward-reachability fixpoint.  Closed under
            # out-edges ⇒ no hot→cold edge ⇒ identical results to full
            # coverage, at seed-local cost.  Initial continue flag is
            # any_cold, so a wave with no cold rows runs zero sweeps.
            seed_mask = (jnp.any(seeds & live_cold[:, None], axis=0)
                         & state.node_active)

            def _grow(carry):
                mark, _ = carry
                nxt = _frontier_sweep(state, mark, both=False)
                return nxt, jnp.any(nxt != mark)

            reach, _ = jax.lax.while_loop(
                lambda c: c[1], _grow, (seed_mask, any_cold))
            hot = hot | reach
        hstats = hstats._replace(num_hot=jnp.sum(hot.astype(jnp.int32)))
    extra = ({} if shard_bucket_capacity is None
             else {"shard_bucket_capacity": shard_bucket_capacity})
    summaries = algo.build_summaries(
        batch_state, state, hot,
        hot_node_capacity=hot_node_capacity,
        hot_edge_capacity=hot_edge_capacity,
        layouts=layouts, backend=backend, **extra,
    )
    new_state, iters, row_delta = algo.summarized_batched(
        batch_state, state, summaries, row_mask=row_mask, backend=backend)

    num_eb = summaries[0].num_eb
    for s in summaries[1:]:
        num_eb = num_eb + s.num_eb
    stats = QueryStepStats(
        num_hot=hstats.num_hot,
        num_kr=hstats.num_kr,
        num_kn=hstats.num_kn,
        num_kdelta=hstats.num_kdelta,
        num_ek=summaries[0].num_ek,
        num_eb=num_eb,
        iterations=iters,
        used_fallback=summaries_overflow(summaries),
    )
    if with_drift:
        probe_b, cold_b = _drift_from_state(
            algo, new_state, batch_state, state, hot, probe_ids,
            layouts=layouts, backend=backend)
        live = row_mask.astype(jnp.float32)
        row_drift = jnp.stack([probe_b, cold_b], axis=-1) * live[:, None]
        stats = stats._replace(drift_probe=jnp.max(probe_b * live),
                               drift_cold=jnp.max(cold_b * live))
        return new_state, stats, row_delta, row_drift
    return new_state, stats, row_delta

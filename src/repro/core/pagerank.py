"""PageRank power method — exact and VeilGraph-summarized versions.

Faithful to the paper (§2, §3.1):

- vertex-centric formulation: each vertex u emits ``rank(u)/d_out(u)`` along
  every out-edge; a vertex v sets ``rank(v) = (1-β) + β·Σ incoming`` (the
  Gelly-style normalization the paper describes — the (1-β) teleport term is
  *not* divided by |V| and dangling mass is not redistributed; both are
  available as beyond-paper options).
- the summarized version runs the same update *only for vertices in K*, with
  the frozen big-vertex contribution ``b_in`` added each iteration and all
  non-K ranks carried over unchanged.

The summarized iteration runs in a *compacted* space: hot edges are gathered
into a bounded ``hot_edge_capacity`` buffer and hot nodes are relabelled to
``[0, hot_node_capacity)``, so per-iteration cost is O(|E_K| + |K|) — this
is the paper's O(K) claim realized with XLA static shapes.

Both sweeps route their inner propagation through the unified
:func:`repro.core.backend.push` primitive: pass a cached
:class:`~repro.core.backend.EdgeLayout` (the engine does) and choose
``backend="pallas"`` to run each iteration as one destination-tiled MXU
kernel call, or ``"segment_sum"`` for the sorted XLA fallback.  The
compacted E_K buffer is emitted *destination-sorted* with per-tile ranges
(``ek_row_offsets``), so the ~30-iteration summarized loop body is a pure
kernel call with the sort amortized into summary construction.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import backend as B
from repro.graph.graph import GraphState, inv_out_degree


# --------------------------------------------------------------------------
# Exact PageRank over the full graph
# --------------------------------------------------------------------------


@functools.partial(
    jax.jit,
    static_argnames=("num_iters", "beta", "tol", "teleport_by_n", "dangling",
                     "backend"),
)
def pagerank(
    state: GraphState,
    init_ranks: Optional[jax.Array] = None,
    *,
    beta: float = 0.85,
    num_iters: int = 30,
    tol: float = 0.0,
    teleport_by_n: bool = False,
    dangling: bool = False,
    teleport_v: Optional[jax.Array] = None,
    layout: Optional[B.EdgeLayout] = None,
    backend: Optional[str] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Full power-method PageRank.

    Returns ``(ranks f32[N_cap], iterations_run)``.  With ``tol > 0`` the
    loop exits early once ``‖r_t − r_{t−1}‖₁ < tol`` (bounded by num_iters).

    ``teleport_v`` (f32[N_cap], optional) replaces the uniform teleport with
    a personalization vector: ``rank(v) = (1-β)·t(v) + β·Σ incoming`` —
    seeded/personalized PageRank in the same Gelly-style normalization.

    ``layout`` is an optional cached forward ``weight="inv_out"`` edge
    layout (see :func:`repro.core.backend.build_layout`); without one the
    pallas backend sorts on entry (amortized over the sweep) and the
    segment_sum backend falls back to the unsorted COO push.
    """
    backend_r = B.resolve_backend(backend)
    B.require_layout(layout, weight="inv_out", reverse=False, who="pagerank")
    n_cap = state.node_capacity
    active = state.node_active
    n_active = jnp.maximum(state.num_active_nodes().astype(jnp.float32), 1.0)
    inv_deg = inv_out_degree(state)
    mask = state.edge_mask()
    if teleport_v is not None:
        teleport = (1.0 - beta) * teleport_v
    else:
        teleport = jnp.where(teleport_by_n, (1.0 - beta) / n_active, 1.0 - beta)

    if init_ranks is None:
        if teleport_v is not None:
            r0 = jnp.where(active, teleport_v, 0.0)
        else:
            r0 = jnp.where(active, jnp.where(teleport_by_n, 1.0 / n_active, 1.0), 0.0)
    else:
        r0 = init_ranks

    if layout is None and backend_r == "pallas":
        layout = B.build_layout(state, weight="inv_out")
    edge_w = jnp.where(mask, inv_deg[state.src], 0.0)

    def body(carry):
        i, r, _ = carry
        if layout is None:
            incoming = B.push_coo(r, state.src, state.dst, n_cap, weight=edge_w)
        else:
            incoming = B.push(r, layout, backend=backend_r)
        if dangling:
            dangle = jnp.sum(jnp.where(active & (state.out_deg == 0), r, 0.0))
            incoming = incoming + dangle / n_active
        new_r = jnp.where(active, teleport + beta * incoming, 0.0)
        delta = jnp.sum(jnp.abs(new_r - r))
        return i + 1, new_r, delta

    def cond(carry):
        i, _, delta = carry
        return (i < num_iters) & (delta > tol)

    i, r, _ = jax.lax.while_loop(cond, body, (jnp.int32(0), r0, jnp.float32(jnp.inf)))
    return r, i


# --------------------------------------------------------------------------
# Summarized PageRank over the hot set (the paper's contribution)
# --------------------------------------------------------------------------


def compact_indices(mask: jax.Array, size: int, *, rows: int = 64) -> jax.Array:
    """Indices of True entries of ``mask``, compacted into int32[size].

    Order-scrambled position assignment via a column-major prefix sum:
    positions are ``col_off[j] + (#True in column j over rows < i)``, which
    is a bijection onto [0, popcount).  Two design constraints drive the
    layout:

    - the lax.scan runs over the SHORT ``rows`` axis (64 trips) with the
      long axis as the carry, so under GSPMD the carry stays sharded and
      the partitioner never all-gathers the edge stream (§Perf iteration
      V1: the previous layout scanned 2^21 rows of 512 and made GSPMD
      replicate a 4.3 GB operand per trip — 9.0e15 bytes of HBM traffic
      on the pod-scale veilgraph cell);
    - column offsets need an exclusive cumsum over the (still sharded)
      column-totals vector; a second short-scan level reduces it to a
      cumsum over len/``rows``² elements, which is cheap and local.

    Unused slots hold ``len(mask)`` (out-of-bounds sentinel: gathers clip,
    scatters with mode="drop" ignore).  If more than ``size`` entries are
    set, an arbitrary subset of exactly ``size`` survives — callers detect
    overflow from the mask popcount.
    """
    e = mask.shape[0]

    def col_prefix(m2):
        """scan over rows: per-element prefix count within its column +
        column totals."""
        def body(carry, row):
            return carry + row, carry
        return jax.lax.scan(body, jnp.zeros(m2.shape[1], jnp.int32), m2)

    cols = max((e + rows - 1) // rows, 1)
    e_pad = rows * cols
    m = jnp.pad(mask, (0, e_pad - e)) if e_pad != e else mask
    m2 = m.reshape(rows, cols).astype(jnp.int32)
    col_tot, pos_in_col = col_prefix(m2)               # (cols,), (rows, cols)

    # exclusive cumsum of col_tot via a second short-scan level
    cols2 = max((cols + rows - 1) // rows, 1)
    pad2 = rows * cols2 - cols
    ct = jnp.pad(col_tot, (0, pad2)) if pad2 else col_tot
    ct2 = ct.reshape(rows, cols2)
    grp_tot, pos_in_grp = col_prefix(ct2)              # (cols2,), (rows, cols2)
    grp_off = jnp.concatenate(
        [jnp.zeros(1, jnp.int32), jnp.cumsum(grp_tot)[:-1]])
    col_off = (grp_off[None, :] + pos_in_grp).reshape(-1)[:cols]

    pos = (col_off[None, :] + pos_in_col).reshape(-1)
    tgt = jnp.where(m & (pos < size), pos, size)
    return jnp.full((size,), e, jnp.int32).at[tgt].set(
        jnp.arange(e_pad, dtype=jnp.int32), mode="drop"
    )


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=("hot_ids", "num_hot", "ek_src", "ek_dst", "ek_w",
                 "ek_row_offsets", "num_ek", "b_in", "num_eb", "overflow"),
    meta_fields=("weight_mode", "semiring", "tile_n", "tile_chunk",
                 "mesh", "axes"),
)
@dataclasses.dataclass(frozen=True)
class SummaryBuffers:
    """Compacted summary graph G = (K ∪ {B}, E_K ∪ E_B) — static capacities.

    ``hot_ids[i]``   — global id of the i-th hot vertex (i < num_hot)
    ``ek_src/dst``   — *local* endpoints of E_K edges, **sorted by local
                       destination** (invalid slots hold the ``K_cap``
                       sentinel destination and sort last)
    ``ek_w``         — val((u,v)) at summary-build time, in the consuming
                       semiring's dtype (1/d_out(u) for the paper's
                       PageRank summaries; the ⊗-identity for ``"unit"``;
                       per-edge lengths for ``"length"``)
    ``ek_row_offsets`` — int32[K_cap + 1] edge range per local destination
                       over the sorted buffer (the summarized sweep's
                       kernel tile ranges derive from it)
    ``b_in``         — per-hot-vertex frozen big-vertex contribution
                       b_in[z] = Σ_{(w,z): w∉K} rank(w)/d_out(w)
    ``overflow``     — True if |K| or |E_K| exceeded a capacity; the caller
                       must fall back to exact recomputation.
    ``weight_mode``/``semiring`` — static metadata recording how
                       ``ek_w``/``b_in`` were baked, so
                       :func:`repro.core.backend.summary_layout` can reject
                       a consumer running the wrong algebra at trace time
                       (a ``plus_times`` sweep over +∞-baked ``min_plus``
                       buffers would silently produce NaNs).

    **Sharded form** (built by :func:`build_summary` when handed a
    :class:`~repro.core.backend.ShardedEdgeLayout`): the ``ek_*`` buffers
    gain a leading shard axis — ``ek_src/dst/w`` become ``[S, H_s]`` and
    ``ek_row_offsets`` ``[S, K_cap + 1]``, one *locally* destination-sorted
    E_K shard per device, with shard ``j`` owning the contiguous local-id
    range ``[j·⌈K_cap/S⌉, (j+1)·⌈K_cap/S⌉)``.  ``hot_ids``/``b_in`` and the
    counters stay replicated node-space vectors/scalars.  ``mesh``/``axes``
    carry the device mapping (static, mirroring ``ShardedEdgeLayout``);
    :func:`repro.core.backend.summary_layout` then emits a sharded layout so
    every summarized sweep runs as a shard_map partial push + all-reduce.
    """

    hot_ids: jax.Array   # int32[K_cap]
    num_hot: jax.Array   # int32
    ek_src: jax.Array    # int32[H_cap] | int32[S, H_s] (local ids, dst-sorted)
    ek_dst: jax.Array    # int32[H_cap] | int32[S, H_s] (sorted; K_cap = padding)
    ek_w: jax.Array      # dtype[H_cap] | dtype[S, H_s] (semiring dtype)
    ek_row_offsets: jax.Array  # int32[K_cap + 1] | int32[S, K_cap + 1]
    num_ek: jax.Array    # int32
    b_in: jax.Array      # dtype[K_cap]
    num_eb: jax.Array    # int32  (size of E_B, for the paper's edge-ratio stat)
    overflow: jax.Array  # bool
    weight_mode: str = "inv_out"
    semiring: str = "plus_times"
    # tuned kernel geometry inherited from the full-graph layout the summary
    # was built against; summary_layout() stamps it onto the E_K layout so
    # summarized sweeps pick the autotuned tile/chunk without user knobs
    tile_n: Optional[int] = None
    tile_chunk: Optional[int] = None
    mesh: Optional["jax.sharding.Mesh"] = None
    axes: Tuple[str, ...] = ()

    @property
    def sharded(self) -> bool:
        """True for the stacked per-shard E_K form (see class docstring)."""
        return self.ek_src.ndim == 2

    @property
    def num_shards(self) -> Optional[int]:
        """Shard count of the sharded form, ``None`` for flat summaries."""
        return self.ek_src.shape[0] if self.sharded else None


def _build_summary_sharded(
    state: GraphState,
    ranks_prev: jax.Array,
    hot_mask: jax.Array,
    *,
    hot_node_capacity: int,
    hot_edge_capacity: int,
    weight: str,
    layout: "B.ShardedEdgeLayout",
    backend: Optional[str],
    s,
    shard_bucket_capacity: Optional[int] = None,
) -> SummaryBuffers:
    """Mesh-native summary construction: a distributed bucket sort over the
    shard axis, so no stage ever materializes a replicated O(E) buffer.

    The replicated construction compacts E_K with full-edge-space gathers
    (``e_src[ek_idx]`` over the whole COO buffer) — under GSPMD edge
    sharding those gathers lower to all-gathers of the edge stream, the
    pod-scale wall-clock ceiling this path removes.  Stages, all shard-local
    except the one exchange:

    1. **local selection** — each shard masks its own locally-sorted stream
       for E_K / E_B membership and relabels endpoints through the
       replicated ``local_of`` node vector (O(N) node state stays
       replicated; O(E) edge state never leaves its shard);
    2. **local dst sort** — one axis-1 argsort per shard by *local
       destination* groups each shard's E_K edges into ``S`` contiguous
       destination buckets (bucket ``j`` = local ids ``[j·W, (j+1)·W)``,
       ``W = ⌈K_cap/S⌉``) and destination-sorts within each bucket in the
       same pass;
    3. **capacity-padded all-to-all** — each (source shard, bucket) block is
       padded to ``C = ⌈H_cap/S⌉`` slots and the ``[S_in, S_out, C]`` stack
       is transposed on its leading axes, which under GSPMD *is* the
       all-to-all collective; shard ``j`` now owns every E_K edge whose
       destination falls in its bucket;
    4. **local merge** — one axis-1 argsort per shard merges its ``S``
       sorted incoming blocks; ``ek_row_offsets`` derive shard-locally by
       ``searchsorted`` (never a global sort).

    A block exceeding ``C`` raises the ``overflow`` flag (alongside the
    usual ``|K|``/``|E_K|`` capacity checks) and the caller falls back to
    exact recomputation — ``compact_indices``'s order-scrambled local ids
    spread destinations across buckets, so balanced blocks are the common
    case.  ``b_in`` runs through the sharded :func:`repro.core.backend.push`
    with the E_B mask, exactly like the flat path with a cached layout.

    ``shard_bucket_capacity`` overrides ``C = ⌈H_cap/S⌉`` with a tighter
    per-(source shard, bucket) slot count: the post-exchange per-shard E_K
    buffer is ``S·C`` slots, so the default bound grows with H_cap even
    when hot edges are well spread — a workload with balanced buckets can
    cut the per-device footprint to ``S · shard_bucket_capacity`` and rely
    on the ``overflow`` flag (→ exact fallback) for the rare skewed batch.
    """
    n_cap = state.node_capacity
    k_cap = hot_node_capacity
    h_cap = hot_edge_capacity
    num_shards = layout.num_shards
    e_pad = layout.dst.shape[1]
    if shard_bucket_capacity is None:
        bucket_cap = -(-h_cap // num_shards)  # C: per (src-shard, bucket)
    else:
        if shard_bucket_capacity < 1:
            raise ValueError(
                f"shard_bucket_capacity must be >= 1; got "
                f"{shard_bucket_capacity}")
        bucket_cap = shard_bucket_capacity
    bucket_w = -(-k_cap // num_shards)     # W: local-dst ids per bucket
    w_dtype = jnp.dtype(s.dtype)
    s_zero = jnp.asarray(s.zero, w_dtype)

    # ---- hot-vertex relabelling (replicated node space, same as flat) ----
    hot_ids = compact_indices(hot_mask, k_cap)
    num_hot = jnp.sum(hot_mask.astype(jnp.int32))
    local_valid = jnp.arange(k_cap, dtype=jnp.int32) < num_hot
    local_of = jnp.zeros((n_cap,), jnp.int32).at[hot_ids].set(
        jnp.arange(k_cap, dtype=jnp.int32), mode="drop")

    # ---- per-shard E_K / E_B selection over the sorted streams -----------
    dst_c = jnp.minimum(layout.dst, n_cap - 1)  # clip the n_cap sentinel
    src_hot = hot_mask[layout.src]
    dst_hot = hot_mask[dst_c]
    ek_mask = layout.valid & src_hot & dst_hot
    eb_mask = layout.valid & (~src_hot) & dst_hot
    num_ek = jnp.sum(ek_mask.astype(jnp.int32))
    num_eb = jnp.sum(eb_mask.astype(jnp.int32))

    # ---- frozen big-vertex boundary: sharded push over the E_B mask ------
    # ranks_prev may be batched [B, N] (shared-summary serving): the push
    # and the hot-id gather both batch along the leading axis, so b_in
    # becomes [B, K_cap] while E_K stays shared across the batch
    b_in_global = B.push(ranks_prev, layout, backend=backend, mask=eb_mask,
                         semiring=s)
    b_in = jnp.where(local_valid, b_in_global[..., hot_ids], s_zero)

    # ---- stage 2: shard-local relabel + destination sort -----------------
    # layout.weight already holds the baked ⊗-operand in stream order (the
    # single bake both paths share), so E_K weights are a masked copy
    lsrc = jnp.where(ek_mask, local_of[layout.src], 0)
    ldst = jnp.where(ek_mask, local_of[dst_c], k_cap)  # sentinel sorts last
    # keep the layout's (possibly bf16-compressed) storage dtype: a f32
    # s_zero would silently promote ek_w back to f32
    s_zero_w = s_zero.astype(layout.weight.dtype)
    ek_w = jnp.where(ek_mask, layout.weight, s_zero_w)
    perm = jnp.argsort(ldst, axis=1, stable=True)
    take = lambda x: jnp.take_along_axis(x, perm, axis=1)
    lsrc, ldst, ek_w = take(lsrc), take(ldst), take(ek_w)

    # ---- stage 3: capacity-padded blocks + all-to-all exchange -----------
    bounds = jnp.minimum(
        jnp.arange(num_shards + 1, dtype=jnp.int32) * bucket_w, k_cap)
    off = jax.vmap(lambda d: jnp.searchsorted(
        d, bounds, side="left").astype(jnp.int32))(ldst)
    n_block = off[:, 1:] - off[:, :-1]              # [S_in, S_out] counts
    block_overflow = jnp.any(n_block > bucket_cap)
    lane = jnp.arange(bucket_cap, dtype=jnp.int32)
    idx = jnp.minimum(
        off[:, :-1, None] + lane[None, None, :], e_pad - 1
    ).reshape(num_shards, num_shards * bucket_cap)
    block_valid = lane[None, None, :] < jnp.minimum(n_block,
                                                    bucket_cap)[:, :, None]

    if layout.mesh is not None:
        # explicit collective: shard_map + lax.all_to_all.  (Left to GSPMD,
        # the leading-axes transpose of the block stack lowers as an
        # all-gather of the whole [S, S, C] array — 64 GiB/device at the
        # pod-scale dry-run shape — instead of the O(C·S) exchange.)
        from jax.sharding import PartitionSpec as _P

        def _swap(b):
            # per device: [S_loc, S, C] -> split buckets across devices,
            # concat source shards -> [S, S_loc, C] -> local transpose
            b = jax.lax.all_to_all(b, layout.axes, split_axis=1,
                                   concat_axis=0, tiled=True)
            return jnp.swapaxes(b, 0, 1)

        transpose_blocks = B._shard_map(
            _swap, mesh=layout.mesh, in_specs=_P(layout.axes),
            out_specs=_P(layout.axes), check_rep=False)
    else:
        transpose_blocks = lambda b: jnp.swapaxes(b, 0, 1)

    def exchange(x, fill):
        """[S_in, E_pad] stream -> [S_out, S_in·C] received blocks: gather
        the per-bucket blocks shard-locally, then exchange the leading
        (source shard, bucket) axes — ``lax.all_to_all`` under a mesh, a
        plain transpose on the single-device reference path."""
        g = jnp.take_along_axis(x, idx, axis=1).reshape(
            num_shards, num_shards, bucket_cap)
        g = jnp.where(block_valid, g, fill)
        return transpose_blocks(g).reshape(
            num_shards, num_shards * bucket_cap)

    ek_src2 = exchange(lsrc, 0)
    ek_dst2 = exchange(ldst, k_cap)
    ek_w2 = exchange(ek_w, s_zero_w)

    # ---- stage 4: shard-local merge sort + row offsets -------------------
    perm2 = jnp.argsort(ek_dst2, axis=1, stable=True)
    take2 = lambda x: jnp.take_along_axis(x, perm2, axis=1)
    ek_src2, ek_dst2, ek_w2 = take2(ek_src2), take2(ek_dst2), take2(ek_w2)
    ek_row_offsets = jax.vmap(lambda d: jnp.searchsorted(
        d, jnp.arange(k_cap + 1, dtype=jnp.int32),
        side="left").astype(jnp.int32))(ek_dst2)

    if layout.mesh is not None:
        # pin the summary shards to the layout's mesh placement so the
        # consuming shard_map never redistributes them (and the partitioner
        # keeps every stage above shard-local)
        from jax.sharding import NamedSharding, PartitionSpec
        sh = NamedSharding(layout.mesh, PartitionSpec(layout.axes))
        pin = lambda x: jax.lax.with_sharding_constraint(x, sh)
        ek_src2, ek_dst2, ek_w2, ek_row_offsets = map(
            pin, (ek_src2, ek_dst2, ek_w2, ek_row_offsets))

    return SummaryBuffers(
        hot_ids=hot_ids,
        num_hot=num_hot,
        ek_src=ek_src2,
        ek_dst=ek_dst2,
        ek_w=ek_w2,
        ek_row_offsets=ek_row_offsets,
        num_ek=num_ek,
        b_in=b_in,
        num_eb=num_eb,
        overflow=(num_hot > k_cap) | (num_ek > h_cap) | block_overflow,
        weight_mode=weight,
        semiring=s.name,
        tile_n=layout.tile_n,
        tile_chunk=layout.tile_chunk,
        mesh=layout.mesh,
        axes=layout.axes,
    )


@functools.partial(
    jax.jit,
    static_argnames=("hot_node_capacity", "hot_edge_capacity", "weight",
                     "reverse", "backend", "semiring",
                     "shard_bucket_capacity"),
)
def build_summary(
    state: GraphState,
    ranks_prev: jax.Array,
    hot_mask: jax.Array,
    *,
    hot_node_capacity: int,
    hot_edge_capacity: int,
    weight: str = "inv_out",
    reverse: bool = False,
    layout: Optional[B.EdgeLayout] = None,
    backend: Optional[str] = None,
    semiring: str = "plus_times",
    lengths: Optional[jax.Array] = None,
    shard_bucket_capacity: Optional[int] = None,
) -> SummaryBuffers:
    """Construct the big-vertex summary (§3.1) into bounded buffers.

    Generalized beyond PageRank so other :class:`StreamingAlgorithm` plugins
    can reuse the same compaction machinery:

    - ``weight``: ``"inv_out"`` (PageRank-style ``val((u,v)) = 1/d_out(u)``),
      ``"unit"`` (the semiring's ⊗-identity — HITS / Katz / CC label-min),
      or ``"length"`` (per-edge lengths for SSSP-style relaxations).
      Length resolution: a passed ``layout``'s baked lengths win (mapped
      back to slot order through ``layout.order``, so E_K and the ``b_in``
      boundary can never disagree), else the explicit ``lengths`` array
      (dtype[E_cap], indexed by original edge slot), else 1 per edge.
    - ``reverse``: build the summary over the *transposed* edge set — the
      emitting endpoint is the original ``dst``.  ``b_in[z]`` then freezes
      the contribution of non-hot vertices reached by z's *out*-edges (the
      hub-update direction in HITS, the symmetric pass in CC).
      ``weight="inv_out"`` is only meaningful in the forward orientation.
    - ``layout``: optional cached full-graph edge layout **matching this
      summary's** ``weight``/``reverse``/``semiring`` (the engine passes
      one per ``StreamingAlgorithm.layout_specs`` entry); the frozen
      big-vertex pass then runs through the sorted
      :func:`repro.core.backend.push` instead of an unsorted segment reduce.
    - ``semiring``: the (⊕, ⊗) algebra of the consuming summarized sweep
      (:mod:`repro.core.semiring`).  ``ek_w`` and ``b_in`` take the
      semiring's dtype, invalid slots its ⊕-identity, and the frozen
      big-vertex pass ⊕-reduces cold contributions (a *min* over frozen
      cold distances/labels for ``min_plus``/``min_min``, the paper's sum
      for ``plus_times``).

    ``ranks_prev`` is whatever state vector the frozen big-vertex
    contribution should be computed from (previous PageRank ranks, previous
    hub scores, previous distances/labels, …).  It may be a batched
    ``[B, N]`` matrix (B queries sharing ONE hot set / E_K structure — the
    serving engine's shared summary): the structural buffers are computed
    once while ``b_in`` becomes per-query ``[B, K_cap]`` via one batched
    push.  ``shard_bucket_capacity`` tightens the sharded construction's
    per-(shard, bucket) slot count — see :func:`_build_summary_sharded`.

    Handed a :class:`~repro.core.backend.ShardedEdgeLayout` (the engine does
    when configured with a mesh), construction itself runs sharded — a
    distributed bucket sort over the shard axis producing the stacked
    per-shard E_K form of :class:`SummaryBuffers` (see
    :func:`_build_summary_sharded`), with zero replicated edge-space
    gathers; the consuming summarized sweeps then run through the sharded
    push automatically.
    """
    if weight == "length" and lengths is None and layout is None:
        lengths = state.edge_len  # streamed per-edge lengths, if any
    s = B.validate_weight_spec(weight, reverse=reverse, semiring=semiring,
                               lengths=lengths,
                               edge_capacity=state.edge_capacity)
    B.require_layout(layout, weight=weight, reverse=reverse,
                     who="build_summary", semiring=s)
    if isinstance(layout, B.ShardedEdgeLayout):
        # sharded construction: the layout's baked weights are the single
        # source of truth (like the flat path's layout.order back-map), so
        # an explicit `lengths` array never overrides them
        return _build_summary_sharded(
            state, ranks_prev, hot_mask,
            hot_node_capacity=hot_node_capacity,
            hot_edge_capacity=hot_edge_capacity,
            weight=weight, layout=layout, backend=backend, s=s,
            shard_bucket_capacity=shard_bucket_capacity)
    n_cap = state.node_capacity
    k_cap = hot_node_capacity
    h_cap = hot_edge_capacity
    mask = state.edge_mask()
    inv_deg = inv_out_degree(state)
    w_dtype = jnp.dtype(s.dtype)
    s_zero = jnp.asarray(s.zero, w_dtype)
    if weight == "length" and layout is not None and layout.order is not None:
        # the layout's baked lengths are the single source of truth: map
        # them back to edge-slot order so E_K cannot silently diverge from
        # the b_in boundary pass (e.g. hop counts vs real lengths)
        lengths = jnp.full((state.edge_capacity,), s_zero).at[
            layout.order].set(layout.weight, mode="drop")

    e_src, e_dst = (state.dst, state.src) if reverse else (state.src, state.dst)
    src_hot = hot_mask[e_src]
    dst_hot = hot_mask[e_dst]
    ek_mask = mask & src_hot & dst_hot
    eb_mask = mask & (~src_hot) & dst_hot

    num_hot = jnp.sum(hot_mask.astype(jnp.int32))
    num_ek = jnp.sum(ek_mask.astype(jnp.int32))
    num_eb = jnp.sum(eb_mask.astype(jnp.int32))
    overflow = (num_hot > k_cap) | (num_ek > h_cap)

    # ---- hot-vertex relabelling: global id -> local id ------------------
    # Padding entries hold an out-of-bounds sentinel: gathers clip (and are
    # masked by local_valid), scatters use mode="drop" so padding never
    # clobbers a real slot.
    hot_ids = compact_indices(hot_mask, k_cap)
    local_valid = jnp.arange(k_cap, dtype=jnp.int32) < num_hot
    local_of = jnp.zeros((n_cap,), jnp.int32)
    local_of = local_of.at[hot_ids].set(
        jnp.arange(k_cap, dtype=jnp.int32), mode="drop"
    )

    # ---- frozen big-vertex contribution (computed once per query) -------
    # b_in_global[z] = ⊕_{(w,z) ∈ E_B} rank_prev(w) ⊗ val(w)
    # one O(E) push; with a cached layout the E_B selection becomes a mask
    # over the sorted stream and the reduce reuses the amortized edge sort
    if layout is None:
        if weight == "inv_out":
            coo_w = inv_deg[e_src]
        elif weight == "length":
            coo_w = (jnp.ones_like(e_src, dtype=w_dtype) if lengths is None
                     else lengths.astype(w_dtype))
        else:  # "unit": ⊗-identity — skip the combine entirely
            coo_w = None
        b_in_global = B.push_coo(ranks_prev, e_src, e_dst, n_cap,
                                 weight=coo_w, mask=eb_mask, semiring=s)
    else:
        eb_mask_s = (~hot_mask[layout.src]) & hot_mask[
            jnp.minimum(layout.dst, n_cap - 1)]
        b_in_global = B.push(ranks_prev, layout, backend=backend,
                             mask=eb_mask_s, semiring=s)
    # batched ranks_prev [B, N] → b_in [B, K_cap] (see sharded path note)
    b_in = jnp.where(local_valid, b_in_global[..., hot_ids], s_zero)

    # ---- compact E_K into the bounded buffer ----------------------------
    ek_idx = compact_indices(ek_mask, h_cap)
    ek_valid = jnp.arange(h_cap, dtype=jnp.int32) < jnp.minimum(num_ek, h_cap)
    gsrc = e_src[ek_idx]
    gdst = e_dst[ek_idx]
    # val((u,v)) = 1/d_out(u) *including* edges that leave K (paper §3.1:
    # discarded out-edges still count in the emitting degree).
    if weight == "inv_out":
        ek_w = jnp.where(ek_valid, inv_deg[gsrc], 0.0)
    elif weight == "length":
        # ek_idx holds original edge slots, so explicit lengths gather
        # directly (clipped gathers on padding slots are masked by ek_valid)
        per_edge = (jnp.asarray(1, w_dtype) if lengths is None
                    else lengths.astype(w_dtype)[jnp.minimum(
                        ek_idx, lengths.shape[0] - 1)])
        ek_w = jnp.where(ek_valid, per_edge, s_zero)
    else:  # "unit": the semiring's ⊗-identity
        ek_w = jnp.where(ek_valid, jnp.asarray(s.one, w_dtype), s_zero)
    ek_src = jnp.where(ek_valid, local_of[gsrc], 0)
    ek_dst = jnp.where(ek_valid, local_of[gdst], 0)

    # ---- destination-sort the compacted buffer --------------------------
    # One argsort over H_cap per query makes every summarized iteration a
    # pure sorted push (kernel tile ranges derive from ek_row_offsets);
    # invalid slots take the K_cap sentinel destination and sort last.
    ek_key = jnp.where(ek_valid, ek_dst, k_cap)
    ek_order = jnp.argsort(ek_key, stable=True)
    ek_dst_s = ek_key[ek_order]
    ek_row_offsets = jnp.searchsorted(
        ek_dst_s, jnp.arange(k_cap + 1, dtype=jnp.int32), side="left"
    ).astype(jnp.int32)

    return SummaryBuffers(
        hot_ids=hot_ids,
        num_hot=num_hot,
        ek_src=ek_src[ek_order],
        ek_dst=ek_dst_s,
        ek_w=ek_w[ek_order],
        ek_row_offsets=ek_row_offsets,
        num_ek=num_ek,
        b_in=b_in,
        num_eb=num_eb,
        overflow=overflow,
        weight_mode=weight,
        semiring=s.name,
        tile_n=None if layout is None else layout.tile_n,
        tile_chunk=None if layout is None else layout.tile_chunk,
    )


@functools.partial(
    jax.jit, static_argnames=("num_iters", "beta", "tol", "backend")
)
def summarized_pagerank(
    summary: SummaryBuffers,
    ranks_prev: jax.Array,
    *,
    beta: float = 0.85,
    num_iters: int = 30,
    tol: float = 0.0,
    teleport_v: Optional[jax.Array] = None,
    backend: Optional[str] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Power iteration restricted to the summary graph (§3.1).

    Per iteration, for every hot vertex z (local id):
        rank(z) = (1-β)·t(z) + β·( Σ_{(u,z)∈E_K} rank(u)·val((u,z)) + b_in(z) )
    with t ≡ 1 for classic PageRank or the global personalization vector
    ``teleport_v`` for seeded PageRank.  Cold ranks are carried over
    unchanged.  Returns the *global* rank vector and the number of
    iterations run.

    The loop body is one :func:`repro.core.backend.push` over the summary's
    pre-sorted E_K layout — a single kernel call per iteration on the
    pallas backend.
    """
    backend_r = B.resolve_backend(backend)
    k_cap = summary.hot_ids.shape[0]
    local_valid = jnp.arange(k_cap, dtype=jnp.int32) < summary.num_hot
    r_local0 = jnp.where(local_valid, ranks_prev[summary.hot_ids], 0.0)
    if teleport_v is not None:
        t_local = jnp.where(local_valid, teleport_v[summary.hot_ids], 0.0)
    else:
        t_local = 1.0
    layout = B.summary_layout(summary)

    def body(carry):
        i, r, _ = carry
        incoming = B.push(r, layout, backend=backend_r)
        new_r = jnp.where(
            local_valid,
            (1.0 - beta) * t_local + beta * (incoming + summary.b_in),
            0.0,
        )
        delta = jnp.sum(jnp.abs(new_r - r))
        return i + 1, new_r, delta

    def cond(carry):
        i, _, delta = carry
        return (i < num_iters) & (delta > tol)

    i, r_local, _ = jax.lax.while_loop(
        cond, body, (jnp.int32(0), r_local0, jnp.float32(jnp.inf))
    )

    # scatter hot results back into the global vector; padding entries of
    # hot_ids are out of bounds and dropped.
    ranks = ranks_prev.at[summary.hot_ids].set(r_local, mode="drop")
    return ranks, i


@functools.partial(
    jax.jit, static_argnames=("num_iters", "beta", "tol", "backend")
)
def summarized_pagerank_batched(
    summary: SummaryBuffers,
    ranks_prev: jax.Array,
    *,
    beta: float = 0.85,
    num_iters: int = 30,
    tol: float = 0.0,
    teleport_v: Optional[jax.Array] = None,
    row_mask: Optional[jax.Array] = None,
    backend: Optional[str] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Batched :func:`summarized_pagerank`: B queries, one shared summary.

    ``ranks_prev`` / ``teleport_v`` are ``[B, N]`` matrices (per-slot
    personalization vectors); the summary is shared across the batch —
    ``b_in`` may be the per-query ``[B, K_cap]`` form
    :func:`build_summary` emits for batched ``ranks_prev``.  Each
    iteration runs ONE batched push over the pre-sorted E_K layout (the
    ``[B, chunk] @ [chunk, tile_n]`` MXU path on the pallas backend).

    ``row_mask`` (bool[B], optional) is the serving engine's per-slot
    convergence mask: rows with ``False`` carry their state unchanged and
    report zero delta, so finished/vacant slots neither drift nor keep the
    wave from converging.

    Returns ``(ranks [B, N], iterations, row_delta [B])`` — ``row_delta``
    is each row's final L1 step size, the per-slot convergence signal.
    """
    backend_r = B.resolve_backend(backend)
    batch = ranks_prev.shape[0]
    k_cap = summary.hot_ids.shape[0]
    local_valid = jnp.arange(k_cap, dtype=jnp.int32) < summary.num_hot
    r_local0 = jnp.where(local_valid, ranks_prev[:, summary.hot_ids], 0.0)
    if teleport_v is not None:
        t_local = jnp.where(local_valid, teleport_v[:, summary.hot_ids], 0.0)
    else:
        t_local = 1.0
    keep = (jnp.ones((batch,), bool) if row_mask is None
            else row_mask)[:, None]
    layout = B.summary_layout(summary)

    def body(carry):
        i, r, _ = carry
        incoming = B.push(r, layout, backend=backend_r)
        new_r = jnp.where(
            local_valid,
            (1.0 - beta) * t_local + beta * (incoming + summary.b_in),
            0.0,
        )
        new_r = jnp.where(keep, new_r, r)
        delta = jnp.sum(jnp.abs(new_r - r), axis=1)
        return i + 1, new_r, delta

    def cond(carry):
        i, _, delta = carry
        return (i < num_iters) & (jnp.max(delta) > tol)

    i, r_local, delta = jax.lax.while_loop(
        cond, body,
        (jnp.int32(0), r_local0, jnp.full((batch,), jnp.inf, jnp.float32)))

    ranks = ranks_prev.at[:, summary.hot_ids].set(r_local, mode="drop")
    ranks = jnp.where(keep, ranks, ranks_prev)
    return ranks, i, delta

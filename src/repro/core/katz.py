"""Katz centrality — exact and VeilGraph-summarized versions.

Katz scores count attenuated walks: ``c = Σ_k α^k (Aᵀ)^k · β·1``, computed
by the fixed-point iteration

    c(v) = β + α · Σ_{(u,v) ∈ E} c(u)

— the same sum-of-products power sweep as PageRank, but over *unit* edge
weights (no out-degree normalization) with the teleport term replaced by
the constant attraction β.  The iteration is a contraction (and the fixed
point exists) whenever ``α < 1/σ_max(A)``; keep α small for hubby graphs.

The summarized version is structurally the summarized PageRank sweep: hot
vertices iterate over the compacted E_K buffer with the *frozen* cold
contribution ``b_in[z] = Σ_{(w,z) ∈ E_B} c_prev(w)`` injected each
iteration, cold scores carried over unchanged.  Both sweeps route through
the unified :func:`repro.core.backend.push` primitive on the ``plus_times``
semiring (the one-hot-matmul MXU fast path).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import backend as B
from repro.core.pagerank import SummaryBuffers
from repro.graph.graph import GraphState


@functools.partial(
    jax.jit,
    static_argnames=("alpha", "beta", "num_iters", "tol", "backend"),
)
def katz(
    state: GraphState,
    init: Optional[jax.Array] = None,
    *,
    alpha: float = 0.05,
    beta: float = 1.0,
    num_iters: int = 30,
    tol: float = 0.0,
    layout: Optional[B.EdgeLayout] = None,
    backend: Optional[str] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Full Katz power iteration.  Returns ``(katz f32[N_cap], iterations)``.

    ``init`` warm-starts the iteration (the sweep is a contraction for
    admissible α, so warm starts only save iterations); with ``tol > 0``
    the loop exits early once ``‖c_t − c_{t−1}‖₁ < tol``.  ``layout`` is an
    optional cached forward ``weight="unit"`` / ``plus_times`` layout;
    without one the sweep sorts on entry, amortized over the iterations on
    both backends.
    """
    backend_r = B.resolve_backend(backend)
    B.require_layout(layout, weight="unit", reverse=False, who="katz")
    active = state.node_active
    c0 = jnp.where(active, beta if init is None else init, 0.0).astype(
        jnp.float32)

    if layout is None:
        # one sort amortized over every iteration, on both backends (the
        # sorted gather_push skips XLA's scatter sort/unique analysis too)
        layout = B.build_layout(state, weight="unit")

    def body(carry):
        i, c, _ = carry
        incoming = B.push(c, layout, backend=backend_r)
        new_c = jnp.where(active, beta + alpha * incoming, 0.0)
        delta = jnp.sum(jnp.abs(new_c - c))
        return i + 1, new_c, delta

    def cond(carry):
        i, _, delta = carry
        return (i < num_iters) & (delta > tol)

    i, c, _ = jax.lax.while_loop(
        cond, body, (jnp.int32(0), c0, jnp.float32(jnp.inf)))
    return c, i


@functools.partial(
    jax.jit,
    static_argnames=("alpha", "beta", "num_iters", "tol", "backend"),
)
def summarized_katz(
    summary: SummaryBuffers,
    katz_prev: jax.Array,
    *,
    alpha: float = 0.05,
    beta: float = 1.0,
    num_iters: int = 30,
    tol: float = 0.0,
    backend: Optional[str] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Katz power iteration restricted to the hot set K.

    ``summary`` is a ``weight="unit"`` big-vertex summary frozen from the
    previous Katz vector; per iteration every hot vertex z updates

        c(z) = β + α · ( Σ_{(u,z) ∈ E_K} c(u) + b_in(z) )

    with cold scores carried over unchanged.  Returns the *global* score
    vector and the iterations run.
    """
    backend_r = B.resolve_backend(backend)
    k_cap = summary.hot_ids.shape[0]
    local_valid = jnp.arange(k_cap, dtype=jnp.int32) < summary.num_hot
    c0 = jnp.where(local_valid, katz_prev[summary.hot_ids], 0.0)
    layout = B.summary_layout(summary)

    def body(carry):
        i, c, _ = carry
        incoming = B.push(c, layout, backend=backend_r)
        new_c = jnp.where(
            local_valid, beta + alpha * (incoming + summary.b_in), 0.0)
        delta = jnp.sum(jnp.abs(new_c - c))
        return i + 1, new_c, delta

    def cond(carry):
        i, _, delta = carry
        return (i < num_iters) & (delta > tol)

    i, c_loc, _ = jax.lax.while_loop(
        cond, body, (jnp.int32(0), c0, jnp.float32(jnp.inf)))
    katz_v = katz_prev.at[summary.hot_ids].set(c_loc, mode="drop")
    return katz_v, i


@functools.partial(
    jax.jit,
    static_argnames=("alpha", "beta", "num_iters", "tol", "backend"),
)
def summarized_katz_batched(
    summary: SummaryBuffers,
    katz_prev: jax.Array,
    *,
    alpha: float = 0.05,
    beta: float = 1.0,
    num_iters: int = 30,
    tol: float = 0.0,
    row_mask: Optional[jax.Array] = None,
    backend: Optional[str] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Batched :func:`summarized_katz`: a ``[B, N]`` score matrix sharing
    one summary, relaxed with one batched push per iteration.  ``row_mask``
    (bool[B]) freezes finished/vacant slots — masked rows carry through
    unchanged and report zero delta.  Returns
    ``(katz [B, N], iterations, row_delta f32[B])``.
    """
    backend_r = B.resolve_backend(backend)
    batch = katz_prev.shape[0]
    k_cap = summary.hot_ids.shape[0]
    local_valid = jnp.arange(k_cap, dtype=jnp.int32) < summary.num_hot
    c0 = jnp.where(local_valid, katz_prev[:, summary.hot_ids], 0.0)
    keep = (jnp.ones((batch,), bool) if row_mask is None
            else row_mask)[:, None]
    layout = B.summary_layout(summary)

    def body(carry):
        i, c, _ = carry
        incoming = B.push(c, layout, backend=backend_r)
        new_c = jnp.where(
            local_valid, beta + alpha * (incoming + summary.b_in), 0.0)
        new_c = jnp.where(keep, new_c, c)
        delta = jnp.sum(jnp.abs(new_c - c), axis=1)
        return i + 1, new_c, delta

    def cond(carry):
        i, _, delta = carry
        return (i < num_iters) & (jnp.max(delta) > tol)

    i, c_loc, delta = jax.lax.while_loop(
        cond, body,
        (jnp.int32(0), c0, jnp.full((batch,), jnp.inf, jnp.float32)))
    katz_v = katz_prev.at[:, summary.hot_ids].set(c_loc, mode="drop")
    katz_v = jnp.where(keep, katz_v, katz_prev)
    return katz_v, i, delta

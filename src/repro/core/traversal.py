"""Traversal workloads on min/max semirings: SSSP, connected components,
and widest (most-reliable) paths.

These are the ROADMAP's long-open "needs a non-float state story" workloads,
unlocked by the semiring-generic propagation API: both are the same power
sweep as PageRank, just over a different algebra —

- **SSSP** (single-source shortest paths) is Bellman-Ford iteration on the
  ``min_plus`` semiring: ``dist(v) = min(dist(v), min_{(u,v)} dist(u) +
  len(u,v))`` with source distances pinned to 0.  Edge lengths come from a
  ``weight="length"`` :class:`~repro.core.backend.EdgeLayout` (unit lengths
  — hop counts — unless the caller bakes explicit per-edge lengths).
- **Connected components** is label-min propagation on the ``min_min``
  semiring over *int32* state: every vertex starts labeled with its own id
  and repeatedly takes the minimum label over its neighborhood.  Weak
  connectivity on the directed stream needs the symmetric closure, so the
  sweep pushes over a forward and a reverse unit layout per iteration
  (labels pass through ⊗ unchanged — ``min_min``'s ⊗-identity is +∞).
- **Widest path** (most-reliable path) is the same relaxation on the
  ``max_times`` semiring: ``width(v) = max(width(v), max_{(u,v)} width(u)
  · len(u,v))`` with sources pinned to 1.  Edge lengths act as
  multiplicative reliabilities/capacities and must be **non-negative**;
  unreached vertices hold 0 (not −∞ — a finite state vector keeps
  0-length edges from manufacturing ``−∞·0`` NaNs).  This is the sweep
  that exercises the masked-reduce *max* kernel path end to end.

Both sweeps iterate until a fixed point (no vertex changed) or the
iteration budget, and both have VeilGraph-summarized versions that restrict
the relaxation to the hot set K with *frozen cold state as a Dirichlet
boundary*: ``b_in[z]`` holds the min over z's cold in-neighbors of their
frozen distance-plus-length (SSSP) or label (CC), injected each iteration
exactly like the paper's frozen big-vertex rank mass.  Because min is
associative, commutative and reassociation-exact (no floating-point
rounding in the reduce order), a summarized sweep over ``hot == all active
vertices`` reproduces the exact sweep **bitwise**, not just approximately.

Monotonicity note: both relaxations only ever decrease state, so
warm-starting from previous distances/labels is exact under edge
*additions* (the paper's e+ stream model) — the summarized paths exploit
that.  Edge removals can strand stale-low values; the exact sweeps
therefore default to a cold start (the engine's ground-truth action).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import backend as B
from repro.core.pagerank import SummaryBuffers
from repro.graph.graph import GraphState

#: int32 "+∞": the label of never-seen vertices and empty reduces.
LABEL_SENTINEL = jnp.iinfo(jnp.int32).max


def _fixed_point(step, x0, num_iters: int):
    """Iterate ``x ← step(x)`` until no element changes or the budget runs
    out.  The shared scaffold of every min-semiring sweep: the relaxations
    are monotone, so "nothing changed" identifies the fixed point exactly
    (no float-tolerance subtleties — min never rounds).  Returns
    ``(x, iterations_run)``."""

    def body(carry):
        i, x, _ = carry
        new_x = step(x)
        return i + 1, new_x, jnp.sum((new_x != x).astype(jnp.int32))

    def cond(carry):
        i, _, changed = carry
        return (i < num_iters) & (changed > 0)

    i, x, _ = jax.lax.while_loop(
        cond, body, (jnp.int32(0), x0, jnp.int32(1)))
    return x, i


def _fixed_point_batched(step, x0, num_iters: int,
                         row_mask: Optional[jax.Array]):
    """Batched :func:`_fixed_point` over ``x0`` of shape ``[B, K]``: rows
    masked out by ``row_mask`` (bool[B], ``None`` = all on) carry their
    state unchanged and report zero change.  Iterates until NO masked row
    changes (min relaxations converge unevenly; the per-row change counts
    are the serving engine's convergence signal).  Returns
    ``(x, iterations_run, changed_rows i32[B])``."""
    batch = x0.shape[0]
    keep = (jnp.ones((batch,), bool) if row_mask is None
            else row_mask)[:, None]

    def body(carry):
        i, x, _ = carry
        new_x = jnp.where(keep, step(x), x)
        changed = jnp.sum((new_x != x).astype(jnp.int32), axis=1)
        return i + 1, new_x, changed

    def cond(carry):
        i, _, changed = carry
        return (i < num_iters) & (jnp.max(changed) > 0)

    i, x, changed = jax.lax.while_loop(
        cond, body, (jnp.int32(0), x0, jnp.ones((batch,), jnp.int32)))
    return x, i, changed


# --------------------------------------------------------------------------
# SSSP — Bellman-Ford on the min_plus semiring
# --------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("num_iters", "backend"))
def sssp(
    state: GraphState,
    source_mask: jax.Array,
    dist0: Optional[jax.Array] = None,
    *,
    num_iters: int = 30,
    layout: Optional[B.EdgeLayout] = None,
    backend: Optional[str] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Bounded Bellman-Ford from the vertices in ``source_mask``.

    Returns ``(dist f32[N_cap], iterations_run)`` — ``inf`` marks
    unreachable vertices.  The loop exits as soon as an iteration changes
    no distance (a fixed point; at most the graph diameter + 1 trips).

    ``dist0`` warm-starts the relaxation (exact under edge additions —
    distances are monotone non-increasing; see the module docstring for
    the removal caveat); sources are pinned to 0 regardless.  ``layout``
    is an optional cached ``weight="length"``/``min_plus`` layout; without
    one the sweep sorts on entry (unit lengths), amortized over the
    relaxations on both backends.
    """
    backend_r = B.resolve_backend(backend)
    B.require_layout(layout, weight="length", reverse=False, who="sssp",
                     semiring="min_plus")
    inf = jnp.float32(jnp.inf)
    if dist0 is None:
        d0 = jnp.where(source_mask, 0.0, inf)
    else:
        d0 = jnp.where(source_mask, 0.0, dist0.astype(jnp.float32))

    if layout is None:
        # one sort amortized over every relaxation, on both backends (the
        # sorted gather_push skips XLA's scatter sort/unique analysis too)
        layout = B.build_layout(state, weight="length", semiring="min_plus")

    def relax(d):
        incoming = B.push(d, layout, semiring="min_plus", backend=backend_r)
        return jnp.where(source_mask, 0.0, jnp.minimum(d, incoming))

    return _fixed_point(relax, d0, num_iters)


@functools.partial(jax.jit, static_argnames=("num_iters", "backend"))
def summarized_sssp(
    summary: SummaryBuffers,
    dist_prev: jax.Array,
    source_mask: jax.Array,
    *,
    num_iters: int = 30,
    backend: Optional[str] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Bellman-Ford restricted to the hot set K (§3.1 on ``min_plus``).

    ``summary`` is a ``weight="length"``/``min_plus`` big-vertex summary:
    ``b_in[z] = min_{(w,z) ∈ E_B} dist_prev(w) + len(w,z)`` freezes the
    cold boundary.  Hot distances relax against E_K and ``b_in``; cold
    distances carry over unchanged.  Returns the *global* distance vector
    and the iterations run.
    """
    backend_r = B.resolve_backend(backend)
    k_cap = summary.hot_ids.shape[0]
    inf = jnp.float32(jnp.inf)
    local_valid = jnp.arange(k_cap, dtype=jnp.int32) < summary.num_hot
    src_local = jnp.where(local_valid, source_mask[summary.hot_ids], False)
    d0 = jnp.where(local_valid, dist_prev[summary.hot_ids], inf)
    d0 = jnp.where(src_local, 0.0, d0)
    layout = B.summary_layout(summary, semiring="min_plus")

    def relax(d):
        relaxed = jnp.minimum(
            d, jnp.minimum(
                B.push(d, layout, semiring="min_plus", backend=backend_r),
                summary.b_in))
        return jnp.where(local_valid, jnp.where(src_local, 0.0, relaxed), inf)

    d_loc, i = _fixed_point(relax, d0, num_iters)
    dist = dist_prev.at[summary.hot_ids].set(d_loc, mode="drop")
    return dist, i


@functools.partial(jax.jit, static_argnames=("num_iters", "backend"))
def summarized_sssp_batched(
    summary: SummaryBuffers,
    dist_prev: jax.Array,
    source_mask: jax.Array,
    *,
    num_iters: int = 30,
    row_mask: Optional[jax.Array] = None,
    backend: Optional[str] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Batched :func:`summarized_sssp`: B source sets, one shared summary.

    ``dist_prev``/``source_mask`` are ``[B, N]`` (per-slot source sets);
    the summary's E_K is shared while ``b_in`` may be the per-query
    ``[B, K_cap]`` form.  Each relaxation is ONE batched ``min_plus`` push
    — min is reassociation-exact, so every row is bitwise equal to its
    single-query sweep over the same summary.  ``row_mask`` (bool[B])
    freezes finished/vacant slots (see serving docs).  Returns
    ``(dist [B, N], iterations, changed_rows i32[B])``.
    """
    backend_r = B.resolve_backend(backend)
    k_cap = summary.hot_ids.shape[0]
    inf = jnp.float32(jnp.inf)
    local_valid = jnp.arange(k_cap, dtype=jnp.int32) < summary.num_hot
    src_local = jnp.where(local_valid, source_mask[:, summary.hot_ids],
                          False)
    d0 = jnp.where(local_valid, dist_prev[:, summary.hot_ids], inf)
    d0 = jnp.where(src_local, 0.0, d0)
    layout = B.summary_layout(summary, semiring="min_plus")

    def relax(d):
        relaxed = jnp.minimum(
            d, jnp.minimum(
                B.push(d, layout, semiring="min_plus", backend=backend_r),
                summary.b_in))
        return jnp.where(local_valid, jnp.where(src_local, 0.0, relaxed),
                         inf)

    d_loc, i, changed = _fixed_point_batched(relax, d0, num_iters, row_mask)
    dist = dist_prev.at[:, summary.hot_ids].set(d_loc, mode="drop")
    if row_mask is not None:
        dist = jnp.where(row_mask[:, None], dist, dist_prev)
    return dist, i, changed


# --------------------------------------------------------------------------
# Widest path — max-reliability relaxation on the max_times semiring
# --------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("num_iters", "backend"))
def widest_path(
    state: GraphState,
    source_mask: jax.Array,
    width0: Optional[jax.Array] = None,
    *,
    num_iters: int = 30,
    layout: Optional[B.EdgeLayout] = None,
    backend: Optional[str] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Bounded widest-path relaxation from the vertices in ``source_mask``.

    ``max_times`` Bellman-Ford: sources are pinned to width 1 and each
    iteration takes ``width(v) = max(width(v), max_{(u,v)} width(u) ·
    len(u,v))`` — with edge lengths in (0, 1] this is the most-reliable
    path; with capacities > 1 a multiplicative throughput.  Lengths must be
    non-negative (they come from the ``weight="length"`` layout; unit
    lengths make every reachable vertex width 1).  Returns
    ``(width f32[N_cap], iterations_run)`` — 0 marks unreachable vertices.

    ``width0`` warm-starts (exact under edge additions — widths are
    monotone non-decreasing); sources re-pin to 1 regardless.
    """
    backend_r = B.resolve_backend(backend)
    B.require_layout(layout, weight="length", reverse=False,
                     who="widest_path", semiring="max_times")
    if width0 is None:
        w0 = jnp.where(source_mask, 1.0, 0.0).astype(jnp.float32)
    else:
        w0 = jnp.where(source_mask, 1.0, width0.astype(jnp.float32))

    if layout is None:
        layout = B.build_layout(state, weight="length", semiring="max_times")

    def relax(w):
        incoming = B.push(w, layout, semiring="max_times", backend=backend_r)
        return jnp.where(source_mask, 1.0, jnp.maximum(w, incoming))

    return _fixed_point(relax, w0, num_iters)


@functools.partial(jax.jit, static_argnames=("num_iters", "backend"))
def summarized_widest_path(
    summary: SummaryBuffers,
    width_prev: jax.Array,
    source_mask: jax.Array,
    *,
    num_iters: int = 30,
    backend: Optional[str] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Widest-path relaxation restricted to the hot set K.

    ``summary`` is a ``weight="length"``/``max_times`` big-vertex summary:
    ``b_in[z] = max_{(w,z) ∈ E_B} width_prev(w) · len(w,z)`` freezes the
    cold boundary (−∞ where z has no cold in-neighbors — harmless under
    max).  Hot widths relax against E_K and ``b_in``; cold widths carry
    over unchanged.  Returns the *global* width vector and the iterations
    run.
    """
    backend_r = B.resolve_backend(backend)
    k_cap = summary.hot_ids.shape[0]
    local_valid = jnp.arange(k_cap, dtype=jnp.int32) < summary.num_hot
    src_local = jnp.where(local_valid, source_mask[summary.hot_ids], False)
    w0 = jnp.where(local_valid, width_prev[summary.hot_ids], 0.0)
    w0 = jnp.where(src_local, 1.0, w0)
    layout = B.summary_layout(summary, semiring="max_times")

    def relax(w):
        relaxed = jnp.maximum(
            w, jnp.maximum(
                B.push(w, layout, semiring="max_times", backend=backend_r),
                summary.b_in))
        return jnp.where(local_valid, jnp.where(src_local, 1.0, relaxed), 0.0)

    w_loc, i = _fixed_point(relax, w0, num_iters)
    width = width_prev.at[summary.hot_ids].set(w_loc, mode="drop")
    return width, i


@functools.partial(jax.jit, static_argnames=("num_iters", "backend"))
def summarized_widest_path_batched(
    summary: SummaryBuffers,
    width_prev: jax.Array,
    source_mask: jax.Array,
    *,
    num_iters: int = 30,
    row_mask: Optional[jax.Array] = None,
    backend: Optional[str] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Batched :func:`summarized_widest_path`: B source sets, one summary.

    ``width_prev``/``source_mask`` are ``[B, N]``; each relaxation is ONE
    batched ``max_times`` push — max is reassociation-exact, so every row
    is bitwise equal to its single-query sweep over the same summary (the
    ``summarized_batched`` leg of the tuned masked-reduce max path).
    ``row_mask`` (bool[B]) freezes finished/vacant slots.  Returns
    ``(width [B, N], iterations, changed_rows i32[B])``.
    """
    backend_r = B.resolve_backend(backend)
    k_cap = summary.hot_ids.shape[0]
    local_valid = jnp.arange(k_cap, dtype=jnp.int32) < summary.num_hot
    src_local = jnp.where(local_valid, source_mask[:, summary.hot_ids],
                          False)
    w0 = jnp.where(local_valid, width_prev[:, summary.hot_ids], 0.0)
    w0 = jnp.where(src_local, 1.0, w0)
    layout = B.summary_layout(summary, semiring="max_times")

    def relax(w):
        relaxed = jnp.maximum(
            w, jnp.maximum(
                B.push(w, layout, semiring="max_times", backend=backend_r),
                summary.b_in))
        return jnp.where(local_valid, jnp.where(src_local, 1.0, relaxed),
                         0.0)

    w_loc, i, changed = _fixed_point_batched(relax, w0, num_iters, row_mask)
    width = width_prev.at[:, summary.hot_ids].set(w_loc, mode="drop")
    if row_mask is not None:
        width = jnp.where(row_mask[:, None], width, width_prev)
    return width, i, changed


# --------------------------------------------------------------------------
# Connected components — label-min propagation on the min_min semiring
# --------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("num_iters", "backend"))
def connected_components(
    state: GraphState,
    labels0: Optional[jax.Array] = None,
    *,
    num_iters: int = 30,
    fwd_layout: Optional[B.EdgeLayout] = None,
    rev_layout: Optional[B.EdgeLayout] = None,
    backend: Optional[str] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Weakly-connected components by label-min propagation.

    Returns ``(labels i32[N_cap], iterations_run)``: every active vertex
    ends up labeled with the minimum vertex id of its weakly-connected
    component; inactive vertices hold :data:`LABEL_SENTINEL`.  ``labels0``
    warm-starts (labels are monotone non-increasing under edge additions);
    every active vertex is re-seeded with ``min(labels0[v], v)`` so
    vertices first seen after ``labels0`` was computed join correctly.
    """
    backend_r = B.resolve_backend(backend)
    B.require_layout(fwd_layout, weight="unit", reverse=False,
                     who="connected_components fwd_layout",
                     semiring="min_min")
    B.require_layout(rev_layout, weight="unit", reverse=True,
                     who="connected_components rev_layout",
                     semiring="min_min")
    n_cap = state.node_capacity
    active = state.node_active
    ids = jnp.arange(n_cap, dtype=jnp.int32)
    if labels0 is None:
        l0 = jnp.where(active, ids, LABEL_SENTINEL)
    else:
        l0 = jnp.where(active, jnp.minimum(labels0.astype(jnp.int32), ids),
                       LABEL_SENTINEL)

    # each direction's sort is amortized over every relaxation, on both
    # backends; a caller may have either one of the two cached already
    if fwd_layout is None:
        fwd_layout = B.build_layout(state, weight="unit", semiring="min_min")
    if rev_layout is None:
        rev_layout = B.build_layout(state, weight="unit", reverse=True,
                                    semiring="min_min")

    def relax(lab):
        incoming = jnp.minimum(
            B.push(lab, fwd_layout, semiring="min_min", backend=backend_r),
            B.push(lab, rev_layout, semiring="min_min", backend=backend_r))
        return jnp.where(active, jnp.minimum(lab, incoming), LABEL_SENTINEL)

    return _fixed_point(relax, l0, num_iters)


@functools.partial(jax.jit, static_argnames=("num_iters", "backend"))
def summarized_connected_components(
    fwd: SummaryBuffers,
    rev: SummaryBuffers,
    labels_prev: jax.Array,
    *,
    num_iters: int = 30,
    backend: Optional[str] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Label-min propagation restricted to the hot set K.

    ``fwd``/``rev`` are ``weight="unit"``/``min_min`` summaries over the
    same hot mask (so they share ``hot_ids``); their ``b_in`` vectors
    freeze the minimum cold label reachable over one boundary edge in each
    orientation.  Hot labels relax against E_K (both directions) and the
    frozen boundary; cold labels carry over unchanged.  Returns the
    *global* label vector and the iterations run.
    """
    backend_r = B.resolve_backend(backend)
    k_cap = fwd.hot_ids.shape[0]
    local_valid = jnp.arange(k_cap, dtype=jnp.int32) < fwd.num_hot
    # re-seed with own ids: a vertex first seen after labels_prev was
    # computed is necessarily hot (new vertices always enter K_r)
    l0 = jnp.where(
        local_valid,
        jnp.minimum(labels_prev.astype(jnp.int32)[fwd.hot_ids], fwd.hot_ids),
        LABEL_SENTINEL)
    boundary = jnp.minimum(fwd.b_in, rev.b_in)
    fwd_layout = B.summary_layout(fwd, semiring="min_min")
    rev_layout = B.summary_layout(rev, semiring="min_min")

    def relax(lab):
        incoming = jnp.minimum(
            B.push(lab, fwd_layout, semiring="min_min", backend=backend_r),
            B.push(lab, rev_layout, semiring="min_min", backend=backend_r))
        relaxed = jnp.minimum(lab, jnp.minimum(incoming, boundary))
        return jnp.where(local_valid, relaxed, LABEL_SENTINEL)

    l_loc, i = _fixed_point(relax, l0, num_iters)
    labels = labels_prev.at[fwd.hot_ids].set(l_loc, mode="drop")
    return labels, i


@functools.partial(jax.jit, static_argnames=("num_iters", "backend"))
def summarized_connected_components_batched(
    fwd: SummaryBuffers,
    rev: SummaryBuffers,
    labels_prev: jax.Array,
    *,
    num_iters: int = 30,
    row_mask: Optional[jax.Array] = None,
    backend: Optional[str] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Batched :func:`summarized_connected_components` over ``[B, N]``
    label matrices sharing one fwd/rev summary pair.  Label-min is
    reassociation-exact, so each row matches its single-query sweep
    bitwise.  ``row_mask`` (bool[B]) freezes finished/vacant slots.
    Returns ``(labels [B, N], iterations, changed_rows i32[B])``.
    """
    backend_r = B.resolve_backend(backend)
    k_cap = fwd.hot_ids.shape[0]
    local_valid = jnp.arange(k_cap, dtype=jnp.int32) < fwd.num_hot
    l0 = jnp.where(
        local_valid,
        jnp.minimum(labels_prev.astype(jnp.int32)[:, fwd.hot_ids],
                    fwd.hot_ids),
        LABEL_SENTINEL)
    boundary = jnp.minimum(fwd.b_in, rev.b_in)
    fwd_layout = B.summary_layout(fwd, semiring="min_min")
    rev_layout = B.summary_layout(rev, semiring="min_min")

    def relax(lab):
        incoming = jnp.minimum(
            B.push(lab, fwd_layout, semiring="min_min", backend=backend_r),
            B.push(lab, rev_layout, semiring="min_min", backend=backend_r))
        relaxed = jnp.minimum(lab, jnp.minimum(incoming, boundary))
        return jnp.where(local_valid, relaxed, LABEL_SENTINEL)

    l_loc, i, changed = _fixed_point_batched(relax, l0, num_iters, row_mask)
    labels = labels_prev.at[:, fwd.hot_ids].set(l_loc, mode="drop")
    if row_mask is not None:
        labels = jnp.where(row_mask[:, None], labels, labels_prev)
    return labels, i, changed

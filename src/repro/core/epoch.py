"""Epoch-versioned snapshots: the async rebuild pipeline's data model.

The synchronous engine rebuilds between apply and query — layout sorts,
summary builds and rebalance recuts all sit on the query's critical path.
The async pipeline (``EngineConfig.async_rebuild=True``) double-buffers
instead: queries serve a frozen :class:`EpochSnapshot` N while snapshot
N+1's rebuild work is *dispatched but never awaited* — JAX's async
dispatch overlaps it with the host-side serving loop for free, because
nothing in this module (or in the apply→query gap it models) forces a
result.  This file is deliberately sync-free and is linted as a hot
module (AST-HOST-SYNC): every host transfer of the async pipeline lives
at the engine/serving boundary, never here.

An :class:`EpochSnapshot` freezes everything a query reads:

- the graph buffers (``GraphState``) — the async apply path uses the
  *non-donating* mutation variants
  (:func:`repro.graph.graph.add_edges_preserving`), so a snapshot's
  arrays stay valid while the engine's live state advances past it;
- the cached sorted ``EdgeLayout``/``ShardedEdgeLayout`` per normalized
  layout spec (built lazily per spec, dispatched eagerly for every spec
  the engine has served so far, at the autotuned geometry);
- the hot-set baselines (degree/activity snapshot at this epoch) that
  become ``deg_prev``/``active_prev`` once a query serves the epoch;
- dispatched-not-awaited device scalars: the node/edge counts
  (:func:`snapshot_counts`) and, for mesh engines, the rebalance
  verdict — both fetched by the engine at *promotion* time, one small
  transfer per epoch flip instead of one per applied batch.

The :class:`AsyncRebuildPipeline` owns exactly two slots — ``current``
(served) and ``building`` (dispatched) — so ``snapshot_lag`` is always 0
or 1.  Promotion happens at wave boundaries only, via :meth:`promote`;
:meth:`dispatch` refuses to overwrite an unpromoted build and enforces
monotone epoch ids, so a completed build can never be skipped.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.graph.graph import GraphState

#: a normalized (weight, reverse, semiring) layout spec — the snapshot
#: layout cache's key type (see ``repro.core.backend.normalize_layout_spec``).
LayoutSpec = Tuple


@jax.jit
def snapshot_counts(state: GraphState) -> jax.Array:
    """int32[2] device vector ``[active_nodes, live_edges]`` for one
    snapshot — dispatched at build time, fetched at promotion time, so
    the serving loop's stats views never force a sync on the live state
    (the sync engine's ``int(num_active_nodes())`` per query)."""
    return jnp.stack([state.num_active_nodes().astype(jnp.int32),
                      state.num_live_edges().astype(jnp.int32)])


@dataclass
class EpochSnapshot:
    """One immutable serving epoch: graph buffers + everything derived
    from them that a query reads, stamped with a monotone epoch id.

    ``deg``/``active`` are this epoch's own hot-set baselines (copies of
    the degree/activity vectors at build time); the engine installs them
    as ``deg_prev``/``active_prev`` after serving a query at this epoch,
    so the first query after a flip sees exactly the inter-epoch churn.
    ``counts`` and ``rebalance_probe`` are dispatched device scalars the
    engine reads once, at promotion (``num_nodes``/``num_edges`` are
    their host-side values, filled at that point).  ``applied`` /
    ``removals_*`` record the update batch this epoch integrated over
    its parent — charged to the stats row of the query that promotes it
    (the query at which the updates become visible).
    """

    epoch: int
    state: GraphState
    deg: jax.Array
    active: jax.Array
    counts: jax.Array
    num_nodes: Optional[int] = None
    num_edges: Optional[int] = None
    applied: int = 0
    removals_requested: int = 0
    removals_resolved: int = 0
    rebalance_probe: Optional[Tuple[jax.Array, jax.Array]] = None
    layouts: Dict[LayoutSpec, Any] = field(default_factory=dict)

    def layout_for(self, spec: LayoutSpec,
                   builder: Callable[[GraphState, LayoutSpec], Any]) -> Any:
        """The snapshot's sorted layout for one normalized spec — built
        (dispatched) on first request against *this epoch's* buffers and
        cached for every later consumer; a layout built here is never
        rebuilt and never observes a later epoch's mutations."""
        layout = self.layouts.get(spec)
        if layout is None:
            layout = builder(self.state, spec)
            self.layouts[spec] = layout
        return layout


class AsyncRebuildPipeline:
    """Double-buffered epoch store: serve ``current`` while ``building``
    is in flight.  Pure host bookkeeping — no device work, no syncs.

    Invariants (the property suite in ``tests/test_async_pipeline.py``
    pins all four): epoch ids are strictly monotone; ``snapshot_lag`` is
    0 or 1; a dispatched build is promoted before the next dispatch
    (never skipped, never overwritten); promotion only ever installs the
    build dispatched for ``current.epoch + 1``.
    """

    def __init__(self, initial: EpochSnapshot):
        self.current = initial
        self.building: Optional[EpochSnapshot] = None
        self.promotions = 0
        self.dispatches = 0

    @property
    def epoch(self) -> int:
        """The served epoch id."""
        return self.current.epoch

    @property
    def latest_epoch(self) -> int:
        """The newest epoch that exists (building if in flight)."""
        return (self.building.epoch if self.building is not None
                else self.current.epoch)

    @property
    def snapshot_lag(self) -> int:
        """How many epochs the served snapshot trails the newest build
        (0 = fully caught up; never exceeds 1 by construction)."""
        return self.latest_epoch - self.current.epoch

    def dispatch(self, snapshot: EpochSnapshot) -> None:
        """Register epoch N+1 (its device work is already enqueued).
        Refuses to overwrite an unpromoted build or accept a
        non-successor epoch id — promotion can never skip a build."""
        if self.building is not None:
            raise RuntimeError(
                f"epoch {self.building.epoch} was dispatched but never "
                f"promoted; promote at the wave boundary before "
                f"dispatching epoch {snapshot.epoch}")
        if snapshot.epoch != self.current.epoch + 1:
            raise RuntimeError(
                f"non-monotone epoch dispatch: serving "
                f"{self.current.epoch}, got {snapshot.epoch}")
        self.building = snapshot
        self.dispatches += 1

    def promote(self) -> Optional[EpochSnapshot]:
        """Wave-boundary flip: install the building snapshot as current
        (a pure host reference swap — never blocks on its device work)
        and return it; ``None`` when no build is in flight."""
        if self.building is None:
            return None
        snapshot, self.building = self.building, None
        self.current = snapshot
        self.promotions += 1
        return snapshot

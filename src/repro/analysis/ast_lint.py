"""Project AST lint: source-level convention checks for the engine surface.

Pure-stdlib (``ast``) rules over ``src/repro``, scoped to the VeilGraph
engine — the quarantined LM substrate (:data:`SKIP_LIST`) is excluded so
the pass maps exactly to the graph system:

- **AST-SEGMENT-REDUCE** — no direct ``segment_sum``/``segment_min``/
  ``segment_max``/``segment_prod`` calls in ``core/`` outside
  ``backend.py``: every sweep must go through :func:`repro.core.backend.
  push` (or the semiring's single dispatch point) so layouts, masks and
  sortedness flags can't drift per call site.
- **AST-PLUGIN-FROZEN** / **AST-PLUGIN-ARRAY-FIELD** — every
  ``StreamingAlgorithm`` subclass must be a ``@dataclass(frozen=True)``
  (it rides through jit as a *static*, hashable argument) and must never
  declare an array-typed field or an array default: per-query traced
  state belongs in ``per_query_params``/``init_state``, never on the
  plugin (the PR 6 contract, machine-checked).
- **AST-HOST-SYNC** — no ``.block_until_ready()``, ``jax.device_get``,
  ``np.asarray(...)`` or ``float(...)``/``int(...)`` coercions of
  computed values inside the hot modules (:data:`HOT_MODULES`): each one
  is a device→host sync that serializes the async dispatch pipeline.
  The engine/serving orchestration layers are the designated host
  boundary and are deliberately not in the hot list.
- **AST-KERNEL-GEOMETRY** — call sites must not hardcode literal
  ``tile_n=``/``chunk=`` kernel geometry outside the kernel/autotuner
  modules themselves: geometry flows from the autotune resolver through
  layout metadata (``EngineConfig.autotune`` → ``build_layout(tile_n=,
  chunk=)`` → ``push`` reads the stamp), so a literal at a call site
  silently pins an untuned shape.

Intentional violations are either allowlisted in
``benchmarks/analysis_baseline.json`` (with a reason) or waived inline
with a ``# analysis: allow(RULE): reason`` comment on the offending line
(or the line above) — see ``docs/analysis.md`` for when to use which.
"""

from __future__ import annotations

import ast
import re
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set

from repro.analysis.findings import Finding

REPO_ROOT = Path(__file__).resolve().parents[3]

#: the quarantined LM substrate — transformer models, their training/serving
#: drivers and the attention kernels kept for reference.  Excluded so the
#: lint's scope is exactly the VeilGraph engine surface (README "Repo
#: layout"); paths are repo-relative prefixes.
SKIP_LIST: tuple = (
    "src/repro/models/",
    "src/repro/train/",
    "src/repro/configs/",
    "src/repro/data/",
    "src/repro/kernels/decode_attention/",
    "src/repro/kernels/flash_attention/",
    "src/repro/launch/specs.py",     # LM dry-run cell specs
    "src/repro/launch/train.py",     # LM training driver
    "src/repro/launch/serve.py",     # LM serving driver
    "src/repro/serve/engine.py",     # LM continuous-batching skeleton
)

#: modules where a hidden device→host sync is a hot-path bug, not a
#: convenience: the propagation primitives, the fused query/summary path
#: and the layout/partition builders — everything that runs per query or
#: per applied update batch.  ``core/engine.py`` and ``serve/graph.py``
#: are the host orchestration boundary and intentionally absent.
HOT_MODULES: tuple = (
    "src/repro/core/backend.py",
    "src/repro/core/epoch.py",
    "src/repro/core/fused.py",
    "src/repro/core/hits.py",
    "src/repro/core/hotset.py",
    "src/repro/core/katz.py",
    "src/repro/core/pagerank.py",
    "src/repro/core/semiring.py",
    "src/repro/core/traversal.py",
    "src/repro/graph/csr.py",
    "src/repro/graph/partition.py",
    "src/repro/kernels/spmv/kernel.py",
    "src/repro/kernels/spmv/ops.py",
)

#: ``core/`` modules allowed to call XLA segment reduces directly: the
#: propagation backend itself (``push_coo``'s fallback lives there).
SEGMENT_REDUCE_ALLOWED: tuple = ("src/repro/core/backend.py",)

#: kernel entry points whose geometry kwargs must come from the autotune
#: resolver (a variable / layout stamp), never a literal at the call site
_KERNEL_ENTRY_POINTS = {
    "spmv_push", "spmv_push_batched",
    "spmv_reduce_push", "spmv_reduce_push_batched",
}
#: modules that *define* geometry: the kernels, their autotuner, and the
#: backend's layout builders (where the resolved geometry is stamped)
_GEOMETRY_ALLOWED: tuple = (
    "src/repro/kernels/spmv/",
    "src/repro/core/backend.py",
)

_SEGMENT_FNS = {"segment_sum", "segment_min", "segment_max", "segment_prod"}

_WAIVER_RE = re.compile(r"#\s*analysis:\s*allow\(([A-Z0-9\-, ]+)\)")

_ARRAY_ANNOTATIONS = re.compile(
    r"\b(jax\.Array|Array|jnp\.ndarray|np\.ndarray|numpy\.ndarray|"
    r"ArrayLike|DeviceArray)\b")
_ARRAY_FACTORIES = {"array", "asarray", "zeros", "ones", "full", "arange",
                    "linspace", "empty", "zeros_like", "ones_like",
                    "full_like"}


def _rel(path: Path) -> str:
    try:
        return path.resolve().relative_to(REPO_ROOT).as_posix()
    except ValueError:
        return path.as_posix()


def _skipped(rel: str) -> bool:
    return any(rel == s or rel.startswith(s) for s in SKIP_LIST)


def _call_name(node: ast.Call) -> str:
    f = node.func
    if isinstance(f, ast.Name):
        return f.id
    if isinstance(f, ast.Attribute):
        return f.attr
    return ""


def _dotted(node: ast.AST) -> str:
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    return ".".join(reversed(parts))


def _waivers(source: str) -> Dict[int, Set[str]]:
    """Line → waived rule ids, from ``# analysis: allow(RULE): reason``."""
    out: Dict[int, Set[str]] = {}
    for i, line in enumerate(source.splitlines(), start=1):
        m = _WAIVER_RE.search(line)
        if m:
            rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
            out[i] = rules
    return out


class _ScopeVisitor(ast.NodeVisitor):
    """Tracks the enclosing def/class name for stable ``where`` keys."""

    def __init__(self):
        self.scope: List[str] = []

    def _scope_name(self) -> str:
        return ".".join(self.scope) if self.scope else "<module>"

    def visit_FunctionDef(self, node):
        self.scope.append(node.name)
        self.generic_visit(node)
        self.scope.pop()

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_ClassDef(self, node):
        self.scope.append(node.name)
        self.generic_visit(node)
        self.scope.pop()


class _Linter(_ScopeVisitor):
    def __init__(self, rel: str, source: str, *,
                 plugin_bases: Set[str]):
        super().__init__()
        self.rel = rel
        self.findings: List[Finding] = []
        self.waivers = _waivers(source)
        self.plugin_bases = plugin_bases
        self.in_core = rel.startswith("src/repro/core/")
        self.is_hot = rel in HOT_MODULES
        self.segment_ok = rel in SEGMENT_REDUCE_ALLOWED
        self.geometry_ok = any(rel == g or rel.startswith(g)
                               for g in _GEOMETRY_ALLOWED)

    def _emit(self, rule: str, node: ast.AST, detail: str) -> None:
        line = getattr(node, "lineno", 0)
        for waived_line in (line, line - 1):
            if rule in self.waivers.get(waived_line, set()):
                return
        self.findings.append(Finding(
            pass_id="ast", rule=rule,
            where=f"{self.rel}:{self._scope_name()}",
            detail=f"line {line}: {detail}"))

    # -- AST-SEGMENT-REDUCE / AST-HOST-SYNC / AST-KERNEL-GEOMETRY ----------

    def visit_Call(self, node: ast.Call):
        name = _call_name(node)
        dotted = _dotted(node.func)

        if (self.in_core and not self.segment_ok
                and isinstance(node.func, ast.Name)
                and name in _SEGMENT_FNS):
            self._emit(
                "AST-SEGMENT-REDUCE", node,
                f"direct {name}() in core/ — route the reduce through "
                f"repro.core.backend.push (or the semiring dispatch) so "
                f"sortedness/masking can't drift per site")

        if self.is_hot:
            if isinstance(node.func, ast.Attribute) and \
                    node.func.attr == "block_until_ready":
                self._emit(
                    "AST-HOST-SYNC", node,
                    "block_until_ready() in a hot module — a device sync "
                    "that stalls async dispatch; force results only at the "
                    "engine/serving host boundary")
            elif dotted in ("jax.device_get", "device_get"):
                self._emit(
                    "AST-HOST-SYNC", node,
                    "jax.device_get() in a hot module — device→host "
                    "transfer; return arrays and let the orchestration "
                    "layer fetch once per batch")
            elif dotted in ("np.asarray", "numpy.asarray", "onp.asarray"):
                self._emit(
                    "AST-HOST-SYNC", node,
                    "np.asarray() in a hot module forces a device→host "
                    "copy when handed a traced/device array; keep hot-path "
                    "data in jnp")
            elif (isinstance(node.func, ast.Name)
                  and node.func.id in ("float", "int")
                  and node.args
                  and isinstance(node.args[0],
                                 (ast.Call, ast.Subscript))):
                self._emit(
                    "AST-HOST-SYNC", node,
                    f"{node.func.id}(...) of a computed value in a hot "
                    f"module — an implicit device→host read; compare on "
                    f"device and transfer one verdict instead")

        if not self.geometry_ok and name in _KERNEL_ENTRY_POINTS:
            for kw in node.keywords:
                if kw.arg in ("tile_n", "chunk") and \
                        isinstance(kw.value, ast.Constant) and \
                        isinstance(kw.value.value, int):
                    self._emit(
                        "AST-KERNEL-GEOMETRY", node,
                        f"{name}({kw.arg}={kw.value.value}) hardcodes "
                        f"kernel geometry at the call site — route through "
                        f"the autotune resolver "
                        f"(repro.kernels.spmv.autotune.tune_for_push) or "
                        f"the layout's stamped tile_n/tile_chunk")
        self.generic_visit(node)

    def visit_Attribute(self, node: ast.Attribute):
        # references count too: stashing jax.ops.segment_sum in a dispatch
        # table is still a direct segment reduce at this site
        if (self.in_core and not self.segment_ok
                and node.attr in _SEGMENT_FNS):
            self._emit(
                "AST-SEGMENT-REDUCE", node,
                f"direct {_dotted(node)} in core/ — route the reduce "
                f"through repro.core.backend.push (or the semiring "
                f"dispatch) so sortedness/masking can't drift per site")
        self.generic_visit(node)

    # -- AST-PLUGIN-FROZEN / AST-PLUGIN-ARRAY-FIELD -------------------------

    def visit_ClassDef(self, node: ast.ClassDef):
        base_names = {_dotted(b) or getattr(b, "id", "") for b in node.bases}
        base_names = {b.split(".")[-1] for b in base_names if b}
        is_plugin = bool(base_names & self.plugin_bases)
        if is_plugin:
            self.plugin_bases.add(node.name)  # transitive subclasses
        self.scope.append(node.name)
        if is_plugin:
            self._check_plugin(node)
        self.generic_visit(node)
        self.scope.pop()

    def _check_plugin(self, node: ast.ClassDef) -> None:
        frozen = False
        for dec in node.decorator_list:
            if isinstance(dec, ast.Call) and \
                    _dotted(dec.func).split(".")[-1] == "dataclass":
                for kw in dec.keywords:
                    if kw.arg == "frozen" and \
                            isinstance(kw.value, ast.Constant) and \
                            kw.value.value is True:
                        frozen = True
        if not frozen:
            self._emit(
                "AST-PLUGIN-FROZEN", node,
                f"StreamingAlgorithm subclass {node.name!r} is not a "
                f"@dataclass(frozen=True) — plugins ride through jit as "
                f"static (hashable) arguments; a mutable plugin retraces "
                f"or silently stales")
        for item in node.body:
            if isinstance(item, ast.AnnAssign) and \
                    isinstance(item.target, ast.Name):
                ann = ast.unparse(item.annotation)
                if _ARRAY_ANNOTATIONS.search(ann):
                    self._emit(
                        "AST-PLUGIN-ARRAY-FIELD", item,
                        f"plugin field {item.target.id!r} annotated "
                        f"{ann!r} — plugins must never store traced "
                        f"arrays; per-query state belongs in "
                        f"init_state/per_query_params")
                value = item.value
            elif isinstance(item, ast.Assign):
                value = item.value
            else:
                continue
            if isinstance(value, ast.Call):
                mod = _dotted(value.func)
                if (value.func and _call_name(value) in _ARRAY_FACTORIES
                        and mod.split(".")[0] in ("jnp", "np", "jax",
                                                  "numpy")):
                    self._emit(
                        "AST-PLUGIN-ARRAY-FIELD", item,
                        f"plugin field default calls {mod}() — an array "
                        f"default makes the plugin unhashable (and leaks "
                        f"one array across every query); use "
                        f"init_state/per_query_params")


def iter_source_files(root: Path = REPO_ROOT) -> List[Path]:
    """Every lint-scoped python file: ``src/repro`` minus the skip-list."""
    out = []
    for p in sorted((root / "src" / "repro").rglob("*.py")):
        if not _skipped(_rel(p)):
            out.append(p)
    return out


def lint_files(paths: Optional[Iterable[Path]] = None,
               *, plugin_bases: Optional[Set[str]] = None) -> List[Finding]:
    """Run every AST rule over ``paths`` (default: the scoped tree).

    ``plugin_bases`` seeds the ``StreamingAlgorithm`` lineage (tests pass
    it to lint fabricated files in isolation); subclasses found during the
    walk extend it, so transitive plugins in later files are covered.
    """
    findings: List[Finding] = []
    bases = plugin_bases if plugin_bases is not None else {
        "StreamingAlgorithm"}
    for path in (iter_source_files() if paths is None else list(paths)):
        source = Path(path).read_text(encoding="utf-8")
        try:
            tree = ast.parse(source)
        except SyntaxError as e:  # pragma: no cover - tree is parseable
            findings.append(Finding(
                pass_id="ast", rule="AST-SYNTAX",
                where=f"{_rel(Path(path))}:<module>",
                detail=f"unparseable: {e}"))
            continue
        linter = _Linter(_rel(Path(path)), source, plugin_bases=bases)
        linter.visit(tree)
        findings.extend(linter.findings)
    # aggregate repeats of one (rule, scope): the key is what baselines
    # match on, so N sites in one scope are one finding with a count
    seen: Dict[str, Finding] = {}
    counts: Dict[str, int] = {}
    for f in findings:
        if f.key not in seen:
            seen[f.key] = f
            counts[f.key] = 1
        else:
            counts[f.key] += 1
    out = []
    for key, f in seen.items():
        if counts[key] > 1:
            f = Finding(f.pass_id, f.rule, f.where,
                        f"{f.detail} [{counts[key]} occurrences]")
        out.append(f)
    return out

"""repro.analysis — static analysis proving the hot path stays on-device.

Four cooperating passes over the traced programs and the source tree,
unified behind ``tools/analyze.py`` and the committed baseline
``benchmarks/analysis_baseline.json`` (see ``docs/analysis.md``):

- :mod:`~repro.analysis.jaxpr_lint` — jaxpr contract lint (no f64, no
  64-bit widening converts, no unsorted scatter-reduce, no host
  callbacks, no ``[E, N]``-class intermediates);
- :mod:`~repro.analysis.hlo_audit` — collective/memory byte budgets over
  compiled HLO (the generalized dry-run all-gather gate);
- :mod:`~repro.analysis.retrace` — jit cache-miss monitor asserting each
  engine loop traces once per (shape, algorithm, geometry);
- :mod:`~repro.analysis.ast_lint` — source-level convention rules for
  the engine surface (sorted reduces through ``push``, frozen
  array-free plugins, no hidden host syncs, autotuned kernel geometry).

:mod:`~repro.analysis.programs` holds the hot-path program catalog the
traced-program passes run over; :mod:`~repro.analysis.findings` the
shared finding/baseline model.
"""

from repro.analysis.findings import (BaselineEntry, Finding, check,
                                     load_baseline, render_report)

__all__ = [
    "BaselineEntry",
    "Finding",
    "check",
    "load_baseline",
    "render_report",
]

"""Jaxpr contract lint: structural invariants of the traced hot path.

Walks a program's :class:`jax.core.ClosedJaxpr` (recursing through every
sub-jaxpr — pjit bodies, scan/while carries, cond branches, shard_map,
custom-derivative wrappers) and enforces the contracts that keep the
approximate path cheap on hardware:

- **JXP-F64** — no 64-bit array anywhere (f64/c128/i64/u64): the engine
  is an f32/bf16-accumulate system; one stray wide dtype doubles HBM
  traffic and knocks the MXU path out.
- **JXP-WIDEN64** — no ``convert_element_type`` that widens into an
  8-byte dtype.  Widening into ≤4-byte dtypes is the legal
  accumulate-up pattern (bf16→f32, bool→i32 mask counts); f32→f64 is the
  silent promotion this rule exists to catch.
- **JXP-UNSORTED-SCATTER** — no *edge-scale* scatter-reduce
  (``scatter-add``/``-min``/``-max``/``-mul``) with
  ``indices_are_sorted=False``: the structural generalization of the
  PR 4 ``push_coo`` trace-count pin.  Sorted layouts make the same
  reduce a linear segmented pass; an unsorted edge-scale scatter in a
  hot program means some sweep bypassed the cached layouts.  The rule
  keys on the *updates* operand's element count against
  ``edge_threshold`` (half an edge buffer): scatters over an apply
  chunk (degree bookkeeping, O(chunk)) or the hot-set K-space
  (compaction marks, O(K)) are not the O(E)-random-HBM-writes failure
  class and are exempt.
- **JXP-CALLBACK** — no host callbacks (``pure_callback``/
  ``io_callback``/``debug_callback``/infeed/outfeed) inside a jitted
  sweep: each one is a device→host round-trip per execution.
- **JXP-EDGE-NODE-MATERIALIZE** — no intermediate of ``[E, N]``-class
  size (≥ ``spec.en_threshold`` elements): materializing an
  edge-count × vertex-count buffer is the quadratic blowup a push-based
  system exists to avoid.  Tiles *inside* ``pallas_call`` kernels are
  exempt — a ``[chunk, tile_n]`` one-hot block is the kernel's bounded
  VMEM working set, not an HBM materialization.

Use :func:`lint_jaxpr` on one traced program, or :func:`lint_programs`
over the :mod:`repro.analysis.programs` catalog.
"""

from __future__ import annotations

from typing import Any, Dict, Iterable, List, Optional, Tuple

import jax
import numpy as np
from jax import core as jax_core

from repro.analysis.findings import Finding

#: dtypes banned outright on the hot path
_WIDE_DTYPES = {"float64", "complex128", "int64", "uint64"}

#: scatter primitives that perform a reduction (plain ``scatter`` —
#: ``.at[].set`` — overwrites and is order-independent per index)
_SCATTER_REDUCE_PRIMS = {"scatter-add", "scatter-min", "scatter-max",
                         "scatter-mul"}

_CALLBACK_PRIMS = {"pure_callback", "io_callback", "debug_callback",
                   "outside_call", "infeed", "outfeed"}

#: widening converts may target at most this many bytes per element
#: (bf16→f32 accumulation et al.); wider targets are JXP-WIDEN64
_MAX_WIDEN_TARGET_BYTES = 4


def _aval_of(v: Any):
    return getattr(v, "aval", None)


def _iter_subjaxprs(params: Dict[str, Any]) -> Iterable[Tuple[str, Any]]:
    """Every (param_name, jaxpr) nested in an eqn's params."""
    for key, val in params.items():
        vals = val if isinstance(val, (list, tuple)) else (val,)
        for v in vals:
            if isinstance(v, jax_core.ClosedJaxpr):
                yield key, v.jaxpr
            elif isinstance(v, jax_core.Jaxpr):
                yield key, v


class _JaxprLinter:
    def __init__(self, program: str, *, en_threshold: Optional[int],
                 edge_threshold: Optional[int] = None,
                 check_f64: bool = True):
        self.program = program
        self.en_threshold = en_threshold
        self.edge_threshold = edge_threshold
        self.check_f64 = check_f64
        self.findings: List[Finding] = []
        self._seen_keys: Dict[str, int] = {}

    def _emit(self, rule: str, prim: str, detail: str) -> None:
        # aggregate per (rule, program, primitive): instruction indices are
        # not stable across refactors, so the key carries none — the first
        # occurrence's detail + a count is the diagnostic
        where = f"{self.program}:{prim}"
        key = f"{rule}::{where}"
        if key in self._seen_keys:
            self._seen_keys[key] += 1
            return
        self._seen_keys[key] = 1
        self.findings.append(Finding(
            pass_id="jaxpr", rule=rule, where=where, detail=detail))

    def _check_aval(self, aval, prim: str, role: str) -> None:
        dtype = getattr(aval, "dtype", None)
        if dtype is None:
            return
        if self.check_f64 and str(dtype) in _WIDE_DTYPES:
            self._emit(
                "JXP-F64", prim,
                f"{role} of {prim!r} has 64-bit dtype {dtype} "
                f"(shape {tuple(getattr(aval, 'shape', ()))}); the hot "
                f"path is f32/bf16-accumulate only")

    def walk(self, jaxpr: jax_core.Jaxpr, *, in_pallas: bool = False
             ) -> None:
        for v in list(jaxpr.invars) + list(jaxpr.constvars):
            self._check_aval(_aval_of(v), "<arg>", "input/const")
        for eqn in jaxpr.eqns:
            prim = eqn.primitive.name
            for ov in eqn.outvars:
                aval = _aval_of(ov)
                self._check_aval(aval, prim, "output")
                if (self.en_threshold is not None and not in_pallas
                        and aval is not None
                        and getattr(aval, "shape", None) is not None):
                    numel = int(np.prod(aval.shape)) if aval.shape else 1
                    if numel >= self.en_threshold:
                        self._emit(
                            "JXP-EDGE-NODE-MATERIALIZE", prim,
                            f"{prim!r} materializes "
                            f"{tuple(aval.shape)} = {numel} elements "
                            f">= [E, N]-class threshold "
                            f"{self.en_threshold}; edge×vertex "
                            f"intermediates defeat the push "
                            f"formulation")

            if prim == "convert_element_type":
                src = _aval_of(eqn.invars[0])
                dst = _aval_of(eqn.outvars[0])
                if src is not None and dst is not None:
                    src_b = np.dtype(src.dtype).itemsize
                    dst_b = np.dtype(dst.dtype).itemsize
                    if (dst_b > src_b
                            and dst_b > _MAX_WIDEN_TARGET_BYTES):
                        self._emit(
                            "JXP-WIDEN64", prim,
                            f"convert_element_type widens {src.dtype} → "
                            f"{dst.dtype} ({src_b}→{dst_b} B/elem); only "
                            f"accumulate-up widening into ≤"
                            f"{_MAX_WIDEN_TARGET_BYTES}-byte dtypes is "
                            f"allowlisted (bf16→f32)")

            if prim in _SCATTER_REDUCE_PRIMS:
                if not eqn.params.get("indices_are_sorted", False):
                    upd = _aval_of(eqn.invars[2]) if len(
                        eqn.invars) > 2 else None
                    upd_shape = getattr(upd, "shape", None)
                    upd_n = (int(np.prod(upd_shape))
                             if upd_shape is not None else None)
                    if (self.edge_threshold is None or upd_n is None
                            or upd_n >= self.edge_threshold):
                        self._emit(
                            "JXP-UNSORTED-SCATTER", prim,
                            f"{prim!r} with indices_are_sorted=False "
                            f"over {upd_n} update rows (edge-scale "
                            f"threshold {self.edge_threshold}) — an "
                            f"unsorted scatter-reduce (O(E) random HBM "
                            f"writes); hot sweeps must push through "
                            f"destination-sorted cached layouts "
                            f"(indices_are_sorted=True segmented "
                            f"reduce)")

            if prim in _CALLBACK_PRIMS or "callback" in prim:
                self._emit(
                    "JXP-CALLBACK", prim,
                    f"{prim!r} inside a jitted sweep — a host round-trip "
                    f"per execution; hot programs must stay on-device "
                    f"end to end")

            inner_pallas = in_pallas or prim == "pallas_call"
            for _, sub in _iter_subjaxprs(eqn.params):
                self.walk(sub, in_pallas=inner_pallas)


def lint_jaxpr(closed: jax_core.ClosedJaxpr, *, program: str,
               en_threshold: Optional[int] = None,
               edge_threshold: Optional[int] = None,
               check_f64: bool = True) -> List[Finding]:
    """Lint one traced program.

    ``en_threshold`` (elements) arms the ``[E, N]``-materialization rule —
    pass ``spec.en_threshold`` from the program catalog so the bound is
    derived from the graph spec the program was traced at.
    ``edge_threshold`` (update rows, ``spec.edge_capacity // 2`` from the
    catalog) scopes the unsorted-scatter rule to edge-scale scatters;
    ``None`` flags every unsorted scatter-reduce regardless of size.
    ``check_f64`` exists for fabricated-violation tests that trace under
    x64.
    """
    linter = _JaxprLinter(program, en_threshold=en_threshold,
                          edge_threshold=edge_threshold,
                          check_f64=check_f64)
    linter.walk(closed.jaxpr)
    # surface multiplicity in the (single) finding per aggregate key
    out = []
    for f in linter.findings:
        n = linter._seen_keys[f.key]
        if n > 1:
            f = Finding(f.pass_id, f.rule, f.where,
                        f"{f.detail} [{n} occurrences]")
        out.append(f)
    return out


def lint_programs(programs, *, interpret: bool = True) -> List[Finding]:
    """Trace + lint every program in a catalog (see
    :func:`repro.analysis.programs.catalog`)."""
    findings: List[Finding] = []
    for prog in programs:
        findings.extend(lint_jaxpr(
            prog.trace(), program=prog.name,
            en_threshold=prog.spec.en_threshold,
            edge_threshold=prog.spec.edge_threshold))
    return findings

"""Finding model + baseline/allowlist matching for the analysis passes.

Every pass (:mod:`~repro.analysis.jaxpr_lint`, :mod:`~repro.analysis.
hlo_audit`, :mod:`~repro.analysis.retrace`, :mod:`~repro.analysis.ast_lint`)
emits :class:`Finding` rows; callers compare them against the committed
baseline (``benchmarks/analysis_baseline.json``) with :func:`check`:

- a finding whose ``key`` matches a baseline entry is *allowlisted* — a
  known, annotated violation (every entry carries a human ``reason``);
- anything else is *new* and fails the run;
- baseline entries that no longer match any finding are *stale* — the
  violation was fixed, so the entry should be deleted (reported as a
  warning, not a failure, to keep the gate monotone under refactors).

Keys are ``"RULE::where"`` where ``where`` is a *stable* location: a
``program:primitive`` pair for traced-program rules, ``path:scope`` for
source rules — never a line number or an instruction index, so baselines
survive unrelated edits.
"""

from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class Finding:
    """One violation from one pass.

    ``pass_id``/``rule`` identify the check, ``where`` the stable location
    (see module docstring), ``detail`` the human diagnostic — the op, the
    measured value and the budget or contract it violated.
    """

    pass_id: str   # "jaxpr" | "hlo" | "retrace" | "ast"
    rule: str      # e.g. "JXP-F64", "HLO-ALLGATHER-BYTES"
    where: str     # stable location, e.g. "push_coo[plus_times]:scatter-add"
    detail: str    # actionable message (measured vs budget, contract text)

    @property
    def key(self) -> str:
        """The baseline-matching identity: ``RULE::where``."""
        return f"{self.rule}::{self.where}"

    def __str__(self) -> str:
        return f"[{self.pass_id}] {self.rule} at {self.where}: {self.detail}"

    def to_dict(self) -> dict:
        """JSON row for the findings report artifact."""
        return dataclasses.asdict(self)


@dataclasses.dataclass
class BaselineEntry:
    """One allowlisted violation: its key plus the reason it is accepted."""

    rule: str
    where: str
    reason: str

    @property
    def key(self) -> str:
        """Same identity space as :attr:`Finding.key`."""
        return f"{self.rule}::{self.where}"


def load_baseline(path: Optional[Path]) -> List[BaselineEntry]:
    """Parse ``benchmarks/analysis_baseline.json`` (``{"allow": [...]}``).

    A missing path (or ``None``) is an empty baseline — every finding is
    new.  Entries must carry non-empty ``reason`` strings: an allowlist
    without rationale is how one-off hacks calcify.
    """
    if path is None or not Path(path).exists():
        return []
    data = json.loads(Path(path).read_text(encoding="utf-8"))
    entries = []
    for row in data.get("allow", []):
        if not row.get("reason", "").strip():
            raise ValueError(
                f"baseline entry {row.get('rule')}::{row.get('where')} has "
                f"no reason string; annotate why this violation is accepted")
        entries.append(BaselineEntry(rule=row["rule"], where=row["where"],
                                     reason=row["reason"]))
    return entries


#: rule-id prefix → the pass that emits it (``JXP-F64`` → ``jaxpr``, …)
_RULE_PASS = {"JXP": "jaxpr", "HLO": "hlo", "RT": "retrace", "AST": "ast"}


def pass_of_rule(rule: str) -> Optional[str]:
    """The pass id a rule belongs to, derived from its prefix.

    Lets staleness be scoped to the passes that actually ran: a ``JXP-*``
    baseline entry can only be declared stale by a run that included the
    jaxpr pass.  Unknown prefixes map to ``None`` (never auto-stale).
    """
    return _RULE_PASS.get(rule.split("-", 1)[0])


def check(findings: Sequence[Finding],
          baseline: Sequence[BaselineEntry],
          *, passes_run: Optional[Iterable[str]] = None,
          ) -> Tuple[List[Finding], List[Finding], List[BaselineEntry]]:
    """Split findings against the baseline.

    Returns ``(new, allowlisted, stale)``: findings with no baseline
    entry (fail), findings matched by an entry (reported, accepted), and
    entries that matched nothing (the fix landed — delete the entry).
    With ``passes_run``, entries owned by a pass that did NOT run are
    never reported stale — ``--pass ast`` must not claim the jaxpr
    allowlist is obsolete.
    """
    allowed: Dict[str, BaselineEntry] = {e.key: e for e in baseline}
    new: List[Finding] = []
    matched: List[Finding] = []
    hit = set()
    for f in findings:
        if f.key in allowed:
            matched.append(f)
            hit.add(f.key)
        else:
            new.append(f)
    ran = None if passes_run is None else set(passes_run)
    stale = [e for e in baseline if e.key not in hit
             and (ran is None or pass_of_rule(e.rule) in ran)]
    return new, matched, stale


def render_report(findings: Sequence[Finding],
                  baseline: Sequence[BaselineEntry],
                  *, passes_run: Iterable[str]) -> dict:
    """The JSON findings report ``tools/analyze.py`` writes (CI artifact)."""
    passes_run = list(passes_run)
    new, matched, stale = check(findings, baseline, passes_run=passes_run)
    return {
        "passes": sorted(passes_run),
        "ok": not new,
        "new": [f.to_dict() for f in new],
        "allowlisted": [
            {**f.to_dict(), "reason": next(
                e.reason for e in baseline if e.key == f.key)}
            for f in matched
        ],
        "stale_baseline_entries": [
            {"rule": e.rule, "where": e.where, "reason": e.reason}
            for e in stale
        ],
    }

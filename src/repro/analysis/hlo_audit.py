"""Collective/memory audit over compiled HLO: per-op byte budgets.

The reusable form of the dry-run's hand-rolled all-gather gate (the check
that caught PR 5's 64 GiB/device replicated-edge all-gather): parse the
post-SPMD HLO with :mod:`repro.launch.hlo_cost` and enforce byte budgets
*derived from the graph spec* on the largest single instruction of each
collective kind (``Cost.coll_max`` — not trip-multiplied, so a loop can't
dilute or inflate the signal) plus the compiled program's peak temp:

- **HLO-ALLGATHER-BYTES** — every all-gather must stay below one edge
  buffer (``4·E_cap``): an all-gather that large means some stage
  replicated the sharded edge stream.
- **HLO-ALLTOALL-BYTES** — the summary bucket exchange is a
  capacity-padded all-to-all of hot blocks; an all-to-all past the padded
  exchange budget means E-space (not K-space) data crossed the mesh.
- **HLO-ALLREDUCE-BYTES** — rank-vector merges are node-space; budget
  optional (``None`` skips).
- **HLO-TEMP-BYTES** — ``memory_analysis().temp_size_in_bytes`` per
  device against the spec budget (the 9.0 → 2.3 GiB axis PR 5 tracked).

:func:`budgets_for_spec` derives a :class:`CollectiveBudgets` from a
program-catalog :class:`~repro.analysis.programs.GraphSpec`;
:func:`budgets_for_graph` is the dry-run's pod-scale variant (edge count
only, the original gate).  ``None`` disables an individual budget.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

from repro.analysis.findings import Finding
from repro.launch.hlo_cost import Cost, analyze_hlo

_RULE_BY_KIND = {
    "all-gather": "HLO-ALLGATHER-BYTES",
    "all-to-all": "HLO-ALLTOALL-BYTES",
    "all-reduce": "HLO-ALLREDUCE-BYTES",
    "reduce-scatter": "HLO-REDUCESCATTER-BYTES",
    "collective-permute": "HLO-PERMUTE-BYTES",
}


@dataclasses.dataclass(frozen=True)
class CollectiveBudgets:
    """Per-kind byte ceilings for the largest single collective
    instruction, plus an optional peak-temp budget.  ``None`` = unchecked.
    """

    all_gather_max: Optional[float] = None
    all_to_all_max: Optional[float] = None
    all_reduce_max: Optional[float] = None
    reduce_scatter_max: Optional[float] = None
    collective_permute_max: Optional[float] = None
    temp_bytes_max: Optional[float] = None

    def budget_for(self, kind: str) -> Optional[float]:
        """The ceiling for one collective kind (``None`` = unchecked)."""
        return {
            "all-gather": self.all_gather_max,
            "all-to-all": self.all_to_all_max,
            "all-reduce": self.all_reduce_max,
            "reduce-scatter": self.reduce_scatter_max,
            "collective-permute": self.collective_permute_max,
        }.get(kind)


def budgets_for_spec(spec) -> CollectiveBudgets:
    """Budgets derived from a program-catalog ``GraphSpec``.

    - all-gather: strictly under one endpoint buffer ``4·E_cap`` — the
      "never replicate the edge stream" bound;
    - all-to-all: the capacity-padded bucket exchange — per exchanged
      buffer ``4·S·⌈H_cap/S⌉`` bytes, with headroom for XLA fusing the
      (src, dst, w, order) streams into one tuple instruction (×8);
    - all-reduce: node-space merges only — a ``[B, N]`` f32 buffer with
      the same ×8 tuple/fusion headroom;
    - temp: ``128·4·E_cap`` per device — roomy for sort scratch
      (a handful of E-sized buffers), two orders under any ``[E, N]``
      materialization.
    """
    e_bytes = 4.0 * spec.edge_capacity
    pad_hot = spec.num_shards * (-(-spec.hot_edge_capacity
                                   // spec.num_shards))
    return CollectiveBudgets(
        all_gather_max=e_bytes,
        all_to_all_max=8.0 * 4.0 * pad_hot,
        all_reduce_max=8.0 * 4.0 * spec.node_capacity * max(
            spec.batch, 1),
        temp_bytes_max=128.0 * e_bytes,
    )


def budgets_for_graph(edge_capacity: int) -> CollectiveBudgets:
    """The dry-run's original pod-scale gate: all-gathers strictly under
    one ``4·E_cap`` edge buffer, everything else unbudgeted (pod-scale
    temp is reported, not gated — the roofline baseline pins it)."""
    return CollectiveBudgets(all_gather_max=4.0 * edge_capacity)


def audit_hlo_text(text: str, budgets: CollectiveBudgets, *,
                   program: str,
                   temp_bytes: Optional[float] = None,
                   ) -> List[Finding]:
    """Audit HLO module text against ``budgets``.

    ``temp_bytes`` (from ``compiled.memory_analysis()``) arms the peak-temp
    rule; text-only callers (tests, saved dumps) may omit it.
    Returns findings; the parsed :class:`Cost` is recomputable via
    :func:`repro.launch.hlo_cost.analyze_hlo` when callers need the
    roofline terms too.
    """
    cost = analyze_hlo(text)
    return audit_cost(cost, budgets, program=program, temp_bytes=temp_bytes)


def audit_cost(cost: Cost, budgets: CollectiveBudgets, *, program: str,
               temp_bytes: Optional[float] = None) -> List[Finding]:
    """Audit an already-parsed :class:`~repro.launch.hlo_cost.Cost`."""
    findings: List[Finding] = []
    for kind, largest in sorted(cost.coll_max.items()):
        budget = budgets.budget_for(kind)
        if budget is not None and largest >= budget:
            findings.append(Finding(
                pass_id="hlo", rule=_RULE_BY_KIND.get(
                    kind, f"HLO-{kind.upper()}-BYTES"),
                where=f"{program}:{kind}",
                detail=f"largest {kind} instruction moves {largest:.3e} B "
                       f">= budget {budget:.3e} B "
                       f"({cost.coll_counts.get(kind, 0):.0f} {kind} "
                       f"instruction(s) total) — an E-space buffer "
                       f"crossed the mesh; keep edge-space data sharded"))
    if (budgets.temp_bytes_max is not None and temp_bytes is not None
            and temp_bytes >= budgets.temp_bytes_max):
        findings.append(Finding(
            pass_id="hlo", rule="HLO-TEMP-BYTES",
            where=f"{program}:temp",
            detail=f"peak temp {temp_bytes:.3e} B/device >= budget "
                   f"{budgets.temp_bytes_max:.3e} B — the program "
                   f"materializes scratch far past the expected "
                   f"edge-buffer working set"))
    return findings


def audit_compiled(compiled, budgets: CollectiveBudgets, *,
                   program: str) -> List[Finding]:
    """Audit a ``jax`` compiled executable (``jit(...).lower().compile()``):
    HLO text budgets plus the peak-temp rule from ``memory_analysis()``."""
    temp = None
    try:
        temp = float(compiled.memory_analysis().temp_size_in_bytes)
    except Exception:  # backends without memory analysis (interpret stubs)
        temp = None
    return audit_hlo_text(compiled.as_text(), budgets, program=program,
                          temp_bytes=temp)

"""The hot-path program catalog the analysis passes trace and audit.

Each :class:`Program` names one jitted program the engine actually runs —
``push``/``push_coo`` over both backends (replicated, loop-sharded and
mesh-sharded), ``build_summary`` (replicated and mesh-native),
``fused_query_step`` / ``fused_query_step_batched`` (the serving engine's
wave step), and the streaming apply step (``add_edges``) — bound to small
concrete inputs from one :class:`GraphSpec`, so

- :func:`~repro.analysis.jaxpr_lint.lint_jaxpr` gets a traced jaxpr plus
  the spec-derived ``[E, N]`` threshold, and
- :func:`~repro.analysis.hlo_audit.audit_compiled` gets a compiled
  executable plus spec-derived collective byte budgets.

Shapes are deliberately modest (tracing is shape-generic: a rule that
holds at ``E=2¹⁴`` holds at ``E=2³⁰`` because the *structure* of the
program doesn't change with capacity), but big enough that the byte
budgets separate cleanly: one edge buffer ≫ the capacity-padded bucket
exchange ≫ a node vector.

Mesh-sharded programs need ≥ 2 devices — run under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (``tools/analyze.py
--all`` forces this itself); :func:`catalog` silently omits them on a
single device and ``tools/analyze.py`` reports the omission.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import hlo_audit
from repro.core import backend as B
from repro.core.algorithm import make_algorithm
from repro.core.control import default_probe_ids
from repro.core.fused import fused_query_step, fused_query_step_batched
from repro.core.pagerank import build_summary
from repro.graph import generators
from repro.core.epoch import snapshot_counts
from repro.graph.graph import (GraphState, add_edges, add_edges_preserving,
                               from_edges)
from repro.graph.partition import build_sharded_layout


@dataclasses.dataclass(frozen=True)
class GraphSpec:
    """The concrete shape every catalog program is traced at, and the
    source of the derived analysis bounds (``en_threshold``, byte
    budgets via :func:`repro.analysis.hlo_audit.budgets_for_spec`)."""

    node_capacity: int = 1024
    edge_capacity: int = 16384
    num_edges: int = 8192
    hot_node_capacity: int = 128
    hot_edge_capacity: int = 512
    batch: int = 4
    num_shards: int = 4
    apply_chunk: int = 64

    @property
    def en_threshold(self) -> int:
        """Elements at which an intermediate counts as ``[E, N]``-class
        (half the full product, to catch padded/halved variants while
        staying orders above any legitimate E- or B·N-sized buffer)."""
        return (self.edge_capacity * self.node_capacity) // 2

    @property
    def edge_threshold(self) -> int:
        """Update rows at which an unsorted scatter-reduce counts as
        *edge-scale* (half an edge buffer — catches full-E scatters
        while exempting apply-chunk degree bookkeeping and hot-set
        K-space compaction)."""
        return self.edge_capacity // 2


@dataclasses.dataclass
class Program:
    """One hot-path program bound to concrete inputs.

    ``fn`` is a positional-args callable (pytree args fine);
    :meth:`trace` returns its ClosedJaxpr, :meth:`compile` the compiled
    executable for the HLO audit.  ``budgets`` defaults to the
    spec-derived collective budgets.
    """

    name: str
    fn: Callable
    args: tuple
    spec: GraphSpec
    budgets: hlo_audit.CollectiveBudgets = None

    def __post_init__(self):
        if self.budgets is None:
            self.budgets = hlo_audit.budgets_for_spec(self.spec)

    def trace(self):
        """Trace to a ClosedJaxpr (no compile — shape-generic lint)."""
        return jax.make_jaxpr(self.fn)(*self.args)

    def compile(self):
        """Lower + compile (SPMD partitioning runs — the HLO audit's
        input)."""
        return jax.jit(self.fn).lower(*self.args).compile()


def build_graph(spec: GraphSpec, seed: int = 0) -> GraphState:
    """A concrete G(n, m) graph at the spec's capacities."""
    src, dst = generators.gnm_edges(
        spec.node_capacity, spec.num_edges, seed=seed)
    return from_edges(src, dst, spec.node_capacity, spec.edge_capacity)


def _query_args(spec: GraphSpec, state: GraphState, algo) -> tuple:
    algo_state = algo.init_state(state)
    return (state, algo_state, state.out_deg,
            state.node_active, jnp.float32(0.2), jnp.float32(0.05))


def catalog(spec: Optional[GraphSpec] = None, *,
            mesh=None) -> List[Program]:
    """Build the full program catalog at ``spec``.

    ``mesh`` (optional, needs ≥ 2 devices) adds the mesh-sharded
    variants: sharded push both backends, the distributed bucket-sort
    summary, and the sharded fused query — the programs whose collectives
    the HLO audit budgets exist for.
    """
    spec = spec or GraphSpec()
    state = build_graph(spec)
    progs: List[Program] = []

    ranks = jnp.where(state.node_active, 1.0, 0.0).astype(jnp.float32)
    values_b = jnp.tile(ranks[None, :], (spec.batch, 1))

    # --- push: the propagation primitive, both backends -------------------
    lay_pt = B.build_layout(state, weight="inv_out", semiring="plus_times")
    lay_mp = B.build_layout(state, weight="length", semiring="min_plus")
    for backend in ("segment_sum", "pallas"):
        progs.append(Program(
            f"push[{backend},plus_times]",
            functools.partial(B.push, semiring="plus_times",
                              backend=backend, interpret=True),
            (ranks, lay_pt), spec))
        progs.append(Program(
            f"push[{backend},min_plus]",
            functools.partial(B.push, semiring="min_plus",
                              backend=backend, interpret=True),
            (ranks, lay_mp), spec))
    progs.append(Program(
        "push_batched[pallas,plus_times]",
        functools.partial(B.push, semiring="plus_times",
                          backend="pallas", interpret=True),
        (values_b, lay_pt), spec))

    # --- push_coo: the unsorted fallback (allowlisted by definition) ------
    w = jnp.ones((spec.edge_capacity,), jnp.float32)
    progs.append(Program(
        "push_coo[plus_times]",
        lambda v, s, d, w: B.push_coo(
            v, s, d, spec.node_capacity, weight=w, semiring="plus_times"),
        (ranks, state.src, state.dst, w), spec))

    # --- sharded push: loop reference (meshless) + real mesh --------------
    sh_loop = build_sharded_layout(
        state, num_shards=spec.num_shards, weight="inv_out",
        semiring="plus_times")
    progs.append(Program(
        "push_sharded[segment_sum,loop]",
        functools.partial(B.push, semiring="plus_times",
                          backend="segment_sum", interpret=True),
        (ranks, sh_loop), spec))

    # --- summary construction + fused queries ------------------------------
    hot = state.node_active
    progs.append(Program(
        "build_summary",
        functools.partial(
            build_summary, hot_node_capacity=spec.hot_node_capacity,
            hot_edge_capacity=spec.hot_edge_capacity,
            backend="segment_sum"),
        (state, ranks, hot), spec))

    pagerank = make_algorithm("pagerank")
    sssp = make_algorithm("sssp", sources=(0,))
    for algo, label in ((pagerank, "pagerank"), (sssp, "sssp")):
        progs.append(Program(
            f"fused_query_step[{label}]",
            functools.partial(
                fused_query_step, algo=algo,
                hot_node_capacity=spec.hot_node_capacity,
                hot_edge_capacity=spec.hot_edge_capacity,
                backend="segment_sum"),
            _query_args(spec, state, algo), spec))

    # the closed-loop variant: drift estimator fused into the query step
    # (repro.core.control) — the controller programs must clear the same
    # gates (no host syncs; the drift scalars ride the stats transfer)
    probes = default_probe_ids(spec.node_capacity, 64)
    progs.append(Program(
        "fused_query_step[pagerank,drift]",
        functools.partial(
            fused_query_step, algo=pagerank,
            hot_node_capacity=spec.hot_node_capacity,
            hot_edge_capacity=spec.hot_edge_capacity,
            backend="segment_sum", with_drift=True),
        _query_args(spec, state, pagerank) + (probes,), spec))

    # the serving engine's wave step: batched bank + row mask + per-row
    # cold flags, exactly as GraphServingEngine.step drives it
    bank = jax.tree_util.tree_map(
        lambda x: jnp.tile(x[None, ...], (spec.batch,) + (1,) * x.ndim),
        pagerank.init_state(state))
    row_mask = jnp.ones((spec.batch,), bool)
    cold_rows = jnp.ones((spec.batch,), bool)
    st, _, deg, act, r, dd = _query_args(spec, state, pagerank)
    progs.append(Program(
        "serving_wave[pagerank,batched]",
        functools.partial(
            fused_query_step_batched, algo=pagerank,
            hot_node_capacity=spec.hot_node_capacity,
            hot_edge_capacity=spec.hot_edge_capacity,
            backend="segment_sum"),
        (st, bank, deg, act, r, dd, row_mask, cold_rows), spec))

    # closed-loop serving wave: per-slot drift rides the row_delta
    # transfer (with_drift=True returns the extra [B, 2] column)
    progs.append(Program(
        "serving_wave[pagerank,batched,drift]",
        functools.partial(
            fused_query_step_batched, algo=pagerank,
            hot_node_capacity=spec.hot_node_capacity,
            hot_edge_capacity=spec.hot_edge_capacity,
            backend="segment_sum", with_drift=True),
        (st, bank, deg, act, r, dd, row_mask, cold_rows, probes), spec))

    # seed-local cold start: PPR's teleport-support seeds drive the
    # reachability while_loop instead of full-active coverage — lints the
    # growth-conditioned frontier expansion
    ppr = make_algorithm("personalized-pagerank", seeds=(1, 5))
    ppr_bank = jax.tree_util.tree_map(
        lambda x: jnp.tile(x[None, ...], (spec.batch,) + (1,) * x.ndim),
        ppr.init_state(state))
    progs.append(Program(
        "serving_wave[ppr,seed-cold]",
        functools.partial(
            fused_query_step_batched, algo=ppr,
            hot_node_capacity=spec.hot_node_capacity,
            hot_edge_capacity=spec.hot_edge_capacity,
            backend="segment_sum"),
        (st, ppr_bank, deg, act, r, dd, row_mask, cold_rows), spec))

    # --- the streaming apply step ------------------------------------------
    new_src = jnp.zeros((spec.apply_chunk,), jnp.int32)
    new_dst = jnp.ones((spec.apply_chunk,), jnp.int32)
    progs.append(Program(
        "engine_apply[add_edges]",
        lambda st, s, d: add_edges(st, s, d),
        (state, new_src, new_dst), spec))

    # the async pipeline's variants: the non-donating apply (served
    # snapshot buffers must survive the mutation) and the per-epoch
    # count vector dispatched at build / fetched at promotion — both
    # must clear the same jaxpr/HLO gates as the donating path
    progs.append(Program(
        "engine_apply[add_edges,preserving]",
        lambda st, s, d: add_edges_preserving(st, s, d),
        (state, new_src, new_dst), spec))
    progs.append(Program(
        "epoch[snapshot_counts]",
        lambda st: snapshot_counts(st),
        (state,), spec))

    # --- mesh-sharded variants ---------------------------------------------
    if mesh is not None:
        sh_mesh = build_sharded_layout(
            state, mesh=mesh, weight="inv_out", semiring="plus_times")
        for backend in ("segment_sum", "pallas"):
            progs.append(Program(
                f"push_sharded[{backend},mesh]",
                functools.partial(B.push, semiring="plus_times",
                                  backend=backend, interpret=True),
                (ranks, sh_mesh), spec))
        progs.append(Program(
            "build_summary[sharded]",
            functools.partial(
                build_summary, hot_node_capacity=spec.hot_node_capacity,
                hot_edge_capacity=spec.hot_edge_capacity,
                layout=sh_mesh, backend="segment_sum"),
            (state, ranks, hot), spec))
        progs.append(Program(
            "fused_query_step[pagerank,sharded]",
            functools.partial(
                fused_query_step, algo=pagerank,
                hot_node_capacity=spec.hot_node_capacity,
                hot_edge_capacity=spec.hot_edge_capacity,
                backend="segment_sum", mesh=mesh),
            _query_args(spec, state, pagerank), spec))
    return progs


def default_mesh():
    """An all-device 1-axis mesh for the sharded catalog entries, or
    ``None`` on a single device."""
    devs = jax.devices()
    if len(devs) < 2:
        return None
    from jax.sharding import Mesh
    return Mesh(np.asarray(devs), ("shards",))


def run_retrace_scenario(spec: Optional[GraphSpec] = None) -> List:
    """The retrace pass's canned engine loop: one session, repeated
    same-shape update batches and queries.  Round 1 warms every program
    cache (session setup, the first exact compute, the first streaming
    step — all legitimate traces, including the eager op wrappers the
    host orchestration dispatches); rounds 2–3 replay identical
    (shape, algorithm, geometry) work and must add **zero** traces.
    Returns RT-RETRACE findings for anything that traced after warm-up.
    """
    from repro.analysis.retrace import TraceMonitor
    from repro.api import session

    spec = spec or GraphSpec()
    rng = np.random.default_rng(0)
    n = min(spec.node_capacity, 256)
    src, dst = generators.gnm_edges(n, 512, seed=1)
    chunk = 32

    def round_(s):
        s.add_edges(rng.integers(0, n, chunk).astype(np.int32),
                    rng.integers(0, n, chunk).astype(np.int32))
        s.query()

    with TraceMonitor() as mon:
        with session((src, dst), algorithm="pagerank",
                     node_capacity=n, edge_capacity=2048) as s:
            round_(s)
            warm = mon.snapshot()
            for _ in range(2):
                round_(s)
    return mon.check_warm(warm, scenario="engine-loop[pagerank]")


def run_async_retrace_scenario(spec: Optional[GraphSpec] = None) -> List:
    """The async pipeline's retrace pass: one ``async_rebuild=True``
    session, same-shape update batches and queries.  Round 1 warms every
    program (the fused step on the served snapshot, the *preserving*
    apply, ``snapshot_counts``, the layout builds dispatched per epoch);
    rounds 2–3 each flip an epoch — promote, serve, integrate, dispatch —
    and must add **zero** traces, proving the epoch machinery reuses the
    sync engine's compiled programs (the fused step's trace is
    epoch-agnostic: snapshots only rebind the same-shape inputs).
    """
    from repro.analysis.retrace import TraceMonitor
    from repro.api import session

    spec = spec or GraphSpec()
    rng = np.random.default_rng(0)
    n = min(spec.node_capacity, 256)
    src, dst = generators.gnm_edges(n, 512, seed=1)
    chunk = 32

    def round_(s):
        s.add_edges(rng.integers(0, n, chunk).astype(np.int32),
                    rng.integers(0, n, chunk).astype(np.int32))
        s.query()

    with TraceMonitor() as mon:
        with session((src, dst), algorithm="pagerank", async_rebuild=True,
                     node_capacity=n, edge_capacity=2048) as s:
            round_(s)   # epoch 0 served, epoch 1 dispatched
            round_(s)   # first full flip: promote 1, dispatch 2
            warm = mon.snapshot()
            for _ in range(2):
                round_(s)   # two more epoch flips, zero new traces
            assert s.engine._pipeline.current.epoch >= 3
    return mon.check_warm(warm, scenario="engine-loop[pagerank,async]")

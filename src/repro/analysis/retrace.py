"""Retrace detector: jit cache misses per function, asserted per loop.

A jitted engine loop must trace each program **once** per
(shape, algorithm, geometry) — weak_type drift (a python float where an
f32 scalar was traced), an unhashable or freshly-constructed static
argument, or a geometry knob changing per call all silently retrace every
iteration, which shows up only as mysterious slowness.

:class:`TraceMonitor` instruments tracing globally while active: it
enables ``jax_log_compiles`` and captures the dispatch layer's
"Finished tracing + transforming <name> for pjit" records with a private
logging handler, counting traces per function name.  (The
``jax.monitoring`` duration events fire for the same spans but do not
carry the function name; the log line is the only place jax reports *what*
retraced, and its format is pinned by jax's own compile-logging tests.)

Two ways to assert:

- **Warm-loop contract** (preferred, what the canned scenario uses):
  run one warm-up iteration, :meth:`TraceMonitor.snapshot`, run more
  identical iterations, then :meth:`TraceMonitor.check_warm` — a warm
  loop must add **zero** traces, so every function that traced again is
  a finding.  This is noise-free: eager op dispatch outside jit (the
  engine's host orchestration) traces tiny ``add``/``_where`` wrappers
  once per distinct shape during warm-up, which is normal and cached
  thereafter.
- **Budget contract**: :meth:`TraceMonitor.check` against explicit
  per-function trace budgets, for tests that fabricate a
  retrace-per-iteration loop and want the count in the diagnostic.

Usage::

    with TraceMonitor() as mon:
        engine.add_edges(*warmup); engine.query()   # warm-up traces
        warm = mon.snapshot()
        for batch in stream:
            engine.add_edges(*batch)
            engine.query()
    findings = mon.check_warm(warm)
"""

from __future__ import annotations

import collections
import logging
import re
from typing import Dict, List, Mapping, Optional

import jax

from repro.analysis.findings import Finding

_TRACE_RE = re.compile(
    r"Finished tracing \+ transforming (\S+) for pjit")


class _CaptureHandler(logging.Handler):
    def __init__(self, counter: collections.Counter):
        super().__init__(level=logging.DEBUG)
        self._counter = counter

    def emit(self, record: logging.LogRecord) -> None:
        try:
            m = _TRACE_RE.search(record.getMessage())
        except Exception:  # pragma: no cover - malformed record
            return
        if m:
            self._counter[m.group(1)] += 1


class TraceMonitor:
    """Context manager counting jit traces per function name.

    ``traces`` is a ``Counter`` of function name → trace count over the
    monitored region; :meth:`check` turns it into findings against a
    per-function budget.  Reentrant-safe for sequential use; do not nest.
    """

    #: the logger jax's trace/compile timing spans report through
    _LOGGER = "jax._src.dispatch"
    #: loggers that also turn chatty under jax_log_compiles — muted (not
    #: captured) while the monitor is active
    _MUTE = ("jax._src.interpreters.pxla",)

    def __init__(self) -> None:
        self.traces: collections.Counter = collections.Counter()
        self._handler: Optional[_CaptureHandler] = None
        self._null: Optional[logging.Handler] = None
        self._prev_log_compiles: Optional[bool] = None
        self._prev_propagate: Dict[str, bool] = {}

    def __enter__(self) -> "TraceMonitor":
        self._prev_log_compiles = bool(
            getattr(jax.config, "jax_log_compiles", False))
        jax.config.update("jax_log_compiles", True)
        self._handler = _CaptureHandler(self.traces)
        self._null = logging.NullHandler()
        logging.getLogger(self._LOGGER).addHandler(self._handler)
        # capture handlers are attached directly, so stop the per-trace
        # WARNING records from also spamming the console: no propagation
        # to the root handler, and a NullHandler so logging.lastResort
        # (the handler-less stderr fallback) never kicks in either
        for name in (self._LOGGER,) + self._MUTE:
            lg = logging.getLogger(name)
            self._prev_propagate[name] = lg.propagate
            lg.propagate = False
            lg.addHandler(self._null)
        return self

    def __exit__(self, *exc) -> None:
        logging.getLogger(self._LOGGER).removeHandler(self._handler)
        for name, prev in self._prev_propagate.items():
            lg = logging.getLogger(name)
            lg.propagate = prev
            lg.removeHandler(self._null)
        self._prev_propagate = {}
        self._handler = None
        self._null = None
        jax.config.update("jax_log_compiles", self._prev_log_compiles)

    def snapshot(self) -> collections.Counter:
        """A copy of the per-function trace counts so far — take one
        after the warm-up iteration, diff with :meth:`check_warm`."""
        return collections.Counter(self.traces)

    def check_warm(self, warm: Mapping[str, int], *,
                   scenario: str = "engine-loop") -> List[Finding]:
        """Findings for every function that traced *after* the warm-up
        snapshot.  A warm engine loop replays cached executables; any
        post-warm-up trace means a static argument, weak_type or
        geometry knob changes per call.
        """
        findings: List[Finding] = []
        for name, count in sorted(self.traces.items()):
            extra = count - warm.get(name, 0)
            if extra > 0:
                findings.append(Finding(
                    pass_id="retrace", rule="RT-RETRACE",
                    where=f"{scenario}:{name}",
                    detail=f"{name!r} traced {extra}× after the warm-up "
                           f"iteration ({count} total) — the loop "
                           f"re-traces on identical (shape, algorithm, "
                           f"geometry) input; a static argument, "
                           f"weak_type or geometry knob is changing per "
                           f"call, and every extra trace is a full "
                           f"compile on the hot path"))
        return findings

    def check(self, max_traces: Mapping[str, int] | None = None, *,
              default_max: int = 1,
              scenario: str = "engine-loop") -> List[Finding]:
        """Findings for every function that traced more than its budget.

        ``max_traces`` maps function name → allowed traces (e.g. an engine
        loop legitimately traces ``fused_query_step`` once per algorithm);
        unnamed functions get ``default_max``.  ``scenario`` keys the
        finding (stable ``where`` = ``scenario:function``).
        """
        budgets: Dict[str, int] = dict(max_traces or {})
        findings: List[Finding] = []
        for name, count in sorted(self.traces.items()):
            allowed = budgets.get(name, default_max)
            if count > allowed:
                findings.append(Finding(
                    pass_id="retrace", rule="RT-RETRACE",
                    where=f"{scenario}:{name}",
                    detail=f"{name!r} traced {count}× (budget {allowed}) "
                           f"over the monitored loop — a static argument, "
                           f"weak_type or geometry knob is changing per "
                           f"call; every extra trace is a full "
                           f"compile on the hot path"))
        return findings

"""Pallas TPU kernel: blocked online-softmax attention (forward).

Grid: (batch·kv_heads, q_tiles, kv_tiles) with the kv axis sequential
("arbitrary") so the (acc, m, l) running state lives in VMEM scratch across
kv steps — the canonical TPU flash-attention layout.  GQA is handled by
giving each kv head its whole query group (G, q_block, hd) per tile, so the
MXU sees (G·q_block × hd) @ (hd × kv_block) products with 128-aligned dims.

Block shapes are BlockSpec'd so per-step VMEM is:
  q tile (G·qb × hd) + k/v tiles (kvb × hd) + scores (G·qb × kvb) f32
  ≈ (8·128×128 + 2·512×128 + 8·128×512)·4B ≈ 3.1 MB   « 16 MB VMEM.
Causal/sliding-window masking is applied from tile coordinates; tiles are
not skipped (correct but redundant for causal — tile skipping is a
documented §Perf follow-up, the interpret-mode container cannot measure it).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across 0.4.x/0.5.x
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, out_ref, acc_ref, m_ref, l_ref, *,
                  q_block: int, kv_block: int, groups: int, scale: float,
                  causal: bool, window, kv_len: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32) * scale        # (G*qb, hd)
    k = k_ref[0].astype(jnp.float32)                # (kvb, hd)
    v = v_ref[0].astype(jnp.float32)                # (kvb, vd)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (G*qb, kvb)

    # row index within the fused (G, qb) dim maps to qb position
    row = jax.lax.broadcasted_iota(jnp.int32, (groups * q_block, kv_block), 0)
    q_pos = qi * q_block + row % q_block
    k_pos = ki * kv_block + jax.lax.broadcasted_iota(
        jnp.int32, (groups * q_block, kv_block), 1)
    mask = k_pos < kv_len
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jnp.dot(p, v)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ki == nk - 1)
    def _finish():
        l = l_ref[...]
        out = jnp.where(l > 0, acc_ref[...] / jnp.maximum(l, 1e-30), 0.0)
        out_ref[0] = out.astype(out_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "q_block", "kv_block", "interpret"),
)
def flash_attention(
    q: jax.Array,          # (B, Sq, H, hd)
    k: jax.Array,          # (B, Skv, KV, hd)
    v: jax.Array,          # (B, Skv, KV, vd)
    *,
    causal: bool = True,
    window=None,
    q_block: int = 128,
    kv_block: int = 512,
    interpret: bool = True,
) -> jax.Array:
    b, sq, h, hd = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    vd = v.shape[-1]
    assert h % kvh == 0
    groups = h // kvh
    scale = hd ** -0.5

    q_block = min(q_block, sq)
    kv_block = min(kv_block, skv)
    sq_p = ((sq + q_block - 1) // q_block) * q_block
    skv_p = ((skv + kv_block - 1) // kv_block) * kv_block
    if sq_p != sq:
        q = jnp.pad(q, ((0, 0), (0, sq_p - sq), (0, 0), (0, 0)))
    if skv_p != skv:
        k = jnp.pad(k, ((0, 0), (0, skv_p - skv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, skv_p - skv), (0, 0), (0, 0)))

    nq, nk = sq_p // q_block, skv_p // kv_block
    # layout: (B·KV, nq, G·q_block, hd) queries; (B·KV, nk, kv_block, hd) keys
    qg = q.reshape(b, sq_p, kvh, groups, hd).transpose(0, 2, 3, 1, 4)
    qg = qg.reshape(b * kvh, groups, nq, q_block, hd).transpose(0, 2, 1, 3, 4)
    qg = qg.reshape(b * kvh, nq, groups * q_block, hd)
    kg = k.transpose(0, 2, 1, 3).reshape(b * kvh, skv_p, hd)
    vg = v.transpose(0, 2, 1, 3).reshape(b * kvh, skv_p, vd)

    kernel = functools.partial(
        _flash_kernel, q_block=q_block, kv_block=kv_block, groups=groups,
        scale=scale, causal=causal, window=window, kv_len=skv)
    out = pl.pallas_call(
        kernel,
        grid=(b * kvh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, None, groups * q_block, hd),
                         lambda g, i, j: (g, i, 0, 0)),
            pl.BlockSpec((1, kv_block, hd), lambda g, i, j: (g, j, 0)),
            pl.BlockSpec((1, kv_block, vd), lambda g, i, j: (g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, None, groups * q_block, vd),
                               lambda g, i, j: (g, i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * kvh, nq, groups * q_block, vd),
                                       q.dtype),
        scratch_shapes=[
            pltpu.VMEM((groups * q_block, vd), jnp.float32),
            pltpu.VMEM((groups * q_block, 1), jnp.float32),
            pltpu.VMEM((groups * q_block, 1), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qg, kg, vg)

    # (B·KV, nq, G·qb, vd) -> (B, Sq, H, vd)
    out = out.reshape(b, kvh, nq, groups, q_block, vd)
    out = out.transpose(0, 2, 4, 1, 3, 5).reshape(b, sq_p, h, vd)
    return out[:, :sq]

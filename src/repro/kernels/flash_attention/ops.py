"""jit'd wrapper selecting the Pallas flash kernel (TPU) or the jnp path."""

from __future__ import annotations

import jax

from repro.kernels.flash_attention.kernel import flash_attention


def flash_attention_op(q, k, v, *, causal=True, window=None, interpret=True):
    return flash_attention(q, k, v, causal=causal, window=window,
                           interpret=interpret)

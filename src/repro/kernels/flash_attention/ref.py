"""Pure-jnp oracle for the flash attention kernel: the blocked exact
online-softmax reference in models/layers (itself validated against a naive
full-softmax oracle in tests/test_models-era checks)."""
from repro.models.layers import _blocked_attention_ref


def flash_attention_ref(q, k, v, *, causal=True, window=None):
    hd = q.shape[-1]
    return _blocked_attention_ref(
        q, k, v, causal=causal, window=window, q_offset=0, kv_offset=0,
        kv_valid_len=None, q_block=128, kv_block=256,
        softmax_scale=hd ** -0.5)

"""jit'd wrapper for the decode-attention kernel (dtype/shape plumbing)."""

from __future__ import annotations

import jax

from repro.kernels.decode_attention.kernel import decode_attention_kernel


def decode_attention_op(q, k_cache, v_cache, cache_len, *, interpret=True):
    return decode_attention_kernel(q, k_cache, v_cache, cache_len,
                                   interpret=interpret)

"""Pallas TPU kernel: GQA decode attention over a long KV cache.

One new token per sequence attends to an S-deep cache (decode_32k /
long_500k shapes).  Grid: (batch·kv_heads, kv_tiles), kv axis sequential —
the (acc, m, l) state for the G grouped queries persists in VMEM scratch
while KV tiles stream HBM -> VMEM.  This is the flash-decoding layout; the
work per tile is a (G × hd) @ (hd × kvb) MXU product, so the kernel is
bandwidth-bound by the cache stream, exactly matching the roofline table's
memory-dominated decode rows.

Slots at index >= cache_len are masked (linear caches); ring caches
(sliding window) pass cache_len == cache size with every slot valid.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across 0.4.x/0.5.x
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, out_ref,
                   acc_ref, m_ref, l_ref, *, kv_block: int, scale: float):
    ki = pl.program_id(1)
    nk = pl.num_programs(1)
    cache_len = len_ref[0]

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32) * scale          # (G, hd)
    k = k_ref[0].astype(jnp.float32)                  # (kvb, hd)
    v = v_ref[0].astype(jnp.float32)                  # (kvb, vd)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))   # (G, kvb)
    k_pos = ki * kv_block + jax.lax.broadcasted_iota(
        jnp.int32, s.shape, 1)
    s = jnp.where(k_pos < cache_len, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jnp.dot(p, v)
    m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        l = l_ref[...]
        out = jnp.where(l > 0, acc_ref[...] / jnp.maximum(l, 1e-30), 0.0)
        out_ref[0] = out.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("kv_block", "interpret"))
def decode_attention_kernel(
    q: jax.Array,          # (B, 1, H, hd) — one new token
    k_cache: jax.Array,    # (B, S, KV, hd)
    v_cache: jax.Array,    # (B, S, KV, vd)
    cache_len: jax.Array,  # () int32 — valid slots
    *,
    kv_block: int = 512,
    interpret: bool = True,
) -> jax.Array:
    b, _, h, hd = q.shape
    s, kvh = k_cache.shape[1], k_cache.shape[2]
    vd = v_cache.shape[-1]
    groups = h // kvh
    scale = hd ** -0.5

    kv_block = min(kv_block, s)
    s_p = ((s + kv_block - 1) // kv_block) * kv_block
    if s_p != s:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, s_p - s), (0, 0), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, s_p - s), (0, 0), (0, 0)))
    nk = s_p // kv_block

    qg = q[:, 0].reshape(b, kvh, groups, hd).reshape(b * kvh, groups, hd)
    kg = k_cache.transpose(0, 2, 1, 3).reshape(b * kvh, s_p, hd)
    vg = v_cache.transpose(0, 2, 1, 3).reshape(b * kvh, s_p, vd)
    clen = jnp.minimum(jnp.asarray(cache_len, jnp.int32),
                       jnp.int32(s)).reshape(1)

    kernel = functools.partial(_decode_kernel, kv_block=kv_block, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=(b * kvh, nk),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),             # cache_len
            pl.BlockSpec((1, groups, hd), lambda g, j: (g, 0, 0)),
            pl.BlockSpec((1, kv_block, hd), lambda g, j: (g, j, 0)),
            pl.BlockSpec((1, kv_block, vd), lambda g, j: (g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, groups, vd), lambda g, j: (g, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * kvh, groups, vd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((groups, vd), jnp.float32),
            pltpu.VMEM((groups, 1), jnp.float32),
            pltpu.VMEM((groups, 1), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(clen, qg, kg, vg)

    return out.reshape(b, kvh, groups, vd).reshape(b, 1, h, vd)

"""Pure-jnp oracle for the decode attention kernel."""
from repro.models.layers import decode_attention as decode_attention_ref  # noqa: F401

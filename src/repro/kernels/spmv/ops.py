"""jit'd wrappers: padded-COO graph -> tiled kernel inputs -> one push.

Thin convenience wrappers over the unified propagation backend
(:mod:`repro.core.backend`): build (or accept) a destination-sorted edge
layout via :func:`repro.graph.csr.sort_by_dst` and run one push through the
Pallas kernels — the one-hot matmul for sum reductions, the masked-reduce
variant for min/max semirings.  ``interpret=True`` runs the kernel body in
Python on CPU (how this container validates it); on TPU the same call
compiles to a Mosaic kernel.

Callers issuing repeated pushes should build the layout once
(:func:`repro.core.backend.build_layout`, or the engine's cached
``edge_layouts``) and pass it in — re-sorting per push is the cost this
layout amortizes away.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax

from repro.core.backend import (AnyEdgeLayout, EdgeLayout, build_layout,
                                push)
from repro.graph.graph import GraphState
from repro.kernels.spmv.kernel import CHUNK, TILE_N  # noqa: F401  (re-export)


@functools.partial(
    jax.jit,
    static_argnames=("semiring", "weight", "interpret", "tile_n", "chunk"))
def semiring_push(state: GraphState, values: jax.Array, *,
                  semiring: str = "plus_times",
                  weight: str = "unit",
                  interpret: bool = True,
                  layout: Optional[EdgeLayout] = None,
                  tile_n: Optional[int] = None,
                  chunk: Optional[int] = None) -> jax.Array:
    """One kernel-backed push over any registered semiring:
    ``out[v] = ⊕_{(u,v)∈E} values[u] ⊗ weight(u, v)`` (e.g.
    ``semiring="min_plus", weight="length"`` is one Bellman-Ford
    relaxation step)."""
    if layout is None:
        layout = build_layout(state, weight=weight, semiring=semiring,
                              chunk=CHUNK if chunk is None else chunk,
                              tile_n=tile_n)
    return push(values, layout, semiring=semiring, backend="pallas",
                tile_n=tile_n, chunk=chunk, interpret=interpret)


def sharded_semiring_push(state: GraphState, values: jax.Array, *,
                          mesh=None,
                          axes: Optional[Tuple[str, ...]] = None,
                          num_shards: Optional[int] = None,
                          semiring: str = "plus_times",
                          weight: str = "unit",
                          backend: Optional[str] = "pallas",
                          interpret: Optional[bool] = True,
                          layout: Optional[AnyEdgeLayout] = None,
                          slots: Optional[jax.Array] = None,
                          tile_n: Optional[int] = None,
                          chunk: Optional[int] = None) -> jax.Array:
    """:func:`semiring_push` over a device mesh: builds (or accepts) a
    per-shard destination-sorted
    :class:`~repro.core.backend.ShardedEdgeLayout` and runs the
    shard_map-ed partial-push + semiring all-reduce.

    ``mesh=None`` with ``num_shards`` runs the same partition as an
    on-device loop (the reference semantics / bench path).  ``slots``
    optionally overrides the contiguous slot cut with an explicit (e.g.
    rebalanced) slot→shard assignment — see
    :func:`repro.graph.partition.balanced_shard_slots`.  Not jitted —
    layout construction happens per call; repeated pushes should build the
    layout once and pass it via ``layout=``.

    Returns the dense ``semiring.dtype[node_capacity]`` result vector.
    """
    if layout is None:
        from repro.graph.partition import build_sharded_layout
        layout = build_sharded_layout(
            state, mesh=mesh, axes=axes, num_shards=num_shards,
            weight=weight, semiring=semiring, chunk=chunk, slots=slots,
            tile_n=tile_n)
    return push(values, layout, semiring=semiring, backend=backend,
                tile_n=tile_n, chunk=chunk, interpret=interpret)


def pagerank_push(state: GraphState, ranks: jax.Array, *,
                  interpret: bool = True,
                  layout: Optional[EdgeLayout] = None,
                  tile_n: Optional[int] = None,
                  chunk: Optional[int] = None) -> jax.Array:
    """One power-iteration push: out[v] = Σ_{(u,v)∈E} ranks[u]/d_out(u) —
    the ``plus_times``/``inv_out`` specialization of
    :func:`semiring_push`."""
    return semiring_push(state, ranks, semiring="plus_times",
                         weight="inv_out", interpret=interpret,
                         layout=layout, tile_n=tile_n, chunk=chunk)

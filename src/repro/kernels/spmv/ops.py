"""jit'd wrapper: padded-COO graph -> tiled kernel inputs -> PageRank push.

Thin convenience wrapper over the unified propagation backend
(:mod:`repro.core.backend`): builds (or accepts) the destination-sorted
``inv_out`` edge layout via :func:`repro.graph.csr.sort_by_dst` and runs one
push through the Pallas kernel.  ``interpret=True`` runs the kernel body in
Python on CPU (how this container validates it); on TPU the same call
compiles to a Mosaic kernel.

Callers issuing repeated pushes should build the layout once
(:func:`repro.core.backend.build_layout`, or the engine's cached
``edge_layouts``) and pass it in — re-sorting per push is the cost this
layout amortizes away.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.core.backend import EdgeLayout, build_layout, push
from repro.graph.graph import GraphState
from repro.kernels.spmv.kernel import CHUNK, TILE_N  # noqa: F401  (re-export)


@functools.partial(
    jax.jit, static_argnames=("interpret", "tile_n", "chunk"))
def pagerank_push(state: GraphState, ranks: jax.Array, *,
                  interpret: bool = True,
                  layout: Optional[EdgeLayout] = None,
                  tile_n: int = TILE_N,
                  chunk: int = CHUNK) -> jax.Array:
    """One power-iteration push: out[v] = Σ_{(u,v)∈E} ranks[u]/d_out(u)."""
    if layout is None:
        layout = build_layout(state, weight="inv_out", chunk=chunk)
    return push(ranks, layout, backend="pallas", tile_n=tile_n, chunk=chunk,
                interpret=interpret)

"""jit'd wrapper: padded-COO graph -> tiled kernel inputs -> PageRank push.

Bridges the VeilGraph GraphState to the Pallas kernel: sorts edges by
destination, derives per-output-tile edge ranges, gathers per-edge
contributions with XLA, and calls the kernel.  ``interpret=True`` runs the
kernel body in Python on CPU (how this container validates it); on TPU the
same call compiles to a Mosaic kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.graph.graph import GraphState, inv_out_degree
from repro.kernels.spmv.kernel import CHUNK, TILE_N, spmv_push


@functools.partial(jax.jit, static_argnames=("interpret",))
def pagerank_push(state: GraphState, ranks: jax.Array, *,
                  interpret: bool = True) -> jax.Array:
    """One power-iteration push: out[v] = Σ_{(u,v)∈E} ranks[u]/d_out(u)."""
    n_cap = state.node_capacity
    num_tiles = (n_cap + TILE_N - 1) // TILE_N
    mask = state.edge_mask()

    # sort edges by destination (invalid edges -> sentinel, sorted last)
    key = jnp.where(mask, state.dst, num_tiles * TILE_N)
    order = jnp.argsort(key)
    dst_s = key[order]
    src_s = state.src[order]
    valid_s = mask[order]

    emit = ranks * inv_out_degree(state)
    contrib = jnp.where(valid_s, emit[src_s], 0.0)

    # per-tile edge ranges over the sorted stream
    bounds = jnp.arange(num_tiles + 1, dtype=jnp.int32) * TILE_N
    tile_start = jnp.searchsorted(dst_s, bounds, side="left").astype(jnp.int32)

    out = spmv_push(contrib, dst_s.astype(jnp.int32), tile_start,
                    num_tiles=num_tiles, interpret=interpret)
    return out[:n_cap]

"""Per-shape geometry autotuner for the destination-tiled SpMV kernels.

The push kernels in :mod:`repro.kernels.spmv.kernel` are parameterised by a
``(tile_n, chunk)`` geometry: ``tile_n`` destination rows per grid step and
``chunk`` edges per streamed load.  The historical defaults (``TILE_N=256``,
``CHUNK=512``) are a reasonable middle of the road but are not optimal
everywhere — small summary layouts want small tiles (the per-tile
partial-chunk overshoot dominates), wide serving batches shrink the VMEM
room for ``chunk``, and the segmented-scan reduce variant pays ``log2
(chunk)`` scan steps per chunk that the sum variant does not.

This module replaces the hardcoded geometry with a small per-shape search:

``TuneKey``
    ``(e_pad, n, b, dtype, reduce, platform)`` — everything the kernel cost
    depends on.  ``e_pad`` is the default-chunk padded edge-stream length
    (a pure function of the edge capacity, so the key is stable across
    candidate chunks), ``reduce`` is the ⊕ kind (``sum``/``min``/``max``)
    and ``platform`` is ``jax.default_backend()`` — tunings are never
    shared across device kinds.

``modeled_push_cost``
    The analytic bytes/FLOPs/VMEM model for one push at a candidate
    geometry.  It is shared with :mod:`repro.launch.roofline` (the CI
    byte-volume gate asserts against the same numbers the tuner ranks by),
    and prunes the candidate grid *before any timing*: candidates whose
    modeled VMEM working set exceeds :data:`VMEM_LIMIT_BYTES` are never
    run.

``tune``
    Mode ``"off"`` returns the defaults; ``"cached"`` answers from the
    in-process cache / any loaded JSON cache, falling back to the analytic
    argmin (no timing — safe for CI); ``"full"`` times the top
    model-ranked candidates on synthetic streams and caches the winner.
    Results are cached in-process exactly like the engine's EdgeLayouts —
    one entry per key, hits skip all work — and the engine surfaces the
    number of measured tunings as ``engine.autotune_runs``.

``save_cache`` / ``load_cache``
    JSON persistence so benchmarks and CI reuse tunings instead of
    re-measuring (``benchmarks/autotune_cache.json`` is the committed
    cache; the benchmark smoke job replays it with ``--autotune cached``).
"""

from __future__ import annotations

import json
import math
import time
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

from repro.kernels.spmv.kernel import (CHUNK, TILE_N, spmv_push,
                                       spmv_push_batched, spmv_reduce_push,
                                       spmv_reduce_push_batched)

#: Lane-aligned tile widths (the VPU lane count is 128; the one-hot matmul
#: wants the output minor dim to be a multiple of it).
TILE_N_CANDIDATES: Tuple[int, ...] = (128, 256, 512)
#: Edge-stream chunk lengths (power-of-two so the segmented scan's
#: ``log2(chunk)`` step count is exact).
CHUNK_CANDIDATES: Tuple[int, ...] = (128, 256, 512, 1024)

#: VMEM working-set budget per grid step.  v5e cores have ~16 MiB of VMEM;
#: the budget leaves headroom for Mosaic's own spills and the output tile.
VMEM_LIMIT_BYTES = 10 * 1024 * 1024

# TPU v5e roofline constants (same values as repro.launch.mesh; duplicated
# here so the kernel package never imports launch at module scope).
PEAK_FLOPS = 197e12
HBM_BW = 819e9


@dataclass(frozen=True)
class TuneKey:
    """Everything the per-push kernel cost depends on."""

    e_pad: int          # default-chunk padded edge-stream length
    n: int              # destination-space size (num_segments)
    b: int              # batch rows pushed per call (1 = single query)
    dtype: str          # contribution dtype ("float32" / "int32")
    reduce: str         # ⊕ kind: "sum" | "min" | "max"
    platform: str       # jax.default_backend() at tune time

    def as_str(self) -> str:
        return (f"{self.e_pad}/{self.n}/{self.b}/{self.dtype}/"
                f"{self.reduce}/{self.platform}")

    @staticmethod
    def from_str(s: str) -> "TuneKey":
        e_pad, n, b, dtype, reduce, platform = s.split("/")
        return TuneKey(int(e_pad), int(n), int(b), dtype, reduce, platform)


@dataclass(frozen=True)
class PushCost:
    """Analytic cost of one push at a candidate geometry."""

    hbm_bytes: float    # edge streams (incl. partial-chunk waste) + output
    flops: float        # one-hot matmul + (reduce only) segmented scan
    vmem_bytes: float   # double-buffered slots + onehot + matmul operands

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def compute_s(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def bound_time_s(self) -> float:
        return max(self.memory_s, self.compute_s)


def modeled_push_cost(*, e_pad: int, n: int, b: int = 1, itemsize: int = 4,
                      reduce: str = "sum", tile_n: int = TILE_N,
                      chunk: int = CHUNK) -> PushCost:
    """Bytes / FLOPs / VMEM model for one push at ``(tile_n, chunk)``.

    HBM traffic: every edge is read once per stream — ``b`` contribution
    rows of ``itemsize`` plus 4-byte ``dst`` (and 4-byte ``rank`` for the
    reduce variant) — plus the per-tile partial-chunk overshoot (each tile
    rounds its edge range up to a chunk multiple: ≤ ``chunk`` wasted edges
    per tile), the tile-start table, and the output write.

    FLOPs: the one-hot matmul is ``rows × chunk × tile_n`` MACs per chunk
    (``rows`` = ``b`` for sum, ``2b+1`` for the reduce encoding), the
    segmented scan adds ``log2(chunk)`` compare/combine passes over the
    chunk, and the reduce encode/decode a few elementwise passes.

    VMEM: two buffered slots per input stream (the double-buffering
    scratch), the materialised one-hot, matmul operands/result and the
    accumulator.
    """
    num_tiles = -(-n // tile_n)
    waste = num_tiles * chunk           # partial-chunk overshoot bound
    edges = e_pad + waste
    per_edge = itemsize * b + 4 + (4 if reduce != "sum" else 0)
    hbm = (edges * per_edge + (num_tiles + 1) * 4
           + num_tiles * tile_n * itemsize * b)

    chunks = edges / chunk
    rows = b if reduce == "sum" else 2 * b + 1
    flops = chunks * 2.0 * rows * chunk * tile_n
    if reduce != "sum":
        nsteps = max(1, math.ceil(math.log2(chunk)))
        flops += chunks * b * chunk * (4.0 * nsteps + 8.0)

    slot = 2 * chunk * per_edge
    onehot = chunk * tile_n * 4
    rows_bytes = rows * (chunk + tile_n) * 4
    acc = 2 * tile_n * b * itemsize
    vmem = slot + onehot + rows_bytes + acc
    return PushCost(hbm_bytes=float(hbm), flops=float(flops),
                    vmem_bytes=float(vmem))


def candidates(key: TuneKey) -> List[Tuple[int, int]]:
    """VMEM-pruned, model-ranked candidate geometries for ``key``
    (cheapest modeled bound-time first).  Pruning is purely analytic —
    nothing is compiled or timed here."""
    itemsize = np.dtype(key.dtype).itemsize
    out = []
    for tile_n in TILE_N_CANDIDATES:
        for chunk in CHUNK_CANDIDATES:
            cost = modeled_push_cost(
                e_pad=key.e_pad, n=key.n, b=key.b, itemsize=itemsize,
                reduce=key.reduce, tile_n=tile_n, chunk=chunk)
            if cost.vmem_bytes > VMEM_LIMIT_BYTES:
                continue
            out.append((cost.bound_time_s, tile_n, chunk))
    out.sort()
    return [(t, c) for _, t, c in out]


# ---------------------------------------------------------------------------
# in-process cache + measured-run counter (engine-observable)

_CACHE: Dict[TuneKey, Tuple[int, int]] = {}
_RUNS = 0           # number of measured ("full") tunings this process
_HITS = 0           # cache answers (in-process or JSON-loaded)


def run_count() -> int:
    """Measured tuning runs so far in this process (cache hits excluded)."""
    return _RUNS


def cache_hits() -> int:
    return _HITS


def clear_cache() -> None:
    global _RUNS, _HITS
    _CACHE.clear()
    _RUNS = 0
    _HITS = 0


def cache_entries() -> Dict[str, Tuple[int, int]]:
    return {k.as_str(): v for k, v in _CACHE.items()}


def save_cache(path) -> None:
    """Persist the in-process cache as JSON (committed caches let CI and
    benchmarks replay tunings with ``--autotune cached``)."""
    p = Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    payload = {"version": 1,
               "entries": {k: list(v) for k, v in cache_entries().items()}}
    p.write_text(json.dumps(payload, indent=1, sort_keys=True) + "\n")


def load_cache(path) -> int:
    """Merge a JSON cache into the in-process cache; returns entries added."""
    p = Path(path)
    if not p.exists():
        return 0
    payload = json.loads(p.read_text())
    added = 0
    for ks, (tile_n, chunk) in payload.get("entries", {}).items():
        key = TuneKey.from_str(ks)
        if key not in _CACHE:
            added += 1
        _CACHE[key] = (int(tile_n), int(chunk))
    return added


# ---------------------------------------------------------------------------
# measurement

def _synthetic_args(key: TuneKey, chunk: int, tile_n: int):
    """Synthetic sorted edge streams shaped like ``key`` for timing."""
    import jax.numpy as jnp

    e = key.e_pad
    e_pad = (e // chunk + 2) * chunk
    n = key.n
    rng = np.random.default_rng(0)
    dst = np.sort(rng.integers(0, n, e)).astype(np.int32)
    dstp = np.full(e_pad, n, np.int32)
    dstp[:e] = dst
    row_offsets = np.searchsorted(dst, np.arange(n + 1)).astype(np.int32)
    rank = np.zeros(e_pad, np.int32)
    rank[:e] = np.arange(e) - row_offsets[dst]
    num_tiles = -(-n // tile_n)
    bounds = np.minimum(np.arange(num_tiles + 1) * tile_n, n)
    tile_start = row_offsets[bounds].astype(np.int32)
    if key.reduce == "sum":
        fill = 0.0
    else:
        info = (np.finfo if key.dtype.startswith("float") else np.iinfo)(
            np.dtype(key.dtype))
        fill = info.max if key.reduce == "min" else info.min
    shape = (e_pad,) if key.b == 1 else (key.b, e_pad)
    contrib = np.full(shape, fill, np.dtype(key.dtype))
    vals = rng.random(e).astype(np.float32) + 1.0
    contrib[..., :e] = vals if key.dtype.startswith("float") else (
        (vals * 1000).astype(np.dtype(key.dtype)))
    return (jnp.asarray(contrib), jnp.asarray(dstp), jnp.asarray(rank),
            jnp.asarray(tile_start), num_tiles)


def _time_candidate(key: TuneKey, tile_n: int, chunk: int, *,
                    interpret: bool, iters: int = 2) -> float:
    import jax

    contrib, dstp, rank, tile_start, num_tiles = _synthetic_args(
        key, chunk, tile_n)
    kw = dict(num_tiles=num_tiles, tile_n=tile_n, chunk=chunk,
              interpret=interpret)
    if key.reduce == "sum":
        fn = spmv_push if key.b == 1 else spmv_push_batched
        call = lambda: fn(contrib, dstp, tile_start, **kw)
    else:
        fn = spmv_reduce_push if key.b == 1 else spmv_reduce_push_batched
        call = lambda: fn(contrib, dstp, rank, tile_start, op=key.reduce,
                          **kw)
    jax.block_until_ready(call())            # compile / first interpret pass
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(call())
    return (time.perf_counter() - t0) / iters


def tune(key: TuneKey, mode: str = "cached", *,
         measure_top: int = 4) -> Tuple[int, int]:
    """Resolve the ``(tile_n, chunk)`` geometry for ``key``.

    ``"off"`` → the hardcoded defaults, no cache interaction.
    ``"cached"`` → in-process/JSON-loaded answer, else the analytic argmin
    of :func:`modeled_push_cost` over the pruned grid (no timing).
    ``"full"`` → time the ``measure_top`` best-modeled candidates on
    synthetic streams and cache the measured winner.
    """
    global _RUNS, _HITS
    if mode == "off":
        return (TILE_N, CHUNK)
    if mode not in ("cached", "full"):
        raise ValueError(f"unknown autotune mode {mode!r}; "
                         f"expected 'off', 'cached' or 'full'")
    hit = _CACHE.get(key)
    if hit is not None:
        _HITS += 1
        return hit
    cands = candidates(key)
    if not cands:
        return (TILE_N, CHUNK)
    if mode == "cached":
        # analytic argmin — deterministic and cheap, so it is NOT written
        # to the cache: the cache holds measured (or JSON-loaded) tunings
        # only, and a later "full" run must still get to time candidates
        return cands[0]
    import jax

    interpret = jax.default_backend() != "tpu"
    timed = [(_time_candidate(key, t, c, interpret=interpret), t, c)
             for t, c in cands[:measure_top]]
    timed.sort()
    best = (timed[0][1], timed[0][2])
    _RUNS += 1
    _CACHE[key] = best
    return best


def tune_for_push(*, edge_capacity: int, num_segments: int, batch: int = 1,
                  dtype: str = "float32", reduce: str = "sum",
                  mode: str = "cached",
                  measure_top: int = 4) -> Tuple[int, int]:
    """Front door used at layout-build time: build the key from engine
    capacities (``e_pad`` = the default-chunk padded stream length, so the
    key does not depend on the chunk being tuned) and resolve."""
    import jax

    e_pad = (edge_capacity // CHUNK + 2) * CHUNK
    key = TuneKey(e_pad=e_pad, n=num_segments, b=batch, dtype=dtype,
                  reduce=reduce, platform=jax.default_backend())
    return tune(key, mode, measure_top=measure_top)


__all__ = [
    "CHUNK_CANDIDATES", "PushCost", "TILE_N_CANDIDATES", "TuneKey",
    "VMEM_LIMIT_BYTES", "cache_entries", "cache_hits", "candidates",
    "clear_cache", "load_cache", "modeled_push_cost", "run_count",
    "save_cache", "tune", "tune_for_push",
]

"""Pure-jnp oracle for the SpMV push kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def spmv_push_ref(contrib: jax.Array, dst_sorted: jax.Array,
                  num_nodes: int) -> jax.Array:
    """out[v] = Σ contrib[e] over edges with dst_sorted[e] == v."""
    return jax.ops.segment_sum(contrib, dst_sorted, num_segments=num_nodes,
                               indices_are_sorted=True)

"""Pallas TPU kernel: destination-tiled SpMV push (the PageRank hot loop).

TPU adaptation of the paper's vertex-centric message push.  A GPU
implementation would scatter with atomics; TPUs have no scatter-atomics, so
the kernel is restructured around the MXU:

- edges are sorted by destination (csr.sort_by_dst, amortized over ~30
  power iterations per query);
- the destination space is tiled into TILE_N-wide output tiles; each grid
  step owns one tile and consumes only its edge range [tile_start[t],
  tile_start[t+1]);
- within a chunk of CHUNK edges, the scatter-add becomes a one-hot matmul:
  acc += onehot(dst_local)ᵀ @ contrib — an (CHUNK × TILE_N)ᵀ·(CHUNK,)
  product that runs on the MXU instead of a serial scatter (the classic
  TPU segment-sum-by-matmul trick);
- per-edge contributions (rank[src] / out_deg[src]) are gathered OUTSIDE
  the kernel by XLA (TPU gathers are efficient; VMEM-resident random
  gather inside the kernel is not), so the kernel input is a dense
  contribution stream — this is the hardware-adaptation note from
  DESIGN.md §2 in action.

VMEM budget per step: contrib chunk (CHUNK f32) + dst chunk (CHUNK i32) +
one-hot (CHUNK × TILE_N f32) + acc (TILE_N f32) ≈ 0.53 MB for
CHUNK=512, TILE_N=256 — far under the ~16 MB VMEM budget; TILE_N is
128-lane aligned.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

CHUNK = 512
TILE_N = 256


def _spmv_kernel(tile_start_ref, contrib_ref, dst_ref, out_ref):
    """One output tile: accumulate its sorted-edge range via one-hot matmuls."""
    t = pl.program_id(0)
    start = tile_start_ref[t]
    end = tile_start_ref[t + 1]
    base = t * TILE_N

    n_chunks = pl.cdiv(end - start, CHUNK)

    def body(i, acc):
        lo = start + i * CHUNK
        idx = lo + jnp.arange(CHUNK, dtype=jnp.int32)
        valid = idx < end
        # dynamic-start loads from the edge stream (HBM -> VMEM)
        c = pl.load(contrib_ref, (pl.ds(lo, CHUNK),))
        d = pl.load(dst_ref, (pl.ds(lo, CHUNK),))
        d_local = jnp.where(valid, d - base, TILE_N)      # OOB -> zero row
        onehot = (d_local[:, None] ==
                  jnp.arange(TILE_N, dtype=jnp.int32)[None, :])
        c = jnp.where(valid, c, 0.0)
        # MXU: scatter-add as a (1, CHUNK) @ (CHUNK, TILE_N) product
        return acc + jnp.dot(c[None, :], onehot.astype(jnp.float32))[0]

    acc0 = jnp.zeros((TILE_N,), jnp.float32)
    acc = jax.lax.fori_loop(0, n_chunks, body, acc0)
    out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("num_tiles", "interpret"))
def spmv_push(
    contrib: jax.Array,      # f32[E_pad] — rank[src]/deg[src], dst-sorted
    dst_sorted: jax.Array,   # i32[E_pad] — destination per edge (sorted)
    tile_start: jax.Array,   # i32[num_tiles + 1] — edge range per tile
    *,
    num_tiles: int,
    interpret: bool = False,
) -> jax.Array:
    """Returns f32[num_tiles * TILE_N] accumulated incoming contributions."""
    out = pl.pallas_call(
        _spmv_kernel,
        grid=(num_tiles,),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),   # tile_start (scalar-ish)
            pl.BlockSpec(memory_space=pl.ANY),   # contrib stream stays in HBM
            pl.BlockSpec(memory_space=pl.ANY),   # dst stream stays in HBM
        ],
        out_specs=pl.BlockSpec((TILE_N,), lambda t: (t,)),
        out_shape=jax.ShapeDtypeStruct((num_tiles * TILE_N,), jnp.float32),
        interpret=interpret,
    )(tile_start, contrib, dst_sorted)
    return out

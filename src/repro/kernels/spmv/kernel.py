"""Pallas TPU kernel: destination-tiled SpMV push (every sweep's hot loop).

TPU adaptation of the paper's vertex-centric message push.  A GPU
implementation would scatter with atomics; TPUs have no scatter-atomics, so
the kernel is restructured around the MXU:

- edges are sorted by destination (csr.sort_by_dst, amortized over ~30
  power iterations per query and — via the engine's layout cache — across
  every query between two applied update batches);
- the destination space is tiled into tile_n-wide output tiles; each grid
  step owns one tile and consumes only its edge range [tile_start[t],
  tile_start[t+1]);
- within a chunk of ``chunk`` edges, the scatter-add becomes a one-hot
  matmul: acc += onehot(dst_local)ᵀ @ contrib — a (chunk × tile_n)ᵀ·(chunk,)
  product that runs on the MXU instead of a serial scatter (the classic
  TPU segment-sum-by-matmul trick);
- per-edge contributions (e.g. rank[src] / out_deg[src]) are gathered
  OUTSIDE the kernel by XLA (TPU gathers are efficient; VMEM-resident random
  gather inside the kernel is not), so the kernel input is a dense
  contribution stream — the kernel is therefore algorithm-agnostic: PageRank
  weights, HITS unit weights and summarized E_K weights all arrive pre-baked
  in the stream.

Two kernel variants share that structure, selected by the reduction of the
semiring the sweep runs over (:mod:`repro.core.semiring`):

- :func:`spmv_push` — the ``sum``-reduce (``plus_times``) fast path: the
  scatter-add becomes a one-hot matmul on the MXU (f32 only);
- :func:`spmv_reduce_push` — the tiled *masked-reduce* variant for
  non-additive reductions (``min``/``max`` over f32 or i32): the same
  one-hot destination mask selects contributions into a
  (chunk × tile_n) tile initialized to the reduce identity, and a VPU
  min/max along the chunk axis replaces the matmul.  This is what makes
  SSSP's min-plus relaxation and connected components' label-min run as
  destination-tiled kernels rather than serial scatters.

``tile_n``/``chunk`` are parameters (module constants are only the
defaults): the summarized sweep runs in the compacted ``k_cap`` space whose
natural tile size differs from the full-graph sweep's.  VMEM budget per
step: contrib chunk (chunk f32) + dst chunk (chunk i32) + one-hot
(chunk × tile_n f32) + acc (tile_n f32) ≈ 0.53 MB for chunk=512,
tile_n=256 — far under the ~16 MB VMEM budget; tile_n should stay 128-lane
aligned.

Batched (multi-query) variants
------------------------------
:func:`spmv_push_batched` and :func:`spmv_reduce_push_batched` accept a
``[B, E_pad]`` contribution matrix — B independent value vectors pushed
through ONE shared edge stream (the serving engine's wave step).  The sum
variant's one-hot product becomes a true ``[B, chunk] @ [chunk, tile_n]``
MXU matmul, so the scatter's fixed cost (edge loads, one-hot build) is
amortized over all B queries — the cheapest throughput multiplier in the
backend.  The reduce variant shrinks its chunk if needed so the
``[B, chunk, tile_n]`` masked tile stays inside the VMEM budget; min/max
are reassociation-exact, so each batch row stays bitwise equal to the
single-query kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

CHUNK = 512
TILE_N = 256


def _make_spmv_kernel(tile_n: int, chunk: int):
    """Kernel body closure over the (static) tile/chunk geometry."""

    def _spmv_kernel(tile_start_ref, contrib_ref, dst_ref, out_ref):
        """One output tile: accumulate its sorted-edge range via one-hot
        matmuls."""
        t = pl.program_id(0)
        start = tile_start_ref[t]
        end = tile_start_ref[t + 1]
        base = t * tile_n

        n_chunks = pl.cdiv(end - start, chunk)

        def body(i, acc):
            lo = start + i * chunk
            idx = lo + jnp.arange(chunk, dtype=jnp.int32)
            valid = idx < end
            # dynamic-start loads from the edge stream (HBM -> VMEM); the
            # layout builder pads the stream by >= one chunk so these loads
            # never run past the buffer even when end is near capacity
            c = pl.load(contrib_ref, (pl.ds(lo, chunk),))
            d = pl.load(dst_ref, (pl.ds(lo, chunk),))
            d_local = jnp.where(valid, d - base, tile_n)      # OOB -> zero row
            onehot = (d_local[:, None] ==
                      jnp.arange(tile_n, dtype=jnp.int32)[None, :])
            c = jnp.where(valid, c, 0.0)
            # MXU: scatter-add as a (1, chunk) @ (chunk, tile_n) product
            return acc + jnp.dot(c[None, :], onehot.astype(jnp.float32))[0]

        acc0 = jnp.zeros((tile_n,), jnp.float32)
        acc = jax.lax.fori_loop(0, n_chunks, body, acc0)
        out_ref[...] = acc

    return _spmv_kernel


def _make_reduce_kernel(tile_n: int, chunk: int, op: str, identity):
    """Masked-reduce kernel body: ⊕ ∈ {min, max} instead of the matmul.

    The one-hot destination mask that the sum variant feeds to the MXU here
    selects contributions into a (chunk × tile_n) tile whose unselected
    lanes hold the reduce identity; a VPU reduce over the chunk axis folds
    the tile into the accumulator.  Works for any dtype with a total order
    (f32 and i32 in practice) — the MXU has no non-additive accumulate, so
    this is the TPU-native form of segment-min/max.
    """
    reduce_fn = jnp.min if op == "min" else jnp.max
    combine_fn = jnp.minimum if op == "min" else jnp.maximum

    def _reduce_kernel(tile_start_ref, contrib_ref, dst_ref, out_ref):
        t = pl.program_id(0)
        start = tile_start_ref[t]
        end = tile_start_ref[t + 1]
        base = t * tile_n

        n_chunks = pl.cdiv(end - start, chunk)

        def body(i, acc):
            lo = start + i * chunk
            idx = lo + jnp.arange(chunk, dtype=jnp.int32)
            valid = idx < end
            c = pl.load(contrib_ref, (pl.ds(lo, chunk),))
            d = pl.load(dst_ref, (pl.ds(lo, chunk),))
            d_local = jnp.where(valid, d - base, tile_n)  # OOB -> no column
            onehot = (d_local[:, None] ==
                      jnp.arange(tile_n, dtype=jnp.int32)[None, :])
            tile = jnp.where(onehot, c[:, None], identity)
            return combine_fn(acc, reduce_fn(tile, axis=0))

        acc0 = jnp.full((tile_n,), identity, contrib_ref.dtype)
        acc = jax.lax.fori_loop(0, n_chunks, body, acc0)
        out_ref[...] = acc

    return _reduce_kernel


@functools.partial(
    jax.jit, static_argnames=("num_tiles", "tile_n", "chunk", "interpret")
)
def spmv_push(
    contrib: jax.Array,      # f32[E_pad] — per-edge contribution, dst-sorted
    dst_sorted: jax.Array,   # i32[E_pad] — destination per edge (sorted)
    tile_start: jax.Array,   # i32[num_tiles + 1] — edge range per tile
    *,
    num_tiles: int,
    tile_n: int = TILE_N,
    chunk: int = CHUNK,
    interpret: bool = False,
) -> jax.Array:
    """Returns f32[num_tiles * tile_n] accumulated incoming contributions."""
    out = pl.pallas_call(
        _make_spmv_kernel(tile_n, chunk),
        grid=(num_tiles,),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),   # tile_start (scalar-ish)
            pl.BlockSpec(memory_space=pl.ANY),   # contrib stream stays in HBM
            pl.BlockSpec(memory_space=pl.ANY),   # dst stream stays in HBM
        ],
        out_specs=pl.BlockSpec((tile_n,), lambda t: (t,)),
        out_shape=jax.ShapeDtypeStruct((num_tiles * tile_n,), jnp.float32),
        interpret=interpret,
    )(tile_start, contrib, dst_sorted)
    return out


@functools.partial(
    jax.jit,
    static_argnames=("num_tiles", "tile_n", "chunk", "op", "interpret"),
)
def spmv_reduce_push(
    contrib: jax.Array,      # [E_pad] per-edge contribution, dst-sorted
    dst_sorted: jax.Array,   # i32[E_pad] destination per edge (sorted)
    tile_start: jax.Array,   # i32[num_tiles + 1] edge range per tile
    *,
    num_tiles: int,
    op: str,
    tile_n: int = TILE_N,
    chunk: int = CHUNK,
    interpret: bool = False,
) -> jax.Array:
    """Masked-reduce sibling of :func:`spmv_push` for ``op`` ∈ {min, max}.

    Returns ``contrib.dtype[num_tiles * tile_n]``; destinations with no
    in-range edge hold the reduce identity (+∞/−∞ or the int extrema) —
    the ⊕-zero of the semiring the caller runs, matching XLA's
    ``segment_min``/``segment_max`` empty-segment convention.
    """
    if op not in ("min", "max"):
        raise ValueError(f"op must be 'min' or 'max', got {op!r}")
    dtype = contrib.dtype
    if jnp.issubdtype(dtype, jnp.floating):
        identity = dtype.type(-jnp.inf if op == "max" else jnp.inf)
    else:
        info = jnp.iinfo(dtype)
        identity = dtype.type(info.min if op == "max" else info.max)
    out = pl.pallas_call(
        _make_reduce_kernel(tile_n, chunk, op, identity),
        grid=(num_tiles,),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((tile_n,), lambda t: (t,)),
        out_shape=jax.ShapeDtypeStruct((num_tiles * tile_n,), dtype),
        interpret=interpret,
    )(tile_start, contrib, dst_sorted)
    return out


def _make_spmv_batched_kernel(batch: int, tile_n: int, chunk: int):
    """Batched sum-kernel body: the one-hot product is a real MXU matmul.

    Identical tiling/chunking to :func:`_make_spmv_kernel`; the chunk load
    is ``[batch, chunk]`` and the accumulate is
    ``acc += contrib_chunk @ onehot`` — a ``[B, chunk] @ [chunk, tile_n]``
    product, so every query in the batch shares one edge-stream pass and
    one one-hot build per chunk.
    """

    def _spmv_batched_kernel(tile_start_ref, contrib_ref, dst_ref, out_ref):
        t = pl.program_id(0)
        start = tile_start_ref[t]
        end = tile_start_ref[t + 1]
        base = t * tile_n

        n_chunks = pl.cdiv(end - start, chunk)

        def body(i, acc):
            lo = start + i * chunk
            idx = lo + jnp.arange(chunk, dtype=jnp.int32)
            valid = idx < end
            c = pl.load(contrib_ref, (slice(None), pl.ds(lo, chunk)))
            d = pl.load(dst_ref, (pl.ds(lo, chunk),))
            d_local = jnp.where(valid, d - base, tile_n)      # OOB -> zero row
            onehot = (d_local[:, None] ==
                      jnp.arange(tile_n, dtype=jnp.int32)[None, :])
            c = jnp.where(valid[None, :], c, 0.0)
            return acc + jnp.dot(c, onehot.astype(jnp.float32),
                                 preferred_element_type=jnp.float32)

        acc0 = jnp.zeros((batch, tile_n), jnp.float32)
        acc = jax.lax.fori_loop(0, n_chunks, body, acc0)
        out_ref[...] = acc

    return _spmv_batched_kernel


def _make_reduce_batched_kernel(batch: int, tile_n: int, chunk: int,
                                op: str, identity):
    """Batched masked-reduce body: one ``[B, chunk, tile_n]`` masked tile
    folded along the chunk axis.  The one-hot destination mask is built
    once per chunk and broadcast over the batch; min/max are
    reassociation-exact, so each row matches the single-query kernel
    bitwise.  Callers bound ``batch * chunk * tile_n`` against VMEM
    (see :func:`spmv_reduce_push_batched`).
    """
    reduce_fn = jnp.min if op == "min" else jnp.max
    combine_fn = jnp.minimum if op == "min" else jnp.maximum

    def _reduce_batched_kernel(tile_start_ref, contrib_ref, dst_ref, out_ref):
        t = pl.program_id(0)
        start = tile_start_ref[t]
        end = tile_start_ref[t + 1]
        base = t * tile_n

        n_chunks = pl.cdiv(end - start, chunk)

        def body(i, acc):
            lo = start + i * chunk
            idx = lo + jnp.arange(chunk, dtype=jnp.int32)
            valid = idx < end
            c = pl.load(contrib_ref, (slice(None), pl.ds(lo, chunk)))
            d = pl.load(dst_ref, (pl.ds(lo, chunk),))
            d_local = jnp.where(valid, d - base, tile_n)  # OOB -> no column
            onehot = (d_local[:, None] ==
                      jnp.arange(tile_n, dtype=jnp.int32)[None, :])
            tile = jnp.where(onehot[None, :, :], c[:, :, None], identity)
            return combine_fn(acc, reduce_fn(tile, axis=1))

        acc0 = jnp.full((batch, tile_n), identity, contrib_ref.dtype)
        acc = jax.lax.fori_loop(0, n_chunks, body, acc0)
        out_ref[...] = acc

    return _reduce_batched_kernel


@functools.partial(
    jax.jit, static_argnames=("num_tiles", "tile_n", "chunk", "interpret")
)
def spmv_push_batched(
    contrib: jax.Array,      # f32[B, E_pad] — per-edge contribs, dst-sorted
    dst_sorted: jax.Array,   # i32[E_pad] — destination per edge (sorted)
    tile_start: jax.Array,   # i32[num_tiles + 1] — edge range per tile
    *,
    num_tiles: int,
    tile_n: int = TILE_N,
    chunk: int = CHUNK,
    interpret: bool = False,
) -> jax.Array:
    """Batched :func:`spmv_push`: B contribution streams through one shared
    sorted edge stream.  Returns ``f32[B, num_tiles * tile_n]``."""
    batch = contrib.shape[0]
    out = pl.pallas_call(
        _make_spmv_batched_kernel(batch, tile_n, chunk),
        grid=(num_tiles,),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),   # tile_start (scalar-ish)
            pl.BlockSpec(memory_space=pl.ANY),   # contrib matrix stays in HBM
            pl.BlockSpec(memory_space=pl.ANY),   # dst stream stays in HBM
        ],
        out_specs=pl.BlockSpec((batch, tile_n), lambda t: (0, t)),
        out_shape=jax.ShapeDtypeStruct((batch, num_tiles * tile_n),
                                       jnp.float32),
        interpret=interpret,
    )(tile_start, contrib, dst_sorted)
    return out


#: VMEM budget (bytes) the batched masked-reduce tile may occupy — chunk is
#: halved until B * chunk * tile_n * itemsize fits (min/max reduces are
#: order-exact, so a smaller chunk changes nothing numerically)
_REDUCE_TILE_VMEM_BYTES = 6 * 1024 * 1024


def batched_reduce_chunk(batch: int, tile_n: int, chunk: int,
                         itemsize: int = 4) -> int:
    """Largest chunk ≤ ``chunk`` whose ``[B, chunk, tile_n]`` masked tile
    fits the VMEM budget (never below 128).  Exposed so callers can reason
    about the effective chunk the batched reduce kernel will use."""
    while batch * chunk * tile_n * itemsize > _REDUCE_TILE_VMEM_BYTES \
            and chunk > 128:
        chunk //= 2
    return chunk


@functools.partial(
    jax.jit,
    static_argnames=("num_tiles", "tile_n", "chunk", "op", "interpret"),
)
def spmv_reduce_push_batched(
    contrib: jax.Array,      # [B, E_pad] per-edge contribs, dst-sorted
    dst_sorted: jax.Array,   # i32[E_pad] destination per edge (sorted)
    tile_start: jax.Array,   # i32[num_tiles + 1] edge range per tile
    *,
    num_tiles: int,
    op: str,
    tile_n: int = TILE_N,
    chunk: int = CHUNK,
    interpret: bool = False,
) -> jax.Array:
    """Batched :func:`spmv_reduce_push` for ``op`` ∈ {min, max}.

    Returns ``contrib.dtype[B, num_tiles * tile_n]``; each batch row is
    bitwise equal to the single-query kernel on the same stream (min/max
    are reassociation-exact).  The chunk shrinks automatically so the
    masked tile stays inside VMEM (smaller chunks load the same edges).
    """
    if op not in ("min", "max"):
        raise ValueError(f"op must be 'min' or 'max', got {op!r}")
    batch = contrib.shape[0]
    dtype = contrib.dtype
    if jnp.issubdtype(dtype, jnp.floating):
        identity = dtype.type(-jnp.inf if op == "max" else jnp.inf)
    else:
        info = jnp.iinfo(dtype)
        identity = dtype.type(info.min if op == "max" else info.max)
    chunk = batched_reduce_chunk(batch, tile_n, chunk, jnp.dtype(dtype).itemsize)
    out = pl.pallas_call(
        _make_reduce_batched_kernel(batch, tile_n, chunk, op, identity),
        grid=(num_tiles,),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((batch, tile_n), lambda t: (0, t)),
        out_shape=jax.ShapeDtypeStruct((batch, num_tiles * tile_n), dtype),
        interpret=interpret,
    )(tile_start, contrib, dst_sorted)
    return out

"""Pallas TPU kernel: destination-tiled SpMV push (every sweep's hot loop).

TPU adaptation of the paper's vertex-centric message push.  A GPU
implementation would scatter with atomics; TPUs have no scatter-atomics, so
the kernel is restructured around the MXU:

- edges are sorted by destination (csr.sort_by_dst, amortized over ~30
  power iterations per query and — via the engine's layout cache — across
  every query between two applied update batches);
- the destination space is tiled into tile_n-wide output tiles; each grid
  step owns one tile and consumes only its edge range [tile_start[t],
  tile_start[t+1]);
- within a chunk of ``chunk`` edges, the scatter-add becomes a one-hot
  matmul: acc += onehot(dst_local)ᵀ @ contrib — a (chunk × tile_n)ᵀ·(chunk,)
  product that runs on the MXU instead of a serial scatter (the classic
  TPU segment-sum-by-matmul trick);
- per-edge contributions (e.g. rank[src] / out_deg[src]) are gathered
  OUTSIDE the kernel by XLA (TPU gathers are efficient; VMEM-resident random
  gather inside the kernel is not), so the kernel input is a dense
  contribution stream — the kernel is therefore algorithm-agnostic: PageRank
  weights, HITS unit weights and summarized E_K weights all arrive pre-baked
  in the stream.

``tile_n``/``chunk`` are parameters (module constants are only the
defaults): the summarized sweep runs in the compacted ``k_cap`` space whose
natural tile size differs from the full-graph sweep's.  VMEM budget per
step: contrib chunk (chunk f32) + dst chunk (chunk i32) + one-hot
(chunk × tile_n f32) + acc (tile_n f32) ≈ 0.53 MB for chunk=512,
tile_n=256 — far under the ~16 MB VMEM budget; tile_n should stay 128-lane
aligned.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

CHUNK = 512
TILE_N = 256


def _make_spmv_kernel(tile_n: int, chunk: int):
    """Kernel body closure over the (static) tile/chunk geometry."""

    def _spmv_kernel(tile_start_ref, contrib_ref, dst_ref, out_ref):
        """One output tile: accumulate its sorted-edge range via one-hot
        matmuls."""
        t = pl.program_id(0)
        start = tile_start_ref[t]
        end = tile_start_ref[t + 1]
        base = t * tile_n

        n_chunks = pl.cdiv(end - start, chunk)

        def body(i, acc):
            lo = start + i * chunk
            idx = lo + jnp.arange(chunk, dtype=jnp.int32)
            valid = idx < end
            # dynamic-start loads from the edge stream (HBM -> VMEM); the
            # layout builder pads the stream by >= one chunk so these loads
            # never run past the buffer even when end is near capacity
            c = pl.load(contrib_ref, (pl.ds(lo, chunk),))
            d = pl.load(dst_ref, (pl.ds(lo, chunk),))
            d_local = jnp.where(valid, d - base, tile_n)      # OOB -> zero row
            onehot = (d_local[:, None] ==
                      jnp.arange(tile_n, dtype=jnp.int32)[None, :])
            c = jnp.where(valid, c, 0.0)
            # MXU: scatter-add as a (1, chunk) @ (chunk, tile_n) product
            return acc + jnp.dot(c[None, :], onehot.astype(jnp.float32))[0]

        acc0 = jnp.zeros((tile_n,), jnp.float32)
        acc = jax.lax.fori_loop(0, n_chunks, body, acc0)
        out_ref[...] = acc

    return _spmv_kernel


@functools.partial(
    jax.jit, static_argnames=("num_tiles", "tile_n", "chunk", "interpret")
)
def spmv_push(
    contrib: jax.Array,      # f32[E_pad] — per-edge contribution, dst-sorted
    dst_sorted: jax.Array,   # i32[E_pad] — destination per edge (sorted)
    tile_start: jax.Array,   # i32[num_tiles + 1] — edge range per tile
    *,
    num_tiles: int,
    tile_n: int = TILE_N,
    chunk: int = CHUNK,
    interpret: bool = False,
) -> jax.Array:
    """Returns f32[num_tiles * tile_n] accumulated incoming contributions."""
    out = pl.pallas_call(
        _make_spmv_kernel(tile_n, chunk),
        grid=(num_tiles,),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),   # tile_start (scalar-ish)
            pl.BlockSpec(memory_space=pl.ANY),   # contrib stream stays in HBM
            pl.BlockSpec(memory_space=pl.ANY),   # dst stream stays in HBM
        ],
        out_specs=pl.BlockSpec((tile_n,), lambda t: (t,)),
        out_shape=jax.ShapeDtypeStruct((num_tiles * tile_n,), jnp.float32),
        interpret=interpret,
    )(tile_start, contrib, dst_sorted)
    return out

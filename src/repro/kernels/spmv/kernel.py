"""Pallas TPU kernel: destination-tiled SpMV push (every sweep's hot loop).

TPU adaptation of the paper's vertex-centric message push.  A GPU
implementation would scatter with atomics; TPUs have no scatter-atomics, so
the kernel is restructured around the MXU:

- edges are sorted by destination (csr.sort_by_dst, amortized over ~30
  power iterations per query and — via the engine's layout cache — across
  every query between two applied update batches);
- the destination space is tiled into tile_n-wide output tiles; each grid
  step owns one tile and consumes only its edge range [tile_start[t],
  tile_start[t+1]);
- within a chunk of ``chunk`` edges, the scatter-add becomes a one-hot
  matmul: acc += onehot(dst_local)ᵀ @ contrib — a (chunk × tile_n)ᵀ·(chunk,)
  product that runs on the MXU instead of a serial scatter (the classic
  TPU segment-sum-by-matmul trick);
- per-edge contributions (e.g. rank[src] / out_deg[src]) are gathered
  OUTSIDE the kernel by XLA (TPU gathers are efficient; VMEM-resident random
  gather inside the kernel is not), so the kernel input is a dense
  contribution stream — the kernel is therefore algorithm-agnostic: PageRank
  weights, HITS unit weights and summarized E_K weights all arrive pre-baked
  in the stream.

Two kernel variants share that structure, selected by the reduction of the
semiring the sweep runs over (:mod:`repro.core.semiring`):

- :func:`spmv_push` — the ``sum``-reduce (``plus_times``) fast path: the
  scatter-add becomes a one-hot matmul on the MXU (f32 only);
- :func:`spmv_reduce_push` — the *segmented-scan* variant for non-additive
  reductions (``min``/``max`` over f32 or i32).  Within each chunk the
  per-destination reduce runs as a Hillis-Steele segmented scan whose
  same-run test is a single compare against the layout's precomputed
  ``rank`` stream (``rank[i]`` = position of edge *i* inside its
  destination run, so "my predecessor at distance ``off`` is in my run"
  is just ``rank >= off`` — no second scan over run-open flags).  Each
  run's reduced value is then scattered through the same one-hot matmul
  as the sum path, encoded so the MXU product stays *bitwise exact*:

  - floats ride as ``[finite value (0 if ±∞), ±∞ sign flag, count]`` rows
    — at most one selected run end per destination column, so each column
    sums exactly one product and ``0·∞`` never reaches the MXU;
  - int32 rides as ``[high 16 bits, low 16 bits, count]`` rows — both
    halves are < 2¹⁶ and therefore exact in f32, and the column
    reconstruction ``(hi << 16) | lo`` recovers every int32 bit pattern.

  A zero count column reconstructs the reduce identity, matching XLA's
  ``segment_min``/``segment_max`` empty-segment convention.  This replaces
  the earlier (chunk × tile_n) masked-tile reduce, whose full-tile
  materialization made min/max pushes ~5.6× slower than the segment-sum
  backend in interpret mode.  Runs spanning a chunk boundary reduce to one
  partial per chunk; the accumulator's ⊕ recombines them, and min/max are
  reassociation-exact so the split changes nothing bitwise.

Edge-stream loads are **double-buffered** on TPU (``double_buffer=True``):
chunk *i+1* is DMA-prefetched into a VMEM slot while the MXU/VPU consumes
chunk *i* — the flash-decoding overlap pattern.  Interpret mode defaults to
plain ``pl.load`` (the DMA emulation only adds overhead there); parity
tests opt in explicitly.

``tile_n``/``chunk`` are parameters (module constants are only the
defaults): the per-shape autotuner (:mod:`repro.kernels.spmv.autotune`)
picks them per (E_pad, N, B, dtype, reduce, platform) and the layout cache
carries the tuned geometry.  VMEM budget per step: 2 buffered chunks per
stream + the (chunk × tile_n) one-hot + accumulators — see
:func:`repro.kernels.spmv.autotune.modeled_push_cost` for the analytic
model the tuner prunes with.

Batched (multi-query) variants
------------------------------
:func:`spmv_push_batched` and :func:`spmv_reduce_push_batched` accept a
``[B, E_pad]`` contribution matrix — B independent value vectors pushed
through ONE shared edge stream (the serving engine's wave step).  The sum
variant's one-hot product becomes a true ``[B, chunk] @ [chunk, tile_n]``
MXU matmul, so the scatter's fixed cost (edge loads, one-hot build) is
amortized over all B queries — the cheapest throughput multiplier in the
backend.  The reduce variant stacks its encoded rows into one
``[2B+1, chunk] @ [chunk, tile_n]`` product and shrinks its chunk
128-granularly (largest fit, not halving) so the scan buffers stay inside
the VMEM budget; min/max are reassociation-exact, so each batch row stays
bitwise equal to the single-query kernel.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

CHUNK = 512
TILE_N = 256


def _shift_right(v: jax.Array, off: int, fill) -> jax.Array:
    """``v`` shifted ``off`` positions toward higher indices along the last
    axis, vacated slots holding ``fill`` (static shapes only)."""
    pad = jnp.full(v.shape[:-1] + (off,), fill, v.dtype)
    return jnp.concatenate([pad, v[..., :-off]], axis=-1)


def _stream_chunks(start, n_chunks, chunk, streams, acc0, compute,
                   double_buffer):
    """Run ``compute(i, loaded, acc)`` over chunks of the edge range.

    ``streams`` is a list of ``(ref, batch, dtype)`` — ``batch=None`` for a
    1-D ``[E_pad]`` stream, an int for a ``[batch, E_pad]`` one; chunk *i*
    loads elements ``[start + i*chunk, start + (i+1)*chunk)`` of each.
    With ``double_buffer`` the loads become async VMEM DMA copies issued
    one chunk ahead (slot *i+1* fills while slot *i* is consumed);
    otherwise plain ``pl.load`` per chunk.  Returns the final accumulator.
    """
    if not double_buffer:
        def body(i, acc):
            lo = start + i * chunk
            loaded = [
                pl.load(ref, (pl.ds(lo, chunk),)) if b is None
                else pl.load(ref, (slice(None), pl.ds(lo, chunk)))
                for ref, b, _ in streams]
            return compute(i, loaded, acc)
        return jax.lax.fori_loop(0, n_chunks, body, acc0)

    def scoped(*alloc):
        bufs = alloc[:len(streams)]
        sems = alloc[len(streams):]

        def dma(i, slot):
            lo = start + i * chunk
            copies = []
            for (ref, b, _), buf, sem in zip(streams, bufs, sems):
                src = (ref.at[pl.ds(lo, chunk)] if b is None
                       else ref.at[:, pl.ds(lo, chunk)])
                copies.append(pltpu.make_async_copy(src, buf.at[slot],
                                                    sem.at[slot]))
            return copies

        @pl.when(n_chunks > 0)
        def _():
            for cp in dma(0, 0):
                cp.start()

        def body(i, acc):
            slot = jax.lax.rem(i, 2)

            @pl.when(i + 1 < n_chunks)
            def _():
                for cp in dma(i + 1, jax.lax.rem(i + 1, 2)):
                    cp.start()

            for cp in dma(i, slot):
                cp.wait()
            loaded = [buf[slot] for buf in bufs]
            return compute(i, loaded, acc)

        return jax.lax.fori_loop(0, n_chunks, body, acc0)

    scratch = [
        pltpu.VMEM((2, chunk) if b is None else (2, b, chunk), dtype)
        for _, b, dtype in streams]
    sems = [pltpu.SemaphoreType.DMA((2,)) for _ in streams]
    return pl.run_scoped(scoped, *scratch, *sems)


def _make_spmv_kernel(tile_n: int, chunk: int, double_buffer: bool):
    """Sum-kernel body closure over the (static) tile/chunk geometry."""

    def _spmv_kernel(tile_start_ref, contrib_ref, dst_ref, out_ref):
        """One output tile: accumulate its sorted-edge range via one-hot
        matmuls."""
        t = pl.program_id(0)
        start = tile_start_ref[t]
        end = tile_start_ref[t + 1]
        base = t * tile_n
        pos = jnp.arange(chunk, dtype=jnp.int32)

        def compute(i, loaded, acc):
            c, d = loaded
            lo = start + i * chunk
            valid = lo + pos < end
            d_local = jnp.where(valid, d - base, tile_n)      # OOB -> zero row
            onehot = (d_local[:, None] ==
                      jnp.arange(tile_n, dtype=jnp.int32)[None, :])
            c = jnp.where(valid, c, 0.0)
            # MXU: scatter-add as a (1, chunk) @ (chunk, tile_n) product
            return acc + jnp.dot(c[None, :], onehot.astype(jnp.float32))[0]

        acc = _stream_chunks(
            start, pl.cdiv(end - start, chunk), chunk,
            [(contrib_ref, None, jnp.float32), (dst_ref, None, jnp.int32)],
            jnp.zeros((tile_n,), jnp.float32), compute, double_buffer)
        out_ref[...] = acc

    return _spmv_kernel


def _run_reduce(c, d, r, valid, *, base, tile_n, chunk, op, identity, acc):
    """Shared chunk step of the segmented-scan reduce kernels.

    ``c`` is the contribution chunk (``[chunk]`` or ``[B, chunk]``),
    ``d``/``r`` the destination and rank-in-run chunks, ``valid`` the
    in-range mask.  Scans each destination run to its last position, then
    scatters the per-run reduces into the accumulator columns through one
    exactness-preserving one-hot matmul (see module docstring).
    """
    combine_fn = jnp.minimum if op == "min" else jnp.maximum
    batched = c.ndim == 2
    d_local = jnp.where(valid, d - base, tile_n)
    v = jnp.where(valid[None, :] if batched else valid, c, identity)
    # Hillis-Steele segmented ⊕-scan: after step k every position holds the
    # reduce of its run's trailing 2^k window; run-last positions end up
    # with the whole run (rank tells same-run membership in one compare)
    off = 1
    for _ in range(max(1, math.ceil(math.log2(chunk)))):
        pulled = combine_fn(v, _shift_right(v, off, identity))
        v = jnp.where(r >= off, pulled, v)
        off *= 2
    # run-last positions: the destination changes at the next slot (the
    # chunk's last slot always flushes — a run spanning chunks scatters one
    # partial per chunk and the accumulator ⊕ recombines them exactly)
    nxt_d = jnp.concatenate([d_local[1:], jnp.full((1,), -1, d_local.dtype)])
    sel = (d_local != nxt_d) & (d_local < tile_n)
    if jnp.issubdtype(v.dtype, jnp.floating):
        finite = jnp.isfinite(v)
        safe = jnp.where(sel & finite, v, 0.0).astype(jnp.float32)
        extra = jnp.where(sel & ~finite, jnp.sign(v), 0.0).astype(jnp.float32)
    else:
        safe = jnp.where(sel, v & 0xffff, 0).astype(jnp.float32)
        extra = jnp.where(sel, (v >> 16) & 0xffff, 0).astype(jnp.float32)
    cnt = jnp.where(sel, 1.0, 0.0).astype(jnp.float32)
    onehot = (d_local[:, None] ==
              jnp.arange(tile_n, dtype=jnp.int32)[None, :])
    if batched:
        rows = jnp.concatenate([safe, extra, cnt[None, :]], axis=0)
    else:
        rows = jnp.stack([safe, extra, cnt])
    agg = jnp.dot(rows, onehot.astype(jnp.float32),
                  preferred_element_type=jnp.float32)
    if batched:
        b = v.shape[0]
        val, ext, ct = agg[:b], agg[b:2 * b], agg[2 * b]
    else:
        val, ext, ct = agg[0], agg[1], agg[2]
    if jnp.issubdtype(v.dtype, jnp.floating):
        col = jnp.where(ext != 0, ext * jnp.inf, val).astype(v.dtype)
    else:
        col = (val.astype(jnp.int32) |
               (ext.astype(jnp.int32) << 16)).astype(v.dtype)
    col = jnp.where(ct > 0, col, identity)
    return combine_fn(acc, col)


def _make_reduce_kernel(tile_n: int, chunk: int, op: str, identity,
                        dtype, double_buffer: bool):
    """Segmented-scan reduce kernel body: ⊕ ∈ {min, max} via rank-scan +
    exact one-hot select matmul (see module docstring)."""

    def _reduce_kernel(tile_start_ref, contrib_ref, dst_ref, rank_ref,
                       out_ref):
        t = pl.program_id(0)
        start = tile_start_ref[t]
        end = tile_start_ref[t + 1]
        base = t * tile_n
        pos = jnp.arange(chunk, dtype=jnp.int32)

        def compute(i, loaded, acc):
            c, d, r = loaded
            valid = start + i * chunk + pos < end
            return _run_reduce(c, d, r, valid, base=base, tile_n=tile_n,
                               chunk=chunk, op=op, identity=identity,
                               acc=acc)

        acc = _stream_chunks(
            start, pl.cdiv(end - start, chunk), chunk,
            [(contrib_ref, None, dtype), (dst_ref, None, jnp.int32),
             (rank_ref, None, jnp.int32)],
            jnp.full((tile_n,), identity, dtype), compute, double_buffer)
        out_ref[...] = acc

    return _reduce_kernel


def _resolve_double_buffer(double_buffer, interpret):
    """Default: DMA-overlap chunk loads on real hardware, plain loads in
    interpret mode (where the DMA emulation only adds overhead)."""
    if double_buffer is None:
        return not interpret
    return double_buffer


def _reduce_identity(dtype, op: str):
    """The ⊕-identity XLA's segment_min/max use for empty segments."""
    if jnp.issubdtype(dtype, jnp.floating):
        return dtype.type(-jnp.inf if op == "max" else jnp.inf)
    info = jnp.iinfo(dtype)
    return dtype.type(info.min if op == "max" else info.max)


@functools.partial(
    jax.jit,
    static_argnames=("num_tiles", "tile_n", "chunk", "interpret",
                     "double_buffer"),
)
def spmv_push(
    contrib: jax.Array,      # f32[E_pad] — per-edge contribution, dst-sorted
    dst_sorted: jax.Array,   # i32[E_pad] — destination per edge (sorted)
    tile_start: jax.Array,   # i32[num_tiles + 1] — edge range per tile
    *,
    num_tiles: int,
    tile_n: int = TILE_N,
    chunk: int = CHUNK,
    interpret: bool = False,
    double_buffer: bool = None,
) -> jax.Array:
    """Returns f32[num_tiles * tile_n] accumulated incoming contributions."""
    db = _resolve_double_buffer(double_buffer, interpret)
    out = pl.pallas_call(
        _make_spmv_kernel(tile_n, chunk, db),
        grid=(num_tiles,),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),   # tile_start (scalar-ish)
            pl.BlockSpec(memory_space=pl.ANY),   # contrib stream stays in HBM
            pl.BlockSpec(memory_space=pl.ANY),   # dst stream stays in HBM
        ],
        out_specs=pl.BlockSpec((tile_n,), lambda t: (t,)),
        out_shape=jax.ShapeDtypeStruct((num_tiles * tile_n,), jnp.float32),
        interpret=interpret,
    )(tile_start, contrib, dst_sorted)
    return out


@functools.partial(
    jax.jit,
    static_argnames=("num_tiles", "tile_n", "chunk", "op", "interpret",
                     "double_buffer"),
)
def spmv_reduce_push(
    contrib: jax.Array,      # [E_pad] per-edge contribution, dst-sorted
    dst_sorted: jax.Array,   # i32[E_pad] destination per edge (sorted)
    rank: jax.Array,         # i32[E_pad] position of each edge in its run
    tile_start: jax.Array,   # i32[num_tiles + 1] edge range per tile
    *,
    num_tiles: int,
    op: str,
    tile_n: int = TILE_N,
    chunk: int = CHUNK,
    interpret: bool = False,
    double_buffer: bool = None,
) -> jax.Array:
    """Segmented-scan sibling of :func:`spmv_push` for ``op`` ∈ {min, max}.

    ``rank`` is the per-edge position inside its destination run (the
    layout builders derive it from ``row_offsets`` once per build; invalid
    and padding slots must carry 0 so they never pull across runs).
    Returns ``contrib.dtype[num_tiles * tile_n]``; destinations with no
    in-range edge hold the reduce identity (+∞/−∞ or the int extrema) —
    the ⊕-zero of the semiring the caller runs, matching XLA's
    ``segment_min``/``segment_max`` empty-segment convention.
    """
    if op not in ("min", "max"):
        raise ValueError(f"op must be 'min' or 'max', got {op!r}")
    dtype = contrib.dtype
    identity = _reduce_identity(dtype, op)
    db = _resolve_double_buffer(double_buffer, interpret)
    out = pl.pallas_call(
        _make_reduce_kernel(tile_n, chunk, op, identity, dtype, db),
        grid=(num_tiles,),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((tile_n,), lambda t: (t,)),
        out_shape=jax.ShapeDtypeStruct((num_tiles * tile_n,), dtype),
        interpret=interpret,
    )(tile_start, contrib, dst_sorted, rank)
    return out


def _make_spmv_batched_kernel(batch: int, tile_n: int, chunk: int,
                              double_buffer: bool):
    """Batched sum-kernel body: the one-hot product is a real MXU matmul.

    Identical tiling/chunking to :func:`_make_spmv_kernel`; the chunk load
    is ``[batch, chunk]`` and the accumulate is
    ``acc += contrib_chunk @ onehot`` — a ``[B, chunk] @ [chunk, tile_n]``
    product, so every query in the batch shares one edge-stream pass and
    one one-hot build per chunk.
    """

    def _spmv_batched_kernel(tile_start_ref, contrib_ref, dst_ref, out_ref):
        t = pl.program_id(0)
        start = tile_start_ref[t]
        end = tile_start_ref[t + 1]
        base = t * tile_n
        pos = jnp.arange(chunk, dtype=jnp.int32)

        def compute(i, loaded, acc):
            c, d = loaded
            valid = start + i * chunk + pos < end
            d_local = jnp.where(valid, d - base, tile_n)      # OOB -> zero row
            onehot = (d_local[:, None] ==
                      jnp.arange(tile_n, dtype=jnp.int32)[None, :])
            c = jnp.where(valid[None, :], c, 0.0)
            return acc + jnp.dot(c, onehot.astype(jnp.float32),
                                 preferred_element_type=jnp.float32)

        acc = _stream_chunks(
            start, pl.cdiv(end - start, chunk), chunk,
            [(contrib_ref, batch, jnp.float32), (dst_ref, None, jnp.int32)],
            jnp.zeros((batch, tile_n), jnp.float32), compute, double_buffer)
        out_ref[...] = acc

    return _spmv_batched_kernel


def _make_reduce_batched_kernel(batch: int, tile_n: int, chunk: int, op: str,
                                identity, dtype, double_buffer: bool):
    """Batched segmented-scan reduce body: the scan runs on the
    ``[B, chunk]`` chunk with the shared rank stream, and the encoded rows
    stack into one ``[2B+1, chunk] @ [chunk, tile_n]`` select matmul.
    min/max are reassociation-exact, so each row matches the single-query
    kernel bitwise."""

    def _reduce_batched_kernel(tile_start_ref, contrib_ref, dst_ref,
                               rank_ref, out_ref):
        t = pl.program_id(0)
        start = tile_start_ref[t]
        end = tile_start_ref[t + 1]
        base = t * tile_n
        pos = jnp.arange(chunk, dtype=jnp.int32)

        def compute(i, loaded, acc):
            c, d, r = loaded
            valid = start + i * chunk + pos < end
            return _run_reduce(c, d, r, valid, base=base, tile_n=tile_n,
                               chunk=chunk, op=op, identity=identity,
                               acc=acc)

        acc = _stream_chunks(
            start, pl.cdiv(end - start, chunk), chunk,
            [(contrib_ref, batch, dtype), (dst_ref, None, jnp.int32),
             (rank_ref, None, jnp.int32)],
            jnp.full((batch, tile_n), identity, dtype), compute,
            double_buffer)
        out_ref[...] = acc

    return _reduce_batched_kernel


@functools.partial(
    jax.jit,
    static_argnames=("num_tiles", "tile_n", "chunk", "interpret",
                     "double_buffer"),
)
def spmv_push_batched(
    contrib: jax.Array,      # f32[B, E_pad] — per-edge contribs, dst-sorted
    dst_sorted: jax.Array,   # i32[E_pad] — destination per edge (sorted)
    tile_start: jax.Array,   # i32[num_tiles + 1] — edge range per tile
    *,
    num_tiles: int,
    tile_n: int = TILE_N,
    chunk: int = CHUNK,
    interpret: bool = False,
    double_buffer: bool = None,
) -> jax.Array:
    """Batched :func:`spmv_push`: B contribution streams through one shared
    sorted edge stream.  Returns ``f32[B, num_tiles * tile_n]``."""
    batch = contrib.shape[0]
    db = _resolve_double_buffer(double_buffer, interpret)
    out = pl.pallas_call(
        _make_spmv_batched_kernel(batch, tile_n, chunk, db),
        grid=(num_tiles,),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),   # tile_start (scalar-ish)
            pl.BlockSpec(memory_space=pl.ANY),   # contrib matrix stays in HBM
            pl.BlockSpec(memory_space=pl.ANY),   # dst stream stays in HBM
        ],
        out_specs=pl.BlockSpec((batch, tile_n), lambda t: (0, t)),
        out_shape=jax.ShapeDtypeStruct((batch, num_tiles * tile_n),
                                       jnp.float32),
        interpret=interpret,
    )(tile_start, contrib, dst_sorted)
    return out


#: VMEM budget (bytes) for the batched reduce kernel's per-step working set
#: — scan buffers + encoded rows + one-hot + accumulator; the chunk shrinks
#: 128-granularly until it fits (min/max reduces are order-exact, so a
#: smaller chunk changes nothing numerically)
_REDUCE_TILE_VMEM_BYTES = 6 * 1024 * 1024


def batched_reduce_chunk(batch: int, tile_n: int, chunk: int,
                         itemsize: int = 4) -> int:
    """Largest 128-multiple chunk ≤ ``chunk`` whose batched-reduce working
    set — ~6 scan/encode buffers of ``[B, chunk]``, the ``[chunk, tile_n]``
    one-hot and the ``[B, tile_n]`` accumulator — fits the VMEM budget
    (never below 128).  The shrink is incremental (largest fit), not the
    former collapse-by-halving, so a marginally-over-budget shape loses a
    sliver of chunk instead of half of it.  Exposed so callers can reason
    about the effective chunk the batched reduce kernel will use."""
    acc_bytes = batch * tile_n * itemsize
    per_chunk = 6 * batch * itemsize + 4 * tile_n
    fit = (_REDUCE_TILE_VMEM_BYTES - acc_bytes) // max(per_chunk, 1)
    fit = max(128, (fit // 128) * 128)
    return min(chunk, fit)


@functools.partial(
    jax.jit,
    static_argnames=("num_tiles", "tile_n", "chunk", "op", "interpret",
                     "double_buffer"),
)
def spmv_reduce_push_batched(
    contrib: jax.Array,      # [B, E_pad] per-edge contribs, dst-sorted
    dst_sorted: jax.Array,   # i32[E_pad] destination per edge (sorted)
    rank: jax.Array,         # i32[E_pad] position of each edge in its run
    tile_start: jax.Array,   # i32[num_tiles + 1] edge range per tile
    *,
    num_tiles: int,
    op: str,
    tile_n: int = TILE_N,
    chunk: int = CHUNK,
    interpret: bool = False,
    double_buffer: bool = None,
) -> jax.Array:
    """Batched :func:`spmv_reduce_push` for ``op`` ∈ {min, max}.

    Returns ``contrib.dtype[B, num_tiles * tile_n]``; each batch row is
    bitwise equal to the single-query kernel on the same stream (min/max
    are reassociation-exact).  The chunk shrinks automatically (largest
    128-granular fit) so the scan working set stays inside VMEM — smaller
    chunks load the same edges.
    """
    if op not in ("min", "max"):
        raise ValueError(f"op must be 'min' or 'max', got {op!r}")
    batch = contrib.shape[0]
    dtype = contrib.dtype
    identity = _reduce_identity(dtype, op)
    chunk = batched_reduce_chunk(batch, tile_n, chunk,
                                 jnp.dtype(dtype).itemsize)
    db = _resolve_double_buffer(double_buffer, interpret)
    out = pl.pallas_call(
        _make_reduce_batched_kernel(batch, tile_n, chunk, op, identity,
                                    dtype, db),
        grid=(num_tiles,),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((batch, tile_n), lambda t: (0, t)),
        out_shape=jax.ShapeDtypeStruct((batch, num_tiles * tile_n), dtype),
        interpret=interpret,
    )(tile_start, contrib, dst_sorted, rank)
    return out

"""Pallas TPU kernels for the compute hot spots.

Each kernel ships as <name>/kernel.py (pl.pallas_call + BlockSpec VMEM
tiling), <name>/ops.py (jit'd wrapper) and <name>/ref.py (pure-jnp oracle);
tests/test_kernels.py sweeps shapes/dtypes in interpret mode.

- spmv:             PageRank push as destination-tiled one-hot MXU matmuls
- flash_attention:  blocked online-softmax attention (train/prefill)
- decode_attention: flash-decoding over long KV caches (decode_32k/long_500k)
"""

"""Multi-tenant graph-query serving: slot-based continuous batching.

This is the streaming-graph analogue of the LM serving skeleton in
:mod:`repro.serve.engine` — the same *static-slot wave* discipline (a
fixed-capacity batch stepped in lockstep, finished entries swapped for
queued ones at wave boundaries), but the unit of work is a **summarized
graph query**, not a decode step:

- A :class:`GraphServingEngine` wraps one started
  :class:`~repro.core.engine.VeilGraphEngine` — one shared graph, one
  shared hot-set/summary per wave, many concurrent queries.
- Requests arrive via :meth:`GraphServingEngine.submit` (e.g. B different
  personalized-PageRank seed sets, B different SSSP sources) and return a
  :class:`QueryTicket` handle immediately.
- Queries of one algorithm *family* share a **lane**: a bank of ``slots``
  static state rows (``[S, ...]`` leaves, the
  :meth:`~repro.core.algorithm.StreamingAlgorithm.init_state` pytree with
  a leading slot axis).  Per-query identity (teleport vectors, source
  masks) lives in the rows, never in the jit-static algorithm instance —
  see ``StreamingAlgorithm.per_query_params`` — so a lane compiles ONE
  batched XLA program (:func:`repro.core.fused.fused_query_step_batched`)
  and reuses it for every wave and every request mix.
- Each :meth:`step` (wave) applies pending graph updates, refills vacant
  slots from the queue, runs one batched fused step per non-empty lane
  with a ``row_mask`` that freezes finished/vacant rows (they stop
  contributing work), then harvests rows whose per-slot convergence
  signal dropped below the request's tolerance (or whose wave budget is
  exhausted) and frees their slots.
- Summary overflow keeps the engine's graceful-degradation contract: the
  batch result of the overflowing wave is discarded and every live row is
  recomputed exactly, row by row, completing those requests.

Observability is a :class:`ServeStats` snapshot: queries served per
second, wave count, mean slot occupancy, and p50/p95 wave latency.

Construct via :func:`repro.api.serve_session`, or wrap an existing
engine directly::

    srv = GraphServingEngine(session.engine, slots=4)
    t1 = srv.submit("personalized-pagerank", seeds=(3,))
    t2 = srv.submit("sssp", sources=(17,))
    srv.run()
    t1.result, t2.result

This module is independent of the quarantined LM substrate — it imports
nothing from :mod:`repro.models` or :mod:`repro.serve.engine`.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import backend as B
from repro.core.algorithm import (AlgoState, StreamingAlgorithm,
                                  make_algorithm)
from repro.core.engine import VeilGraphEngine
from repro.core.fused import fused_query_step_batched


@dataclass
class QueryTicket:
    """Handle for one submitted query — returned by ``submit`` immediately.

    ``tol`` is the completion threshold on the per-slot convergence
    signal (L1 change of the last inner iteration for the ranking family,
    changed-entry count for the min-semiring relaxations); ``max_waves``
    bounds how many waves the query may occupy a slot.  The defaults
    (``tol=0.0, max_waves=1``) complete every query after one summarized
    sweep — the batched equivalent of one ``engine.query()`` — while
    ``max_waves > 1`` opts into multi-wave refinement until the signal
    reaches ``tol``.

    ``result`` is the algorithm's ``result_view`` row (own dtype:
    f32 ranks/distances, int32 labels) once ``done``; ``converged``
    records whether the tolerance was actually met (False = wave budget
    exhausted or exact fallback served it).
    """

    ticket_id: int
    algorithm: str
    params: Dict
    tol: float = 0.0
    max_waves: int = 1
    # filled in by the engine
    done: bool = False
    converged: bool = False
    exact_fallback: bool = False
    waves_run: int = 0
    last_delta: float = float("inf")
    result: Optional[np.ndarray] = None
    _instance: Optional[StreamingAlgorithm] = None


@dataclass
class ServeStats:
    """Aggregate serving metrics, updated once per wave.

    ``occupancy_sum`` accumulates the per-wave fraction of occupied
    slots (across all lanes), so :attr:`mean_occupancy` is the average
    slot utilization over the engine's lifetime; wave latencies feed the
    p50/p95 percentiles.
    """

    queries_submitted: int = 0
    queries_completed: int = 0
    waves: int = 0
    wall_s: float = 0.0
    overflow_fallbacks: int = 0
    occupancy_sum: float = 0.0
    wave_latencies_s: List[float] = field(default_factory=list)
    # closed-loop quality columns (quality_target engines only): refresh
    # count across lanes, the last wave's worst-slot drift reading, and
    # the controller's current/worst-case quality estimate
    refreshes: int = 0
    last_drift: float = 0.0
    quality_est: float = 1.0
    min_quality_est: float = 1.0
    # async-pipeline staleness columns (async_rebuild engines; sync
    # engines keep the zeros): the epoch the last wave served from, and
    # how far it trailed the newest dispatched build (0 or 1)
    epoch: int = 0
    snapshot_lag: int = 0

    @property
    def queries_per_s(self) -> float:
        """Completed queries per second of wave wall time.  Guarded: a
        run with zero waves (or waves too fast for the clock to resolve)
        reports 0.0 rather than dividing by zero."""
        return self.queries_completed / self.wall_s if self.wall_s > 0 else 0.0

    @property
    def mean_occupancy(self) -> float:
        """Mean fraction of slots occupied per wave, in [0, 1].  0.0
        before the first wave (never a division by zero)."""
        return self.occupancy_sum / self.waves if self.waves else 0.0

    def _latency_quantile(self, q: float) -> float:
        """Nearest-rank quantile of the wave latencies.

        Guarded for the empty/single-sample runs that used to misbehave:
        no samples -> 0.0, one sample -> that sample for every q.  The
        previous ``int(q * len)`` rank was also off by one — p95 of 20
        samples indexed element 19 (the maximum, i.e. p100); nearest
        rank is ``ceil(q * len)`` 1-indexed, so p95 of 20 reads the 19th
        order statistic."""
        lat = sorted(self.wave_latencies_s)
        if not lat:
            return 0.0
        q = min(max(q, 0.0), 1.0)
        idx = min(max(int(np.ceil(q * len(lat))) - 1, 0), len(lat) - 1)
        return lat[idx]

    @property
    def p50_wave_latency_s(self) -> float:
        """Median wall-clock latency of one wave, in seconds."""
        return self._latency_quantile(0.50)

    @property
    def p95_wave_latency_s(self) -> float:
        """95th-percentile wall-clock latency of one wave, in seconds."""
        return self._latency_quantile(0.95)


@dataclass
class _Lane:
    """One algorithm family's slot bank (internal).

    ``template`` is the jit-static algorithm instance shared by every
    request in the lane (requests differing only in
    ``per_query_params`` batch together); ``bank`` is the ``[S, ...]``
    state pytree; ``tickets[i]`` is the request occupying slot i (None =
    vacant); ``waves[i]`` counts waves the current occupant has run.
    """

    template: StreamingAlgorithm
    bank: AlgoState
    tickets: List[Optional[QueryTicket]]
    waves: List[int]
    # cold[i]: slot i's occupant has never yet converged — its waves need
    # full hot-set coverage (the batched analogue of the single-query
    # protocol's initial exact compute); cleared the first time the row's
    # convergence signal reaches its tolerance
    cold: List[bool] = field(default_factory=list)
    queue: List[QueryTicket] = field(default_factory=list)
    # per-lane SLO controller (quality_target engines only): each lane
    # runs its own accuracy loop, since lanes disagree on residual scale
    controller: Optional["QualityController"] = None

    @property
    def row_mask(self) -> jax.Array:
        return jnp.asarray([t is not None for t in self.tickets], bool)

    @property
    def occupied(self) -> int:
        return sum(t is not None for t in self.tickets)


def _lane_key(algo: StreamingAlgorithm) -> Tuple:
    """Requests batch into one lane when they differ only in the knobs
    :attr:`~repro.core.algorithm.StreamingAlgorithm.per_query_params`
    declares state-carried (seed sets, source sets) — everything else
    (iteration budgets, damping factors, the algorithm itself) is part of
    the jit-static template and therefore of the key."""
    import dataclasses

    skip = set(algo.per_query_params)
    knobs = tuple(
        (f.name, getattr(algo, f.name))
        for f in dataclasses.fields(algo) if f.name not in skip)
    return (type(algo).__name__, algo.name) + knobs


class GraphServingEngine:
    """Continuous-batching front door over one VeilGraph engine.

    ``slots`` is the static batch width *per lane* (one lane per
    algorithm family — mixed workloads, e.g. personalized PageRank plus
    SSSP, get one lane each over the same shared graph).  Slot banks and
    the batched fused step compile once per (lane, capacities) pair;
    submitting, refilling and harvesting never recompile.

    Graph updates stream through :meth:`add_edges` /
    :meth:`remove_edges` (buffered in the wrapped engine) and are
    applied at the next wave boundary, so every query in a wave sees one
    consistent graph snapshot.
    """

    def __init__(self, engine: VeilGraphEngine, *, slots: int = 4):
        if slots < 1:
            raise ValueError(f"slots must be >= 1; got {slots}")
        if not getattr(engine, "_started", False):
            raise ValueError(
                "GraphServingEngine wraps a *started* engine — call "
                "engine.start(...) (or build via repro.api.serve_session)")
        self.engine = engine
        self.slots = slots
        # batched sweeps run [slots, N] pushes — tune for that batch width
        engine.autotune_batch_hint = slots
        self.stats = ServeStats()
        self._lanes: Dict[Tuple, _Lane] = {}
        # shared edge-layout cache across lanes, keyed by normalized
        # (weight, reverse, semiring) spec; invalidated when the graph
        # mutates at a wave boundary
        self._layouts: Dict[Tuple, B.AnyEdgeLayout] = {}
        self._next_ticket = 0

    # ---- submission ------------------------------------------------------
    def submit(
        self,
        algorithm: Union[StreamingAlgorithm, str],
        *,
        tol: float = 0.0,
        max_waves: int = 1,
        **params,
    ) -> QueryTicket:
        """Enqueue one query; returns its :class:`QueryTicket` handle.

        ``algorithm`` is a registry name with factory kwargs (e.g.
        ``submit("personalized-pagerank", seeds=(3,))``) or a prebuilt
        instance.  The algorithm must implement ``summarized_batched``
        (all shipped algorithms do); the request joins the lane of its
        family and starts at the next wave boundary with a free slot.
        """
        if max_waves < 1:
            raise ValueError(f"max_waves must be >= 1; got {max_waves}")
        algo = make_algorithm(algorithm, **params)
        if (type(algo).summarized_batched
                is StreamingAlgorithm.summarized_batched):
            raise TypeError(
                f"algorithm {algo.name!r} does not implement "
                "summarized_batched — it cannot be served in a batched "
                "lane (run it through engine.query() instead)")
        ticket = QueryTicket(
            ticket_id=self._next_ticket,
            algorithm=algo.name,
            params=dict(params),
            tol=float(tol),
            max_waves=int(max_waves),
            _instance=algo,
        )
        self._next_ticket += 1
        self.stats.queries_submitted += 1
        self._lane_for(algo).queue.append(ticket)
        return ticket

    @property
    def pending(self) -> int:
        """Queries submitted but not yet done (queued or in a slot)."""
        return sum(
            len(lane.queue) + lane.occupied
            for lane in self._lanes.values())

    # ---- streaming passthrough -------------------------------------------
    def add_edges(self, src, dst, weights=None) -> "GraphServingEngine":
        """Buffer edge additions (optionally with a per-edge length
        column); applied at the next wave boundary."""
        self.engine.register_add_edges(
            np.asarray(src), np.asarray(dst),
            None if weights is None else np.asarray(weights))
        return self

    def remove_edges(self, src, dst) -> "GraphServingEngine":
        """Buffer edge removals; applied at the next wave boundary."""
        self.engine.register_remove_edges(np.asarray(src), np.asarray(dst))
        return self

    # ---- internals -------------------------------------------------------
    def _lane_for(self, algo: StreamingAlgorithm) -> _Lane:
        key = _lane_key(algo)
        lane = self._lanes.get(key)
        if lane is None:
            proto = algo.init_state(self.engine.state)
            bank = jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(
                    a[None], (self.slots,) + a.shape).copy(), proto)
            algo.validate_batch_state(bank, self.slots)
            cfg = self.engine.config
            controller = None
            if cfg.quality_target is not None:
                from repro.core.control import QualityController

                controller = QualityController(
                    cfg.quality_target,
                    r0=cfg.r, delta0=cfg.delta,
                    adjust_r=cfg.control_r,
                    adjust_delta=cfg.control_delta,
                    contraction=algo.drift_contraction,
                )
            lane = _Lane(
                template=algo,
                bank=bank,
                tickets=[None] * self.slots,
                waves=[0] * self.slots,
                cold=[False] * self.slots,
                controller=controller,
            )
            self._lanes[key] = lane
        return lane

    def _spec_layouts(self, algo: StreamingAlgorithm, snap=None) -> Tuple:
        """Cached edge layouts for an algorithm's ``layout_specs`` —
        shared across lanes that declare the same spec, rebuilt only
        after graph mutations (mirrors ``VeilGraphEngine.edge_layouts``
        but keyed by spec, since lanes disagree on which specs they
        need).

        With ``snap`` (the async pipeline's served
        :class:`~repro.core.epoch.EpochSnapshot`), layouts come from the
        snapshot's own epoch-bound cache instead, and the spec is
        registered with the engine so every *future* snapshot pre-sorts
        it at build (dispatch) time."""
        eng = self.engine
        out = []
        for spec in map(B.normalize_layout_spec, algo.layout_specs):
            if snap is not None:
                eng._async_specs[spec] = True
                out.append(snap.layout_for(spec, eng._build_spec_layout))
                continue
            layout = self._layouts.get(spec)
            if layout is None:
                layout = eng._build_spec_layout(eng.state, spec)
                self._layouts[spec] = layout
            out.append(layout)
        return tuple(out)

    def _apply_updates(self):
        """Wave-boundary ApplyUpdates: integrate buffered stream updates
        and invalidate every cached layout (the engine's own cache too —
        it shares the graph)."""
        eng = self.engine
        if not eng._pending_count:
            return
        applied, _, _ = eng._apply_pending()
        if applied:
            eng._maybe_rebalance()
            self._layouts.clear()

    def _refill(self, lane: _Lane, state=None):
        """Seat queued requests in vacant slots (wave boundary only).

        A fresh occupant's state rows come from *its own* algorithm
        instance (its seeds/sources), written into the shared bank with
        static-shaped row scatters — the bank's pytree structure, and
        therefore the lane's compiled wave program, never changes.
        ``state`` pins the graph the rows initialize against (the async
        pipeline passes the served snapshot's state).
        """
        if state is None:
            state = self.engine.state
        for i in range(self.slots):
            if lane.tickets[i] is not None or not lane.queue:
                continue
            ticket = lane.queue.pop(0)
            row = ticket._instance.init_state(state)
            lane.bank = {
                k: lane.bank[k].at[i].set(row[k]) for k in lane.bank}
            lane.tickets[i] = ticket
            lane.waves[i] = 0
            lane.cold[i] = True

    def _harvest(self, lane: _Lane, row_delta: np.ndarray,
                 *, force: bool = False):
        """Complete finished occupants and free their slots.

        A row finishes when its convergence signal reached the request's
        tolerance, its wave budget is exhausted, or ``force`` is set
        (exact fallback already produced final answers)."""
        results = None
        for i, ticket in enumerate(lane.tickets):
            if ticket is None:
                continue
            ticket.waves_run = lane.waves[i]
            ticket.last_delta = float(row_delta[i])
            # a force-harvest (exact fallback) answers exactly but never
            # *observed* the tolerance being met — converged stays False,
            # per the QueryTicket contract
            converged = (not force) and ticket.last_delta <= ticket.tol
            if converged or force:
                lane.cold[i] = False
            if not (converged or lane.waves[i] >= ticket.max_waves or force):
                continue
            if results is None:  # one device transfer per harvesting wave
                results = np.asarray(
                    jax.device_get(lane.template.result_view(lane.bank)))
            ticket.result = results[i].copy()
            ticket.converged = converged
            ticket.done = True
            lane.tickets[i] = None
            lane.waves[i] = 0
            lane.cold[i] = False
            self.stats.queries_completed += 1

    def _exact_fallback(self, lane: _Lane, state=None, snap=None):
        """Summary overflow: serve every live row with a per-row exact
        recompute (graceful degradation, same contract as
        ``engine.query``), then harvest them all.  ``state``/``snap``
        pin the recompute to the wave's served snapshot in async mode —
        the fallback must answer at the epoch the wave was serving."""
        eng = self.engine
        if state is None:
            state = eng.state
        deltas = np.zeros((self.slots,), np.float32)
        for i, ticket in enumerate(lane.tickets):
            if ticket is None:
                continue
            row = {k: lane.bank[k][i] for k in lane.bank}
            new_row, _ = ticket._instance.exact(
                row, state,
                layouts=self._spec_layouts(ticket._instance, snap),
                backend=eng.backend)
            lane.bank = {
                k: lane.bank[k].at[i].set(new_row[k]) for k in lane.bank}
            ticket.exact_fallback = True
        self.stats.overflow_fallbacks += 1
        if lane.controller is not None:
            # exact answers = accurate baseline; accumulated drift resets
            lane.controller.refreshed()
        self._harvest(lane, deltas, force=True)

    # ---- the wave loop ---------------------------------------------------
    def step(self) -> int:
        """Run one wave: apply updates, refill, one batched fused step
        per non-empty lane, harvest.  Returns the number of queries
        completed this wave.

        Async engines (``EngineConfig.async_rebuild``) reorder the
        boundary work: the wave *promotes* the finished epoch build,
        serves every lane from the promoted snapshot, and only then
        integrates buffered updates — dispatching (never awaiting) the
        next epoch's apply + sorts + rebalance probe, which overlap with
        the harvest transfers and the next wave's host-side boundary
        work."""
        eng = self.engine
        cfg = eng.config
        pipe = eng._pipeline
        t0 = time.perf_counter()
        completed_before = self.stats.queries_completed

        snap = None
        if pipe is not None:
            promoted = pipe.promote()
            if promoted is not None:
                eng._finalize_promotion(promoted)
            snap = pipe.current
            state = snap.state
            self.stats.epoch = snap.epoch
        else:
            self._apply_updates()
            state = eng.state
        occupied = 0
        for lane in self._lanes.values():
            self._refill(lane, state)
            occupied += lane.occupied

        for lane in self._lanes.values():
            if lane.occupied == 0:
                continue
            row_mask = lane.row_mask
            ctl = lane.controller
            r_now = ctl.r_eff if ctl is not None else cfg.r
            delta_now = ctl.delta_eff if ctl is not None else cfg.delta
            # cold-start coverage: rows whose occupant has never converged
            # get seed-local delta expansion inside the fused step (see
            # fused_query_step_batched's cold_rows contract) — no cold
            # rows costs zero extra sweeps
            cold_rows = jnp.asarray(
                [c and t is not None
                 for c, t in zip(lane.cold, lane.tickets)], bool)
            out = fused_query_step_batched(
                state,
                lane.bank,
                eng.deg_prev,
                eng.active_prev,
                jnp.float32(r_now),
                jnp.float32(delta_now),
                row_mask,
                cold_rows,
                eng._probe_ids,
                algo=lane.template,
                hot_node_capacity=cfg.hot_node_capacity,
                hot_edge_capacity=cfg.hot_edge_capacity,
                n=cfg.n,
                delta_hop_cap=cfg.delta_hop_cap,
                degree_mode=cfg.degree_mode,
                expand_both=cfg.expand_both,
                layouts=self._spec_layouts(lane.template, snap),
                backend=eng.backend,
                shard_bucket_capacity=cfg.shard_hot_edge_capacity,
                with_drift=ctl is not None,
            )
            if ctl is not None:
                new_bank, qs, row_delta, row_drift = out
            else:
                new_bank, qs, row_delta = out
                row_drift = None
            if bool(qs.used_fallback):
                # batch result is invalid — discard, serve rows exactly
                # (pinned to this wave's snapshot in async mode)
                self._exact_fallback(lane, state, snap)
                continue
            lane.bank = new_bank
            for i in range(self.slots):
                if lane.tickets[i] is not None:
                    lane.waves[i] += 1
            if ctl is not None:
                # one combined transfer: per-slot deltas + drift columns
                rd, drift = jax.device_get((row_delta, row_drift))
                drift = np.asarray(drift)
                probe = float(drift[:, 0].max(initial=0.0))
                cold_d = float(drift[:, 1].max(initial=0.0))
                dec = ctl.observe(probe, cold_d)
                self.stats.last_drift = max(probe, cold_d)
                self.stats.quality_est = dec.quality_est
                self.stats.min_quality_est = min(
                    self.stats.min_quality_est, dec.quality_est)
                if dec.refresh:
                    # SLO breach: re-mark every live slot cold so the next
                    # wave re-covers them (the batched analogue of the
                    # single-query engine's exact refresh), and reset the
                    # accumulated drift
                    for i, t in enumerate(lane.tickets):
                        if t is not None:
                            lane.cold[i] = True
                    self.stats.refreshes += 1
                    ctl.refreshed()
                self._harvest(lane, np.asarray(rd))
            else:
                self._harvest(lane, np.asarray(jax.device_get(row_delta)))

        if pipe is not None:
            # every lane's result for this wave is already fetched: apply
            # buffered updates and dispatch epoch N+1's build — it drains
            # behind this wave's compute while the host runs the epilogue
            # and the next wave's boundary work
            if eng._pending_count:
                eng._async_integrate()
            self.stats.snapshot_lag = pipe.snapshot_lag
            # the served epoch's own baselines become the next wave's
            # deg_prev/active_prev (drift measured across whole epochs)
            eng.deg_prev = snap.deg
            eng.active_prev = snap.active
        else:
            # hot-set snapshots advance exactly like engine.query()'s
            # epilogue
            eng.deg_prev = eng._degree_snapshot()
            eng.active_prev = jnp.copy(eng.state.node_active)

        wave_s = time.perf_counter() - t0
        self.stats.waves += 1
        self.stats.wall_s += wave_s
        self.stats.wave_latencies_s.append(wave_s)
        total_slots = max(len(self._lanes) * self.slots, 1)
        self.stats.occupancy_sum += occupied / total_slots
        return self.stats.queries_completed - completed_before

    def run(self, max_steps: int = 10_000) -> ServeStats:
        """Drive waves until every submitted query is done (or
        ``max_steps`` waves elapse — a safety valve against a request
        whose tolerance is unreachable within its wave budget, which
        cannot happen with the shipped completion rule).  Returns the
        accumulated :class:`ServeStats`."""
        steps = 0
        while self.pending:
            if steps >= max_steps:
                raise RuntimeError(
                    f"serving did not drain after {max_steps} waves "
                    f"({self.pending} queries still pending)")
            self.step()
            steps += 1
        return self.stats

    # ---- lifecycle -------------------------------------------------------
    def close(self):
        """Fire the wrapped engine's OnStop UDF (``with``-exit calls it)."""
        self.engine.stop()

    def __enter__(self) -> "GraphServingEngine":
        return self

    def __exit__(self, *exc):
        self.close()
        return False

"""Batched serving engine: prefill + decode loop with a static-slot batch.

Continuous-batching-lite: a fixed number of slots decode in lockstep; a
finished sequence's slot is refilled at the next prefill boundary.  This is
the CPU-runnable serving driver for the examples; at pod scale the same
``serve_step`` is what the dry-run lowers (decode_32k / long_500k cells).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.transformer import lm_decode_step, lm_prefill


@dataclass
class Request:
    prompt: np.ndarray           # int32[prompt_len]
    max_new_tokens: int = 16
    id: int = 0
    # filled by the engine
    output: List[int] = field(default_factory=list)
    done: bool = False


@dataclass
class ServeStats:
    prefill_s: float = 0.0
    decode_s: float = 0.0
    steps: int = 0
    tokens_out: int = 0

    @property
    def tokens_per_s(self) -> float:
        return self.tokens_out / self.decode_s if self.decode_s else 0.0


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params: Any, *, batch_slots: int = 4,
                 max_len: int = 256, greedy: bool = True, seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.slots = batch_slots
        self.max_len = max_len
        self.greedy = greedy
        self.key = jax.random.PRNGKey(seed)
        self._decode = jax.jit(
            lambda p, c, t, pos: lm_decode_step(p, cfg, c, t, pos))

    def run(self, requests: List[Request]) -> ServeStats:
        """Serve requests in waves of `batch_slots` (lockstep decode)."""
        stats = ServeStats()
        queue = list(requests)
        while queue:
            wave = queue[: self.slots]
            queue = queue[self.slots:]
            self._run_wave(wave, stats)
        return stats

    def _run_wave(self, wave: List[Request], stats: ServeStats):
        cfg = self.cfg
        b = len(wave)
        plen = max(len(r.prompt) for r in wave)
        toks = np.zeros((b, plen), np.int32)
        for i, r in enumerate(wave):
            toks[i, plen - len(r.prompt):] = r.prompt  # left-pad
        t0 = time.perf_counter()
        logits, cache = lm_prefill(self.params, cfg, jnp.asarray(toks),
                                   cache_len=self.max_len)
        last = logits[:, -1]
        jax.block_until_ready(last)
        stats.prefill_s += time.perf_counter() - t0

        t0 = time.perf_counter()
        max_new = max(r.max_new_tokens for r in wave)
        pos = plen
        cur = self._select(last)
        for step in range(max_new):
            for i, r in enumerate(wave):
                if not r.done and len(r.output) < r.max_new_tokens:
                    r.output.append(int(cur[i]))
                    stats.tokens_out += 1
                elif not r.done:
                    r.done = True
            if all(len(r.output) >= r.max_new_tokens for r in wave):
                break
            if pos >= self.max_len - 1:
                break
            logits, cache = self._decode(self.params, cache,
                                         cur[:, None], jnp.int32(pos))
            cur = self._select(logits)
            pos += 1
            stats.steps += 1
        jax.block_until_ready(cur)
        stats.decode_s += time.perf_counter() - t0
        for r in wave:
            r.done = True

    def _select(self, logits: jax.Array) -> jax.Array:
        if self.greedy:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self.key, sub = jax.random.split(self.key)
        return jax.random.categorical(sub, logits).astype(jnp.int32)

"""Mamba2-2.7B: attention-free SSD [arXiv:2405.21060; unverified].

64L, d_model 2560, d_inner 5120 (expand 2), 80 SSM heads (headdim 64),
ssm_state 128, vocab 50280.  long_500k decodes with O(1)/token state.
"""
from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b", family="ssm",
    num_layers=64, d_model=2560, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=50280, head_dim=64,
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, chunk_size=256),
)

SMOKE_CONFIG = ModelConfig(
    name="mamba2-2.7b-smoke", family="ssm",
    num_layers=3, d_model=128, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=512, head_dim=32,
    ssm=SSMConfig(d_state=16, head_dim=32, expand=2, chunk_size=32),
)

"""Zamba2-7B: hybrid Mamba2 backbone + shared attention block
[arXiv:2411.15242; unverified].

81L, d_model 3584, shared attn 32H (kv=32), d_ff 14336, vocab 32000,
ssm_state 64.  The shared transformer block (one weight set) is applied
every 6 mamba layers (13 applications + 3 tail mamba layers).
"""
from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid",
    num_layers=81, d_model=3584, num_heads=32, num_kv_heads=32,
    d_ff=14336, vocab_size=32000, hybrid_period=6,
    ssm=SSMConfig(d_state=64, head_dim=64, expand=2, chunk_size=256),
)

SMOKE_CONFIG = ModelConfig(
    name="zamba2-7b-smoke", family="hybrid",
    num_layers=5, d_model=128, num_heads=4, num_kv_heads=4,
    d_ff=256, vocab_size=512, hybrid_period=2,
    ssm=SSMConfig(d_state=16, head_dim=32, expand=2, chunk_size=32),
    q_block=32, kv_block=64,
)

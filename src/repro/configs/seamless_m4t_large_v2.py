"""SeamlessM4T-large-v2 backbone: encoder-decoder [arXiv:2308.11596].

24 encoder + 24 decoder layers, d_model 1024, 16 heads, d_ff 8192,
vocab 256206.  The speech/text frontend is a STUB: input_specs() provides
precomputed frame embeddings (B, S_enc, d) for the encoder.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2", family="encdec",
    num_layers=24, encoder_layers=24, d_model=1024,
    num_heads=16, num_kv_heads=16, d_ff=8192, vocab_size=256206,
    frontend="audio",
)

SMOKE_CONFIG = ModelConfig(
    name="seamless-m4t-large-v2-smoke", family="encdec",
    num_layers=2, encoder_layers=2, d_model=96,
    num_heads=4, num_kv_heads=4, d_ff=192, vocab_size=512,
    frontend="audio", q_block=32, kv_block=64,
)

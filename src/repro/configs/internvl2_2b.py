"""InternVL2-2B: InternViT frontend + InternLM2-1.8B backbone
[arXiv:2404.16821].

LM backbone: 24L, d_model 2048, 16 heads (GQA kv=8), d_ff 8192, vocab 92553.
The ViT frontend is a STUB: input_specs() provides precomputed patch
embeddings (B, P, d) prepended to the token sequence.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b", family="dense",
    num_layers=24, d_model=2048, num_heads=16, num_kv_heads=8,
    d_ff=8192, vocab_size=92553, frontend="vision", frontend_len=256,
)

SMOKE_CONFIG = ModelConfig(
    name="internvl2-2b-smoke", family="dense",
    num_layers=3, d_model=128, num_heads=8, num_kv_heads=4,
    d_ff=256, vocab_size=512, frontend="vision", frontend_len=16,
    q_block=32, kv_block=64,
)

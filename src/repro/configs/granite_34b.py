"""Granite-34B-Code: llama-arch MQA (kv=1) [arXiv:2405.04324].

88L, d_model 6144, 48 heads (MQA kv=1), d_ff 24576, vocab 49152.
GPT-BigCode lineage: ungated 2-matrix GELU MLP (mlp_gated=False).  The
original uses learned absolute positions; we use RoPE for stack uniformity
(documented hardware-adaptation simplification in DESIGN.md).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-34b", family="dense",
    num_layers=88, d_model=6144, num_heads=48, num_kv_heads=1,
    d_ff=24576, vocab_size=49152, mlp_gated=False,
)

SMOKE_CONFIG = ModelConfig(
    name="granite-34b-smoke", family="dense",
    num_layers=4, d_model=128, num_heads=8, num_kv_heads=1,
    d_ff=512, vocab_size=512, mlp_gated=False,
    q_block=32, kv_block=64,
)

"""MiniCPM3-4B: dense with Multi-head Latent Attention
[hf:openbmb/MiniCPM3-4B].

62L, d_model 2560, 40 heads, d_ff 6400, vocab 73448; MLA ranks:
q_lora 768, kv_lora 256, qk_nope 64, qk_rope 32, v_head 64.
The pool lists "GQA kv=40": with MLA every head gets its own expanded K/V
(kv==num_heads); the cached state is the rank-256 latent.
"""
from repro.models.config import MLAConfig, ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b", family="dense",
    num_layers=62, d_model=2560, num_heads=40, num_kv_heads=40,
    d_ff=6400, vocab_size=73448,
    mla=MLAConfig(q_lora_rank=768, kv_lora_rank=256,
                  qk_nope_head_dim=64, qk_rope_head_dim=32, v_head_dim=64),
)

SMOKE_CONFIG = ModelConfig(
    name="minicpm3-4b-smoke", family="dense",
    num_layers=3, d_model=128, num_heads=4, num_kv_heads=4,
    d_ff=256, vocab_size=512,
    mla=MLAConfig(q_lora_rank=48, kv_lora_rank=32,
                  qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16),
    q_block=32, kv_block=64,
)

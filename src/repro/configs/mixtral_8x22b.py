"""Mixtral-8x22B: MoE (8 experts, top-2) with sliding-window attention
[arXiv:2401.04088].

56L, d_model 6144, 48 heads (GQA kv=8), expert d_ff 16384, vocab 32768,
window 4096.  SWA gives O(window) decode caches, so long_500k runs with a
ring cache.
"""
from repro.models.config import MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b", family="moe",
    num_layers=56, d_model=6144, num_heads=48, num_kv_heads=8,
    d_ff=16384, vocab_size=32768, sliding_window=4096,
    moe=MoEConfig(num_experts=8, top_k=2, capacity_factor=1.25),
    rope_theta=1_000_000.0,
)

SMOKE_CONFIG = ModelConfig(
    name="mixtral-8x22b-smoke", family="moe",
    num_layers=3, d_model=128, num_heads=8, num_kv_heads=2,
    d_ff=256, vocab_size=512, sliding_window=32,
    moe=MoEConfig(num_experts=4, top_k=2, capacity_factor=1.25),
    q_block=32, kv_block=64,
)

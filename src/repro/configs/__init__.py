"""Architecture registry: ``get_config(arch_id)`` / ``get_smoke_config(arch_id)``.

Full configs are exercised only through the dry-run (ShapeDtypeStruct, no
allocation); smoke configs are reduced same-family models that run a real
forward/train step on CPU.
"""

from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ModelConfig

ARCH_IDS: List[str] = [
    "yi_9b",
    "minicpm3_4b",
    "qwen2_0_5b",
    "granite_34b",
    "zamba2_7b",
    "seamless_m4t_large_v2",
    "mixtral_8x22b",
    "dbrx_132b",
    "mamba2_2_7b",
    "internvl2_2b",
]

# accepted CLI aliases (--arch yi-9b etc.)
ALIASES: Dict[str, str] = {a.replace("_", "-"): a for a in ARCH_IDS}
ALIASES.update({
    "yi-9b": "yi_9b", "minicpm3-4b": "minicpm3_4b", "qwen2-0.5b": "qwen2_0_5b",
    "granite-34b": "granite_34b", "zamba2-7b": "zamba2_7b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "mixtral-8x22b": "mixtral_8x22b", "dbrx-132b": "dbrx_132b",
    "mamba2-2.7b": "mamba2_2_7b", "internvl2-2b": "internvl2_2b",
})


def _module(arch: str):
    arch = ALIASES.get(arch, arch)
    if arch not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ALIASES)}")
    return importlib.import_module(f"repro.configs.{arch}")


def get_config(arch: str) -> ModelConfig:
    return _module(arch).CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return _module(arch).SMOKE_CONFIG

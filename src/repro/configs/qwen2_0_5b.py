"""Qwen2-0.5B: dense GQA with QKV bias [arXiv:2407.10671].

24L, d_model 896, 14 heads (GQA kv=2), d_ff 4864, vocab 151936.
head_dim = 64; embeddings tied (small model).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b", family="dense",
    num_layers=24, d_model=896, num_heads=14, num_kv_heads=2,
    d_ff=4864, vocab_size=151936, qkv_bias=True, tie_embeddings=True,
    rope_theta=1_000_000.0,
)

SMOKE_CONFIG = ModelConfig(
    name="qwen2-0.5b-smoke", family="dense",
    num_layers=3, d_model=96, num_heads=6, num_kv_heads=2,
    d_ff=256, vocab_size=512, qkv_bias=True, tie_embeddings=True,
    q_block=32, kv_block=64,
)

"""DBRX-132B: fine-grained MoE (16 experts, top-4) [hf:databricks/dbrx-base].

40L, d_model 6144, 48 heads (GQA kv=8), expert d_ff 10752, vocab 100352.
"""
from repro.models.config import MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b", family="moe",
    num_layers=40, d_model=6144, num_heads=48, num_kv_heads=8,
    d_ff=10752, vocab_size=100352,
    moe=MoEConfig(num_experts=16, top_k=4, capacity_factor=1.25),
    rope_theta=500_000.0,
)

SMOKE_CONFIG = ModelConfig(
    name="dbrx-132b-smoke", family="moe",
    num_layers=3, d_model=128, num_heads=8, num_kv_heads=2,
    d_ff=192, vocab_size=512,
    moe=MoEConfig(num_experts=4, top_k=2, capacity_factor=1.25),
    q_block=32, kv_block=64,
)

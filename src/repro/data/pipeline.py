"""Synthetic token data pipeline: sharded host loader with bounded prefetch.

Production shape: each host generates/loads only its addressable slice of the
global batch (process-sharded), a background thread keeps a bounded queue of
device-ready batches (prefetch hides host latency and is the first line of
straggler mitigation), and the iterator is deterministic in (seed, step) so a
restarted job resumes mid-epoch without data skew.
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Any, Dict, Iterator, Optional

import jax
import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    # synthetic LM task: noisy copy of a lag-k markov stream (learnable)
    lag: int = 2
    noise: float = 0.05


class SyntheticLMData:
    """Deterministic-per-step synthetic LM batches.

    The task is a lag-k repeat-with-noise language: predictable enough that a
    few hundred steps of a ~100M model show a clearly decreasing loss (used
    by repro.launch.train), random enough not to be trivial.
    """

    def __init__(self, cfg: DataConfig, *, host_batch: Optional[int] = None):
        self.cfg = cfg
        self.host_batch = host_batch or max(
            cfg.global_batch // jax.process_count(), 1)

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * (jax.process_index() + 1))
        b, s = self.host_batch, cfg.seq_len
        base = rng.integers(0, cfg.vocab_size, size=(b, s + cfg.lag),
                            dtype=np.int64)
        # token t copies token t-lag with prob (1-noise)
        copy = rng.random((b, s + cfg.lag)) > cfg.noise
        for t in range(cfg.lag, s + cfg.lag):
            base[:, t] = np.where(copy[:, t], base[:, t - cfg.lag], base[:, t])
        tokens = base[:, : s].astype(np.int32)
        labels = base[:, 1: s + 1].astype(np.int32)
        return {"tokens": tokens, "labels": labels}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """Bounded background prefetch queue over any batch iterator."""

    def __init__(self, it: Iterator[Any], depth: int = 2):
        self._it = it
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._err: Optional[BaseException] = None
        self._done = False
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _fill(self):
        try:
            for item in self._it:
                self._q.put(item)
                if self._done:
                    return
        except BaseException as e:
            self._err = e
        finally:
            self._q.put(None)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is None:
            if self._err:
                raise self._err
            raise StopIteration
        return item

    def close(self):
        self._done = True


def shard_batch(batch: Dict[str, np.ndarray], shardings: Dict[str, Any]):
    """Place a host batch onto devices with the given shardings."""
    return {
        k: jax.device_put(v, shardings[k]) if k in shardings else v
        for k, v in batch.items()
    }

"""Property-based tests for the edge partition behind sharded layouts.

On random graphs (including empty graphs, singleton shards and more shards
than edges) the partition must be exactly that — a partition:

- every live edge slot lands in exactly one shard (and padding in none);
- each shard's stream is destination-sorted *locally*, with per-shard
  ``row_offsets`` consistent with it;
- the ⊕-merge of the per-shard partial pushes equals the unsorted
  ``push_coo`` reference over the whole edge set;
- *rebalanced* partitions (:func:`balanced_shard_slots`, the streaming
  load-balance recut) are partitions too, spread live edges within one of
  perfectly even, and preserve push results — **bitwise** for the
  min-reduce semirings, whose ⊕ is reassociation-exact.

Runs with the real ``hypothesis`` when installed, or the deterministic
shim from ``tests/_hypothesis_compat.py`` otherwise.
"""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import backend as B
from repro.core.semiring import resolve_semiring
from repro.graph import from_edges
from repro.graph.graph import remove_edges_by_slot
from repro.graph.partition import (balanced_shard_slots,
                                   build_sharded_layout,
                                   rebalance_sharded_layout,
                                   shard_imbalance, shard_live_counts,
                                   shard_slots)


def _random_graph(rng, n, m, e_extra):
    src = rng.integers(0, n, m).astype(np.int32)
    dst = rng.integers(0, n, m).astype(np.int32)
    return from_edges(src, dst, n, m + e_extra)


def test_shard_slots_partition_the_slot_space():
    for e_cap, s in [(10, 3), (8, 8), (5, 12), (1, 1), (7, 1)]:
        slots = shard_slots(e_cap, s)
        assert slots.shape[0] == s
        real = slots[slots < e_cap]
        np.testing.assert_array_equal(np.sort(real), np.arange(e_cap))


@settings(max_examples=12, deadline=None)
@given(n=st.integers(2, 60), m=st.integers(0, 150),
       num_shards=st.integers(1, 12), seed=st.integers(0, 10_000),
       semiring=st.sampled_from(["plus_times", "min_plus", "min_min",
                                 "max_times"]))
def test_every_edge_lands_in_exactly_one_shard(n, m, num_shards, seed,
                                               semiring):
    rng = np.random.default_rng(seed)
    g = _random_graph(rng, n, m, e_extra=5)
    weight = "inv_out" if semiring == "plus_times" else "unit"
    lay = build_sharded_layout(g, num_shards=num_shards, weight=weight,
                               semiring=semiring)
    order = np.asarray(lay.order)
    valid = np.asarray(lay.valid)
    # the valid positions' original slots are exactly the live slots, once
    live = np.flatnonzero(np.asarray(g.edge_mask()))
    np.testing.assert_array_equal(np.sort(order[valid]), live)
    # padding/invalid positions never alias a live slot into a second shard
    assert not np.isin(order[~valid], live).any()
    # shard_slots is the oracle for the partition the layout actually
    # applied: per shard, the layout's (sort-permuted) slot set equals it
    slots = shard_slots(g.edge_capacity, num_shards)
    e_cap = g.edge_capacity
    for s_i in range(num_shards):
        np.testing.assert_array_equal(
            np.unique(order[s_i][order[s_i] < e_cap]),
            np.unique(slots[s_i][slots[s_i] < e_cap]))


@settings(max_examples=12, deadline=None)
@given(n=st.integers(2, 60), m=st.integers(0, 150),
       num_shards=st.integers(1, 12), seed=st.integers(0, 10_000))
def test_each_shard_is_destination_sorted(n, m, num_shards, seed):
    rng = np.random.default_rng(seed)
    g = _random_graph(rng, n, m, e_extra=3)
    lay = build_sharded_layout(g, num_shards=num_shards, weight="unit",
                               semiring="min_min")
    dst = np.asarray(lay.dst)
    valid = np.asarray(lay.valid)
    ro = np.asarray(lay.row_offsets)
    assert (np.diff(dst, axis=1) >= 0).all()  # sentinel N sorts last
    assert (dst[~valid] == g.node_capacity).all()
    for s in range(dst.shape[0]):
        assert ro[s, 0] == 0 and ro[s, -1] == int(valid[s].sum())
        for v in (0, n // 2, n - 1):
            assert (dst[s, ro[s, v]:ro[s, v + 1]] == v).all()


@settings(max_examples=12, deadline=None)
@given(n=st.integers(2, 50), m=st.integers(0, 120),
       num_shards=st.integers(1, 10), seed=st.integers(0, 10_000),
       semiring=st.sampled_from(["plus_times", "min_plus", "min_min",
                                 "max_times"]))
def test_merged_shard_pushes_equal_push_coo(n, m, num_shards, seed,
                                            semiring):
    """⊕ over per-shard partials == one unsorted reduce over all edges —
    the single-device anchor the distributed all-reduce is pinned to
    (bitwise for the min semirings, f32-order tolerance for sums)."""
    s = resolve_semiring(semiring)
    rng = np.random.default_rng(seed)
    g = _random_graph(rng, n, m, e_extra=4)
    weight = "inv_out" if semiring == "plus_times" else "unit"
    if np.issubdtype(s.np_dtype, np.floating):
        values = jnp.asarray(rng.random(n).astype(s.np_dtype))
    else:
        values = jnp.asarray(rng.integers(0, n, n).astype(s.np_dtype))
    lay = build_sharded_layout(g, num_shards=num_shards, weight=weight,
                               semiring=semiring)
    out = B.push(values, lay, semiring=semiring, backend="segment_sum")

    mask = g.edge_mask()
    if weight == "inv_out":
        from repro.graph.graph import inv_out_degree
        w = jnp.where(mask, inv_out_degree(g)[g.src], 0.0)
    else:
        w = jnp.where(mask, jnp.asarray(s.one, s.dtype),
                      jnp.asarray(s.zero, s.dtype))
    ref = B.push_coo(values, g.src, g.dst, n, weight=w, mask=mask,
                     semiring=semiring)
    assert out.dtype == ref.dtype
    if s.add == "min":
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    else:
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# Rebalanced partitions (the streaming load-balance recut)
# ---------------------------------------------------------------------------


def _churned_graph(rng, n, m, e_extra, removals):
    """A graph with streaming damage: tombstones sprinkled over the buffer
    (what hollows out shards) plus append headroom (what fills tail-heavy)."""
    g = _random_graph(rng, n, m, e_extra)
    if m and removals:
        slots = rng.choice(m, size=min(removals, m), replace=False)
        g = remove_edges_by_slot(g, jnp.asarray(slots, jnp.int32))
    return g


@settings(max_examples=12, deadline=None)
@given(n=st.integers(2, 50), m=st.integers(0, 120),
       e_extra=st.integers(0, 200), removals=st.integers(0, 40),
       num_shards=st.integers(1, 10), seed=st.integers(0, 10_000))
def test_balanced_slots_is_an_even_partition(n, m, e_extra, removals,
                                             num_shards, seed):
    """balanced_shard_slots is a partition of the slot space whose
    per-shard live counts differ by at most one (a perfect deal)."""
    rng = np.random.default_rng(seed)
    g = _churned_graph(rng, n, m, e_extra, removals)
    slots = np.asarray(balanced_shard_slots(g, num_shards=num_shards))
    e_cap = g.edge_capacity
    real = slots[slots < e_cap]
    np.testing.assert_array_equal(np.sort(real), np.arange(e_cap))
    counts = np.asarray(shard_live_counts(g, jnp.asarray(slots)))
    assert counts.sum() == int(g.num_live_edges())
    assert counts.max() - counts.min() <= 1


@settings(max_examples=12, deadline=None)
@given(n=st.integers(2, 50), m=st.integers(1, 120),
       e_extra=st.integers(0, 200), removals=st.integers(0, 40),
       num_shards=st.integers(1, 10), seed=st.integers(0, 10_000),
       semiring=st.sampled_from(["plus_times", "min_plus", "min_min",
                                 "max_times"]))
def test_rebalanced_layout_preserves_push(n, m, e_extra, removals,
                                          num_shards, seed, semiring):
    """A layout built over the rebalanced assignment pushes identically to
    the unsorted reference — **bitwise** for the min-reduce semirings
    (rebalancing is a pure load-balance decision, never a semantics one)."""
    s = resolve_semiring(semiring)
    rng = np.random.default_rng(seed)
    g = _churned_graph(rng, n, m, e_extra, removals)
    weight = "inv_out" if semiring == "plus_times" else "unit"
    if np.issubdtype(s.np_dtype, np.floating):
        values = jnp.asarray(rng.random(n).astype(s.np_dtype))
    else:
        values = jnp.asarray(rng.integers(0, n, n).astype(s.np_dtype))
    slots = balanced_shard_slots(g, num_shards=num_shards)
    lay = build_sharded_layout(g, num_shards=num_shards, weight=weight,
                               semiring=semiring, slots=slots)
    out = B.push(values, lay, semiring=semiring, backend="segment_sum")

    mask = g.edge_mask()
    if weight == "inv_out":
        from repro.graph.graph import inv_out_degree
        w = jnp.where(mask, inv_out_degree(g)[g.src], 0.0)
    else:
        w = jnp.where(mask, jnp.asarray(s.one, s.dtype),
                      jnp.asarray(s.zero, s.dtype))
    ref = B.push_coo(values, g.src, g.dst, n, weight=w, mask=mask,
                     semiring=semiring)
    assert out.dtype == ref.dtype
    if s.add == "min":
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    else:
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-6)


@settings(max_examples=8, deadline=None)
@given(n=st.integers(4, 40), m=st.integers(8, 100),
       num_shards=st.integers(2, 8), seed=st.integers(0, 10_000))
def test_rebalance_trigger_thresholds(n, m, num_shards, seed):
    """rebalance_sharded_layout recuts exactly when imbalance exceeds the
    threshold: a front-loaded buffer (huge append headroom) trips it, and
    the recut assignment measures (near-)zero imbalance afterwards."""
    rng = np.random.default_rng(seed)
    g = _random_graph(rng, n, m, e_extra=8 * m)  # lives in the head only
    slots0 = jnp.asarray(shard_slots(g.edge_capacity, num_shards))
    imb0 = float(shard_imbalance(shard_live_counts(g, slots0)))
    # below threshold: assignment unchanged
    same, rebalanced, measured = rebalance_sharded_layout(
        g, num_shards=num_shards, threshold=imb0 + 1.0)
    assert not rebalanced and measured == imb0
    np.testing.assert_array_equal(np.asarray(same), np.asarray(slots0))
    # above threshold: recut to (near-)even
    new, rebalanced, measured = rebalance_sharded_layout(
        g, num_shards=num_shards, threshold=imb0 / 2)
    if imb0 > imb0 / 2:
        assert rebalanced
        counts = np.asarray(shard_live_counts(g, new))
        assert counts.max() - counts.min() <= 1

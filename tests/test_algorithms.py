"""The pluggable-algorithm API: parity, registry, session front door.

Acceptance contract for every registered algorithm:

- *exactness at full coverage*: on a static graph (no pending updates) a
  summarized query with r = 1.0-equivalent selection (every vertex hot)
  reproduces the exact reference up to f32 reassociation;
- *accuracy at paper defaults*: over a streamed synthetic dataset with the
  paper's (r, n, Δ) = (0.2, 1, 0.1), per-query RBO vs an exact replay stays
  >= 0.95.
"""

import numpy as np
import pytest

import repro as veilgraph
from repro.core import (Action, EngineConfig, HITSAlgorithm,
                        PageRankAlgorithm, PersonalizedPageRankAlgorithm,
                        StreamingAlgorithm, VeilGraphEngine,
                        available_algorithms, make_algorithm,
                        register_algorithm)
from repro.core.policies import always
from repro.graph.generators import barabasi_albert_edges
from repro.metrics import rbo_from_scores
from repro.stream import StreamConfig, build_stream

ALGORITHMS = {
    "pagerank": lambda: PageRankAlgorithm(num_iters=60, tol=1e-7),
    "personalized-pagerank": lambda: PersonalizedPageRankAlgorithm(
        seeds=(0, 3, 14), num_iters=60, tol=1e-7),
    "hits": lambda: HITSAlgorithm(num_iters=60, tol=1e-7),
}


def _cfg(n_cap, e_cap, **kw):
    base = dict(node_capacity=n_cap, edge_capacity=e_cap,
                hot_node_capacity=n_cap, hot_edge_capacity=e_cap,
                r=0.2, n=1, delta=0.1)
    base.update(kw)
    return EngineConfig(**base)


@pytest.fixture(scope="module")
def static_graph():
    return barabasi_albert_edges(800, 3, seed=0)


@pytest.fixture(scope="module")
def paper_stream():
    # paper-representative churn: update chunks are ~0.5% of |E| per query
    src, dst = barabasi_albert_edges(5000, 4, seed=0)
    return build_stream(src, dst, StreamConfig(stream_size=1000,
                                               num_queries=8, seed=2))


@pytest.mark.parametrize("name", sorted(ALGORITHMS))
@pytest.mark.parametrize("fused", [True, False])
def test_full_hot_set_matches_exact(static_graph, name, fused):
    """r = 1.0 coverage (every active vertex hot, empty big vertex) ==> the
    summarized path is the exact computation."""
    src, dst = static_graph
    algo = ALGORITHMS[name]()
    # r < 0 makes every previously-seen vertex "changed" => K == V_active
    approx = VeilGraphEngine(_cfg(1000, 8192, r=-1.0, delta=1e9, fused=fused),
                             algo)
    exact = VeilGraphEngine(_cfg(1000, 8192, fused=fused), algo,
                            on_query=always(Action.EXACT))
    approx.start(src, dst)
    exact.start(src, dst)
    ra, sa = approx.query()
    re_, se = exact.query()
    assert sa.action == "compute-approximate"
    assert not sa.overflow_fallback
    assert sa.num_hot == sa.num_nodes  # full coverage
    np.testing.assert_allclose(ra, re_, rtol=1e-4, atol=1e-6)


@pytest.mark.parametrize("name", sorted(ALGORITHMS))
def test_streamed_rbo_at_paper_defaults(paper_stream, name):
    """Summarized replay tracks the exact replay at (r, n, Δ) = (.2, 1, .1)."""
    algo = ALGORITHMS[name]()
    knobs = dict(node_capacity=5000, edge_capacity=40000, r=0.2, n=1,
                 delta=0.1)
    approx = veilgraph.session(paper_stream, algo, **knobs)
    exact = veilgraph.session(paper_stream, algo,
                              on_query=always(Action.EXACT), **knobs)
    for ra, re_ in zip(approx.play(), exact.play()):
        active = np.asarray(approx.engine.state.node_active)
        rbo = rbo_from_scores(ra.scores, re_.scores, depth=1000,
                              active=active)
        assert not ra.stats.overflow_fallback
        assert 0 < ra.stats.num_hot < ra.stats.num_nodes
        assert rbo >= 0.95, (name, ra.stats.query_id, rbo)


def test_registry_round_trip():
    listed = set(available_algorithms())
    assert {"pagerank", "personalized-pagerank", "hits"} <= listed
    assert "ppr" not in listed  # aliases resolve but are not listed
    assert isinstance(make_algorithm("ppr"), PersonalizedPageRankAlgorithm)
    a = make_algorithm("personalized-pagerank", seeds=(1, 2), beta=0.9)
    assert isinstance(a, PersonalizedPageRankAlgorithm)
    assert a.seeds == (1, 2) and a.beta == 0.9
    # instances pass through untouched
    assert make_algorithm(a) is a
    with pytest.raises(ValueError):
        make_algorithm(a, beta=0.5)
    with pytest.raises(KeyError):
        make_algorithm("no-such-algorithm")
    # custom registration: latest wins, visible through the session builder
    register_algorithm("custom-pr", lambda **kw: PageRankAlgorithm(**kw))
    assert "custom-pr" in available_algorithms()
    b = make_algorithm("custom-pr", beta=0.5)
    assert isinstance(b, PageRankAlgorithm) and b.beta == 0.5
    # legacy knobs forward through a **kwargs factory in the session builder
    src = np.asarray([0, 1, 2], np.int32)
    dst = np.asarray([1, 2, 0], np.int32)
    with veilgraph.session((src, dst), "custom-pr", num_iters=5) as s:
        assert s.algorithm.num_iters == 5


def test_algorithms_are_jit_static():
    """Frozen dataclasses: equal configs hash equal (shared jit caches)."""
    assert hash(PageRankAlgorithm(beta=0.9)) == hash(PageRankAlgorithm(beta=0.9))
    assert PageRankAlgorithm() != HITSAlgorithm()
    assert isinstance(PageRankAlgorithm(), StreamingAlgorithm)


def test_session_front_door(static_graph):
    src, dst = static_graph
    with veilgraph.session((src, dst), "pagerank", tol=1e-6) as s:
        r0 = s.query()
        assert r0.action == "compute-approximate"
        assert r0.scores.shape[0] == s.engine.config.node_capacity
        assert len(r0.top(7)) == 7
        s.add_edges([0, 1], [5, 6])
        r1 = s.query()
        assert r1.stats.pending_applied == 2
    # per-algorithm param routing through the builder
    s2 = veilgraph.session((src, dst), "ppr", seeds=(3,), num_iters=40)
    assert s2.algorithm.seeds == (3,)
    assert s2.algorithm.num_iters == 40
    # explicit config + overrides is an error
    with pytest.raises(ValueError):
        veilgraph.session((src, dst), "pagerank",
                          EngineConfig(10, 10, 10, 10), r=0.5)
    with pytest.raises(KeyError):
        veilgraph.session("no-such-dataset")
    # legacy knobs must reach the algorithm or fail loudly, never silently
    # configure nothing (beta/num_iters/tol are also EngineConfig fields)
    with pytest.raises(ValueError, match="already-constructed"):
        veilgraph.session((src, dst), HITSAlgorithm(), num_iters=50)
    with pytest.raises(ValueError, match="does not accept"):
        veilgraph.session((src, dst), "hits", beta=0.9)
    # forwarded algorithm knobs coexist with an explicit config
    s3 = veilgraph.session((src, dst), "hits",
                           EngineConfig(1000, 8192, 1000, 8192), num_iters=5)
    assert s3.algorithm.num_iters == 5
    with pytest.raises(ValueError):
        veilgraph.session((src, dst), "ppr", seeds=(-1,))


def test_session_stream_source(static_graph):
    src, dst = static_graph
    stream = build_stream(src, dst, StreamConfig(stream_size=200,
                                                 num_queries=2, seed=3))
    s = veilgraph.session(stream, "pagerank", tol=1e-6)
    results = list(s.play())
    assert len(results) == 2
    assert all(r.stats.action == "compute-approximate" for r in results)
    # sessions built from raw edges have no stream to play
    with pytest.raises(ValueError):
        next(veilgraph.session((src, dst)).play())


def test_query_view_refreshed_after_updates(static_graph):
    """OnQuery must see post-update node/edge counts (stale-view fix)."""
    src, dst = static_graph
    seen = {}

    def spy(query_id, view):
        seen.update(view)
        return Action.REPEAT_LAST

    eng = VeilGraphEngine(_cfg(1000, 8192), on_query=spy)
    eng.start(src, dst)
    e0 = int(eng.state.num_live_edges())
    # fresh vertices 900/901 so both node and edge counts must move
    eng.register_add_edges([900], [901])
    eng.query()
    assert seen["num_edges"] == e0 + 1
    assert seen["num_nodes"] == int(eng.state.num_active_nodes())
    assert seen["pending"] == 0 and seen["applied"] == 1


def test_repeat_last_staleness_accumulates(static_graph):
    """Updates integrated under repeat-last answers keep counting toward
    policy thresholds until a compute happens."""
    from repro.core.policies import repeat_below_threshold

    src, dst = static_graph
    eng = VeilGraphEngine(_cfg(1000, 8192, tol=1e-6),
                          on_query=repeat_below_threshold(25))
    eng.start(src, dst)
    actions = []
    for _ in range(4):
        eng.register_add_edges([0] * 10, list(range(10, 20)))
        _, st = eng.query()
        actions.append(st.action)
    # 10, 20 stale -> repeat; 30 crosses the threshold -> approximate;
    # counter resets -> 10 stale -> repeat again
    assert actions == ["repeat-last-answer", "repeat-last-answer",
                       "compute-approximate", "repeat-last-answer"]


def test_hits_rank_by_validated():
    with pytest.raises(ValueError):
        HITSAlgorithm(rank_by="authority")
    assert HITSAlgorithm(rank_by="hub").rank_by == "hub"


def test_removal_accounting_reports_resolved(static_graph):
    """Removals that match no live edge are requested but never resolved."""
    src, dst = static_graph
    eng = VeilGraphEngine(_cfg(1000, 8192, tol=1e-6))
    eng.start(src, dst)
    # two live edges + two that don't exist
    rm_s = np.array([src[0], src[1], 998, 999], np.int32)
    rm_d = np.array([dst[0], dst[1], 999, 998], np.int32)
    eng.register_remove_edges(rm_s, rm_d)
    assert eng.pending_updates == 4
    _, st = eng.query()
    assert st.removals_requested == 4
    assert st.removals_resolved == 2
    assert st.pending_applied == 2  # only what actually changed the graph
    assert eng.pending_updates == 0

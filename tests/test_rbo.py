"""RBO metric: known values + hypothesis properties."""

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.metrics.rbo import rbo_extrapolated, rbo_from_scores


def test_identity_is_one():
    assert rbo_extrapolated([1, 2, 3, 4], [1, 2, 3, 4]) == 1.0


def test_disjoint_is_zero():
    assert rbo_extrapolated([1, 2, 3], [4, 5, 6]) == 0.0


def test_empty_lists():
    assert rbo_extrapolated([], []) == 1.0
    assert rbo_extrapolated([1], []) == 0.0


def test_same_set_different_order_below_one():
    v = rbo_extrapolated([1, 2, 3, 4], [4, 3, 2, 1], p=0.9)
    assert 0.0 < v < 1.0


def test_top_weightedness():
    """Disagreement at the top hurts more than at the bottom."""
    base = list(range(20))
    swap_top = [1, 0] + base[2:]
    swap_bottom = base[:-2] + [base[-1], base[-2]]
    v_top = rbo_extrapolated(base, swap_top, p=0.9)
    v_bottom = rbo_extrapolated(base, swap_bottom, p=0.9)
    assert v_bottom > v_top


def test_known_value_two_lists():
    # S=[a,b], T=[b,a], p=0.5: A_1=0, A_2=1 -> (1-p)*A_1*p^0 + A_2*p^1 = 0.5
    assert abs(rbo_extrapolated(["a", "b"], ["b", "a"], p=0.5) - 0.5) < 1e-12


@settings(max_examples=50, deadline=None)
@given(
    perm_seed=st.integers(0, 2**16),
    n=st.integers(1, 60),
    p=st.floats(0.1, 0.99),
)
def test_bounds_and_symmetry(perm_seed, n, p):
    rng = np.random.default_rng(perm_seed)
    a = rng.permutation(n).tolist()
    b = rng.permutation(n).tolist()
    v1 = rbo_extrapolated(a, b, p=p)
    v2 = rbo_extrapolated(b, a, p=p)
    assert 0.0 <= v1 <= 1.0 + 1e-12
    assert abs(v1 - v2) < 1e-12  # symmetric


def test_rbo_from_scores_ranks_by_value():
    a = np.array([0.1, 0.9, 0.5, 0.7])
    b = np.array([0.2, 0.8, 0.4, 0.6])  # same induced ranking
    assert rbo_from_scores(a, b, depth=4) == 1.0


def test_rbo_from_scores_active_mask():
    a = np.array([9.0, 0.1, 0.2, 0.3])
    b = np.array([0.0, 0.1, 0.2, 0.3])  # vertex 0 differs wildly but inactive
    active = np.array([False, True, True, True])
    assert rbo_from_scores(a, b, depth=3, active=active) == 1.0

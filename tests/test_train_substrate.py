"""Training substrate: optimizer, checkpoint/restart, FT, data, compression."""

import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import DataConfig, Prefetcher, SyntheticLMData
from repro.train.checkpoint import CheckpointManager
from repro.train.compression import (compressed_mean, compression_ratio,
                                     dequantize, init_error_state, quantize)
from repro.train.fault_tolerance import (LoopConfig, RestartableLoop,
                                         StepTimer, elastic_reshard)
from repro.train.optimizer import (adamw_init, adamw_update,
                                   clip_by_global_norm, cosine_schedule,
                                   global_norm)


# ---------------------------------------------------------------------- adam
def test_adamw_decreases_quadratic():
    params = {"w": jnp.array([3.0, -2.0]), "b": jnp.array(1.5)}
    state = adamw_init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2) + p["b"] ** 2
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state = adamw_update(g, state, params, jnp.float32(0.05),
                                     weight_decay=0.0)
    assert float(loss(params)) < 1e-2
    assert int(state.step) == 200


def test_clip_by_global_norm():
    g = {"a": jnp.ones(4) * 3.0, "b": jnp.ones(2) * 4.0}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5
    assert float(norm) > 1.0
    same, _ = clip_by_global_norm(g, 1e9)
    np.testing.assert_allclose(same["a"], g["a"])


def test_cosine_schedule():
    lr = cosine_schedule(1e-3, warmup=10, total=100)
    assert float(lr(jnp.int32(0))) == 0.0
    assert abs(float(lr(jnp.int32(10))) - 1e-3) < 1e-9
    assert float(lr(jnp.int32(100))) < 1e-5


# ----------------------------------------------------------------- checkpoint
def test_checkpoint_save_restore_roundtrip(tmp_path):
    ckpt = CheckpointManager(tmp_path, keep_last_k=2, async_save=False)
    tree = {"w": jnp.arange(12.0).reshape(3, 4),
            "nested": {"b": jnp.ones(5, jnp.int32)},
            "step": jnp.int32(7)}
    ckpt.save(3, tree)
    assert ckpt.latest_step() == 3
    restored = ckpt.restore(3, jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), tree))
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)),
        tree, restored)


def test_checkpoint_gc_keeps_last_k(tmp_path):
    ckpt = CheckpointManager(tmp_path, keep_last_k=2, async_save=False)
    tree = {"w": jnp.ones(3)}
    for s in (1, 2, 3, 4):
        ckpt.save(s, tree)
    assert ckpt.all_steps() == [3, 4]


def test_checkpoint_async_and_wait(tmp_path):
    ckpt = CheckpointManager(tmp_path, async_save=True)
    tree = {"w": jnp.ones((128, 128))}
    ckpt.save(1, tree)
    ckpt.wait()
    assert ckpt.latest_step() == 1


def test_checkpoint_uncommitted_invisible(tmp_path):
    ckpt = CheckpointManager(tmp_path, async_save=False)
    tree = {"w": jnp.ones(3)}
    ckpt.save(5, tree)
    # simulate a torn write: remove the commit marker
    (tmp_path / "step_00000005.COMMITTED").unlink()
    assert ckpt.latest_step() is None
    with pytest.raises(FileNotFoundError):
        ckpt.restore(5, tree)


def test_elastic_reshard_to_new_sharding(tmp_path):
    """Checkpoint saved unsharded restores under an explicit sharding."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    ckpt = CheckpointManager(tmp_path, async_save=False)
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    ckpt.save(1, tree)
    mesh = jax.make_mesh((1,), ("data",), devices=jax.devices()[:1])
    sh = {"w": NamedSharding(mesh, P("data", None))}
    out = elastic_reshard(ckpt, 1, tree, sh)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(tree["w"]))
    assert out["w"].sharding == sh["w"]


# ------------------------------------------------------------ fault tolerance
def test_step_timer_flags_stragglers():
    t = StepTimer(ema_alpha=0.5, outlier_factor=2.0)
    for i in range(5):
        assert not t.record(i, 0.1)
    assert t.record(5, 0.5)      # 5x the EMA -> straggler
    assert t.outliers == [5]
    assert t.summary()["outliers"] == 1


def test_restartable_loop_retries_and_resumes(tmp_path):
    ckpt = CheckpointManager(tmp_path, async_save=False)
    cfg = LoopConfig(total_steps=7, checkpoint_every=2, max_step_retries=2,
                     log_every=0)
    loop = RestartableLoop(ckpt, cfg, log=lambda s: None)
    fails = {"n": 0}

    def step_fn(state, step):
        if step == 3 and fails["n"] < 1:
            fails["n"] += 1
            raise RuntimeError("transient")
        return {"w": state["w"] + 1.0}

    out = loop.run({"w": jnp.zeros(2)}, step_fn)
    assert float(out["w"][0]) == 7.0
    assert fails["n"] == 1
    # resume: latest checkpoint exists, new loop starts past it
    loop2 = RestartableLoop(ckpt, cfg, log=lambda s: None)
    assert loop2.resume_step() > 0


def test_restartable_loop_raises_after_retries(tmp_path):
    ckpt = CheckpointManager(tmp_path, async_save=False)
    cfg = LoopConfig(total_steps=3, checkpoint_every=0, max_step_retries=1,
                     log_every=0)
    loop = RestartableLoop(ckpt, cfg, log=lambda s: None)

    def bad(state, step):
        raise RuntimeError("permanent")

    with pytest.raises(RuntimeError):
        loop.run({"w": jnp.zeros(1)}, bad)


# ----------------------------------------------------------------------- data
def test_synthetic_data_deterministic_and_learnable():
    cfg = DataConfig(vocab_size=64, seq_len=32, global_batch=4, seed=1, lag=2)
    ds = SyntheticLMData(cfg, host_batch=4)
    b1 = ds.batch_at(5)
    b2 = ds.batch_at(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # labels shift tokens by one
    np.testing.assert_array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])
    # the lag structure is present: token t == token t-2 mostly
    t = b1["tokens"]
    frac = (t[:, 2:] == t[:, :-2]).mean()
    assert frac > 0.8


def test_prefetcher_yields_in_order():
    it = iter(range(10))
    pf = Prefetcher(it, depth=3)
    out = [next(pf) for _ in range(10)]
    assert out == list(range(10))


# ---------------------------------------------------------------- compression
def test_quantize_dequantize_error_bounded():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(1000,)).astype(np.float32))
    q, s = quantize(g)
    back = dequantize(q, s, g.shape, g.size)
    err = np.abs(np.asarray(back - g))
    # max error per block is scale/2 = max|g|/254 per block
    assert err.max() < float(jnp.abs(g).max()) / 100
    assert compression_ratio() < 0.26


def test_compressed_mean_with_error_feedback():
    mesh = jax.make_mesh((1,), ("data",), devices=jax.devices()[:1])
    rng = np.random.default_rng(1)
    g = {"w": jnp.asarray(rng.normal(size=(1, 512)).astype(np.float32))}
    err = init_error_state(g)
    mean, new_err = compressed_mean(g, err, mesh, axis="data")
    # single rank: mean ~= g up to int8 quantization
    np.testing.assert_allclose(np.asarray(mean["w"]), np.asarray(g["w"]),
                               atol=float(jnp.abs(g["w"]).max()) / 100)
    # error feedback: err + sent == original
    resent = np.asarray(mean["w"][0] + new_err["w"][0])
    np.testing.assert_allclose(resent, np.asarray(g["w"][0]), rtol=1e-5,
                               atol=1e-6)

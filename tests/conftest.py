"""Test-suite bootstrap: make the optional ``hypothesis`` dependency soft.

Six tier-1 modules import hypothesis at module scope; without this shim the
whole suite dies at collection on machines that only have the core
requirements.  The real package wins when installed."""

import importlib.util
import pathlib
import sys

try:
    import hypothesis  # noqa: F401  (real package present — use it)
except ImportError:
    _shim_path = pathlib.Path(__file__).parent / "_hypothesis_compat.py"
    _spec = importlib.util.spec_from_file_location(
        "_hypothesis_compat", _shim_path)
    _mod = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_mod)
    _mod.install()


# Very long single-process runs (the suite is 380+ tests, most of which
# jit-compile fresh programs) can crash XLA's CPU JIT once the live
# executable count grows past a few thousand — a segfault inside
# backend_compile near the end of the run, with every module passing in
# isolation.  Dropping JAX's compilation caches between modules keeps the
# resident executable set bounded without changing any test semantics
# (each module recompiles what it needs).
import jax
import pytest

# Strict dtype promotion for the whole suite: implicit cross-kind
# promotions (f32 + python int is fine; f32 + i32 array is not) raise
# instead of silently widening.  The hot path is f32/bf16-accumulate by
# contract — the jaxpr lint (JXP-F64/JXP-WIDEN64) catches wide dtypes
# structurally, and strict promotion catches the habits that create them
# at the source level.  See docs/analysis.md.
jax.config.update("jax_numpy_dtype_promotion", "strict")


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_per_module():
    yield
    jax.clear_caches()

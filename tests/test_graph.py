"""Graph substrate: streaming updates, degrees, capacities."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.graph import graph as G


def _rand_edges(rng, n_nodes, m):
    src = rng.integers(0, n_nodes, m).astype(np.int32)
    dst = rng.integers(0, n_nodes, m).astype(np.int32)
    return src, dst


def test_from_edges_basic():
    src = np.array([0, 1, 2, 0], np.int32)
    dst = np.array([1, 2, 0, 2], np.int32)
    g = G.from_edges(src, dst, node_capacity=8, edge_capacity=16)
    assert int(g.num_edges) == 4
    assert int(g.num_live_edges()) == 4
    assert int(g.num_active_nodes()) == 3
    np.testing.assert_array_equal(np.asarray(g.out_deg)[:4], [2, 1, 1, 0])
    np.testing.assert_array_equal(np.asarray(g.in_deg)[:4], [1, 1, 2, 0])


def test_from_edges_capacity_checks():
    with pytest.raises(ValueError):
        G.from_edges(np.zeros(10, np.int32), np.zeros(10, np.int32), 4, 5)
    with pytest.raises(ValueError):
        G.from_edges(np.array([9], np.int32), np.array([0], np.int32), 4, 5)


@settings(max_examples=25, deadline=None)
@given(
    n_init=st.integers(0, 40),
    n_add=st.integers(1, 40),
    seed=st.integers(0, 2**16),
)
def test_incremental_degrees_match_recompute(n_init, n_add, seed):
    """Property: incrementally-maintained degrees equal a full recount."""
    rng = np.random.default_rng(seed)
    n_nodes = 16
    s0, d0 = _rand_edges(rng, n_nodes, n_init)
    g = G.from_edges(s0, d0, node_capacity=n_nodes, edge_capacity=128)
    s1, d1 = _rand_edges(rng, n_nodes, n_add)
    g = G.add_edges(g, jnp.asarray(s1), jnp.asarray(d1))
    out_ref, in_ref = G.recompute_degrees(g)
    np.testing.assert_array_equal(np.asarray(g.out_deg), np.asarray(out_ref))
    np.testing.assert_array_equal(np.asarray(g.in_deg), np.asarray(in_ref))


def test_add_edges_beyond_capacity_drops():
    g = G.from_edges(np.array([0], np.int32), np.array([1], np.int32), 4, 3)
    g = G.add_edges(g, jnp.array([1, 2, 3], jnp.int32), jnp.array([0, 0, 0], jnp.int32))
    assert int(g.num_edges) == 3       # capped at capacity
    assert int(g.num_live_edges()) == 3
    # the dropped edge (3->0) must not contribute to degrees
    assert int(np.asarray(g.out_deg)[3]) == 0


def test_remove_edges_tombstones():
    src = np.array([0, 1, 2], np.int32)
    dst = np.array([1, 2, 0], np.int32)
    g = G.from_edges(src, dst, 4, 8)
    slots = G.find_edge_slots(g, np.array([1]), np.array([2]))
    assert slots[0] == 1
    g = G.remove_edges_by_slot(g, jnp.asarray(slots))
    assert int(g.num_live_edges()) == 2
    assert int(np.asarray(g.out_deg)[1]) == 0
    assert int(np.asarray(g.in_deg)[2]) == 0
    # double removal is a no-op
    g = G.remove_edges_by_slot(g, jnp.asarray(slots))
    assert int(g.num_live_edges()) == 2
    assert int(np.asarray(g.out_deg)[1]) == 0


def test_compact_reclaims_tombstones():
    src = np.array([0, 1, 2], np.int32)
    dst = np.array([1, 2, 0], np.int32)
    g = G.from_edges(src, dst, 4, 8)
    g = G.remove_edges_by_slot(g, jnp.array([0], jnp.int32))
    g2 = G.compact(g)
    assert int(g2.num_edges) == 2
    out_ref, in_ref = G.recompute_degrees(g2)
    np.testing.assert_array_equal(np.asarray(g2.out_deg), np.asarray(out_ref))


def test_networkx_roundtrip():
    rng = np.random.default_rng(0)
    src, dst = _rand_edges(rng, 20, 50)
    g = G.from_edges(src, dst, 20, 64)
    nxg = G.to_networkx(g)
    # COO may contain duplicate edges; networkx dedupes
    uniq = {(int(a), int(b)) for a, b in zip(src, dst)}
    assert nxg.number_of_edges() == len(uniq)

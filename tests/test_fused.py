"""Pin the fused query step's overflow fallback contract.

``approximate_query_step`` computes the summarized result unconditionally
and reports capacity overflow in ``stats.used_fallback`` — the caller's
side of the contract (the engine's) is to *discard* the summarized ranks
and recompute exactly.  No test exercised the overflow leg of the fused
path before; both legs are pinned here against exact PageRank.
"""

import jax.numpy as jnp
import numpy as np

from repro.core.fused import approximate_query_step
from repro.core.pagerank import pagerank
from repro.graph import from_edges
from repro.graph.generators import gnm_edges

TOL = dict(rtol=1e-5, atol=1e-6)


def _fixture(n=300, m=2000, seed=0):
    src, dst = gnm_edges(n, m, seed=seed)
    g = from_edges(src, dst, n, m + 64)
    ranks, _ = pagerank(g, num_iters=8)
    return g, ranks, jnp.copy(g.out_deg), jnp.copy(g.node_active)


def test_overflow_sets_used_fallback_and_caller_recomputes_exact():
    g, ranks, deg, act = _fixture()
    # a zero degree snapshot marks every active vertex as changed
    # -> |K| = all active >> capacity 16
    new_ranks, stats = approximate_query_step(
        g, ranks, jnp.zeros_like(deg), act, jnp.float32(0.0),
        jnp.float32(0.1), hot_node_capacity=16, hot_edge_capacity=64,
        num_iters=8)
    assert bool(stats.used_fallback)
    assert int(stats.num_hot) > 16
    # the summarized ranks were still computed (overflow does not branch on
    # device) and stay well-formed — but the caller must discard them and
    # serve the exact recompute (the engine leg below pins that end to end)
    assert new_ranks.shape == ranks.shape
    assert bool(jnp.all(jnp.isfinite(new_ranks)))


def test_no_overflow_with_full_capacities_matches_exact():
    """At full coverage (hot set = every active vertex, r=0) the summarized
    sweep must reproduce exact PageRank — the non-overflow leg."""
    g, ranks, deg, act = _fixture(seed=1)
    new_ranks, stats = approximate_query_step(
        g, ranks, jnp.zeros_like(deg), act, jnp.float32(0.0),
        jnp.float32(0.1), hot_node_capacity=g.node_capacity,
        hot_edge_capacity=g.edge_capacity, num_iters=30, tol=1e-7)
    assert not bool(stats.used_fallback)
    assert int(stats.num_hot) == int(g.num_active_nodes())
    exact, _ = pagerank(g, num_iters=30, tol=1e-7)
    np.testing.assert_allclose(np.asarray(new_ranks), np.asarray(exact),
                               **TOL)


def test_engine_discards_summarized_state_on_fused_overflow():
    """Engine-side of the contract through the fused path: capacities
    exceeded -> overflow_fallback recorded and the served ranks are the
    exact recomputation, not the truncated summarized state."""
    import repro

    src, dst = gnm_edges(250, 1500, seed=2)
    with repro.session((src, dst), algorithm="pagerank", num_iters=12,
                       hot_node_capacity=8, hot_edge_capacity=32,
                       r=0.0, delta=1e-6, fused=True) as s:
        assert s.engine.config.fused
        s.add_edges([0, 1, 2], [3, 4, 5])
        res = s.query()
        assert res.stats.overflow_fallback
        assert res.action == "compute-approximate"
        exact, _ = pagerank(s.engine.state, num_iters=12)
        np.testing.assert_allclose(res.scores, np.asarray(exact), **TOL)

"""Hot-set selection: Eqs. 2-5 semantics and monotonicity properties."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.graph import graph as G
from repro.graph.generators import barabasi_albert_edges, gnm_edges
from repro.core.hotset import select_hot_set
from repro.core.pagerank import pagerank


def _setup(seed=0, n=150, m=3):
    src, dst = barabasi_albert_edges(n, m, seed=seed)
    g = G.from_edges(src, dst, n + 50, 4096)
    r0, _ = pagerank(g, num_iters=10)
    return g, r0, src, dst


def test_kr_ratio_threshold_semantics():
    """K_r contains exactly the vertices whose degree ratio exceeds r."""
    g, r0, src, dst = _setup()
    deg_prev = jnp.copy(g.out_deg)
    # add edges so some sources change out-degree
    new_src = jnp.array([0, 0, 0, 5, 5], jnp.int32)
    new_dst = jnp.array([10, 11, 12, 13, 14], jnp.int32)
    g2 = G.add_edges(g, new_src, new_dst)
    r = 0.25
    hot, stats = select_hot_set(
        g2, deg_prev, r0, jnp.float32(r), jnp.float32(1e9), n=0, delta_hop_cap=0
    )
    # with n=0 and delta never matching, hot == K_r
    dp = np.asarray(deg_prev)
    dn = np.asarray(g2.out_deg)
    active = np.asarray(g2.node_active)
    expect = active & (
        ((dp == 0) & active) | ((dp > 0) & (np.abs(dn / np.maximum(dp, 1) - 1.0) > r))
    )
    np.testing.assert_array_equal(np.asarray(hot), expect)


def test_new_vertices_always_in_kr():
    g, r0, _, _ = _setup()
    deg_prev = jnp.copy(g.out_deg)
    fresh = g.node_capacity - 1  # id never used before
    g2 = G.add_edges(g, jnp.array([fresh], jnp.int32), jnp.array([0], jnp.int32))
    hot, _ = select_hot_set(
        g2, deg_prev, r0, jnp.float32(1e9), jnp.float32(1e9), n=0, delta_hop_cap=0
    )
    assert bool(np.asarray(hot)[fresh])


def test_zero_prior_degree_vertices_audit():
    """Zero-prior-degree audit pins: the ratio test divides by deg_prev,
    which is 0 both for brand-new vertices and for pre-existing
    zero-out-degree sinks.  Both paths must be division-free and
    r-independent: a brand-new vertex is hot at ANY r (including inf);
    a pre-existing sink that *gains* degree is hot at any r; one whose
    degree stays zero is never selected; and r = inf selects nothing
    through the ratio branch (finite ratio, no NaN comparisons)."""
    zeros = jnp.zeros(8, jnp.float32)

    def base():
        src = np.array([0, 0], np.int32)  # 0→1, 0→2; 1 and 2 are sinks
        dst = np.array([1, 2], np.int32)
        return G.from_edges(src, dst, 8, 16)

    for r in (0.0, 1e9, np.inf):
        # brand-new vertex: unconditionally hot, nothing valid to freeze
        g = base()
        deg_prev = jnp.copy(g.out_deg)
        active_prev = jnp.copy(g.node_active)
        fresh = 6
        g2 = G.add_edges(g, jnp.array([fresh], jnp.int32),
                         jnp.array([0], jnp.int32))
        hot, _ = select_hot_set(
            g2, deg_prev, zeros, jnp.float32(r), jnp.float32(1e9),
            active_prev=active_prev, n=0, delta_hop_cap=0)
        hot = np.asarray(hot)
        assert hot[fresh], r
        # unchanged vertices (incl. the zero-degree sinks): never selected,
        # even at r = 0 (the threshold is strict) or r = inf (finite ratio)
        assert not hot[0] and not hot[1] and not hot[2], r

        # pre-existing sink gains its first out-edge: 0 → >0 degree is a
        # change at any threshold — the deg_prev == 0 branch, not a ratio
        g = base()
        deg_prev = jnp.copy(g.out_deg)
        active_prev = jnp.copy(g.node_active)
        g2 = G.add_edges(g, jnp.array([2], jnp.int32),
                         jnp.array([0], jnp.int32))
        hot, _ = select_hot_set(
            g2, deg_prev, zeros, jnp.float32(r), jnp.float32(1e9),
            active_prev=active_prev, n=0, delta_hop_cap=0)
        hot = np.asarray(hot)
        assert hot[2], r
        assert not hot[1], r  # the other sink's degree stayed 0: cold


def test_kn_expansion_follows_out_edges():
    # tiny chain: 0 -> 1 -> 2 -> 3
    src = np.array([0, 1, 2], np.int32)
    dst = np.array([1, 2, 3], np.int32)
    g = G.from_edges(src, dst, 8, 16)
    r0, _ = pagerank(g, num_iters=5)
    # out-degree snapshot at t-1: vertex 3 is a sink (deg 0) but existed
    deg_prev = jnp.asarray(np.array([1, 1, 1, 0, 0, 0, 0, 0], np.int32))
    active_prev = jnp.asarray(np.array([1, 1, 1, 1, 0, 0, 0, 0], bool))
    # grow vertex 0's out-degree 1 -> 2 (ratio 1.0 > r)
    g2 = G.add_edges(g, jnp.array([0], jnp.int32), jnp.array([2], jnp.int32))
    for n_hops, expect_hot in [(0, {0}), (1, {0, 1, 2}), (2, {0, 1, 2, 3})]:
        hot, _ = select_hot_set(
            g2, deg_prev, r0, jnp.float32(0.5), jnp.float32(1e9),
            active_prev=active_prev, n=n_hops, delta_hop_cap=0,
        )
        got = set(np.nonzero(np.asarray(hot))[0].tolist())
        assert got == expect_hot, (n_hops, got)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**10))
def test_monotonic_in_r(seed):
    """Property: larger r (stricter threshold) never grows K_r."""
    g, r0, src, dst = _setup(seed=seed % 4)
    deg_prev = jnp.copy(g.out_deg)
    rng = np.random.default_rng(seed)
    ns = rng.integers(0, 150, 30).astype(np.int32)
    nd = rng.integers(0, 150, 30).astype(np.int32)
    g2 = G.add_edges(g, jnp.asarray(ns), jnp.asarray(nd))
    sizes = []
    for r in (0.05, 0.2, 0.5):
        _, stats = select_hot_set(
            g2, deg_prev, r0, jnp.float32(r), jnp.float32(1e9), n=0, delta_hop_cap=0
        )
        sizes.append(int(stats.num_kr))
    assert sizes[0] >= sizes[1] >= sizes[2]


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**10))
def test_monotonic_in_n_and_delta(seed):
    """Larger n grows K; smaller Δ (more conservative) never shrinks K."""
    g, r0, src, dst = _setup(seed=seed % 4)
    deg_prev = jnp.copy(g.out_deg)
    rng = np.random.default_rng(seed)
    ns = rng.integers(0, 150, 20).astype(np.int32)
    nd = rng.integers(0, 150, 20).astype(np.int32)
    g2 = G.add_edges(g, jnp.asarray(ns), jnp.asarray(nd))
    h0, s0 = select_hot_set(g2, deg_prev, r0, jnp.float32(0.2), jnp.float32(1e9), n=0)
    h1, s1 = select_hot_set(g2, deg_prev, r0, jnp.float32(0.2), jnp.float32(1e9), n=1)
    assert int(s1.num_hot) >= int(s0.num_hot)
    assert bool(np.all(~np.asarray(h0) | np.asarray(h1)))  # h0 ⊆ h1
    _, sd_small = select_hot_set(g2, deg_prev, r0, jnp.float32(0.2), jnp.float32(0.01), n=1)
    _, sd_big = select_hot_set(g2, deg_prev, r0, jnp.float32(0.2), jnp.float32(0.9), n=1)
    assert int(sd_small.num_hot) >= int(sd_big.num_hot)


def test_hot_subset_of_active():
    g, r0, _, _ = _setup(seed=1)
    deg_prev = jnp.zeros(g.node_capacity, jnp.int32)  # everything "new"
    hot, _ = select_hot_set(g, deg_prev, r0, jnp.float32(0.1), jnp.float32(0.1), n=1)
    assert bool(np.all(~np.asarray(hot) | np.asarray(g.node_active)))

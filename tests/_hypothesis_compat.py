"""Minimal stand-in for the optional ``hypothesis`` dependency.

The tier-1 suite uses a small slice of hypothesis (``given`` / ``settings``
/ ``strategies.integers|floats|sampled_from``).  When the real package is
absent, ``conftest.py`` installs this module under ``sys.modules
["hypothesis"]`` so the property-test modules still *collect and run* —
each ``@given`` test executes a small, deterministic set of examples drawn
from a PRNG seeded by the test name (no shrinking, no example database).

Install the real thing for full property-based coverage::

    pip install -r requirements-test.txt
"""

from __future__ import annotations

import functools
import inspect
import random
import sys
import types
import zlib

#: examples per @given test. The real hypothesis defaults to 100 and the
#: suite's @settings ask for 8-30; the shim caps lower — it is a collection
#: unblocker, not a property-testing engine.
MAX_EXAMPLES = 5


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng: random.Random):
        return self._draw(rng)


def integers(min_value, max_value):
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def floats(min_value, max_value):
    return _Strategy(lambda rng: rng.uniform(min_value, max_value))


def sampled_from(elements):
    seq = list(elements)
    return _Strategy(lambda rng: rng.choice(seq))


def booleans():
    return _Strategy(lambda rng: rng.random() < 0.5)


def settings(*args, **kwargs):
    """Decorator shim: records max_examples (clamped to MAX_EXAMPLES)."""
    max_examples = kwargs.get("max_examples")

    def deco(fn):
        if max_examples is not None:
            fn._shim_max_examples = min(int(max_examples), MAX_EXAMPLES)
        return fn

    return deco


def given(*arg_strategies, **kw_strategies):
    if arg_strategies:
        raise NotImplementedError(
            "hypothesis shim supports keyword strategies only")

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_shim_max_examples", MAX_EXAMPLES)
            # deterministic per-test seed, stable across runs/processes
            seed = zlib.crc32(fn.__qualname__.encode())
            rng = random.Random(seed)
            for _ in range(n):
                drawn = {k: s.draw(rng) for k, s in kw_strategies.items()}
                fn(*args, **kwargs, **drawn)

        # pytest must not see the strategy-filled parameters (it would hunt
        # for fixtures with those names): expose the residual signature and
        # drop __wrapped__ so introspection stops at the wrapper.
        params = [p for name, p in inspect.signature(fn).parameters.items()
                  if name not in kw_strategies]
        wrapper.__signature__ = inspect.Signature(params)
        del wrapper.__wrapped__
        return wrapper

    return deco


def install() -> None:
    """Register this shim as ``hypothesis`` (+ ``hypothesis.strategies``)."""
    hyp = types.ModuleType("hypothesis")
    hyp.__doc__ = __doc__
    hyp.given = given
    hyp.settings = settings
    hyp.HealthCheck = types.SimpleNamespace(too_slow=None, data_too_large=None)

    st = types.ModuleType("hypothesis.strategies")
    st.integers = integers
    st.floats = floats
    st.sampled_from = sampled_from
    st.booleans = booleans
    hyp.strategies = st

    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st

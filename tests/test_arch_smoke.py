"""Per-architecture smoke tests (assignment deliverable f).

Each assigned architecture instantiates a REDUCED same-family config and runs
a real forward + one train step on CPU, asserting output shapes and absence
of NaNs.  Full configs are exercised only via the dry-run.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models.config import MoEConfig, SHAPES
from repro.models.params import abstract_params, init_params, param_count_actual
from repro.models.transformer import lm_decode_step, lm_forward, lm_prefill
from repro.train.optimizer import adamw_init
from repro.train.step import make_serve_step, make_train_step

B, S = 2, 32


def _batch(cfg, key):
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    labels = jax.random.randint(jax.random.PRNGKey(9), (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": labels}
    if cfg.frontend == "vision":
        batch["patch_embeds"] = 0.02 * jax.random.normal(
            key, (B, cfg.frontend_len, cfg.d_model), jnp.float32)
        batch["labels"] = jnp.concatenate(
            [labels, jax.random.randint(key, (B, cfg.frontend_len), 0,
                                        cfg.vocab_size)], 1)[:, :S]
    if cfg.encoder_layers > 0:
        batch["frames"] = 0.02 * jax.random.normal(
            key, (B, 16, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward(arch):
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    batch = _batch(cfg, key)
    kw = {}
    if cfg.frontend == "vision":
        kw["prefix_embeds"] = batch["patch_embeds"]
    if cfg.encoder_layers > 0:
        kw["encoder_embeds"] = batch["frames"]
    logits = lm_forward(params, cfg, batch["tokens"], **kw)
    extra = cfg.frontend_len if cfg.frontend == "vision" else 0
    assert logits.shape == (B, S + extra, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), "NaN/Inf in logits"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(1)
    params = init_params(key, cfg)
    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg, learning_rate=1e-3, remat=True))
    batch = _batch(cfg, key)
    p1, o1, m1 = step(params, opt, batch)
    assert bool(jnp.isfinite(m1["loss"])), "NaN loss"
    assert float(m1["loss"]) > 0
    # parameters actually moved
    moved = jax.tree_util.tree_map(
        lambda a, b: float(jnp.abs(a - b).max()), params, p1)
    assert max(jax.tree_util.tree_leaves(moved)) > 0
    # a second step decreases loss on the SAME batch (sanity of the update)
    p2, o2, m2 = step(p1, o1, batch)
    assert float(m2["loss"]) < float(m1["loss"])


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_matches_forward(arch):
    cfg = get_smoke_config(arch)
    if cfg.moe is not None:  # disable capacity drops for exactness
        cfg = dataclasses.replace(
            cfg, moe=MoEConfig(cfg.moe.num_experts, cfg.moe.top_k, 8.0))
    key = jax.random.PRNGKey(2)
    params = init_params(key, cfg)
    batch = _batch(cfg, key)
    kw = {}
    prefix = 0
    if cfg.frontend == "vision":
        kw["prefix_embeds"] = batch["patch_embeds"]
        prefix = cfg.frontend_len
    if cfg.encoder_layers > 0:
        kw["encoder_embeds"] = batch["frames"]
    extra = jax.random.randint(jax.random.PRNGKey(3), (B, 2), 0, cfg.vocab_size)
    toks_full = jnp.concatenate([batch["tokens"], extra], axis=1)
    logits_full = lm_forward(params, cfg, toks_full, **kw)
    logits_pre, cache = lm_prefill(params, cfg, batch["tokens"],
                                   cache_len=S + prefix + 4, **kw)
    scale = float(jnp.abs(logits_full).max())
    tol = 0.05 * max(scale, 1.0)  # bf16 accumulation-order differences
    assert float(jnp.abs(logits_pre - logits_full[:, : S + prefix]).max()) < tol
    for i in range(2):
        lg, cache = lm_decode_step(params, cfg, cache, extra[:, i:i + 1],
                                   jnp.int32(S + prefix + i))
        err = float(jnp.abs(lg[:, 0] - logits_full[:, S + prefix + i]).max())
        assert err < tol, (i, err, tol)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_abstract_params(arch):
    """Full configs build their parameter trees abstractly (no allocation)."""
    cfg = get_config(arch)
    tree = abstract_params(cfg)
    n = sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(tree))
    assert n == param_count_actual(cfg)
    # sanity vs published sizes (±25%)
    expected = {
        "yi_9b": 8.8e9, "minicpm3_4b": 4.1e9, "qwen2_0_5b": 0.49e9,
        "granite_34b": 34e9, "zamba2_7b": 7.3e9,
        "seamless_m4t_large_v2": 2.3e9, "mixtral_8x22b": 141e9,
        "dbrx_132b": 132e9, "mamba2_2_7b": 2.7e9, "internvl2_2b": 1.9e9,
    }[arch]
    assert 0.75 * expected < n < 1.25 * expected, (n, expected)


def test_sliding_window_ring_cache():
    """Mixtral-style SWA: decode beyond the window keeps a bounded cache and
    matches a full forward restricted to the window."""
    cfg = get_smoke_config("mixtral_8x22b")
    cfg = dataclasses.replace(
        cfg, moe=MoEConfig(cfg.moe.num_experts, cfg.moe.top_k, 8.0),
        sliding_window=16)
    key = jax.random.PRNGKey(4)
    params = init_params(key, cfg)
    s_total = 40  # > window
    toks = jax.random.randint(key, (B, s_total), 0, cfg.vocab_size)
    logits_full = lm_forward(params, cfg, toks)
    logits_pre, cache = lm_prefill(params, cfg, toks[:, :-1], cache_len=64)
    assert cache["kv"]["k"].shape[2] == 16  # ring bounded by window
    lg, cache = lm_decode_step(params, cfg, cache, toks[:, -1:],
                               jnp.int32(s_total - 1))
    scale = float(jnp.abs(logits_full).max())
    err = float(jnp.abs(lg[:, 0] - logits_full[:, -1]).max())
    assert err < 0.05 * max(scale, 1.0), err


def test_long_context_flags():
    from repro.configs import get_config
    assert get_config("mamba2_2_7b").supports_long_context
    assert get_config("zamba2_7b").supports_long_context
    assert get_config("mixtral_8x22b").supports_long_context  # SWA
    assert not get_config("yi_9b").supports_long_context
    assert not get_config("dbrx_132b").supports_long_context


def test_shapes_table():
    assert SHAPES["train_4k"].seq_len == 4096
    assert SHAPES["long_500k"].global_batch == 1
    assert SHAPES["decode_32k"].kind == "decode"

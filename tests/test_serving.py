"""Serving suite: batched ``[B, N]`` propagation parity + the slot engine.

The contract (see docs/serving.md):

- batched ``push`` over a ``[B, N]`` value matrix == the stack of B
  single-vector pushes, per registered semiring × backend, on replicated
  *and* sharded layouts — **bitwise** for the min-reduce semirings (min
  is reassociation-exact), to f32 summation order otherwise;
- every registered algorithm's ``summarized_batched`` == its per-query
  ``summarized`` loop over one shared summary structure (bitwise for the
  min-semiring workloads), with ``row_mask`` freezing masked rows;
- the :class:`~repro.serve.graph.GraphServingEngine` serves ≥ 2× its
  slot count of mixed concurrent queries through one shared graph and
  answers identically to per-query sessions (PPR allclose, SSSP
  bitwise), refilling slots as uneven convergence frees them;
- streamed *weighted* edges reach SSSP through the serving front door;
- summary overflow degrades to per-row exact recomputes, never crashes.

Run under ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` the
sharded cases drive the real ``shard_map`` path; on one device they
cover the shard-loop reference path, same assertions.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

import repro
from repro.core import backend as B
from repro.core.algorithm import StreamingAlgorithm, make_algorithm
from repro.core.hits import hits, summarized_hits
from repro.core.pagerank import build_summary
from repro.core.semiring import resolve_semiring
from repro.graph import from_edges
from repro.graph.generators import gnm_edges
from repro.graph.partition import build_sharded_layout
from repro.serve.graph import GraphServingEngine

TOL = dict(rtol=1e-5, atol=1e-6)
BATCH = 3

#: every registered semiring × a weight mode it supports (mirrors
#: test_sharded's coverage — the batched path must not narrow it)
SEMIRING_WEIGHTS = [
    ("plus_times", "inv_out"),
    ("plus_times", "unit"),
    ("min_plus", "length"),
    ("min_min", "unit"),
    ("max_times", "unit"),
]
#: reduces for which batching must be bitwise (reassociation-exact ⊕)
BITWISE_ADDS = ("min",)

ALGORITHMS = ("pagerank", "personalized-pagerank", "hits", "katz",
              "connected-components", "sssp", "widest-path")
#: min/max-semiring workloads: batched vs looped must be bitwise
BITWISE_ALGOS = ("connected-components", "sssp", "widest-path")


def _mesh(max_devices: int = 8) -> Mesh:
    n = min(jax.device_count(), max_devices)
    return Mesh(np.asarray(jax.devices()[:n]), ("shards",))


def _graph(n=150, m=900, seed=0):
    src, dst = gnm_edges(n, m, seed=seed)
    return from_edges(src, dst, n, m + 64)


def _batch_values(semiring, n, batch=BATCH, seed=0):
    s = resolve_semiring(semiring)
    rng = np.random.default_rng(seed)
    if np.issubdtype(s.np_dtype, np.floating):
        return jnp.asarray(rng.random((batch, n)).astype(s.np_dtype))
    return jnp.asarray(rng.integers(0, n, (batch, n)).astype(s.np_dtype))


def _assert_rows_match(out, ref, semiring_or_bitwise):
    if isinstance(semiring_or_bitwise, bool):
        bitwise = semiring_or_bitwise
    else:
        bitwise = resolve_semiring(semiring_or_bitwise).add in BITWISE_ADDS
    assert out.dtype == ref.dtype
    assert out.shape == ref.shape
    if bitwise:
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    else:
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL)


# ---------------------------------------------------------------------------
# layer 1: batched push parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("semiring,weight", SEMIRING_WEIGHTS)
@pytest.mark.parametrize("backend", ("segment_sum", "pallas"))
def test_push_batched_parity(semiring, weight, backend):
    """[B, N] push == the stack of B single pushes (bitwise for min)."""
    g = _graph()
    layout = B.build_layout(g, weight=weight, semiring=semiring)
    vals = _batch_values(semiring, g.node_capacity)
    out = B.push(vals, layout, semiring=semiring, backend=backend)
    ref = jnp.stack([
        B.push(vals[i], layout, semiring=semiring, backend=backend)
        for i in range(BATCH)])
    _assert_rows_match(out, ref, semiring)


@pytest.mark.parametrize("semiring,weight", SEMIRING_WEIGHTS)
def test_push_batched_parity_sharded(semiring, weight):
    """Batched push over a ShardedEdgeLayout == batched replicated push
    == stacked single sharded pushes."""
    g = _graph(seed=1)
    mesh = _mesh()
    layout_s = build_sharded_layout(
        g, mesh=mesh, num_shards=mesh.devices.size,
        weight=weight, semiring=semiring)
    layout_r = B.build_layout(g, weight=weight, semiring=semiring)
    vals = _batch_values(semiring, g.node_capacity, seed=1)
    out = B.push(vals, layout_s, semiring=semiring)
    _assert_rows_match(
        out, B.push(vals, layout_r, semiring=semiring), semiring)
    ref = jnp.stack([
        B.push(vals[i], layout_s, semiring=semiring) for i in range(BATCH)])
    _assert_rows_match(out, ref, semiring)


def test_push_batched_rejects_3d():
    g = _graph()
    layout = B.build_layout(g)
    with pytest.raises(ValueError, match=r"\[N\] or \[B, N\]"):
        B.push(jnp.ones((2, 2, g.node_capacity)), layout)


# ---------------------------------------------------------------------------
# layer 2: batched summarized sweeps vs the per-query loop
# ---------------------------------------------------------------------------


def _instances(name, batch=BATCH):
    """B algorithm instances differing only in per-query identity."""
    if name == "personalized-pagerank":
        return [make_algorithm(name, seeds=(i,)) for i in range(batch)]
    if name in ("sssp", "widest-path"):
        return [make_algorithm(name, sources=(i,)) for i in range(batch)]
    return [make_algorithm(name)] * batch


def _rows(insts, g, name):
    """Per-query state rows; float states perturbed per row so identical
    instances still exercise genuinely different batch rows."""
    rows = []
    for i, inst in enumerate(insts):
        row = inst.init_state(g)
        if name not in ("personalized-pagerank", "sssp",
                        "connected-components", "widest-path"):
            row = {k: v * (1.0 + 0.05 * i) for k, v in row.items()}
        rows.append(row)
    return rows


def _stack(rows):
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *rows)


@pytest.mark.parametrize("name", ALGORITHMS)
def test_summarized_batched_parity(name):
    """Batched sweep over one shared summary == the B-way per-query loop
    (bitwise for the min-semiring workloads), and row_mask freezes rows."""
    g = _graph(seed=2)
    caps = dict(hot_node_capacity=g.node_capacity,
                hot_edge_capacity=g.edge_capacity)
    insts = _instances(name)
    rows = _rows(insts, g, name)
    batch_state = _stack(rows)
    algo = insts[0]
    algo.validate_batch_state(batch_state, BATCH)
    hot = jnp.copy(g.node_active)

    summaries_b = algo.build_summaries(batch_state, g, hot, **caps)
    out_b, _, row_delta = algo.summarized_batched(
        batch_state, g, summaries_b)
    assert row_delta.shape == (BATCH,)
    bitwise = name in BITWISE_ALGOS
    for i, (inst, row) in enumerate(zip(insts, rows)):
        summaries_i = inst.build_summaries(row, g, hot, **caps)
        out_i, _ = inst.summarized(row, g, summaries_i)
        for key in out_i:
            _assert_rows_match(out_b[key][i], out_i[key], bitwise)

    # masked rows carry through unchanged and report zero delta
    mask = jnp.asarray([True, False, True])
    out_m, _, delta_m = algo.summarized_batched(
        batch_state, g, summaries_b, row_mask=mask)
    for key in out_m:
        np.testing.assert_array_equal(
            np.asarray(out_m[key][1]), np.asarray(batch_state[key][1]))
        _assert_rows_match(out_m[key][0], out_b[key][0], bitwise)
    assert float(delta_m[1]) == 0.0


@pytest.mark.parametrize("name", ("pagerank", "sssp"))
def test_summarized_batched_parity_sharded(name):
    """Batched-vs-looped parity holds over mesh-sharded layouts (the
    distributed-bucket-sort summary construction) — bitwise for SSSP."""
    g = _graph(seed=3)
    mesh = _mesh()
    caps = dict(hot_node_capacity=g.node_capacity,
                hot_edge_capacity=g.edge_capacity)
    insts = _instances(name)
    rows = _rows(insts, g, name)
    batch_state = _stack(rows)
    algo = insts[0]
    hot = jnp.copy(g.node_active)
    layouts = tuple(
        build_sharded_layout(g, mesh=mesh, num_shards=mesh.devices.size,
                             weight=w, reverse=rev, semiring=s)
        for (w, rev, s) in map(B.normalize_layout_spec, algo.layout_specs))

    summaries_b = algo.build_summaries(
        batch_state, g, hot, **caps, layouts=layouts)
    out_b, _, _ = algo.summarized_batched(batch_state, g, summaries_b)
    bitwise = name in BITWISE_ALGOS
    for i, (inst, row) in enumerate(zip(insts, rows)):
        summaries_i = inst.build_summaries(row, g, hot, **caps,
                                           layouts=layouts)
        out_i, _ = inst.summarized(row, g, summaries_i)
        for key in out_i:
            _assert_rows_match(out_b[key][i], out_i[key], bitwise)


def test_validate_batch_state_rejects():
    g = _graph()
    algo = make_algorithm("sssp", sources=(0,))
    bank = _stack([algo.init_state(g)] * 2)
    algo.validate_batch_state(bank, 2)  # well-formed
    with pytest.raises(ValueError, match="missing declared keys"):
        algo.validate_batch_state(
            {k: v for k, v in bank.items() if k != "dist"}, 2)
    with pytest.raises(ValueError, match="dtype"):
        bad = dict(bank, dist=jnp.zeros_like(bank["dist"], jnp.int32))
        algo.validate_batch_state(bad, 2)
    with pytest.raises(ValueError, match="leading batch axis"):
        algo.validate_batch_state(bank, 3)


# ---------------------------------------------------------------------------
# layer 3: the serving engine
# ---------------------------------------------------------------------------


def _serve(graph_source, **kw):
    return repro.serve_session(graph_source, **kw)


def test_serving_mixed_tenants_match_sessions():
    """A slot-4 engine drains 14 concurrent queries (3.5× its slots) —
    10 PPR seed sets + 4 SSSP sources — through ONE shared graph, and
    every answer matches a dedicated single-query session: allclose for
    PPR, bitwise for SSSP."""
    n, m = 150, 900
    src, dst = gnm_edges(n, m, seed=4)
    srv = _serve((src, dst), slots=4)
    ppr = [srv.submit("personalized-pagerank", seeds=(s,))
           for s in range(10)]
    sssp = [srv.submit("sssp", sources=(s,)) for s in range(4)]
    assert srv.pending == 14
    stats = srv.run()
    assert srv.pending == 0
    assert stats.queries_submitted == stats.queries_completed == 14
    assert stats.waves >= 3          # 10 queries through 4 slots
    assert 0.0 < stats.mean_occupancy <= 1.0
    assert stats.queries_per_s > 0.0
    assert stats.p95_wave_latency_s >= stats.p50_wave_latency_s > 0.0

    for s, t in enumerate(ppr):
        # default tickets complete by wave budget (one summarized sweep,
        # like engine.query()); `converged` stays False unless the inner
        # delta actually reached tol
        assert t.done and not t.exact_fallback
        with repro.session((src, dst), "personalized-pagerank",
                           seeds=(s,)) as ref:
            np.testing.assert_allclose(
                np.asarray(t.result), np.asarray(ref.query().scores), **TOL)
    for s, t in enumerate(sssp):
        assert t.done and t.converged
        with repro.session((src, dst), "sssp", sources=(s,)) as ref:
            np.testing.assert_array_equal(
                np.asarray(t.result), np.asarray(ref.query().scores))
    srv.close()


def test_uneven_convergence_refills_slots():
    """Two slots, three SSSP queries of very different depths on a
    64-vertex path: the shallow query converges and frees its slot for
    the queued one while the deep query keeps iterating — per-slot
    convergence masking, not lane-wide barriers."""
    n = 64
    src = np.arange(n - 1, dtype=np.int32)
    dst = src + 1
    srv = _serve((src, dst), slots=2)
    near = srv.submit("sssp", sources=(62,), num_iters=2, max_waves=200)
    far = srv.submit("sssp", sources=(0,), num_iters=2, max_waves=200)

    while not near.done:
        srv.step()
    assert not far.done              # the deep query is still in its slot
    extra = srv.submit("sssp", sources=(50,), num_iters=2, max_waves=200)
    srv.run()

    for t in (near, far, extra):
        assert t.done and t.converged and not t.exact_fallback
    # two relaxations per wave: depth 1 needs 2 waves (second detects
    # convergence), depth 63 needs ~32, depth 13 ~7 — and the refilled
    # query's wave count proves it started after `near` freed the slot
    assert near.waves_run < extra.waves_run < far.waves_run
    assert float(near.result[63]) == 1.0
    assert float(extra.result[63]) == 13.0
    assert float(far.result[63]) == 63.0
    srv.close()


def test_streamed_weighted_edges_reach_sssp():
    """A weighted add_edges chunk through the serving front door lands in
    the length layouts: the streamed 2.5-length edge completes the
    0→…→3→4 path at distance 5.5."""
    src = np.asarray([0, 1, 2, 4], np.int32)
    dst = np.asarray([1, 2, 3, 0], np.int32)
    srv = _serve((src, dst), slots=2, edge_capacity=16)
    srv.add_edges([3], [4], weights=[2.5])
    t = srv.submit("sssp", sources=(0,))
    srv.run()
    assert t.done and t.converged
    assert float(t.result[4]) == 5.5
    srv.close()


def test_overflow_falls_back_to_exact():
    """Summary capacity too small for the cold-start wave: the batch
    result is discarded and every live query is served by a per-row
    exact recompute — graceful degradation, correct answers."""
    src, dst = gnm_edges(100, 800, seed=5)
    srv = _serve((src, dst), slots=2, hot_node_capacity=128,
                 hot_edge_capacity=16)
    t = srv.submit("personalized-pagerank", seeds=(7,))
    srv.run()
    assert t.done and t.exact_fallback and not t.converged
    assert srv.stats.overflow_fallbacks >= 1
    with repro.session((src, dst), "personalized-pagerank",
                       seeds=(7,)) as ref:
        np.testing.assert_allclose(
            np.asarray(t.result), np.asarray(ref.query().scores), **TOL)
    srv.close()


def test_serve_stats_empty_and_single_sample_guards():
    """ServeStats aggregates are total functions: a fresh (or idle)
    engine reports zeros instead of dividing by zero, and a single
    sample is its own p50 and p95."""
    from repro.serve.graph import ServeStats

    empty = ServeStats()
    assert empty.queries_per_s == 0.0
    assert empty.mean_occupancy == 0.0
    assert empty.p50_wave_latency_s == 0.0
    assert empty.p95_wave_latency_s == 0.0

    one = ServeStats(queries_completed=1, waves=1, wall_s=0.25,
                     occupancy_sum=0.5, wave_latencies_s=[0.25])
    assert one.p50_wave_latency_s == 0.25
    assert one.p95_wave_latency_s == 0.25
    assert one.queries_per_s == 4.0
    assert one.mean_occupancy == 0.5

    # a wave too fast for the clock to resolve must not divide by zero
    zero_wall = ServeStats(queries_completed=3, waves=1, wall_s=0.0)
    assert zero_wall.queries_per_s == 0.0


def test_serve_stats_nearest_rank_quantiles():
    """Nearest-rank pins: with 20 samples 0.01..0.20, p95 is the 19th
    order statistic (0.19), NOT the maximum — the old ``int(q * len)``
    rank read element 19 (p100).  q is clamped into [0, 1]."""
    from repro.serve.graph import ServeStats

    lat = [round(0.01 * k, 2) for k in range(20, 0, -1)]  # unsorted
    s = ServeStats(wave_latencies_s=lat)
    assert s.p95_wave_latency_s == 0.19
    assert s.p50_wave_latency_s == 0.10
    assert s._latency_quantile(0.0) == 0.01
    assert s._latency_quantile(1.0) == 0.20
    assert s._latency_quantile(-3.0) == 0.01   # clamped
    assert s._latency_quantile(7.0) == 0.20    # clamped


def test_seed_local_cold_start_covers_only_reachable():
    """Cold-start coverage is seed-local, not graph-global: an SSSP row
    whose source sits in a 10-vertex component hot-covers exactly that
    component's forward reachability, while a global algorithm
    (``batched_cold_seeds`` is None) still covers the full active set.
    Churn/hub selection is pinned off (r, Δ huge; n=0) so the measured
    hot count is the cold expansion alone."""
    from repro.core.fused import fused_query_step_batched

    path_s = np.arange(9, dtype=np.int32)          # component A: 0→1→…→9
    gs, gd = gnm_edges(120, 700, seed=4)           # component B: 20..139
    src = np.concatenate([path_s, gs.astype(np.int32) + 20])
    dst = np.concatenate([path_s + 1, gd.astype(np.int32) + 20])
    srv = _serve((src, dst), slots=2, n=0, r=1e9, delta=1e9)
    eng = srv.engine
    cfg = eng.config

    def cold_wave_hot_count(algo):
        bank = _stack([algo.init_state(eng.state)] * 2)
        _, qs, _ = fused_query_step_batched(
            eng.state, bank, eng.deg_prev, eng.active_prev,
            jnp.float32(cfg.r), jnp.float32(cfg.delta),
            jnp.asarray([True, True]), jnp.asarray([True, True]),
            eng._probe_ids,
            algo=algo, hot_node_capacity=cfg.hot_node_capacity,
            hot_edge_capacity=cfg.hot_edge_capacity, n=cfg.n,
            delta_hop_cap=cfg.delta_hop_cap, degree_mode=cfg.degree_mode,
            expand_both=cfg.expand_both, layouts=srv._spec_layouts(algo),
            backend=eng.backend,
            shard_bucket_capacity=cfg.shard_hot_edge_capacity)
        return int(qs.num_hot)

    n_active = int(jnp.sum(eng.state.node_active.astype(jnp.int32)))
    hot_sssp = cold_wave_hot_count(make_algorithm("sssp", sources=(0,)))
    hot_global = cold_wave_hot_count(make_algorithm("pagerank"))
    assert hot_sssp == 10            # exactly the source's component
    assert hot_global == n_active    # seedless → full active coverage
    assert hot_sssp < hot_global
    srv.close()


def test_submit_rejects_unbatched_algorithm():
    """Legacy plugins without ``summarized_batched`` are rejected at
    submit time, not at trace time mid-wave."""

    @dataclasses.dataclass(frozen=True)
    class NoBatch(StreamingAlgorithm):
        name = "nobatch"

        def init_state(self, graph):
            return {"x": jnp.zeros((graph.node_capacity,), jnp.float32)}

        def exact(self, state, graph, *, layouts=None, backend=None):
            return state, jnp.int32(0)

        def summarized(self, state, graph, summaries, *, backend=None):
            return state, jnp.int32(0)

        def result_view(self, state):
            return state["x"]

    src, dst = gnm_edges(50, 200, seed=6)
    srv = _serve((src, dst), slots=2)
    with pytest.raises(TypeError, match="summarized_batched"):
        srv.submit(NoBatch())
    with pytest.raises(ValueError, match="max_waves"):
        srv.submit("pagerank", max_waves=0)
    srv.close()


def test_wrapping_requires_started_engine():
    from repro.core.engine import EngineConfig, VeilGraphEngine

    eng = VeilGraphEngine(EngineConfig(
        node_capacity=8, edge_capacity=16,
        hot_node_capacity=8, hot_edge_capacity=16))
    with pytest.raises(ValueError, match="started"):
        GraphServingEngine(eng, slots=2)


def test_serving_on_mesh_with_shard_capacity_knob():
    """Serving composes with the sharded path: a mesh engine answers
    identically (bitwise for SSSP), and the post-exchange
    ``shard_hot_edge_capacity`` knob threads through — a generous cap
    changes nothing, a starved cap degrades to the exact fallback with
    correct answers."""
    src, dst = gnm_edges(120, 700, seed=7)
    mesh = _mesh()

    with repro.session((src, dst), "sssp", sources=(3,)) as ref:
        want = np.asarray(ref.query().scores)

    srv = _serve((src, dst), slots=2, mesh=mesh,
                 shard_hot_edge_capacity=4096)
    t = srv.submit("sssp", sources=(3,))
    srv.run()
    assert t.done and not t.exact_fallback
    np.testing.assert_array_equal(np.asarray(t.result), want)
    srv.close()

    srv = _serve((src, dst), slots=2, mesh=mesh, shard_hot_edge_capacity=2)
    t = srv.submit("sssp", sources=(3,))
    srv.run()
    assert t.done and t.exact_fallback
    assert srv.stats.overflow_fallbacks >= 1
    np.testing.assert_array_equal(np.asarray(t.result), want)
    srv.close()


# ---------------------------------------------------------------------------
# satellite: the tracked global-σ HITS estimate
# ---------------------------------------------------------------------------


def test_summarized_hits_full_coverage_matches_exact():
    """With K = V the cold mass is zero and the tracked σ̂ reduces to the
    exact sweep's global normalization."""
    g = _graph(seed=8)
    n = g.node_capacity
    auth0 = jnp.full((n,), 1.0 / n)
    hub0 = jnp.full((n,), 1.0 / n)
    caps = dict(hot_node_capacity=n, hot_edge_capacity=g.edge_capacity)
    hot = jnp.copy(g.node_active)
    fwd = build_summary(g, hub0, hot, **caps, weight="unit")
    rev = build_summary(g, auth0, hot, **caps, weight="unit", reverse=True)
    a, h, _, _ = summarized_hits(fwd, rev, auth0, hub0, num_iters=15)
    a_ref, h_ref, _, _ = hits(g, num_iters=15)
    np.testing.assert_allclose(np.asarray(a), np.asarray(a_ref), **TOL)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref), **TOL)


def test_summarized_hits_partial_coverage_sigma_counts_cold_mass():
    """Partial coverage: cold scores are a frozen Dirichlet boundary
    (bitwise unchanged outside K) and the σ̂ estimate — anchored to the
    σ the exact computation measured, extrapolating the boundary's raw
    mass — keeps the hot block *stationary* at the global fixed point.
    The pre-fix hot-only estimator pinned the hot/cold mass ratio
    instead of the scale, and a naive blend that counts the frozen cold
    mass without the σ extrapolation drifts linearly."""
    g = _graph(seed=9)
    n = g.node_capacity
    a_ref, h_ref, _, sigma = hits(g, num_iters=60, tol=1e-7)
    # warm start at the fixed point, then restrict to a half-graph hot
    # set: a well-scaled sweep should STAY at the fixed point
    hot = jnp.arange(n) < n // 2
    caps = dict(hot_node_capacity=n, hot_edge_capacity=g.edge_capacity)
    fwd = build_summary(g, h_ref, hot, **caps, weight="unit")
    rev = build_summary(g, a_ref, hot, **caps, weight="unit", reverse=True)
    a, h, _, sigma_out = summarized_hits(
        fwd, rev, a_ref, h_ref, sigma, num_iters=10)
    assert np.all(np.isfinite(np.asarray(a)))
    assert np.all(np.isfinite(np.asarray(h)))
    cold = ~np.asarray(hot)
    np.testing.assert_array_equal(
        np.asarray(a)[cold], np.asarray(a_ref)[cold])
    np.testing.assert_array_equal(
        np.asarray(h)[cold], np.asarray(h_ref)[cold])
    # anchored normalization: hot L1 mass stays where the warm start put
    # it (no drift against the frozen boundary), and the refreshed σ̂
    # stays pinned to the measured anchor
    hot_np = np.asarray(hot)
    for new, ref in ((a, a_ref), (h, h_ref)):
        m_new = float(jnp.sum(jnp.abs(new[hot_np])))
        m_ref = float(jnp.sum(jnp.abs(ref[hot_np])))
        assert 0.8 * m_ref < m_new < 1.25 * m_ref, (m_new, m_ref)
    np.testing.assert_allclose(np.asarray(sigma_out), np.asarray(sigma),
                               rtol=0.1)

"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode).

The container is CPU-only; ``interpret=True`` executes each kernel body in
Python with the same BlockSpec tiling the TPU backend would use, so tiling /
masking / accumulation logic is what is being validated.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.graph import from_edges
from repro.graph.generators import gnm_edges
from repro.graph.graph import inv_out_degree
from repro.kernels.decode_attention.kernel import decode_attention_kernel
from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.spmv.ops import pagerank_push
from repro.models.layers import _blocked_attention_ref, decode_attention


# ------------------------------------------------------------------ spmv
@pytest.mark.parametrize("n,m,seed", [(300, 2000, 0), (1024, 6000, 1),
                                      (257, 900, 2)])
def test_spmv_matches_segment_sum(n, m, seed):
    src, dst = gnm_edges(n, m, seed=seed)
    n_cap = ((n + 255) // 256) * 256
    g = from_edges(src, dst, n_cap, m + 64)
    ranks = jnp.asarray(
        np.random.default_rng(seed).random(n_cap).astype(np.float32))
    out = pagerank_push(g, ranks, interpret=True)
    emit = ranks * inv_out_degree(g)
    contrib = jnp.where(g.edge_mask(), emit[g.src], 0.0)
    ref = jax.ops.segment_sum(contrib, g.dst, num_segments=n_cap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-6, atol=1e-6)


def test_spmv_empty_graph():
    g = from_edges(np.zeros(0, np.int32), np.zeros(0, np.int32), 256, 64)
    out = pagerank_push(g, jnp.ones(256), interpret=True)
    assert float(jnp.abs(out).max()) == 0.0


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 1000), density=st.floats(0.001, 0.05))
def test_spmv_property_random_graphs(seed, density):
    rng = np.random.default_rng(seed)
    n = 256
    m = max(1, int(density * n * n))
    src = rng.integers(0, n, m).astype(np.int32)
    dst = rng.integers(0, n, m).astype(np.int32)
    g = from_edges(src, dst, n, m + 8)
    ranks = jnp.asarray(rng.random(n).astype(np.float32))
    out = pagerank_push(g, ranks, interpret=True)
    emit = ranks * inv_out_degree(g)
    contrib = jnp.where(g.edge_mask(), emit[g.src], 0.0)
    ref = jax.ops.segment_sum(contrib, g.dst, num_segments=n)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-6)


# ------------------------------------------------------- flash attention
SHAPE_SWEEP = [
    # B, S, H, KV, hd, vd, causal, window, dtype
    (2, 256, 8, 2, 64, 64, True, None, jnp.float32),
    (1, 192, 4, 4, 32, 32, True, 64, jnp.float32),      # MHA + window + pad
    (2, 128, 6, 2, 32, 16, False, None, jnp.bfloat16),  # MLA-ish vd != hd
    (1, 128, 16, 1, 64, 64, True, None, jnp.bfloat16),  # MQA (granite-like)
    (3, 64, 4, 2, 128, 128, True, None, jnp.float32),   # 128-dim heads
]


@pytest.mark.parametrize("b,s,h,kv,hd,vd,causal,window,dtype", SHAPE_SWEEP)
def test_flash_attention_sweep(b, s, h, kv, hd, vd, causal, window, dtype):
    ks = jax.random.split(jax.random.PRNGKey(hash((b, s, h)) % 2**31), 3)
    q = jax.random.normal(ks[0], (b, s, h, hd), dtype)
    k = jax.random.normal(ks[1], (b, s, kv, hd), dtype)
    v = jax.random.normal(ks[2], (b, s, kv, vd), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          q_block=64, kv_block=64, interpret=True)
    ref = _blocked_attention_ref(
        q, k, v, causal=causal, window=window, q_offset=0, kv_offset=0,
        kv_valid_len=None, q_block=64, kv_block=64, softmax_scale=hd ** -0.5)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        rtol=tol, atol=tol)


def test_flash_attention_exact_softmax_oracle():
    """Direct check against an unblocked full-softmax computation."""
    b, s, h, kv, hd = 1, 96, 4, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, kv, hd))
    v = jax.random.normal(ks[2], (b, s, kv, hd))
    g = h // kv
    qg = q.reshape(b, s, kv, g, hd)
    scores = jnp.einsum("bqkgd,bckd->bkgqc", qg, k) * hd ** -0.5
    mask = jnp.tril(jnp.ones((s, s), bool))
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    ref = jnp.einsum("bkgqc,bckd->bqkgd", p, v).reshape(b, s, h, hd)
    out = flash_attention(q, k, v, causal=True, q_block=32, kv_block=32,
                          interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


# ------------------------------------------------------ decode attention
@pytest.mark.parametrize("b,s,h,kv,hd,clen,dtype", [
    (2, 256, 8, 2, 64, 200, jnp.float32),
    (1, 512, 16, 1, 64, 512, jnp.bfloat16),   # MQA full cache
    (4, 128, 4, 4, 32, 77, jnp.float32),      # partial cache
])
def test_decode_attention_sweep(b, s, h, kv, hd, clen, dtype):
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (b, 1, h, hd), dtype)
    kc = jax.random.normal(ks[1], (b, s, kv, hd), dtype)
    vc = jax.random.normal(ks[2], (b, s, kv, hd), dtype)
    out = decode_attention_kernel(q, kc, vc, jnp.int32(clen), interpret=True)
    ref = decode_attention(q, kc, vc, cache_len=jnp.int32(clen))
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        rtol=tol, atol=tol)


def test_decode_attention_ignores_invalid_slots():
    """Cache contents beyond cache_len must not affect the output."""
    b, s, h, kv, hd = 1, 128, 4, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    q = jax.random.normal(ks[0], (b, 1, h, hd))
    kc = jax.random.normal(ks[1], (b, s, kv, hd))
    vc = jax.random.normal(ks[2], (b, s, kv, hd))
    out1 = decode_attention_kernel(q, kc, vc, jnp.int32(50), interpret=True)
    kc2 = kc.at[:, 50:].set(99.0)
    vc2 = vc.at[:, 50:].set(-99.0)
    out2 = decode_attention_kernel(q, kc2, vc2, jnp.int32(50), interpret=True)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               rtol=1e-6, atol=1e-6)

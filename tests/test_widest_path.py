"""Widest (most-reliable) path — the seventh registered algorithm.

max_times Bellman–Ford over edge reliabilities: sources pinned to width
1.0, unreached vertices 0.0 (never −∞, so 0-length edges cannot produce
−∞ · 0 NaNs).  Monotone non-decreasing under edge additions, so the
warm-started summarized sweep is exact on a full hot set, and ``max`` is
reassociation-exact, so backend parity is bitwise.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.algorithm import WidestPathAlgorithm, algorithm_factory
from repro.graph import from_edges
from repro.graph.generators import gnm_edges


def _ref_widest(n, src, dst, rel, sources, iters=80):
    w = np.zeros(n, np.float32)
    w[list(sources)] = 1.0
    for _ in range(iters):
        new = w.copy()
        np.maximum.at(new, dst, w[src] * rel)
        new[list(sources)] = 1.0
        if np.array_equal(new, w):
            break
        w = new
    return w


def _fixture(n=300, m=1800, seed=0):
    rng = np.random.default_rng(seed)
    src, dst = gnm_edges(n, m, seed=seed)
    rel = (rng.random(len(src)) * 0.9 + 0.05).astype(np.float32)
    g = from_edges(src, dst, n, len(src) + 64, weights=rel)
    return g, src, dst, rel


@pytest.mark.parametrize("backend", ["segment_sum", "pallas"])
def test_widest_path_exact_matches_reference(backend):
    from repro.core.traversal import widest_path

    g, src, dst, rel = _fixture()
    mask = jnp.zeros(300, bool).at[jnp.asarray([0, 7])].set(True)
    w, iters = widest_path(g, mask, num_iters=80, backend=backend)
    ref = _ref_widest(300, src, dst, rel, (0, 7))
    np.testing.assert_array_equal(np.asarray(w), ref)
    assert 0 < int(iters) <= 80


def test_widest_path_zero_reliability_edges_stay_finite():
    """0-weight edges must not poison anything (the −∞ encoding would)."""
    from repro.core.traversal import widest_path

    src = np.asarray([0, 1], np.int32)
    dst = np.asarray([1, 2], np.int32)
    rel = np.asarray([0.0, 0.5], np.float32)
    g = from_edges(src, dst, 8, 8, weights=rel)
    mask = jnp.zeros(8, bool).at[0].set(True)
    w, _ = widest_path(g, mask, num_iters=8)
    out = np.asarray(w)
    assert np.all(np.isfinite(out))
    assert out[0] == 1.0 and out[1] == 0.0 and out[2] == 0.0


def test_summarized_widest_path_full_hot_set_is_bitwise_exact():
    algo = WidestPathAlgorithm(sources=(0, 3), warm_start=True,
                               num_iters=80)
    g, src, dst, rel = _fixture(seed=3)
    st0 = algo.init_state(g)
    st, _ = algo.exact(st0, g)
    from repro.graph.graph import add_edges
    g2 = add_edges(g, jnp.asarray([0, 5, 9], jnp.int32),
                   jnp.asarray([250, 260, 270], jnp.int32),
                   jnp.asarray([0.9, 0.8, 0.7], jnp.float32))
    hot = jnp.copy(g2.node_active)
    summaries = algo.build_summaries(
        st, g2, hot, hot_node_capacity=300, hot_edge_capacity=2048)
    approx, _ = algo.summarized(st, g2, summaries)
    exact, _ = algo.exact(st, g2)
    # max is reassociation-exact: equality is bitwise
    np.testing.assert_array_equal(np.asarray(approx["width"]),
                                  np.asarray(exact["width"]))


def test_summarized_widest_path_batched_matches_single():
    import jax

    algo = WidestPathAlgorithm(sources=(0,), warm_start=True, num_iters=80)
    g, src, dst, rel = _fixture(seed=5)
    st0 = algo.init_state(g)
    st, _ = algo.exact(st0, g)
    hot = jnp.copy(g.node_active)
    summaries = algo.build_summaries(
        st, g, hot, hot_node_capacity=300, hot_edge_capacity=2048)
    single, _ = algo.summarized(st, g, summaries)

    batch_state = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), st, st)
    summaries_b = algo.build_summaries(
        batch_state, g, hot, hot_node_capacity=300, hot_edge_capacity=2048)
    out_b, _, row_delta = algo.summarized_batched(
        batch_state, g, summaries_b, row_mask=jnp.asarray([True, True]))
    assert row_delta.shape == (2,)
    for i in range(2):
        np.testing.assert_array_equal(np.asarray(out_b["width"][i]),
                                      np.asarray(single["width"]))


def test_widest_path_registered_with_alias():
    assert algorithm_factory("widest-path") is WidestPathAlgorithm
    assert algorithm_factory("most-reliable-path") is WidestPathAlgorithm
    algo = WidestPathAlgorithm()
    assert algo.semiring == "max_times"
    assert algo.per_query_params == ("sources",)


def test_widest_path_through_session_and_serving():
    from repro import api

    g, src, dst, rel = _fixture(seed=7)
    srv = api.serve_session((src, dst), slots=2, node_capacity=300,
                            edge_capacity=2048, hot_node_capacity=300,
                            hot_edge_capacity=2048)
    t1 = srv.submit("widest-path", sources=(0,), num_iters=80)
    t2 = srv.submit("widest-path", sources=(7,), num_iters=80)
    srv.run()
    ones = np.ones(len(src), np.float32)  # streamed edges carry unit lengths
    np.testing.assert_allclose(np.asarray(t1.result)[:300],
                               _ref_widest(300, src, dst, ones, (0,)),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(t2.result)[:300],
                               _ref_widest(300, src, dst, ones, (7,)),
                               rtol=1e-6, atol=1e-6)
    srv.close()

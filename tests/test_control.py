"""Closed accuracy loop (ISSUE 9): drift estimator + SLO controller.

The contract (see docs/performance.md "Closed-loop quality control"):

- :func:`repro.core.control.drift_signals` turns one fixed-point residual
  vector into two relative-error scalars (probe-sampled + frozen-outside-K)
  with hand-checkable arithmetic, ±∞ sentinels masked;
- the fused step's ``with_drift=True`` estimate *agrees with the offline
  exact error*: replaying the same update burst exactly and measuring
  ‖approx − exact‖₁/‖exact‖₁ lands within a small factor of the on-device
  estimate, and bigger bursts read bigger;
- :class:`~repro.core.control.QualityController` converges to the SLO on
  a drifting synthetic stream — measured rank quality (RBO vs the exact
  oracle) stays ≥ the target while summarized work stays strictly below
  the open-loop full-accuracy configuration (the acceptance numbers also
  recorded in BENCH_sweeps.json meta.controller) — and relaxes the knobs
  back when the stream quiets;
- batched serving under ``quality_target`` answers identically to
  per-query sessions (PPR allclose, SSSP bitwise — cold-start coverage is
  knob-independent);
- knob precedence: an explicitly passed ``r``/``delta`` is pinned; the
  controller only adjusts the knobs left to it.
"""

import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.api import Action
from repro.core import backend as B
from repro.core.algorithm import make_algorithm
from repro.core.control import (QualityController, default_probe_ids,
                                drift_signals)
from repro.core.fused import fused_query_step
from repro.graph import graph as G
from repro.graph.generators import gnm_edges
from repro.metrics.rbo import rbo_from_scores

ROOT = Path(__file__).resolve().parent.parent


# ---------------------------------------------------------------------------
# layer 1: the estimator primitives
# ---------------------------------------------------------------------------


def test_default_probe_ids_deterministic_and_bounded():
    p1 = default_probe_ids(1024, 64)
    p2 = default_probe_ids(1024, 64)
    np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))
    assert p1.shape == (64,) and p1.dtype == jnp.int32
    assert int(p1.min()) >= 0 and int(p1.max()) < 1024
    # more probes than vertices clamps to the vertex count
    small = default_probe_ids(16, 64)
    assert small.shape == (16,)
    assert len(set(np.asarray(small).tolist())) == 16


def test_drift_signals_hand_computed():
    """4-vertex fabricated residual: both scalars check by hand."""
    resid = jnp.asarray([0.1, 0.0, 0.3, 0.0], jnp.float32)
    result = jnp.asarray([1.0, 2.0, 1.0, 1.0], jnp.float32)
    hot = jnp.asarray([True, True, False, False])
    active = jnp.ones((4,), bool)
    probes = jnp.asarray([0, 2], jnp.int32)
    probe, cold = drift_signals(resid, result, hot, active, probes)
    # mass = 5.0; cold residual = 0.3 (vertex 2 is the only ~hot resid)
    np.testing.assert_allclose(float(cold), 0.3 / 5.0, rtol=1e-6)
    # probe mean = (0.1 + 0.3)/2 = 0.2, × n_active(4) / mass(5) = 0.16
    np.testing.assert_allclose(float(probe), 0.2 * 4 / 5.0, rtol=1e-6)


def test_drift_signals_count_normalize_and_inf_masking():
    """count-normalize divides by n_active (CC's 0/1 flips); ±∞ sentinel
    entries (unreachable SSSP distances) drop out of both sums."""
    resid = jnp.asarray([1.0, 0.0, 1.0, 5.0], jnp.float32)
    result = jnp.asarray([3.0, 7.0, 2.0, jnp.inf], jnp.float32)
    hot = jnp.asarray([True, False, False, False])
    active = jnp.ones((4,), bool)
    probes = jnp.asarray([0, 3], jnp.int32)
    probe, cold = drift_signals(resid, result, hot, active, probes,
                                normalize="count")
    # vertex 3 is non-finite: excluded everywhere.  cold = resid on
    # ~hot&finite vertices {1, 2} = 1.0, / n_active 4
    np.testing.assert_allclose(float(cold), 1.0 / 4.0, rtol=1e-6)
    # live probes: only vertex 0 (3 is masked) -> mean 1.0 × 4/4 = 1.0
    np.testing.assert_allclose(float(probe), 1.0, rtol=1e-6)


# ---------------------------------------------------------------------------
# layer 2: the controller policy (pure host floats)
# ---------------------------------------------------------------------------


def test_controller_validates_target():
    for bad in (0.0, 1.0, -0.5, 1.5):
        with pytest.raises(ValueError, match="quality_target"):
            QualityController(bad, r0=0.2, delta0=0.1)


def test_controller_tightens_relaxes_with_deadband():
    ctl = QualityController(0.95, r0=0.2, delta0=0.1)
    budget = 1.0 - 0.95
    # high drift (err above half budget): both knobs tighten
    ctl.observe(budget / ctl.gain, 0.0)
    assert ctl.r_eff < 0.2 and ctl.delta_eff < 0.1
    r_tight = ctl.r_eff
    # mid-band drift: deadband, no change
    mid = 0.3 * budget / ctl.gain
    ctl.accum = 0.0
    ctl.observe(mid, 0.0)
    assert ctl.r_eff == r_tight
    # quiet: relax back, clamped to the upper bound
    ctl.accum = 0.0
    for _ in range(100):
        ctl.accum = 0.0
        ctl.observe(0.0, 0.0)
    assert ctl.r_eff == ctl.r_bounds[1]


def test_controller_refresh_on_accumulated_cold_drift():
    """Frozen error compounds across observations until refreshed()."""
    ctl = QualityController(0.95, r0=0.2, delta0=0.1)
    per_query_cold = 0.004  # gain 3 -> breach after accum > 0.0167
    refreshed_at = None
    for i in range(20):
        dec = ctl.observe(0.0, per_query_cold)
        if dec.refresh:
            refreshed_at = i
            ctl.refreshed()
            break
    assert refreshed_at is not None and refreshed_at >= 2
    assert ctl.accum == 0.0 and ctl.refreshes == 1
    # post-refresh the loop starts clean: next observation doesn't breach
    assert not ctl.observe(0.0, per_query_cold).refresh


def test_controller_pinned_knobs_never_move():
    ctl = QualityController(0.95, r0=0.3, delta0=0.1,
                            adjust_r=False, adjust_delta=True)
    for _ in range(5):
        ctl.accum = 0.0
        ctl.observe(1.0, 0.0)       # massive drift
    assert ctl.r_eff == 0.3         # pinned
    assert ctl.delta_eff < 0.1      # free knob tightened


# ---------------------------------------------------------------------------
# layer 3: estimator agreement with offline exact error
# ---------------------------------------------------------------------------


def _drifted_step(burst, *, n=400, m=2500, seed=9):
    """Freeze everything (huge r/Δ) after a `burst`-edge update, return
    (on-device drift estimate, offline exact relative L1 error)."""
    algo = make_algorithm("pagerank")
    src, dst = gnm_edges(n, m, seed=seed)
    g = G.from_edges(src, dst, n, 8192)
    st, _ = algo.exact(algo.init_state(g), g)
    deg, act = jnp.copy(g.out_deg), jnp.copy(g.node_active)
    rng = np.random.default_rng(2)
    g2 = G.add_edges(
        g, jnp.asarray(rng.integers(0, n, burst), jnp.int32),
        jnp.asarray(rng.integers(0, n, burst), jnp.int32))
    layouts = tuple(
        B.build_layout(g2, weight=w, reverse=rev, semiring=s)
        for (w, rev, s) in map(B.normalize_layout_spec, algo.layout_specs))
    new_state, stats = fused_query_step(
        g2, st, deg, act, jnp.float32(1e9), jnp.float32(1e9),
        default_probe_ids(n, 64),
        algo=algo, hot_node_capacity=n, hot_edge_capacity=8192,
        layouts=layouts, with_drift=True)
    exact, _ = algo.exact(algo.init_state(g2), g2, layouts=layouts)
    a = np.asarray(algo.result_view(new_state))
    e = np.asarray(exact["ranks"])
    true_rel = float(np.abs(a - e).sum() / np.abs(e).sum())
    est = max(float(stats.drift_probe), float(stats.drift_cold))
    return est, true_rel


def test_drift_estimate_agrees_with_offline_error():
    """The one-sweep residual estimate lands within a small factor of the
    offline ‖approx − exact‖₁/‖exact‖₁ (measured ratios are 1.07–1.16
    across a 16× burst range; the bound leaves slack, not orders of
    magnitude), and is monotone in the burst size."""
    estimates, truths = [], []
    for burst in (30, 120, 480):
        est, true_rel = _drifted_step(burst)
        assert true_rel > 1e-3          # the burst genuinely drifted
        assert 0.5 * true_rel <= est <= 3.0 * true_rel
        estimates.append(est)
        truths.append(true_rel)
    assert estimates[0] < estimates[1] < estimates[2]
    assert truths[0] < truths[1] < truths[2]


def test_drift_near_zero_at_fixed_point():
    """No updates -> the exact state is the fixed point -> both drift
    scalars read ~0 for every supports-fused algorithm."""
    est, _ = _drifted_step(0)
    assert est < 1e-4


# ---------------------------------------------------------------------------
# layer 4: SLO convergence through the engine
# ---------------------------------------------------------------------------


def _drifting_stream(n, steps, chunk, seed=11):
    rng = np.random.default_rng(seed)
    return [(rng.integers(0, n, chunk).astype(np.int32),
             rng.integers(0, n, chunk).astype(np.int32))
            for _ in range(steps)]


def test_slo_convergence_on_drifting_stream():
    """quality_target=0.95 on a drifting stream: measured rank quality
    (RBO vs an exact-oracle replay) stays >= the target while summarized
    work stays strictly below the open-loop full-accuracy replay — the
    ISSUE 9 acceptance assertion, live."""
    n, m, steps, chunk = 600, 4_000, 4, 60
    src, dst = gnm_edges(n, m, seed=7)
    stream = _drifting_stream(n, steps, chunk)
    caps = dict(node_capacity=n, edge_capacity=m + steps * chunk + 1024)

    def replay(**kw):
        scores, work = [], []
        with repro.session((src, dst), algorithm="pagerank",
                           **caps, **kw) as s:
            for a, b in stream:
                s.add_edges(a, b)
                res = s.query()
                st = res.stats
                full = (st.action == "exact" or st.overflow_fallback
                        or st.refreshed)
                work.append(st.num_edges if full
                            else st.num_ek + st.num_eb)
                scores.append(np.asarray(res.scores))
        return scores, float(np.mean(work))

    exact, _ = replay(on_query=lambda qid, view: Action.EXACT)
    closed, w_closed = replay(quality_target=0.95)
    _, w_open = replay(r=0.0, delta=1e-6)

    quality = [float(rbo_from_scores(jnp.asarray(a), jnp.asarray(e),
                                     depth=100))
               for a, e in zip(closed, exact)]
    assert min(quality) >= 0.95
    assert w_closed < w_open            # strictly less summarized work


def test_quality_rises_after_forced_correction():
    """A near-1 target on a heavy stream forces refreshes; the refreshed
    query's answer is exact (RBO == 1 vs the oracle) — quality rises
    after correction."""
    n, m, steps, chunk = 300, 2_000, 5, 150
    src, dst = gnm_edges(n, m, seed=3)
    stream = _drifting_stream(n, steps, chunk, seed=5)
    caps = dict(node_capacity=n, edge_capacity=m + steps * chunk + 1024)

    with repro.session((src, dst), algorithm="pagerank",
                       quality_target=0.999, **caps) as s, \
         repro.session((src, dst), algorithm="pagerank",
                       on_query=lambda q, v: Action.EXACT, **caps) as oracle:
        hit = False
        for a, b in stream:
            s.add_edges(a, b)
            oracle.add_edges(a, b)
            res = s.query()
            ref = oracle.query()
            if res.stats.refreshed:
                hit = True
                assert res.stats.quality_est == 1.0
                np.testing.assert_allclose(
                    np.asarray(res.scores), np.asarray(ref.scores),
                    rtol=1e-5, atol=1e-7)
        assert hit                      # the tiny budget forced >= 1 refresh
        assert s.engine.controller.refreshes >= 1


def test_work_shrinks_when_stream_quiets():
    """Drift tightens the knobs; a quiet tail relaxes them back (less
    selection pressure -> the controller stops paying for accuracy it
    doesn't need)."""
    n, m = 400, 2_500
    src, dst = gnm_edges(n, m, seed=13)
    caps = dict(node_capacity=n, edge_capacity=8192)
    with repro.session((src, dst), algorithm="pagerank",
                       quality_target=0.95, **caps) as s:
        for a, b in _drifting_stream(n, 4, 120, seed=17):
            s.add_edges(a, b)
            s.query()
        r_tight = s.engine.controller.r_eff
        for _ in range(12):             # quiet: no updates at all
            s.query()
        assert s.engine.controller.r_eff > r_tight
        # quiet queries observe ~zero drift
        assert s.engine.stats_log[-1].drift < 1e-3


def test_knob_precedence_explicit_r_wins():
    src, dst = gnm_edges(200, 1200, seed=1)
    with repro.session((src, dst), quality_target=0.95, r=0.3,
                       edge_capacity=4096) as s:
        ctl = s.engine.controller
        assert not ctl.adjust_r and ctl.adjust_delta
        for a, b in _drifting_stream(200, 3, 80):
            s.add_edges(a, b)
            s.query()
        assert ctl.r_eff == 0.3         # pinned despite drift
    with repro.session((src, dst), quality_target=0.95,
                       edge_capacity=4096) as s:
        ctl = s.engine.controller
        assert ctl.adjust_r and ctl.adjust_delta


def test_quality_target_requires_fused():
    src, dst = gnm_edges(50, 200, seed=0)
    with pytest.raises(ValueError, match="quality_target"):
        repro.session((src, dst), quality_target=0.95, fused=False)


def test_exact_action_counts_as_refresh():
    """An EXACT policy decision resets the accumulated drift (the state
    is accurate again) and stamps the stats row."""
    src, dst = gnm_edges(100, 600, seed=2)
    actions = iter([Action.APPROXIMATE, Action.EXACT])
    with repro.session((src, dst), quality_target=0.95, edge_capacity=2048,
                       on_query=lambda q, v: next(actions)) as s:
        s.add_edges([1, 2], [3, 4])
        s.query()
        s.engine.controller.accum = 0.123
        s.add_edges([5, 6], [7, 8])
        res = s.query()
        assert res.stats.refreshed
        assert s.engine.controller.accum == 0.0


# ---------------------------------------------------------------------------
# layer 5: batched serving under the controller
# ---------------------------------------------------------------------------


def test_serving_parity_under_controller():
    """quality_target serving answers match dedicated per-query sessions
    (PPR allclose, SSSP bitwise) — cold-start seed-local coverage is
    knob-independent, so the controller cannot change first-wave
    answers."""
    n, m = 150, 900
    src, dst = gnm_edges(n, m, seed=4)
    srv = repro.serve_session((src, dst), slots=3, quality_target=0.95)
    ppr = [srv.submit("personalized-pagerank", seeds=(s,))
           for s in range(5)]
    sssp = [srv.submit("sssp", sources=(s,)) for s in range(3)]
    stats = srv.run()
    assert stats.queries_completed == 8
    assert stats.min_quality_est > 0.0
    for lane in srv._lanes.values():
        assert lane.controller is not None
        assert lane.controller.observations >= 1
    for s, t in enumerate(ppr):
        with repro.session((src, dst), "personalized-pagerank",
                           seeds=(s,)) as ref:
            np.testing.assert_allclose(
                np.asarray(t.result), np.asarray(ref.query().scores),
                rtol=5e-5, atol=1e-7)
    for s, t in enumerate(sssp):
        with repro.session((src, dst), "sssp", sources=(s,)) as ref:
            np.testing.assert_array_equal(
                np.asarray(t.result), np.asarray(ref.query().scores))
    srv.close()


def test_serving_refresh_remarks_slots_cold():
    """An SLO breach re-marks live slots cold and resets the loop.

    Live serving rows are *always* cold by design — the cold flag clears
    only on convergence, which also frees the slot — so every wave runs
    with seed-local full coverage and organic drift stays ~0 (pinned by
    the test below); the refresh path is a correctness backstop.  Drive
    it directly: pre-load the lane controller with accumulated frozen
    drift and verify the next wave performs the full refresh bookkeeping
    (stats row, cold re-marking, accumulator reset) while the
    long-running occupant keeps iterating to the exact answer."""
    n = 64
    src = np.arange(n - 1, dtype=np.int32)
    dst = src + 1
    srv = repro.serve_session((src, dst), slots=2, quality_target=0.9999)
    far = srv.submit("sssp", sources=(0,), num_iters=2, max_waves=200)
    srv.step()                          # wave 1 seats + runs the query
    assert not far.done
    (lane,) = srv._lanes.values()
    lane.controller.accum = 1.0         # simulated frozen-error debt
    srv.step()
    assert srv.stats.refreshes == 1
    assert lane.controller.accum == 0.0  # refreshed() paid the debt
    assert srv.stats.min_quality_est < 1.0
    assert all(c for c, t in zip(lane.cold, lane.tickets) if t is not None)
    srv.run()
    assert far.done and far.converged
    assert float(far.result[n - 1]) == float(n - 1)  # answer still exact
    srv.close()


def test_serving_organic_drift_stays_low_under_updates():
    """With seed-local cold coverage, every wave re-covers each live
    row's full relevant subgraph, so even a heavy mid-serve burst
    produces near-zero measured drift and *no* organic refresh — the
    coverage machinery, not the refresh backstop, absorbs the churn."""
    n = 200
    src, dst = gnm_edges(n, 1200, seed=6)
    srv = repro.serve_session((src, dst), slots=2, quality_target=0.9999,
                              edge_capacity=8192)
    tickets = [srv.submit("personalized-pagerank", seeds=(s,),
                          max_waves=6, tol=1e-9) for s in range(2)]
    srv.step()
    rng = np.random.default_rng(0)
    srv.add_edges(rng.integers(0, n, 400), rng.integers(0, n, 400))
    srv.run()
    assert all(t.done for t in tickets)
    assert srv.stats.refreshes == 0
    assert srv.stats.last_drift < 1e-3
    assert srv.stats.min_quality_est > 0.99
    srv.close()


# ---------------------------------------------------------------------------
# layer 6: the committed acceptance numbers
# ---------------------------------------------------------------------------


def test_bench_sweeps_records_controller_acceptance():
    """BENCH_sweeps.json carries the ISSUE 9 acceptance numbers: closed
    loop >= 95% measured rank quality with summarized work strictly
    below the open-loop full-accuracy configuration."""
    record = json.loads((ROOT / "BENCH_sweeps.json").read_text())
    ctl = record["meta"]["controller"]
    assert ctl["quality_target"] == 0.95
    assert ctl["quality"] >= 0.95
    assert ctl["work_per_query"] < ctl["openloop_work_per_query"]
    names = {row["name"] for row in record["rows"]}
    assert {"controller_closedloop_query",
            "controller_openloop_full_query"} <= names


# ---------------------------------------------------------------------------
# layer 7: per-workload gain calibration (drift_contraction)
# ---------------------------------------------------------------------------


def test_gain_resolution_precedence():
    """Explicit gain > declared contraction (1/(1-c)) > legacy 3.0, and
    a declared contraction outside [0, 1) is rejected."""
    kw = dict(r0=0.2, delta0=0.1)
    assert QualityController(0.95, **kw).gain == 3.0
    assert QualityController(0.95, contraction=0.0, **kw).gain == 1.0
    assert QualityController(0.95, contraction=0.5, **kw).gain == 2.0
    assert QualityController(0.95, gain=5.0, contraction=0.5, **kw).gain == 5.0
    with pytest.raises(ValueError, match="contraction"):
        QualityController(0.95, contraction=1.0, **kw)


def test_algorithms_declare_contraction_and_engine_wires_it():
    """The min/max-semiring relaxations declare contraction 0 (their
    sweeps settle — residuals don't amplify geometrically), the damped
    ranking algebras declare nothing (conservative legacy gain), and the
    engine threads the declaration into its controller."""
    src, dst = gnm_edges(120, 700, seed=2)
    caps = dict(node_capacity=120, edge_capacity=2048)
    with repro.session((src, dst), algorithm="sssp",
                       quality_target=0.9, **caps) as s:
        assert s.algorithm.drift_contraction == 0.0
        assert s.engine.controller.gain == 1.0
    with repro.session((src, dst), algorithm="pagerank",
                       quality_target=0.9, **caps) as s:
        assert s.algorithm.drift_contraction is None
        assert s.engine.controller.gain == 3.0


def test_calibrated_gain_cuts_refreshes_on_quiet_min_plus_stream():
    """The ISSUE 10 calibration pin: on a low-churn min_plus stream the
    calibrated controller (sssp declares contraction 0 -> gain 1)
    refreshes strictly less often than the legacy blanket gain=3 -- same
    stream, same budget -- while its measured rank quality (RBO@100 vs
    an exact-oracle replay) never drops below 0.95."""
    n, m, steps, chunk = 300, 1_800, 10, 6
    src, dst = gnm_edges(n, m, seed=21)
    stream = _drifting_stream(n, steps, chunk, seed=11)
    caps = dict(node_capacity=n, edge_capacity=m + steps * chunk + 512)

    def replay(legacy_gain):
        with repro.session((src, dst), algorithm="sssp", sources=(0, 7),
                           quality_target=0.95, **caps) as s:
            if legacy_gain:
                # reproduce the pre-calibration controller byte-for-byte:
                # identical loop, only the blanket gain restored
                s.engine.controller.gain = 3.0
            scores = []
            for a, b in stream:
                s.add_edges(a, b)
                scores.append(np.asarray(s.query().scores))
            return scores, s.engine.controller.refreshes

    cal_scores, cal_refreshes = replay(False)
    _, leg_refreshes = replay(True)
    assert leg_refreshes >= 1            # legacy over-refreshes here...
    assert cal_refreshes < leg_refreshes  # ...calibration stops paying

    with repro.session((src, dst), algorithm="sssp", sources=(0, 7),
                       on_query=lambda q, v: Action.EXACT, **caps) as oracle:
        quality = []
        for (a, b), approx in zip(stream, cal_scores):
            oracle.add_edges(a, b)
            exact = np.asarray(oracle.query().scores)
            # distances rank ascending: negate so rbo's descending sort
            # puts nearest vertices first (unreachable +inf -> last)
            quality.append(rbo_from_scores(
                jnp.asarray(-approx), jnp.asarray(-exact), depth=100))
    assert min(quality) >= 0.95
